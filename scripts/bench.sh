#!/usr/bin/env bash
# Run the hot-path benchmark suite and write BENCH_hotpath.json at the
# repo root (the machine-readable perf trajectory every perf PR updates;
# see EXPERIMENTS.md §Perf).
#
# Usage: scripts/bench.sh [extra cargo bench args...]
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
export BENCH_JSON="${BENCH_JSON:-$ROOT/BENCH_hotpath.json}"

cd "$ROOT/rust"
cargo bench --bench hotpath "$@"

echo "bench results: $BENCH_JSON"
