#!/usr/bin/env bash
# Run the hot-path benchmark suite and write BENCH_hotpath.json at the
# repo root (the machine-readable perf trajectory every perf PR updates;
# see EXPERIMENTS.md §Perf), then print a measured-vs-committed delta
# summary so before/after never needs manual JSON diffing.
#
# Usage: scripts/bench.sh [extra cargo bench args...]
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
export BENCH_JSON="${BENCH_JSON:-$ROOT/BENCH_hotpath.json}"

# Snapshot the committed trajectory before the bench overwrites it, so
# the delta table below compares against what the repo carried.
BASELINE=""
if [[ -f "$ROOT/BENCH_hotpath.json" ]]; then
  BASELINE="$(mktemp "${TMPDIR:-/tmp}/bench_committed.XXXXXX.json")"
  trap 'rm -f "$BASELINE"' EXIT
  cp "$ROOT/BENCH_hotpath.json" "$BASELINE"
fi

cd "$ROOT/rust"
cargo bench --bench hotpath "$@"

echo "bench results: $BENCH_JSON"

if [[ -n "$BASELINE" ]] && command -v python3 >/dev/null 2>&1; then
  python3 - "$BASELINE" "$BENCH_JSON" <<'PY'
import json, sys

committed = {r["name"]: r for r in json.load(open(sys.argv[1]))["results"]}
fresh = {r["name"]: r for r in json.load(open(sys.argv[2]))["results"]}

print("\n=== measured vs committed (ns/iter) ===")
print(f"{'case':<56} {'committed':>12} {'measured':>12} {'delta':>8}")
for name, f in fresh.items():
    c = committed.get(name)
    if c is None:
        print(f"{name:<56} {'(new)':>12} {f['ns_per_iter']:>12.0f} {'':>8}")
        continue
    flag = "~" if c.get("estimated") else ""
    ratio = c["ns_per_iter"] / f["ns_per_iter"] if f["ns_per_iter"] else float("inf")
    # >1x = faster than the committed number, <1x = slower.
    print(
        f"{name:<56} {flag}{c['ns_per_iter']:>11.0f} {f['ns_per_iter']:>12.0f} "
        f"{ratio:>7.2f}x"
    )
dropped = sorted(set(committed) - set(fresh))
if dropped:
    print("WARNING: committed cases missing from this run: %s" % dropped)
est = sum(1 for c in committed.values() if c.get("estimated"))
if est:
    print(f"(~ marks committed values that were flagged analytic estimates: {est} rows)")
PY
elif [[ -n "$BASELINE" ]]; then
  echo "bench.sh: note - python3 unavailable, skipped delta summary" >&2
fi
