#!/usr/bin/env bash
# One-entry-point CI gate for this repo (future PRs: run this first).
#
#   1. tier-1 gate:  cargo build --release && cargo test -q
#      (the test suite includes the bench-JSON validator smoke test —
#      tests/batched_equivalence.rs::committed_bench_trajectory_is_well_formed_json
#      runs util::bench::json_is_well_formed over BENCH_hotpath.json)
#   2. a toolchain-independent structural re-check of BENCH_hotpath.json
#      (python3 json.tool), so a corrupted perf trajectory is caught even
#      on machines without Rust.
#
# Usage: scripts/ci.sh [extra cargo test args...]
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

if command -v cargo >/dev/null 2>&1; then
  cd "$ROOT/rust"
  cargo build --release
  cargo test -q "$@"
  cd "$ROOT"
else
  echo "ci.sh: WARNING - no Rust toolchain on PATH; tier-1 gate skipped" >&2
fi

if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$ROOT/BENCH_hotpath.json" >/dev/null
  echo "ci.sh: BENCH_hotpath.json is well-formed JSON"
else
  echo "ci.sh: note - python3 unavailable, skipped standalone JSON check" >&2
fi

echo "ci.sh: done"
