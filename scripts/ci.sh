#!/usr/bin/env bash
# One-entry-point CI gate for this repo (future PRs: run this first).
#
#   1. tier-1 gate:  cargo build --release && cargo test -q
#      (the test suite includes the bench-JSON validator smoke test —
#      tests/batched_equivalence.rs::committed_bench_trajectory_is_well_formed_json
#      runs util::bench::json_is_well_formed over BENCH_hotpath.json)
#   2. a toolchain-independent structural re-check of BENCH_hotpath.json
#      (python3 json.tool), so a corrupted perf trajectory is caught even
#      on machines without Rust.
#
# Flags:
#   --require-toolchain  exit non-zero when cargo is missing instead of
#                        warn-and-pass. Hosted CI always passes this so
#                        "toolchain absent" can never masquerade as a
#                        green gate.
#   --smoke-bench        run one short hotpath bench iteration
#                        (BENCH_SMOKE=1, JSON to a temp path) and verify
#                        the fresh run still covers every case recorded
#                        in the committed BENCH_hotpath.json — a perf
#                        case silently dropped or a bench that no longer
#                        builds/runs fails CI. Also requires at least one
#                        fused serve-batch case in the fresh run (the
#                        ISSUE 7 lockstep serving path stays exercised).
#                        Requires the toolchain.
#   --fuzz-smoke         run the deterministic wire-codec fuzz target
#                        (tests/wire_fuzz.rs) at a fixed seeded budget
#                        (WIRE_FUZZ_CASES, default 12000 — the ISSUE 6
#                        "no reachable panic from hostile frame bytes"
#                        gate). Requires the toolchain.
#
# Usage: scripts/ci.sh [--require-toolchain] [--smoke-bench] [--fuzz-smoke]
#        [extra cargo test args...]
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

REQUIRE_TOOLCHAIN=0
SMOKE_BENCH=0
FUZZ_SMOKE=0
EXTRA_ARGS=()
for arg in "$@"; do
  case "$arg" in
    --require-toolchain) REQUIRE_TOOLCHAIN=1 ;;
    --smoke-bench) SMOKE_BENCH=1 ;;
    --fuzz-smoke) FUZZ_SMOKE=1 ;;
    *) EXTRA_ARGS+=("$arg") ;;
  esac
done

if command -v cargo >/dev/null 2>&1; then
  cd "$ROOT/rust"
  cargo build --release
  # The repo-root walkthrough drivers are registered example targets
  # (rust/Cargo.toml [[example]]): build them so they can never rot.
  cargo build --release --examples
  cargo test -q "${EXTRA_ARGS[@]+"${EXTRA_ARGS[@]}"}"

  if [[ "$FUZZ_SMOKE" == "1" ]]; then
    FUZZ_BUDGET="${WIRE_FUZZ_CASES:-12000}"
    echo "ci.sh: wire-codec fuzz (WIRE_FUZZ_CASES=$FUZZ_BUDGET, deterministic seeds)"
    WIRE_FUZZ_CASES="$FUZZ_BUDGET" cargo test -q --release --test wire_fuzz
  fi

  if [[ "$SMOKE_BENCH" == "1" ]]; then
    SMOKE_JSON="$(mktemp "${TMPDIR:-/tmp}/bench_smoke.XXXXXX.json")"
    trap 'rm -f "$SMOKE_JSON"' EXIT
    echo "ci.sh: smoke bench (BENCH_SMOKE=1, JSON -> $SMOKE_JSON)"
    BENCH_SMOKE=1 BENCH_JSON="$SMOKE_JSON" cargo bench --bench hotpath
    if command -v python3 >/dev/null 2>&1; then
      python3 - "$ROOT/BENCH_hotpath.json" "$SMOKE_JSON" <<'PY'
import json, sys
committed = {r["name"] for r in json.load(open(sys.argv[1]))["results"]}
fresh = {r["name"] for r in json.load(open(sys.argv[2]))["results"]}
missing = sorted(committed - fresh)
if missing:
    sys.exit("ci.sh: smoke bench no longer covers committed cases: %s" % missing)
fused = [n for n in fresh if "serve-batch" in n and "fused" in n]
if not fused:
    sys.exit("ci.sh: smoke bench exercises no fused serve-batch case "
             "(lockstep serving path, ISSUE 7)")
print("ci.sh: smoke bench covers all %d committed cases "
      "(incl. %d fused serve-batch)" % (len(committed), len(fused)))
PY
    else
      echo "ci.sh: note - python3 unavailable, skipped smoke/committed case comparison" >&2
    fi
  fi
  cd "$ROOT"
elif [[ "$REQUIRE_TOOLCHAIN" == "1" ]]; then
  echo "ci.sh: ERROR - --require-toolchain set but no cargo on PATH" >&2
  exit 1
else
  echo "ci.sh: WARNING - no Rust toolchain on PATH; tier-1 gate skipped" >&2
  if [[ "$SMOKE_BENCH" == "1" ]]; then
    echo "ci.sh: WARNING - --smoke-bench needs cargo; skipped" >&2
  fi
  if [[ "$FUZZ_SMOKE" == "1" ]]; then
    echo "ci.sh: WARNING - --fuzz-smoke needs cargo; skipped" >&2
  fi
fi

if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$ROOT/BENCH_hotpath.json" >/dev/null
  echo "ci.sh: BENCH_hotpath.json is well-formed JSON"
else
  echo "ci.sh: note - python3 unavailable, skipped standalone JSON check" >&2
fi

echo "ci.sh: done"
