#!/usr/bin/env bash
# One-entry-point CI gate for this repo (future PRs: run this first).
#
#   1. tier-1 gate:  cargo build --release && cargo test -q
#      (the test suite includes the bench-JSON validator smoke test —
#      tests/batched_equivalence.rs::committed_bench_trajectory_is_well_formed_json
#      runs util::bench::json_is_well_formed over BENCH_hotpath.json)
#   2. a toolchain-independent structural re-check of BENCH_hotpath.json
#      (python3 json.tool), so a corrupted perf trajectory is caught even
#      on machines without Rust.
#
# Flags:
#   --require-toolchain  exit non-zero when cargo is missing instead of
#                        warn-and-pass. Hosted CI always passes this so
#                        "toolchain absent" can never masquerade as a
#                        green gate.
#   --smoke-bench        run one short hotpath bench iteration
#                        (BENCH_SMOKE=1, JSON to a temp path) and verify
#                        the fresh run still covers every case recorded
#                        in the committed BENCH_hotpath.json — a perf
#                        case silently dropped or a bench that no longer
#                        builds/runs fails CI. Also requires at least one
#                        fused serve-batch case in the fresh run (the
#                        ISSUE 7 lockstep serving path stays exercised).
#                        Requires the toolchain.
#   --fuzz-smoke         run the deterministic wire-codec fuzz target
#                        (tests/wire_fuzz.rs) at a fixed seeded budget
#                        (WIRE_FUZZ_CASES, default 12000 — the ISSUE 6
#                        "no reachable panic from hostile frame bytes"
#                        gate). Requires the toolchain.
#   --telemetry-smoke    run a short artifact-free loadgen
#                        (`--engine mock`) with the streaming JSONL
#                        exporter on and validate the emitted file
#                        (python3): >= 2 lines, every line parses,
#                        strictly increasing t_ms, exactly the last
#                        line final, offered = admitted+shed+malformed
#                        per line and per interval, and interval
#                        deltas reconciling to the final cumulative
#                        counters (ISSUE 9). Requires the toolchain.
#   --fault-smoke        the ISSUE 10 robustness gate, two halves:
#                        (a) the seeded fault-plan serving runs in
#                        tests/fault_determinism.rs (synthetic-weight
#                        pooled engines through the full EdgeServer —
#                        the hosted runner has no trained artifacts):
#                        zero panics, bit-identical results across
#                        pool threads x fusion, and *exact*
#                        degraded_planes / faults_injected accounting;
#                        (b) a fault-free mock loadgen whose JSONL
#                        lines must carry a well-formed, reconciling,
#                        all-zero "faults" block + shutdown_forced
#                        (the inert-layer signature). Requires the
#                        toolchain.
#
# Usage: scripts/ci.sh [--require-toolchain] [--smoke-bench] [--fuzz-smoke]
#        [--telemetry-smoke] [--fault-smoke] [extra cargo test args...]
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

REQUIRE_TOOLCHAIN=0
SMOKE_BENCH=0
FUZZ_SMOKE=0
TELEMETRY_SMOKE=0
FAULT_SMOKE=0
EXTRA_ARGS=()
for arg in "$@"; do
  case "$arg" in
    --require-toolchain) REQUIRE_TOOLCHAIN=1 ;;
    --smoke-bench) SMOKE_BENCH=1 ;;
    --fuzz-smoke) FUZZ_SMOKE=1 ;;
    --telemetry-smoke) TELEMETRY_SMOKE=1 ;;
    --fault-smoke) FAULT_SMOKE=1 ;;
    *) EXTRA_ARGS+=("$arg") ;;
  esac
done

TMP_FILES=()
cleanup() {
  if [[ ${#TMP_FILES[@]} -gt 0 ]]; then
    rm -f "${TMP_FILES[@]}"
  fi
}
trap cleanup EXIT

if command -v cargo >/dev/null 2>&1; then
  cd "$ROOT/rust"
  cargo build --release
  # The repo-root walkthrough drivers are registered example targets
  # (rust/Cargo.toml [[example]]): build them so they can never rot.
  cargo build --release --examples
  cargo test -q "${EXTRA_ARGS[@]+"${EXTRA_ARGS[@]}"}"

  if [[ "$FUZZ_SMOKE" == "1" ]]; then
    FUZZ_BUDGET="${WIRE_FUZZ_CASES:-12000}"
    echo "ci.sh: wire-codec fuzz (WIRE_FUZZ_CASES=$FUZZ_BUDGET, deterministic seeds)"
    WIRE_FUZZ_CASES="$FUZZ_BUDGET" cargo test -q --release --test wire_fuzz
  fi

  if [[ "$TELEMETRY_SMOKE" == "1" ]]; then
    TELEM_JSONL="$(mktemp "${TMPDIR:-/tmp}/telemetry_smoke.XXXXXX.jsonl")"
    TMP_FILES+=("$TELEM_JSONL")
    echo "ci.sh: telemetry smoke (mock engine loadgen, JSONL -> $TELEM_JSONL)"
    cargo run --release --quiet -- loadgen --engine mock --requests 400 --qps 2000 \
      --metrics-interval-ms 40 --metrics-out "$TELEM_JSONL"
    if command -v python3 >/dev/null 2>&1; then
      python3 - "$TELEM_JSONL" <<'PY'
import json, sys
lines = [l for l in open(sys.argv[1]).read().splitlines() if l.strip()]
if len(lines) < 2:
    sys.exit("ci.sh: telemetry smoke emitted %d line(s), want >= 2" % len(lines))
rows, prev_t = [], -1.0
for i, l in enumerate(lines):
    try:
        row = json.loads(l)
    except ValueError as e:
        sys.exit("ci.sh: telemetry line %d is not valid JSON (%s): %s" % (i, e, l))
    if row.get("schema") != "adcim.telemetry.v1":
        sys.exit("ci.sh: telemetry line %d has wrong schema tag" % i)
    if row["t_ms"] <= prev_t:
        sys.exit("ci.sh: t_ms not strictly increasing at line %d" % i)
    prev_t = row["t_ms"]
    if row["final"] != (i == len(lines) - 1):
        sys.exit("ci.sh: 'final' must mark exactly the last line (line %d)" % i)
    if row["offered"] != row["admitted"] + row["shed"] + row["rejected_malformed"]:
        sys.exit("ci.sh: cumulative offered identity broken at line %d" % i)
    iv = row["interval"]
    if iv["offered"] != iv["admitted"] + iv["shed"] + iv["malformed"]:
        sys.exit("ci.sh: interval offered identity broken at line %d" % i)
    rows.append(row)
last = rows[-1]
for key, total in (("admitted", last["admitted"]), ("shed", last["shed"]),
                   ("malformed", last["rejected_malformed"]),
                   ("completed", last["completed"])):
    delta_sum = sum(r["interval"][key] for r in rows)
    if delta_sum != total:
        sys.exit("ci.sh: interval %s deltas sum to %d, final cumulative is %d"
                 % (key, delta_sum, total))
print("ci.sh: telemetry smoke - %d validator-clean lines, deltas reconcile"
      % len(lines))
PY
    else
      echo "ci.sh: note - python3 unavailable, skipped telemetry JSONL validation" >&2
    fi
  fi

  if [[ "$FAULT_SMOKE" == "1" ]]; then
    echo "ci.sh: fault smoke (seeded fault-plan serving, exact blast-radius accounting)"
    cargo test -q --release --test fault_determinism
    FAULT_JSONL="$(mktemp "${TMPDIR:-/tmp}/fault_smoke.XXXXXX.jsonl")"
    TMP_FILES+=("$FAULT_JSONL")
    echo "ci.sh: fault smoke (fault-free JSONL faults block -> $FAULT_JSONL)"
    cargo run --release --quiet -- loadgen --engine mock --requests 200 --qps 2000 \
      --metrics-interval-ms 40 --metrics-out "$FAULT_JSONL"
    if command -v python3 >/dev/null 2>&1; then
      python3 - "$FAULT_JSONL" <<'PY'
import json, sys
KEYS = ("injected", "stuck_cells", "drifting", "dead", "arrays_down", "probes_run",
        "probes_failed", "quarantined", "degraded_planes", "rerouted", "mav_oob")
lines = [l for l in open(sys.argv[1]).read().splitlines() if l.strip()]
if not lines:
    sys.exit("ci.sh: fault smoke emitted no telemetry lines")
for i, l in enumerate(lines):
    row = json.loads(l)
    ft = row.get("faults")
    if ft is None:
        sys.exit("ci.sh: line %d has no 'faults' block (stable-schema contract)" % i)
    missing = [k for k in KEYS if k not in ft]
    if missing:
        sys.exit("ci.sh: line %d faults block missing keys %s" % (i, missing))
    by_type = ft["stuck_cells"] + ft["drifting"] + ft["dead"] + ft["arrays_down"]
    if ft["injected"] != by_type:
        sys.exit("ci.sh: line %d injected=%d but per-type counters sum to %d"
                 % (i, ft["injected"], by_type))
    if "shutdown_forced" not in row:
        sys.exit("ci.sh: line %d has no shutdown_forced counter" % i)
    if any(ft[k] for k in KEYS) or row["shutdown_forced"]:
        sys.exit("ci.sh: fault-free run reported nonzero fault/shutdown counters "
                 "at line %d: %s" % (i, l))
print("ci.sh: fault smoke - %d lines, faults block well-formed, reconciling, inert"
      % len(lines))
PY
    else
      echo "ci.sh: note - python3 unavailable, skipped fault JSONL validation" >&2
    fi
  fi

  if [[ "$SMOKE_BENCH" == "1" ]]; then
    SMOKE_JSON="$(mktemp "${TMPDIR:-/tmp}/bench_smoke.XXXXXX.json")"
    TMP_FILES+=("$SMOKE_JSON")
    echo "ci.sh: smoke bench (BENCH_SMOKE=1, JSON -> $SMOKE_JSON)"
    BENCH_SMOKE=1 BENCH_JSON="$SMOKE_JSON" cargo bench --bench hotpath
    if command -v python3 >/dev/null 2>&1; then
      python3 - "$ROOT/BENCH_hotpath.json" "$SMOKE_JSON" <<'PY'
import json, sys
committed = {r["name"] for r in json.load(open(sys.argv[1]))["results"]}
fresh = {r["name"] for r in json.load(open(sys.argv[2]))["results"]}
missing = sorted(committed - fresh)
if missing:
    sys.exit("ci.sh: smoke bench no longer covers committed cases: %s" % missing)
fused = [n for n in fresh if "serve-batch" in n and "fused" in n]
if not fused:
    sys.exit("ci.sh: smoke bench exercises no fused serve-batch case "
             "(lockstep serving path, ISSUE 7)")
print("ci.sh: smoke bench covers all %d committed cases "
      "(incl. %d fused serve-batch)" % (len(committed), len(fused)))
PY
    else
      echo "ci.sh: note - python3 unavailable, skipped smoke/committed case comparison" >&2
    fi
  fi
  cd "$ROOT"
elif [[ "$REQUIRE_TOOLCHAIN" == "1" ]]; then
  echo "ci.sh: ERROR - --require-toolchain set but no cargo on PATH" >&2
  exit 1
else
  echo "ci.sh: WARNING - no Rust toolchain on PATH; tier-1 gate skipped" >&2
  if [[ "$SMOKE_BENCH" == "1" ]]; then
    echo "ci.sh: WARNING - --smoke-bench needs cargo; skipped" >&2
  fi
  if [[ "$FUZZ_SMOKE" == "1" ]]; then
    echo "ci.sh: WARNING - --fuzz-smoke needs cargo; skipped" >&2
  fi
  if [[ "$TELEMETRY_SMOKE" == "1" ]]; then
    echo "ci.sh: WARNING - --telemetry-smoke needs cargo; skipped" >&2
  fi
  if [[ "$FAULT_SMOKE" == "1" ]]; then
    echo "ci.sh: WARNING - --fault-smoke needs cargo; skipped" >&2
  fi
fi

if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$ROOT/BENCH_hotpath.json" >/dev/null
  echo "ci.sh: BENCH_hotpath.json is well-formed JSON"
else
  echo "ci.sh: note - python3 unavailable, skipped standalone JSON check" >&2
fi

echo "ci.sh: done"
