"""AOT compile path: train the L2 model, lower to HLO *text*, export.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs (consumed by the rust runtime; python never runs at serve time):

- ``model_float.hlo.txt`` — float forward with trained weights baked in:
  f32[BATCH, 144] image batch -> tuple(f32[BATCH, 10]) logits.
- ``model_quant.hlo.txt``  — the ADC-free forward (4-bit inputs, 1-bit
  product-sum BWHT) with the same weights.
- ``bwht_kernel.hlo.txt``  — the L1 Pallas BWHT layer alone (micro path).
- ``model.weights.bin`` / ``model.manifest.txt`` — raw little-endian f32
  weights + name/shape/offset manifest.
- ``test_batch.bin`` / ``test_labels.txt`` / ``expected_logits.bin`` —
  a held-out batch and the float-path logits the rust integration tests
  compare against bit-for-bit (same HLO, same PJRT CPU backend).

HLO **text** (not ``.serialize()``) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

BATCH = 16
TRAIN_N = 600
TEST_N = 160
FLOAT_EPOCHS = 12
QUANT_EPOCHS = 8
INPUT_BITS = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned).

    ``print_large_constants=True`` is essential: the default elides the
    baked weight tensors as ``{...}``, which the text parser silently
    reads back as zeros — the model would compile and run but ignore its
    input entirely.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_weights(params, out_dir):
    """Flat little-endian f32 blob + manifest (name shape offset)."""
    flat = []
    manifest = []
    offset = 0
    for name in sorted(params.keys()):
        arr = np.asarray(params[name], dtype=np.float32)
        flat.append(arr.ravel())
        manifest.append(
            {"name": name, "shape": list(arr.shape), "offset": offset,
             "len": int(arr.size)})
        offset += arr.size
    blob = np.concatenate(flat) if flat else np.zeros(0, np.float32)
    blob.tofile(os.path.join(out_dir, "model.weights.bin"))
    with open(os.path.join(out_dir, "model.manifest.txt"), "w") as f:
        json.dump({"params": manifest, "total_f32": int(blob.size),
                   "batch": BATCH, "input": model.INPUT,
                   "classes": model.CLASSES, "hidden": model.HIDDEN,
                   "input_bits": INPUT_BITS}, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=FLOAT_EPOCHS)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # ---- train -----------------------------------------------------
    xs, ys = model.digits_dataset(TRAIN_N + TEST_N, seed=3)
    xtr, ytr = xs[:TRAIN_N], ys[:TRAIN_N]
    xte, yte = xs[TRAIN_N:], ys[TRAIN_N:]
    params = model.init_params(jax.random.PRNGKey(0))
    params, fl = model.train(params, xtr, ytr, epochs=args.epochs, lr=0.1)
    acc_f = model.accuracy(params, xte, yte)
    # Quantization-aware fine-tune against the 1-bit product-sum path,
    # with the threshold-widening pull of Fig 6.
    params, ql = model.train(params, xtr, ytr, epochs=QUANT_EPOCHS, lr=0.03,
                             input_bits=INPUT_BITS, t_reg=0.002)
    acc_q = model.accuracy(params, xte, yte, input_bits=INPUT_BITS)
    print(f"float acc {acc_f:.3f} | quant({INPUT_BITS}b,1b-sum) acc {acc_q:.3f}")
    print(f"float loss curve  {[round(x, 3) for x in fl]}")
    print(f"quant loss curve  {[round(x, 3) for x in ql]}")

    # ---- lower to HLO text ------------------------------------------
    spec = jax.ShapeDtypeStruct((BATCH, model.INPUT), jnp.float32)

    def fwd_float(x):
        return (model.apply_float(params, x),)

    def fwd_quant(x):
        return (model.apply_quantized(params, x, INPUT_BITS),)

    for name, fn in [("model_float", fwd_float), ("model_quant", fwd_quant)]:
        text = to_hlo_text(jax.jit(fn).lower(spec))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # L1 kernel alone (fixed trained thresholds baked in).
    kspec = jax.ShapeDtypeStruct((BATCH, model.HIDDEN), jnp.float32)
    t_trained = params["t"]

    def kernel_fn(x):
        from .kernels import bwht as k
        return (k.bwht_layer(x, t_trained),)

    text = to_hlo_text(jax.jit(kernel_fn).lower(kspec))
    with open(os.path.join(args.out_dir, "bwht_kernel.hlo.txt"), "w") as f:
        f.write(text)
    print(f"wrote bwht_kernel.hlo.txt ({len(text)} chars)")

    # ---- weights + golden vectors ------------------------------------
    export_weights(params, args.out_dir)
    batch = xte[:BATCH].astype(np.float32)
    batch.tofile(os.path.join(args.out_dir, "test_batch.bin"))
    with open(os.path.join(args.out_dir, "test_labels.txt"), "w") as f:
        f.write(" ".join(str(int(l)) for l in yte[:BATCH]))
    logits = np.asarray(model.apply_float(params, jnp.asarray(batch)),
                        dtype=np.float32)
    logits.tofile(os.path.join(args.out_dir, "expected_logits.bin"))
    logits_q = np.asarray(
        model.apply_quantized(params, jnp.asarray(batch), INPUT_BITS),
        dtype=np.float32)
    logits_q.tofile(os.path.join(args.out_dir, "expected_logits_quant.bin"))
    meta = {
        "float_test_acc": acc_f, "quant_test_acc": acc_q,
        "input_bits": INPUT_BITS, "batch": BATCH,
        "float_loss": fl, "quant_loss": ql,
    }
    with open(os.path.join(args.out_dir, "train_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print("artifacts complete")


if __name__ == "__main__":
    main()
