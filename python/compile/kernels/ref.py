"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every kernel in bwht.py is
pytest-checked against these functions (python/tests/test_kernel.py),
and the rust crate's own WHT substrate mirrors the same math
(rust/src/wht), so all three layers agree on the transform.
"""

import jax.numpy as jnp
import numpy as np


def hadamard_matrix(m: int) -> np.ndarray:
    """Dense natural-order Hadamard matrix H_k (Sylvester recursion,
    paper eq. (2)). m must be a power of two."""
    assert m & (m - 1) == 0 and m > 0, f"order must be a power of two, got {m}"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < m:
        h = np.block([[h, h], [h, -h]])
    return h


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalised Walsh-Hadamard transform along the last axis
    (natural/Hadamard order), as a dense matmul oracle."""
    m = x.shape[-1]
    return x @ jnp.asarray(hadamard_matrix(m)).T


def soft_threshold_ref(x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """S_T(x) = sign(x) * max(|x| - T, 0) (paper eq. (3))."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - jnp.abs(t), 0.0)


def bwht_layer_ref(x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Float BWHT layer: y = H S_T(H x) / m over the last axis.

    x: [..., m] with m a power of two; t: [m] per-coefficient thresholds.
    """
    m = x.shape[-1]
    z = fwht_ref(x)
    y = soft_threshold_ref(z, t)
    return fwht_ref(y) / m


def bitplane_transform_ref(levels: jnp.ndarray, bits: int, gamma: float,
                           step: float) -> jnp.ndarray:
    """1-bit product-sum quantized transform (paper SS III-B, Fig 4).

    levels: [..., m] unsigned integer levels (< 2**bits).
    Per bitplane p: d_p = H . plane_p; s_p = +-1 by sign (ties -> -1,
    matching the crossbar comparator's strict >); output is
    gamma * step * sum_p 2^p s_p.
    """
    m = levels.shape[-1]
    h = jnp.asarray(hadamard_matrix(m))
    acc = jnp.zeros(levels.shape, dtype=jnp.float32)
    for p in range(bits):
        plane = ((levels >> p) & 1).astype(jnp.float32)
        d = plane @ h.T
        s = jnp.where(d > 0, 1.0, -1.0)
        acc = acc + (2.0 ** p) * s
    return gamma * step * acc


def quantize_ref(x: jnp.ndarray, bits: int, hi: float) -> jnp.ndarray:
    """Affine quantization of [0, hi] onto {0..2^bits-1} (round-half-up,
    matching rust UniformQuantizer)."""
    levels = (1 << bits) - 1
    t = jnp.clip(x / hi, 0.0, 1.0)
    return jnp.floor(t * levels + 0.5).astype(jnp.uint32)
