"""L1: Pallas kernels for the BWHT layer (paper §III).

TPU-adaptation notes (DESIGN.md §Hardware-Adaptation): the paper's hot
spot is an analog ±1 crossbar; on TPU-class hardware the same insight —
a Walsh–Hadamard transform needs no multiplies — maps to *addition-only
butterflies* on the VPU, tiled so one Hadamard block lives in a single
VMEM tile. BlockSpec carries the batch grid (the HBM↔VMEM schedule that
the silicon does with row/column-merge signals); the butterfly runs
log2(m) stages in-register. No MXU matmul is emitted for the transform.

All kernels use ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md). Numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step: one VMEM tile of the batch.
_BATCH_TILE = 8


def _fwht_stages(v):
    """In-register FWHT butterfly over the last axis (length m, power of
    two): log2(m) stages of reshape/add/sub — no multiplies, no matmul."""
    m = v.shape[-1]
    n_stages = m.bit_length() - 1
    lead = v.shape[:-1]
    for s in range(n_stages):
        h = 1 << s
        # Pair elements at distance h: reshape to [..., m/(2h), 2, h].
        w = v.reshape(lead + (m // (2 * h), 2, h))
        a = w[..., 0, :]
        b = w[..., 1, :]
        v = jnp.stack([a + b, a - b], axis=-2).reshape(lead + (m,))
    return v


def _bwht_kernel_body(x_ref, t_ref, o_ref):
    """One batch tile: z = H x; y = S_T(z); o = H y / m."""
    x = x_ref[...]
    m = x.shape[-1]
    z = _fwht_stages(x)
    t = jnp.abs(t_ref[...])
    y = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)
    o_ref[...] = _fwht_stages(y) / m


def _bwht_layer_pallas(x, t):
    """Raw Pallas call (not differentiable by itself)."""
    b, m = x.shape
    assert m & (m - 1) == 0, f"m must be a power of two, got {m}"
    tile = min(_BATCH_TILE, b)
    assert b % tile == 0, f"batch {b} not divisible by tile {tile}"
    return pl.pallas_call(
        _bwht_kernel_body,
        grid=(b // tile,),
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(x, t)


@jax.custom_vjp
def bwht_layer(x, t):
    """Float BWHT layer via Pallas: x [b, m], t [m] -> [b, m].

    m must be a power of two (the caller pads; see rust BwhtLayout).
    Differentiable: interpret-mode Pallas has no AD rule, so the VJP is
    supplied explicitly — and since H is symmetric, the backward pass is
    the *same butterfly kernel* (y = H S_T(Hx)/m ⇒ gx = H(mask ∘ Hg/m))."""
    return _bwht_layer_pallas(x, t)


def _bwht_layer_fwd(x, t):
    z = fwht(x)  # residual: frequency-domain pre-activation
    return _bwht_layer_pallas(x, t), (z, t)


def _bwht_layer_bwd(res, g):
    z, t = res
    m = z.shape[-1]
    gy = fwht(g) / m
    mask = (jnp.abs(z) > jnp.abs(t)).astype(g.dtype)
    gz = gy * mask
    gx = fwht(gz)
    # dS/dT = -sign(z) where passing; d|t|/dt = sign(t); sum over batch.
    gt = jnp.sum(-jnp.sign(z) * gy * mask * jnp.sign(t), axis=0)
    return gx, gt


bwht_layer.defvjp(_bwht_layer_fwd, _bwht_layer_bwd)


def _bitplane_kernel_body(levels_ref, o_ref, *, bits, gamma, step):
    """One batch tile of the 1-bit product-sum path (paper Fig 4):
    per plane p, transform the {0,1} plane and keep only the sign."""
    levels = levels_ref[...]
    acc = jnp.zeros(levels.shape, dtype=jnp.float32)
    for p in range(bits):
        plane = ((levels >> p) & 1).astype(jnp.float32)
        d = _fwht_stages(plane)
        s = jnp.where(d > 0, 1.0, -1.0)
        acc = acc + (2.0 ** p) * s
    o_ref[...] = gamma * step * acc


def bitplane_transform(levels, bits: int, gamma: float, step: float):
    """ADC-free quantized transform via Pallas: levels [b, m] uint32 ->
    [b, m] f32 reconstruction (gamma*step*Σ 2^p sign(H·plane_p))."""
    b, m = levels.shape
    assert m & (m - 1) == 0, f"m must be a power of two, got {m}"
    tile = min(_BATCH_TILE, b)
    assert b % tile == 0, f"batch {b} not divisible by tile {tile}"
    body = functools.partial(
        _bitplane_kernel_body, bits=bits, gamma=gamma, step=step
    )
    return pl.pallas_call(
        body,
        grid=(b // tile,),
        in_specs=[pl.BlockSpec((tile, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(levels)


def _fwht_pallas(x):
    b, m = x.shape
    assert m & (m - 1) == 0
    tile = min(_BATCH_TILE, b)
    assert b % tile == 0

    def body(x_ref, o_ref):
        o_ref[...] = _fwht_stages(x_ref[...])

    return pl.pallas_call(
        body,
        grid=(b // tile,),
        in_specs=[pl.BlockSpec((tile, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(x)


@jax.custom_vjp
def fwht(x):
    """Bare unnormalised FWHT over the last axis via Pallas.

    Differentiable: H is symmetric, so the VJP of `Hx` is `Hg` — the
    same kernel again."""
    return _fwht_pallas(x)


def _fwht_fwd(x):
    return _fwht_pallas(x), None


def _fwht_bwd(_res, g):
    return (_fwht_pallas(g),)


fwht.defvjp(_fwht_fwd, _fwht_bwd)
