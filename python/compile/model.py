"""L2: the frequency-domain model in JAX, calling the L1 Pallas kernels.

The model mirrors the rust-side ``nn::model::bwht_mlp`` — the digit
classifier whose hidden stage is the paper's BWHT + soft-threshold layer:

    Dense(input -> hidden) -> ReLU -> BWHT(S_T) -> ReLU -> Dense(hidden -> classes)

Two inference paths share the trained parameters:

- ``apply_float``     — float BWHT via the Pallas butterfly kernel.
- ``apply_quantized`` — the ADC-free path: inputs quantized to
  ``input_bits``, the transform's per-plane sums quantized to ONE bit
  (paper SS III-B), reassembled with the trained gain. Training runs
  against this path with a straight-through estimator, exactly as the
  paper trains against extreme quantization (Fig 5).

Python is build-time only: aot.py lowers ``apply_float`` /
``apply_quantized`` (with trained weights baked in) to HLO text that the
rust runtime loads via PJRT.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bwht as kernels

HIDDEN = 32          # power of two: one Hadamard block / one crossbar
INPUT = 144          # 12x12 synthetic digit images
CLASSES = 10
IN_QUANT_HI = 4.0


def init_params(rng_key):
    k1, k2, k3 = jax.random.split(rng_key, 3)
    s1 = (2.0 / INPUT) ** 0.5
    s2 = (2.0 / HIDDEN) ** 0.5
    return {
        "w1": jax.random.normal(k1, (INPUT, HIDDEN)) * s1,
        "b1": jnp.zeros((HIDDEN,)),
        "t": 0.01 + 0.02 * jax.random.uniform(k2, (HIDDEN,)),
        "gamma": jnp.asarray(HIDDEN ** 0.5 / 2.0),
        "w2": jax.random.normal(k3, (HIDDEN, CLASSES)) * s2,
        "b2": jnp.zeros((CLASSES,)),
    }


def apply_float(params, x):
    """Float forward: x [b, INPUT] -> logits [b, CLASSES]."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = kernels.bwht_layer(h, params["t"])
    h = jax.nn.relu(h)
    return h @ params["w2"] + params["b2"]


def _fake_quant_ste(x, bits, hi):
    """Quantize-dequantize with straight-through gradient."""
    levels = (1 << bits) - 1
    t = jnp.clip(x / hi, 0.0, 1.0)
    q = jnp.round(t * levels) / levels * hi
    return x + jax.lax.stop_gradient(q - x)


def _one_bit_transform_ste(h, params, input_bits):
    """1-bit product-sum BWHT with STE backward = float transform."""
    step = IN_QUANT_HI / ((1 << input_bits) - 1)
    hq = _fake_quant_ste(h, input_bits, IN_QUANT_HI)
    levels = jnp.round(jnp.clip(hq / IN_QUANT_HI, 0.0, 1.0)
                       * ((1 << input_bits) - 1)).astype(jnp.uint32)
    zq = kernels.bitplane_transform(levels, input_bits,
                                    1.0, 1.0) * params["gamma"] * step
    # STE: forward value zq, gradient of the float transform.
    zf = kernels.fwht(hq)
    return zf + jax.lax.stop_gradient(zq - zf)


def apply_quantized(params, x, input_bits=4):
    """ADC-free forward (1-bit product-sum quantization, paper Fig 4)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    z = _one_bit_transform_ste(h, params, input_bits)
    t = jnp.abs(params["t"])
    y = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)
    h = kernels.fwht(y) / HIDDEN
    h = jax.nn.relu(h)
    return h @ params["w2"] + params["b2"]


def loss_fn(params, x, labels, input_bits=None, t_reg=0.0):
    """Softmax CE (+ optional threshold-widening regulariser, Fig 6)."""
    if input_bits is None:
        logits = apply_float(params, x)
    else:
        logits = apply_quantized(params, x, input_bits)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    return ce - t_reg * jnp.mean(jnp.abs(params["t"]))


# ------------------------------------------------------------- dataset

_GLYPHS = np.array([
    [1, 1, 1, 0, 1, 1, 1], [0, 0, 1, 0, 0, 1, 0], [1, 0, 1, 1, 1, 0, 1],
    [1, 0, 1, 1, 0, 1, 1], [0, 1, 1, 1, 0, 1, 0], [1, 1, 0, 1, 0, 1, 1],
    [1, 1, 0, 1, 1, 1, 1], [1, 0, 1, 0, 0, 1, 0], [1, 1, 1, 1, 1, 1, 1],
    [1, 1, 1, 1, 0, 1, 1]], dtype=bool)


def _segment_mask(seg, u, v, t):
    def hline(cy):
        return (np.abs(v - cy) < t) & (u >= 0.3) & (u <= 0.7)

    def vline(cx, lo, hi):
        return (np.abs(u - cx) < t) & (v >= lo) & (v <= hi)

    return [hline(0.15), vline(0.3, 0.15, 0.5), vline(0.7, 0.15, 0.5),
            hline(0.5), vline(0.3, 0.5, 0.85), vline(0.7, 0.5, 0.85),
            hline(0.85)][seg]


def digits_dataset(n, side=12, seed=3):
    """Procedural seven-segment digits — the same distribution the rust
    nn::dataset::digits generator draws from."""
    rs = np.random.RandomState(seed)
    xs = np.zeros((n, side * side), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    yy, xx = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    for i in range(n):
        d = rs.randint(10)
        jx, jy = rs.uniform(-0.1, 0.1, 2)
        t = 0.08 + 0.05 * rs.uniform()
        u = xx / side - jx
        v = yy / side - jy
        lit = np.zeros((side, side), dtype=bool)
        for seg in range(7):
            if _GLYPHS[d, seg]:
                lit |= _segment_mask(seg, u, v, t)
        img = np.where(lit, 0.9, 0.1) + 0.1 * rs.randn(side, side)
        xs[i] = np.clip(img, 0.0, 1.0).ravel()
        ys[i] = d
    return xs, ys


# ------------------------------------------------------------- training

def train(params, xs, ys, *, epochs=10, lr=0.1, batch=16, input_bits=None,
          t_reg=0.0, seed=0):
    """Plain SGD; returns (params, per-epoch losses)."""
    grad_fn = jax.jit(
        jax.value_and_grad(
            functools.partial(loss_fn, input_bits=input_bits, t_reg=t_reg)))
    n = xs.shape[0]
    rs = np.random.RandomState(seed)
    losses = []
    for _ in range(epochs):
        order = rs.permutation(n)
        epoch_loss = 0.0
        nb = 0
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            l, g = grad_fn(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
            params = jax.tree.map(lambda p, gi: p - lr * gi, params, g)
            epoch_loss += float(l)
            nb += 1
        losses.append(epoch_loss / max(nb, 1))
        lr *= 0.85
    return params, losses


def accuracy(params, xs, ys, input_bits=None, batch=16):
    n = (xs.shape[0] // batch) * batch
    correct = 0
    for i in range(0, n, batch):
        xb = jnp.asarray(xs[i:i + batch])
        logits = (apply_float(params, xb) if input_bits is None
                  else apply_quantized(params, xb, input_bits))
        correct += int((jnp.argmax(logits, axis=1)
                        == jnp.asarray(ys[i:i + batch])).sum())
    return correct / n
