"""AOT path: HLO text round-trips through the XLA parser and the
exported artifacts are mutually consistent."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from jax._src.lib import xla_client as xc


def test_hlo_text_parses_back():
    p = model.init_params(jax.random.PRNGKey(0))
    spec = jax.ShapeDtypeStruct((aot.BATCH, model.INPUT), jnp.float32)
    text = aot.to_hlo_text(jax.jit(lambda x: (model.apply_float(p, x),)).lower(spec))
    assert "ENTRY" in text and "f32[16,144]" in text.replace(" ", "")
    # REGRESSION GUARD: the default as_hlo_text() elides the baked weight
    # constants as "{...}", which parses back as zeros — the model then
    # ignores its input. print_large_constants=True must stay on.
    assert "{...}" not in text, "weight constants elided from HLO text"
    # The 0.5.1-era parser requirement that motivated text interchange:
    # ids in text form are reassigned on parse, so this must not throw.
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_weights_export_layout(tmp_path):
    p = model.init_params(jax.random.PRNGKey(1))
    aot.export_weights(p, str(tmp_path))
    blob = np.fromfile(tmp_path / "model.weights.bin", dtype=np.float32)
    man = json.loads((tmp_path / "model.manifest.txt").read_text())
    assert man["total_f32"] == blob.size
    # Every param recoverable by offset/len and bit-exact.
    for ent in man["params"]:
        arr = np.asarray(p[ent["name"]], dtype=np.float32).ravel()
        got = blob[ent["offset"]:ent["offset"] + ent["len"]]
        np.testing.assert_array_equal(got, arr)


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "model_float.hlo.txt")),
    reason="run `make artifacts` first",
)
def test_exported_artifacts_consistent():
    man = json.loads(
        open(os.path.join(ARTIFACTS, "model.manifest.txt")).read())
    batch = np.fromfile(os.path.join(ARTIFACTS, "test_batch.bin"),
                        dtype=np.float32).reshape(man["batch"], man["input"])
    logits = np.fromfile(os.path.join(ARTIFACTS, "expected_logits.bin"),
                         dtype=np.float32).reshape(man["batch"], man["classes"])
    labels = [int(t) for t in
              open(os.path.join(ARTIFACTS, "test_labels.txt")).read().split()]
    assert len(labels) == man["batch"]
    # The exported golden logits should classify most of the held-out
    # batch correctly (the trained model works).
    acc = float(np.mean(np.argmax(logits, axis=1) == np.asarray(labels)))
    assert acc > 0.5, f"golden accuracy {acc}"
