"""L2 correctness: model shapes, training behaviour, quantized path."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _data(n=96, seed=5):
    return model.digits_dataset(n, seed=seed)


def test_shapes():
    xs, _ = _data(32)
    p = model.init_params(jax.random.PRNGKey(0))
    lf = model.apply_float(p, jnp.asarray(xs[:16]))
    lq = model.apply_quantized(p, jnp.asarray(xs[:16]), 4)
    assert lf.shape == (16, model.CLASSES)
    assert lq.shape == (16, model.CLASSES)


def test_dataset_is_deterministic_and_labelled():
    xs1, ys1 = _data(20, seed=9)
    xs2, ys2 = _data(20, seed=9)
    np.testing.assert_array_equal(xs1, xs2)
    np.testing.assert_array_equal(ys1, ys2)
    assert set(ys1.tolist()) <= set(range(10))
    assert xs1.min() >= 0.0 and xs1.max() <= 1.0


def test_float_training_reduces_loss():
    xs, ys = _data(160)
    p = model.init_params(jax.random.PRNGKey(1))
    p, losses = model.train(p, xs, ys, epochs=4, lr=0.1)
    assert losses[-1] < losses[0]
    assert model.accuracy(p, xs, ys) > 0.3  # chance = 0.1


def test_quantized_training_works_and_tracks_float():
    """Fig 5 shape: quant-aware training converges within a few points
    of the float baseline (paper: 3-4% lower at convergence)."""
    xs, ys = _data(240)
    p0 = model.init_params(jax.random.PRNGKey(2))
    pf, _ = model.train(p0, xs, ys, epochs=6, lr=0.1)
    acc_f = model.accuracy(pf, xs, ys)
    pq, _ = model.train(p0, xs, ys, epochs=6, lr=0.1, input_bits=4)
    acc_q = model.accuracy(pq, xs, ys, input_bits=4)
    assert acc_q > 0.3, f"quantized path failed to learn: {acc_q}"
    assert acc_q > acc_f - 0.35, f"float {acc_f} vs quant {acc_q}"


def test_t_reg_widens_thresholds():
    """The Fig 6 regulariser must push |T| outward."""
    xs, ys = _data(160)
    p0 = model.init_params(jax.random.PRNGKey(3))
    p_plain, _ = model.train(p0, xs, ys, epochs=3, lr=0.05)
    p_reg, _ = model.train(p0, xs, ys, epochs=3, lr=0.05, t_reg=0.05)
    t_plain = float(jnp.mean(jnp.abs(p_plain["t"])))
    t_reg = float(jnp.mean(jnp.abs(p_reg["t"])))
    assert t_reg > t_plain, f"{t_reg} !> {t_plain}"


def test_quantized_forward_is_deterministic():
    xs, _ = _data(16)
    p = model.init_params(jax.random.PRNGKey(4))
    a = model.apply_quantized(p, jnp.asarray(xs[:16]), 4)
    b = model.apply_quantized(p, jnp.asarray(xs[:16]), 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
