"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/bit-widths; every kernel output must match its
ref.py oracle to float tolerance (bitplane path: exactly).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bwht, ref

POW2 = [8, 16, 32, 64, 128]
BATCHES = [1, 2, 8, 16]


@settings(max_examples=30, deadline=None)
@given(
    m=st.sampled_from(POW2),
    b=st.sampled_from(BATCHES),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_dense_oracle(m, b, seed):
    x = np.random.RandomState(seed).randn(b, m).astype(np.float32)
    got = bwht.fwht(jnp.asarray(x))
    exp = ref.fwht_ref(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-3 * m)


@settings(max_examples=30, deadline=None)
@given(
    m=st.sampled_from(POW2),
    b=st.sampled_from(BATCHES),
    seed=st.integers(0, 2**31 - 1),
    tscale=st.floats(0.0, 5.0),
)
def test_bwht_layer_matches_oracle(m, b, seed, tscale):
    rs = np.random.RandomState(seed)
    x = rs.randn(b, m).astype(np.float32)
    t = (tscale * np.abs(rs.randn(m))).astype(np.float32)
    got = bwht.bwht_layer(jnp.asarray(x), jnp.asarray(t))
    exp = ref.bwht_layer_ref(jnp.asarray(x), jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    bits=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitplane_transform_exact_vs_oracle(m, bits, seed):
    rs = np.random.RandomState(seed)
    levels = rs.randint(0, 1 << bits, (8, m)).astype(np.uint32)
    gamma, step = 2.5, 0.125
    got = bwht.bitplane_transform(jnp.asarray(levels), bits, gamma, step)
    exp = ref.bitplane_transform_ref(jnp.asarray(levels), bits, gamma, step)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_fwht_self_inverse():
    x = np.random.RandomState(0).randn(8, 64).astype(np.float32)
    y = bwht.fwht(bwht.fwht(jnp.asarray(x))) / 64.0
    np.testing.assert_allclose(np.asarray(y), x, rtol=1e-4, atol=1e-4)


def test_bwht_layer_zero_threshold_is_identity():
    x = np.random.RandomState(1).randn(8, 32).astype(np.float32)
    t = np.zeros(32, np.float32)
    y = bwht.bwht_layer(jnp.asarray(x), jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(y), x, rtol=1e-4, atol=1e-4)


def test_bwht_layer_huge_threshold_zeroes():
    x = np.random.RandomState(2).randn(8, 32).astype(np.float32)
    t = np.full(32, 1e6, np.float32)
    y = bwht.bwht_layer(jnp.asarray(x), jnp.asarray(t))
    assert float(jnp.abs(y).max()) < 1e-5


def test_bwht_layer_gradients_match_oracle():
    """custom_vjp vs jax-AD of the dense oracle."""
    x = np.random.RandomState(3).randn(8, 16).astype(np.float32)
    t = (0.5 * np.abs(np.random.RandomState(4).randn(16))).astype(np.float32)

    def loss_kernel(x, t):
        return jnp.sum(bwht.bwht_layer(x, t) ** 2)

    def loss_ref(x, t):
        return jnp.sum(ref.bwht_layer_ref(x, t) ** 2)

    gx_k, gt_k = jax.grad(loss_kernel, argnums=(0, 1))(
        jnp.asarray(x), jnp.asarray(t))
    gx_r, gt_r = jax.grad(loss_ref, argnums=(0, 1))(
        jnp.asarray(x), jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gt_k), np.asarray(gt_r),
                               rtol=1e-3, atol=1e-3)


def test_quantize_round_half_up():
    x = jnp.asarray([0.0, 0.49, 0.51, 3.99, 4.0, 9.0], dtype=jnp.float32)
    q = ref.quantize_ref(x, 4, 4.0)
    # step = 4/15; levels = round(x/4*15 + eps)
    exp = np.floor(np.clip(np.asarray(x) / 4.0, 0, 1) * 15 + 0.5)
    np.testing.assert_array_equal(np.asarray(q), exp.astype(np.uint32))


def test_non_pow2_rejected():
    with pytest.raises(AssertionError):
        bwht.fwht(jnp.zeros((8, 24), jnp.float32))
