//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! supplies exactly the surface `adcim` uses — `Error`, `Result`,
//! `Context`, and the `anyhow!` / `bail!` / `ensure!` macros — with the
//! same call-site syntax as the real crate. Swapping in real `anyhow`
//! later is a one-line Cargo.toml change; no source edits needed.
//!
//! Like real `anyhow`, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that is what allows the blanket
//! `impl From<E: std::error::Error> for Error` to coexist with the
//! identity conversion used by `?` on an already-`anyhow` result.

use std::fmt::{self, Debug, Display};

/// A context-carrying error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with an outer context message (`"context: inner"`).
    pub fn context<C: Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg), source: self.source }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.msg, f)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().map(|s| s as &dyn std::error::Error);
        // Walk the source chain like anyhow's Debug output does.
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a fixed context message to the error/`None` case.
    fn context<C: Display>(self, ctx: C) -> Result<T, Error>;
    /// Attach a lazily-built context message.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return Err($crate::anyhow!($($t)+).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_wraps_message() {
        let e: Result<()> = io_fail().context("reading manifest");
        let msg = e.unwrap_err().to_string();
        assert!(msg.starts_with("reading manifest: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("param {} missing", "w1")).unwrap_err();
        assert_eq!(e.to_string(), "param w1 missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert!(f(99).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        assert!(f(1).unwrap_err().to_string().contains("fell through with 1"));
    }

    #[test]
    fn debug_prints_source_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "root cause").into();
        let e = e.context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
    }
}
