//! Compile-surface stub for the `xla` (PJRT) crate.
//!
//! The offline build image has no PJRT shared library and no crates.io
//! access, so the real `xla` crate cannot be built here. This stub
//! mirrors exactly the API surface `adcim::runtime::client` uses, with
//! every constructor failing at **runtime** with a clear message — so
//! `cargo build --features xla` type-checks the PJRT path end-to-end
//! without linking PJRT. Deploy targets with a real PJRT install swap
//! this path dependency for the real crate in rust/Cargo.toml.

use std::fmt;

/// Error type matching the real crate's `xla::Error` role.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what} is unavailable in the offline build \
         (vendor/xla-stub); install PJRT and use the real xla crate"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub).
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}
