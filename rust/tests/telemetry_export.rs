//! Telemetry-exporter integration: the streaming JSONL sink over a
//! live serving loop (ISSUE 9, satellite S3).
//!
//! Three layers of guarantee:
//!
//! 1. **Golden schema** — every line the sink emits during a real
//!    mixed-traffic run (plain submits + malformed wire frames) passes
//!    the in-house JSON well-formedness checker, carries the schema
//!    tag, and the export clock is strictly increasing.
//! 2. **Reconciliation** — per-interval rows satisfy
//!    `offered = admitted + shed + malformed`, and the summed interval
//!    deltas reproduce the final cumulative snapshot exactly
//!    (admitted / shed / malformed / completed / fused).
//! 3. **Determinism** — serving identical traffic with telemetry on
//!    and off yields bit-identical logits: stage stamping and counter
//!    sampling are observers, never participants.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use adcim::config::ServerConfig;
use adcim::coordinator::engine::MockEngine;
use adcim::coordinator::{EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy};
use adcim::util::bench::json_is_well_formed;
use adcim::util::loadgen::{self, LoadMode, LoadSpec};
use adcim::util::telemetry::TelemetrySink;

fn mock_engines(n: usize, delay_us: u64) -> Vec<Box<dyn InferenceEngine>> {
    (0..n)
        .map(|_| {
            Box::new(MockEngine {
                classes: 10,
                input: 4,
                delay: Duration::from_micros(delay_us),
            }) as Box<dyn InferenceEngine>
        })
        .collect()
}

/// `Write` handle into a shared buffer so the test can read back what
/// the sink wrote after the sink consumed the boxed writer.
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl std::io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// A paced open-loop run with malformed wire frames sprinkled in,
/// sampled by the sink on a 25 ms cadence: every emitted line is
/// validator-clean, time-ordered, satisfies the offered identity per
/// interval, and the interval deltas sum back to the final cumulative
/// snapshot. Stage breakdown telescopes under end-to-end latency.
#[test]
fn exporter_emits_validator_clean_reconciling_jsonl() {
    let cfg = ServerConfig {
        workers: 2,
        batch: 4,
        batch_deadline_us: 300,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, mock_engines(2, 300), RoutingPolicy::RoundRobin).unwrap();
    let buf = Arc::new(Mutex::new(Vec::new()));
    let mut sink = TelemetrySink::new(Box::new(SharedBuf(buf.clone())), 25).with_label("it");

    // 120 offers at ~1500 qps stretches the run across several export
    // intervals; every 10th offer is junk wire bytes (malformed).
    let spec = LoadSpec {
        mode: LoadMode::Open { qps: 1_500, burst: 4 },
        total: 120,
        drain: Duration::from_secs(10),
    };
    let report = loadgen::run_with_tick(
        &server,
        &spec,
        |i| {
            if i % 10 == 9 {
                server.submit_wire(0, &[0xde, 0xad, 0xbe]).map(|_| ())
            } else {
                server.submit(InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4]))
            }
        },
        || {
            sink.maybe_flush_with(|| server.metrics_snapshot());
        },
    );
    assert_eq!(report.offered, 120);
    assert_eq!(report.malformed, 12);
    assert_eq!(report.completed, report.admitted, "drain window must not expire");

    // Guarantee at least one non-final line even on a very slow box.
    for _ in 0..200 {
        if sink.lines_written() >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
        sink.maybe_flush_with(|| server.metrics_snapshot());
    }
    assert!(sink.lines_written() >= 1, "no interval line emitted during the run");

    let snap = server.shutdown();
    sink.flush_final(&snap);

    // 1. Golden schema: every line is validator-clean JSONL.
    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 2, "want >= 2 snapshots, got {}", lines.len());
    assert_eq!(lines.len() as u64, sink.lines_written());
    for l in &lines {
        assert!(json_is_well_formed(l), "bad JSON line: {l}");
        assert!(l.contains("\"schema\":\"adcim.telemetry.v1\""));
        assert!(l.contains("\"label\":\"it\""));
    }
    let finals = lines.iter().filter(|l| l.contains("\"final\":true")).count();
    assert_eq!(finals, 1, "exactly one final line");
    assert!(lines.last().unwrap().contains("\"final\":true"));

    // 2. Reconciliation over the structured rows behind the lines.
    let rows = sink.rows();
    assert_eq!(rows.len(), lines.len());
    for w in rows.windows(2) {
        assert!(w[1].t_ms > w[0].t_ms, "export clock not strictly increasing");
    }
    let mut sums = (0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
    for r in rows {
        assert_eq!(r.offered, r.admitted + r.shed + r.malformed, "offered identity per row");
        sums.0 += r.offered;
        sums.1 += r.admitted;
        sums.2 += r.shed;
        sums.3 += r.malformed;
        sums.4 += r.completed;
        sums.5 += r.fused;
    }
    let admitted: u64 = snap.qos_admitted.iter().sum();
    let shed: u64 = snap.qos_shed.iter().sum();
    assert_eq!(sums.1, admitted, "interval admitted deltas sum to cumulative");
    assert_eq!(sums.2, shed, "interval shed deltas sum to cumulative");
    assert_eq!(sums.3, snap.rejected_malformed, "interval malformed deltas sum to cumulative");
    assert_eq!(sums.0, admitted + shed + snap.rejected_malformed);
    assert_eq!(sums.4, snap.completed, "interval completed deltas sum to cumulative");
    assert_eq!(sums.5, snap.samples_fused, "interval fused deltas sum to cumulative");
    assert_eq!(sums.3, 12);
    assert_eq!(sums.0, 120);

    // 3. Stage breakdown: one resolved span per completion, each stage
    //    telescoping under end-to-end (small slack for the histogram's
    //    1/128 floor quantization and clock-read skew).
    assert_eq!(snap.stages.service.count, snap.completed);
    assert_eq!(snap.stages.queue_wait.count, snap.completed);
    assert_eq!(snap.stages.batch_wait.count, snap.completed);
    assert!(
        snap.stages.service.mean_us >= 200.0,
        "service stage must cover the 300us mock engine delay, got {}",
        snap.stages.service.mean_us
    );
    let stage_sum = snap.stages.queue_wait.mean_us
        + snap.stages.batch_wait.mean_us
        + snap.stages.service.mean_us;
    assert!(
        stage_sum <= snap.mean_latency_us * 1.02 + 50.0,
        "stage means {stage_sum} exceed end-to-end mean {}",
        snap.mean_latency_us
    );
    let p99_sum = snap.stages.queue_wait.p99_us
        + snap.stages.batch_wait.p99_us
        + snap.stages.service.p99_us;
    assert!(
        p99_sum as f64 <= snap.p99_latency_us * 3.0 + 150.0,
        "stage p99s {p99_sum} wildly exceed end-to-end p99 {}",
        snap.p99_latency_us
    );
    // Conversion energy is attributed to the service stage only (zero
    // on the ADC-free mock path, but the attribution must agree).
    assert_eq!(snap.stages.service.energy_fj, snap.adc_energy_fj);
    assert_eq!(snap.stages.queue_wait.energy_fj, 0.0);
    assert_eq!(snap.stages.batch_wait.energy_fj, 0.0);
}

fn serve_fixed_load(telemetry: bool) -> (Vec<adcim::coordinator::InferenceResponse>, u64) {
    let cfg = ServerConfig {
        workers: 2,
        batch: 8,
        batch_deadline_us: 400,
        telemetry,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, mock_engines(2, 100), RoutingPolicy::RoundRobin).unwrap();
    let spec = LoadSpec {
        mode: LoadMode::Closed { concurrency: 8 },
        total: 96,
        drain: Duration::from_secs(10),
    };
    let report = loadgen::run(&server, &spec, |i| {
        server.submit(InferenceRequest::new(i, (i % 4) as u32, vec![(i % 10) as f32; 4]))
    });
    assert_eq!(report.completed, 96);
    let mut responses = report.responses;
    responses.sort_unstable_by_key(|r| r.id);
    let snap = server.shutdown();
    if telemetry {
        assert_eq!(snap.stages.service.count, 96, "telemetry on: every span resolves");
    } else {
        assert_eq!(snap.stages.service.count, 0, "telemetry off: no spans recorded");
        assert_eq!(snap.stages.queue_wait.count, 0);
    }
    (responses, snap.completed)
}

/// Telemetry is an observer: identical traffic served with stage
/// spans + runtime sampling on vs. off must produce bit-identical
/// logits and classes for every frame.
#[test]
fn telemetry_toggle_never_changes_results() {
    let (on, on_completed) = serve_fixed_load(true);
    let (off, off_completed) = serve_fixed_load(false);
    assert_eq!(on_completed, off_completed);
    assert_eq!(on.len(), off.len());
    for (a, b) in on.iter().zip(&off) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.class, b.class);
        assert_eq!(a.logits, b.logits, "logit drift on frame {}", a.id);
        assert!(a.error.is_none() && b.error.is_none());
    }
}
