//! PJRT integration: the AOT-compiled JAX/Pallas model, loaded and run
//! from rust, must reproduce the golden logits python exported — the
//! proof that all three layers compose. Requires `make artifacts` and a
//! real PJRT runtime, so the whole file is gated behind the `xla`
//! feature (the default offline build compiles it away).
#![cfg(feature = "xla")]

use adcim::coordinator::{DigitalEngine, InferenceEngine};
use adcim::runtime::{Artifacts, Runtime};

fn artifacts() -> Artifacts {
    Artifacts::open(Artifacts::default_dir()).expect("run `make artifacts` first")
}

#[test]
fn float_model_reproduces_golden_logits() {
    let a = artifacts();
    let m = a.manifest().unwrap();
    let runtime = Runtime::cpu().unwrap();
    let model = runtime.load_hlo_text(&a.hlo_path("model_float")).unwrap();
    let batch = a.test_batch().unwrap();
    let logits = model.run_f32(&batch, &[m.batch, m.input]).unwrap();
    let expected = a.expected_logits().unwrap();
    assert_eq!(logits.len(), expected.len());
    for (i, (g, e)) in logits.iter().zip(&expected).enumerate() {
        assert!(
            (g - e).abs() < 1e-3 * (1.0 + e.abs()),
            "logit {i}: rust {g} vs python {e}"
        );
    }
}

#[test]
fn quant_model_runs_and_classifies() {
    let a = artifacts();
    let m = a.manifest().unwrap();
    let runtime = Runtime::cpu().unwrap();
    let model = runtime.load_hlo_text(&a.hlo_path("model_quant")).unwrap();
    let batch = a.test_batch().unwrap();
    let logits = model.run_f32(&batch, &[m.batch, m.input]).unwrap();
    let expected = a.read_f32("expected_logits_quant.bin").unwrap();
    for (i, (g, e)) in logits.iter().zip(&expected).enumerate() {
        assert!(
            (g - e).abs() < 1e-3 * (1.0 + e.abs()),
            "quant logit {i}: rust {g} vs python {e}"
        );
    }
}

#[test]
fn golden_logits_classify_test_labels() {
    let a = artifacts();
    let m = a.manifest().unwrap();
    let labels = a.test_labels().unwrap();
    let logits = a.expected_logits().unwrap();
    let mut correct = 0;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits[i * m.classes..(i + 1) * m.classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if pred == label {
            correct += 1;
        }
    }
    assert!(correct * 2 > labels.len(), "golden accuracy {correct}/{}", labels.len());
}

#[test]
fn digital_engine_matches_golden_on_test_batch() {
    let a = artifacts();
    let m = a.manifest().unwrap();
    let mut engine = DigitalEngine::load(&a, false).unwrap();
    let batch = a.test_batch().unwrap();
    let images: Vec<Vec<f32>> =
        batch.chunks(m.input).map(|c| c.to_vec()).collect();
    let out = engine.infer_batch(&images).unwrap();
    let expected = a.expected_logits().unwrap();
    for (i, logits) in out.iter().enumerate() {
        for (j, g) in logits.iter().enumerate() {
            let e = expected[i * m.classes + j];
            assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()), "[{i},{j}] {g} vs {e}");
        }
    }
}

#[test]
fn bwht_kernel_hlo_loads_and_runs() {
    let a = artifacts();
    let m = a.manifest().unwrap();
    let runtime = Runtime::cpu().unwrap();
    let kernel = runtime.load_hlo_text(&a.hlo_path("bwht_kernel")).unwrap();
    let x = vec![0.5f32; m.batch * m.hidden];
    let y = kernel.run_f32(&x, &[m.batch, m.hidden]).unwrap();
    assert_eq!(y.len(), m.batch * m.hidden);
    assert!(y.iter().all(|v| v.is_finite()));
}

#[test]
fn analog_engine_with_jax_weights_beats_chance() {
    use adcim::cim::CrossbarConfig;
    use adcim::coordinator::AnalogEngine;
    let a = artifacts();
    let m = a.manifest().unwrap();
    let labels = a.test_labels().unwrap();
    let batch = a.test_batch().unwrap();
    let images: Vec<Vec<f32>> = batch.chunks(m.input).map(|c| c.to_vec()).collect();
    let mut engine =
        AnalogEngine::load(&a, CrossbarConfig::default(), None, m.input_bits, 99).unwrap();
    let out = engine.infer_batch(&images).unwrap();
    let mut correct = 0;
    for (logits, &label) in out.iter().zip(&labels) {
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if pred == label {
            correct += 1;
        }
    }
    // The analog path carries quantization + noise; well above 10% chance.
    assert!(correct * 3 > labels.len(), "analog accuracy {correct}/{}", labels.len());
}
