//! The batched-pipeline contracts of the zero-allocation crossbar PR:
//!
//! 1. `BitplaneEngine::transform_batch` is bit-exactly equal to N
//!    sequential `transform` calls under the same per-sample seed
//!    schedule (`Rng::for_stream(seed, i)`), with and without early
//!    termination, on noisy configs.
//! 2. `AnalogEngine::infer_batch` results are invariant to the worker
//!    thread count and to how a batch is split across calls.
//! 3. Termination accounting survives the thread-shard merge.
//! 4. The committed `BENCH_hotpath.json` perf trajectory stays
//!    well-formed JSON.

use adcim::cim::{BitplaneEngine, Crossbar, CrossbarConfig, EarlyTermination};
use adcim::coordinator::{AnalogEngine, InferenceEngine};
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::util::bench::json_is_well_formed;
use adcim::util::{prop, Rng};

fn batch_inputs(n: usize, cols: usize, bits: u8, rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..n)
        .map(|_| (0..cols).map(|_| rng.below(1 << bits) as u32).collect())
        .collect()
}

#[test]
fn prop_transform_batch_equals_sequential_transforms() {
    prop::check("transform_batch == N x transform", 24, |rng| {
        let m = 1usize << (3 + rng.index(3)); // 8..32
        let bits = (1 + rng.index(6)) as u8;
        let noisy = rng.bool();
        let cfg = if noisy { CrossbarConfig::default() } else { CrossbarConfig::ideal() };
        let fab_seed = rng.next_u64();
        let batch_seed = rng.next_u64();
        let et = if rng.bool() {
            Some(EarlyTermination::exact((1 + rng.index(20)) as f32))
        } else {
            None
        };

        let mut fab = Rng::new(fab_seed);
        let mut batch_eng = BitplaneEngine::new(Crossbar::walsh(m, cfg, &mut fab), bits);
        batch_eng.early_term = et;
        let mut fab = Rng::new(fab_seed);
        let mut seq_eng = BitplaneEngine::new(Crossbar::walsh(m, cfg, &mut fab), bits);
        seq_eng.early_term = et;

        let xs = batch_inputs(1 + rng.index(8), m, bits, rng);
        let batched = batch_eng.transform_batch(&xs, batch_seed);
        adcim::prop_assert!(batched.len() == xs.len(), "batch length");
        for (i, x) in xs.iter().enumerate() {
            let mut r = Rng::for_stream(batch_seed, i as u64);
            let single = seq_eng.transform(x, &mut r);
            adcim::prop_assert!(
                batched[i].values == single.values,
                "sample {i}: batched {:?} vs sequential {:?}",
                batched[i].values,
                single.values
            );
            adcim::prop_assert!(
                batched[i].plane_signs == single.plane_signs,
                "sample {i}: plane signs diverged"
            );
            adcim::prop_assert!(
                batched[i].term.processed == single.term.processed
                    && batched[i].term.skipped == single.term.skipped,
                "sample {i}: termination stats diverged"
            );
        }
        Ok(())
    });
}

/// Analog digit-MLP engine over synthetic weights (no artifacts needed).
fn analog_engine(threads: usize, early_term: Option<EarlyTermination>) -> AnalogEngine {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(36, 4, 16, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term,
            seed: 42,
            pool: None,
        })
    });
    AnalogEngine::from_model(model, 36).with_threads(threads)
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..36).map(|j| ((i * j + i) % 7) as f32 * 0.3).collect())
        .collect()
}

#[test]
fn infer_batch_is_thread_count_invariant() {
    let imgs = images(13);
    let mut base_engine = analog_engine(1, None);
    let base = base_engine.infer_batch(&imgs).unwrap();
    for threads in [2usize, 4, 8, 0] {
        let mut e = analog_engine(threads, None);
        let got = e.infer_batch(&imgs).unwrap();
        assert_eq!(got, base, "threads={threads} changed analog batch results");
    }
}

#[test]
fn infer_batch_stream_offsets_survive_call_splits() {
    // Two half-batches on one engine == one full batch on another, even
    // when the two engines shard differently: the noise stream is a pure
    // function of (seed, global sample index).
    let imgs = images(12);
    let mut split_engine = analog_engine(2, None);
    let first = split_engine.infer_batch(&imgs[..5]).unwrap();
    let second = split_engine.infer_batch(&imgs[5..]).unwrap();
    let mut full_engine = analog_engine(3, None);
    let full = full_engine.infer_batch(&imgs).unwrap();
    let stitched: Vec<Vec<f32>> = first.into_iter().chain(second).collect();
    assert_eq!(stitched, full);
}

#[test]
fn termination_accounting_survives_shard_merge() {
    // bwht_mlp(36, 4, 16): one 16-wide BWHT block, 4 input bits ⇒ each
    // forward is 16 rows × 4 planes = 64 row-plane pairs.
    let imgs = images(9);
    let per_sample = 64u64;

    let mut seq = analog_engine(1, Some(EarlyTermination::exact(6.0)));
    let _ = seq.infer_batch(&imgs).unwrap();
    let (p1, s1) = seq.termination_stats();
    assert_eq!(p1 + s1, per_sample * imgs.len() as u64);

    let mut par = analog_engine(4, Some(EarlyTermination::exact(6.0)));
    let _ = par.infer_batch(&imgs).unwrap();
    let (p4, s4) = par.termination_stats();
    assert_eq!(
        (p4, s4),
        (p1, s1),
        "sharded termination accounting must match the sequential run"
    );
}

#[test]
fn empty_batch_is_a_noop() {
    let mut e = analog_engine(4, None);
    assert!(e.infer_batch(&[]).unwrap().is_empty());
}

#[test]
fn wrong_dim_errors_in_threaded_mode_too() {
    let mut e = analog_engine(3, None);
    assert!(e.infer_batch(&[vec![0.0; 7], vec![0.0; 36], vec![0.0; 36]]).is_err());
}

#[test]
fn committed_bench_trajectory_is_well_formed_json() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    let text = std::fs::read_to_string(path)
        .expect("BENCH_hotpath.json missing at repo root (scripts/bench.sh writes it)");
    assert!(json_is_well_formed(&text), "BENCH_hotpath.json is not valid JSON");
    assert!(text.contains("\"results\""), "missing results array");
    assert!(text.contains("crossbar 128x128 bitplane"), "missing the tentpole case");
}
