//! Cross-module invariants: the properties DESIGN.md promises, tested
//! across module boundaries (randomized via the in-house prop driver).

use adcim::adc::{binomial_mav_pmf, Adc, AsymmetricSearch, ImmersedAdc, ImmersedMode};
use adcim::cim::{BitplaneEngine, BitVec, Crossbar, CrossbarConfig, EarlyTermination};
use adcim::network::{CouplingMode, InterleaveSchedule, Topology};
use adcim::util::{prop, Rng};
use adcim::wht::{soft_threshold, Bwht};

/// The full chain WHT → crossbar bitplanes → reconstruction equals the
/// integer transform when everything is ideal and quantization is
/// bypassed (∞-precision oracle).
#[test]
fn ideal_bitplane_chain_equals_integer_transform() {
    prop::check("bitplane chain == integer matvec", 64, |rng| {
        let m = 1usize << (3 + rng.index(3)); // 8..32
        let bits = 1 + rng.index(6) as u8;
        let x: Vec<u32> = (0..m).map(|_| rng.below(1 << bits) as u32).collect();
        let mut r2 = Rng::new(rng.next_u64());
        let xb = Crossbar::walsh(m, CrossbarConfig::ideal(), &mut r2);
        let eng = BitplaneEngine::new(xb, bits);
        let exact = eng.transform_exact(&x);
        // Oracle via float FWHT (sequency order matches Walsh matrix).
        let mut f: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        adcim::wht::fwht_sequency_inplace(&mut f);
        for (a, b) in exact.iter().zip(&f) {
            adcim::prop_assert!((*a as f32 - b).abs() < 1e-3, "{a} vs {b}");
        }
        Ok(())
    });
}

/// BWHT round trip through the padded layout is exact for any dim.
#[test]
fn bwht_round_trip_any_dim() {
    prop::check("bwht round trip", 128, |rng| {
        let n = 1 + rng.index(300);
        let max_block = 1usize << (2 + rng.index(6));
        let b = Bwht::for_dim(n, max_block);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let y = b.forward(&x);
        let back = b.inverse(&y);
        for (a, e) in back.iter().zip(&x) {
            adcim::prop_assert!((a - e).abs() < 1e-3, "n={n} {a} vs {e}");
        }
        Ok(())
    });
}

/// Early termination with margin 1.0 never changes soft-thresholded
/// outputs, at any threshold, noise-free.
#[test]
fn exact_early_termination_is_output_preserving() {
    prop::check("exact ET output preserving", 48, |rng| {
        let m = 16;
        let bits = 4u8;
        let t = rng.uniform_in(0.0, 40.0) as f32;
        let x: Vec<u32> = (0..m).map(|_| rng.below(1 << bits) as u32).collect();
        let seed = rng.next_u64();

        let mut base = BitplaneEngine::new(
            Crossbar::walsh(m, CrossbarConfig::ideal(), &mut Rng::new(5)),
            bits,
        );
        let plain = base.transform(&x, &mut Rng::new(seed));
        let mut et_eng = BitplaneEngine::new(
            Crossbar::walsh(m, CrossbarConfig::ideal(), &mut Rng::new(5)),
            bits,
        )
        .with_early_term(EarlyTermination::exact(t));
        let early = et_eng.transform(&x, &mut Rng::new(seed));
        for (a, b) in plain.values.iter().zip(&early.values) {
            adcim::prop_assert!(
                soft_threshold(*a, t) == soft_threshold(*b, t),
                "T={t}: {a} vs {b}"
            );
        }
        Ok(())
    });
}

/// Asymmetric search always returns the ideal code for any distribution
/// it was built from (correctness is distribution-independent; only the
/// comparison count depends on the pmf).
#[test]
fn asymmetric_search_code_correct_for_any_pmf() {
    prop::check("asymmetric codes independent of pmf", 64, |rng| {
        let bits = 4u8;
        let n = 1usize << bits;
        // Random pmf.
        let pmf: Vec<f64> = (0..n).map(|_| rng.uniform() + 1e-6).collect();
        let tree = AsymmetricSearch::build(bits, &pmf);
        let mut adc = ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar);
        let v = rng.uniform();
        let c = tree.convert(&mut adc, v, rng);
        adcim::prop_assert!(c.code == adc.ideal_code(v), "v={v}");
        Ok(())
    });
}

/// Entropy lower bound and bits upper bound on expected comparisons.
#[test]
fn asymmetric_search_bounds() {
    prop::check("asym search entropy/bits bounds", 48, |rng| {
        let bits = 3 + rng.index(3) as u8;
        let cols = 16 + rng.index(48);
        let pmf = binomial_mav_pmf(cols, rng.uniform_in(0.2, 0.9), bits);
        let tree = AsymmetricSearch::build(bits, &pmf);
        let h = adcim::util::stats::entropy_bits(&pmf);
        let e = tree.expected_comparisons();
        adcim::prop_assert!(e + 1e-9 >= h, "E={e} < H={h}");
        adcim::prop_assert!(e <= bits as f64 + 1e-9, "E={e} > bits={bits}");
        Ok(())
    });
}

/// Interleave schedules uphold the pairing invariants for every
/// topology and phase count.
#[test]
fn interleave_schedules_always_valid() {
    prop::check("schedules valid across topologies", 96, |rng| {
        let mode = match rng.index(3) {
            0 => CouplingMode::NearestNeighbour,
            1 => CouplingMode::FlashGroup { refs: 3 },
            _ => CouplingMode::FlashGroup { refs: 1 + rng.index(7) },
        };
        let n = mode.group_size() * (1 + rng.index(6)) + rng.index(mode.group_size());
        let t = Topology::new(n, mode);
        let s = InterleaveSchedule::build(&t, 1 + rng.index(16));
        s.validate(&t)
    });
}

/// The crossbar's raw MAV voltages are always within rails and the
/// plus/minus charge counts are consistent with the packed dot product.
#[test]
fn crossbar_mav_within_rails() {
    prop::check("MAV within [0, VDD]", 64, |rng| {
        let m = 1usize << (3 + rng.index(3));
        let mut r2 = Rng::new(rng.next_u64());
        let mut xb = Crossbar::walsh(m, CrossbarConfig::default(), &mut r2);
        let bits: Vec<bool> = (0..m).map(|_| rng.bool()).collect();
        let x = BitVec::from_bits(&bits);
        for v in xb.compute_mav(&x, rng) {
            adcim::prop_assert!((0.0..=1.01).contains(&v), "MAV {v} out of rails");
        }
        Ok(())
    });
}
