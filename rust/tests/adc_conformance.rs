//! `Adc`-trait conformance across every converter style, through the
//! [`AnyAdc`] unifier the digitization pool constructs from: noise-free
//! instances of Sar / Flash / Immersed (Sar, Flash, Hybrid) /
//! Asymmetric must all reproduce the `ideal_code` floor-quantizer
//! oracle at every bit width, and report the mode's documented
//! cycle/comparison costs.

use adcim::adc::{
    binomial_mav_pmf, Adc, AnyAdc, AsymmetricAdc, AsymmetricSearch, FlashAdc, ImmersedAdc,
    ImmersedMode, SarAdc,
};
use adcim::util::{prop, Rng};

/// Every converter style at `bits`, fabricated noise-free.
fn ideal_bank(bits: u8) -> Vec<AnyAdc> {
    let flash_bits = if bits > 2 { 2 } else { 1 };
    let pmf = binomial_mav_pmf(32, 0.5, bits);
    vec![
        AnyAdc::Sar(SarAdc::ideal(bits, 1.0)),
        AnyAdc::Flash(FlashAdc::ideal(bits, 1.0)),
        AnyAdc::Immersed(ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar)),
        AnyAdc::Immersed(ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Flash)),
        AnyAdc::Immersed(ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Hybrid { flash_bits })),
        AnyAdc::Asymmetric(AsymmetricAdc::new(
            ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar),
            AsymmetricSearch::build(bits, &pmf),
        )),
    ]
}

#[test]
fn prop_noise_free_convert_matches_ideal_code_oracle() {
    prop::check("AnyAdc ideal convert == ideal_code", 96, |rng| {
        let bits = (2 + rng.index(5)) as u8; // 2..=6
        for adc in ideal_bank(bits).iter_mut() {
            let v = rng.uniform();
            let got = adc.convert(v, rng).code;
            let want = adc.ideal_code(v);
            adcim::prop_assert!(
                got == want,
                "bits={bits} style={} v={v}: {got} != {want}",
                adc.style()
            );
            adcim::prop_assert!(adc.bits() == bits, "style={} bits", adc.style());
            adcim::prop_assert!(adc.vdd() == 1.0, "style={} vdd", adc.style());
        }
        Ok(())
    });
}

#[test]
fn conversion_costs_match_mode_contracts() {
    let mut rng = Rng::new(1);
    let bits = 5u8;
    for adc in ideal_bank(bits).iter_mut() {
        let c = adc.convert(0.41, &mut rng);
        match adc.style() {
            "dedicated-sar" | "immersed-sar" => {
                assert_eq!(c.comparisons, bits as u32, "{}", adc.style());
                assert_eq!(c.cycles, bits as u32, "{}", adc.style());
            }
            "dedicated-flash" | "immersed-flash" => {
                assert_eq!(c.comparisons, (1u32 << bits) - 1, "{}", adc.style());
                assert_eq!(c.cycles, 1, "{}", adc.style());
            }
            "immersed-hybrid" => {
                // 2 bits flash (3 comparisons, 1 cycle) + 3 bits SAR.
                assert_eq!(c.comparisons, 3 + 3, "{}", adc.style());
                assert_eq!(c.cycles, 1 + 3, "{}", adc.style());
            }
            "immersed-asymmetric" => {
                // Tree depth varies by code; never worse than 2^bits − 1
                // and cycles track comparisons one-to-one.
                assert!(c.comparisons < (1u32 << bits), "{}", adc.style());
                assert_eq!(c.cycles, c.comparisons, "{}", adc.style());
            }
            other => panic!("unknown style {other}"),
        }
        assert!(c.energy_fj > 0.0, "{} spent no energy", adc.style());
    }
}

#[test]
fn asymmetric_averages_fewer_comparisons_than_symmetric_on_mavs() {
    // The Fig 10 claim, measured through the unified trait: identical
    // codes, fewer expected comparator decisions on binomial MAVs.
    let bits = 5u8;
    let cols = 32usize;
    let pmf = binomial_mav_pmf(cols, 0.5, bits);
    let mut asym = AnyAdc::Asymmetric(AsymmetricAdc::new(
        ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar),
        AsymmetricSearch::build(bits, &pmf),
    ));
    let mut sym = AnyAdc::Immersed(ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar));
    let mut rng = Rng::new(2);
    let mut asym_cmp = 0u64;
    let mut sym_cmp = 0u64;
    let trials = 2000;
    for _ in 0..trials {
        let plus = (0..cols).filter(|_| rng.bernoulli(0.25)).count();
        let v = (plus as f64 + 0.5) / cols as f64;
        let ca = asym.convert(v, &mut rng);
        let cs = sym.convert(v, &mut rng);
        assert_eq!(ca.code, cs.code, "codes diverged at v={v}");
        asym_cmp += ca.comparisons as u64;
        sym_cmp += cs.comparisons as u64;
    }
    assert_eq!(sym_cmp, trials * bits as u64);
    assert!(
        (asym_cmp as f64) < 0.9 * sym_cmp as f64,
        "asymmetric {asym_cmp} not clearly below symmetric {sym_cmp}"
    );
}

#[test]
#[should_panic(expected = "resolution mismatch")]
fn asymmetric_adapter_rejects_mismatched_tree() {
    let pmf = binomial_mav_pmf(32, 0.5, 4);
    AsymmetricAdc::new(
        ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar),
        AsymmetricSearch::build(4, &pmf),
    );
}

#[test]
#[should_panic(expected = "SAR-coupled")]
fn asymmetric_adapter_rejects_flash_coupling() {
    let pmf = binomial_mav_pmf(32, 0.5, 4);
    AsymmetricAdc::new(
        ImmersedAdc::ideal(4, 1.0, ImmersedMode::Flash),
        AsymmetricSearch::build(4, &pmf),
    );
}
