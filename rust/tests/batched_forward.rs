//! ISSUE 7 tentpole acceptance: the lockstep batched forward serves
//! traffic bit-identically to the per-sample loop — logits AND
//! conversion accounting (including f64 `energy_fj`) AND ET counters —
//! across pool thread counts, engine shard counts, early termination
//! on/off, and raw / compressed / mixed payload batches; and served
//! `--fuse-batch` traffic actually takes the lockstep path, proven by
//! the `samples_fused` metric end-to-end through the server.

use std::time::Duration;

use adcim::adc::ImmersedMode;
use adcim::cim::{CrossbarConfig, EarlyTermination, PoolSpec};
use adcim::config::ServerConfig;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, FramePayload, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::frontend::{CodecParams, FrameEncoder, Selection, LOSSLESS};
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::util::Rng;

/// Analog digit-MLP engine (64 → 4, one 16-wide BWHT block per pixel
/// group) with every BWHT stage behind a fusing 4-array pool.
fn fused_engine(pool_threads: usize, early_term: Option<EarlyTermination>) -> AnalogEngine {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(64, 4, 16, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term,
            seed: 42,
            pool: Some(PoolSpec {
                n_arrays: 4,
                adc_bits: 4,
                mode: ImmersedMode::Sar,
                asymmetric: false,
                threads: pool_threads,
                fuse_batch: true,
            }),
        })
    });
    AnalogEngine::from_model(model, 64)
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| (0..64).map(|j| ((i * j + 3 * i) % 9) as f32 / 9.0).collect()).collect()
}

/// Tentpole bit-exactness on raw images: one lockstep forward over the
/// whole batch == the per-sample loop, at every pool thread count and
/// with exact ET on or off — logits, `ConversionStats` (f64 energy
/// included), and ET counters all `assert_eq!`-identical. Only the
/// lockstep engine reports fused samples.
#[test]
fn lockstep_matches_per_sample_on_raw_images() {
    let imgs = images(7);
    for pool_threads in [1usize, 2, 4] {
        for et in [None, Some(EarlyTermination::exact(8.0))] {
            let tag = format!("pool_threads={pool_threads} et={}", et.is_some());
            let mut seq = fused_engine(pool_threads, et).with_lockstep(false);
            let want = seq.infer_batch(&imgs).unwrap();
            let mut lock = fused_engine(pool_threads, et);
            let got = lock.infer_batch(&imgs).unwrap();
            assert_eq!(got, want, "{tag}: lockstep changed logits");
            assert_eq!(
                lock.conversion_stats(),
                seq.conversion_stats(),
                "{tag}: conversion accounting diverged"
            );
            assert_eq!(
                lock.termination_stats(),
                seq.termination_stats(),
                "{tag}: ET counters diverged"
            );
            assert_eq!(lock.samples_fused(), imgs.len() as u64, "{tag}");
            assert_eq!(seq.samples_fused(), 0, "{tag}: per-sample loop must not count");
        }
    }
}

/// The lockstep path composes with engine batch sharding: results and
/// accounting are worker-thread-count invariant, and every sample of
/// every multi-sample shard slice is counted as fused.
#[test]
fn lockstep_is_engine_thread_count_invariant() {
    let imgs = images(9);
    let mut base = fused_engine(1, None);
    let want = base.infer_batch(&imgs).unwrap();
    let want_stats = base.conversion_stats();
    assert!(want_stats.conversions > 0);
    for threads in [2usize, 4] {
        let mut e = fused_engine(1, None).with_threads(threads);
        let got = e.infer_batch(&imgs).unwrap();
        assert_eq!(got, want, "threads={threads} changed lockstep logits");
        assert_eq!(e.conversion_stats(), want_stats, "threads={threads}");
        assert!(e.samples_fused() > 0, "threads={threads}");
    }
}

/// Compressed serving: an all-lossy (folded fast path), an all-lossless
/// (decode fallback), and a mixed raw/lossless/lossy batch each serve
/// bit-identically through the lockstep payload path.
#[test]
fn lockstep_matches_per_sample_on_compressed_and_mixed_payloads() {
    let lossy_params = CodecParams::new(1, 64, 8, 8).unwrap();
    let lossless_params = CodecParams::new(1, 64, 8, LOSSLESS).unwrap();
    let mut lossy_enc = FrameEncoder::new(lossy_params, Selection::TopK(24));
    let mut lossless_enc = FrameEncoder::new(lossless_params, Selection::All);
    let imgs = images(8);

    let lossy: Vec<FramePayload> = imgs
        .iter()
        .enumerate()
        .map(|(i, f)| FramePayload::Compressed(lossy_enc.encode(f, i as u64)))
        .collect();
    let lossless: Vec<FramePayload> = imgs
        .iter()
        .enumerate()
        .map(|(i, f)| FramePayload::Compressed(lossless_enc.encode(f, i as u64)))
        .collect();
    let mixed: Vec<FramePayload> = imgs
        .iter()
        .enumerate()
        .map(|(i, f)| match i % 3 {
            0 => FramePayload::Raw(f.clone()),
            1 => FramePayload::Compressed(lossless_enc.encode(f, i as u64)),
            _ => FramePayload::Compressed(lossy_enc.encode(f, i as u64)),
        })
        .collect();

    for (name, payloads) in [("lossy", &lossy), ("lossless", &lossless), ("mixed", &mixed)] {
        let mut seq = fused_engine(1, None).with_lockstep(false);
        let want = seq.infer_payloads(payloads).unwrap();
        let mut lock = fused_engine(1, None);
        let got = lock.infer_payloads(payloads).unwrap();
        assert_eq!(got, want, "{name}: lockstep changed payload logits");
        assert_eq!(lock.conversion_stats(), seq.conversion_stats(), "{name}");
        assert_eq!(lock.samples_fused(), payloads.len() as u64, "{name}");
        // Sharded payload serving agrees too.
        let mut sharded = fused_engine(1, None).with_threads(3);
        assert_eq!(sharded.infer_payloads(payloads).unwrap(), want, "{name} sharded");
    }
}

/// Without a pool the lockstep walk still runs (Dense layers batch,
/// BWHT falls back to its per-sample inner loop) and stays bit-exact
/// with the per-sample engine.
#[test]
fn lockstep_without_pool_matches_per_sample() {
    let mk = || {
        let mut rng = Rng::new(1);
        let mut model = bwht_mlp(64, 4, 16, &mut rng);
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 42,
                pool: None,
            })
        });
        AnalogEngine::from_model(model, 64)
    };
    let imgs = images(6);
    let mut seq = mk().with_lockstep(false);
    let want = seq.infer_batch(&imgs).unwrap();
    let mut lock = mk();
    let got = lock.infer_batch(&imgs).unwrap();
    assert_eq!(got, want);
    assert_eq!(lock.termination_stats(), seq.termination_stats());
}

/// ISSUE 7 acceptance: served `--fuse-batch` traffic takes the lockstep
/// path — the worker's whole batch goes through one multi-sample
/// forward, visible as `samples_fused` in the end-to-end metrics
/// snapshot (and its Display line), with all requests answered.
#[test]
fn served_fuse_batch_traffic_reports_fused_samples() {
    let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(fused_engine(1, None))];
    let cfg = ServerConfig {
        workers: 1,
        batch: 8,
        batch_deadline_us: 200_000,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
    let imgs = images(8);
    for (i, img) in imgs.iter().enumerate() {
        server.submit(InferenceRequest::new(i as u64, 0, img.clone())).unwrap();
    }
    let mut got = 0u64;
    while got < 8 {
        match server.recv_response(Duration::from_secs(10)) {
            Some(r) => {
                assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
                got += 1;
            }
            None => break,
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.errors, 0);
    // The 200ms deadline comfortably collects all 8 submissions into
    // one batch, but even a split keeps every multi-sample slice fused.
    assert!(
        snap.samples_fused >= 2,
        "served fuse-batch traffic must take the lockstep path: {snap}"
    );
    assert!(snap.samples_fused <= 8, "{snap}");
    let line = snap.to_string();
    assert!(line.contains("fused="), "snapshot Display must surface fusion: {line}");
    assert!(line.contains("batches=["), "snapshot Display must surface batch sizes: {line}");
    assert!(snap.batch_hist.iter().sum::<u64>() >= 1, "{snap}");
}
