//! Graceful degradation under hostile ingest (ISSUE 6 acceptance):
//!
//! 1. **Panic isolation**: a request that makes the engine panic yields
//!    a failure response — the worker survives and keeps serving, and
//!    the panic shows up in `panics_isolated` / `degraded`.
//! 2. **Faulty-wire end-to-end**: with a deterministic 1e-3 BER channel
//!    corrupting a compressed stream, the server keeps answering a
//!    concurrent clean stream correctly while malformed deliveries
//!    bounce off the validated `submit_wire` boundary and are counted.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use adcim::config::ServerConfig;
use adcim::coordinator::{
    EdgeServer, InferenceEngine, InferenceRequest, InferenceResponse, RoutingPolicy, SubmitError,
};
use adcim::frontend::{Channel, ChannelConfig, CodecParams, FrameEncoder, Selection};
use anyhow::Result;

/// Threshold classifier over the first input value. With `trap` set it
/// panics — like a buggy kernel would — when fed a poisoned
/// (negative-lead) frame; untrapped it classifies anything.
struct TrapEngine {
    input_dim: usize,
    trap: bool,
}

impl InferenceEngine for TrapEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(images
            .iter()
            .map(|img| {
                let lead = img.first().copied().unwrap_or(0.0);
                assert!(!self.trap || lead >= 0.0, "poisoned frame reached the kernel");
                vec![1.0 - lead, lead]
            })
            .collect())
    }

    fn name(&self) -> &'static str {
        "trap"
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }
}

fn collect(server: &EdgeServer, n: usize) -> Vec<InferenceResponse> {
    let mut got = Vec::new();
    let t0 = Instant::now();
    while got.len() < n && t0.elapsed() < Duration::from_secs(10) {
        if let Some(r) = server.recv_response(Duration::from_millis(100)) {
            got.push(r);
        }
    }
    got
}

#[test]
fn worker_survives_a_panicking_request() {
    let cfg = ServerConfig { workers: 1, batch: 1, batch_deadline_us: 200, ..Default::default() };
    let engines: Vec<Box<dyn InferenceEngine>> =
        vec![Box::new(TrapEngine { input_dim: 4, trap: true })];
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();

    server.submit(InferenceRequest::new(1, 0, vec![0.25; 4])).unwrap();
    server.submit(InferenceRequest::new(2, 0, vec![-1.0; 4])).unwrap();
    server.submit(InferenceRequest::new(3, 0, vec![0.75; 4])).unwrap();

    let got = collect(&server, 3);
    assert_eq!(got.len(), 3, "every request must be answered, poisoned or not");
    for r in &got {
        match r.id {
            2 => {
                let err = r.error.as_deref().expect("poisoned request must fail");
                assert!(err.contains("panic"), "failure reason should name the panic: {err}");
            }
            1 | 3 => {
                assert!(r.error.is_none(), "clean request {} degraded: {:?}", r.id, r.error);
                assert_eq!(r.class, if r.id == 1 { 0 } else { 1 });
            }
            other => panic!("unexpected response id {other}"),
        }
    }

    let snap = server.shutdown();
    assert_eq!(snap.panics_isolated, 1);
    assert_eq!(snap.completed, 2, "the two clean requests complete normally");
    assert_eq!(snap.degraded, 1);
    let line = snap.to_string();
    assert!(line.contains("degraded=1 (panics=1)"), "metrics line must surface it: {line}");
}

#[test]
fn serving_survives_a_noisy_wire_alongside_a_clean_stream() {
    const N_WIRE: usize = 200;
    const N_CLEAN: usize = 40;
    const CLEAN_BASE: u64 = 1_000_000;

    let params = CodecParams::new(1, 64, 8, 8).unwrap();
    let mut enc = FrameEncoder::new(params, Selection::All);
    let mut channel = Channel::new(ChannelConfig {
        ber: 1e-3,
        seed: 0xbe2,
        ..ChannelConfig::default()
    })
    .unwrap();

    let cfg = ServerConfig {
        workers: 2,
        batch: 8,
        batch_deadline_us: 500,
        queue_depth: 4096,
        ..Default::default()
    };
    // Untrapped: a corrupted-but-parseable frame may decode to
    // arbitrary values, and a panic would poison whole batches shared
    // with the clean stream — panic isolation has its own test above.
    let engines: Vec<Box<dyn InferenceEngine>> = vec![
        Box::new(TrapEngine { input_dim: 64, trap: false }),
        Box::new(TrapEngine { input_dim: 64, trap: false }),
    ];
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();

    // Hand-made garbage first: guarantees wire rejections regardless of
    // what the stochastic (but seeded) BER draws do.
    for garbage in [&b"not a frame"[..], &[0u8; 4][..], &[]] {
        match server.submit_wire(0, garbage) {
            Err(SubmitError::Malformed(_)) => {}
            other => panic!("garbage must be rejected as malformed, got {other:?}"),
        }
    }

    // Interleave the corrupted compressed stream with a clean raw one.
    let mut wire_accepted = 0u64;
    let mut wire_rejected = 0u64;
    let mut clean = 0usize;
    for i in 0..N_WIRE {
        // Sensor-grid values in [0, 1] so the trap never fires on a
        // frame the codec round-trips faithfully.
        let frame: Vec<f32> = (0..64).map(|s| ((i + s) % 17) as f32 / 17.0).collect();
        let cf = enc.encode(&frame, i as u64);
        for (_, wire) in channel.transmit(i as u64, &cf.to_bytes()) {
            match server.submit_wire(0, &wire) {
                Ok(_) => wire_accepted += 1,
                Err(SubmitError::Malformed(_)) => wire_rejected += 1,
                Err(e) => panic!("unexpected reject: {e}"),
            }
        }
        if i % (N_WIRE / N_CLEAN) == 0 && clean < N_CLEAN {
            let lead = (clean % 2) as f32;
            server
                .submit(InferenceRequest::new(CLEAN_BASE + clean as u64, 1, vec![lead; 64]))
                .unwrap();
            clean += 1;
        }
    }
    for (_, wire) in channel.flush() {
        match server.submit_wire(0, &wire) {
            Ok(_) => wire_accepted += 1,
            Err(SubmitError::Malformed(_)) => wire_rejected += 1,
            Err(e) => panic!("unexpected reject: {e}"),
        }
    }

    let stats = channel.stats();
    assert_eq!(stats.offered as usize, N_WIRE);
    assert!(stats.bits_flipped > 0, "a 1e-3 BER over ~{N_WIRE} frames must flip bits");
    assert_eq!(
        wire_accepted + wire_rejected,
        stats.delivered,
        "every delivered frame either enters or is rejected at the boundary"
    );

    let total = wire_accepted as usize + clean;
    let got = collect(&server, total);
    assert_eq!(got.len(), total, "no request may vanish: accepted wire + clean");

    // Every clean request is answered correctly despite the deluge of
    // corrupted neighbours.
    let mut clean_ok = HashSet::new();
    for r in &got {
        if (CLEAN_BASE..CLEAN_BASE + clean as u64).contains(&r.id) && r.error.is_none() {
            assert_eq!(r.class as u64, (r.id - CLEAN_BASE) % 2, "clean request misclassified");
            clean_ok.insert(r.id);
        }
    }
    assert_eq!(clean_ok.len(), clean, "all clean requests served");

    let snap = server.shutdown();
    assert_eq!(
        snap.rejected_malformed,
        3 + wire_rejected,
        "boundary rejections: 3 garbage blobs + every corrupted delivery"
    );
    assert!(snap.completed >= clean as u64);
    let line = snap.to_string();
    assert!(line.contains("rejected:"), "metrics line must surface wire rejections: {line}");
}
