//! ISSUE 10 acceptance: the analog fault layer's determinism and
//! accounting contracts.
//!
//! 1. With an active [`FaultPlan`], served results and every
//!    [`FaultStats`] counter are **bit-identical** across the pool's
//!    plane fan-out thread counts and the fused batched forward —
//!    fault effects are pure functions of the plane-slot clock, so no
//!    execution strategy can change an outcome.
//! 2. Quarantine transitions are arrival-order independent: chunking
//!    the same sample stream differently changes nothing.
//! 3. The layer is fully inert when unconfigured: a pool that never saw
//!    a plan and a pool whose plan was cleared serve identical bits and
//!    report all-zero fault stats.
//! 4. Every injected fault is accounted: `faults_injected` equals the
//!    sum of the per-type counters, quarantines latch exactly once per
//!    unit, and the degraded-plane count is exact.

use std::time::Duration;

use adcim::adc::ImmersedMode;
use adcim::cim::{CrossbarConfig, FaultPlan, FaultStats, HealthStatus, PoolSpec};
use adcim::config::ServerConfig;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::util::Rng;

/// Analog digit-MLP engine with every BWHT stage behind a 4-array SAR
/// pool (synthetic weights; no artifacts needed). Four arrays pair into
/// two coupling groups, and each 16-wide transform dispatches 4 plane
/// slots — enough geometry for every fault kind to land somewhere real.
fn pooled_engine(pool_threads: usize, fuse_batch: bool) -> AnalogEngine {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(36, 4, 16, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term: None,
            seed: 42,
            pool: Some(PoolSpec {
                n_arrays: 4,
                adc_bits: 4,
                mode: ImmersedMode::Sar,
                asymmetric: false,
                threads: pool_threads,
                fuse_batch,
            }),
        })
    });
    AnalogEngine::from_model(model, 36).with_threads(1)
}

/// One fault of every kind, all landing inside the first transform's
/// slot range (0..4) so the whole lifecycle — injection, probe failure,
/// debounced quarantine, reroute, degraded schedule — plays out:
///
/// - group 0's converter dies at slot 0 (probes at 0 and 2 both fail,
///   so debounce 2 quarantines it at probe slot 2 → slot-2 dispatches
///   reroute from then on),
/// - group 1's converter drifts from slot 1 (fails only the slot-2
///   probe → stays Suspect, never quarantined),
/// - array 3 goes down at slot 0 (quarantined at probe slot 2 → the
///   degraded schedule idles it out of group 1's rotation),
/// - one cell of array 1 sticks at +1.
fn plan() -> FaultPlan {
    let mut p = FaultPlan::parse("dead@0=0; drift@1=1,1.2,0.1; down@0=3; stuck@0=1,2,5,+")
        .expect("valid plan");
    p.probe_interval = 2;
    p.probe_tolerance = 1;
    p.probe_debounce = 2;
    p
}

fn faulty_engine(pool_threads: usize, fuse_batch: bool) -> AnalogEngine {
    pooled_engine(pool_threads, fuse_batch)
        .with_fault_plan(Some(plan()))
        .expect("plan fits the pool geometry")
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..36).map(|j| ((i * j + i) % 7) as f32 * 0.3).collect())
        .collect()
}

/// Tentpole determinism contract: with faults active, logits and every
/// fault counter are bit-identical at any pool thread count, fused or
/// not.
#[test]
fn faulty_serving_is_pool_thread_and_fusion_invariant() {
    let imgs = images(8);
    let mut base = faulty_engine(1, false);
    let want = base.infer_batch(&imgs).unwrap();
    let want_faults = base.fault_stats();
    let want_conv = base.conversion_stats();
    assert!(want_faults.faults_injected > 0, "plan must actually fire");
    for (threads, fuse) in [(2, false), (4, false), (1, true), (2, true), (4, true)] {
        let mut e = faulty_engine(threads, fuse);
        let got = e.infer_batch(&imgs).unwrap();
        assert_eq!(got, want, "pool_threads={threads} fuse={fuse} changed faulty logits");
        assert_eq!(
            e.fault_stats(),
            want_faults,
            "pool_threads={threads} fuse={fuse} changed fault accounting"
        );
        assert_eq!(
            e.conversion_stats().conversions,
            want_conv.conversions,
            "pool_threads={threads} fuse={fuse} changed conversion count"
        );
    }
}

/// Quarantine transitions (and everything downstream of them) are
/// arrival-order independent: the same stream served in one batch, two
/// chunks, or one sample at a time produces the same bits and the same
/// final health/fault state.
#[test]
fn quarantine_is_chunking_invariant() {
    let imgs = images(8);
    let mut whole = faulty_engine(1, false);
    let want = whole.infer_batch(&imgs).unwrap();
    let want_faults = whole.fault_stats();

    let mut halves = faulty_engine(1, false);
    let mut got = halves.infer_batch(&imgs[..4]).unwrap();
    got.extend(halves.infer_batch(&imgs[4..]).unwrap());
    assert_eq!(got, want, "4+4 chunking changed faulty logits");
    assert_eq!(halves.fault_stats(), want_faults, "4+4 chunking changed fault accounting");

    let mut single = faulty_engine(1, false);
    let mut got = Vec::new();
    for img in &imgs {
        got.extend(single.infer_batch(std::slice::from_ref(img)).unwrap());
    }
    assert_eq!(got, want, "per-sample serving changed faulty logits");
    assert_eq!(single.fault_stats(), want_faults, "per-sample serving changed accounting");
}

/// Inertness: an engine whose plan was installed then cleared serves
/// the same bits as one that never had a fault layer, and fault-free
/// engines report all-zero stats.
#[test]
fn unconfigured_fault_layer_is_fully_inert() {
    let imgs = images(6);
    let mut never = pooled_engine(1, false);
    let want = never.infer_batch(&imgs).unwrap();
    assert!(never.fault_stats().is_zero());

    let mut cleared = pooled_engine(1, false)
        .with_fault_plan(Some(plan()))
        .unwrap()
        .with_fault_plan(None)
        .unwrap();
    let got = cleared.infer_batch(&imgs).unwrap();
    assert_eq!(got, want, "cleared fault plan left residue in the serving path");
    assert!(cleared.fault_stats().is_zero());

    // An *empty* plan (probes only) must not perturb serving either:
    // healthy probes pass, nothing degrades, outputs stay identical.
    let empty = FaultPlan { faults: Vec::new(), ..plan() };
    let mut probed = pooled_engine(1, false).with_fault_plan(Some(empty)).unwrap();
    let got = probed.infer_batch(&imgs).unwrap();
    assert_eq!(got, want, "healthy calibration probes changed served bits");
    let s = probed.fault_stats();
    assert!(s.probes_run > 0, "probing was configured on");
    assert_eq!(s.probes_failed, 0);
    assert_eq!(s.faults_injected, 0);
    assert_eq!(s.quarantined, 0);
    assert_eq!(s.degraded_planes, 0);
    assert_eq!(s.conversions_rerouted, 0);
}

/// Exact blast-radius accounting for the canonical plan: one injection
/// per kind, two debounced quarantines (dead converter + down array),
/// the drifting converter held at Suspect, every plane of every
/// transform degraded (each slot carries some active effect), and
/// slot-2 conversions rerouted off the quarantined converter.
#[test]
fn every_injected_fault_is_accounted() {
    let n = 8usize;
    let mut e = faulty_engine(1, false);
    let _ = e.infer_batch(&images(n)).unwrap();
    let s = e.fault_stats();
    assert_eq!(s.faults_injected, 4);
    assert_eq!(s.injected_by_type(), s.faults_injected, "per-type counters must reconcile");
    assert_eq!(
        (s.stuck_cells, s.converters_drifting, s.converters_dead, s.arrays_down),
        (1, 1, 1, 1)
    );
    assert_eq!(s.quarantined, 2, "dead converter + down array");
    assert!(s.probes_run > 0);
    assert!(s.probes_failed > 0);
    // 4 plane slots per transform, all degraded: slot 0 dead converter,
    // slot 1 drift, slot 2 reroute (post-quarantine), slot 3 drift.
    assert_eq!(s.degraded_planes, 4 * n as u64);
    // Slot-2 dispatches (16 rows each) reroute once per transform.
    assert_eq!(s.conversions_rerouted, 16 * n as u64);
}

/// The health ledger exposes the debounced per-unit state machine:
/// quarantined dead converter, Suspect drifting converter, quarantined
/// down array, healthy everything else.
#[test]
fn health_ledger_reflects_probe_outcomes() {
    let mut e = faulty_engine(1, false);
    let _ = e.infer_batch(&images(2)).unwrap();
    let mut statuses = Vec::new();
    e.for_each_health(|h| {
        statuses.push((
            h.converter_status(0),
            h.converter_status(1),
            h.array_status(3),
            h.array_status(0),
            h.quarantined(),
        ));
    });
    assert!(!statuses.is_empty(), "pooled stage must expose its ledger");
    for (dead, drifting, down, fine, total) in statuses {
        assert_eq!(dead, HealthStatus::Quarantined);
        assert_eq!(drifting, HealthStatus::Suspect(1));
        assert_eq!(down, HealthStatus::Quarantined);
        assert_eq!(fine, HealthStatus::Healthy);
        assert_eq!(total, 2);
    }
}

/// End-to-end: a server whose only engine carries an active fault plan
/// completes every request with zero panics and zero errors, and the
/// blast radius reaches the metrics snapshot (and its Display line).
#[test]
fn faulty_serving_completes_end_to_end() {
    let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(faulty_engine(1, false))];
    let cfg = ServerConfig { workers: 1, batch: 4, batch_deadline_us: 500, ..Default::default() };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
    let imgs = images(12);
    let mut submitted = 0u64;
    for (i, img) in imgs.iter().enumerate() {
        if server.submit(InferenceRequest::new(i as u64, 0, img.clone())).is_ok() {
            submitted += 1;
        }
    }
    let mut got = 0u64;
    while got < submitted {
        match server.recv_response(Duration::from_secs(10)) {
            Some(r) => {
                assert!(r.error.is_none(), "faulty serving must degrade, not error");
                got += 1;
            }
            None => break,
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, submitted);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.faults.faults_injected, 4);
    assert_eq!(snap.faults.injected_by_type(), snap.faults.faults_injected);
    assert_eq!(snap.faults.quarantined, 2);
    assert_eq!(snap.faults.degraded_planes, 4 * submitted);
    assert!(snap.to_string().contains("faults: injected=4"), "snapshot line: {snap}");
    assert_eq!(snap.shutdown_forced, 0);
}

/// The stats algebra the shard-merge and telemetry layers lean on.
#[test]
fn fault_stats_algebra_reconciles() {
    let mut e = faulty_engine(1, false);
    let _ = e.infer_batch(&images(3)).unwrap();
    let first = e.fault_stats();
    let _ = e.infer_batch(&images(5)).unwrap();
    let total = e.fault_stats();
    let delta = total.minus(&first);
    let mut recombined = first;
    recombined.merge(&delta);
    assert_eq!(recombined, total);
    assert_eq!(delta.faults_injected, 0, "injections are latched once, not re-counted");
    assert!(delta.degraded_planes > 0, "later transforms still run degraded");
    assert_eq!(FaultStats::default().minus(&FaultStats::default()), FaultStats::default());
}
