//! Serving-policy integration: graduated QoS admission + the load
//! generator, end to end over the coordinator (ISSUE 8).
//!
//! Three layers of guarantee:
//!
//! 1. A randomized property pins the stateful [`AdmissionControl`] to
//!    the pure [`admissible`] rule and the rule to priority/depth
//!    monotonicity — together: the server never sheds a frame while
//!    admitting a *lower-priority* frame at the same queue depth.
//! 2. A deterministic overload trace through [`loadgen`] checks the
//!    exact shed arithmetic and that every Keep-class (top-band) frame
//!    is admitted and answered correctly while low-band traffic sheds.
//! 3. An adaptive-vs-static A/B over identical traffic checks the
//!    batching policy can never change per-sample results (the
//!    lockstep-forward contract), `assert_eq!` on every logit.

use std::time::Duration;

use adcim::config::ServerConfig;
use adcim::coordinator::engine::MockEngine;
use adcim::coordinator::{
    admissible, AdmissionControl, EdgeServer, InferenceEngine, InferenceRequest,
    InferenceResponse, RoutingPolicy,
};
use adcim::prop_assert;
use adcim::util::loadgen::{self, LoadMode, LoadSpec};
use adcim::util::prop;

fn mock_engines(n: usize, delay_us: u64) -> Vec<Box<dyn InferenceEngine>> {
    (0..n)
        .map(|_| {
            Box::new(MockEngine {
                classes: 10,
                input: 4,
                delay: Duration::from_micros(delay_us),
            }) as Box<dyn InferenceEngine>
        })
        .collect()
}

/// The pure rule is monotone in priority (at fixed depth) and
/// anti-monotone in depth (at fixed priority): a shed frame implies
/// every lower-priority frame at the same or deeper queue is also
/// shed, so graduated shedding can never invert the QoS order.
#[test]
fn admissibility_never_inverts_qos_order() {
    prop::check("admission-monotone", 512, |rng| {
        let max_depth = 1 + (rng.next_u64() % 256) as usize;
        let depth = (rng.next_u64() % (max_depth as u64 + 1)) as usize;
        let hi = (rng.next_u64() % 256) as u8;
        let lo = (rng.next_u64() % (hi as u64 + 1)) as u8;
        if admissible(lo, depth, max_depth) {
            prop_assert!(
                admissible(hi, depth, max_depth),
                "priority inversion: lo={lo} admitted, hi={hi} shed \
                 at depth {depth}/{max_depth}"
            );
        }
        if depth > 0 && !admissible(hi, depth - 1, max_depth) {
            prop_assert!(
                !admissible(hi, depth, max_depth),
                "depth inversion: priority {hi} shed at {} but admitted at {depth} \
                 (max {max_depth})",
                depth - 1
            );
        }
        Ok(())
    });
}

/// The stateful window behaves exactly as the pure rule predicts from
/// the depth observed before each submission — random priority
/// sequences with random interleaved releases.
#[test]
fn admission_control_matches_pure_rule_under_random_traffic() {
    prop::check("admission-stateful", 256, |rng| {
        let max_depth = 1 + (rng.next_u64() % 64) as usize;
        let ac = AdmissionControl::new(max_depth);
        let mut outstanding = 0usize;
        for _ in 0..128 {
            if outstanding > 0 && rng.next_u64() % 4 == 0 {
                ac.release();
                outstanding -= 1;
                continue;
            }
            let priority = (rng.next_u64() % 256) as u8;
            let depth = ac.depth();
            let expect = admissible(priority, depth, max_depth);
            let got = ac.admit_priority(priority);
            prop_assert!(
                got == expect,
                "admit_priority({priority}) at depth {depth}/{max_depth}: \
                 got {got}, pure rule says {expect}"
            );
            if got {
                outstanding += 1;
            }
        }
        Ok(())
    });
}

/// Deterministic overload through the real server: a stalled batcher
/// (huge batch, long deadline, one worker) makes the queue depth a
/// pure function of the submission sequence, so the shed tally is
/// exact. Alternating Keep-band (255) and low-band (60) priorities
/// against `queue_depth` 16: the linear ramp starts at depth 8 and
/// sheds exactly the low-band frames offered at depth ≥ 11
/// (min-priority bar 96 > 60) — 5 of 20 — while every Keep frame
/// admits and answers its own label.
#[test]
fn overload_sheds_low_band_exactly_and_keeps_keep_band() {
    let cfg = ServerConfig {
        workers: 1,
        batch: 64,
        batch_deadline_us: 500_000,
        queue_depth: 16,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, mock_engines(1, 50), RoutingPolicy::RoundRobin).unwrap();
    let spec = LoadSpec {
        mode: LoadMode::Open { qps: 1_000_000, burst: 20 },
        total: 20,
        drain: Duration::from_secs(10),
    };
    let report = loadgen::run(&server, &spec, |i| {
        let priority = if i % 2 == 0 { 255 } else { 60 };
        server.submit(
            InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4]).with_priority(priority),
        )
    });

    assert_eq!(report.offered, 20);
    assert_eq!(report.admitted, 15, "10 Keep + 5 low-band before the ramp bites");
    assert_eq!(report.shed, 5, "low-band frames offered at depth >= 11");
    assert_eq!(report.offered, report.admitted + report.shed + report.malformed);
    assert_eq!(report.completed, 15, "every admitted frame answers after the flush");
    assert_eq!(report.degraded, 0);

    // Keep-class accuracy preserved: every even (Keep-band) id is
    // present and classifies its own label.
    let mut keep_ids: Vec<u64> = report
        .responses
        .iter()
        .filter(|r| r.id % 2 == 0)
        .map(|r| r.id)
        .collect();
    keep_ids.sort_unstable();
    assert_eq!(keep_ids, (0..20).step_by(2).collect::<Vec<u64>>());
    for r in &report.responses {
        assert_eq!(r.class, (r.id % 10) as usize, "wrong answer for frame {}", r.id);
    }

    let snap = server.shutdown();
    assert_eq!(snap.qos_shed, [5, 0, 0, 0], "only class 0 sheds");
    assert_eq!(snap.qos_admitted[3], 10, "every Keep-band frame admitted");
    assert_eq!(snap.qos_admitted[0], 5);
    assert_eq!(snap.rejected_queue_full, 5);
}

fn serve_identical_load(adaptive: bool) -> Vec<InferenceResponse> {
    let cfg = ServerConfig {
        workers: 2,
        batch: 8,
        batch_deadline_us: 400,
        adaptive,
        p99_target_us: if adaptive { 50_000 } else { 0 },
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, mock_engines(2, 30), RoutingPolicy::RoundRobin).unwrap();
    let spec = LoadSpec {
        mode: LoadMode::Closed { concurrency: 8 },
        total: 96,
        drain: Duration::from_secs(10),
    };
    let report = loadgen::run(&server, &spec, |i| {
        server.submit(InferenceRequest::new(i, (i % 4) as u32, vec![(i % 10) as f32; 4]))
    });
    assert_eq!(report.admitted, 96);
    assert_eq!(report.completed, 96);
    let mut responses = report.responses;
    responses.sort_unstable_by_key(|r| r.id);
    server.shutdown();
    responses
}

/// Adaptive-vs-static A/B over byte-identical traffic: whatever batch
/// compositions the two closers produce, per-sample outputs must be
/// bit-for-bit equal — batching policy is a latency knob, never a
/// results knob.
#[test]
fn adaptive_and_static_serving_produce_identical_logits() {
    let static_rs = serve_identical_load(false);
    let adaptive_rs = serve_identical_load(true);
    assert_eq!(static_rs.len(), adaptive_rs.len());
    for (s, a) in static_rs.iter().zip(&adaptive_rs) {
        assert_eq!(s.id, a.id);
        assert_eq!(s.class, a.class);
        assert_eq!(s.logits, a.logits, "logit drift on frame {}", s.id);
        assert!(s.error.is_none() && a.error.is_none());
    }
}
