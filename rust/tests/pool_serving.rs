//! The collaborative digitization pool's serving contracts (ISSUE 2
//! acceptance criteria):
//!
//! 1. An `AnalogEngine` with a 4-array pool in each `ImmersedMode`
//!    serves batched requests end-to-end with per-request
//!    energy/cycles/comparisons visible in `MetricsSnapshot`.
//! 2. The exactly-once digitization invariant holds under runtime
//!    assertions (exercised positively on the serving path and
//!    negatively via the ledger's panics — see also `cim::pool` unit
//!    tests).
//! 3. Pooled `transform_batch` == N sequential transforms, and pooled
//!    `infer_batch` is worker-thread-count invariant.
//! 4. The aligned ideal pool path recovers the *exact* integer
//!    transform (vs the 1-bit path's sign reconstruction).

use std::time::Duration;

use adcim::adc::ImmersedMode;
use adcim::cim::{BitplaneEngine, CimArrayPool, Crossbar, CrossbarConfig, PoolSpec, SignMatrix};
use adcim::config::ServerConfig;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::util::Rng;

/// Analog digit-MLP engine with every BWHT stage behind a 4-array pool
/// (synthetic weights; no artifacts needed). Block width is 16, so pool
/// resolution is capped at 4 bits.
fn pooled_engine(mode: ImmersedMode, adc_bits: u8, threads: usize) -> AnalogEngine {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(36, 4, 16, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term: None,
            seed: 42,
            pool: Some(PoolSpec {
                n_arrays: 4,
                adc_bits,
                mode,
                asymmetric: false,
                threads: 1,
                fuse_batch: false,
            }),
        })
    });
    AnalogEngine::from_model(model, 36).with_threads(threads)
}

/// Ideal-aligned pooled engine (cols == 2^adc_bits, no noise): the
/// configuration where the pooled path is bit-exact with the integer
/// transform and the exact-ET guarantee is airtight. `n_arrays = 8`
/// gives the SAR fabric four independent coupling groups, so
/// `pool_threads` has real parallelism to exercise. Layer thresholds
/// are pinned to the ET dead band expressed in output units
/// (`T_layer = T_et · cols · step`), which is what makes gated and
/// ungated runs produce identical post-threshold outputs.
fn ideal_pooled_engine(
    n_arrays: usize,
    pool_threads: usize,
    t_et: f32,
    gate: bool,
) -> AnalogEngine {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(36, 4, 16, &mut rng);
    let step = 4.0f32 / 15.0; // in_quant_hi / (2^4 − 1)
    model.for_each_bwht(|b| {
        let padded = b.layout().padded_len();
        b.set_thresholds(vec![t_et * 16.0 * step; padded]);
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::ideal(),
            early_term: gate.then(|| adcim::cim::EarlyTermination::exact(t_et)),
            seed: 42,
            pool: Some(PoolSpec {
                n_arrays,
                adc_bits: 4,
                mode: ImmersedMode::Sar,
                asymmetric: false,
                threads: pool_threads,
                fuse_batch: false,
            }),
        })
    });
    AnalogEngine::from_model(model, 36)
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..36).map(|j| ((i * j + i) % 7) as f32 * 0.3).collect())
        .collect()
}

/// Acceptance: 4-array pool in Sar / Flash / Hybrid serves through the
/// full coordinator stack, and the snapshot carries the pool's
/// per-request conversion accounting with the documented per-mode
/// cycle/comparison arithmetic.
#[test]
fn four_array_pool_serves_end_to_end_in_every_mode() {
    let cases = [
        // (mode, adc_bits, cycles/conv, comparisons/conv)
        (ImmersedMode::Sar, 4u8, 4u64, 4u64),
        (ImmersedMode::Flash, 2, 1, 3),
        (ImmersedMode::Hybrid { flash_bits: 2 }, 4, 3, 5),
    ];
    for (mode, adc_bits, cycles, comparisons) in cases {
        let engines: Vec<Box<dyn InferenceEngine>> =
            vec![Box::new(pooled_engine(mode, adc_bits, 2))];
        let cfg = ServerConfig {
            workers: 1,
            batch: 4,
            batch_deadline_us: 500,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
        let imgs = images(12);
        let mut submitted = 0u64;
        for (i, img) in imgs.iter().enumerate() {
            if server.submit(InferenceRequest::new(i as u64, 0, img.clone())).is_ok() {
                submitted += 1;
            }
        }
        let mut got = 0u64;
        while got < submitted {
            match server.recv_response(Duration::from_secs(10)) {
                Some(_) => got += 1,
                None => break,
            }
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, submitted, "{mode:?}");
        assert_eq!(snap.errors, 0, "{mode:?}");
        // Every sample: one 16-wide BWHT block, 4 input planes, 16 rows
        // digitized exactly once per plane.
        let expected_conv = submitted * 16 * 4;
        assert_eq!(snap.conversions, expected_conv, "{mode:?}");
        assert_eq!(snap.adc_cycles, cycles * expected_conv, "{mode:?}");
        assert_eq!(snap.adc_comparisons, comparisons * expected_conv, "{mode:?}");
        assert!(snap.adc_energy_fj > 0.0, "{mode:?}");
        assert!(snap.energy_per_req_fj > 0.0, "{mode:?}");
        assert!(
            (snap.comparisons_per_conversion - comparisons as f64).abs() < 1e-9,
            "{mode:?}"
        );
    }
}

/// The exactly-once invariant on the live serving path: conversions in
/// the snapshot equal MAVs produced (no row converted twice or dropped;
/// the runtime ledger would have panicked otherwise).
#[test]
fn serving_digitizes_every_mav_exactly_once() {
    let mut engine = pooled_engine(ImmersedMode::Sar, 4, 1);
    let imgs = images(6);
    let _ = engine.infer_batch(&imgs).unwrap();
    let stats = engine.conversion_stats();
    // 6 samples x 16 rows x 4 planes.
    assert_eq!(stats.conversions, 6 * 16 * 4);
    assert_eq!(stats.comparisons, 4 * stats.conversions); // 4-bit SAR
}

/// Pooled batch == sequential per-stream transforms (determinism
/// through the pool's phase scheduling + begin_transform reset).
#[test]
fn pooled_transform_batch_equals_sequential_transforms() {
    let spec = PoolSpec {
        n_arrays: 4,
        adc_bits: 5,
        mode: ImmersedMode::Sar,
        asymmetric: false,
        threads: 1,
        fuse_batch: false,
    };
    let mk = || {
        let mut fab = Rng::new(11);
        let matrix = SignMatrix::walsh(32);
        BitplaneEngine::new(Crossbar::new(matrix.clone(), CrossbarConfig::default(), &mut fab), 4)
            .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::default(), spec, &mut fab))
    };
    let mut batch_eng = mk();
    let mut seq_eng = mk();
    let xs: Vec<Vec<u32>> = (0..10)
        .map(|s| (0..32).map(|i| ((i * 7 + s * 13) % 16) as u32).collect())
        .collect();
    let seed = 0xb001u64;
    let batched = batch_eng.transform_batch(&xs, seed);
    for (i, x) in xs.iter().enumerate() {
        let mut r = Rng::for_stream(seed, i as u64);
        let single = seq_eng.transform(x, &mut r);
        assert_eq!(batched[i].values, single.values, "sample {i}");
        assert_eq!(batched[i].conv, single.conv, "sample {i} conversion stats");
    }
}

/// Pooled analog inference is invariant to the engine's worker-thread
/// count, and the shard-merged conversion accounting matches the
/// sequential run.
#[test]
fn pooled_infer_batch_is_thread_count_invariant() {
    let imgs = images(9);
    let mut base = pooled_engine(ImmersedMode::Hybrid { flash_bits: 2 }, 4, 1);
    let want = base.infer_batch(&imgs).unwrap();
    let want_stats = base.conversion_stats();
    for threads in [2usize, 4] {
        let mut e = pooled_engine(ImmersedMode::Hybrid { flash_bits: 2 }, 4, threads);
        let got = e.infer_batch(&imgs).unwrap();
        assert_eq!(got, want, "threads={threads} changed pooled results");
        let stats = e.conversion_stats();
        assert_eq!(stats.conversions, want_stats.conversions, "threads={threads}");
        assert_eq!(stats.comparisons, want_stats.comparisons, "threads={threads}");
        assert_eq!(stats.cycles, want_stats.cycles, "threads={threads}");
        // Energy totals sum identical per-conversion terms; only the
        // shard-merge addition order differs (ulp-level float drift).
        let tol = 1e-9 * want_stats.energy_fj.max(1.0);
        assert!(
            (stats.energy_fj - want_stats.energy_fj).abs() < tol,
            "threads={threads}: energy {} vs {}",
            stats.energy_fj,
            want_stats.energy_fj
        );
    }
}

/// In the aligned ideal case (cols == 2^bits, full settling, no noise)
/// the pooled path is bit-exact with the integer transform oracle —
/// the multi-bit win over the 1-bit sign reconstruction.
#[test]
fn ideal_pool_path_recovers_exact_integer_transform() {
    let spec = PoolSpec {
        n_arrays: 4,
        adc_bits: 5,
        mode: ImmersedMode::Sar,
        asymmetric: false,
        threads: 1,
        fuse_batch: false,
    };
    let mut fab = Rng::new(3);
    let matrix = SignMatrix::walsh(32);
    let mut eng =
        BitplaneEngine::new(Crossbar::new(matrix.clone(), CrossbarConfig::ideal(), &mut fab), 4)
            .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::ideal(), spec, &mut fab));
    let mut rng = Rng::new(4);
    for s in 0..6u32 {
        // Keep at least one zero per plane (x[0] = 0) so no plane is
        // all-ones (full-scale codes clamp at 2^bits − 1).
        let x: Vec<u32> =
            (0..32).map(|i| if i == 0 { 0 } else { (i as u32 * 5 + s) % 16 }).collect();
        let exact = eng.transform_exact(&x);
        let out = eng.transform(&x, &mut rng);
        for (r, e) in exact.iter().enumerate() {
            assert_eq!(out.values[r] as i64, *e, "sample {s} row {r}");
        }
        assert_eq!(out.conv.conversions, 32 * 4);
    }
}

/// ISSUE 3 tentpole: fanning the pool's coupling groups across worker
/// threads must not change served logits or conversion accounting —
/// `process_planes` results are identical at any thread count, all the
/// way up through the engine.
#[test]
fn pool_thread_fanout_does_not_change_serving_results() {
    let imgs = images(8);
    let mut base = ideal_pooled_engine(8, 1, 0.0, false);
    let want = base.infer_batch(&imgs).unwrap();
    let want_stats = base.conversion_stats();
    assert!(want_stats.conversions > 0);
    for pool_threads in [0usize, 2, 4] {
        let mut e = ideal_pooled_engine(8, pool_threads, 0.0, false);
        let got = e.infer_batch(&imgs).unwrap();
        assert_eq!(got, want, "pool_threads={pool_threads} changed logits");
        assert_eq!(e.conversion_stats(), want_stats, "pool_threads={pool_threads}");
    }
}

/// ISSUE 3 acceptance: pooled serving with exact early termination on
/// the ideal-aligned configuration reports strictly fewer conversions
/// and lower conversion energy than the ungated run — at identical
/// logits (the exact-ET guarantee, with layer thresholds pinned to the
/// ET dead band).
#[test]
fn gated_serving_saves_conversions_at_equal_accuracy() {
    let imgs = images(8);
    // T_et = 16: after the MSB plane every row's bound test
    // |acc|/cols + (2^3 − 1) ≤ 16 holds (|acc| ≤ 8·cols), so the three
    // remaining planes are provably skippable — the savings are
    // deterministic, not input-dependent.
    let mut plain = ideal_pooled_engine(4, 1, 16.0, false);
    let mut gated = ideal_pooled_engine(4, 1, 16.0, true);
    let logits_plain = plain.infer_batch(&imgs).unwrap();
    let logits_gated = gated.infer_batch(&imgs).unwrap();
    assert_eq!(logits_gated, logits_plain, "exact ET must not change served logits");
    let sp = plain.conversion_stats();
    let sg = gated.conversion_stats();
    assert!(
        sg.conversions < sp.conversions,
        "gated {} !< ungated {}",
        sg.conversions,
        sp.conversions
    );
    assert!(
        sg.energy_fj < sp.energy_fj,
        "gated energy {} !< ungated {}",
        sg.energy_fj,
        sp.energy_fj
    );
    assert!(sg.cycles < sp.cycles);
    assert_eq!(sp.gated, 0);
}

/// Gated-ET sweep (EXPERIMENTS.md §Pool): as the exact-ET threshold
/// widens, conversions and conversion energy shrink monotonically,
/// per-row gating shows up in the ledger, and the soft-thresholded
/// outputs stay identical to the ungated transform at every rung.
#[test]
fn gated_et_sweep_is_monotone_and_output_preserving() {
    let spec = PoolSpec {
        n_arrays: 4,
        adc_bits: 5,
        mode: ImmersedMode::Sar,
        asymmetric: false,
        threads: 1,
        fuse_batch: false,
    };
    let matrix = SignMatrix::walsh(32);
    let mk = |t_et: Option<f32>| {
        let mut fab = Rng::new(3);
        let mut eng = BitplaneEngine::new(
            Crossbar::new(matrix.clone(), CrossbarConfig::ideal(), &mut fab),
            4,
        )
        .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::ideal(), spec, &mut fab));
        if let Some(t) = t_et {
            eng.early_term = Some(adcim::cim::EarlyTermination::exact(t));
        }
        eng
    };
    let x: Vec<u32> = (0..32).map(|i| ((i * 5 + 3) % 16) as u32).collect();
    let plain = mk(None).transform(&x, &mut Rng::new(1));

    let ladder = [0.0f32, 2.0, 4.0, 8.0, 16.0];
    let mut prev: Option<adcim::cim::ConversionStats> = None;
    let mut sweep = Vec::new();
    for t in ladder {
        let mut eng = mk(Some(t));
        let out = eng.transform(&x, &mut Rng::new(1));
        // Exact ET preserves the soft-thresholded output at the dead
        // band T·cols (transform units).
        for (r, (a, b)) in out.values.iter().zip(&plain.values).enumerate() {
            let ya = adcim::wht::soft_threshold(*a, t * 32.0);
            let yb = adcim::wht::soft_threshold(*b, t * 32.0);
            assert_eq!(ya, yb, "T={t} row {r}: gated {a} vs plain {b}");
        }
        if let Some(p) = &prev {
            assert!(
                out.conv.conversions <= p.conversions,
                "T={t}: conversions rose {} -> {}",
                p.conversions,
                out.conv.conversions
            );
            assert!(
                out.conv.energy_fj <= p.energy_fj,
                "T={t}: energy rose {} -> {}",
                p.energy_fj,
                out.conv.energy_fj
            );
        }
        let pool = eng.pool().unwrap();
        assert_eq!(
            pool.mavs_produced(),
            pool.mavs_digitized() + pool.mavs_gated(),
            "T={t}: every MAV is digitized or gated"
        );
        sweep.push(out.conv);
        prev = Some(out.conv);
    }
    let first = &sweep[0];
    let last = sweep.last().unwrap();
    assert!(last.conversions < first.conversions, "widest dead band must gate work");
    assert!(last.energy_fj < first.energy_fj);
    assert_eq!(first.gated, 0, "T=0 gates nothing");
    assert!(
        sweep.iter().any(|s| s.gated > 0),
        "some rung must show per-row gating (not just whole-plane skips): {sweep:?}"
    );
}

/// The ADC-free 1-bit default path (pool: None) still reconstructs via
/// gamma-scaled signs — pooled and non-pooled engines coexist and the
/// default is untouched by the refactor (bit-exactness with the
/// pre-refactor path is pinned by the unchanged `cim` unit tests and
/// `batched_equivalence.rs`).
#[test]
fn default_path_reports_zero_conversions() {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(36, 4, 16, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term: None,
            seed: 42,
            pool: None,
        })
    });
    let mut engine = AnalogEngine::from_model(model, 36);
    let _ = engine.infer_batch(&images(4)).unwrap();
    let stats = engine.conversion_stats();
    assert_eq!(stats.conversions, 0);
    assert_eq!(stats.energy_fj, 0.0);
}
