//! End-to-end coordinator integration: router + batcher + backpressure +
//! workers over real engines (analog CiM simulator; digital PJRT is
//! covered in runtime_integration.rs and examples/edge_pipeline.rs).

use std::time::{Duration, Instant};

use adcim::cim::CrossbarConfig;
use adcim::config::ServerConfig;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::nn::dataset::Dataset;
use adcim::runtime::Artifacts;

/// Trained-weight artifacts need `make artifacts` (a python/JAX step the
/// offline CI image cannot run); tests that exercise real weights skip
/// gracefully when they are absent instead of failing the tier-1 suite.
/// `Artifacts::open` only errors when `model.manifest.txt` is absent —
/// corrupt artifacts still fail loudly inside the tests' unwraps.
fn artifacts() -> Option<Artifacts> {
    match Artifacts::open(Artifacts::default_dir()) {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            None
        }
    }
}

fn collect(server: &EdgeServer, n: usize) -> Vec<adcim::coordinator::InferenceResponse> {
    let mut got = Vec::new();
    let t0 = Instant::now();
    while got.len() < n && t0.elapsed() < Duration::from_secs(60) {
        if let Some(r) = server.recv_response(Duration::from_millis(200)) {
            got.push(r);
        }
    }
    got
}

#[test]
fn analog_pool_serves_with_expected_accuracy() {
    let Some(a) = artifacts() else {
        return;
    };
    let engines: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(&a, CrossbarConfig::default(), None, 4, w as u64).unwrap(),
            ) as Box<dyn InferenceEngine>
        })
        .collect();
    let cfg = ServerConfig { workers: 2, batch: 8, batch_deadline_us: 1000, ..Default::default() };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::LeastLoaded).unwrap();

    let data = Dataset::digits(48, 12, 0xeda);
    for (i, img) in data.images.iter().enumerate() {
        assert!(server
            .submit(InferenceRequest::new(
                i as u64,
                (i % 3) as u32,
                img.clone().reshape(&[144]).data().to_vec()
            ))
            .is_ok());
    }
    let got = collect(&server, 48);
    assert_eq!(got.len(), 48, "all responses arrive");
    let correct = got.iter().filter(|r| r.class == data.labels[r.id as usize]).count();
    assert!(correct * 3 > 48, "accuracy {correct}/48 vs chance 4.8");
    let snap = server.shutdown();
    assert_eq!(snap.completed, 48);
    assert_eq!(snap.errors, 0);
}

#[test]
fn per_request_ids_preserved_through_pipeline() {
    let Some(a) = artifacts() else {
        return;
    };
    let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(
        AnalogEngine::load(&a, CrossbarConfig::ideal(), None, 4, 1).unwrap(),
    )];
    let cfg = ServerConfig { workers: 1, batch: 4, batch_deadline_us: 500, ..Default::default() };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
    let data = Dataset::digits(12, 12, 0x1d5);
    for (i, img) in data.images.iter().enumerate() {
        server
            .submit(InferenceRequest::new(
                1000 + i as u64,
                0,
                img.clone().reshape(&[144]).data().to_vec(),
            ))
            .unwrap();
    }
    let got = collect(&server, 12);
    let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, (1000..1012).collect::<Vec<u64>>());
    server.shutdown();
}

#[test]
fn analog_engine_early_termination_counts_and_saves() {
    use adcim::cim::EarlyTermination;
    use adcim::coordinator::InferenceEngine as _;
    let Some(a) = artifacts() else {
        return;
    };
    let m = a.manifest().unwrap();
    let batch = a.test_batch().unwrap();
    let images: Vec<Vec<f32>> = batch.chunks(m.input).map(|c| c.to_vec()).collect();
    let mut engine = AnalogEngine::load(
        &a,
        CrossbarConfig::default(),
        Some(EarlyTermination::exact(6.0)),
        m.input_bits,
        3,
    )
    .unwrap();
    let _ = engine.infer_batch(&images).unwrap();
    let (processed, skipped) = engine.termination_stats();
    assert!(processed > 0, "no work recorded");
    // The QAT-trained thresholds give the dead band real width: some
    // row-plane work must be skipped.
    assert!(skipped > 0, "early termination saved nothing");
}

#[test]
fn wrong_image_dim_is_engine_error_not_panic() {
    use adcim::coordinator::InferenceEngine as _;
    let Some(a) = artifacts() else {
        return;
    };
    let mut engine = AnalogEngine::load(&a, CrossbarConfig::ideal(), None, 4, 5).unwrap();
    let res = engine.infer_batch(&[vec![0.0; 7]]);
    assert!(res.is_err(), "dim mismatch must surface as Err");
}

#[test]
fn metrics_reflect_served_load() {
    let Some(a) = artifacts() else {
        return;
    };
    let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(
        AnalogEngine::load(&a, CrossbarConfig::ideal(), None, 4, 2).unwrap(),
    )];
    let cfg = ServerConfig { workers: 1, batch: 8, batch_deadline_us: 500, ..Default::default() };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
    let data = Dataset::digits(16, 12, 0x3e7);
    for (i, img) in data.images.iter().enumerate() {
        server
            .submit(InferenceRequest::new(
                i as u64,
                0,
                img.clone().reshape(&[144]).data().to_vec(),
            ))
            .unwrap();
    }
    let got = collect(&server, 16);
    assert_eq!(got.len(), 16);
    let snap = server.shutdown();
    assert_eq!(snap.completed, 16);
    assert!(snap.p50_latency_us > 0.0);
    assert!(snap.mean_batch >= 1.0);
    assert!(snap.throughput_per_s > 0.0);
}
