//! ISSUE 5 acceptance: the persistent deterministic worker runtime and
//! cross-sample plane fusion serve **bit-identically** to the PR-3
//! sequential plane walk.
//!
//! 1. A persistent-runtime engine serving N consecutive batches equals
//!    the sequential (t=1) engine serving the same N batches — logits,
//!    termination counters, conversion accounting — and the runtime is
//!    built once, not per batch.
//! 2. Fused == unfused == sequential at the `BitplaneEngine` level:
//!    outputs, plane signs and `ConversionStats` (energy float
//!    accumulation included) are `assert_eq!`-equal at any pool thread
//!    count.
//! 3. Gated early termination under fusion keeps the
//!    `gated_et_sweep_is_monotone_and_output_preserving` semantics:
//!    monotone conversion/energy decline, per-row gating visible, and
//!    outputs preserved under the dead-band soft threshold.
//! 4. The same identities hold end-to-end through `AnalogEngine`
//!    (shards × pool lanes on one shared runtime).

use std::sync::Arc;

use adcim::adc::ImmersedMode;
use adcim::cim::{
    BitplaneEngine, CimArrayPool, ConversionStats, Crossbar, CrossbarConfig, PoolSpec, SignMatrix,
};
use adcim::coordinator::AnalogEngine;
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::util::Rng;

fn spec(n_arrays: usize, threads: usize, fuse_batch: bool) -> PoolSpec {
    PoolSpec {
        n_arrays,
        adc_bits: 5,
        mode: ImmersedMode::Sar,
        asymmetric: false,
        threads,
        fuse_batch,
    }
}

/// Noisy pooled bitplane engine over a 32-wide Walsh crossbar.
fn pooled_bitplane_engine(pool_spec: PoolSpec) -> BitplaneEngine {
    let mut fab = Rng::new(11);
    let matrix = SignMatrix::walsh(32);
    BitplaneEngine::new(Crossbar::new(matrix.clone(), CrossbarConfig::default(), &mut fab), 4)
        .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::default(), pool_spec, &mut fab))
}

/// Analog digit-MLP engine with pooled BWHT stages (16-wide blocks cap
/// the pool at 4 bits).
fn pooled_analog_engine(
    engine_threads: usize,
    pool_threads: usize,
    fuse_batch: bool,
) -> AnalogEngine {
    let mut rng = Rng::new(1);
    let mut model = bwht_mlp(36, 4, 16, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term: None,
            seed: 42,
            pool: Some(PoolSpec {
                n_arrays: 4,
                adc_bits: 4,
                mode: ImmersedMode::Sar,
                asymmetric: false,
                threads: pool_threads,
                fuse_batch,
            }),
        })
    });
    AnalogEngine::from_model(model, 36).with_threads(engine_threads)
}

fn images(n: usize, salt: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..36).map(|j| ((i * j + i + salt * 7) % 7) as f32 * 0.3).collect())
        .collect()
}

fn batch(n: usize, salt: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|s| (0..32).map(|i| ((i * 7 + s * 13 + salt * 5) % 16) as u32).collect())
        .collect()
}

fn assert_energy_close(a: &ConversionStats, b: &ConversionStats, what: &str) {
    let tol = 1e-9 * b.energy_fj.max(1.0);
    assert!(
        (a.energy_fj - b.energy_fj).abs() < tol,
        "{what}: energy {} vs {}",
        a.energy_fj,
        b.energy_fj
    );
}

/// Satellite: a persistent-runtime serve of N consecutive batches is
/// bit-identical to the same N batches on the sequential engine —
/// outputs, `ConversionStats` counters, energy (to shard-merge float
/// association), and the runtime itself is reused across batches.
#[test]
fn persistent_runtime_serves_consecutive_batches_like_sequential() {
    let mut seq = pooled_analog_engine(1, 1, false);
    let mut par = pooled_analog_engine(4, 2, false);
    for round in 0..3usize {
        let imgs = images(9, round);
        let want = seq.infer_batch(&imgs).unwrap();
        let got = par.infer_batch(&imgs).unwrap();
        assert_eq!(got, want, "round {round}: persistent-runtime logits diverged");

        // The runtime is built at the first parallel batch and reused
        // for the engine's lifetime — never rebuilt per batch.
        let exec = par.executor().expect("parallel engine has a runtime").clone();
        if round == 0 {
            assert!(exec.lanes() >= 2);
        }
        let imgs2 = images(5, 100 + round);
        let want2 = seq.infer_batch(&imgs2).unwrap();
        let got2 = par.infer_batch(&imgs2).unwrap();
        assert_eq!(got2, want2, "round {round}: second batch diverged");
        let exec2 = par.executor().unwrap();
        assert!(Arc::ptr_eq(&exec, exec2), "round {round}: runtime was rebuilt");
    }
    let s = seq.conversion_stats();
    let p = par.conversion_stats();
    assert!(s.conversions > 0);
    assert_eq!(p.conversions, s.conversions);
    assert_eq!(p.comparisons, s.comparisons);
    assert_eq!(p.cycles, s.cycles);
    assert_eq!(p.gated, s.gated);
    assert_energy_close(&p, &s, "persistent vs sequential");
    assert_eq!(par.termination_stats(), seq.termination_stats());
}

/// Tentpole bit-exactness: fused == unfused == sequential at the
/// bitplane-engine level, `assert_eq!` down to the `energy_fj` float
/// accumulation, at every pool thread count.
#[test]
fn fused_transform_batch_equals_unfused_bit_exactly() {
    let xs = batch(12, 0);
    let seed = 0xfade;
    let mut base = pooled_bitplane_engine(spec(8, 1, false));
    let want = base.transform_batch(&xs, seed);
    let want_pool = base.pool().unwrap().stats();
    assert!(want_pool.conversions > 0);

    for threads in [1usize, 2, 4] {
        let mut fused = pooled_bitplane_engine(spec(8, threads, true));
        let got = fused.transform_batch(&xs, seed);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.values, w.values, "t={threads} sample {i} values");
            assert_eq!(g.plane_signs, w.plane_signs, "t={threads} sample {i} signs");
            assert_eq!(g.conv, w.conv, "t={threads} sample {i} conversion stats");
            assert_eq!(g.term.processed, w.term.processed, "t={threads} sample {i}");
            assert_eq!(g.term.skipped, w.term.skipped, "t={threads} sample {i}");
        }
        let pool = fused.pool().unwrap();
        assert_eq!(pool.stats(), want_pool, "t={threads} pool accounting");
        assert_eq!(pool.mavs_produced(), pool.mavs_digitized() + pool.mavs_gated());
    }

    // And repeated fused batches keep matching repeated sequential
    // transforms (scratch arenas reused, no state bleed).
    let mut fused = pooled_bitplane_engine(spec(8, 2, true));
    let mut seq = pooled_bitplane_engine(spec(8, 1, false));
    for (round, salt) in [(0usize, 0usize), (1, 3)] {
        let round_xs = batch(7, salt);
        let a = fused.transform_batch(&round_xs, 0x11 + round as u64);
        let b = seq.transform_batch(&round_xs, 0x11 + round as u64);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.values, y.values, "round {round} sample {i}");
            assert_eq!(x.conv, y.conv, "round {round} sample {i}");
        }
    }
}

/// Gated ET under fusion: the fused walk matches the sequential gated
/// walk exactly at every dead-band rung, the sweep stays monotone, and
/// per-row gating still fires inside fused submissions.
#[test]
fn fused_gated_et_keeps_sweep_semantics() {
    let mk = |t_et: Option<f32>, fuse: bool| {
        let mut fab = Rng::new(3);
        let matrix = SignMatrix::walsh(32);
        let mut eng = BitplaneEngine::new(
            Crossbar::new(matrix.clone(), CrossbarConfig::ideal(), &mut fab),
            4,
        )
        .with_pool(CimArrayPool::new(
            &matrix,
            CrossbarConfig::ideal(),
            spec(4, 1, fuse),
            &mut fab,
        ));
        if let Some(t) = t_et {
            eng.early_term = Some(adcim::cim::EarlyTermination::exact(t));
        }
        eng
    };
    // Sample 0 is exactly the `gated_et_sweep_is_monotone_and_output_preserving`
    // input, which that test proves gates rows at some rung — so the
    // `any_gated` assertion below is deterministic, not hopeful.
    let xs: Vec<Vec<u32>> = (0..5)
        .map(|s| (0..32).map(|i| ((i * 5 + 3 + s * 2) % 16) as u32).collect())
        .collect();
    let seed = 0x5eed;
    let plain = mk(None, true).transform_batch(&xs, seed);

    let ladder = [0.0f32, 2.0, 4.0, 8.0, 16.0];
    let mut first: Option<ConversionStats> = None;
    let mut prev: Option<ConversionStats> = None;
    let mut any_gated = false;
    for t in ladder {
        let mut fused = mk(Some(t), true);
        let mut seq = mk(Some(t), false);
        let got = fused.transform_batch(&xs, seed);
        let want = seq.transform_batch(&xs, seed);
        let mut total = ConversionStats::default();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.values, w.values, "T={t} sample {i}: fused != sequential");
            assert_eq!(g.conv, w.conv, "T={t} sample {i}: accounting diverged");
            assert_eq!(g.term.skipped, w.term.skipped, "T={t} sample {i}");
            total.merge(&g.conv);
            // Exact ET preserves the soft-thresholded output at the
            // dead band T·cols (transform units).
            for (r, (a, b)) in g.values.iter().zip(&plain[i].values).enumerate() {
                let ya = adcim::wht::soft_threshold(*a, t * 32.0);
                let yb = adcim::wht::soft_threshold(*b, t * 32.0);
                assert_eq!(ya, yb, "T={t} sample {i} row {r}");
            }
        }
        if let Some(p) = &prev {
            assert!(
                total.conversions <= p.conversions,
                "T={t}: conversions rose {} -> {}",
                p.conversions,
                total.conversions
            );
            assert!(total.energy_fj <= p.energy_fj, "T={t}: energy rose");
        }
        any_gated |= total.gated > 0;
        let pool = fused.pool().unwrap();
        assert_eq!(
            pool.mavs_produced(),
            pool.mavs_digitized() + pool.mavs_gated(),
            "T={t}: every MAV digitized or gated under fusion"
        );
        if first.is_none() {
            first = Some(total);
        }
        prev = Some(total);
    }
    let (first, last) = (first.unwrap(), prev.unwrap());
    assert!(last.conversions > 0, "widest rung still converts the MSB plane");
    assert!(last.conversions < first.conversions, "widest dead band must gate work");
    assert!(last.energy_fj < first.energy_fj);
    assert!(any_gated, "some rung must gate rows inside fused submissions");
}

/// Fusion end-to-end: `AnalogEngine` with `fuse_batch` serves the same
/// logits and accounting as without, across engine-thread and
/// pool-thread counts — the serving-path identity the `--fuse-batch`
/// flag relies on.
#[test]
fn fused_serving_through_engine_is_identical() {
    let imgs = images(8, 2);
    let mut base = pooled_analog_engine(1, 1, false);
    let want = base.infer_batch(&imgs).unwrap();
    let want_stats = base.conversion_stats();
    assert!(want_stats.conversions > 0);
    for (engine_threads, pool_threads) in [(1usize, 1usize), (1, 4), (2, 1), (2, 4)] {
        let mut fused = pooled_analog_engine(engine_threads, pool_threads, true);
        let got = fused.infer_batch(&imgs).unwrap();
        assert_eq!(got, want, "fuse t=({engine_threads},{pool_threads}) changed served logits");
        let stats = fused.conversion_stats();
        assert_eq!(stats.conversions, want_stats.conversions);
        assert_eq!(stats.comparisons, want_stats.comparisons);
        assert_eq!(stats.cycles, want_stats.cycles);
        assert_eq!(stats.gated, want_stats.gated);
        assert_energy_close(
            &stats,
            &want_stats,
            &format!("fused ({engine_threads},{pool_threads})"),
        );
    }
}
