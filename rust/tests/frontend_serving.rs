//! The frequency-domain sensor frontend's serving contracts (ISSUE 4
//! acceptance criteria):
//!
//! 1. Compressed frames flow through the real batcher/router/worker
//!    path (`EdgeServer` end to end), and `FrontendStats` lands in the
//!    final `MetricsSnapshot`.
//! 2. **Zero-compression serving is bit-exact vs raw**: with every
//!    coefficient kept losslessly, serving the compressed deluge
//!    produces bit-identical logits to serving the (sensor-snapped) raw
//!    deluge — through the full coordinator stack, analog noise
//!    included.
//! 3. **Top-K retention contains the deluge**: on the multispectral
//!    workload, compressed ingest is ≥ 5× smaller in bytes at matched
//!    argmax accuracy, and the triage policy sheds blank filler frames.
//! 4. The folded transform-domain fast path agrees with the decode
//!    fallback (engine-level test in `coordinator::engine`; here it is
//!    exercised implicitly — lossy frames served below take it).

use std::time::Duration;

use adcim::cim::CrossbarConfig;
use adcim::config::ServerConfig;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::frontend::{
    CodecParams, FrameEncoder, FrontendConfig, IngestDecision, LOSSLESS, RetentionPolicy,
    Selection, SensorFrontend,
};
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::nn::train::{train, TrainConfig};
use adcim::nn::{Dataset, Tensor};
use adcim::util::Rng;

const CHANNELS: usize = 4;
const SIDE: usize = 8;
const SAMPLES: usize = SIDE * SIDE;
const INPUT: usize = CHANNELS * SAMPLES;
const CLASSES: usize = 4;

/// Analog digit-MLP engine over the multispectral input dim (synthetic
/// weights; no artifacts needed).
fn analog_engine(seed: u64) -> AnalogEngine {
    let mut rng = Rng::new(seed);
    let mut model = bwht_mlp(INPUT, CLASSES, 32, &mut rng);
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term: None,
            seed: 42,
            pool: None,
        })
    });
    AnalogEngine::from_model(model, INPUT)
}

fn flat_frames(n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
    let data = Dataset::multispectral(n, CLASSES, SIDE, CHANNELS, seed);
    let frames = data
        .images
        .iter()
        .map(|i| i.clone().reshape(&[INPUT]).data().to_vec())
        .collect();
    (frames, data.labels)
}

/// Serve `requests` through a 1-worker server and collect responses by
/// id. One worker + one batcher keeps the engine's per-sample stream
/// assignment equal to submission order, so two runs over the same
/// frames are comparable bit-for-bit.
fn serve(
    engine: AnalogEngine,
    requests: Vec<InferenceRequest>,
) -> (Vec<(u64, Vec<f32>, usize)>, adcim::coordinator::metrics::MetricsSnapshot) {
    let cfg = ServerConfig {
        workers: 1,
        batch: 8,
        batch_deadline_us: 500,
        queue_depth: 4096,
        ..Default::default()
    };
    let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(engine)];
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
    let mut submitted = 0u64;
    for req in requests {
        assert!(server.submit(req).is_ok(), "queue must admit the test load");
        submitted += 1;
    }
    let mut got = Vec::new();
    while (got.len() as u64) < submitted {
        match server.recv_response(Duration::from_secs(10)) {
            Some(r) => got.push((r.id, r.logits, r.class)),
            None => break,
        }
    }
    assert_eq!(got.len() as u64, submitted, "lost responses");
    got.sort_by_key(|(id, _, _)| *id);
    let snap = server.shutdown();
    (got, snap)
}

/// Acceptance: zero-compression (lossless, keep-all) serving through
/// the full coordinator stack is bit-identical to raw serving of the
/// sensor-snapped frames.
#[test]
fn zero_compression_serving_is_bit_exact_vs_raw() {
    let params = CodecParams::new(CHANNELS, SAMPLES, 8, LOSSLESS).unwrap();
    let (frames, _) = flat_frames(24, 0xa11);
    let snapped: Vec<Vec<f32>> = frames
        .iter()
        .map(|f| f.iter().map(|&v| params.snap(v)).collect())
        .collect();

    let raw_reqs: Vec<InferenceRequest> = snapped
        .iter()
        .enumerate()
        .map(|(i, f)| InferenceRequest::new(i as u64, 0, f.clone()))
        .collect();
    let (raw, _) = serve(analog_engine(1), raw_reqs);

    let mut enc = FrameEncoder::new(params, Selection::All);
    let comp_reqs: Vec<InferenceRequest> = frames
        .iter()
        .enumerate()
        .map(|(i, f)| InferenceRequest::compressed(i as u64, 0, enc.encode(f, i as u64)))
        .collect();
    let (comp, _) = serve(analog_engine(1), comp_reqs);

    assert_eq!(raw.len(), comp.len());
    for ((id_r, logits_r, _), (id_c, logits_c, _)) in raw.iter().zip(&comp) {
        assert_eq!(id_r, id_c);
        assert_eq!(logits_r, logits_c, "id {id_r}: compressed serving must be bit-exact");
    }
}

/// Acceptance: top-K retention cuts ingest bytes ≥ 5× at matched argmax
/// accuracy on the multispectral workload, with a trained classifier.
#[test]
fn topk_retention_reduces_bytes_at_matched_accuracy() {
    // Train a classifier on raw multispectral frames.
    let data = Dataset::multispectral(320, CLASSES, SIDE, CHANNELS, 0x5eed);
    let (tr, te) = data.split(0.8);
    let (tr, te) = (tr.flattened(), te.flattened());
    let mut model = bwht_mlp(INPUT, CLASSES, 32, &mut Rng::new(7));
    let log = train(
        &mut model,
        &tr,
        &te,
        TrainConfig { epochs: 5, lr: 0.06, ..Default::default() },
    );
    let trained_acc = *log.epoch_test_acc.last().unwrap();
    assert!(trained_acc > 0.45, "classifier failed to train: {trained_acc}");

    // Evaluate raw vs top-K compressed frames on the same model.
    let params = CodecParams::new(CHANNELS, SAMPLES, 8, 8).unwrap();
    let mut enc = FrameEncoder::new(params, Selection::TopK(32));
    let mut bytes_in = 0usize;
    let mut bytes_out = 0usize;
    let mut raw_correct = 0usize;
    let mut comp_correct = 0usize;
    let mut agree = 0usize;
    for (i, (img, &label)) in te.images.iter().zip(&te.labels).enumerate() {
        let cf = enc.encode(img.data(), i as u64);
        bytes_in += params.raw_frame_bytes();
        bytes_out += cf.encoded_bytes();
        let dec = cf.decode();
        let raw_class = model.forward_inference(img).argmax();
        let comp_class = model.forward_inference(&Tensor::vec1(&dec)).argmax();
        if raw_class == label {
            raw_correct += 1;
        }
        if comp_class == label {
            comp_correct += 1;
        }
        if raw_class == comp_class {
            agree += 1;
        }
    }
    let n = te.len();
    let ratio = bytes_in as f64 / bytes_out as f64;
    assert!(ratio >= 5.0, "ingest-byte reduction {ratio:.1}x < 5x");
    let raw_acc = raw_correct as f64 / n as f64;
    let comp_acc = comp_correct as f64 / n as f64;
    assert!(
        comp_acc >= raw_acc - 0.06,
        "compressed accuracy {comp_acc:.3} fell more than 0.06 below raw {raw_acc:.3}"
    );
    assert!(
        agree as f64 / n as f64 >= 0.8,
        "argmax agreement {:.3} < 0.8 ({agree}/{n})",
        agree as f64 / n as f64
    );
}

/// The retention policy sheds blank filler, compressed survivors serve
/// end-to-end through the coordinator, and `FrontendStats` shows up in
/// the `MetricsSnapshot` with a real byte reduction.
#[test]
fn retention_triage_contains_the_deluge_end_to_end() {
    let params = CodecParams::new(CHANNELS, SAMPLES, 8, 8).unwrap();
    let mut frontend = SensorFrontend::new(FrontendConfig {
        policy: RetentionPolicy::triage_default(),
        ..FrontendConfig::new(params, Selection::TopK(32))
    });
    let (frames, _) = flat_frames(20, 0xfee);

    // Interleave real frames with pure-blank filler (the deluge).
    let mut requests = Vec::new();
    let mut offered = 0u64;
    let mut blank_kept = 0u64;
    for (i, frame) in frames.iter().enumerate() {
        for (slot, f) in
            [frame.clone(), vec![0.5f32; INPUT]].into_iter().enumerate()
        {
            let id = 2 * i as u64 + slot as u64;
            offered += 1;
            if let IngestDecision::Keep(cf) = frontend.ingest(&f, id, 0) {
                if slot == 1 {
                    blank_kept += 1;
                }
                requests.push(InferenceRequest::compressed(id, 0, cf));
            }
        }
    }
    assert_eq!(blank_kept, 0, "constant blank frames must never be kept");
    assert!(
        requests.len() >= frames.len() / 2,
        "too few real frames survived: {}/{}",
        requests.len(),
        frames.len()
    );

    let stats = frontend.take_stats();
    assert_eq!(stats.frames_in, offered);
    assert_eq!(stats.kept as usize, requests.len());
    assert_eq!(stats.kept + stats.summarized + stats.dropped, offered);
    assert!(stats.dropped > 0, "the blank half must be shed");
    assert!(
        stats.compression_ratio() >= 5.0,
        "deluge bytes {} -> {} is under 5x",
        stats.bytes_in,
        stats.bytes_out
    );

    let n = requests.len() as u64;
    let (got, snap) = {
        let engine = analog_engine(3);
        let cfg = ServerConfig {
            workers: 1,
            batch: 8,
            batch_deadline_us: 500,
            queue_depth: 4096,
            ..Default::default()
        };
        let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(engine)];
        let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
        for req in requests {
            assert!(server.submit(req).is_ok());
        }
        let mut got = Vec::new();
        while (got.len() as u64) < n {
            match server.recv_response(Duration::from_secs(10)) {
                Some(r) => got.push(r),
                None => break,
            }
        }
        server.record_frontend(&stats);
        (got, server.shutdown())
    };
    assert_eq!(got.len() as u64, n, "every kept frame must serve");
    assert_eq!(snap.completed, n);
    assert_eq!(snap.frontend.frames_in, offered);
    assert!(snap.frontend.dropped > 0);
    let line = format!("{snap}");
    assert!(line.contains("frontend:"), "snapshot must show the frontend: {line}");
}

/// Frontend ingest is deterministic under the `Rng::for_stream` dither
/// contract even when streams interleave differently.
#[test]
fn frontend_ingest_is_order_independent_per_frame_id() {
    let params = CodecParams::new(CHANNELS, SAMPLES, 8, 6).unwrap();
    let mk = || {
        let mut cfg = FrontendConfig::new(params, Selection::TopK(16));
        cfg.dither = true;
        cfg.seed = 0xd17;
        SensorFrontend::new(cfg)
    };
    let (frames, _) = flat_frames(12, 0x0dd);
    // Forward order.
    let mut a = mk();
    let fwd: Vec<_> = frames
        .iter()
        .enumerate()
        .map(|(i, f)| a.ingest(f, i as u64, 0))
        .collect();
    // Reverse arrival order — same ids ⇒ same encodings.
    let mut b = mk();
    let mut rev: Vec<_> = frames
        .iter()
        .enumerate()
        .rev()
        .map(|(i, f)| (i, b.ingest(f, i as u64, 1)))
        .collect();
    rev.sort_by_key(|(i, _)| *i);
    for ((i, r), f) in rev.into_iter().zip(&fwd) {
        match (&r, f) {
            (IngestDecision::Keep(x), IngestDecision::Keep(y)) => {
                assert_eq!(x, y, "frame {i} encoding must not depend on arrival order")
            }
            _ => assert_eq!(
                std::mem::discriminant(&r),
                std::mem::discriminant(f),
                "frame {i} verdict changed"
            ),
        }
    }
}
