//! Deterministic wire-codec fuzz (ISSUE 6 acceptance: *no reachable
//! panic from hostile frame bytes*).
//!
//! A pool of valid encodings spanning the codec parameter grid is
//! mutated with seeded bit flips, truncations and splices; every
//! mutant must either come back as a [`CodecError`] or decode as a
//! well-formed frame — never panic. Runs ≥ 10k cases by default;
//! `WIRE_FUZZ_CASES` overrides the budget (CI smoke uses the same
//! count explicitly via `scripts/ci.sh --fuzz-smoke`).

use std::panic::{catch_unwind, AssertUnwindSafe};

use adcim::frontend::{CodecParams, CompressedFrame, FrameEncoder, Selection, LOSSLESS};
use adcim::prop_assert;
use adcim::util::{prop, Rng};

/// Fuzz budget: `WIRE_FUZZ_CASES` env override, else a fast smoke count
/// under `BENCH_SMOKE`, else the full 12k (> the 10k acceptance floor).
fn fuzz_cases() -> u64 {
    if let Ok(v) = std::env::var("WIRE_FUZZ_CASES") {
        if let Ok(n) = v.parse::<u64>() {
            return n.max(1);
        }
    }
    if adcim::util::bench::smoke_mode() {
        1_500
    } else {
        12_000
    }
}

/// Codec parameter grid: channel counts, non-power-of-two sample
/// counts, the full codec-bits range including lossless, and the
/// degenerate 1×1 frame. All satisfy the exactness bound.
const GRID: &[(usize, usize, u8, u8)] = &[
    (1, 64, 8, 8),
    (4, 144, 8, 6),
    (3, 33, 4, LOSSLESS),
    (2, 256, 6, 2),
    (8, 32, 10, 16),
    (1, 1, 1, 2),
];

/// Valid wire encodings across the grid × selection × dither — the
/// fuzz corpus.
fn encoding_pool() -> Vec<Vec<u8>> {
    let mut rng = Rng::new(0xf0_22);
    let mut pool = Vec::new();
    for &(channels, samples, sensor_bits, codec_bits) in GRID {
        let params = CodecParams::new(channels, samples, sensor_bits, codec_bits).unwrap();
        for selection in [Selection::All, Selection::TopK(9), Selection::EnergyFrac(0.8)] {
            for dither in [false, true] {
                let mut enc = FrameEncoder::new(params, selection);
                enc.dither = dither;
                enc.seed = 7;
                let frame: Vec<f32> =
                    (0..channels * samples).map(|_| rng.uniform() as f32).collect();
                pool.push(enc.encode_wire(&frame, pool.len() as u64));
            }
        }
    }
    pool
}

/// One seeded mutant: a pool encoding put through 1..=3 mutations drawn
/// from {bit flips, truncation, foreign-chunk splice, delete/overwrite}.
fn mutate(rng: &mut Rng, pool: &[Vec<u8>]) -> Vec<u8> {
    let mut b = pool[rng.index(pool.len())].clone();
    for _ in 0..1 + rng.index(3) {
        match rng.index(4) {
            0 => {
                if b.is_empty() {
                    continue;
                }
                for _ in 0..1 + rng.index(8) {
                    let bit = rng.index(b.len() * 8);
                    b[bit / 8] ^= 1 << (bit % 8);
                }
            }
            1 => b.truncate(rng.index(b.len() + 1)),
            2 => {
                let src = &pool[rng.index(pool.len())];
                let n = 1 + rng.index(16.min(src.len()));
                let start = rng.index(src.len() - n + 1);
                let at = rng.index(b.len() + 1);
                for (k, &byte) in src[start..start + n].iter().enumerate() {
                    b.insert(at + k, byte);
                }
            }
            _ => {
                if b.is_empty() {
                    continue;
                }
                let n = 1 + rng.index(8.min(b.len()));
                let at = rng.index(b.len() - n + 1);
                if rng.bool() {
                    b.drain(at..at + n);
                } else {
                    for byte in b.iter_mut().skip(at).take(n) {
                        *byte = (rng.next_u64() & 0xff) as u8;
                    }
                }
            }
        }
    }
    b
}

/// Every mutant either errors or validates; a validated frame must also
/// decode without panicking, to the declared dense length.
#[test]
fn mutated_wire_bytes_never_panic() {
    let pool = encoding_pool();
    let cases = fuzz_cases();
    prop::check("wire-fuzz-no-panic", cases, |rng| {
        let mutated = mutate(rng, &pool);
        let parsed =
            match catch_unwind(AssertUnwindSafe(|| CompressedFrame::from_bytes(&mutated))) {
                Ok(r) => r,
                Err(_) => return Err(format!("from_bytes panicked on {} bytes", mutated.len())),
            };
        if let Ok(frame) = parsed {
            let dense = frame.params.channels * frame.params.samples;
            match catch_unwind(AssertUnwindSafe(|| frame.try_decode())) {
                Ok(Ok(out)) => {
                    prop_assert!(
                        out.len() == dense,
                        "validated frame decoded to {} samples, declared {dense}",
                        out.len()
                    );
                }
                Ok(Err(e)) => return Err(format!("validated frame failed decode: {e}")),
                Err(_) => return Err("try_decode panicked on a validated frame".to_string()),
            }
        }
        Ok(())
    });
}

/// Untouched corpus encodings survive the boundary byte-for-byte:
/// `from_bytes` accepts them and `to_bytes` reproduces them exactly
/// (the canonical-encoding contract the fuzz relies on).
#[test]
fn corpus_round_trips_byte_exact() {
    for (i, wire) in encoding_pool().iter().enumerate() {
        let frame = CompressedFrame::from_bytes(wire)
            .unwrap_or_else(|e| panic!("corpus frame {i} rejected: {e}"));
        assert_eq!(&frame.to_bytes(), wire, "corpus frame {i} is not canonical");
        assert_eq!(
            frame.try_decode().unwrap().len(),
            frame.params.channels * frame.params.samples,
            "corpus frame {i} decode length"
        );
    }
}
