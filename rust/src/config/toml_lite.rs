//! Minimal TOML-subset parser.
//!
//! Grammar: `[section]` lines, `key = value` lines, `#` comments, blank
//! lines. Values: i64, f64, bool, "quoted string". No arrays, no nested
//! tables — config files in configs/ stay within this subset.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Quoted string.
    Str(String),
}

/// Parsed document: (section, key) → value.
#[derive(Debug, Clone, Default)]
pub struct TomlLite {
    entries: BTreeMap<(String, String), Value>,
}

impl TomlLite {
    /// Parse `[section]\nkey = value` text (flat sections only).
    pub fn parse(text: &str) -> Result<TomlLite> {
        let mut doc = TomlLite::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
            };
            let key = k.trim().to_string();
            let val = Self::parse_value(v.trim())
                .ok_or_else(|| anyhow::anyhow!("line {}: bad value {v:?}", lineno + 1))?;
            doc.entries.insert((section.clone(), key), val);
        }
        Ok(doc)
    }

    /// Parse a file from disk.
    pub fn load(path: &str) -> Result<TomlLite> {
        TomlLite::parse(&std::fs::read_to_string(path)?)
    }

    fn parse_value(v: &str) -> Option<Value> {
        if let Some(s) = v.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Some(Value::Str(s.to_string()));
        }
        match v {
            "true" => return Some(Value::Bool(true)),
            "false" => return Some(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = v.parse::<i64>() {
            return Some(Value::Int(i));
        }
        if let Ok(f) = v.parse::<f64>() {
            return Some(Value::Float(f));
        }
        None
    }

    /// Merge `other` over `self` (later files win).
    pub fn merge_from(&mut self, other: TomlLite) {
        self.entries.extend(other.entries);
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// Integer lookup (None on absence or type mismatch).
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float lookup; integer values coerce.
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean lookup (None on absence or type mismatch).
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String lookup (None on absence or type mismatch).
    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        match self.get(section, key)? {
            Value::Str(s) => Some(s.clone()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = TomlLite::parse(
            "# comment\n[a]\nx = 1\ny = 2.5\nz = true\ns = \"hi\" # trailing\n[b]\nx = -3\n",
        )
        .unwrap();
        assert_eq!(t.get_int("a", "x"), Some(1));
        assert_eq!(t.get_float("a", "y"), Some(2.5));
        assert_eq!(t.get_bool("a", "z"), Some(true));
        assert_eq!(t.get_str("a", "s"), Some("hi".to_string()));
        assert_eq!(t.get_int("b", "x"), Some(-3));
        assert_eq!(t.get_int("a", "missing"), None);
    }

    #[test]
    fn int_promotes_to_float() {
        let t = TomlLite::parse("[a]\nv = 2\n").unwrap();
        assert_eq!(t.get_float("a", "v"), Some(2.0));
    }

    #[test]
    fn merge_overrides() {
        let mut base = TomlLite::parse("[a]\nx = 1\ny = 2\n").unwrap();
        base.merge_from(TomlLite::parse("[a]\nx = 9\n").unwrap());
        assert_eq!(base.get_int("a", "x"), Some(9));
        assert_eq!(base.get_int("a", "y"), Some(2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlLite::parse("[a]\nnot a kv line\n").is_err());
        assert!(TomlLite::parse("[a]\nx = @@\n").is_err());
    }
}
