//! Layered configuration (tiny TOML subset, offline replacement for
//! `toml`+`serde`).
//!
//! Supports `[section]` headers and `key = value` lines where value is
//! int / float / bool / "string". Later files override earlier ones;
//! CLI flags override files (wired in main.rs). See configs/*.toml.

pub mod toml_lite;

pub use toml_lite::TomlLite;

use crate::analog::OperatingPoint;

/// Chip-level configuration (crossbar geometry + operating point).
#[derive(Debug, Clone, Copy)]
pub struct ChipConfig {
    /// Crossbar rows per array.
    pub array_rows: usize,
    /// Crossbar columns per array.
    pub array_cols: usize,
    /// Arrays on the chip.
    pub n_arrays: usize,
    /// Supply voltage (V).
    pub vdd: f64,
    /// Clock frequency (GHz).
    pub clock_ghz: f64,
    /// Immersed-ADC resolution (bits).
    pub adc_bits: u8,
}

impl Default for ChipConfig {
    fn default() -> Self {
        // The paper's fabricated configuration: four 16x32 arrays, 5-bit
        // immersed ADC.
        ChipConfig {
            array_rows: 16,
            array_cols: 32,
            n_arrays: 4,
            vdd: 1.0,
            clock_ghz: 1.0,
            adc_bits: 5,
        }
    }
}

impl ChipConfig {
    /// The analog operating point this chip runs at.
    pub fn operating_point(&self) -> OperatingPoint {
        OperatingPoint::new(self.vdd, self.clock_ghz)
    }

    /// Overlay `[chip]` keys from a parsed TOML file onto the defaults.
    pub fn from_toml(t: &TomlLite) -> Self {
        let d = ChipConfig::default();
        ChipConfig {
            array_rows: t.get_int("chip", "array_rows").unwrap_or(d.array_rows as i64) as usize,
            array_cols: t.get_int("chip", "array_cols").unwrap_or(d.array_cols as i64) as usize,
            n_arrays: t.get_int("chip", "n_arrays").unwrap_or(d.n_arrays as i64) as usize,
            vdd: t.get_float("chip", "vdd").unwrap_or(d.vdd),
            clock_ghz: t.get_float("chip", "clock_ghz").unwrap_or(d.clock_ghz),
            adc_bits: t.get_int("chip", "adc_bits").unwrap_or(d.adc_bits as i64) as u8,
        }
    }
}

/// Server-level configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Serving worker threads (one engine each).
    pub workers: usize,
    /// Batch-size cap (close-when-full bound).
    pub batch: usize,
    /// Max time a batch waits before dispatch (microseconds).
    pub batch_deadline_us: u64,
    /// Bounded-queue depth before backpressure sheds load.
    pub queue_depth: usize,
    /// "digital" (PJRT) or "analog" (CiM simulator).
    pub engine: String,
    /// Worker threads *inside* each analog engine's `infer_batch`
    /// (0 = auto-detect, 1 = sequential). Results are thread-count
    /// invariant by the per-sample RNG-stream contract.
    pub engine_threads: usize,
    /// CiM arrays per collaborative digitization pool (analog engine
    /// only). 0 = no pool: the ADC-free 1-bit default path.
    pub pool_arrays: usize,
    /// Converter networking for the pool: "sar", "flash" or "hybrid".
    pub adc_mode: String,
    /// Pool converter resolution; 0 auto-selects per mode (flash 2,
    /// otherwise the paper's 5).
    pub adc_bits: u8,
    /// Drive SAR references with the Fig 10 asymmetric comparison tree.
    pub asymmetric_adc: bool,
    /// Worker threads for the pool's batched plane fan-out
    /// (`CimArrayPool::process_planes`): independent coupling groups of
    /// one interleave phase run concurrently. 0 = auto-detect,
    /// 1 = inline sequential (default). Results are thread-count
    /// invariant by the per-plane RNG-stream contract. Shards and pool
    /// lanes share one persistent worker runtime, so this composes with
    /// `engine_threads` without oversubscribing.
    pub pool_threads: usize,
    /// Plane fusion (`adcim serve --fuse-batch`, analog engine with a
    /// pool): the engine's lockstep batched forward routes EVERY
    /// sample of a worker batch — all Hadamard blocks of all pixels of
    /// all samples in a shard slice — to the pool as one shared
    /// submission, so pool lanes stay busy across sample boundaries
    /// (the `samples_fused` metric counts the fused samples).
    /// Bit-identical serving results; off by default.
    pub fuse_batch: bool,
    /// Run ingest through the frequency-domain sensor frontend
    /// (`adcim serve --frontend`): frames are sequency-encoded,
    /// triaged, and served compressed.
    pub frontend: bool,
    /// Frontend top-K coefficient budget per frame; 0 keeps every
    /// non-zero coefficient.
    pub frontend_topk: usize,
    /// Frontend selection rule override (`all`, `topK`, `eF` — see
    /// `frontend::Selection::parse`); empty derives from
    /// `frontend_topk`.
    pub frontend_select: String,
    /// Kept-coefficient precision in bits; 0 = lossless f32
    /// (zero-compression mode, bit-exact serving).
    pub codec_bits: u8,
    /// Sensor grid resolution the frontend snaps frames to.
    pub sensor_bits: u8,
    /// Retention policy name: "keep" (compress only) or "triage"
    /// (keep / summarize / drop scoring).
    pub retain: String,
    /// Fault-injection bit error rate on the simulated sensor link
    /// (`adcim serve --channel-ber`; requires the frontend). 0 = clean.
    pub channel_ber: f64,
    /// Fault-injection frame drop probability on the simulated link
    /// (`adcim serve --channel-drop`). 0 = clean.
    pub channel_drop: f64,
    /// Frame truncation probability on the simulated link
    /// (`--channel-truncate`). 0 = clean.
    pub channel_truncate: f64,
    /// Frame duplication probability on the simulated link
    /// (`--channel-duplicate`). 0 = clean.
    pub channel_duplicate: f64,
    /// Pairwise frame reorder probability on the simulated link
    /// (`--channel-reorder`). 0 = in-order.
    pub channel_reorder: f64,
    /// Analog fault-injection plan for the digitization pool
    /// (`--fault-plan`, `[fault] plan`): semicolon-separated spec per
    /// [`crate::cim::FaultPlan::parse`]; empty = no fault layer (the
    /// serving path is byte-identical to a build without it).
    pub fault_plan: String,
    /// Calibration probe cadence in plane slots (`[fault]
    /// probe_interval`); 0 = faults inject but never heal.
    pub fault_probe_interval: u64,
    /// Probe failure threshold in output codes (`[fault]
    /// probe_tolerance`).
    pub fault_probe_tolerance: u32,
    /// Consecutive probe failures before quarantine (`[fault]
    /// probe_debounce`; must be ≥ 1).
    pub fault_probe_debounce: u32,
    /// Shutdown join deadline in milliseconds
    /// (`--shutdown-timeout-ms`): workers that outlive it are detached
    /// and counted in the `shutdown_forced` metric. 0 = wait forever
    /// (the legacy unconditional join).
    pub shutdown_timeout_ms: u64,
    /// Adaptive batch close (`adcim serve --adaptive`): tune the
    /// effective batch size / deadline from the live served-batch
    /// histogram and the p99 target. Off = the static closer,
    /// bit-identical to pre-adaptive serving.
    pub adaptive: bool,
    /// p99 completion-latency target in µs for the adaptive closer
    /// (`--p99-target-us`). 0 disables the latency rule; the adaptive
    /// closer then only walks toward the histogram knee.
    pub p99_target_us: u64,
    /// Record per-request stage spans and executor/pool runtime deltas
    /// into the metrics (on by default; `--no-telemetry` turns the
    /// sampling off — serving results are bit-identical either way).
    pub telemetry: bool,
    /// Periodic telemetry export cadence in milliseconds
    /// (`--metrics-interval-ms`). 0 = no streaming exporter; the final
    /// summary still prints.
    pub metrics_interval_ms: u64,
    /// Where the streaming exporter writes its JSON-lines snapshots
    /// (`--metrics-out PATH`); empty = stderr.
    pub metrics_out: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        // Probe-knob defaults mirror `FaultPlan::default` so a bare
        // `--fault-plan` spec behaves like a hand-built default plan.
        let fp = crate::cim::FaultPlan::default();
        ServerConfig {
            workers: 2,
            batch: 16,
            batch_deadline_us: 2000,
            queue_depth: 256,
            engine: "digital".to_string(),
            engine_threads: 1,
            pool_arrays: 0,
            adc_mode: "hybrid".to_string(),
            adc_bits: 0,
            asymmetric_adc: false,
            pool_threads: 1,
            fuse_batch: false,
            frontend: false,
            frontend_topk: 32,
            frontend_select: String::new(),
            codec_bits: 8,
            sensor_bits: 8,
            retain: "keep".to_string(),
            channel_ber: 0.0,
            channel_drop: 0.0,
            channel_truncate: 0.0,
            channel_duplicate: 0.0,
            channel_reorder: 0.0,
            fault_plan: String::new(),
            fault_probe_interval: fp.probe_interval,
            fault_probe_tolerance: fp.probe_tolerance,
            fault_probe_debounce: fp.probe_debounce,
            shutdown_timeout_ms: 5000,
            adaptive: false,
            p99_target_us: 0,
            telemetry: true,
            metrics_interval_ms: 0,
            metrics_out: String::new(),
        }
    }
}

impl ServerConfig {
    /// Overlay `[server]` keys from a parsed TOML file onto the defaults.
    pub fn from_toml(t: &TomlLite) -> Self {
        let d = ServerConfig::default();
        ServerConfig {
            workers: t.get_int("server", "workers").unwrap_or(d.workers as i64) as usize,
            batch: t.get_int("server", "batch").unwrap_or(d.batch as i64) as usize,
            batch_deadline_us: t
                .get_int("server", "batch_deadline_us")
                .unwrap_or(d.batch_deadline_us as i64) as u64,
            queue_depth: t.get_int("server", "queue_depth").unwrap_or(d.queue_depth as i64)
                as usize,
            engine: t.get_str("server", "engine").unwrap_or(d.engine),
            engine_threads: t
                .get_int("server", "engine_threads")
                .unwrap_or(d.engine_threads as i64) as usize,
            // Out-of-range values must surface as errors, not wrap into
            // valid-looking settings (`260 as u8` is 4): `pool_arrays`
            // wraps negatives to huge values that PoolSpec::validate
            // rejects loudly, and `adc_bits` pins anything outside
            // 0..=255 at 255, which validate rejects as "outside 1..=10".
            pool_arrays: t.get_int("server", "pool_arrays").unwrap_or(d.pool_arrays as i64)
                as usize,
            adc_mode: t.get_str("server", "adc_mode").unwrap_or(d.adc_mode),
            adc_bits: {
                let raw = t.get_int("server", "adc_bits").unwrap_or(d.adc_bits as i64);
                if (0..=255).contains(&raw) {
                    raw as u8
                } else {
                    u8::MAX
                }
            },
            asymmetric_adc: t
                .get_bool("server", "asymmetric_adc")
                .unwrap_or(d.asymmetric_adc),
            // A perf knob, not a correctness setting: negatives mean
            // "auto" (0) rather than wrapping to 2^64-1, and the cap
            // keeps a fat-fingered value from requesting absurd fan-out.
            pool_threads: t
                .get_int("server", "pool_threads")
                .unwrap_or(d.pool_threads as i64)
                .clamp(0, 1024) as usize,
            fuse_batch: t.get_bool("server", "fuse_batch").unwrap_or(d.fuse_batch),
            frontend: t.get_bool("server", "frontend").unwrap_or(d.frontend),
            // Negative budgets mean "keep all" (0) instead of wrapping.
            frontend_topk: t
                .get_int("server", "frontend_topk")
                .unwrap_or(d.frontend_topk as i64)
                .max(0) as usize,
            frontend_select: t.get_str("server", "frontend_select").unwrap_or(d.frontend_select),
            // Same out-of-range discipline as adc_bits: pin to 255 so
            // CodecParams::new rejects loudly instead of serving a
            // silently wrapped precision.
            codec_bits: {
                let raw = t.get_int("server", "codec_bits").unwrap_or(d.codec_bits as i64);
                if (0..=255).contains(&raw) {
                    raw as u8
                } else {
                    u8::MAX
                }
            },
            sensor_bits: {
                let raw = t.get_int("server", "sensor_bits").unwrap_or(d.sensor_bits as i64);
                if (0..=255).contains(&raw) {
                    raw as u8
                } else {
                    u8::MAX
                }
            },
            retain: t.get_str("server", "retain").unwrap_or(d.retain),
            // Raw pass-through: ChannelConfig::validate rejects
            // out-of-range probabilities with a real diagnostic.
            channel_ber: t.get_float("server", "channel_ber").unwrap_or(d.channel_ber),
            channel_drop: t.get_float("server", "channel_drop").unwrap_or(d.channel_drop),
            channel_truncate: t
                .get_float("server", "channel_truncate")
                .unwrap_or(d.channel_truncate),
            channel_duplicate: t
                .get_float("server", "channel_duplicate")
                .unwrap_or(d.channel_duplicate),
            channel_reorder: t
                .get_float("server", "channel_reorder")
                .unwrap_or(d.channel_reorder),
            // The `[fault]` table: the plan spec itself plus probe
            // cadence knobs. The spec string passes through raw —
            // FaultPlan::parse rejects bad entries with a real
            // diagnostic at engine construction.
            fault_plan: t.get_str("fault", "plan").unwrap_or(d.fault_plan),
            // Negative cadences mean "probing off" (0), not a wrap.
            fault_probe_interval: t
                .get_int("fault", "probe_interval")
                .unwrap_or(d.fault_probe_interval as i64)
                .max(0) as u64,
            // Out-of-range values pin to the extreme; FaultPlan's own
            // validation rejects a zero debounce loudly.
            fault_probe_tolerance: t
                .get_int("fault", "probe_tolerance")
                .unwrap_or(d.fault_probe_tolerance as i64)
                .clamp(0, u32::MAX as i64) as u32,
            fault_probe_debounce: t
                .get_int("fault", "probe_debounce")
                .unwrap_or(d.fault_probe_debounce as i64)
                .clamp(0, u32::MAX as i64) as u32,
            // Negative deadlines mean "wait forever" (0), not a wrap.
            shutdown_timeout_ms: t
                .get_int("server", "shutdown_timeout_ms")
                .unwrap_or(d.shutdown_timeout_ms as i64)
                .max(0) as u64,
            adaptive: t.get_bool("server", "adaptive").unwrap_or(d.adaptive),
            // Negative targets mean "no latency rule" (0), not a wrap.
            p99_target_us: t
                .get_int("server", "p99_target_us")
                .unwrap_or(d.p99_target_us as i64)
                .max(0) as u64,
            telemetry: t.get_bool("server", "telemetry").unwrap_or(d.telemetry),
            // Negative cadences mean "exporter off" (0), not a wrap.
            metrics_interval_ms: t
                .get_int("server", "metrics_interval_ms")
                .unwrap_or(d.metrics_interval_ms as i64)
                .max(0) as u64,
            metrics_out: t.get_str("server", "metrics_out").unwrap_or(d.metrics_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_chip() {
        let c = ChipConfig::default();
        assert_eq!((c.array_rows, c.array_cols, c.n_arrays, c.adc_bits), (16, 32, 4, 5));
    }

    #[test]
    fn from_toml_overrides() {
        let t = TomlLite::parse(
            "[chip]\nvdd = 0.85\nclock_ghz = 4.0\n[server]\nworkers = 8\nengine = \"analog\"\n",
        )
        .unwrap();
        let c = ChipConfig::from_toml(&t);
        assert_eq!(c.vdd, 0.85);
        assert_eq!(c.clock_ghz, 4.0);
        assert_eq!(c.array_rows, 16); // default preserved
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.workers, 8);
        assert_eq!(s.engine, "analog");
        assert_eq!(s.pool_arrays, 0); // pool off by default
    }

    #[test]
    fn from_toml_pool_settings() {
        let t = TomlLite::parse(
            "[server]\npool_arrays = 4\nadc_mode = \"sar\"\nadc_bits = 5\n\
             asymmetric_adc = true\npool_threads = 4\nfuse_batch = true\n",
        )
        .unwrap();
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.pool_arrays, 4);
        assert_eq!(s.adc_mode, "sar");
        assert_eq!(s.adc_bits, 5);
        assert!(s.asymmetric_adc);
        assert_eq!(s.pool_threads, 4);
        assert!(s.fuse_batch);
        let d = ServerConfig::from_toml(&TomlLite::default());
        assert_eq!(d.pool_threads, 1, "pool fan-out defaults to sequential");
        assert!(!d.fuse_batch, "cross-sample fusion defaults off");
    }

    #[test]
    fn from_toml_frontend_settings() {
        let t = TomlLite::parse(
            "[server]\nfrontend = true\nfrontend_topk = 16\ncodec_bits = 6\n\
             sensor_bits = 10\nretain = \"triage\"\nfrontend_select = \"e0.95\"\n",
        )
        .unwrap();
        let s = ServerConfig::from_toml(&t);
        assert!(s.frontend);
        assert_eq!(s.frontend_topk, 16);
        assert_eq!(s.frontend_select, "e0.95");
        assert_eq!(s.codec_bits, 6);
        assert_eq!(s.sensor_bits, 10);
        assert_eq!(s.retain, "triage");
        let d = ServerConfig::from_toml(&TomlLite::default());
        assert!(!d.frontend, "frontend defaults off");
        assert_eq!(d.retain, "keep");
        // Out-of-range values pin to invalid (rejected downstream).
        let t = TomlLite::parse("[server]\ncodec_bits = 300\nfrontend_topk = -4\n").unwrap();
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.codec_bits, u8::MAX);
        assert_eq!(s.frontend_topk, 0);
    }

    #[test]
    fn from_toml_adaptive_settings() {
        let t = TomlLite::parse("[server]\nadaptive = true\np99_target_us = 1500\n").unwrap();
        let s = ServerConfig::from_toml(&t);
        assert!(s.adaptive);
        assert_eq!(s.p99_target_us, 1500);
        let d = ServerConfig::from_toml(&TomlLite::default());
        assert!(!d.adaptive, "adaptive close defaults off (static batcher)");
        assert_eq!(d.p99_target_us, 0, "latency rule defaults off");
        // Negative targets mean "latency rule off", not a wrapped huge value.
        let t = TomlLite::parse("[server]\np99_target_us = -5\n").unwrap();
        assert_eq!(ServerConfig::from_toml(&t).p99_target_us, 0);
    }

    #[test]
    fn from_toml_channel_settings() {
        let t = TomlLite::parse("[server]\nchannel_ber = 0.001\nchannel_drop = 0.05\n").unwrap();
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.channel_ber, 0.001);
        assert_eq!(s.channel_drop, 0.05);
        let d = ServerConfig::from_toml(&TomlLite::default());
        assert_eq!(d.channel_ber, 0.0, "channel defaults clean");
        assert_eq!(d.channel_drop, 0.0);
        // Out-of-range values pass through for ChannelConfig::validate
        // to reject loudly at server startup.
        let t = TomlLite::parse("[server]\nchannel_ber = 1.5\n").unwrap();
        assert_eq!(ServerConfig::from_toml(&t).channel_ber, 1.5);
    }

    #[test]
    fn from_toml_fault_and_shutdown_settings() {
        let t = TomlLite::parse(
            "[server]\nchannel_truncate = 0.02\nchannel_duplicate = 0.03\n\
             channel_reorder = 0.04\nshutdown_timeout_ms = 750\n\
             [fault]\nplan = \"dead@0=1;down@4=2\"\nprobe_interval = 8\n\
             probe_tolerance = 2\nprobe_debounce = 3\n",
        )
        .unwrap();
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.channel_truncate, 0.02);
        assert_eq!(s.channel_duplicate, 0.03);
        assert_eq!(s.channel_reorder, 0.04);
        assert_eq!(s.shutdown_timeout_ms, 750);
        assert_eq!(s.fault_plan, "dead@0=1;down@4=2");
        assert_eq!(s.fault_probe_interval, 8);
        assert_eq!(s.fault_probe_tolerance, 2);
        assert_eq!(s.fault_probe_debounce, 3);
        let d = ServerConfig::from_toml(&TomlLite::default());
        assert_eq!(d.fault_plan, "", "fault layer defaults off");
        assert_eq!(d.shutdown_timeout_ms, 5000, "bounded shutdown defaults on");
        let fp = crate::cim::FaultPlan::default();
        assert_eq!(d.fault_probe_interval, fp.probe_interval);
        assert_eq!(d.fault_probe_tolerance, fp.probe_tolerance);
        assert_eq!(d.fault_probe_debounce, fp.probe_debounce);
        // Negative cadences/deadlines mean "off", not a wrap.
        let t = TomlLite::parse(
            "[server]\nshutdown_timeout_ms = -1\n[fault]\nprobe_interval = -4\n",
        )
        .unwrap();
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.shutdown_timeout_ms, 0);
        assert_eq!(s.fault_probe_interval, 0);
    }

    #[test]
    fn from_toml_telemetry_settings() {
        let t = TomlLite::parse(
            "[server]\ntelemetry = false\nmetrics_interval_ms = 250\n\
             metrics_out = \"/tmp/m.jsonl\"\n",
        )
        .unwrap();
        let s = ServerConfig::from_toml(&t);
        assert!(!s.telemetry);
        assert_eq!(s.metrics_interval_ms, 250);
        assert_eq!(s.metrics_out, "/tmp/m.jsonl");
        let d = ServerConfig::from_toml(&TomlLite::default());
        assert!(d.telemetry, "stage telemetry defaults on");
        assert_eq!(d.metrics_interval_ms, 0, "streaming exporter defaults off");
        assert_eq!(d.metrics_out, "", "empty sink path means stderr");
        // Negative cadences mean "exporter off", not a wrapped huge value.
        let t = TomlLite::parse("[server]\nmetrics_interval_ms = -100\n").unwrap();
        assert_eq!(ServerConfig::from_toml(&t).metrics_interval_ms, 0);
    }

    #[test]
    fn out_of_range_adc_bits_pins_to_invalid_not_wrapped() {
        // `260 as u8` would silently be 4 — instead the value pins at
        // 255, which PoolSpec::validate rejects with a real diagnostic.
        let t = TomlLite::parse("[server]\nadc_bits = 260\n").unwrap();
        let s = ServerConfig::from_toml(&t);
        assert_eq!(s.adc_bits, u8::MAX);
        let t = TomlLite::parse("[server]\nadc_bits = -3\n").unwrap();
        assert_eq!(ServerConfig::from_toml(&t).adc_bits, u8::MAX);
    }
}
