//! # adcim — Frequency-Domain Compression in Collaborative Compute-in-Memory Networks
//!
//! Behavioural, bit/charge-accurate reproduction of *"Containing Analog Data
//! Deluge at Edge through Frequency-Domain Compression in Collaborative
//! Compute-in-Memory Networks"* (Darabi & Trivedi, 2023).
//!
//! The library is organised as the paper's stack, bottom-up:
//!
//! - [`wht`] — Walsh–Hadamard transform substrate: Hadamard/Walsh matrices,
//!   the O(m log m) fast transform, and the blockwise (BWHT) variant used
//!   for frequency-domain DNN compression.
//! - [`analog`] — behavioural analog substrate: supply/frequency scaling,
//!   thermal + offset noise, clocked comparators, bit-line capacitive DACs
//!   and RC signal timing. Everything the paper measured on its 65 nm test
//!   chip is modelled here as explicit charge arithmetic.
//! - [`cim`] — the paper's ADC/DAC-free compute-in-SRAM crossbar: the
//!   4-step NMOS crossbar operation, bitplane-wise multi-bit processing,
//!   1-bit product-sum quantization, the early-termination engine, and
//!   the collaborative digitization pool (`cim::pool`) that schedules N
//!   arrays to take turns computing MAVs and digitizing each other's.
//! - [`adc`] — digitization substrate: conventional SAR and Flash ADC
//!   baselines, the paper's memory-immersed collaborative ADC (SAR, Flash
//!   and hybrid modes), the asymmetric MAV-statistics-aware search, and
//!   DNL/INL/staircase characterization.
//! - [`network`] — collaborative CiM array networking: left/right pairing,
//!   one-to-many Flash coupling and the compute/digitize interleave
//!   scheduler.
//! - [`energy`] — area/energy/latency models calibrated to the paper's
//!   Table I anchors, with 65 nm ↔ 40 nm technology scaling.
//! - [`frontend`] — the frequency-domain sensor frontend (paper §II-A):
//!   sequency-domain frame compression (`CompressedFrame` codec with
//!   top-K / energy-threshold coefficient selection and per-band
//!   quantization) and the keep/summarize/drop retention policy that
//!   contains the ingest deluge before it reaches the serving queue.
//! - [`nn`] — quantized neural network stack: tensors, BWHT compression
//!   layers with soft-thresholding, miniature MobileNetV2/ResNet20 models,
//!   straight-through-estimator training against 1-bit product-sum
//!   quantization, MAC/parameter accounting and the synthetic edge-sensor
//!   dataset.
//! - [`coordinator`] — the L3 edge serving layer: sensor-stream router,
//!   dynamic batcher, CiM array-pool scheduler, backpressure and metrics.
//! - [`runtime`] — PJRT runtime that loads the AOT-compiled JAX/Pallas HLO
//!   artifacts (`artifacts/*.hlo.txt`) for the digital reference path.
//! - [`config`] — layered TOML configuration for chip, model and server.
//! - [`report`] — regenerates every table and figure of the paper's
//!   evaluation as text reports.
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts`
//! lowers the L2 model (calling the L1 Pallas BWHT kernel) to HLO text and
//! trains the reference weights. The serve path is pure rust.

#![warn(missing_docs)]

pub mod adc;
pub mod analog;
pub mod cim;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod frontend;
pub mod network;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod util;
pub mod wht;

/// Library result type.
pub type Result<T> = anyhow::Result<T>;
