//! Admission control: bounded in-flight depth with graduated,
//! priority-aware load shedding.
//!
//! Edge nodes cannot buffer an analog data deluge — when the queue is
//! full the right move is to drop the frame (sensor data is perishable)
//! and count it, not to grow memory. `AdmissionControl` is shared by
//! the submitting side and the workers.
//!
//! Shedding is *graduated*: below half depth everything is admitted;
//! from half depth to full depth the minimum admissible priority ramps
//! linearly from 0 to 256, so low-priority (Summarize-class, see
//! [`crate::frontend::retention::RetentionPolicy::priority`]) frames
//! shed first while top-priority (Keep-class / raw) traffic is only
//! refused when the queue is completely full. For priority-255 traffic
//! the ramp is exactly the legacy full-queue check — `admit()` behavior
//! is bit-identical to the pre-QoS admission control.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Pure graduated-shedding rule: may a frame of `priority` enter a
/// queue currently at `depth` out of `max_depth`?
///
/// - `depth < max_depth / 2`: always admissible (no pressure).
/// - `max_depth / 2 <= depth < max_depth`: admissible iff
///   `priority >= (depth - start) * 256 / (max_depth - start)` where
///   `start = max_depth / 2` — the bar rises linearly with depth.
/// - `depth >= max_depth`: never admissible (hard cap).
///
/// Floor division keeps the bar at or below 255 for every
/// `depth < max_depth`, so priority-255 traffic is only shed at the
/// hard cap — exactly the legacy non-graduated behavior.
pub fn admissible(priority: u8, depth: usize, max_depth: usize) -> bool {
    if depth >= max_depth {
        return false;
    }
    let start = max_depth / 2;
    if depth < start {
        return true;
    }
    let min_priority = ((depth - start) * 256) / (max_depth - start);
    priority as usize >= min_priority
}

/// Shared admission state.
#[derive(Debug, Default)]
pub struct AdmissionControl {
    max_depth: usize,
    depth: AtomicUsize,
    shed: AtomicU64,
    admitted: AtomicU64,
}

impl AdmissionControl {
    /// Admission gate over at most `max_depth` in-flight requests.
    pub fn new(max_depth: usize) -> Self {
        assert!(max_depth > 0);
        AdmissionControl {
            max_depth,
            depth: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
        }
    }

    /// Try to admit one top-priority request (legacy path: only a
    /// completely full queue sheds). True = admitted (caller must
    /// `release` when the request completes).
    pub fn admit(&self) -> bool {
        self.admit_priority(u8::MAX)
    }

    /// Try to admit one request under the graduated-shedding rule
    /// ([`admissible`]). True = admitted (caller must `release` when
    /// the request completes).
    pub fn admit_priority(&self, priority: u8) -> bool {
        let mut cur = self.depth.load(Ordering::Relaxed);
        loop {
            if !admissible(priority, cur, self.max_depth) {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            match self.depth.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Release one slot.
    pub fn release(&self) {
        let prev = self.depth.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "release without admit");
    }

    /// Current in-flight depth.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Total requests refused admission (all priorities).
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Total requests admitted (all priorities).
    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn admits_up_to_depth_then_sheds() {
        let ac = AdmissionControl::new(2);
        assert!(ac.admit());
        assert!(ac.admit());
        assert!(!ac.admit());
        assert_eq!(ac.shed_count(), 1);
        ac.release();
        assert!(ac.admit());
        assert_eq!(ac.admitted_count(), 3);
    }

    /// For top-priority traffic the graduated rule is exactly the
    /// legacy "shed iff full" check at every depth.
    #[test]
    fn top_priority_matches_legacy_full_queue_rule() {
        for max_depth in 1..=300usize {
            for depth in 0..=max_depth + 2 {
                assert_eq!(
                    admissible(u8::MAX, depth, max_depth),
                    depth < max_depth,
                    "max_depth={max_depth} depth={depth}"
                );
            }
        }
    }

    /// The admissibility bar only rises with depth and only falls with
    /// priority — no priority/depth combination inverts the ordering.
    #[test]
    fn admissibility_is_monotone_in_priority_and_depth() {
        for max_depth in [1usize, 2, 5, 64, 256, 1000] {
            for depth in 0..=max_depth {
                for p in 0..255u8 {
                    // p admitted implies p+1 admitted.
                    assert!(
                        !admissible(p, depth, max_depth) || admissible(p + 1, depth, max_depth),
                        "priority inversion at max_depth={max_depth} depth={depth} p={p}"
                    );
                }
                if depth > 0 {
                    for p in [0u8, 64, 128, 192, 255] {
                        // Shallower queue never sheds where deeper admits.
                        assert!(
                            admissible(p, depth - 1, max_depth) || !admissible(p, depth, max_depth),
                            "depth inversion at max_depth={max_depth} depth={depth} p={p}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn graduated_shedding_drops_low_priority_first() {
        let max_depth = 64usize;
        // Below half depth everyone gets in.
        assert!(admissible(0, 31, max_depth));
        // At three-quarters depth the bar is at half scale: priority
        // (48-32)*256/32 = 128.
        assert!(!admissible(127, 48, max_depth));
        assert!(admissible(128, 48, max_depth));
        // Just below full, only near-top priorities remain: bar =
        // (63-32)*256/32 = 248.
        assert!(!admissible(247, 63, max_depth));
        assert!(admissible(248, 63, max_depth));
        assert!(admissible(255, 63, max_depth));
        // Full queue sheds everyone.
        assert!(!admissible(255, 64, max_depth));
    }

    #[test]
    fn admit_priority_sheds_by_class_under_load() {
        let ac = AdmissionControl::new(4);
        // Fill to half depth (2 of 4) — free admission.
        assert!(ac.admit_priority(0));
        assert!(ac.admit_priority(0));
        // depth=2 = start → bar 0: still admitted.
        assert!(ac.admit_priority(0));
        // depth=3 → bar (3-2)*256/2 = 128: Summarize-band priority
        // sheds, Keep-band passes.
        assert!(!ac.admit_priority(100));
        assert!(ac.admit_priority(200));
        // depth=4 = full → even top priority sheds.
        assert!(!ac.admit_priority(255));
        assert_eq!(ac.shed_count(), 2);
        assert_eq!(ac.admitted_count(), 4);
        for _ in 0..4 {
            ac.release();
        }
        assert_eq!(ac.depth(), 0);
    }

    #[test]
    fn concurrent_admissions_never_exceed_depth() {
        let ac = Arc::new(AdmissionControl::new(8));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let ac = ac.clone();
            handles.push(std::thread::spawn(move || {
                let mut local_max = 0usize;
                for _ in 0..2000 {
                    if ac.admit() {
                        local_max = local_max.max(ac.depth());
                        ac.release();
                    }
                }
                local_max
            }));
        }
        for h in handles {
            assert!(h.join().unwrap() <= 8);
        }
        assert_eq!(ac.depth(), 0);
    }
}
