//! Admission control: bounded in-flight depth with load shedding.
//!
//! Edge nodes cannot buffer an analog data deluge — when the queue is
//! full the right move is to drop the frame (sensor data is perishable)
//! and count it, not to grow memory. `AdmissionControl` is shared by
//! the submitting side and the workers.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Shared admission state.
#[derive(Debug, Default)]
pub struct AdmissionControl {
    max_depth: usize,
    depth: AtomicUsize,
    shed: AtomicU64,
    admitted: AtomicU64,
}

impl AdmissionControl {
    pub fn new(max_depth: usize) -> Self {
        assert!(max_depth > 0);
        AdmissionControl {
            max_depth,
            depth: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
        }
    }

    /// Try to admit one request. True = admitted (caller must `release`
    /// when the request completes).
    pub fn admit(&self) -> bool {
        let mut cur = self.depth.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_depth {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            match self.depth.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Release one slot.
    pub fn release(&self) {
        let prev = self.depth.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "release without admit");
    }

    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn admits_up_to_depth_then_sheds() {
        let ac = AdmissionControl::new(2);
        assert!(ac.admit());
        assert!(ac.admit());
        assert!(!ac.admit());
        assert_eq!(ac.shed_count(), 1);
        ac.release();
        assert!(ac.admit());
        assert_eq!(ac.admitted_count(), 3);
    }

    #[test]
    fn concurrent_admissions_never_exceed_depth() {
        let ac = Arc::new(AdmissionControl::new(8));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let ac = ac.clone();
            handles.push(std::thread::spawn(move || {
                let mut local_max = 0usize;
                for _ in 0..2000 {
                    if ac.admit() {
                        local_max = local_max.max(ac.depth());
                        ac.release();
                    }
                }
                local_max
            }));
        }
        for h in handles {
            assert!(h.join().unwrap() <= 8);
        }
        assert_eq!(ac.depth(), 0);
    }
}
