//! Dynamic batching: dispatch when full OR when the oldest request has
//! waited past the deadline — the standard latency/throughput knob of
//! serving systems (vLLM-style), sized here to the model's AOT batch.

use std::time::{Duration, Instant};

use super::request::InferenceRequest;

/// A dispatched batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<InferenceRequest>,
    /// When the batch was sealed.
    pub sealed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Pure batching logic (threading lives in server.rs).
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    deadline: Duration,
    pending: Vec<InferenceRequest>,
    oldest: Option<Instant>,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(max_batch > 0);
        DynamicBatcher { max_batch, deadline, pending: Vec::new(), oldest: None }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Add a request; returns a sealed batch if it filled up.
    pub fn push(&mut self, req: InferenceRequest, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(req);
        if self.pending.len() >= self.max_batch {
            return self.seal(now);
        }
        None
    }

    /// Deadline check (call on a timer / between receives).
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.deadline => {
                self.seal(now)
            }
            _ => None,
        }
    }

    /// Force-dispatch whatever is pending (shutdown path).
    pub fn flush(&mut self, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            self.seal(now)
        }
    }

    /// Time until the current deadline expires (for recv timeouts).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t0| self.deadline.saturating_sub(now.duration_since(t0)))
    }

    fn seal(&mut self, now: Instant) -> Option<Batch> {
        self.oldest = None;
        Some(Batch { requests: std::mem::take(&mut self.pending), sealed_at: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, 0, vec![0.0])
    }

    #[test]
    fn seals_when_full() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(10));
        let now = Instant::now();
        assert!(b.push(req(1), now).is_none());
        assert!(b.push(req(2), now).is_none());
        let batch = b.push(req(3), now).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn seals_on_deadline() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(req(1), t0);
        assert!(b.poll(t0).is_none(), "deadline not reached");
        let later = t0 + Duration::from_millis(6);
        let batch = b.poll(later).expect("deadline batch");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_order() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(1));
        let now = Instant::now();
        for i in 0..3 {
            b.push(req(i), now);
        }
        let batch = b.flush(now).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn flush_empty_is_none() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(1));
        assert!(b.flush(Instant::now()).is_none());
    }

    #[test]
    fn deadline_resets_after_seal() {
        let mut b = DynamicBatcher::new(2, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(req(1), t0);
        b.push(req(2), t0); // seals
        b.push(req(3), t0 + Duration::from_millis(20));
        // New epoch: deadline measured from the new oldest.
        assert!(b.poll(t0 + Duration::from_millis(25)).is_none());
        assert!(b.poll(t0 + Duration::from_millis(31)).is_some());
    }
}
