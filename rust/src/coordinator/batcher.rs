//! Dynamic batching: dispatch when full OR when the oldest request has
//! waited past the deadline — the standard latency/throughput knob of
//! serving systems (vLLM-style), sized here to the model's AOT batch.
//!
//! Two closers live here:
//!
//! - [`DynamicBatcher`] — the static `(max_batch, deadline)` pair; pure
//!   logic, unchanged since PR 1 and still the default.
//! - [`AdaptiveBatcher`] — wraps the same core but *tunes* the
//!   effective batch size and close deadline from live signals: the
//!   knee of the recently-sealed batch-size histogram (same
//!   [`super::metrics::BATCH_BUCKET_BOUNDS`] buckets the metrics
//!   export) and the observed p99 completion latency against a target
//!   (`--p99-target-us`). With adaptation unable to trigger it is
//!   bit-for-bit the static batcher, which is what `--adaptive` off
//!   serves through.

use std::time::{Duration, Instant};

use super::metrics::BATCH_BUCKET_BOUNDS;
use super::request::InferenceRequest;

/// A dispatched batch.
#[derive(Debug)]
pub struct Batch {
    /// Requests in submission order.
    pub requests: Vec<InferenceRequest>,
    /// When the batch was sealed.
    pub sealed_at: Instant,
}

impl Batch {
    /// Requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch holds no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Pure batching logic (threading lives in server.rs).
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    deadline: Duration,
    pending: Vec<InferenceRequest>,
    oldest: Option<Instant>,
}

impl DynamicBatcher {
    /// Batcher sealing at `max_batch` or `deadline`, whichever first.
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(max_batch > 0);
        DynamicBatcher { max_batch, deadline, pending: Vec::new(), oldest: None }
    }

    /// Requests currently waiting in the open batch.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Current batch-size cap (the close-when-full bound).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Current close deadline.
    pub fn deadline(&self) -> Duration {
        self.deadline
    }

    /// Retune the batch-size cap in place ([`AdaptiveBatcher`]'s knob).
    /// Takes effect on the next push/poll; an already-overfull pending
    /// set seals on the next push.
    pub fn set_max_batch(&mut self, max_batch: usize) {
        assert!(max_batch > 0);
        self.max_batch = max_batch;
    }

    /// Retune the close deadline in place ([`AdaptiveBatcher`]'s knob).
    pub fn set_deadline(&mut self, deadline: Duration) {
        self.deadline = deadline;
    }

    /// Add a request; returns a sealed batch if it filled up.
    pub fn push(&mut self, req: InferenceRequest, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(req);
        if self.pending.len() >= self.max_batch {
            return self.seal(now);
        }
        None
    }

    /// Deadline check (call on a timer / between receives).
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.deadline => {
                self.seal(now)
            }
            _ => None,
        }
    }

    /// Force-dispatch whatever is pending (shutdown path).
    pub fn flush(&mut self, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            self.seal(now)
        }
    }

    /// Time until the current deadline expires (for recv timeouts).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t0| self.deadline.saturating_sub(now.duration_since(t0)))
    }

    fn seal(&mut self, now: Instant) -> Option<Batch> {
        self.oldest = None;
        let mut requests = std::mem::take(&mut self.pending);
        // Stage-span stamp, one clock read per sealed batch (the
        // adaptive closer seals through this same core). Telemetry
        // only: nothing downstream schedules on it.
        for r in &mut requests {
            r.trace.sealed = Some(now);
        }
        Some(Batch { requests, sealed_at: now })
    }
}

/// Tuning bounds and signals for [`AdaptiveBatcher`].
///
/// `max_batch`/`deadline_us` are the configured operating point (the
/// same values the static batcher would run); adaptation only ever
/// moves the *effective* knobs inside `[min_batch, max_batch]` ×
/// `[min_deadline_us, deadline_us]`, so the configured pair stays the
/// worst-case promise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Configured batch-size cap (adaptation walks below, never above).
    pub max_batch: usize,
    /// Floor for the effective batch size.
    pub min_batch: usize,
    /// Configured close deadline in microseconds (also the relax cap).
    pub deadline_us: u64,
    /// Floor for the effective close deadline (µs).
    pub min_deadline_us: u64,
    /// p99 completion-latency target in µs; 0 disables the latency
    /// rule (the batcher then only walks toward the histogram knee).
    pub p99_target_us: u64,
    /// Sealed batches per adaptation step. Larger windows react slower
    /// but resist noise; `usize::MAX` freezes adaptation entirely
    /// (bit-for-bit the static batcher).
    pub window: usize,
    /// Hysteresis dead band as a fraction of `p99_target_us`: observed
    /// p99 inside `[(1 - band) · target, target]` changes nothing, so
    /// the knobs cannot oscillate around a steady operating point.
    pub band: f64,
}

impl AdaptiveConfig {
    /// Conventional operating point: floor batch 1, floor deadline
    /// 50 µs, a 16-batch window and a 30 % hysteresis band.
    pub fn new(max_batch: usize, deadline_us: u64, p99_target_us: u64) -> Self {
        AdaptiveConfig {
            max_batch,
            min_batch: 1,
            deadline_us,
            min_deadline_us: 50.min(deadline_us.max(1)),
            p99_target_us,
            window: 16,
            band: 0.3,
        }
    }
}

/// How a batch left the [`AdaptiveBatcher`] — the signal the knee walk
/// feeds on (full seals mean demand saturates the effective cap;
/// deadline seals mean the cap is above what traffic delivers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SealKind {
    Full,
    Deadline,
}

/// Self-tuning batch closer (ROADMAP direction 1, the policy half).
///
/// Wraps a [`DynamicBatcher`] core and, every [`AdaptiveConfig::window`]
/// sealed batches, walks the *effective* `(batch, deadline)` pair:
///
/// 1. **Latency rule** (needs `p99_target_us > 0` and an observed p99
///    from the caller): p99 over target → tighten, multiplicatively
///    shrinking both the deadline (×¾) and the batch cap (−¼); p99
///    under `(1 - band) · target` → relax the deadline (×5/4) and fall
///    through to the knee rule; p99 inside the band → hold (hysteresis).
/// 2. **Knee rule**: if ≤ ¼ of the window's seals closed full, the cap
///    overshoots arrivals — walk it down to the histogram knee (the
///    smallest [`BATCH_BUCKET_BOUNDS`] bound covering ≥ 90 % of the
///    window's sealed sizes). If ≥ ¾ closed full *and* seals arrived
///    faster than one per effective deadline (mean seal spacing ≤
///    `eff_deadline`), demand genuinely saturates the cap — double it
///    toward `max_batch`. The spacing guard is what keeps a trickle
///    that instantly fills a small cap from flapping the cap back up:
///    full seals alone are not evidence of pressure, full seals at
///    sub-deadline spacing are. The middle band holds, again for
///    hysteresis.
///
/// Everything is pure logic driven by `push`/`poll`/`maybe_adapt`; the
/// server thread supplies observed p99 from the metrics' recent-latency
/// ring. With `window: usize::MAX` (or simply never calling
/// `maybe_adapt`) the wrapper is bit-for-bit the static batcher — the
/// `--adaptive` off-switch relies on that identity.
#[derive(Debug)]
pub struct AdaptiveBatcher {
    core: DynamicBatcher,
    cfg: AdaptiveConfig,
    eff_batch: usize,
    eff_deadline_us: u64,
    /// Sealed-size histogram for the current window (same buckets as
    /// the metrics' served-batch histogram).
    window_hist: [u64; BATCH_BUCKET_BOUNDS.len() + 1],
    window_seals: usize,
    window_full: usize,
    /// First/last seal timestamps of the window (seal spacing is the
    /// demand-rate signal the grow rule needs).
    window_first: Option<Instant>,
    window_last: Option<Instant>,
    adaptations: u64,
}

impl AdaptiveBatcher {
    /// Start at the configured operating point (effective = configured).
    pub fn new(cfg: AdaptiveConfig) -> Self {
        assert!(cfg.max_batch > 0 && cfg.min_batch > 0 && cfg.min_batch <= cfg.max_batch);
        assert!(cfg.deadline_us > 0 && cfg.min_deadline_us <= cfg.deadline_us);
        AdaptiveBatcher {
            core: DynamicBatcher::new(cfg.max_batch, Duration::from_micros(cfg.deadline_us)),
            cfg,
            eff_batch: cfg.max_batch,
            eff_deadline_us: cfg.deadline_us,
            window_hist: [0; BATCH_BUCKET_BOUNDS.len() + 1],
            window_seals: 0,
            window_full: 0,
            window_first: None,
            window_last: None,
            adaptations: 0,
        }
    }

    /// Requests currently waiting in the open batch.
    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    /// Current effective batch-size cap.
    pub fn eff_batch(&self) -> usize {
        self.eff_batch
    }

    /// Current effective close deadline (µs).
    pub fn eff_deadline_us(&self) -> u64 {
        self.eff_deadline_us
    }

    /// Completed adaptation steps so far.
    pub fn adaptations(&self) -> u64 {
        self.adaptations
    }

    /// Add a request; a returned batch sealed because it filled the
    /// effective cap.
    pub fn push(&mut self, req: InferenceRequest, now: Instant) -> Option<Batch> {
        let sealed = self.core.push(req, now);
        if let Some(b) = &sealed {
            self.note_seal(b.len(), SealKind::Full, now);
        }
        sealed
    }

    /// Deadline check; a returned batch sealed because its oldest
    /// request aged past the effective deadline.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        let sealed = self.core.poll(now);
        if let Some(b) = &sealed {
            self.note_seal(b.len(), SealKind::Deadline, now);
        }
        sealed
    }

    /// Force-dispatch whatever is pending (shutdown path; does not
    /// count toward the adaptation window).
    pub fn flush(&mut self, now: Instant) -> Option<Batch> {
        self.core.flush(now)
    }

    /// Time until the current effective deadline expires.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.core.time_to_deadline(now)
    }

    /// True once a full window of seals is waiting on [`Self::maybe_adapt`].
    pub fn window_ready(&self) -> bool {
        self.window_seals >= self.cfg.window
    }

    /// Run one adaptation step if a full window of sealed batches has
    /// accumulated. `observed_p99_us` is the caller's recent p99
    /// completion latency (`None` when too few completions exist).
    /// Returns true when the step ran (the effective knobs may or may
    /// not have moved).
    pub fn maybe_adapt(&mut self, observed_p99_us: Option<f64>) -> bool {
        if !self.window_ready() {
            return false;
        }
        self.adapt(observed_p99_us);
        true
    }

    fn note_seal(&mut self, size: usize, kind: SealKind, now: Instant) {
        let bucket = BATCH_BUCKET_BOUNDS
            .iter()
            .position(|&b| size <= b)
            .unwrap_or(BATCH_BUCKET_BOUNDS.len());
        self.window_hist[bucket] += 1;
        self.window_seals += 1;
        if kind == SealKind::Full {
            self.window_full += 1;
        }
        if self.window_first.is_none() {
            self.window_first = Some(now);
        }
        self.window_last = Some(now);
    }

    /// True when the window's seals landed faster than one per
    /// effective deadline — full batches under real arrival pressure,
    /// not a trickle that happens to fill a small cap.
    fn seals_outpace_deadline(&self) -> bool {
        let (Some(first), Some(last)) = (self.window_first, self.window_last) else {
            return false;
        };
        let intervals = self.window_seals.saturating_sub(1) as u64;
        let elapsed_us = last.duration_since(first).as_micros() as u64;
        elapsed_us <= intervals * self.eff_deadline_us
    }

    /// The histogram knee: smallest bucket bound covering ≥ 90 % of the
    /// window's sealed batches (overflow bucket maps to `max_batch`).
    fn window_knee(&self) -> usize {
        let total: u64 = self.window_hist.iter().sum();
        if total == 0 {
            return self.eff_batch;
        }
        let need = total - total / 10; // ceil(0.9·total) without floats
        let mut cum = 0u64;
        for (i, &c) in self.window_hist.iter().enumerate() {
            cum += c;
            if cum >= need {
                return match BATCH_BUCKET_BOUNDS.get(i) {
                    Some(&b) => b,
                    None => self.cfg.max_batch,
                };
            }
        }
        self.cfg.max_batch
    }

    fn adapt(&mut self, observed_p99_us: Option<f64>) {
        self.adaptations += 1;
        let full_frac_hi = self.window_full * 4 >= self.window_seals * 3; // ≥ ¾
        let full_frac_lo = self.window_full * 4 <= self.window_seals; // ≤ ¼
        let knee = self.window_knee();

        let mut allow_relax = self.cfg.p99_target_us == 0;
        if self.cfg.p99_target_us > 0 {
            if let Some(p99) = observed_p99_us {
                let target = self.cfg.p99_target_us as f64;
                if p99 > target {
                    // Over target: tighten both knobs and stop — latency
                    // recovery outranks throughput this window.
                    self.eff_deadline_us =
                        (self.eff_deadline_us * 3 / 4).max(self.cfg.min_deadline_us);
                    let step = (self.eff_batch / 4).max(1);
                    self.eff_batch = self.eff_batch.saturating_sub(step).max(self.cfg.min_batch);
                    self.apply();
                    self.reset_window();
                    return;
                }
                if p99 < target * (1.0 - self.cfg.band) {
                    // Comfortably under target: the deadline may relax
                    // back toward the configured cap.
                    allow_relax = true;
                } else {
                    // Inside the hysteresis band: hold everything.
                    self.reset_window();
                    return;
                }
            }
            // No p99 sample yet: fall through to the knee rule only.
        }

        if allow_relax && self.eff_deadline_us < self.cfg.deadline_us {
            self.eff_deadline_us = (self.eff_deadline_us * 5 / 4 + 1).min(self.cfg.deadline_us);
        }
        if full_frac_hi && self.seals_outpace_deadline() {
            // Demand saturates the cap at sub-deadline seal spacing:
            // grow toward the configured max.
            self.eff_batch = (self.eff_batch * 2).min(self.cfg.max_batch);
        } else if full_frac_lo && knee < self.eff_batch {
            // Deadline seals dominate and the histogram knee sits below
            // the cap: walk down so full-closes fire instead of waiting.
            self.eff_batch = knee.max(self.cfg.min_batch);
        }
        self.apply();
        self.reset_window();
    }

    fn apply(&mut self) {
        self.core.set_max_batch(self.eff_batch);
        self.core.set_deadline(Duration::from_micros(self.eff_deadline_us));
    }

    fn reset_window(&mut self) {
        self.window_hist = [0; BATCH_BUCKET_BOUNDS.len() + 1];
        self.window_seals = 0;
        self.window_full = 0;
        self.window_first = None;
        self.window_last = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, 0, vec![0.0])
    }

    #[test]
    fn seals_when_full() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(10));
        let now = Instant::now();
        assert!(b.push(req(1), now).is_none());
        assert!(b.push(req(2), now).is_none());
        let batch = b.push(req(3), now).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn seals_on_deadline() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(req(1), t0);
        assert!(b.poll(t0).is_none(), "deadline not reached");
        let later = t0 + Duration::from_millis(6);
        let batch = b.poll(later).expect("deadline batch");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_order() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(1));
        let now = Instant::now();
        for i in 0..3 {
            b.push(req(i), now);
        }
        let batch = b.flush(now).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn flush_empty_is_none() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(1));
        assert!(b.flush(Instant::now()).is_none());
    }

    #[test]
    fn deadline_resets_after_seal() {
        let mut b = DynamicBatcher::new(2, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(req(1), t0);
        b.push(req(2), t0); // seals
        b.push(req(3), t0 + Duration::from_millis(20));
        // New epoch: deadline measured from the new oldest.
        assert!(b.poll(t0 + Duration::from_millis(25)).is_none());
        assert!(b.poll(t0 + Duration::from_millis(31)).is_some());
    }

    #[test]
    fn retuned_knobs_take_effect() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(req(1), t0);
        b.set_max_batch(2);
        assert_eq!(b.max_batch(), 2);
        let batch = b.push(req(2), t0).expect("new cap seals at 2");
        assert_eq!(batch.len(), 2);
        b.set_deadline(Duration::from_millis(1));
        assert_eq!(b.deadline(), Duration::from_millis(1));
        b.push(req(3), t0);
        assert!(b.poll(t0 + Duration::from_millis(2)).is_some(), "new deadline fires");
    }

    // ---- AdaptiveBatcher ----

    fn acfg(max_batch: usize, deadline_us: u64, target_us: u64) -> AdaptiveConfig {
        AdaptiveConfig::new(max_batch, deadline_us, target_us)
    }

    /// Frozen adaptation (window = usize::MAX) is bit-for-bit the
    /// static batcher on an arbitrary push/poll trace — the identity
    /// the `--adaptive` off-switch rests on.
    #[test]
    fn frozen_adaptive_matches_static_bit_for_bit() {
        let cfg = AdaptiveConfig { window: usize::MAX, ..acfg(4, 5_000, 1_000) };
        let mut adaptive = AdaptiveBatcher::new(cfg);
        let mut fixed = DynamicBatcher::new(4, Duration::from_micros(5_000));
        let t0 = Instant::now();
        let mut seals_a: Vec<Vec<u64>> = Vec::new();
        let mut seals_s: Vec<Vec<u64>> = Vec::new();
        let ids = |b: Batch| b.requests.iter().map(|r| r.id).collect::<Vec<_>>();
        for i in 0..23u64 {
            let now = t0 + Duration::from_micros(i * 1_700);
            if let Some(b) = adaptive.poll(now) {
                seals_a.push(ids(b));
            }
            if let Some(b) = fixed.poll(now) {
                seals_s.push(ids(b));
            }
            if let Some(b) = adaptive.push(req(i), now) {
                seals_a.push(ids(b));
            }
            if let Some(b) = fixed.push(req(i), now) {
                seals_s.push(ids(b));
            }
        }
        let now = t0 + Duration::from_secs(1);
        if let Some(b) = adaptive.flush(now) {
            seals_a.push(ids(b));
        }
        if let Some(b) = fixed.flush(now) {
            seals_s.push(ids(b));
        }
        assert_eq!(seals_a, seals_s);
        assert_eq!(adaptive.adaptations(), 0);
        assert_eq!((adaptive.eff_batch(), adaptive.eff_deadline_us()), (4, 5_000));
    }

    /// Sparse traffic (two frames per 10 ms, far apart against a 2 ms
    /// deadline) walks the effective cap down to the histogram knee —
    /// and *stays* there: once at the knee the pairs seal "full", but
    /// their seal spacing is way past the deadline, so the grow rule
    /// must not flap the cap back up.
    #[test]
    fn deadline_sealed_trickle_converges_to_knee_without_flapping() {
        let mut ab = AdaptiveBatcher::new(acfg(64, 2_000, 0));
        let t0 = Instant::now();
        let mut id = 0u64;
        let mut step = 0u64;
        let mut caps = Vec::new();
        for _round in 0..6 {
            while !ab.window_ready() {
                let now = t0 + Duration::from_micros(step * 10_000);
                // Two requests arrive together, then quiet until the
                // deadline (or the tightened cap) seals them.
                ab.push(req(id), now);
                id += 1;
                ab.push(req(id), now);
                id += 1;
                ab.poll(now + Duration::from_micros(2_500));
                step += 1;
            }
            ab.maybe_adapt(None);
            caps.push(ab.eff_batch());
        }
        // Knee of all-size-2 seals is the ≤2 bucket.
        assert_eq!(ab.eff_batch(), 2, "walked to the histogram knee: {caps:?}");
        assert!(
            caps.windows(2).all(|w| w[1] <= w[0]),
            "cap must walk down monotonically, never flap: {caps:?}"
        );
        assert!(ab.adaptations() >= 2);
    }

    /// Saturating traffic (every batch seals full) grows the cap back
    /// toward the configured maximum.
    #[test]
    fn full_seals_grow_cap_toward_max() {
        let cfg = AdaptiveConfig { min_batch: 1, ..acfg(32, 2_000, 0) };
        let mut ab = AdaptiveBatcher::new(cfg);
        // Start from a tightened state.
        ab.eff_batch = 4;
        ab.apply();
        let t0 = Instant::now();
        let mut id = 0u64;
        for round in 0..4 {
            while !ab.window_ready() {
                let now = t0 + Duration::from_micros(id * 100);
                if ab.push(req(id), now).is_some() {
                    // sealed full
                }
                id += 1;
                let _ = round;
            }
            ab.maybe_adapt(None);
        }
        assert_eq!(ab.eff_batch(), 32, "doubled 4→8→16→32");
    }

    /// p99 over target tightens both knobs; p99 inside the hysteresis
    /// band holds them; p99 far under target relaxes the deadline.
    #[test]
    fn latency_rule_tightens_holds_and_relaxes() {
        let mut ab = AdaptiveBatcher::new(acfg(16, 4_000, 1_000));
        let t0 = Instant::now();
        let mut id = 0u64;
        let mut fill_window = |ab: &mut AdaptiveBatcher, id: &mut u64| {
            while !ab.window_ready() {
                let now = t0 + Duration::from_micros(*id * 50);
                ab.push(req(*id), now);
                *id += 1;
            }
        };
        // Overshoot: deadline ×¾, batch −¼.
        fill_window(&mut ab, &mut id);
        assert!(ab.maybe_adapt(Some(2_000.0)));
        assert_eq!(ab.eff_deadline_us(), 3_000);
        assert_eq!(ab.eff_batch(), 12);
        // In-band (between 700 and 1000): hold exactly.
        fill_window(&mut ab, &mut id);
        assert!(ab.maybe_adapt(Some(900.0)));
        assert_eq!((ab.eff_batch(), ab.eff_deadline_us()), (12, 3_000), "hysteresis holds");
        // Far under target: deadline relaxes back toward the cap (and
        // full seals keep growing the batch).
        fill_window(&mut ab, &mut id);
        assert!(ab.maybe_adapt(Some(100.0)));
        assert_eq!(ab.eff_deadline_us(), 3_000 * 5 / 4 + 1);
        assert_eq!(ab.eff_batch(), 16);
    }

    /// A steady in-band workload never oscillates: repeated adapt steps
    /// leave the knobs exactly where they were.
    #[test]
    fn steady_state_is_stable_under_repeated_adaptation() {
        let mut ab = AdaptiveBatcher::new(acfg(16, 4_000, 1_000));
        let t0 = Instant::now();
        let mut id = 0u64;
        let mut history = Vec::new();
        for step in 0..8 {
            while !ab.window_ready() {
                let now = t0 + Duration::from_micros(id * 50);
                ab.push(req(id), now);
                id += 1;
            }
            let _ = step;
            ab.maybe_adapt(Some(850.0)); // inside the 30 % band
            history.push((ab.eff_batch(), ab.eff_deadline_us()));
        }
        assert!(history.windows(2).all(|w| w[0] == w[1]), "no oscillation: {history:?}");
    }

    /// The relax cap: the deadline never exceeds the configured value,
    /// the tighten floor never goes below `min_deadline_us`.
    #[test]
    fn knobs_stay_inside_configured_bounds() {
        let mut ab = AdaptiveBatcher::new(acfg(8, 1_000, 500));
        let t0 = Instant::now();
        let mut id = 0u64;
        for _ in 0..32 {
            while !ab.window_ready() {
                ab.push(req(id), t0 + Duration::from_micros(id * 10));
                id += 1;
            }
            ab.maybe_adapt(Some(10_000.0)); // always over target
        }
        assert_eq!(ab.eff_deadline_us(), 50, "pinned at the floor");
        assert_eq!(ab.eff_batch(), 1, "pinned at min_batch");
        for _ in 0..32 {
            while !ab.window_ready() {
                ab.push(req(id), t0 + Duration::from_micros(id * 10));
                id += 1;
            }
            ab.maybe_adapt(Some(1.0)); // always far under target
        }
        assert_eq!(ab.eff_deadline_us(), 1_000, "relaxed back to the configured cap, not past");
        assert_eq!(ab.eff_batch(), 8);
    }
}
