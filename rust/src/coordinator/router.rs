//! Batch routing across the worker pool.
//!
//! Each worker owns an inference engine (a PJRT executable or a CiM
//! array group) and an mpsc queue. The router picks the queue; depth
//! counters make least-loaded routing possible without locking the
//! queues themselves.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{SendError, Sender};
use std::sync::Arc;

use super::batcher::Batch;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through workers.
    RoundRobin,
    /// Pick the worker with the fewest queued batches.
    LeastLoaded,
    /// Hash the first request's stream id (per-stream ordering).
    StreamAffinity,
}

/// Routes sealed batches to per-worker channels.
pub struct Router {
    senders: Vec<Sender<Batch>>,
    depths: Vec<Arc<AtomicUsize>>,
    policy: RoutingPolicy,
    next: AtomicUsize,
}

impl Router {
    /// Router over per-worker channels with the given policy.
    pub fn new(senders: Vec<Sender<Batch>>, policy: RoutingPolicy) -> Self {
        let depths = (0..senders.len()).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        Router { senders, depths, policy, next: AtomicUsize::new(0) }
    }

    /// Number of worker queues.
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Depth handle for worker `i` (the worker decrements on dequeue).
    pub fn depth_handle(&self, i: usize) -> Arc<AtomicUsize> {
        self.depths[i].clone()
    }

    /// Batches currently queued at worker `i`.
    pub fn queued(&self, i: usize) -> usize {
        self.depths[i].load(Ordering::Relaxed)
    }

    /// Pick a worker for this batch.
    pub fn pick(&self, batch: &Batch) -> usize {
        match self.policy {
            RoutingPolicy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.senders.len()
            }
            RoutingPolicy::LeastLoaded => self
                .depths
                .iter()
                .enumerate()
                .min_by_key(|(_, d)| d.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap_or(0),
            RoutingPolicy::StreamAffinity => {
                let stream = batch.requests.first().map(|r| r.stream).unwrap_or(0);
                stream as usize % self.senders.len()
            }
        }
    }

    /// Route and enqueue.
    pub fn dispatch(&self, batch: Batch) -> Result<usize, SendError<Batch>> {
        let w = self.pick(&batch);
        self.depths[w].fetch_add(1, Ordering::AcqRel);
        self.senders[w].send(batch)?;
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferenceRequest;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn batch(stream: u32) -> Batch {
        Batch {
            requests: vec![InferenceRequest::new(0, stream, vec![])],
            sealed_at: Instant::now(),
        }
    }

    fn router(n: usize, policy: RoutingPolicy) -> (Router, Vec<std::sync::mpsc::Receiver<Batch>>) {
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        (Router::new(senders, policy), receivers)
    }

    #[test]
    fn round_robin_cycles() {
        let (r, rxs) = router(3, RoutingPolicy::RoundRobin);
        for _ in 0..6 {
            r.dispatch(batch(0)).unwrap();
        }
        for rx in &rxs {
            assert_eq!(rx.try_iter().count(), 2);
        }
    }

    #[test]
    fn least_loaded_prefers_empty_queue() {
        let (r, _rxs) = router(2, RoutingPolicy::LeastLoaded);
        // Simulate worker 0 busy with 5 queued batches.
        r.depth_handle(0).store(5, Ordering::Relaxed);
        assert_eq!(r.pick(&batch(0)), 1);
    }

    #[test]
    fn stream_affinity_is_stable() {
        let (r, _rxs) = router(4, RoutingPolicy::StreamAffinity);
        let w1 = r.pick(&batch(7));
        let w2 = r.pick(&batch(7));
        assert_eq!(w1, w2);
        assert_eq!(w1, 7 % 4);
    }

    #[test]
    fn dispatch_increments_depth() {
        let (r, rxs) = router(1, RoutingPolicy::RoundRobin);
        r.dispatch(batch(0)).unwrap();
        assert_eq!(r.queued(0), 1);
        // Worker dequeues and decrements.
        let _ = rxs[0].recv().unwrap();
        r.depth_handle(0).fetch_sub(1, Ordering::AcqRel);
        assert_eq!(r.queued(0), 0);
    }
}
