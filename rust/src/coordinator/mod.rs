//! L3 edge-serving coordinator.
//!
//! The deployment shape the paper motivates (§I: autonomous-drone /
//! IoT edge nodes): sensor streams produce frames; the coordinator
//! admits, batches and routes them onto inference engines — either the
//! **digital reference** (the AOT-compiled JAX/Pallas model on PJRT,
//! [`crate::runtime`]) or the **analog CiM pool** (the paper's crossbar
//! + collaborative-ADC simulator). Rust owns the event loop, queues,
//! metrics and backpressure; python never appears at serve time.
//!
//! - [`request`] — request/response types; a request carries a
//!   [`FramePayload`] — a raw dense frame or a frontend-compressed
//!   [`crate::frontend::CompressedFrame`] that rides the batcher/router
//!   natively and is decoded (or served transform-domain) only at the
//!   engine — plus a QoS `priority` (derived from the frontend triage
//!   score on the wire path; [`TOP_PRIORITY`] otherwise).
//! - [`backpressure`] — bounded admission with *graduated* QoS
//!   shedding: below half depth everything enters, then the minimum
//!   admissible priority ramps linearly to the hard cap, so
//!   Summarize-class frames shed first and Keep-class traffic sheds
//!   last (the pure rule is [`admissible`]).
//! - [`batcher`] — deadline/size batch close (pure logic, testable
//!   without threads), in two flavors: the static [`DynamicBatcher`]
//!   and the self-tuning [`AdaptiveBatcher`] that walks the effective
//!   batch size toward the served-histogram knee and retunes the
//!   deadline against a p99 target (`--adaptive` / `--p99-target-us`).
//! - [`router`] — per-worker queues with round-robin / least-loaded
//!   dispatch.
//! - [`engine`] — the `InferenceEngine` trait + digital (PJRT) and
//!   analog (CiM simulator) implementations. The analog engine can
//!   serve through a scheduled [`crate::cim::pool::CimArrayPool`]
//!   (`AnalogEngine::with_pool`): crossbar MAVs digitized by neighbour
//!   arrays, with per-conversion energy/cycles/comparisons merged back
//!   from worker shards.
//! - [`metrics`] — latency/throughput accounting (bounded log-bucketed
//!   histograms) plus the pool's per-request digitization energy, the
//!   ingest frontend's deluge-triage counters, per-QoS-class
//!   admitted/shed tallies, the adaptive closer's live knob state, a
//!   rolling-window p99 (the adaptive feedback signal), the per-request
//!   stage breakdown (queue-wait / batch-wait / service, from
//!   [`crate::util::telemetry::RequestTrace`] stamps), executor/pool
//!   runtime counters, and the robustness tallies
//!   (rejected-at-the-door, malformed-wire, panic-isolated) in every
//!   `MetricsSnapshot` — which the streaming exporter
//!   ([`crate::util::telemetry::TelemetrySink`]) samples on a cadence.
//! - [`server`] — thread-per-worker serving loop tying it together;
//!   workers record per-batch conversion deltas into the metrics.
//!   Untrusted wire bytes enter only through `EdgeServer::submit_wire`
//!   (validated by `CompressedFrame::from_bytes`), and each worker
//!   isolates engine panics with `catch_unwind`: a poisoned request
//!   degrades to a failure response instead of killing the worker.

pub mod backpressure;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use backpressure::{admissible, AdmissionControl};
pub use batcher::{AdaptiveBatcher, AdaptiveConfig, Batch, DynamicBatcher};
#[cfg(feature = "xla")]
pub use engine::DigitalEngine;
pub use engine::{AnalogEngine, InferenceEngine};
pub use metrics::{AdaptiveSnapshot, Metrics, MetricsSnapshot};
pub use request::{FramePayload, InferenceRequest, InferenceResponse, TOP_PRIORITY};
pub use router::{Router, RoutingPolicy};
pub use server::{EdgeServer, SubmitError};
