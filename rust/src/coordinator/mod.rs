//! L3 edge-serving coordinator.
//!
//! The deployment shape the paper motivates (§I: autonomous-drone /
//! IoT edge nodes): sensor streams produce frames; the coordinator
//! admits, batches and routes them onto inference engines — either the
//! **digital reference** (the AOT-compiled JAX/Pallas model on PJRT,
//! [`crate::runtime`]) or the **analog CiM pool** (the paper's crossbar
//! + collaborative-ADC simulator). Rust owns the event loop, queues,
//! metrics and backpressure; python never appears at serve time.
//!
//! - [`request`] — request/response types; a request carries a
//!   [`FramePayload`] — a raw dense frame or a frontend-compressed
//!   [`crate::frontend::CompressedFrame`] that rides the batcher/router
//!   natively and is decoded (or served transform-domain) only at the
//!   engine.
//! - [`backpressure`] — bounded admission with load shedding.
//! - [`batcher`] — deadline/size dynamic batcher (pure logic, testable
//!   without threads).
//! - [`router`] — per-worker queues with round-robin / least-loaded
//!   dispatch.
//! - [`engine`] — the `InferenceEngine` trait + digital (PJRT) and
//!   analog (CiM simulator) implementations. The analog engine can
//!   serve through a scheduled [`crate::cim::pool::CimArrayPool`]
//!   (`AnalogEngine::with_pool`): crossbar MAVs digitized by neighbour
//!   arrays, with per-conversion energy/cycles/comparisons merged back
//!   from worker shards.
//! - [`metrics`] — latency/throughput accounting plus the pool's
//!   per-request digitization energy, the ingest frontend's
//!   deluge-triage counters, and the robustness tallies
//!   (rejected-at-the-door, malformed-wire, panic-isolated) in every
//!   `MetricsSnapshot`.
//! - [`server`] — thread-per-worker serving loop tying it together;
//!   workers record per-batch conversion deltas into the metrics.
//!   Untrusted wire bytes enter only through `EdgeServer::submit_wire`
//!   (validated by `CompressedFrame::from_bytes`), and each worker
//!   isolates engine panics with `catch_unwind`: a poisoned request
//!   degrades to a failure response instead of killing the worker.

pub mod backpressure;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use backpressure::AdmissionControl;
pub use batcher::{Batch, DynamicBatcher};
#[cfg(feature = "xla")]
pub use engine::DigitalEngine;
pub use engine::{AnalogEngine, InferenceEngine};
pub use metrics::Metrics;
pub use request::{FramePayload, InferenceRequest, InferenceResponse};
pub use router::{Router, RoutingPolicy};
pub use server::{EdgeServer, SubmitError};
