//! The serving loop: ingest → admission → batcher → router → workers.
//!
//! Thread layout (std threads; the node is CPU-bound anyway):
//!
//! ```text
//!  submit()──▶ [admission] ──▶ ingest mpsc ──▶ batcher thread
//!                                               │ (size/deadline)
//!                                        router (policy)
//!                                        ┌──────┴──────┐
//!                                   worker 0 …    worker N-1   (one engine each)
//!                                        └──────┬──────┘
//!                                         response mpsc ──▶ take_responses()
//! ```

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServerConfig;

use super::backpressure::AdmissionControl;
use super::batcher::DynamicBatcher;
use super::engine::InferenceEngine;
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};
use super::router::{Router, RoutingPolicy};

enum Ingest {
    Req(InferenceRequest),
    Shutdown,
}

/// Why [`EdgeServer::submit`] refused a request. Callers can tell load
/// shedding (retry later) from hostile input (don't bother).
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// Shed by backpressure: the admission queue is full.
    QueueFull,
    /// Wire bytes failed frame validation at the ingest boundary.
    Malformed(crate::frontend::CodecError),
    /// The server is shutting down (ingest channel closed).
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::Malformed(e) => write!(f, "malformed frame: {e}"),
            SubmitError::Closed => write!(f, "server is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A running edge-inference server.
pub struct EdgeServer {
    ingest_tx: Sender<Ingest>,
    response_rx: Receiver<InferenceResponse>,
    admission: Arc<AdmissionControl>,
    metrics: Arc<Metrics>,
    threads: Vec<JoinHandle<()>>,
}

impl EdgeServer {
    /// Start with one engine per worker (engines are moved into their
    /// worker threads).
    pub fn start(
        cfg: &ServerConfig,
        engines: Vec<Box<dyn InferenceEngine>>,
        policy: RoutingPolicy,
    ) -> Result<Self> {
        anyhow::ensure!(!engines.is_empty(), "need at least one engine");
        let admission = Arc::new(AdmissionControl::new(cfg.queue_depth));
        let metrics = Arc::new(Metrics::new());
        let (ingest_tx, ingest_rx) = channel::<Ingest>();
        let (response_tx, response_rx) = channel::<InferenceResponse>();

        // Workers.
        let mut worker_senders = Vec::new();
        let mut threads = Vec::new();
        let mut worker_rxs = Vec::new();
        for _ in 0..engines.len() {
            let (tx, rx) = channel();
            worker_senders.push(tx);
            worker_rxs.push(rx);
        }
        let router = Arc::new(Router::new(worker_senders, policy));
        for (wid, (engine, rx)) in engines.into_iter().zip(worker_rxs).enumerate() {
            let response_tx = response_tx.clone();
            let metrics = metrics.clone();
            let admission = admission.clone();
            let depth = router.depth_handle(wid);
            threads.push(std::thread::spawn(move || {
                worker_loop(wid, engine, rx, response_tx, metrics, admission, depth)
            }));
        }

        // Batcher thread.
        {
            let router = router.clone();
            let metrics = metrics.clone();
            let max_batch = cfg.batch;
            let deadline = Duration::from_micros(cfg.batch_deadline_us);
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingest_rx, router, metrics, max_batch, deadline)
            }));
        }

        Ok(EdgeServer { ingest_tx, response_rx, admission, metrics, threads })
    }

    /// Submit a request; the error says *why* it was refused
    /// (queue-full shedding vs hostile input vs shutdown).
    pub fn submit(&self, req: InferenceRequest) -> Result<(), SubmitError> {
        if !self.admission.admit() {
            self.metrics.record_rejected_queue_full();
            return Err(SubmitError::QueueFull);
        }
        if self.ingest_tx.send(Ingest::Req(req)).is_err() {
            self.admission.release();
            return Err(SubmitError::Closed);
        }
        Ok(())
    }

    /// Submit one frame straight off the wire: validate the bytes at
    /// the trust boundary, then enqueue the decoded frame. Returns the
    /// frame's own id (the wire header's `frame_id` becomes the request
    /// id). This is the only path untrusted bytes take into the server
    /// — everything past it handles a `CompressedFrame` that
    /// `from_bytes` fully vetted.
    pub fn submit_wire(&self, stream: u32, bytes: &[u8]) -> Result<u64, SubmitError> {
        let frame = crate::frontend::CompressedFrame::from_bytes(bytes).map_err(|e| {
            self.metrics.record_rejected_malformed();
            SubmitError::Malformed(e)
        })?;
        let id = frame.frame_id;
        self.submit(InferenceRequest::compressed(id, stream, frame))?;
        Ok(id)
    }

    /// Drain any completed responses without blocking.
    pub fn take_responses(&self) -> Vec<InferenceResponse> {
        self.response_rx.try_iter().collect()
    }

    /// Block for one response (with timeout).
    pub fn recv_response(&self, timeout: Duration) -> Option<InferenceResponse> {
        self.response_rx.recv_timeout(timeout).ok()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Fold an ingest-side frontend's counters into this server's
    /// metrics so the final `MetricsSnapshot` shows the deluge triage
    /// next to serving latency and pool conversions.
    pub fn record_frontend(&self, stats: &crate::frontend::FrontendStats) {
        self.metrics.record_frontend(stats);
    }

    pub fn shed_count(&self) -> u64 {
        self.admission.shed_count()
    }

    /// Flush, stop all threads, return final metrics.
    pub fn shutdown(self) -> super::metrics::MetricsSnapshot {
        let _ = self.ingest_tx.send(Ingest::Shutdown);
        for t in self.threads {
            let _ = t.join();
        }
        self.metrics.snapshot()
    }
}

fn batcher_loop(
    rx: Receiver<Ingest>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    max_batch: usize,
    deadline: Duration,
) {
    let mut batcher = DynamicBatcher::new(max_batch, deadline);
    loop {
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50))
            .max(Duration::from_micros(50));
        match rx.recv_timeout(wait) {
            Ok(Ingest::Req(req)) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                }
            }
            Ok(Ingest::Shutdown) => {
                if let Some(batch) = batcher.flush(Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                }
                // Dropping the router drops worker senders → workers exit.
                break;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.flush(Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                }
                break;
            }
        }
    }
}

fn worker_loop(
    wid: usize,
    mut engine: Box<dyn InferenceEngine>,
    rx: Receiver<super::batcher::Batch>,
    response_tx: Sender<InferenceResponse>,
    metrics: Arc<Metrics>,
    admission: Arc<AdmissionControl>,
    depth: Arc<std::sync::atomic::AtomicUsize>,
) {
    // Engine conversion/fusion counters are cumulative; record
    // per-batch deltas.
    let mut last_conv = engine.conversion_stats();
    let mut last_fused = engine.samples_fused();
    while let Ok(batch) = rx.recv() {
        depth.fetch_sub(1, Ordering::AcqRel);
        // Payloads travel as-is: compressed frames reach the engine
        // without being materialized on the coordinator side.
        let payloads: Vec<super::request::FramePayload> =
            batch.requests.iter().map(|r| r.payload.clone()).collect();
        // A poisoned request must cost its batch, not the worker: catch
        // the unwind, answer every request with a failure response, and
        // keep serving. (AssertUnwindSafe: on panic the engine's only
        // cross-batch state we still read is the monotone conversion
        // counters, and a torn batch's partial counts are acceptable.)
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.infer_payloads(&payloads)
        }));
        match outcome {
            Ok(Ok(all_logits)) => {
                for (req, logits) in batch.requests.iter().zip(all_logits) {
                    let resp = InferenceResponse::from_logits(req, logits, wid);
                    metrics.record_completion(resp.latency_us);
                    admission.release();
                    let _ = response_tx.send(resp);
                }
            }
            Ok(Err(e)) => {
                let reason = format!("engine error: {e:#}");
                for req in &batch.requests {
                    metrics.record_error();
                    admission.release();
                    let _ = response_tx.send(InferenceResponse::failure(req, wid, reason.clone()));
                }
            }
            Err(payload) => {
                let reason = format!("worker panic isolated: {}", panic_message(&payload));
                for req in &batch.requests {
                    metrics.record_panic_isolated();
                    admission.release();
                    let _ = response_tx.send(InferenceResponse::failure(req, wid, reason.clone()));
                }
            }
        }
        let now = engine.conversion_stats();
        metrics.record_conversions(&now.minus(&last_conv));
        last_conv = now;
        let fused = engine.samples_fused();
        metrics.record_samples_fused(fused - last_fused);
        last_fused = fused;
    }
}

/// Best-effort text of a caught panic payload (`panic!` carries a
/// `&str` or `String`; anything else stays opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic payload>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;

    fn mock(n: usize) -> Vec<Box<dyn InferenceEngine>> {
        (0..n)
            .map(|_| {
                Box::new(MockEngine {
                    classes: 10,
                    input: 4,
                    delay: Duration::from_micros(200),
                }) as Box<dyn InferenceEngine>
            })
            .collect()
    }

    #[test]
    fn serves_requests_end_to_end() {
        let cfg =
            ServerConfig { workers: 2, batch: 4, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        for i in 0..20u64 {
            assert!(server.submit(InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4])).is_ok());
        }
        let mut got = Vec::new();
        let t0 = Instant::now();
        while got.len() < 20 && t0.elapsed() < Duration::from_secs(5) {
            if let Some(r) = server.recv_response(Duration::from_millis(100)) {
                got.push(r);
            }
        }
        assert_eq!(got.len(), 20);
        // Mock classifies image[0] % 10.
        for r in &got {
            assert_eq!(r.class, (r.id % 10) as usize);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn backpressure_sheds_when_full() {
        let cfg = ServerConfig {
            workers: 1,
            batch: 64,
            batch_deadline_us: 500_000, // long deadline: queue fills
            queue_depth: 8,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::RoundRobin).unwrap();
        let mut accepted = 0u64;
        let mut queue_full = 0u64;
        for i in 0..64u64 {
            match server.submit(InferenceRequest::new(i, 0, vec![0.0; 4])) {
                Ok(()) => accepted += 1,
                Err(SubmitError::QueueFull) => queue_full += 1,
                Err(e) => panic!("unexpected reject reason: {e}"),
            }
        }
        assert!(accepted <= 8, "admitted {accepted} > depth 8");
        assert!(server.shed_count() >= 56);
        let snap = server.shutdown();
        assert_eq!(snap.rejected_queue_full, queue_full);
        assert_eq!(accepted + queue_full, 64);
        assert!(format!("{snap}").contains("rejected: queue="), "{snap}");
    }

    /// The wire ingest boundary: valid bytes serve, garbage is refused
    /// with `Malformed` and counted, and the server stays healthy.
    #[test]
    fn submit_wire_validates_at_the_boundary() {
        use crate::frontend::codec::{CodecParams, LOSSLESS};
        use crate::frontend::encoder::{FrameEncoder, Selection};
        let cfg =
            ServerConfig { workers: 1, batch: 2, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::RoundRobin).unwrap();
        let params = CodecParams::new(1, 4, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        let wire = enc.encode_wire(&[1.0, 0.25, 0.5, 0.75], 42);
        assert_eq!(server.submit_wire(0, &wire).unwrap(), 42, "request id = wire frame id");

        assert!(matches!(
            server.submit_wire(0, b"not a frame"),
            Err(SubmitError::Malformed(_))
        ));
        assert!(matches!(
            server.submit_wire(0, &wire[..wire.len() - 1]),
            Err(SubmitError::Malformed(_))
        ));

        let r = server.recv_response(Duration::from_secs(2)).expect("valid frame serves");
        assert_eq!(r.id, 42);
        assert_eq!(r.class, 1);
        assert!(r.error.is_none());
        let snap = server.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.rejected_malformed, 2);
    }

    /// Compressed requests flow through the real batcher/router/worker
    /// path: the worker hands payloads to the engine, which decodes.
    #[test]
    fn serves_compressed_requests_end_to_end() {
        use crate::frontend::codec::{CodecParams, LOSSLESS};
        use crate::frontend::encoder::{FrameEncoder, Selection};
        let cfg =
            ServerConfig { workers: 2, batch: 4, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        let params = CodecParams::new(1, 4, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        for i in 0..12u64 {
            // Mock classifies image[0]; keep it on the sensor grid so
            // the lossless round trip preserves it exactly (0 or 1).
            let frame = vec![(i % 2) as f32, 0.25, 0.5, 0.75];
            let cf = enc.encode(&frame, i);
            assert!(server.submit(InferenceRequest::compressed(i, 0, cf)).is_ok());
        }
        let mut got = Vec::new();
        let t0 = Instant::now();
        while got.len() < 12 && t0.elapsed() < Duration::from_secs(5) {
            if let Some(r) = server.recv_response(Duration::from_millis(100)) {
                got.push(r);
            }
        }
        assert_eq!(got.len(), 12);
        for r in &got {
            assert_eq!(r.class, (r.id % 2) as usize, "id {}", r.id);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 12);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        let cfg = ServerConfig {
            workers: 1,
            batch: 1000,
            batch_deadline_us: 2_000,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::LeastLoaded).unwrap();
        server.submit(InferenceRequest::new(1, 0, vec![1.0; 4])).unwrap();
        let r = server.recv_response(Duration::from_secs(2)).expect("deadline dispatch");
        assert_eq!(r.id, 1);
        server.shutdown();
    }
}
