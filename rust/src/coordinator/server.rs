//! The serving loop: ingest → admission/QoS → batcher → router →
//! workers.
//!
//! Thread layout (std threads; the node is CPU-bound anyway):
//!
//! ```text
//!  submit(req)────────────────────┐
//!  submit_wire(bytes)─▶ [codec    │   trusted InferenceRequest
//!    (untrusted wire,    validate,│   (priority from triage score)
//!     Channel-faulted)   priority]│
//!                                 ▼
//!                     [admission: graduated QoS shed]
//!                                 │ admitted
//!                                 ▼
//!                          ingest mpsc ──▶ batcher thread
//!                                           │ static (max_batch, deadline)
//!                                           │ or adaptive (knee walk +
//!                                           │ p99-target retune)
//!                                    router (policy)
//!                                    ┌──────┴──────┐
//!                               worker 0 …    worker N-1   (one engine each,
//!                                    │  panic-isolated,  │   lockstep-fused
//!                                    └──────┬──────┘        multi-sample forward)
//!                                     response mpsc ──▶ take_responses()
//! ```
//!
//! The two ingest edges differ in trust: `submit` takes an in-process
//! [`InferenceRequest`] as-is, `submit_wire` is the only path untrusted
//! bytes enter (full [`crate::frontend::CompressedFrame::from_bytes`]
//! validation, `Malformed` rejects counted). Both then pass graduated
//! admission: each request's QoS priority (derived from the frontend
//! triage score for wire frames; [`super::request::TOP_PRIORITY`] for
//! plain submits) is checked against the queue-depth ramp in
//! [`super::backpressure::admissible`], so under overload the
//! least-valuable frames shed first. The batcher thread closes batches
//! either statically or adaptively ([`super::batcher::AdaptiveBatcher`],
//! `--adaptive`), and every dispatched batch reaches one panic-isolated
//! worker that serves it through the engine's fused multi-sample
//! forward.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServerConfig;
use crate::frontend::retention::RetentionPolicy;

use super::backpressure::AdmissionControl;
use super::batcher::{AdaptiveBatcher, AdaptiveConfig, Batch, DynamicBatcher};
use super::engine::InferenceEngine;
use super::metrics::{AdaptiveSnapshot, Metrics};
use super::request::{InferenceRequest, InferenceResponse};
use super::router::{Router, RoutingPolicy};

enum Ingest {
    Req(InferenceRequest),
    Shutdown,
}

/// The batcher thread's close policy: the static `(max_batch, deadline)`
/// pair, or the self-tuning wrapper. Static is the `--adaptive`-off
/// path and stays bit-identical to the pre-adaptive server.
enum Closer {
    Static(DynamicBatcher),
    Adaptive(AdaptiveBatcher),
}

impl Closer {
    fn push(&mut self, req: InferenceRequest, now: Instant) -> Option<Batch> {
        match self {
            Closer::Static(b) => b.push(req, now),
            Closer::Adaptive(b) => b.push(req, now),
        }
    }

    fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self {
            Closer::Static(b) => b.poll(now),
            Closer::Adaptive(b) => b.poll(now),
        }
    }

    fn flush(&mut self, now: Instant) -> Option<Batch> {
        match self {
            Closer::Static(b) => b.flush(now),
            Closer::Adaptive(b) => b.flush(now),
        }
    }

    fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        match self {
            Closer::Static(b) => b.time_to_deadline(now),
            Closer::Adaptive(b) => b.time_to_deadline(now),
        }
    }

    /// Run one adaptation step if a window of seals is ready, feeding
    /// the metrics' rolling p99 in and the retuned knobs back out.
    /// No-op for the static closer.
    fn adapt_if_ready(&mut self, metrics: &Metrics) {
        if let Closer::Adaptive(b) = self {
            if b.window_ready() && b.maybe_adapt(metrics.recent_p99_us()) {
                metrics.record_adaptive_state(AdaptiveSnapshot {
                    eff_batch: b.eff_batch(),
                    eff_deadline_us: b.eff_deadline_us(),
                    adaptations: b.adaptations(),
                });
            }
        }
    }
}

/// Why [`EdgeServer::submit`] refused a request. Callers can tell load
/// shedding (retry later) from hostile input (don't bother).
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// Shed by backpressure: the admission queue is full.
    QueueFull,
    /// Wire bytes failed frame validation at the ingest boundary.
    Malformed(crate::frontend::CodecError),
    /// The server is shutting down (ingest channel closed).
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::Malformed(e) => write!(f, "malformed frame: {e}"),
            SubmitError::Closed => write!(f, "server is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A running edge-inference server.
pub struct EdgeServer {
    ingest_tx: Sender<Ingest>,
    response_rx: Receiver<InferenceResponse>,
    admission: Arc<AdmissionControl>,
    metrics: Arc<Metrics>,
    /// Scores wire frames into QoS priorities (the same policy
    /// `cfg.retain` names; `KeepAll` pins everything to top priority).
    wire_policy: RetentionPolicy,
    threads: Vec<JoinHandle<()>>,
    /// Shutdown join deadline (ms); 0 joins unconditionally.
    shutdown_timeout_ms: u64,
}

impl EdgeServer {
    /// Start with one engine per worker (engines are moved into their
    /// worker threads).
    pub fn start(
        cfg: &ServerConfig,
        engines: Vec<Box<dyn InferenceEngine>>,
        policy: RoutingPolicy,
    ) -> Result<Self> {
        anyhow::ensure!(!engines.is_empty(), "need at least one engine");
        let admission = Arc::new(AdmissionControl::new(cfg.queue_depth));
        let metrics = Arc::new(Metrics::new());
        let (ingest_tx, ingest_rx) = channel::<Ingest>();
        let (response_tx, response_rx) = channel::<InferenceResponse>();

        // Workers.
        let mut worker_senders = Vec::new();
        let mut threads = Vec::new();
        let mut worker_rxs = Vec::new();
        for _ in 0..engines.len() {
            let (tx, rx) = channel();
            worker_senders.push(tx);
            worker_rxs.push(rx);
        }
        let router = Arc::new(Router::new(worker_senders, policy));
        let telemetry = cfg.telemetry;
        for (wid, (engine, rx)) in engines.into_iter().zip(worker_rxs).enumerate() {
            let response_tx = response_tx.clone();
            let metrics = metrics.clone();
            let admission = admission.clone();
            let depth = router.depth_handle(wid);
            threads.push(std::thread::spawn(move || {
                worker_loop(wid, engine, rx, response_tx, metrics, admission, depth, telemetry)
            }));
        }

        // Batcher thread: static closer by default, adaptive when asked.
        {
            let router = router.clone();
            let metrics = metrics.clone();
            let closer = if cfg.adaptive {
                let acfg = AdaptiveConfig::new(cfg.batch, cfg.batch_deadline_us, cfg.p99_target_us);
                let b = AdaptiveBatcher::new(acfg);
                metrics.record_adaptive_state(AdaptiveSnapshot {
                    eff_batch: b.eff_batch(),
                    eff_deadline_us: b.eff_deadline_us(),
                    adaptations: 0,
                });
                Closer::Adaptive(b)
            } else {
                Closer::Static(DynamicBatcher::new(
                    cfg.batch,
                    Duration::from_micros(cfg.batch_deadline_us),
                ))
            };
            threads
                .push(std::thread::spawn(move || batcher_loop(ingest_rx, router, metrics, closer)));
        }

        let wire_policy =
            RetentionPolicy::parse(&cfg.retain).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(EdgeServer {
            ingest_tx,
            response_rx,
            admission,
            metrics,
            wire_policy,
            threads,
            shutdown_timeout_ms: cfg.shutdown_timeout_ms,
        })
    }

    /// Submit a request; the error says *why* it was refused
    /// (graduated QoS shedding vs hostile input vs shutdown). A request
    /// built without an explicit priority carries
    /// [`super::request::TOP_PRIORITY`] and is only shed when the queue
    /// is completely full — the legacy admission behavior.
    pub fn submit(&self, mut req: InferenceRequest) -> Result<(), SubmitError> {
        let class = req.qos_class();
        if !self.admission.admit_priority(req.priority) {
            self.metrics.record_rejected_queue_full();
            self.metrics.record_qos(class, false);
            return Err(SubmitError::QueueFull);
        }
        self.metrics.record_qos(class, true);
        // First stage stamp: the request is past admission. A cheap
        // clock read, never consulted by scheduling — always on.
        req.trace.admitted = Some(Instant::now());
        if self.ingest_tx.send(Ingest::Req(req)).is_err() {
            self.admission.release();
            return Err(SubmitError::Closed);
        }
        Ok(())
    }

    /// Submit one frame straight off the wire: validate the bytes at
    /// the trust boundary, score them into a QoS priority, then enqueue
    /// the decoded frame. Returns the frame's own id (the wire header's
    /// `frame_id` becomes the request id). This is the only path
    /// untrusted bytes take into the server — everything past it
    /// handles a `CompressedFrame` that `from_bytes` fully vetted.
    ///
    /// The priority comes from the server's retention policy
    /// (`cfg.retain`) scoring the frame's triage statistics; with the
    /// default `keep` policy every frame is top priority and admission
    /// is the legacy full-queue check.
    pub fn submit_wire(&self, stream: u32, bytes: &[u8]) -> Result<u64, SubmitError> {
        let frame = crate::frontend::CompressedFrame::from_bytes(bytes).map_err(|e| {
            self.metrics.record_rejected_malformed();
            SubmitError::Malformed(e)
        })?;
        let id = frame.frame_id;
        let priority = self.wire_policy.priority(&frame);
        self.submit(InferenceRequest::compressed(id, stream, frame).with_priority(priority))?;
        Ok(id)
    }

    /// Drain any completed responses without blocking.
    pub fn take_responses(&self) -> Vec<InferenceResponse> {
        self.response_rx.try_iter().collect()
    }

    /// Block for one response (with timeout).
    pub fn recv_response(&self, timeout: Duration) -> Option<InferenceResponse> {
        self.response_rx.recv_timeout(timeout).ok()
    }

    /// Live metrics handle (snapshot any time; workers keep writing).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Consistent counter snapshot of the live run — what the periodic
    /// telemetry exporter ([`crate::util::telemetry::TelemetrySink`])
    /// samples on its cadence.
    pub fn metrics_snapshot(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Fold an ingest-side frontend's counters into this server's
    /// metrics so the final `MetricsSnapshot` shows the deluge triage
    /// next to serving latency and pool conversions.
    pub fn record_frontend(&self, stats: &crate::frontend::FrontendStats) {
        self.metrics.record_frontend(stats);
    }

    /// Requests refused admission so far (all priorities).
    pub fn shed_count(&self) -> u64 {
        self.admission.shed_count()
    }

    /// Flush, stop all threads, return final metrics.
    ///
    /// Joining is bounded by `cfg.shutdown_timeout_ms`: a worker stuck
    /// inside a wedged engine forward (the one thing panic isolation
    /// can't catch) would otherwise hang the whole process on exit.
    /// Workers that outlive the deadline are **detached** — their
    /// handles dropped, the threads left to die with the process — and
    /// counted in the snapshot's `shutdown_forced`. A timeout of 0
    /// restores the legacy unconditional join.
    pub fn shutdown(self) -> super::metrics::MetricsSnapshot {
        let _ = self.ingest_tx.send(Ingest::Shutdown);
        if self.shutdown_timeout_ms == 0 {
            for t in self.threads {
                let _ = t.join();
            }
            return self.metrics.snapshot();
        }
        let deadline = Instant::now() + Duration::from_millis(self.shutdown_timeout_ms);
        let mut pending = self.threads;
        let forced = loop {
            // Reap every thread that has already exited (join cannot
            // block on a finished thread), keep waiting on the rest.
            let mut still = Vec::with_capacity(pending.len());
            for t in pending {
                if t.is_finished() {
                    let _ = t.join();
                } else {
                    still.push(t);
                }
            }
            pending = still;
            if pending.is_empty() {
                break 0;
            }
            if Instant::now() >= deadline {
                // Detach the stragglers: dropping a JoinHandle leaves
                // the thread running, so shutdown returns instead of
                // hanging; the count lands in the metrics.
                break pending.len() as u64;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        self.metrics.record_shutdown_forced(forced);
        self.metrics.snapshot()
    }
}

fn batcher_loop(
    rx: Receiver<Ingest>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    mut closer: Closer,
) {
    loop {
        let wait = closer
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50))
            .max(Duration::from_micros(50));
        match rx.recv_timeout(wait) {
            Ok(Ingest::Req(req)) => {
                if let Some(batch) = closer.push(req, Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                    closer.adapt_if_ready(&metrics);
                }
            }
            Ok(Ingest::Shutdown) => {
                if let Some(batch) = closer.flush(Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                }
                // Dropping the router drops worker senders → workers exit.
                break;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = closer.poll(Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                    closer.adapt_if_ready(&metrics);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = closer.flush(Instant::now()) {
                    metrics.record_batch(batch.len());
                    let _ = router.dispatch(batch);
                }
                break;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wid: usize,
    mut engine: Box<dyn InferenceEngine>,
    rx: Receiver<super::batcher::Batch>,
    response_tx: Sender<InferenceResponse>,
    metrics: Arc<Metrics>,
    admission: Arc<AdmissionControl>,
    depth: Arc<std::sync::atomic::AtomicUsize>,
    telemetry: bool,
) {
    // Engine conversion/fusion/runtime counters are cumulative; record
    // per-batch deltas.
    let mut last_conv = engine.conversion_stats();
    let mut last_fused = engine.samples_fused();
    let mut last_runtime = engine.runtime_counters();
    let mut last_faults = engine.fault_stats();
    while let Ok(batch) = rx.recv() {
        depth.fetch_sub(1, Ordering::AcqRel);
        // Payloads travel as-is: compressed frames reach the engine
        // without being materialized on the coordinator side.
        let payloads: Vec<super::request::FramePayload> =
            batch.requests.iter().map(|r| r.payload.clone()).collect();
        // A poisoned request must cost its batch, not the worker: catch
        // the unwind, answer every request with a failure response, and
        // keep serving. (AssertUnwindSafe: on panic the engine's only
        // cross-batch state we still read is the monotone conversion
        // counters, and a torn batch's partial counts are acceptable.)
        let engine_start = Instant::now();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.infer_payloads(&payloads)
        }));
        let engine_end = Instant::now();
        match outcome {
            Ok(Ok(all_logits)) => {
                for (req, logits) in batch.requests.iter().zip(all_logits) {
                    let resp = InferenceResponse::from_logits(req, logits, wid);
                    metrics.record_completion(resp.latency_us);
                    if telemetry {
                        let mut trace = req.trace;
                        trace.engine_start = Some(engine_start);
                        trace.engine_end = Some(engine_end);
                        if let Some(s) = trace.stages(req.submitted, Instant::now()) {
                            metrics.record_stages(s);
                        }
                    }
                    admission.release();
                    let _ = response_tx.send(resp);
                }
            }
            Ok(Err(e)) => {
                let reason = format!("engine error: {e:#}");
                for req in &batch.requests {
                    metrics.record_error();
                    admission.release();
                    let _ = response_tx.send(InferenceResponse::failure(req, wid, reason.clone()));
                }
            }
            Err(payload) => {
                let reason = format!("worker panic isolated: {}", panic_message(&payload));
                for req in &batch.requests {
                    metrics.record_panic_isolated();
                    admission.release();
                    let _ = response_tx.send(InferenceResponse::failure(req, wid, reason.clone()));
                }
            }
        }
        let now = engine.conversion_stats();
        metrics.record_conversions(&now.minus(&last_conv));
        last_conv = now;
        let fused = engine.samples_fused();
        metrics.record_samples_fused(fused - last_fused);
        last_fused = fused;
        // Fault-free engines report all-zero deltas and the recorder
        // skips the metrics lock entirely — this stays off the clean
        // path's cost profile.
        let faults = engine.fault_stats();
        metrics.record_faults(&faults.minus(&last_faults));
        last_faults = faults;
        if telemetry {
            let rc = engine.runtime_counters();
            metrics.record_runtime(&rc.minus(&last_runtime));
            last_runtime = rc;
        }
    }
}

/// Best-effort text of a caught panic payload (`panic!` carries a
/// `&str` or `String`; anything else stays opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic payload>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;

    fn mock(n: usize) -> Vec<Box<dyn InferenceEngine>> {
        (0..n)
            .map(|_| {
                Box::new(MockEngine {
                    classes: 10,
                    input: 4,
                    delay: Duration::from_micros(200),
                }) as Box<dyn InferenceEngine>
            })
            .collect()
    }

    #[test]
    fn serves_requests_end_to_end() {
        let cfg =
            ServerConfig { workers: 2, batch: 4, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        for i in 0..20u64 {
            assert!(server.submit(InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4])).is_ok());
        }
        let mut got = Vec::new();
        let t0 = Instant::now();
        while got.len() < 20 && t0.elapsed() < Duration::from_secs(5) {
            if let Some(r) = server.recv_response(Duration::from_millis(100)) {
                got.push(r);
            }
        }
        assert_eq!(got.len(), 20);
        // Mock classifies image[0] % 10.
        for r in &got {
            assert_eq!(r.class, (r.id % 10) as usize);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.shutdown_forced, 0, "healthy workers join in time");
        assert!(snap.faults.is_zero(), "no fault plan, no fault counters");
    }

    /// A worker wedged inside a long engine forward cannot hang
    /// shutdown: the join deadline expires, the straggler is detached
    /// and counted, and the caller gets its snapshot back promptly.
    #[test]
    fn bounded_shutdown_detaches_stuck_workers() {
        let cfg = ServerConfig {
            workers: 1,
            batch: 1,
            batch_deadline_us: 100,
            shutdown_timeout_ms: 100,
            ..Default::default()
        };
        let slow: Vec<Box<dyn InferenceEngine>> = vec![Box::new(MockEngine {
            classes: 10,
            input: 4,
            delay: Duration::from_secs(10),
        })];
        let server = EdgeServer::start(&cfg, slow, RoutingPolicy::RoundRobin).unwrap();
        server.submit(InferenceRequest::new(0, 0, vec![1.0; 4])).unwrap();
        // Give the batcher time to seal and dispatch the batch so the
        // worker is genuinely inside the 10 s forward.
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        let snap = server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown must not wait out the wedged forward"
        );
        assert_eq!(snap.shutdown_forced, 1, "the stuck worker was detached");
        assert!(format!("{snap}").contains("shutdown_forced=1"), "{snap}");
    }

    #[test]
    fn backpressure_sheds_when_full() {
        let cfg = ServerConfig {
            workers: 1,
            batch: 64,
            batch_deadline_us: 500_000, // long deadline: queue fills
            queue_depth: 8,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::RoundRobin).unwrap();
        let mut accepted = 0u64;
        let mut queue_full = 0u64;
        for i in 0..64u64 {
            match server.submit(InferenceRequest::new(i, 0, vec![0.0; 4])) {
                Ok(()) => accepted += 1,
                Err(SubmitError::QueueFull) => queue_full += 1,
                Err(e) => panic!("unexpected reject reason: {e}"),
            }
        }
        assert!(accepted <= 8, "admitted {accepted} > depth 8");
        assert!(server.shed_count() >= 56);
        let snap = server.shutdown();
        assert_eq!(snap.rejected_queue_full, queue_full);
        assert_eq!(accepted + queue_full, 64);
        assert!(format!("{snap}").contains("rejected: queue="), "{snap}");
    }

    /// The wire ingest boundary: valid bytes serve, garbage is refused
    /// with `Malformed` and counted, and the server stays healthy.
    #[test]
    fn submit_wire_validates_at_the_boundary() {
        use crate::frontend::codec::{CodecParams, LOSSLESS};
        use crate::frontend::encoder::{FrameEncoder, Selection};
        let cfg =
            ServerConfig { workers: 1, batch: 2, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::RoundRobin).unwrap();
        let params = CodecParams::new(1, 4, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        let wire = enc.encode_wire(&[1.0, 0.25, 0.5, 0.75], 42);
        assert_eq!(server.submit_wire(0, &wire).unwrap(), 42, "request id = wire frame id");

        assert!(matches!(
            server.submit_wire(0, b"not a frame"),
            Err(SubmitError::Malformed(_))
        ));
        assert!(matches!(
            server.submit_wire(0, &wire[..wire.len() - 1]),
            Err(SubmitError::Malformed(_))
        ));

        let r = server.recv_response(Duration::from_secs(2)).expect("valid frame serves");
        assert_eq!(r.id, 42);
        assert_eq!(r.class, 1);
        assert!(r.error.is_none());
        let snap = server.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.rejected_malformed, 2);
    }

    /// Compressed requests flow through the real batcher/router/worker
    /// path: the worker hands payloads to the engine, which decodes.
    #[test]
    fn serves_compressed_requests_end_to_end() {
        use crate::frontend::codec::{CodecParams, LOSSLESS};
        use crate::frontend::encoder::{FrameEncoder, Selection};
        let cfg =
            ServerConfig { workers: 2, batch: 4, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        let params = CodecParams::new(1, 4, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        for i in 0..12u64 {
            // Mock classifies image[0]; keep it on the sensor grid so
            // the lossless round trip preserves it exactly (0 or 1).
            let frame = vec![(i % 2) as f32, 0.25, 0.5, 0.75];
            let cf = enc.encode(&frame, i);
            assert!(server.submit(InferenceRequest::compressed(i, 0, cf)).is_ok());
        }
        let mut got = Vec::new();
        let t0 = Instant::now();
        while got.len() < 12 && t0.elapsed() < Duration::from_secs(5) {
            if let Some(r) = server.recv_response(Duration::from_millis(100)) {
                got.push(r);
            }
        }
        assert_eq!(got.len(), 12);
        for r in &got {
            assert_eq!(r.class, (r.id % 2) as usize, "id {}", r.id);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 12);
        assert_eq!(snap.errors, 0);
    }

    /// Adaptive close serves the same traffic correctly and publishes
    /// its knob state into the snapshot.
    #[test]
    fn adaptive_closer_serves_and_reports_state() {
        let cfg = ServerConfig {
            workers: 2,
            batch: 4,
            batch_deadline_us: 500,
            adaptive: true,
            p99_target_us: 50_000,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        for i in 0..20u64 {
            assert!(server.submit(InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4])).is_ok());
        }
        let mut got = Vec::new();
        let t0 = Instant::now();
        while got.len() < 20 && t0.elapsed() < Duration::from_secs(5) {
            if let Some(r) = server.recv_response(Duration::from_millis(100)) {
                got.push(r);
            }
        }
        assert_eq!(got.len(), 20);
        for r in &got {
            assert_eq!(r.class, (r.id % 10) as usize);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 20);
        let a = snap.adaptive.expect("adaptive state published");
        assert!(a.eff_batch >= 1 && a.eff_batch <= 4);
        assert!(a.eff_deadline_us <= 500);
        assert!(format!("{snap}").contains("adaptive: batch="), "{snap}");
    }

    /// Static serving leaves no adaptive fingerprint in the snapshot —
    /// the off-switch really is the old server.
    #[test]
    fn static_closer_reports_no_adaptive_state() {
        let cfg =
            ServerConfig { workers: 1, batch: 4, batch_deadline_us: 500, ..Default::default() };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::RoundRobin).unwrap();
        server.submit(InferenceRequest::new(1, 0, vec![1.0; 4])).unwrap();
        assert!(server.recv_response(Duration::from_secs(2)).is_some());
        let snap = server.shutdown();
        assert!(snap.adaptive.is_none());
        assert!(!format!("{snap}").contains("adaptive"), "{snap}");
    }

    /// Under a stuffed queue, graduated admission sheds low-priority
    /// requests while Keep-band traffic still gets in — and the
    /// per-class counters account for both.
    #[test]
    fn graduated_shedding_prefers_high_priority() {
        let cfg = ServerConfig {
            workers: 1,
            batch: 64,
            batch_deadline_us: 500_000, // long deadline: queue fills
            queue_depth: 16,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::RoundRobin).unwrap();
        // Fill past the ramp start (depth 8 of 16) with top priority.
        for i in 0..12u64 {
            assert!(server.submit(InferenceRequest::new(i, 0, vec![0.0; 4])).is_ok());
        }
        // depth=12: bar = (12-8)*256/8 = 128. Low priority sheds…
        let low = InferenceRequest::new(100, 0, vec![0.0; 4]).with_priority(60);
        assert_eq!(server.submit(low), Err(SubmitError::QueueFull));
        // …top priority still enters.
        assert!(server.submit(InferenceRequest::new(101, 0, vec![0.0; 4])).is_ok());
        let snap = server.shutdown();
        assert_eq!(snap.qos_shed[0], 1, "priority-60 request shed");
        assert_eq!(snap.qos_shed[3], 0, "Keep band never shed");
        assert_eq!(snap.qos_admitted[3], 13);
        assert!(format!("{snap}").contains("qos shed=[c0:1"), "{snap}");
    }

    /// Every served request resolves its stage spans, and the spans
    /// telescope under the end-to-end latency; `--no-telemetry` leaves
    /// the stage histograms empty without changing what serves.
    #[test]
    fn stage_spans_resolve_and_telescope() {
        let cfg =
            ServerConfig { workers: 2, batch: 4, batch_deadline_us: 500, ..Default::default() };
        assert!(cfg.telemetry, "telemetry defaults on");
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        for i in 0..16u64 {
            assert!(server.submit(InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4])).is_ok());
        }
        let mut got = 0;
        let t0 = Instant::now();
        while got < 16 && t0.elapsed() < Duration::from_secs(5) {
            if server.recv_response(Duration::from_millis(100)).is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 16);
        let snap = server.shutdown();
        assert_eq!(snap.stages.queue_wait.count, 16);
        assert_eq!(snap.stages.batch_wait.count, 16);
        assert_eq!(snap.stages.service.count, 16);
        // The mock sleeps 200µs per batch: service dominates and the
        // stage means telescope under the end-to-end mean.
        assert!(snap.stages.service.mean_us >= 150.0, "{:?}", snap.stages.service);
        let sum = snap.stages.queue_wait.mean_us
            + snap.stages.batch_wait.mean_us
            + snap.stages.service.mean_us;
        assert!(sum <= snap.mean_latency_us + 1e-6, "{sum} vs {}", snap.mean_latency_us);

        // Telemetry off: same serving, no stage samples.
        let cfg = ServerConfig { telemetry: false, ..cfg };
        let server = EdgeServer::start(&cfg, mock(2), RoutingPolicy::RoundRobin).unwrap();
        server.submit(InferenceRequest::new(0, 0, vec![3.0; 4])).unwrap();
        let r = server.recv_response(Duration::from_secs(2)).expect("still serves");
        assert_eq!(r.class, 3);
        let snap = server.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.stages.service.count, 0, "no stage samples when telemetry is off");
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        let cfg = ServerConfig {
            workers: 1,
            batch: 1000,
            batch_deadline_us: 2_000,
            ..Default::default()
        };
        let server = EdgeServer::start(&cfg, mock(1), RoutingPolicy::LeastLoaded).unwrap();
        server.submit(InferenceRequest::new(1, 0, vec![1.0; 4])).unwrap();
        let r = server.recv_response(Duration::from_secs(2)).expect("deadline dispatch");
        assert_eq!(r.id, 1);
        server.shutdown();
    }
}
