//! Inference engines: what a worker runs a batch on.
//!
//! - [`DigitalEngine`] — the AOT-compiled JAX/Pallas model on PJRT
//!   (digital reference path; exact logits). Gated behind the `xla`
//!   feature: the default offline build serves analog-only.
//! - [`AnalogEngine`] — the same trained parameters executed through
//!   the CiM crossbar simulator ([`crate::cim`]) at a configurable
//!   operating point: the paper's hardware path, with its quantization
//!   and analog non-idealities. Batches shard across std worker threads
//!   with per-sample deterministic noise streams, so results are
//!   identical at any thread count.

use anyhow::Result;

use crate::cim::{ConversionStats, CrossbarConfig, EarlyTermination, PoolSpec};
use crate::nn::bwht_layer::BwhtExec;
use crate::nn::model::bwht_mlp_from_weights;
use crate::nn::{Sequential, Tensor};
use crate::runtime::Artifacts;
#[cfg(feature = "xla")]
use crate::runtime::{LoadedModel, Manifest, Runtime};

/// A batch-inference engine.
pub trait InferenceEngine: Send {
    /// Logits for each image (image length = input dim).
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    fn name(&self) -> &'static str;
    /// Input dimension.
    fn input_dim(&self) -> usize;
    /// Cumulative collaborative-digitization accounting (monotone).
    /// Engines without an ADC pool report zeros; the serving loop
    /// records per-batch deltas into [`super::Metrics`].
    fn conversion_stats(&mut self) -> ConversionStats {
        ConversionStats::default()
    }
}

/// PJRT-backed digital reference engine.
///
/// Owns its *own* PJRT client: the `xla` crate's handles are `Rc`-based
/// (`!Send`), so the only sound way to move an engine into a worker
/// thread is to move the client and every executable referencing it as
/// one unit — which is exactly what this struct is.
#[cfg(feature = "xla")]
pub struct DigitalEngine {
    // Field order matters: `model` must drop before `runtime`.
    model: LoadedModel,
    _runtime: Runtime,
    manifest: Manifest,
}

// SAFETY: all Rc handles into the PJRT client are confined to this
// struct (`_runtime` + `model`); moving the whole struct to another
// thread moves every reference together, and the engine is used by one
// thread at a time (worker ownership). No Rc clone escapes.
#[cfg(feature = "xla")]
unsafe impl Send for DigitalEngine {}

#[cfg(feature = "xla")]
impl DigitalEngine {
    /// Load `model_float.hlo.txt` (or `model_quant.hlo.txt` with
    /// `quant = true`) from an artifacts directory, with a private PJRT
    /// CPU client.
    pub fn load(artifacts: &Artifacts, quant: bool) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let manifest = artifacts.manifest()?;
        let name = if quant { "model_quant" } else { "model_float" };
        let model = runtime.load_hlo_text(&artifacts.hlo_path(name))?;
        Ok(DigitalEngine { model, _runtime: runtime, manifest })
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }
}

#[cfg(feature = "xla")]
impl InferenceEngine for DigitalEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let b = self.manifest.batch;
        let d = self.manifest.input;
        let c = self.manifest.classes;
        let mut out = Vec::with_capacity(images.len());
        // The AOT module has a fixed batch dimension: run in chunks,
        // padding the tail with zeros.
        for chunk in images.chunks(b) {
            let mut flat = vec![0.0f32; b * d];
            for (i, img) in chunk.iter().enumerate() {
                anyhow::ensure!(img.len() == d, "image dim {} != {d}", img.len());
                flat[i * d..(i + 1) * d].copy_from_slice(img);
            }
            let logits = self.model.run_f32(&flat, &[b, d])?;
            anyhow::ensure!(logits.len() == b * c, "bad output size {}", logits.len());
            for i in 0..chunk.len() {
                out.push(logits[i * c..(i + 1) * c].to_vec());
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "digital-pjrt"
    }

    fn input_dim(&self) -> usize {
        self.manifest.input
    }
}

/// CiM-simulator-backed analog engine (same trained weights).
///
/// `infer_batch` shards the batch across std worker threads (scoped, one
/// deep model clone per shard). Determinism contract: sample `i` of a
/// batch always draws its analog noise from the per-layer stream
/// `Rng::for_stream(layer_seed, i)` — a pure function of the sample's
/// global index — so logits are bit-identical whether the batch runs on
/// one thread or sixteen, and regardless of shard boundaries.
pub struct AnalogEngine {
    model: Sequential,
    input: usize,
    /// Worker threads for `infer_batch`: 0 = auto (available
    /// parallelism), 1 = in-place sequential (default).
    threads: usize,
    /// Termination counters merged back from worker-shard model clones.
    shard_term: (u64, u64),
    /// Conversion accounting merged back from worker-shard model clones.
    shard_conv: ConversionStats,
    /// Next sample stream offset, advanced per inferred sample so
    /// repeated `infer_batch` calls keep drawing fresh noise.
    next_stream: u64,
}

impl AnalogEngine {
    /// Build from artifacts, executing every BWHT layer on the analog
    /// crossbar simulator with `config` (noise, VDD, clock) and optional
    /// early termination.
    pub fn load(
        artifacts: &Artifacts,
        config: CrossbarConfig,
        early_term: Option<EarlyTermination>,
        input_bits: u8,
        seed: u64,
    ) -> Result<Self> {
        let manifest = artifacts.manifest()?;
        let blob = artifacts.weights()?;
        let mut model = bwht_mlp_from_weights(&manifest, &blob)?;
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog { input_bits, config, early_term, seed, pool: None });
        });
        Ok(AnalogEngine::from_model(model, manifest.input))
    }

    /// Wrap an already-built model (tests, sweeps).
    pub fn from_model(model: Sequential, input: usize) -> Self {
        AnalogEngine {
            model,
            input,
            threads: 1,
            shard_term: (0, 0),
            shard_conv: ConversionStats::default(),
            next_stream: 0,
        }
    }

    /// Set the `infer_batch` worker-thread count (0 = auto-detect).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Serve every BWHT stage through a collaborative digitization pool
    /// (`None` restores the ADC-free 1-bit default). Applies to layers
    /// already in analog exec mode; resets their fabricated engines.
    /// `spec.threads` controls the pool's own per-phase plane fan-out
    /// (`CimArrayPool::process_planes`) and composes with
    /// [`AnalogEngine::with_threads`] batch sharding — both are
    /// thread-count invariant, so logits never depend on either knob.
    /// Validates the spec against each BWHT block's width up front, so
    /// an infeasible resolution is a clean error here instead of an
    /// assertion panic on a serving worker thread mid-batch.
    pub fn with_pool(mut self, pool: Option<PoolSpec>) -> Result<Self> {
        if let Some(spec) = &pool {
            spec.validate().map_err(|e| anyhow::anyhow!("invalid pool spec: {e}"))?;
            let mut narrowest = usize::MAX;
            self.model.for_each_bwht(|b| narrowest = narrowest.min(b.layout().block_size));
            anyhow::ensure!(
                narrowest != usize::MAX,
                "model has no BWHT stage to serve through a pool"
            );
            anyhow::ensure!(
                narrowest >= (1usize << spec.adc_bits),
                "pool adc_bits {} needs 2^bits = {} column lines, but the model's \
                 narrowest BWHT block is only {} wide",
                spec.adc_bits,
                1usize << spec.adc_bits,
                narrowest
            );
        }
        self.model.for_each_bwht(|b| {
            if let BwhtExec::Analog { input_bits, config, early_term, seed, .. } = b.exec {
                b.set_exec(BwhtExec::Analog { input_bits, config, early_term, seed, pool });
            }
        });
        Ok(self)
    }

    /// Access early-termination counters accumulated by the BWHT layers
    /// (including work done by worker-shard clones).
    pub fn termination_stats(&mut self) -> (u64, u64) {
        let mut processed = self.shard_term.0;
        let mut skipped = self.shard_term.1;
        self.model.for_each_bwht(|b| {
            processed += b.term_processed;
            skipped += b.term_skipped;
        });
        (processed, skipped)
    }

    /// Run one sample on `model`, pinning every BWHT layer's analog
    /// noise stream to the sample's global stream id first.
    fn infer_one(
        model: &mut Sequential,
        input: usize,
        img: &[f32],
        stream: u64,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(img.len() == input, "image dim {} != {input}", img.len());
        model.for_each_bwht(|b| b.set_analog_stream(stream));
        Ok(model.forward_inference(&Tensor::vec1(img)).data().to_vec())
    }
}

impl InferenceEngine for AnalogEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let threads = match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            t => t,
        }
        .clamp(1, images.len());
        let stream0 = self.next_stream;
        self.next_stream += images.len() as u64;

        if threads == 1 {
            return images
                .iter()
                .enumerate()
                .map(|(i, img)| {
                    Self::infer_one(&mut self.model, self.input, img, stream0 + i as u64)
                })
                .collect();
        }

        // Contiguous shards, one deep model clone per worker thread.
        // Shard boundaries cannot influence results: every sample's
        // noise stream is derived from its global index alone.
        // Warm the lazily-built analog engines on the prototype first so
        // shard clones copy the fabricated crossbars instead of each
        // re-fabricating them (SignMatrix + comparator sampling) per
        // batch.
        self.model.for_each_bwht(|b| b.prepare_analog());
        let chunk = images.len().div_ceil(threads);
        let input = self.input;
        let model = &self.model;
        let shard_results: Vec<Result<(Vec<Vec<f32>>, u64, u64, ConversionStats)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = images
                    .chunks(chunk)
                    .enumerate()
                    .map(|(shard, shard_images)| {
                        let mut shard_model = model.clone();
                        let first_stream = stream0 + (shard * chunk) as u64;
                        scope.spawn(move || {
                            let mut out = Vec::with_capacity(shard_images.len());
                            for (i, img) in shard_images.iter().enumerate() {
                                out.push(Self::infer_one(
                                    &mut shard_model,
                                    input,
                                    img,
                                    first_stream + i as u64,
                                )?);
                            }
                            let mut processed = 0;
                            let mut skipped = 0;
                            let mut conv = ConversionStats::default();
                            shard_model.for_each_bwht(|b| {
                                processed += b.term_processed;
                                skipped += b.term_skipped;
                                conv.merge(&b.conv_stats);
                            });
                            Ok((out, processed, skipped, conv))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("shard panicked")).collect()
            });

        // Shard clones inherit this model's counters at clone time; only
        // the delta beyond that baseline is work the shard itself did.
        let (base_p, base_s, base_conv) = {
            let mut p = 0;
            let mut s = 0;
            let mut c = ConversionStats::default();
            self.model.for_each_bwht(|b| {
                p += b.term_processed;
                s += b.term_skipped;
                c.merge(&b.conv_stats);
            });
            (p, s, c)
        };
        let mut all = Vec::with_capacity(images.len());
        for res in shard_results {
            let (logits, processed, skipped, conv) = res?;
            self.shard_term.0 += processed - base_p;
            self.shard_term.1 += skipped - base_s;
            self.shard_conv.merge(&conv.minus(&base_conv));
            all.extend(logits);
        }
        Ok(all)
    }

    fn name(&self) -> &'static str {
        "analog-cim"
    }

    fn input_dim(&self) -> usize {
        self.input
    }

    /// Pool digitization accounting: prototype-model layers plus the
    /// merged worker-shard deltas (same baseline discipline as
    /// [`AnalogEngine::termination_stats`]).
    fn conversion_stats(&mut self) -> ConversionStats {
        let mut total = self.shard_conv;
        self.model.for_each_bwht(|b| total.merge(&b.conv_stats));
        total
    }
}

/// Trivial engine for coordinator tests: echoes a one-hot of
/// `image[0] as usize % classes` after an optional simulated delay.
pub struct MockEngine {
    pub classes: usize,
    pub input: usize,
    pub delay: std::time::Duration,
}

impl InferenceEngine for MockEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(images
            .iter()
            .map(|img| {
                let c = (img.first().copied().unwrap_or(0.0) as usize) % self.classes;
                let mut logits = vec![0.0f32; self.classes];
                logits[c] = 1.0;
                logits
            })
            .collect())
    }

    fn name(&self) -> &'static str {
        "mock"
    }

    fn input_dim(&self) -> usize {
        self.input
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_engine_one_hots() {
        let mut e = MockEngine { classes: 4, input: 2, delay: std::time::Duration::ZERO };
        let out = e.infer_batch(&[vec![2.0, 0.0], vec![7.0, 0.0]]).unwrap();
        assert_eq!(out[0][2], 1.0);
        assert_eq!(out[1][3], 1.0); // 7 % 4
    }
}
