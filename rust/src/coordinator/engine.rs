//! Inference engines: what a worker runs a batch on.
//!
//! - [`DigitalEngine`] — the AOT-compiled JAX/Pallas model on PJRT
//!   (digital reference path; exact logits).
//! - [`AnalogEngine`] — the same trained parameters executed through
//!   the CiM crossbar simulator ([`crate::cim`]) at a configurable
//!   operating point: the paper's hardware path, with its quantization
//!   and analog non-idealities.

use anyhow::Result;

use crate::cim::{CrossbarConfig, EarlyTermination};
use crate::nn::bwht_layer::BwhtExec;
use crate::nn::model::bwht_mlp_from_weights;
use crate::nn::{Sequential, Tensor};
use crate::runtime::{Artifacts, LoadedModel, Manifest, Runtime};

/// A batch-inference engine.
pub trait InferenceEngine: Send {
    /// Logits for each image (image length = input dim).
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    fn name(&self) -> &'static str;
    /// Input dimension.
    fn input_dim(&self) -> usize;
}

/// PJRT-backed digital reference engine.
///
/// Owns its *own* PJRT client: the `xla` crate's handles are `Rc`-based
/// (`!Send`), so the only sound way to move an engine into a worker
/// thread is to move the client and every executable referencing it as
/// one unit — which is exactly what this struct is.
pub struct DigitalEngine {
    // Field order matters: `model` must drop before `runtime`.
    model: LoadedModel,
    _runtime: Runtime,
    manifest: Manifest,
}

// SAFETY: all Rc handles into the PJRT client are confined to this
// struct (`_runtime` + `model`); moving the whole struct to another
// thread moves every reference together, and the engine is used by one
// thread at a time (worker ownership). No Rc clone escapes.
unsafe impl Send for DigitalEngine {}

impl DigitalEngine {
    /// Load `model_float.hlo.txt` (or `model_quant.hlo.txt` with
    /// `quant = true`) from an artifacts directory, with a private PJRT
    /// CPU client.
    pub fn load(artifacts: &Artifacts, quant: bool) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let manifest = artifacts.manifest()?;
        let name = if quant { "model_quant" } else { "model_float" };
        let model = runtime.load_hlo_text(&artifacts.hlo_path(name))?;
        Ok(DigitalEngine { model, _runtime: runtime, manifest })
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }
}

impl InferenceEngine for DigitalEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let b = self.manifest.batch;
        let d = self.manifest.input;
        let c = self.manifest.classes;
        let mut out = Vec::with_capacity(images.len());
        // The AOT module has a fixed batch dimension: run in chunks,
        // padding the tail with zeros.
        for chunk in images.chunks(b) {
            let mut flat = vec![0.0f32; b * d];
            for (i, img) in chunk.iter().enumerate() {
                anyhow::ensure!(img.len() == d, "image dim {} != {d}", img.len());
                flat[i * d..(i + 1) * d].copy_from_slice(img);
            }
            let logits = self.model.run_f32(&flat, &[b, d])?;
            anyhow::ensure!(logits.len() == b * c, "bad output size {}", logits.len());
            for i in 0..chunk.len() {
                out.push(logits[i * c..(i + 1) * c].to_vec());
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "digital-pjrt"
    }

    fn input_dim(&self) -> usize {
        self.manifest.input
    }
}

/// CiM-simulator-backed analog engine (same trained weights).
pub struct AnalogEngine {
    model: Sequential,
    input: usize,
}

impl AnalogEngine {
    /// Build from artifacts, executing every BWHT layer on the analog
    /// crossbar simulator with `config` (noise, VDD, clock) and optional
    /// early termination.
    pub fn load(
        artifacts: &Artifacts,
        config: CrossbarConfig,
        early_term: Option<EarlyTermination>,
        input_bits: u8,
        seed: u64,
    ) -> Result<Self> {
        let manifest = artifacts.manifest()?;
        let blob = artifacts.weights()?;
        let mut model = bwht_mlp_from_weights(&manifest, &blob)?;
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog { input_bits, config, early_term, seed });
        });
        Ok(AnalogEngine { model, input: manifest.input })
    }

    /// Wrap an already-built model (tests, sweeps).
    pub fn from_model(model: Sequential, input: usize) -> Self {
        AnalogEngine { model, input }
    }

    /// Access early-termination counters accumulated by the BWHT layers.
    pub fn termination_stats(&mut self) -> (u64, u64) {
        let mut processed = 0;
        let mut skipped = 0;
        self.model.for_each_bwht(|b| {
            processed += b.term_processed;
            skipped += b.term_skipped;
        });
        (processed, skipped)
    }
}

impl InferenceEngine for AnalogEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        images
            .iter()
            .map(|img| {
                anyhow::ensure!(img.len() == self.input, "image dim");
                Ok(self.model.forward(&Tensor::vec1(img)).data().to_vec())
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "analog-cim"
    }

    fn input_dim(&self) -> usize {
        self.input
    }
}

/// Trivial engine for coordinator tests: echoes a one-hot of
/// `image[0] as usize % classes` after an optional simulated delay.
pub struct MockEngine {
    pub classes: usize,
    pub input: usize,
    pub delay: std::time::Duration,
}

impl InferenceEngine for MockEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(images
            .iter()
            .map(|img| {
                let c = (img.first().copied().unwrap_or(0.0) as usize) % self.classes;
                let mut logits = vec![0.0f32; self.classes];
                logits[c] = 1.0;
                logits
            })
            .collect())
    }

    fn name(&self) -> &'static str {
        "mock"
    }

    fn input_dim(&self) -> usize {
        self.input
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_engine_one_hots() {
        let mut e = MockEngine { classes: 4, input: 2, delay: std::time::Duration::ZERO };
        let out = e.infer_batch(&[vec![2.0, 0.0], vec![7.0, 0.0]]).unwrap();
        assert_eq!(out[0][2], 1.0);
        assert_eq!(out[1][3], 1.0); // 7 % 4
    }
}
