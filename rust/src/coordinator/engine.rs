//! Inference engines: what a worker runs a batch on.
//!
//! - [`DigitalEngine`] — the AOT-compiled JAX/Pallas model on PJRT
//!   (digital reference path; exact logits). Gated behind the `xla`
//!   feature: the default offline build serves analog-only.
//! - [`AnalogEngine`] — the same trained parameters executed through
//!   the CiM crossbar simulator ([`crate::cim`]) at a configurable
//!   operating point: the paper's hardware path, with its quantization
//!   and analog non-idealities. Batches shard across a persistent
//!   worker runtime (`util::Executor`, shared with the CiM pool's
//!   plane lanes) with per-sample deterministic noise streams, so
//!   results are identical at any thread count.
//!
//! Compressed serving: workers hand engines [`FramePayload`]s. The
//! default path decodes each [`crate::frontend::CompressedFrame`] to
//! its dense form (bit-exact for lossless frames) and serves as usual;
//! the analog engine additionally folds its first Dense layer into the
//! sequency domain once and serves *lossy* compressed frames straight
//! from their kept coefficients — `O(kept · hidden)` instead of
//! decode + dense matvec, reconstructing nothing.

use std::sync::Arc;

use anyhow::Result;

use crate::cim::{
    ConversionStats, CrossbarConfig, EarlyTermination, FaultPlan, FaultStats, HealthLedger,
    PoolSpec,
};
use crate::frontend::codec::{CodecParams, CompressedFrame, DecodeScratch, LOSSLESS};
use crate::nn::bwht_layer::BwhtExec;
use crate::util::telemetry::RuntimeCounters;
use crate::util::Executor;
use crate::nn::model::bwht_mlp_from_weights;
use crate::nn::{Sequential, Tensor};
use crate::runtime::Artifacts;
#[cfg(feature = "xla")]
use crate::runtime::{LoadedModel, Manifest, Runtime};
use crate::wht::fwht::walsh_to_hadamard_index;
use crate::wht::fwht_inplace;

use super::request::FramePayload;

/// A batch-inference engine.
pub trait InferenceEngine: Send {
    /// Logits for each image (image length = input dim).
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    fn name(&self) -> &'static str;
    /// Input dimension.
    fn input_dim(&self) -> usize;
    /// Cumulative collaborative-digitization accounting (monotone).
    /// Engines without an ADC pool report zeros; the serving loop
    /// records per-batch deltas into [`super::Metrics`].
    fn conversion_stats(&mut self) -> ConversionStats {
        ConversionStats::default()
    }
    /// Cumulative count of samples served through a genuinely
    /// multi-sample forward (the lockstep batched walk, or the AOT
    /// module's fixed-batch call) rather than a per-sample loop —
    /// monotone, like [`InferenceEngine::conversion_stats`]; the
    /// serving loop records per-batch deltas into [`super::Metrics`]
    /// as `samples_fused`. Engines without a batched path report 0.
    fn samples_fused(&mut self) -> u64 {
        0
    }
    /// Cumulative executor/pool runtime counters (monotone): tasks the
    /// engine's worker runtime ran, per-lane busy time, queue-depth
    /// high water, and CiM-pool planes dispatched/fused. The serving
    /// loop records per-batch deltas into [`super::Metrics`]. Engines
    /// without a worker runtime report zeros.
    fn runtime_counters(&mut self) -> RuntimeCounters {
        RuntimeCounters::default()
    }
    /// Cumulative analog fault-injection / self-healing accounting
    /// (monotone, like [`InferenceEngine::conversion_stats`]): faults
    /// activated, probes run/failed, quarantines, degraded planes,
    /// rerouted conversions. All zeros unless a
    /// [`crate::cim::FaultPlan`] is installed — the serving loop
    /// records per-batch deltas into [`super::Metrics`] only when they
    /// are nonzero, so fault-free serving stays byte-identical.
    fn fault_stats(&mut self) -> FaultStats {
        FaultStats::default()
    }
    /// Logits for a batch of raw/compressed frame payloads. The default
    /// decodes every compressed frame to its dense form and defers to
    /// [`InferenceEngine::infer_batch`]; engines with a
    /// transform-domain fast path override ([`AnalogEngine`]).
    fn infer_payloads(&mut self, frames: &[FramePayload]) -> Result<Vec<Vec<f32>>> {
        let images: Vec<Vec<f32>> = frames
            .iter()
            .map(FramePayload::try_to_dense)
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("compressed frame rejected: {e}"))?;
        self.infer_batch(&images)
    }
}

/// PJRT-backed digital reference engine.
///
/// Owns its *own* PJRT client: the `xla` crate's handles are `Rc`-based
/// (`!Send`), so the only sound way to move an engine into a worker
/// thread is to move the client and every executable referencing it as
/// one unit — which is exactly what this struct is.
#[cfg(feature = "xla")]
pub struct DigitalEngine {
    // Field order matters: `model` must drop before `runtime`.
    model: LoadedModel,
    _runtime: Runtime,
    manifest: Manifest,
    /// Flat input staging reused across chunks and batches (the AOT
    /// module has a fixed batch dimension; re-zeroed per chunk).
    flat: Vec<f32>,
    /// Samples served through a multi-sample module call (monotone).
    samples_fused: u64,
}

// SAFETY: all Rc handles into the PJRT client are confined to this
// struct (`_runtime` + `model`); moving the whole struct to another
// thread moves every reference together, and the engine is used by one
// thread at a time (worker ownership). No Rc clone escapes.
#[cfg(feature = "xla")]
unsafe impl Send for DigitalEngine {}

#[cfg(feature = "xla")]
impl DigitalEngine {
    /// Load `model_float.hlo.txt` (or `model_quant.hlo.txt` with
    /// `quant = true`) from an artifacts directory, with a private PJRT
    /// CPU client.
    pub fn load(artifacts: &Artifacts, quant: bool) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let manifest = artifacts.manifest()?;
        let name = if quant { "model_quant" } else { "model_float" };
        let model = runtime.load_hlo_text(&artifacts.hlo_path(name))?;
        Ok(DigitalEngine {
            model,
            _runtime: runtime,
            manifest,
            flat: Vec::new(),
            samples_fused: 0,
        })
    }

    /// The compiled HLO batch dimension.
    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }
}

#[cfg(feature = "xla")]
impl InferenceEngine for DigitalEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let b = self.manifest.batch;
        let d = self.manifest.input;
        let c = self.manifest.classes;
        let mut out = Vec::with_capacity(images.len());
        // The AOT module has a fixed batch dimension: every chunk is
        // already ONE multi-sample module call (the digital twin of the
        // analog engine's lockstep forward) — stage into one reused
        // flat buffer, padding the tail with zeros.
        let mut flat = std::mem::take(&mut self.flat);
        for chunk in images.chunks(b) {
            flat.clear();
            flat.resize(b * d, 0.0);
            for (i, img) in chunk.iter().enumerate() {
                anyhow::ensure!(img.len() == d, "image dim {} != {d}", img.len());
                flat[i * d..(i + 1) * d].copy_from_slice(img);
            }
            let logits = self.model.run_f32(&flat, &[b, d])?;
            anyhow::ensure!(logits.len() == b * c, "bad output size {}", logits.len());
            for i in 0..chunk.len() {
                out.push(logits[i * c..(i + 1) * c].to_vec());
            }
            if chunk.len() > 1 {
                self.samples_fused += chunk.len() as u64;
            }
        }
        self.flat = flat;
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "digital-pjrt"
    }

    fn input_dim(&self) -> usize {
        self.manifest.input
    }

    fn samples_fused(&mut self) -> u64 {
        self.samples_fused
    }
}

/// CiM-simulator-backed analog engine (same trained weights).
///
/// `infer_batch` shards the batch across the engine's **persistent
/// worker runtime** ([`Executor`]: long-lived workers built once per
/// engine lifetime, one deep model clone per shard per batch) — thread
/// spawn is off the per-request path entirely, and each shard executes
/// its whole slice as ONE **lockstep batched forward**
/// (`Sequential::forward_batch_inference`), so a `--fuse-batch` pool
/// receives every sample's blocks in a single submission instead of
/// draining between samples. The same runtime is
/// injected into every BWHT layer's collaborative digitization pool,
/// so `engine_threads × pool_threads` share one set of workers instead
/// of oversubscribing. Determinism contract: sample `i` of a batch
/// always draws its analog noise from the per-layer stream
/// `Rng::for_stream(layer_seed, i)` — a pure function of the sample's
/// global index — so logits are bit-identical whether the batch runs on
/// one thread or sixteen, and regardless of shard boundaries.
pub struct AnalogEngine {
    model: Sequential,
    input: usize,
    /// Worker threads for `infer_batch`: 0 = auto (available
    /// parallelism), 1 = in-place sequential (default).
    threads: usize,
    /// Persistent worker runtime shared by batch shards and pool plane
    /// lanes; built lazily at first parallel use, then reused for the
    /// engine's lifetime.
    executor: Option<Arc<Executor>>,
    /// Termination counters merged back from worker-shard model clones.
    shard_term: (u64, u64),
    /// Conversion accounting merged back from worker-shard model clones.
    shard_conv: ConversionStats,
    /// Pool plane counters (dispatched, fused) merged back from
    /// worker-shard model clones, same baseline discipline as
    /// `shard_conv`.
    shard_planes: (u64, u64),
    /// Fault-injection accounting merged back from worker-shard model
    /// clones, same baseline discipline as `shard_conv`. Stays zero
    /// (and untouched) without an installed fault plan.
    shard_faults: FaultStats,
    /// Next sample stream offset, advanced per inferred sample so
    /// repeated `infer_batch` calls keep drawing fresh noise.
    next_stream: u64,
    /// Decode buffers for the sequential compressed path (shards build
    /// their own).
    decode_scratch: DecodeScratch,
    /// Serve lossy compressed frames transform-domain through the
    /// folded first layer instead of decoding (on by default; lossless
    /// frames always take the bit-exact decode fallback).
    compressed_fast_path: bool,
    /// Lazily folded first Dense layer, keyed by the frame geometry it
    /// was built for.
    folded: Option<(CodecParams, Arc<FoldedFirstLayer>)>,
    /// Serve each shard slice through ONE lockstep batched forward
    /// (default on). Off forces the legacy per-sample loop — the
    /// bit-exactness baseline the equivalence tests compare against.
    lockstep: bool,
    /// Samples served through a multi-sample lockstep forward
    /// (monotone; the serving loop records per-batch deltas).
    samples_fused: u64,
}

/// The first Dense layer folded into the sequency domain.
///
/// A decoded channel is `x_ch = H·h_ch / M` (Hadamard-order scatter of
/// the kept coefficients, inverse transform). For a first layer
/// `y = W·x + b` the per-coefficient fold is
/// `V[ch·M + h][o] = fwht(pad(W_row_chunk))[h] / M`, so serving a
/// compressed frame is `y = b + Σ_kept value · V[col]` — one
/// `hidden`-long axpy per kept coefficient, no reconstruction. Numerics
/// differ from decode-then-matvec by float reassociation only, which is
/// why the fold applies to *lossy* frames (already carrying quantization
/// error) while lossless frames keep the bit-exact decode fallback.
struct FoldedFirstLayer {
    /// Geometry the fold was built for (codec/sensor bits ignored).
    params: CodecParams,
    hidden: usize,
    /// Column-major folded weights: `v[col·hidden .. (col+1)·hidden]`
    /// for coefficient-space column `col = ch·block + hadamard_index`.
    v: Vec<f32>,
    bias: Vec<f32>,
    /// sequency → Hadamard index map for one block.
    had: Vec<u32>,
}

impl FoldedFirstLayer {
    /// Fold `model`'s first layer for `params`' geometry; `None` when
    /// the model does not start with a Dense of the matching input dim.
    fn build(model: &Sequential, input: usize, params: CodecParams) -> Option<Self> {
        if params.dense_len() != input {
            return None;
        }
        let dense = model.first_layer_dense()?;
        if dense.in_dim != input {
            return None;
        }
        let hidden = dense.out_dim;
        let block = params.block();
        let space = params.coeff_space();
        let w = dense.weights();
        let mut v = vec![0.0f32; space * hidden];
        let mut row = vec![0.0f32; block];
        let inv = 1.0 / block as f32;
        for o in 0..hidden {
            for ch in 0..params.channels {
                row.iter_mut().for_each(|x| *x = 0.0);
                let base = o * input + ch * params.samples;
                row[..params.samples].copy_from_slice(&w[base..base + params.samples]);
                fwht_inplace(&mut row);
                for h in 0..block {
                    v[(ch * block + h) * hidden + o] = row[h] * inv;
                }
            }
        }
        let bits = block.trailing_zeros();
        let had = (0..block).map(|s| walsh_to_hadamard_index(s, bits) as u32).collect();
        Some(FoldedFirstLayer { params, hidden, v, bias: dense.bias().to_vec(), had })
    }

    /// Does this fold serve the given frame? Geometry must match and
    /// the frame must be lossy (lossless frames promise bit-exact
    /// serving, which only the decode fallback provides).
    fn matches(&self, cf: &CompressedFrame) -> bool {
        cf.params.codec_bits != LOSSLESS
            && cf.params.channels == self.params.channels
            && cf.params.samples == self.params.samples
    }

    /// Fold one frame's kept coefficients into its layer-1 entry:
    /// `bias + Σ_kept value · V[col]` — one `hidden`-long axpy per kept
    /// coefficient, no reconstruction.
    fn fold(&self, cf: &CompressedFrame) -> Result<Vec<f32>> {
        let mut pre = self.bias.clone();
        let block = self.params.block();
        let hidden = self.hidden;
        cf.try_for_each_coeff(|ch, s, value| {
            let col = ch * block + self.had[s] as usize;
            let wcol = &self.v[col * hidden..(col + 1) * hidden];
            for (p, w) in pre.iter_mut().zip(wcol) {
                *p += value * w;
            }
        })
        .map_err(|e| anyhow::anyhow!("frame {}: {e}", cf.frame_id))?;
        Ok(pre)
    }
}

/// What one worker shard hands back: its slice's logits plus the
/// clone's termination / conversion / pool-plane / fault counters
/// (merged against the prototype baseline by the caller).
type ShardOutcome = (Vec<Vec<f32>>, u64, u64, ConversionStats, (u64, u64), FaultStats);

impl AnalogEngine {
    /// Build from artifacts, executing every BWHT layer on the analog
    /// crossbar simulator with `config` (noise, VDD, clock) and optional
    /// early termination.
    pub fn load(
        artifacts: &Artifacts,
        config: CrossbarConfig,
        early_term: Option<EarlyTermination>,
        input_bits: u8,
        seed: u64,
    ) -> Result<Self> {
        let manifest = artifacts.manifest()?;
        let blob = artifacts.weights()?;
        let mut model = bwht_mlp_from_weights(&manifest, &blob)?;
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog { input_bits, config, early_term, seed, pool: None });
        });
        Ok(AnalogEngine::from_model(model, manifest.input))
    }

    /// Wrap an already-built model (tests, sweeps).
    pub fn from_model(model: Sequential, input: usize) -> Self {
        AnalogEngine {
            model,
            input,
            threads: 1,
            executor: None,
            shard_term: (0, 0),
            shard_conv: ConversionStats::default(),
            shard_planes: (0, 0),
            shard_faults: FaultStats::default(),
            next_stream: 0,
            decode_scratch: DecodeScratch::default(),
            compressed_fast_path: true,
            folded: None,
            lockstep: true,
            samples_fused: 0,
        }
    }

    /// Enable/disable the lockstep batched forward (default on): each
    /// shard slice advances through the model as ONE multi-sample
    /// forward, so `--fuse-batch` pools see every sample's blocks in a
    /// single submission. Off restores the per-sample loop — results
    /// are bit-identical either way (the per-sample stream contract),
    /// which `tests/batched_forward.rs` pins.
    pub fn with_lockstep(mut self, on: bool) -> Self {
        self.lockstep = on;
        self
    }

    /// Set the `infer_batch` worker-thread count (0 = auto-detect).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Enable/disable the transform-domain compressed fast path
    /// (default on). Off forces every compressed frame through the
    /// decode fallback — useful to pin fast-path vs fallback agreement.
    pub fn with_compressed_fast_path(mut self, on: bool) -> Self {
        self.compressed_fast_path = on;
        self
    }

    /// Serve every BWHT stage through a collaborative digitization pool
    /// (`None` restores the ADC-free 1-bit default). Applies to layers
    /// already in analog exec mode; resets their fabricated engines.
    /// `spec.threads` controls the pool's own per-phase plane fan-out
    /// (`CimArrayPool::process_planes`) and composes with
    /// [`AnalogEngine::with_threads`] batch sharding — both draw from
    /// the engine's one persistent runtime and both are thread-count
    /// invariant, so logits never depend on either knob.
    /// `spec.fuse_batch` additionally turns on plane fusion inside
    /// each BWHT layer — with the lockstep batched forward (default)
    /// ALL samples of a shard slice share one pool submission; with
    /// [`AnalogEngine::with_lockstep`] off, fusion still spans each
    /// sample's Hadamard blocks (bit-identical either way).
    /// Validates the spec against each BWHT block's width up front, so
    /// an infeasible resolution is a clean error here instead of an
    /// assertion panic on a serving worker thread mid-batch.
    pub fn with_pool(mut self, pool: Option<PoolSpec>) -> Result<Self> {
        if let Some(spec) = &pool {
            spec.validate().map_err(|e| anyhow::anyhow!("invalid pool spec: {e}"))?;
            let mut narrowest = usize::MAX;
            self.model.for_each_bwht(|b| narrowest = narrowest.min(b.layout().block_size));
            anyhow::ensure!(
                narrowest != usize::MAX,
                "model has no BWHT stage to serve through a pool"
            );
            anyhow::ensure!(
                narrowest >= (1usize << spec.adc_bits),
                "pool adc_bits {} needs 2^bits = {} column lines, but the model's \
                 narrowest BWHT block is only {} wide",
                spec.adc_bits,
                1usize << spec.adc_bits,
                narrowest
            );
        }
        self.model.for_each_bwht(|b| {
            if let BwhtExec::Analog { input_bits, config, early_term, seed, .. } = b.exec {
                b.set_exec(BwhtExec::Analog { input_bits, config, early_term, seed, pool });
            }
        });
        Ok(self)
    }

    /// Install (or clear) an analog fault-injection plan on every BWHT
    /// stage's digitization pool (`None` restores fault-free serving).
    /// The plan's fault indices are validated against each pool's
    /// geometry **here** — the layers' pools are built eagerly first —
    /// so an out-of-range array or group is a clean error at engine
    /// construction instead of a panic on a serving worker mid-batch.
    /// Requires the pool to be configured first
    /// ([`AnalogEngine::with_pool`]); without a pool the plan has
    /// nothing to fault and this is a clean error too.
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> Result<Self> {
        if plan.is_some() {
            let mut pooled = false;
            self.model.for_each_bwht(|b| {
                pooled |= matches!(b.exec, BwhtExec::Analog { pool: Some(_), .. });
            });
            anyhow::ensure!(
                pooled,
                "a fault plan targets the digitization pool; configure one first"
            );
        }
        let mut err: Option<String> = None;
        self.model.for_each_bwht(|b| {
            b.prepare_analog();
            if let Err(e) = b.set_fault_plan(plan.clone()) {
                err.get_or_insert(e);
            }
        });
        if let Some(e) = err {
            anyhow::bail!("invalid fault plan: {e}");
        }
        Ok(self)
    }

    /// Visit the health ledger of every pooled BWHT stage carrying an
    /// installed fault layer. Reads the prototype model — the state
    /// single-threaded serving mutates in place; worker-shard clones
    /// replay the same slot-pure timeline, so their ledgers agree.
    pub fn for_each_health(&mut self, mut f: impl FnMut(&HealthLedger)) {
        self.model.for_each_bwht(|b| {
            if let Some(h) = b.health() {
                f(h);
            }
        });
    }

    /// Access early-termination counters accumulated by the BWHT layers
    /// (including work done by worker-shard clones).
    pub fn termination_stats(&mut self) -> (u64, u64) {
        let mut processed = self.shard_term.0;
        let mut skipped = self.shard_term.1;
        self.model.for_each_bwht(|b| {
            processed += b.term_processed;
            skipped += b.term_skipped;
        });
        (processed, skipped)
    }

    /// Run one sample on `model`, pinning every BWHT layer's analog
    /// noise stream to the sample's global stream id first.
    fn infer_one(
        model: &mut Sequential,
        input: usize,
        img: &[f32],
        stream: u64,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(img.len() == input, "image dim {} != {input}", img.len());
        model.for_each_bwht(|b| b.set_analog_stream(stream));
        Ok(model.forward_inference(&Tensor::vec1(img)).data().to_vec())
    }

    /// Serve one compressed frame transform-domain: fold the kept
    /// coefficients through the pre-built first layer, then run the
    /// remaining layers as usual (stream pinned like [`Self::infer_one`],
    /// so analog noise is identical either way).
    fn infer_folded(
        model: &mut Sequential,
        folded: &FoldedFirstLayer,
        cf: &CompressedFrame,
        stream: u64,
    ) -> Result<Vec<f32>> {
        model.for_each_bwht(|b| b.set_analog_stream(stream));
        let mut cur = Tensor::vec1(&folded.fold(cf)?);
        for l in model.layers_mut()[1..].iter_mut() {
            cur = l.forward_inference(&cur);
        }
        Ok(cur.data().to_vec())
    }

    /// The folded first layer to serve `frames` with, if the fast path
    /// is on, some frame is lossy-compressed, and the model starts with
    /// a matching Dense (cached per geometry).
    fn folded_for(&mut self, frames: &[FramePayload]) -> Option<Arc<FoldedFirstLayer>> {
        if !self.compressed_fast_path {
            return None;
        }
        let params = frames.iter().find_map(|p| match p {
            FramePayload::Compressed(cf) if cf.params.codec_bits != LOSSLESS => Some(cf.params),
            _ => None,
        })?;
        if let Some((cached, f)) = &self.folded {
            if cached.channels == params.channels && cached.samples == params.samples {
                return Some(f.clone());
            }
        }
        let f = Arc::new(FoldedFirstLayer::build(&self.model, self.input, params)?);
        self.folded = Some((params, f.clone()));
        Some(f)
    }

    /// Widest pool plane fan-out any BWHT layer asks for (resolved via
    /// the shared `0 = auto` policy, capped by the pool's array count
    /// — it can never have more coupling-group lanes than arrays;
    /// 1 = no pool parallelism).
    fn max_pool_lanes(&mut self) -> usize {
        let mut lanes = 1usize;
        self.model.for_each_bwht(|b| {
            if let BwhtExec::Analog { pool: Some(spec), .. } = b.exec {
                let t = crate::util::executor::resolve_lanes(spec.threads);
                lanes = lanes.max(t.min(spec.n_arrays.max(1)));
            }
        });
        lanes
    }

    /// The engine's persistent worker runtime, built at first parallel
    /// use (and widened if a later configuration asks for more lanes) —
    /// the once-per-server-lifetime thread spawn.
    fn ensure_executor(&mut self, lanes: usize) -> Arc<Executor> {
        let rebuild = match &self.executor {
            Some(e) => e.lanes() < lanes,
            None => true,
        };
        if rebuild {
            self.executor = Some(Arc::new(Executor::new(lanes)));
        }
        self.executor.as_ref().expect("executor just ensured").clone()
    }

    /// The persistent runtime, if one has been built yet.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        self.executor.as_ref()
    }

    /// Shard `items` across the persistent worker runtime (inline when
    /// `threads == 1`), running `run` once per **shard slice** with the
    /// slice's first global stream id — the engine's one batch loop,
    /// shared by the raw and payload paths. Since PR 7 a shard is no
    /// longer a per-item loop: `run` sees the whole slice and (with
    /// lockstep on) executes it as ONE multi-sample forward, so
    /// `--fuse-batch` pools receive every sample's blocks together.
    /// Per-shard termination/conversion counters merge back against the
    /// prototype baseline exactly as before; results are thread-count
    /// invariant by the per-sample stream contract (sample `i`'s noise
    /// is a pure function of `stream0 + i`, never of slice boundaries).
    /// One runtime serves both the batch shards submitted here and the
    /// pool plane lanes the shards submit from inside (nested-safe by
    /// the executor's caller-participation), so `engine_threads ×
    /// pool_threads` never oversubscribes the machine.
    fn infer_sharded<T, F>(&mut self, items: &[T], run: F) -> Result<Vec<Vec<f32>>>
    where
        T: Sync,
        F: Fn(&mut Sequential, &mut DecodeScratch, &[T], u64) -> Result<Vec<Vec<f32>>> + Sync,
    {
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let threads = crate::util::executor::resolve_lanes(self.threads).clamp(1, items.len());
        let pool_lanes = self.max_pool_lanes();
        let stream0 = self.next_stream;
        self.next_stream += items.len() as u64;

        if threads == 1 {
            // One slice — the whole batch; pools may still fan planes
            // out, so hand them the engine runtime (sized for their
            // lanes) once instead of letting each build its own.
            if pool_lanes > 1 {
                let exec = self.ensure_executor(pool_lanes);
                self.model.for_each_bwht(|b| b.set_executor(Some(exec.clone())));
            }
            let mut scratch = std::mem::take(&mut self.decode_scratch);
            let out = run(&mut self.model, &mut scratch, items, stream0);
            self.decode_scratch = scratch;
            let out = out?;
            anyhow::ensure!(
                out.len() == items.len(),
                "engine returned {} results for {} items",
                out.len(),
                items.len()
            );
            if self.lockstep && items.len() > 1 {
                self.samples_fused += items.len() as u64;
            }
            return Ok(out);
        }

        // Contiguous shards, one deep model clone per runtime task.
        // Shard boundaries cannot influence results: every sample's
        // noise stream is derived from its global index alone.
        // Warm the lazily-built analog engines on the prototype first so
        // shard clones copy the fabricated crossbars instead of each
        // re-fabricating them (SignMatrix + comparator sampling) per
        // batch — and inject the shared runtime before cloning so every
        // shard's pool submits lanes to the same workers instead of
        // spawning private ones per batch.
        let exec = self.ensure_executor(threads.max(pool_lanes));
        self.model.for_each_bwht(|b| {
            b.set_executor(Some(exec.clone()));
            b.prepare_analog();
        });
        let chunk = items.len().div_ceil(threads);
        let model = &self.model;
        let run = &run;
        let mut tasks = Vec::with_capacity(items.len().div_ceil(chunk));
        for (shard, shard_items) in items.chunks(chunk).enumerate() {
            let mut shard_model = model.clone();
            let first_stream = stream0 + (shard * chunk) as u64;
            tasks.push(move || -> Result<ShardOutcome> {
                let mut scratch = DecodeScratch::default();
                let out = run(&mut shard_model, &mut scratch, shard_items, first_stream)?;
                anyhow::ensure!(
                    out.len() == shard_items.len(),
                    "engine returned {} results for {} items",
                    out.len(),
                    shard_items.len()
                );
                let mut processed = 0;
                let mut skipped = 0;
                let mut conv = ConversionStats::default();
                let mut planes = (0u64, 0u64);
                let mut faults = FaultStats::default();
                shard_model.for_each_bwht(|b| {
                    processed += b.term_processed;
                    skipped += b.term_skipped;
                    conv.merge(&b.conv_stats);
                    let (pd, pf) = b.pool_planes();
                    planes.0 += pd;
                    planes.1 += pf;
                    faults.merge(&b.fault_stats());
                });
                Ok((out, processed, skipped, conv, planes, faults))
            });
        }
        let shard_results: Vec<Result<ShardOutcome>> = exec.run(tasks);

        // Shard clones inherit this model's counters at clone time; only
        // the delta beyond that baseline is work the shard itself did.
        let (base_p, base_s, base_conv, base_planes, base_faults) = {
            let mut p = 0;
            let mut s = 0;
            let mut c = ConversionStats::default();
            let mut pl = (0u64, 0u64);
            let mut f = FaultStats::default();
            self.model.for_each_bwht(|b| {
                p += b.term_processed;
                s += b.term_skipped;
                c.merge(&b.conv_stats);
                let (pd, pf) = b.pool_planes();
                pl.0 += pd;
                pl.1 += pf;
                f.merge(&b.fault_stats());
            });
            (p, s, c, pl, f)
        };
        let mut all = Vec::with_capacity(items.len());
        for res in shard_results {
            let (logits, processed, skipped, conv, planes, faults) = res?;
            self.shard_term.0 += processed - base_p;
            self.shard_term.1 += skipped - base_s;
            self.shard_conv.merge(&conv.minus(&base_conv));
            self.shard_planes.0 += planes.0 - base_planes.0;
            self.shard_planes.1 += planes.1 - base_planes.1;
            self.shard_faults.merge(&faults.minus(&base_faults));
            all.extend(logits);
        }
        if self.lockstep {
            for shard_items in items.chunks(chunk) {
                if shard_items.len() > 1 {
                    self.samples_fused += shard_items.len() as u64;
                }
            }
        }
        Ok(all)
    }

    /// Forward one slice of raw images. With lockstep on and more than
    /// one image, this is ONE multi-sample forward: per-sample streams
    /// are pinned first, then every layer advances the whole slice
    /// together (`Sequential::forward_batch_inference`), which is what
    /// lets `--fuse-batch` pools span sample boundaries.
    fn forward_images(
        model: &mut Sequential,
        input: usize,
        imgs: &[Vec<f32>],
        first_stream: u64,
        lockstep: bool,
    ) -> Result<Vec<Vec<f32>>> {
        if !lockstep || imgs.len() == 1 {
            return imgs
                .iter()
                .enumerate()
                .map(|(i, img)| Self::infer_one(model, input, img, first_stream + i as u64))
                .collect();
        }
        for img in imgs {
            anyhow::ensure!(img.len() == input, "image dim {} != {input}", img.len());
        }
        let streams: Vec<u64> = (0..imgs.len() as u64).map(|i| first_stream + i).collect();
        model.for_each_bwht(|b| b.set_analog_streams(streams.clone()));
        let xs: Vec<Tensor> = imgs.iter().map(|v| Tensor::vec1(v)).collect();
        Ok(model
            .forward_batch_inference(&xs)
            .into_iter()
            .map(|t| t.data().to_vec())
            .collect())
    }

    /// Lockstep forward for one slice of mixed payloads. Every sample's
    /// layer-1 entry is computed first, in sample order — folded lossy
    /// frames via the transform-domain fold, everything else (raw
    /// frames, lossless frames, geometry mismatches) through the
    /// batched first layer on its decoded dense form — then the
    /// remaining layers walk the whole slice together. Sample-order
    /// entries keep the analog stream consumption and ConversionStats
    /// merge order identical to the per-sample loop, so logits and
    /// accounting are bit-identical to it.
    fn forward_payload_slice(
        model: &mut Sequential,
        scratch: &mut DecodeScratch,
        input: usize,
        folded: Option<&FoldedFirstLayer>,
        slice: &[FramePayload],
        first_stream: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let streams: Vec<u64> = (0..slice.len() as u64).map(|i| first_stream + i).collect();
        let folds: Vec<Option<&CompressedFrame>> = slice
            .iter()
            .map(|p| match p {
                FramePayload::Compressed(cf) if folded.is_some_and(|f| f.matches(cf)) => {
                    Some(cf)
                }
                _ => None,
            })
            .collect();

        // Dense a payload that the fold does not serve.
        let to_dense = |payload: &FramePayload, scratch: &mut DecodeScratch| -> Result<Tensor> {
            let dense: &[f32] = match payload {
                FramePayload::Raw(img) => img,
                FramePayload::Compressed(cf) => scratch
                    .try_decode(cf)
                    .map_err(|e| anyhow::anyhow!("frame {}: {e}", cf.frame_id))?,
            };
            anyhow::ensure!(dense.len() == input, "image dim {} != {input}", dense.len());
            Ok(Tensor::vec1(dense))
        };

        if folds.iter().all(Option::is_none) {
            // Uniform slice — no folded entries: lockstep from layer 0.
            let mut xs = Vec::with_capacity(slice.len());
            for payload in slice {
                xs.push(to_dense(payload, scratch)?);
            }
            model.for_each_bwht(|b| b.set_analog_streams(streams.clone()));
            return Ok(model
                .forward_batch_inference(&xs)
                .into_iter()
                .map(|t| t.data().to_vec())
                .collect());
        }
        let folded = folded.expect("a fold matched, so a fold exists");

        // Mixed slice: batched first layer for the dense subset…
        let mut dense_in = Vec::new();
        let mut dense_pos = Vec::new();
        for (i, payload) in slice.iter().enumerate() {
            if folds[i].is_some() {
                continue;
            }
            dense_pos.push(i);
            dense_in.push(to_dense(payload, scratch)?);
        }
        let mut entries: Vec<Option<Tensor>> = vec![None; slice.len()];
        if !dense_in.is_empty() {
            let (first, _) =
                model.layers_mut().split_first_mut().expect("fold implies a first layer");
            for (pos, y) in dense_pos.iter().zip(first.forward_batch_inference(&dense_in)) {
                entries[*pos] = Some(y);
            }
        }
        // …folded entries for the rest…
        for (i, cf) in folds.iter().enumerate() {
            let Some(cf) = cf else { continue };
            entries[i] = Some(Tensor::vec1(&folded.fold(cf)?));
        }
        // …then ONE lockstep walk of the remaining layers.
        model.for_each_bwht(|b| b.set_analog_streams(streams.clone()));
        let mut cur: Vec<Tensor> =
            entries.into_iter().map(|e| e.expect("every sample has an entry")).collect();
        for l in model.layers_mut()[1..].iter_mut() {
            cur = l.forward_batch_inference(&cur);
        }
        Ok(cur.into_iter().map(|t| t.data().to_vec()).collect())
    }
}

impl InferenceEngine for AnalogEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let input = self.input;
        let lockstep = self.lockstep;
        self.infer_sharded(images, move |model, _scratch, slice, first_stream| {
            Self::forward_images(model, input, slice, first_stream, lockstep)
        })
    }

    /// Compressed-domain serving: lossy frames take the folded fast
    /// path (when the model starts with a matching Dense), everything
    /// else — raw frames and lossless compressed frames — goes through
    /// the zero-alloc decode fallback, which is bit-exact vs raw
    /// serving at zero compression. With lockstep on (default), every
    /// shard slice is served by ONE multi-sample forward: folded
    /// entries enter at layer 1 next to the dense subset's batched
    /// first-layer outputs ([`AnalogEngine::forward_payload_slice`]).
    fn infer_payloads(&mut self, frames: &[FramePayload]) -> Result<Vec<Vec<f32>>> {
        let input = self.input;
        let folded = self.folded_for(frames);
        let lockstep = self.lockstep;
        self.infer_sharded(frames, move |model, scratch, slice, first_stream| {
            if lockstep && slice.len() > 1 {
                return Self::forward_payload_slice(
                    model,
                    scratch,
                    input,
                    folded.as_deref(),
                    slice,
                    first_stream,
                );
            }
            slice
                .iter()
                .enumerate()
                .map(|(i, payload)| {
                    let stream = first_stream + i as u64;
                    match payload {
                        FramePayload::Raw(img) => Self::infer_one(model, input, img, stream),
                        FramePayload::Compressed(cf) => {
                            if let Some(f) = folded.as_deref() {
                                if f.matches(cf) {
                                    return Self::infer_folded(model, f, cf, stream);
                                }
                            }
                            let dense = scratch
                                .try_decode(cf)
                                .map_err(|e| anyhow::anyhow!("frame {}: {e}", cf.frame_id))?;
                            Self::infer_one(model, input, dense, stream)
                        }
                    }
                })
                .collect()
        })
    }

    fn name(&self) -> &'static str {
        "analog-cim"
    }

    fn input_dim(&self) -> usize {
        self.input
    }

    /// Pool digitization accounting: prototype-model layers plus the
    /// merged worker-shard deltas (same baseline discipline as
    /// [`AnalogEngine::termination_stats`]).
    fn conversion_stats(&mut self) -> ConversionStats {
        let mut total = self.shard_conv;
        self.model.for_each_bwht(|b| total.merge(&b.conv_stats));
        total
    }

    fn samples_fused(&mut self) -> u64 {
        self.samples_fused
    }

    /// Fault-injection accounting: prototype-model layers plus the
    /// merged worker-shard deltas (same baseline discipline as
    /// [`AnalogEngine::conversion_stats`]). Zeros without a plan.
    fn fault_stats(&mut self) -> FaultStats {
        let mut total = self.shard_faults;
        self.model.for_each_bwht(|b| total.merge(&b.fault_stats()));
        total
    }

    /// Executor runtime counters plus CiM-pool plane accounting:
    /// prototype-model layers plus the merged worker-shard deltas
    /// (same baseline discipline as the conversion stats).
    fn runtime_counters(&mut self) -> RuntimeCounters {
        let mut rc = match &self.executor {
            Some(e) => RuntimeCounters::from_executor(&e.stats()),
            None => RuntimeCounters::default(),
        };
        let mut planes = self.shard_planes;
        self.model.for_each_bwht(|b| {
            let (pd, pf) = b.pool_planes();
            planes.0 += pd;
            planes.1 += pf;
        });
        rc.planes_dispatched = planes.0;
        rc.planes_fused = planes.1;
        rc
    }
}

/// Trivial engine for coordinator tests: echoes a one-hot of
/// `image[0] as usize % classes` after an optional simulated delay.
pub struct MockEngine {
    /// Classes in the one-hot echo.
    pub classes: usize,
    /// Declared input dimension.
    pub input: usize,
    /// Simulated per-batch inference latency.
    pub delay: std::time::Duration,
}

impl InferenceEngine for MockEngine {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(images
            .iter()
            .map(|img| {
                let c = (img.first().copied().unwrap_or(0.0) as usize) % self.classes;
                let mut logits = vec![0.0f32; self.classes];
                logits[c] = 1.0;
                logits
            })
            .collect())
    }

    fn name(&self) -> &'static str {
        "mock"
    }

    fn input_dim(&self) -> usize {
        self.input
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::encoder::{FrameEncoder, Selection};
    use crate::nn::model::bwht_mlp;
    use crate::util::Rng;

    #[test]
    fn mock_engine_one_hots() {
        let mut e = MockEngine { classes: 4, input: 2, delay: std::time::Duration::ZERO };
        let out = e.infer_batch(&[vec![2.0, 0.0], vec![7.0, 0.0]]).unwrap();
        assert_eq!(out[0][2], 1.0);
        assert_eq!(out[1][3], 1.0); // 7 % 4
    }

    /// The trait's default payload path decodes and defers to
    /// `infer_batch` — exercised through the mock.
    #[test]
    fn default_payload_path_decodes_for_plain_engines() {
        let params = CodecParams::new(1, 4, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        let mut e = MockEngine { classes: 8, input: 4, delay: std::time::Duration::ZERO };
        // Mock classifies image[0]; 1.0 survives the lossless round trip.
        let cf = enc.encode(&[1.0, 0.25, 0.5, 0.75], 0);
        let out = e
            .infer_payloads(&[
                FramePayload::Raw(vec![3.0, 0.0, 0.0, 0.0]),
                FramePayload::Compressed(cf),
            ])
            .unwrap();
        assert_eq!(out[0][3], 1.0);
        assert_eq!(out[1][1], 1.0);
    }

    fn analog_digit_engine(seed: u64) -> AnalogEngine {
        let mut rng = Rng::new(seed);
        let mut model = bwht_mlp(64, 4, 16, &mut rng);
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 42,
                pool: None,
            })
        });
        AnalogEngine::from_model(model, 64)
    }

    fn frames(n: usize) -> Vec<Vec<f32>> {
        (0..n).map(|i| (0..64).map(|j| ((i * j + 3 * i) % 9) as f32 / 9.0).collect()).collect()
    }

    /// Lossless compressed payloads serve bit-identically to their
    /// (snapped) raw frames — the decode fallback's exactness contract,
    /// analog noise streams included.
    #[test]
    fn lossless_payload_serving_is_bit_exact_vs_raw() {
        let params = CodecParams::new(1, 64, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        let imgs = frames(6);
        let snapped: Vec<Vec<f32>> =
            imgs.iter().map(|f| f.iter().map(|&v| params.snap(v)).collect()).collect();
        let payloads: Vec<FramePayload> = imgs
            .iter()
            .enumerate()
            .map(|(i, f)| FramePayload::Compressed(enc.encode(f, i as u64)))
            .collect();
        let mut raw_engine = analog_digit_engine(1);
        let want = raw_engine.infer_batch(&snapped).unwrap();
        let mut c_engine = analog_digit_engine(1);
        let got = c_engine.infer_payloads(&payloads).unwrap();
        assert_eq!(got, want, "zero-compression serving must be bit-exact");
    }

    /// The folded transform-domain fast path agrees with the decode
    /// fallback on lossy frames up to float reassociation.
    #[test]
    fn folded_fast_path_tracks_decode_fallback() {
        let params = CodecParams::new(1, 64, 8, 8).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::TopK(24));
        let payloads: Vec<FramePayload> = frames(6)
            .iter()
            .enumerate()
            .map(|(i, f)| FramePayload::Compressed(enc.encode(f, i as u64)))
            .collect();
        let mut fast = analog_digit_engine(1);
        let mut slow = analog_digit_engine(1).with_compressed_fast_path(false);
        let a = fast.infer_payloads(&payloads).unwrap();
        let b = slow.infer_payloads(&payloads).unwrap();
        for (i, (la, lb)) in a.iter().zip(&b).enumerate() {
            for (x, y) in la.iter().zip(lb) {
                assert!(
                    (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                    "sample {i}: folded {x} vs decoded {y}"
                );
            }
        }
    }

    /// Payload batches are worker-thread-count invariant like raw ones.
    #[test]
    fn payload_serving_is_thread_count_invariant() {
        let params = CodecParams::new(1, 64, 8, 6).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::TopK(16));
        let payloads: Vec<FramePayload> = frames(9)
            .iter()
            .enumerate()
            .map(|(i, f)| FramePayload::Compressed(enc.encode(f, i as u64)))
            .collect();
        let mut base = analog_digit_engine(1);
        let want = base.infer_payloads(&payloads).unwrap();
        for threads in [2usize, 4] {
            let mut e = analog_digit_engine(1).with_threads(threads);
            let got = e.infer_payloads(&payloads).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }
}
