//! Serving metrics: latency distribution, throughput, batch shapes.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Moments;

/// Shared metrics (interior mutability; cheap enough off the hot loop).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latency_us: Moments,
    batch_size: Moments,
    completed: u64,
    errors: u64,
    latencies: Vec<f64>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub errors: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p95_latency_us: f64,
    pub max_latency_us: f64,
    pub mean_batch: f64,
    pub throughput_per_s: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_batch(&self, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        g.batch_size.push(batch_size as f64);
    }

    pub fn record_completion(&self, latency_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.latency_us.push(latency_us as f64);
        g.latencies.push(latency_us as f64);
        g.completed += 1;
        g.finished = Some(Instant::now());
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut sorted = g.latencies.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            if sorted.is_empty() {
                0.0
            } else {
                crate::util::stats::percentile_sorted(&sorted, p)
            }
        };
        let wall = match (g.started, g.finished) {
            (Some(s), Some(f)) if f > s => f.duration_since(s).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            completed: g.completed,
            errors: g.errors,
            mean_latency_us: g.latency_us.mean(),
            p50_latency_us: pct(50.0),
            p95_latency_us: pct(95.0),
            max_latency_us: g.latency_us.max(),
            mean_batch: g.batch_size.mean(),
            throughput_per_s: if wall > 0.0 { g.completed as f64 / wall } else { 0.0 },
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "completed={} errors={} p50={:.0}µs p95={:.0}µs mean={:.0}µs batch={:.1} rate={:.0}/s",
            self.completed,
            self.errors,
            self.p50_latency_us,
            self.p95_latency_us,
            self.mean_latency_us,
            self.mean_batch,
            self.throughput_per_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(4);
        for lat in [100u64, 200, 300, 400] {
            m.record_completion(lat);
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.completed, 4);
        assert_eq!(s.errors, 1);
        assert!((s.mean_latency_us - 250.0).abs() < 1e-9);
        assert_eq!(s.max_latency_us, 400.0);
        assert_eq!(s.mean_batch, 4.0);
        assert!(s.p95_latency_us >= s.p50_latency_us);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_latency_us, 0.0);
    }
}
