//! Serving metrics: latency distribution, throughput, batch shapes,
//! per-stage latency spans (queue-wait / batch-wait / service, from
//! [`RequestTrace`](crate::util::telemetry::RequestTrace) stamps),
//! executor/pool runtime counters, collaborative-digitization
//! accounting (conversions, comparator decisions, cycles and fJ from
//! the CiM array pool, per request), and the ingest frontend's
//! deluge-triage counters ([`crate::frontend::FrontendStats`]).
//!
//! All latency distributions live in fixed-size log-bucketed
//! histograms ([`LatencyHistogram`]) — constant memory however long
//! the run, ≤1% percentile quantization — so the periodic telemetry
//! exporter can snapshot at any cadence without the old
//! clone-and-sort-every-latency cost.

use std::sync::Mutex;
use std::time::Instant;

use crate::cim::{ConversionStats, FaultStats};
use crate::frontend::FrontendStats;
use crate::util::stats::Moments;
use crate::util::telemetry::{
    LatencyHistogram, RuntimeCounters, StageBreakdown, StageSample, StageStats,
};

/// Shared metrics (interior mutability; cheap enough off the hot loop).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Served-batch-size histogram bucket upper bounds (last bucket is
/// everything above). Powers of two: the axis `--batch` is tuned on.
pub const BATCH_BUCKET_BOUNDS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Bucket index for a served batch of `n` requests.
fn batch_bucket(n: usize) -> usize {
    BATCH_BUCKET_BOUNDS
        .iter()
        .position(|&b| n <= b)
        .unwrap_or(BATCH_BUCKET_BOUNDS.len())
}

/// Number of QoS classes tracked per-priority-band
/// (`priority >> 6`: Drop band, low/high Summarize, Keep band).
pub const QOS_CLASSES: usize = 4;

/// Completions the rolling-latency window holds for the adaptive
/// batcher's p99 feedback signal ([`Metrics::recent_p99_us`]).
pub const RECENT_LATENCY_WINDOW: usize = 256;

/// Live state of the adaptive batch closer, mirrored into the snapshot
/// so the serving summary shows where the knobs settled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptiveSnapshot {
    /// Effective batch-size cap after adaptation.
    pub eff_batch: usize,
    /// Effective close deadline (µs) after adaptation.
    pub eff_deadline_us: u64,
    /// Windows that changed at least one knob.
    pub adaptations: u64,
}

#[derive(Debug, Default)]
struct Inner {
    latency_us: Moments,
    batch_size: Moments,
    /// Served-batch-size histogram (dispatched batches per bucket).
    batch_hist: [u64; BATCH_BUCKET_BOUNDS.len() + 1],
    completed: u64,
    errors: u64,
    rejected_queue_full: u64,
    rejected_malformed: u64,
    panics_isolated: u64,
    /// Samples the engines served through a genuinely multi-sample
    /// forward (lockstep batched walk / fixed-batch module call).
    samples_fused: u64,
    /// End-to-end latency distribution (bounded log-bucketed buckets;
    /// the unbounded per-completion `Vec` this replaced grew without
    /// limit and cost a clone+sort per snapshot).
    latency_hist: LatencyHistogram,
    /// Queue-wait stage distribution (admission → batch seal).
    stage_queue: LatencyHistogram,
    /// Batch-wait stage distribution (batch seal → engine start).
    stage_batch: LatencyHistogram,
    /// Service stage distribution (engine start → engine end).
    stage_service: LatencyHistogram,
    /// Accumulated executor/pool runtime counters (per-batch deltas
    /// folded in by the serving workers).
    runtime: RuntimeCounters,
    /// Rolling window of the most recent completion latencies (ring
    /// buffer) — the adaptive batcher's p99 feedback signal.
    recent_latency: Vec<f64>,
    recent_idx: usize,
    /// Admissions per QoS class (`priority >> 6`).
    qos_admitted: [u64; QOS_CLASSES],
    /// Graduated sheds per QoS class.
    qos_shed: [u64; QOS_CLASSES],
    /// Latest adaptive-batcher knob state, if adaptive close is on.
    adaptive: Option<AdaptiveSnapshot>,
    /// Start of the throughput window: the first recorded metrics
    /// event of any kind — admission, shed, malformed reject, batch or
    /// completion — so overload runs that shed before the first batch
    /// seal still measure their full wall time.
    started: Option<Instant>,
    finished: Option<Instant>,
    conv: ConversionStats,
    frontend: FrontendStats,
    /// Accumulated fault-injection / self-healing counters (per-batch
    /// deltas folded in by the serving workers; all zero without an
    /// installed [`crate::cim::FaultPlan`]).
    faults: FaultStats,
    /// Workers abandoned at shutdown because they outlived the
    /// configured join deadline (detached, not joined).
    shutdown_forced: u64,
}

/// Snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Requests answered (served or degraded).
    pub completed: u64,
    /// Requests answered with an engine-error failure response.
    pub errors: u64,
    /// Requests refused at the door by backpressure (queue full).
    pub rejected_queue_full: u64,
    /// Wire frames refused at the validated ingest boundary
    /// (`CodecError` from `submit_wire`).
    pub rejected_malformed: u64,
    /// Requests whose engine panicked; the worker caught the unwind and
    /// answered with a failure response instead of dying.
    pub panics_isolated: u64,
    /// Requests that got a degraded (failure) response instead of
    /// logits: engine errors + isolated panics.
    pub degraded: u64,
    /// Mean end-to-end latency (µs).
    pub mean_latency_us: f64,
    /// Median end-to-end latency (µs).
    pub p50_latency_us: f64,
    /// 95th-percentile end-to-end latency (µs).
    pub p95_latency_us: f64,
    /// 99th-percentile end-to-end latency (µs).
    pub p99_latency_us: f64,
    /// Worst observed end-to-end latency (µs).
    pub max_latency_us: f64,
    /// Mean dispatched batch size.
    pub mean_batch: f64,
    /// Served-batch-size histogram: dispatched batches whose size fell
    /// in each [`BATCH_BUCKET_BOUNDS`] bucket (last = above the top
    /// bound) — how well the batcher is actually filling batches.
    pub batch_hist: [u64; BATCH_BUCKET_BOUNDS.len() + 1],
    /// Samples served through a genuinely multi-sample engine forward
    /// (the lockstep batched walk): the fusion the batcher's batches
    /// actually bought, next to `mean_batch` which only measures what
    /// was dispatched.
    pub samples_fused: u64,
    /// Completions per wall-clock second over the run.
    pub throughput_per_s: f64,
    /// Admissions per QoS class (`priority >> 6`; class 3 = Keep band).
    pub qos_admitted: [u64; QOS_CLASSES],
    /// Graduated sheds per QoS class — which traffic the admission ramp
    /// actually refused under load.
    pub qos_shed: [u64; QOS_CLASSES],
    /// Adaptive batch-closer knob state (`None` when serving with the
    /// static closer).
    pub adaptive: Option<AdaptiveSnapshot>,
    /// MAV→code conversions performed by the digitization pool (0 on
    /// the ADC-free path).
    pub conversions: u64,
    /// Conversions avoided by per-row gating: early termination had
    /// already pruned the row, so the converter never fired. The ET
    /// savings visible in the ADC energy column.
    pub conversions_gated: u64,
    /// Comparator decisions across all conversions.
    pub adc_comparisons: u64,
    /// Conversion clock cycles across all conversions.
    pub adc_cycles: u64,
    /// Conversion energy (fJ) across all conversions.
    pub adc_energy_fj: f64,
    /// Average comparator decisions per conversion (the Fig 10 axis).
    pub comparisons_per_conversion: f64,
    /// Conversion energy per completed request (fJ).
    pub energy_per_req_fj: f64,
    /// Ingest-side frontend triage counters (all zero when serving
    /// without `--frontend`).
    pub frontend: FrontendStats,
    /// Per-stage latency breakdown (queue-wait / batch-wait / service)
    /// with the conversion energy attributed to the service stage.
    /// All-zero when telemetry is disabled or nothing resolved stages.
    pub stages: StageBreakdown,
    /// Executor/pool runtime counters accumulated across the serving
    /// workers (tasks, per-lane busy-ns, queue high water, planes).
    pub runtime: RuntimeCounters,
    /// The full end-to-end latency histogram behind the percentile
    /// fields — the exporter diffs successive snapshots of it for
    /// per-interval percentiles.
    pub latency_hist: LatencyHistogram,
    /// Fault-injection / self-healing counters (blast radius of the
    /// installed fault plan: injections by type, probe outcomes,
    /// quarantines, degraded planes, rerouted conversions). All zero —
    /// and absent from the summary line — without a plan.
    pub faults: FaultStats,
    /// Worker threads detached at shutdown after the join deadline
    /// expired (0 when every worker joined in time).
    pub shutdown_forced: u64,
}

/// Open the throughput window at the first metrics event of any kind
/// (see the `Inner::started` docs — admission/shed/reject included, so
/// shed-only overload traces don't overstate `throughput_per_s`).
fn touch_started(g: &mut Inner) {
    if g.started.is_none() {
        g.started = Some(Instant::now());
    }
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// One dispatched batch of `batch_size` requests.
    pub fn record_batch(&self, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        touch_started(&mut g);
        g.batch_size.push(batch_size as f64);
        g.batch_hist[batch_bucket(batch_size)] += 1;
    }

    /// Fold a per-batch delta of engine-fused samples into the totals
    /// (workers call this after each engine invocation, same delta
    /// discipline as [`Metrics::record_conversions`]).
    pub fn record_samples_fused(&self, delta: u64) {
        if delta == 0 {
            return;
        }
        self.inner.lock().unwrap().samples_fused += delta;
    }

    /// One answered request with its end-to-end latency.
    pub fn record_completion(&self, latency_us: u64) {
        let mut g = self.inner.lock().unwrap();
        touch_started(&mut g);
        g.latency_us.push(latency_us as f64);
        g.latency_hist.record(latency_us);
        if g.recent_latency.len() < RECENT_LATENCY_WINDOW {
            g.recent_latency.push(latency_us as f64);
        } else {
            let idx = g.recent_idx;
            g.recent_latency[idx] = latency_us as f64;
        }
        g.recent_idx = (g.recent_idx + 1) % RECENT_LATENCY_WINDOW;
        g.completed += 1;
        g.finished = Some(Instant::now());
    }

    /// p99 over the most recent [`RECENT_LATENCY_WINDOW`] completions —
    /// the adaptive batcher's feedback signal. `None` before the first
    /// completion.
    pub fn recent_p99_us(&self) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        if g.recent_latency.is_empty() {
            return None;
        }
        let mut sorted = g.recent_latency.clone();
        drop(g);
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(crate::util::stats::percentile_sorted(&sorted, 99.0))
    }

    /// One admission decision bucketed by QoS class (`priority >> 6`):
    /// `admitted = false` counts a graduated shed.
    pub fn record_qos(&self, class: usize, admitted: bool) {
        let mut g = self.inner.lock().unwrap();
        touch_started(&mut g);
        let class = class.min(QOS_CLASSES - 1);
        if admitted {
            g.qos_admitted[class] += 1;
        } else {
            g.qos_shed[class] += 1;
        }
    }

    /// One request's resolved stage spans (queue-wait / batch-wait /
    /// service). Workers call this per served response when telemetry
    /// is enabled; the end-to-end latency is recorded separately by
    /// [`Metrics::record_completion`].
    pub fn record_stages(&self, s: StageSample) {
        let mut g = self.inner.lock().unwrap();
        g.stage_queue.record(s.queue_wait_us);
        g.stage_batch.record(s.batch_wait_us);
        g.stage_service.record(s.service_us);
    }

    /// Fold a per-batch delta of executor/pool runtime counters into
    /// the totals (same delta discipline as
    /// [`Metrics::record_conversions`]).
    pub fn record_runtime(&self, delta: &RuntimeCounters) {
        if delta.is_zero() && delta.exec_lanes == 0 {
            return;
        }
        self.inner.lock().unwrap().runtime.merge(delta);
    }

    /// Publish the adaptive batch closer's current knob state (the
    /// batcher thread calls this after each adaptation window).
    pub fn record_adaptive_state(&self, state: AdaptiveSnapshot) {
        self.inner.lock().unwrap().adaptive = Some(state);
    }

    /// One request answered with an engine-error failure response.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// A request shed at the door because the admission queue was full.
    pub fn record_rejected_queue_full(&self) {
        let mut g = self.inner.lock().unwrap();
        touch_started(&mut g);
        g.rejected_queue_full += 1;
    }

    /// A wire frame refused by the validated ingest boundary.
    pub fn record_rejected_malformed(&self) {
        let mut g = self.inner.lock().unwrap();
        touch_started(&mut g);
        g.rejected_malformed += 1;
    }

    /// A request whose engine panicked inside a worker; the unwind was
    /// caught and the request answered with a failure response.
    pub fn record_panic_isolated(&self) {
        self.inner.lock().unwrap().panics_isolated += 1;
    }

    /// Fold a per-batch delta of pool digitization work into the totals
    /// (workers call this after each `infer_batch`).
    pub fn record_conversions(&self, delta: &ConversionStats) {
        if delta.conversions == 0 && delta.gated == 0 && delta.energy_fj == 0.0 {
            return;
        }
        self.inner.lock().unwrap().conv.merge(delta);
    }

    /// Fold a per-batch delta of fault-injection counters into the
    /// totals (same delta discipline as [`Metrics::record_conversions`];
    /// workers skip the lock entirely on the all-zero deltas a
    /// fault-free run produces).
    pub fn record_faults(&self, delta: &FaultStats) {
        if delta.is_zero() {
            return;
        }
        self.inner.lock().unwrap().faults.merge(delta);
    }

    /// Count worker threads detached at shutdown after the join
    /// deadline expired.
    pub fn record_shutdown_forced(&self, n: u64) {
        if n == 0 {
            return;
        }
        self.inner.lock().unwrap().shutdown_forced += n;
    }

    /// Fold frontend triage counters into the totals (the ingest side
    /// reports deltas, e.g. via [`super::EdgeServer::record_frontend`]).
    pub fn record_frontend(&self, delta: &FrontendStats) {
        if delta.frames_in == 0 {
            return;
        }
        self.inner.lock().unwrap().frontend.merge(delta);
    }

    /// Consistent copy of every counter for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let pct = |p: f64| {
            if g.latency_hist.is_empty() {
                0.0
            } else {
                g.latency_hist.percentile(p) as f64
            }
        };
        let wall = match (g.started, g.finished) {
            (Some(s), Some(f)) if f > s => f.duration_since(s).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            completed: g.completed,
            errors: g.errors,
            rejected_queue_full: g.rejected_queue_full,
            rejected_malformed: g.rejected_malformed,
            panics_isolated: g.panics_isolated,
            degraded: g.errors + g.panics_isolated,
            mean_latency_us: g.latency_us.mean(),
            p50_latency_us: pct(50.0),
            p95_latency_us: pct(95.0),
            p99_latency_us: pct(99.0),
            max_latency_us: g.latency_us.max(),
            mean_batch: g.batch_size.mean(),
            batch_hist: g.batch_hist,
            samples_fused: g.samples_fused,
            throughput_per_s: if wall > 0.0 { g.completed as f64 / wall } else { 0.0 },
            qos_admitted: g.qos_admitted,
            qos_shed: g.qos_shed,
            adaptive: g.adaptive,
            conversions: g.conv.conversions,
            conversions_gated: g.conv.gated,
            adc_comparisons: g.conv.comparisons,
            adc_cycles: g.conv.cycles,
            adc_energy_fj: g.conv.energy_fj,
            comparisons_per_conversion: g.conv.comparisons_per_conversion(),
            energy_per_req_fj: if g.completed > 0 {
                g.conv.energy_fj / g.completed as f64
            } else {
                0.0
            },
            frontend: g.frontend.clone(),
            stages: StageBreakdown {
                queue_wait: StageStats::from_histogram(&g.stage_queue, 0.0),
                batch_wait: StageStats::from_histogram(&g.stage_batch, 0.0),
                service: StageStats::from_histogram(&g.stage_service, g.conv.energy_fj),
            },
            runtime: g.runtime.clone(),
            latency_hist: g.latency_hist.clone(),
            faults: g.faults,
            shutdown_forced: g.shutdown_forced,
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "completed={} errors={} p50={:.0}µs p95={:.0}µs p99={:.0}µs mean={:.0}µs \
             batch={:.1} rate={:.0}/s",
            self.completed,
            self.errors,
            self.p50_latency_us,
            self.p95_latency_us,
            self.p99_latency_us,
            self.mean_latency_us,
            self.mean_batch,
            self.throughput_per_s
        )?;
        if self.samples_fused > 0 {
            write!(f, " fused={}", self.samples_fused)?;
        }
        if self.batch_hist.iter().any(|&c| c > 0) {
            write!(f, " batches=[")?;
            for (i, &c) in self.batch_hist.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                match BATCH_BUCKET_BOUNDS.get(i) {
                    Some(b) => write!(f, "≤{b}:{c}")?,
                    None => write!(f, ">{}:{c}", BATCH_BUCKET_BOUNDS[i - 1])?,
                }
            }
            write!(f, "]")?;
        }
        if self.conversions > 0 || self.conversions_gated > 0 {
            write!(
                f,
                " conv={} gated={} cmp/conv={:.2} cycles={} E/req={:.0}fJ",
                self.conversions,
                self.conversions_gated,
                self.comparisons_per_conversion,
                self.adc_cycles,
                self.energy_per_req_fj
            )?;
        }
        if let Some(a) = self.adaptive {
            write!(
                f,
                " adaptive: batch={} deadline={}µs retunes={}",
                a.eff_batch, a.eff_deadline_us, a.adaptations
            )?;
        }
        if self.rejected_queue_full > 0 || self.rejected_malformed > 0 {
            write!(
                f,
                " rejected: queue={} wire={}",
                self.rejected_queue_full, self.rejected_malformed
            )?;
        }
        if self.qos_shed.iter().any(|&c| c > 0) {
            write!(f, " qos shed=[")?;
            for (c, &n) in self.qos_shed.iter().enumerate() {
                write!(f, "{}c{c}:{n}", if c > 0 { " " } else { "" })?;
            }
            write!(f, "] admitted=[")?;
            for (c, &n) in self.qos_admitted.iter().enumerate() {
                write!(f, "{}c{c}:{n}", if c > 0 { " " } else { "" })?;
            }
            write!(f, "]")?;
        }
        if self.degraded > 0 {
            write!(f, " degraded={} (panics={})", self.degraded, self.panics_isolated)?;
        }
        if self.frontend.frames_in > 0 {
            write!(f, " {}", self.frontend)?;
        }
        if self.stages.service.count > 0 {
            write!(
                f,
                " stages: queue p50={}µs p99={}µs | wait p50={}µs p99={}µs \
                 | service p50={}µs p99={}µs",
                self.stages.queue_wait.p50_us,
                self.stages.queue_wait.p99_us,
                self.stages.batch_wait.p50_us,
                self.stages.batch_wait.p99_us,
                self.stages.service.p50_us,
                self.stages.service.p99_us
            )?;
        }
        if !self.faults.is_zero() {
            write!(
                f,
                " faults: injected={} probes={}/{} quarantined={} degraded={} rerouted={}",
                self.faults.faults_injected,
                self.faults.probes_failed,
                self.faults.probes_run,
                self.faults.quarantined,
                self.faults.degraded_planes,
                self.faults.conversions_rerouted
            )?;
        }
        if self.shutdown_forced > 0 {
            write!(f, " shutdown_forced={}", self.shutdown_forced)?;
        }
        if !self.runtime.is_zero() {
            write!(
                f,
                " exec: tasks={} batches={} hw={} lanes={} planes={}/{}",
                self.runtime.exec_tasks,
                self.runtime.exec_batches,
                self.runtime.exec_queue_high_water,
                self.runtime.exec_lanes,
                self.runtime.planes_fused,
                self.runtime.planes_dispatched
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(4);
        for lat in [100u64, 200, 300, 400] {
            m.record_completion(lat);
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.completed, 4);
        assert_eq!(s.errors, 1);
        assert!((s.mean_latency_us - 250.0).abs() < 1e-9);
        assert_eq!(s.max_latency_us, 400.0);
        assert_eq!(s.mean_batch, 4.0);
        assert!(s.p95_latency_us >= s.p50_latency_us);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_latency_us, 0.0);
        assert_eq!(s.conversions, 0);
        assert_eq!(s.energy_per_req_fj, 0.0);
        assert_eq!(s.rejected_queue_full, 0);
        assert_eq!(s.rejected_malformed, 0);
        assert_eq!(s.panics_isolated, 0);
        assert_eq!(s.degraded, 0);
        // A clean run keeps the summary line free of robustness noise.
        let line = format!("{s}");
        assert!(!line.contains("rejected"), "{line}");
        assert!(!line.contains("degraded"), "{line}");
    }

    #[test]
    fn rejection_and_panic_counters_reach_snapshot_and_display() {
        let m = Metrics::new();
        m.record_completion(100);
        m.record_rejected_queue_full();
        m.record_rejected_queue_full();
        m.record_rejected_malformed();
        m.record_panic_isolated();
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.rejected_queue_full, 2);
        assert_eq!(s.rejected_malformed, 1);
        assert_eq!(s.panics_isolated, 1);
        assert_eq!(s.degraded, 2, "errors + isolated panics");
        let line = format!("{s}");
        assert!(line.contains("rejected: queue=2 wire=1"), "{line}");
        assert!(line.contains("degraded=2 (panics=1)"), "{line}");
    }

    #[test]
    fn qos_counters_reach_snapshot_and_display_only_under_shedding() {
        let m = Metrics::new();
        m.record_completion(100);
        m.record_qos(3, true);
        m.record_qos(3, true);
        m.record_qos(1, true);
        // No sheds yet: the summary line stays clean.
        let s = m.snapshot();
        assert_eq!(s.qos_admitted, [0, 1, 0, 2]);
        assert_eq!(s.qos_shed, [0; QOS_CLASSES]);
        assert!(!format!("{s}").contains("qos"), "{s}");
        // A shed turns the block on with the full class breakdown.
        m.record_qos(1, false);
        m.record_qos(0, false);
        m.record_qos(0, false);
        m.record_qos(9, false); // out-of-range class clamps to top
        let s = m.snapshot();
        assert_eq!(s.qos_shed, [2, 1, 0, 1]);
        let line = format!("{s}");
        assert!(line.contains("qos shed=[c0:2 c1:1 c2:0 c3:1]"), "{line}");
        assert!(line.contains("admitted=[c0:0 c1:1 c2:0 c3:2]"), "{line}");
    }

    #[test]
    fn adaptive_state_reaches_snapshot_and_display() {
        let m = Metrics::new();
        m.record_completion(100);
        assert!(m.snapshot().adaptive.is_none());
        assert!(!format!("{}", m.snapshot()).contains("adaptive"));
        m.record_adaptive_state(AdaptiveSnapshot {
            eff_batch: 8,
            eff_deadline_us: 1500,
            adaptations: 3,
        });
        let s = m.snapshot();
        assert_eq!(
            s.adaptive,
            Some(AdaptiveSnapshot { eff_batch: 8, eff_deadline_us: 1500, adaptations: 3 })
        );
        assert!(format!("{s}").contains("adaptive: batch=8 deadline=1500µs retunes=3"), "{s}");
    }

    #[test]
    fn recent_p99_tracks_a_rolling_window() {
        let m = Metrics::new();
        assert!(m.recent_p99_us().is_none());
        // Fill the whole window with slow completions…
        for _ in 0..RECENT_LATENCY_WINDOW {
            m.record_completion(10_000);
        }
        assert!(m.recent_p99_us().unwrap() >= 10_000.0 - 1e-9);
        // …then overwrite it with fast ones: the rolling p99 must
        // forget the old regime while the lifetime p99 cannot.
        for _ in 0..RECENT_LATENCY_WINDOW {
            m.record_completion(100);
        }
        assert!(m.recent_p99_us().unwrap() <= 100.0 + 1e-9);
        assert!(m.snapshot().p99_latency_us >= 9_000.0);
    }

    #[test]
    fn conversion_deltas_accumulate_into_per_request_energy() {
        let m = Metrics::new();
        for lat in [100u64, 200] {
            m.record_completion(lat);
        }
        m.record_conversions(&ConversionStats {
            conversions: 64,
            comparisons: 320,
            cycles: 320,
            energy_fj: 150.0,
            gated: 8,
        });
        m.record_conversions(&ConversionStats {
            conversions: 64,
            comparisons: 320,
            cycles: 320,
            energy_fj: 50.0,
            gated: 24,
        });
        let s = m.snapshot();
        assert_eq!(s.conversions, 128);
        assert_eq!(s.conversions_gated, 32);
        assert_eq!(s.adc_comparisons, 640);
        assert_eq!(s.adc_cycles, 640);
        assert!((s.adc_energy_fj - 200.0).abs() < 1e-9);
        assert!((s.comparisons_per_conversion - 5.0).abs() < 1e-9);
        assert!((s.energy_per_req_fj - 100.0).abs() < 1e-9);
        let line = format!("{s}");
        assert!(line.contains("conv=128"), "{line}");
        assert!(line.contains("gated=32"), "{line}");
    }

    #[test]
    fn batch_histogram_and_fused_counter_reach_snapshot_and_display() {
        let m = Metrics::new();
        m.record_completion(100);
        for size in [1usize, 2, 3, 8, 9, 64, 65, 1000] {
            m.record_batch(size);
        }
        m.record_samples_fused(6);
        m.record_samples_fused(0); // no-op delta
        m.record_samples_fused(10);
        let s = m.snapshot();
        // Buckets: ≤1, ≤2, ≤4, ≤8, ≤16, ≤32, ≤64, >64.
        assert_eq!(s.batch_hist, [1, 1, 1, 1, 1, 0, 1, 2]);
        assert_eq!(s.samples_fused, 16);
        assert!(s.p99_latency_us >= s.p95_latency_us);
        let line = format!("{s}");
        assert!(line.contains("fused=16"), "{line}");
        assert!(line.contains("p99="), "{line}");
        assert!(line.contains("batches=[≤1:1 ≤2:1 ≤4:1 ≤8:1 ≤16:1 ≤32:0 ≤64:1 >64:2]"), "{line}");
        // A run with no batches/fusion keeps the summary line clean.
        let empty = Metrics::new().snapshot();
        let eline = format!("{empty}");
        assert!(!eline.contains("fused"), "{eline}");
        assert!(!eline.contains("batches"), "{eline}");
    }

    #[test]
    fn frontend_stats_reach_snapshot_and_display() {
        let m = Metrics::new();
        m.record_completion(100);
        let mut fe = FrontendStats {
            frames_in: 10,
            kept: 7,
            summarized: 2,
            dropped: 1,
            bytes_in: 40_960,
            bytes_out: 4_096,
            ..Default::default()
        };
        fe.record_retained(0.95);
        m.record_frontend(&fe);
        m.record_frontend(&FrontendStats::default()); // no-op delta
        let s = m.snapshot();
        assert_eq!(s.frontend.frames_in, 10);
        assert_eq!(s.frontend.kept, 7);
        assert_eq!(s.frontend.bytes_out, 4_096);
        let line = format!("{s}");
        assert!(line.contains("frontend: in=10 kept=7"), "{line}");
        assert!(line.contains("10.0x"), "{line}");
        // Without frontend traffic the line stays clean.
        let empty = Metrics::new().snapshot();
        assert!(!format!("{empty}").contains("frontend"), "{empty}");
    }

    /// The throughput window must open at the *first* metrics event —
    /// not the first dispatched batch — or a run that sheds under
    /// overload before its first batch seal reports an inflated rate.
    #[test]
    fn throughput_window_opens_at_first_event_not_first_batch() {
        let m = Metrics::new();
        // Overload preamble: sheds arrive well before anything serves.
        m.record_qos(0, false);
        m.record_qos(1, false);
        std::thread::sleep(std::time::Duration::from_millis(60));
        m.record_batch(1);
        m.record_completion(100);
        let s = m.snapshot();
        // One completion over ≥60ms of wall clock: if the window had
        // only opened at record_batch, this would read as hundreds/s.
        assert!(
            s.throughput_per_s <= 1000.0 / 60.0 + 1.0,
            "window must cover the shed-only preamble: {}/s",
            s.throughput_per_s
        );
        assert!(s.throughput_per_s > 0.0);
    }

    #[test]
    fn stage_samples_reach_snapshot_and_display() {
        use crate::util::telemetry::StageSample;
        let m = Metrics::new();
        for (q, b, sv) in [(100u64, 50u64, 200u64), (120, 60, 220), (80, 40, 180)] {
            m.record_completion(q + b + sv);
            m.record_stages(StageSample {
                queue_wait_us: q,
                batch_wait_us: b,
                service_us: sv,
                end_to_end_us: q + b + sv,
            });
        }
        let s = m.snapshot();
        assert_eq!(s.stages.queue_wait.count, 3);
        assert_eq!(s.stages.batch_wait.count, 3);
        assert_eq!(s.stages.service.count, 3);
        assert!((s.stages.queue_wait.mean_us - 100.0).abs() < 1e-9);
        assert_eq!(s.stages.service.p99_us, 220);
        // Stage sums telescope under the end-to-end distribution.
        let sum_means = s.stages.queue_wait.mean_us
            + s.stages.batch_wait.mean_us
            + s.stages.service.mean_us;
        assert!(sum_means <= s.mean_latency_us + 1e-9);
        // Service energy attribution follows the conversion totals.
        m.record_conversions(&ConversionStats {
            conversions: 4,
            comparisons: 20,
            cycles: 20,
            energy_fj: 42.0,
            gated: 0,
        });
        let s = m.snapshot();
        assert!((s.stages.service.energy_fj - 42.0).abs() < 1e-9);
        assert_eq!(s.stages.queue_wait.energy_fj, 0.0);
        let line = format!("{s}");
        assert!(line.contains("stages: queue"), "{line}");
        // A run without stage samples keeps the line clean.
        let empty = Metrics::new().snapshot();
        assert!(!format!("{empty}").contains("stages"), "{empty}");
    }

    #[test]
    fn fault_and_shutdown_counters_reach_snapshot_and_display() {
        let m = Metrics::new();
        m.record_completion(100);
        m.record_faults(&FaultStats::default()); // no-op: lock-free path
        let d = FaultStats {
            faults_injected: 3,
            converters_dead: 2,
            arrays_down: 1,
            probes_run: 8,
            probes_failed: 2,
            quarantined: 1,
            degraded_planes: 5,
            conversions_rerouted: 32,
            ..Default::default()
        };
        m.record_faults(&d);
        m.record_faults(&d);
        m.record_shutdown_forced(0); // no-op
        m.record_shutdown_forced(1);
        let s = m.snapshot();
        assert_eq!(s.faults.faults_injected, 6);
        assert_eq!(s.faults.probes_run, 16);
        assert_eq!(s.faults.degraded_planes, 10);
        assert_eq!(s.shutdown_forced, 1);
        let line = format!("{s}");
        assert!(
            line.contains("faults: injected=6 probes=4/16 quarantined=2 degraded=10 rerouted=64"),
            "{line}"
        );
        assert!(line.contains("shutdown_forced=1"), "{line}");
        // Fault-free runs keep the summary line clean.
        let empty = Metrics::new().snapshot();
        assert!(empty.faults.is_zero());
        let eline = format!("{empty}");
        assert!(!eline.contains("faults"), "{eline}");
        assert!(!eline.contains("shutdown_forced"), "{eline}");
    }

    #[test]
    fn runtime_counter_deltas_accumulate() {
        use crate::util::telemetry::RuntimeCounters;
        let m = Metrics::new();
        m.record_completion(100);
        m.record_runtime(&RuntimeCounters::default()); // no-op delta
        let d = RuntimeCounters {
            exec_tasks: 8,
            exec_batches: 2,
            exec_queue_high_water: 3,
            exec_lanes: 2,
            exec_busy_ns: vec![1_000, 2_000],
            planes_dispatched: 16,
            planes_fused: 12,
        };
        m.record_runtime(&d);
        m.record_runtime(&d);
        let s = m.snapshot();
        assert_eq!(s.runtime.exec_tasks, 16);
        assert_eq!(s.runtime.exec_batches, 4);
        assert_eq!(s.runtime.exec_queue_high_water, 3, "high water maxes, not sums");
        assert_eq!(s.runtime.exec_lanes, 2);
        assert_eq!(s.runtime.exec_busy_ns, vec![2_000, 4_000]);
        assert_eq!(s.runtime.planes_dispatched, 32);
        assert_eq!(s.runtime.planes_fused, 24);
        let line = format!("{s}");
        assert!(line.contains("exec: tasks=16"), "{line}");
        assert!(line.contains("planes=24/32"), "{line}");
        // A run without runtime deltas keeps the line clean.
        let empty = Metrics::new().snapshot();
        assert!(!format!("{empty}").contains("exec:"), "{empty}");
    }
}
