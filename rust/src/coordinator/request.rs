//! Request/response types for the serving path.

use std::time::Instant;

use crate::frontend::codec::CompressedFrame;

/// What a request carries: a dense sensor frame, or a frontend-encoded
/// [`CompressedFrame`] that travels the batcher/router/worker path
/// natively and is only reconstructed (or served transform-domain) at
/// the engine.
#[derive(Debug, Clone)]
pub enum FramePayload {
    /// Flattened dense frame, length = model input dim.
    Raw(Vec<f32>),
    /// Sequency-domain compressed frame (`frontend::codec`).
    Compressed(CompressedFrame),
}

impl FramePayload {
    /// Length of the dense frame this payload reconstructs to.
    pub fn dense_len(&self) -> usize {
        match self {
            FramePayload::Raw(v) => v.len(),
            FramePayload::Compressed(cf) => cf.params.dense_len(),
        }
    }

    /// Bytes this payload occupies on the ingest path (raw f32 frame vs
    /// the codec's wire size).
    pub fn ingest_bytes(&self) -> usize {
        match self {
            FramePayload::Raw(v) => v.len() * 4,
            FramePayload::Compressed(cf) => cf.encoded_bytes(),
        }
    }

    /// Materialize the dense frame (reference path; engines with scratch
    /// use `DecodeScratch` instead).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            FramePayload::Raw(v) => v.clone(),
            FramePayload::Compressed(cf) => cf.decode(),
        }
    }
}

/// One inference request: a sensor frame (raw or compressed).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Unique id (assigned by the submitting side).
    pub id: u64,
    /// Originating sensor stream (router affinity / ordering key).
    pub stream: u32,
    /// The frame itself.
    pub payload: FramePayload,
    /// Submission timestamp (latency accounting).
    pub submitted: Instant,
}

impl InferenceRequest {
    /// A raw dense-frame request (the pre-frontend ingest shape).
    pub fn new(id: u64, stream: u32, image: Vec<f32>) -> Self {
        InferenceRequest {
            id,
            stream,
            payload: FramePayload::Raw(image),
            submitted: Instant::now(),
        }
    }

    /// A frontend-compressed request.
    pub fn compressed(id: u64, stream: u32, frame: CompressedFrame) -> Self {
        InferenceRequest {
            id,
            stream,
            payload: FramePayload::Compressed(frame),
            submitted: Instant::now(),
        }
    }
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub stream: u32,
    /// Raw logits.
    pub logits: Vec<f32>,
    /// argmax class.
    pub class: usize,
    /// End-to-end latency in microseconds.
    pub latency_us: u64,
    /// Which worker served it.
    pub worker: usize,
}

impl InferenceResponse {
    pub fn from_logits(req: &InferenceRequest, logits: Vec<f32>, worker: usize) -> Self {
        let class = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        InferenceResponse {
            id: req.id,
            stream: req.stream,
            logits,
            class,
            latency_us: req.submitted.elapsed().as_micros() as u64,
            worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::codec::CodecParams;
    use crate::frontend::encoder::{FrameEncoder, Selection};

    #[test]
    fn response_classifies_by_argmax() {
        let req = InferenceRequest::new(7, 1, vec![0.0; 4]);
        let resp = InferenceResponse::from_logits(&req, vec![0.1, 3.0, -1.0], 2);
        assert_eq!(resp.class, 1);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.worker, 2);
    }

    #[test]
    fn payload_byte_and_dense_accounting() {
        let raw = FramePayload::Raw(vec![0.25; 64]);
        assert_eq!(raw.dense_len(), 64);
        assert_eq!(raw.ingest_bytes(), 256);
        assert_eq!(raw.to_dense(), vec![0.25; 64]);

        let p = CodecParams::new(1, 64, 8, 8).unwrap();
        let frame: Vec<f32> = (0..64).map(|i| (i % 8) as f32 / 8.0).collect();
        let cf = FrameEncoder::new(p, Selection::TopK(8)).encode(&frame, 1);
        let compressed = FramePayload::Compressed(cf.clone());
        assert_eq!(compressed.dense_len(), 64);
        assert_eq!(compressed.ingest_bytes(), cf.encoded_bytes());
        assert!(compressed.ingest_bytes() < raw.ingest_bytes());
        assert_eq!(compressed.to_dense(), cf.decode());

        let req = InferenceRequest::compressed(3, 2, cf);
        assert!(matches!(req.payload, FramePayload::Compressed(_)));
        assert_eq!(req.id, 3);
    }
}
