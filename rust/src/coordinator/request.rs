//! Request/response types for the serving path.

use std::time::Instant;

/// One inference request: a flattened sensor frame.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Unique id (assigned by the submitting side).
    pub id: u64,
    /// Originating sensor stream (router affinity / ordering key).
    pub stream: u32,
    /// Flattened image, length = model input dim.
    pub image: Vec<f32>,
    /// Submission timestamp (latency accounting).
    pub submitted: Instant,
}

impl InferenceRequest {
    pub fn new(id: u64, stream: u32, image: Vec<f32>) -> Self {
        InferenceRequest { id, stream, image, submitted: Instant::now() }
    }
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub stream: u32,
    /// Raw logits.
    pub logits: Vec<f32>,
    /// argmax class.
    pub class: usize,
    /// End-to-end latency in microseconds.
    pub latency_us: u64,
    /// Which worker served it.
    pub worker: usize,
}

impl InferenceResponse {
    pub fn from_logits(req: &InferenceRequest, logits: Vec<f32>, worker: usize) -> Self {
        let class = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        InferenceResponse {
            id: req.id,
            stream: req.stream,
            logits,
            class,
            latency_us: req.submitted.elapsed().as_micros() as u64,
            worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_classifies_by_argmax() {
        let req = InferenceRequest::new(7, 1, vec![0.0; 4]);
        let resp = InferenceResponse::from_logits(&req, vec![0.1, 3.0, -1.0], 2);
        assert_eq!(resp.class, 1);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.worker, 2);
    }
}
