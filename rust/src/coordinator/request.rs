//! Request/response types for the serving path.

use std::time::Instant;

use crate::frontend::codec::{CodecError, CompressedFrame};
use crate::util::telemetry::RequestTrace;

/// What a request carries: a dense sensor frame, or a frontend-encoded
/// [`CompressedFrame`] that travels the batcher/router/worker path
/// natively and is only reconstructed (or served transform-domain) at
/// the engine.
#[derive(Debug, Clone)]
pub enum FramePayload {
    /// Flattened dense frame, length = model input dim.
    Raw(Vec<f32>),
    /// Sequency-domain compressed frame (`frontend::codec`).
    Compressed(CompressedFrame),
}

impl FramePayload {
    /// Length of the dense frame this payload reconstructs to.
    pub fn dense_len(&self) -> usize {
        match self {
            FramePayload::Raw(v) => v.len(),
            FramePayload::Compressed(cf) => cf.params.dense_len(),
        }
    }

    /// Bytes this payload occupies on the ingest path (raw f32 frame vs
    /// the codec's wire size).
    pub fn ingest_bytes(&self) -> usize {
        match self {
            FramePayload::Raw(v) => v.len() * 4,
            FramePayload::Compressed(cf) => cf.encoded_bytes(),
        }
    }

    /// Materialize the dense frame (reference path; engines with scratch
    /// use `DecodeScratch` instead).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            FramePayload::Raw(v) => v.clone(),
            FramePayload::Compressed(cf) => cf.decode(),
        }
    }

    /// Checked [`Self::to_dense`]: a corrupt compressed frame reports a
    /// [`CodecError`] instead of panicking (raw payloads cannot fail).
    pub fn try_to_dense(&self) -> Result<Vec<f32>, CodecError> {
        match self {
            FramePayload::Raw(v) => Ok(v.clone()),
            FramePayload::Compressed(cf) => cf.try_decode(),
        }
    }
}

/// Highest QoS priority: raw/lossless frames and anything the
/// frontend's triage marked unambiguously worth keeping. Requests built
/// without an explicit priority get this, so pre-QoS callers see the
/// legacy shed-only-when-full admission behavior unchanged.
pub const TOP_PRIORITY: u8 = u8::MAX;

/// One inference request: a sensor frame (raw or compressed).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Unique id (assigned by the submitting side).
    pub id: u64,
    /// Originating sensor stream (router affinity / ordering key).
    pub stream: u32,
    /// The frame itself.
    pub payload: FramePayload,
    /// Submission timestamp (latency accounting).
    pub submitted: Instant,
    /// QoS priority for graduated admission (255 = never shed before
    /// the queue is completely full; lower sheds earlier under load).
    /// Derived from the frontend triage score for compressed frames
    /// ([`crate::frontend::retention::RetentionPolicy::priority`]);
    /// raw frames default to [`TOP_PRIORITY`].
    pub priority: u8,
    /// Stage-span timestamps stamped by the serving pipeline
    /// (admission → batch seal → engine start/end). Pure telemetry:
    /// never read by scheduling, batching, or the engines.
    pub trace: RequestTrace,
}

impl InferenceRequest {
    /// A raw dense-frame request (the pre-frontend ingest shape).
    pub fn new(id: u64, stream: u32, image: Vec<f32>) -> Self {
        InferenceRequest {
            id,
            stream,
            payload: FramePayload::Raw(image),
            submitted: Instant::now(),
            priority: TOP_PRIORITY,
            trace: RequestTrace::default(),
        }
    }

    /// A frontend-compressed request.
    pub fn compressed(id: u64, stream: u32, frame: CompressedFrame) -> Self {
        InferenceRequest {
            id,
            stream,
            payload: FramePayload::Compressed(frame),
            submitted: Instant::now(),
            priority: TOP_PRIORITY,
            trace: RequestTrace::default(),
        }
    }

    /// Same request with an explicit QoS priority (builder style).
    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// QoS class for metrics bucketing: `priority >> 6`, so class 3 is
    /// the Keep band (192..=255), classes 1–2 the Summarize band
    /// (64..=191), class 0 the Drop band (0..=63).
    pub fn qos_class(&self) -> usize {
        (self.priority >> 6) as usize
    }
}

/// One inference response. `error` is `None` for a served request; a
/// degraded request (engine failure or panic-isolated worker) still
/// answers, with the reason here and empty logits.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    /// Id of the request this answers.
    pub id: u64,
    /// Stream of the originating request.
    pub stream: u32,
    /// Raw logits (empty on a failure response).
    pub logits: Vec<f32>,
    /// argmax class (0 on a failure response).
    pub class: usize,
    /// End-to-end latency in microseconds.
    pub latency_us: u64,
    /// Which worker served it.
    pub worker: usize,
    /// Why the request degraded instead of serving, if it did.
    pub error: Option<String>,
}

impl InferenceResponse {
    /// A served answer: classify by total-order argmax over `logits`.
    pub fn from_logits(req: &InferenceRequest, logits: Vec<f32>, worker: usize) -> Self {
        // total_cmp keeps the argmax total even if a hostile frame
        // decodes to NaN logits — a wrong class beats a dead worker.
        let class = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        InferenceResponse {
            id: req.id,
            stream: req.stream,
            logits,
            class,
            latency_us: req.submitted.elapsed().as_micros() as u64,
            worker,
            error: None,
        }
    }

    /// A degraded-request answer: no logits, but the submitter still
    /// hears back instead of waiting forever on a failed batch.
    pub fn failure(req: &InferenceRequest, worker: usize, reason: String) -> Self {
        InferenceResponse {
            id: req.id,
            stream: req.stream,
            logits: Vec::new(),
            class: 0,
            latency_us: req.submitted.elapsed().as_micros() as u64,
            worker,
            error: Some(reason),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::codec::CodecParams;
    use crate::frontend::encoder::{FrameEncoder, Selection};

    #[test]
    fn response_classifies_by_argmax() {
        let req = InferenceRequest::new(7, 1, vec![0.0; 4]);
        let resp = InferenceResponse::from_logits(&req, vec![0.1, 3.0, -1.0], 2);
        assert_eq!(resp.class, 1);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.worker, 2);
    }

    /// A hostile frame can legally decode to NaN-laced dense values in
    /// lossy mode; the argmax must stay total instead of panicking.
    #[test]
    fn nan_logits_do_not_panic_argmax() {
        let req = InferenceRequest::new(1, 0, vec![0.0; 4]);
        let resp = InferenceResponse::from_logits(&req, vec![f32::NAN, 1.0, f32::NAN], 0);
        assert!(resp.error.is_none());
        assert!(resp.class < 3);
    }

    #[test]
    fn failure_response_carries_reason() {
        let req = InferenceRequest::new(9, 3, vec![0.0; 4]);
        let resp = InferenceResponse::failure(&req, 1, "engine exploded".into());
        assert_eq!((resp.id, resp.stream, resp.worker), (9, 3, 1));
        assert!(resp.logits.is_empty());
        assert_eq!(resp.error.as_deref(), Some("engine exploded"));
    }

    #[test]
    fn try_to_dense_matches_to_dense_on_valid_payloads() {
        let p = CodecParams::new(1, 16, 8, 8).unwrap();
        let frame: Vec<f32> = (0..16).map(|i| (i % 4) as f32 / 4.0).collect();
        let cf = FrameEncoder::new(p, Selection::TopK(6)).encode(&frame, 0);
        let payload = FramePayload::Compressed(cf);
        assert_eq!(payload.try_to_dense().unwrap(), payload.to_dense());
        let raw = FramePayload::Raw(vec![0.5; 4]);
        assert_eq!(raw.try_to_dense().unwrap(), vec![0.5; 4]);
    }

    #[test]
    fn default_priority_is_top_and_builder_overrides() {
        let req = InferenceRequest::new(1, 0, vec![0.0; 4]);
        assert_eq!(req.priority, TOP_PRIORITY);
        assert_eq!(req.qos_class(), 3);
        let req = req.with_priority(70);
        assert_eq!(req.priority, 70);
        assert_eq!(req.qos_class(), 1);
        assert_eq!(req.clone().with_priority(0).qos_class(), 0);
        assert_eq!(req.with_priority(191).qos_class(), 2);
    }

    #[test]
    fn payload_byte_and_dense_accounting() {
        let raw = FramePayload::Raw(vec![0.25; 64]);
        assert_eq!(raw.dense_len(), 64);
        assert_eq!(raw.ingest_bytes(), 256);
        assert_eq!(raw.to_dense(), vec![0.25; 64]);

        let p = CodecParams::new(1, 64, 8, 8).unwrap();
        let frame: Vec<f32> = (0..64).map(|i| (i % 8) as f32 / 8.0).collect();
        let cf = FrameEncoder::new(p, Selection::TopK(8)).encode(&frame, 1);
        let compressed = FramePayload::Compressed(cf.clone());
        assert_eq!(compressed.dense_len(), 64);
        assert_eq!(compressed.ingest_bytes(), cf.encoded_bytes());
        assert!(compressed.ingest_bytes() < raw.ingest_bytes());
        assert_eq!(compressed.to_dense(), cf.decode());

        let req = InferenceRequest::compressed(3, 2, cf);
        assert!(matches!(req.payload, FramePayload::Compressed(_)));
        assert_eq!(req.id, 3);
    }
}
