//! `adcim` — leader binary: serve, load-test, compress, report,
//! characterize.
//!
//! Subcommands:
//!   serve     run the edge-inference server on a synthetic sensor load
//!   loadgen   deterministic open/closed-loop load generator against a
//!             freshly started server (QPS pacing, bursts, overload)
//!   compress  run the sensor frontend standalone over a synthetic
//!             multispectral deluge (ratio / accuracy tables)
//!   report    regenerate paper tables/figures (--all or --id fig7)
//!   adc       one-off ADC characterization (staircase/linearity)
//!   info      print chip/model/artifact status

use adcim::adc::{Adc, ImmersedAdc, ImmersedMode};
use adcim::analog::NoiseModel;
use adcim::cim::{CrossbarConfig, FaultPlan, PoolSpec};
use adcim::config::{ChipConfig, ServerConfig, TomlLite};
#[cfg(feature = "xla")]
use adcim::coordinator::DigitalEngine;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::frontend::{
    Channel, ChannelConfig, CodecParams, FrameEncoder, FrameSummary, FrontendConfig,
    IngestDecision, RetentionPolicy, Selection, SensorFrontend,
};
use adcim::nn::dataset::Dataset;
use adcim::nn::train::{train, TrainConfig};
use adcim::nn::{model, Tensor};
use adcim::runtime::Artifacts;
use adcim::util::cli::Args;
use adcim::util::loadgen::{self, LoadMode, LoadSpec};
use adcim::util::telemetry::TelemetrySink;
use adcim::util::Rng;
use anyhow::Result;

const VALUE_KEYS: &[&str] = &[
    "id", "out-dir", "config", "engine", "workers", "requests", "batch", "vdd", "clock",
    "bits", "mode", "artifacts", "policy", "threads", "pool", "adc-mode", "adc-bits",
    "pool-threads", "topk", "codec-bits", "retain", "sensor-bits", "select", "frames",
    "channels", "side", "classes", "channel-ber", "channel-drop", "channel-truncate",
    "channel-duplicate", "channel-reorder", "p99-target-us", "qps", "burst",
    "concurrency", "metrics-interval-ms", "metrics-out", "fault-plan", "probe-interval",
    "probe-tolerance", "probe-debounce", "shutdown-timeout-ms",
];

/// Parse a numeric flag *loudly*: an unparseable value is an error, not
/// a silent fall-through to the default (same discipline the TOML layer
/// applies to out-of-range `codec_bits`).
fn parse_flag<T: std::str::FromStr>(args: &Args, key: &str) -> Result<Option<T>> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| anyhow::anyhow!("invalid --{key} value '{v}'")),
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUE_KEYS);
    match args.positional().first().map(String::as_str) {
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("compress") => cmd_compress(&args),
        Some("report") => cmd_report(&args),
        Some("adc") => cmd_adc(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: adcim <serve|loadgen|compress|report|adc|info> [--config file.toml]\n\
                 \n\
                 serve  --engine digital|analog|mock --workers N --requests N [--policy rr|ll|affinity]\n\
                 \x20       [--pool N --adc-mode sar|flash|hybrid --adc-bits B --asym]\n\
                 \x20       [--pool-threads T] [--fuse-batch]\n\
                 \x20       [--adaptive --p99-target-us T]\n\
                 \x20       [--metrics-interval-ms MS [--metrics-out PATH]] [--no-telemetry]\n\
                 \x20       [--frontend --topk K --select all|topK|eF --codec-bits B\n\
                 \x20        --retain keep|triage]\n\
                 \x20       [--channel-ber P --channel-drop P --channel-truncate P\n\
                 \x20        --channel-duplicate P --channel-reorder P]\n\
                 \x20       [--fault-plan SPEC --probe-interval N --probe-tolerance LSB\n\
                 \x20        --probe-debounce N] [--shutdown-timeout-ms MS]\n\
                 \x20       (--pool N serves the analog BWHT stages through an N-array\n\
                 \x20        collaborative digitization pool; 0/omitted = ADC-free 1-bit path;\n\
                 \x20        --pool-threads T fans the pool's coupling groups across T persistent\n\
                 \x20        workers, 0 = auto — results are thread-count invariant;\n\
                 \x20        --fuse-batch fuses the whole served batch — every sample's\n\
                 \x20        bitplanes across all BWHT blocks — into shared pool submissions\n\
                 \x20        via the lockstep batched forward (bit-identical results);\n\
                 \x20        --frontend ingests through the frequency-domain sensor frontend:\n\
                 \x20        frames are sequency-compressed to the top K coefficients at B\n\
                 \x20        bits (0 = lossless) and triaged by the retention policy;\n\
                 \x20        --channel-* knobs push kept frames through a deterministic\n\
                 \x20        fault-injecting wire channel (bit flips, drops, truncation,\n\
                 \x20        duplication, pairwise reordering) — corrupted frames are\n\
                 \x20        rejected at the validated ingest boundary, visible in the\n\
                 \x20        metrics line;\n\
                 \x20        --fault-plan injects seeded analog faults into the pool\n\
                 \x20        (stuck@SLOT=ARRAY,ROW,COL,+|- drift@SLOT=GROUP,GAIN,OFFSET\n\
                 \x20        dead@SLOT=GROUP down@SLOT=ARRAY, ';'-separated); calibration\n\
                 \x20        probes every --probe-interval slots quarantine faulty\n\
                 \x20        converters/arrays after --probe-debounce failures beyond\n\
                 \x20        --probe-tolerance LSB, and serving degrades without stopping;\n\
                 \x20        --shutdown-timeout-ms bounds shutdown — unresponsive workers\n\
                 \x20        are detached and counted (0 waits forever);\n\
                 \x20        --adaptive replaces the static batch closer with the\n\
                 \x20        self-tuning one: the effective batch size walks toward the\n\
                 \x20        served-histogram knee and the close deadline is retuned\n\
                 \x20        against --p99-target-us, 0 = size-only tuning;\n\
                 \x20        --metrics-interval-ms streams one JSON-lines metrics snapshot\n\
                 \x20        per interval to --metrics-out (stderr if omitted), with\n\
                 \x20        per-stage queue-wait/batch-wait/service breakdowns;\n\
                 \x20        --no-telemetry turns stage-span sampling off;\n\
                 \x20        --engine mock serves a trivial artifact-free engine —\n\
                 \x20        hermetic pipeline/telemetry exercise, no trained model)\n\
                 loadgen [--qps N --burst B | --closed --concurrency C] [--requests N]\n\
                 \x20       [--wire] [plus any serve engine/server flags above]\n\
                 \x20       (deterministic load generator against a freshly started\n\
                 \x20        server: the open loop paces offered traffic at --qps in\n\
                 \x20        --burst-sized bursts without waiting on responses\n\
                 \x20        (coordinated-omission honest); --closed keeps --concurrency\n\
                 \x20        requests in flight instead; --wire drives the validated\n\
                 \x20        ingest boundary with encoded frames, QoS-scored by --retain,\n\
                 \x20        optionally through the lossy --channel-* wire model;\n\
                 \x20        with --metrics-interval-ms the run also prints a per-interval\n\
                 \x20        timeline table from the streamed snapshots)\n\
                 compress [--frames N --channels C --side S --classes K --codec-bits B]\n\
                 \x20       (standalone frontend over a synthetic multispectral deluge:\n\
                 \x20        compression-ratio / retained-energy / accuracy tables)\n\
                 report --all | --id <table1|fig1c|fig1d|fig3|fig5|fig6|fig7|fig8|fig10|fig12|fig13> [--out-dir reports]\n\
                 adc    --bits B --mode sar|flash|hybrid [--vdd V]\n\
                 info"
            );
            Ok(())
        }
    }
}

fn load_configs(args: &Args) -> Result<(ChipConfig, ServerConfig)> {
    let mut doc = TomlLite::default();
    if let Some(path) = args.get("config") {
        doc.merge_from(TomlLite::load(path)?);
    }
    Ok((ChipConfig::from_toml(&doc), ServerConfig::from_toml(&doc)))
}

fn cmd_report(args: &Args) -> Result<()> {
    let out_dir = args.get("out-dir");
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
    }
    let ids: Vec<&str> = if args.flag("all") {
        adcim::report::ALL.iter().map(|(n, _)| *n).collect()
    } else if let Some(id) = args.get("id") {
        vec![id]
    } else {
        anyhow::bail!("report: pass --all or --id <name>");
    };
    for id in ids {
        let text = adcim::report::generate(id)
            .ok_or_else(|| anyhow::anyhow!("unknown report id {id}"))?;
        match out_dir {
            Some(dir) => {
                let path = format!("{dir}/{id}.txt");
                std::fs::write(&path, &text)?;
                println!("wrote {path}");
            }
            None => println!("{text}"),
        }
    }
    Ok(())
}

fn cmd_adc(args: &Args) -> Result<()> {
    let bits: u8 = args.get_parse_or("bits", 5);
    let vdd: f64 = args.get_parse_or("vdd", 1.0);
    let mode = match args.get_or("mode", "hybrid") {
        "sar" => ImmersedMode::Sar,
        "flash" => ImmersedMode::Flash,
        _ => ImmersedMode::Hybrid { flash_bits: 2 },
    };
    let mut rng = Rng::new(0xadc);
    let noise = NoiseModel::default();
    let units = (1usize << bits).max(32);
    let mut adc = ImmersedAdc::sample(bits, vdd, mode, units, 20.0, &noise, &mut rng);
    let lin = adcim::adc::metrics::linearity(&mut adc, 32, &mut rng);
    println!(
        "immersed ADC {bits}-bit {:?} @ {vdd} V: max|DNL| {:.3} LSB, max|INL| {:.3} LSB",
        mode,
        lin.max_abs_dnl(),
        lin.max_abs_inl()
    );
    for v in [0.2, 0.5, 0.8] {
        let c = adc.convert(v * vdd, &mut rng);
        println!(
            "  V_in {:.2} -> code {} ({} comparisons, {} cycles, {:.1} fJ)",
            v * vdd,
            c.code,
            c.comparisons,
            c.cycles,
            c.energy_fj
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let (chip, server) = load_configs(args)?;
    println!("chip:   {chip:?}");
    println!("server: {server:?}");
    let dir = args.get("artifacts").map(String::from).unwrap_or_else(|| {
        Artifacts::default_dir().to_string_lossy().into_owned()
    });
    match Artifacts::open(&dir) {
        Ok(a) => {
            let m = a.manifest()?;
            println!(
                "artifacts: {dir} (batch {}, input {}, hidden {}, classes {}, {} params)",
                m.batch,
                m.input,
                m.hidden,
                m.classes,
                m.params.len()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

/// Fold command-line overrides onto the TOML-derived [`ServerConfig`].
/// Shared by `serve` and `loadgen` so both subcommands accept the same
/// engine/server surface.
fn apply_server_flags(args: &Args, server_cfg: &mut ServerConfig) -> Result<()> {
    if let Some(w) = args.get_parse::<usize>("workers") {
        server_cfg.workers = w;
    }
    if let Some(b) = args.get_parse::<usize>("batch") {
        server_cfg.batch = b;
    }
    if let Some(e) = args.get("engine") {
        server_cfg.engine = e.to_string();
    }
    if let Some(t) = args.get_parse::<usize>("threads") {
        server_cfg.engine_threads = t;
    }
    if let Some(p) = args.get_parse::<usize>("pool") {
        server_cfg.pool_arrays = p;
    }
    if let Some(m) = args.get("adc-mode") {
        server_cfg.adc_mode = m.to_string();
    }
    if let Some(b) = args.get_parse::<u8>("adc-bits") {
        server_cfg.adc_bits = b;
    }
    if args.flag("asym") {
        server_cfg.asymmetric_adc = true;
    }
    if let Some(t) = args.get_parse::<usize>("pool-threads") {
        server_cfg.pool_threads = t;
    }
    if args.flag("fuse-batch") {
        server_cfg.fuse_batch = true;
    }
    if args.flag("adaptive") {
        server_cfg.adaptive = true;
    }
    if let Some(t) = parse_flag::<u64>(args, "p99-target-us")? {
        server_cfg.p99_target_us = t;
    }
    if args.flag("frontend") {
        server_cfg.frontend = true;
    }
    if let Some(k) = parse_flag::<usize>(args, "topk")? {
        server_cfg.frontend_topk = k;
    }
    if let Some(s) = args.get("select") {
        server_cfg.frontend_select = s.to_string();
    }
    if let Some(b) = parse_flag::<u8>(args, "codec-bits")? {
        server_cfg.codec_bits = b;
    }
    if let Some(b) = parse_flag::<u8>(args, "sensor-bits")? {
        server_cfg.sensor_bits = b;
    }
    if let Some(r) = args.get("retain") {
        server_cfg.retain = r.to_string();
    }
    if let Some(p) = parse_flag::<f64>(args, "channel-ber")? {
        server_cfg.channel_ber = p;
    }
    if let Some(p) = parse_flag::<f64>(args, "channel-drop")? {
        server_cfg.channel_drop = p;
    }
    if let Some(p) = parse_flag::<f64>(args, "channel-truncate")? {
        server_cfg.channel_truncate = p;
    }
    if let Some(p) = parse_flag::<f64>(args, "channel-duplicate")? {
        server_cfg.channel_duplicate = p;
    }
    if let Some(p) = parse_flag::<f64>(args, "channel-reorder")? {
        server_cfg.channel_reorder = p;
    }
    if let Some(plan) = args.get("fault-plan") {
        server_cfg.fault_plan = plan.to_string();
    }
    if let Some(i) = parse_flag::<u64>(args, "probe-interval")? {
        server_cfg.fault_probe_interval = i;
    }
    if let Some(t) = parse_flag::<u32>(args, "probe-tolerance")? {
        server_cfg.fault_probe_tolerance = t;
    }
    if let Some(d) = parse_flag::<u32>(args, "probe-debounce")? {
        server_cfg.fault_probe_debounce = d;
    }
    if let Some(ms) = parse_flag::<u64>(args, "shutdown-timeout-ms")? {
        server_cfg.shutdown_timeout_ms = ms;
    }
    if args.flag("no-telemetry") {
        server_cfg.telemetry = false;
    }
    if let Some(ms) = parse_flag::<u64>(args, "metrics-interval-ms")? {
        server_cfg.metrics_interval_ms = ms;
    }
    if let Some(path) = args.get("metrics-out") {
        server_cfg.metrics_out = path.to_string();
    }
    Ok(())
}

/// Build the periodic JSONL exporter from the server config, if a
/// cadence was asked for: `--metrics-out PATH` streams to the file
/// (truncating), empty streams to stderr, so stdout tables stay clean.
fn build_sink(server_cfg: &ServerConfig, label: &str) -> Result<Option<TelemetrySink>> {
    if server_cfg.metrics_interval_ms == 0 {
        return Ok(None);
    }
    let out: Box<dyn std::io::Write + Send> = if server_cfg.metrics_out.is_empty() {
        Box::new(std::io::stderr())
    } else {
        Box::new(std::fs::File::create(&server_cfg.metrics_out).map_err(|e| {
            anyhow::anyhow!("cannot open --metrics-out {}: {e}", server_cfg.metrics_out)
        })?)
    };
    Ok(Some(TelemetrySink::new(out, server_cfg.metrics_interval_ms).with_label(label)))
}

/// Build the optional fault-injecting wire channel between the encoder
/// and the coordinator's validated ingest boundary. Any nonzero (or
/// invalid) knob builds a channel so bad values are rejected loudly;
/// all-zero knobs mean no channel at all (the wire path stays a plain
/// function call). Shared by `serve` and `loadgen --wire` so both drive
/// the same lossy link model.
fn build_channel(server_cfg: &ServerConfig) -> Result<Option<Channel>> {
    let cfg = ChannelConfig {
        ber: server_cfg.channel_ber,
        drop_prob: server_cfg.channel_drop,
        truncate_prob: server_cfg.channel_truncate,
        duplicate_prob: server_cfg.channel_duplicate,
        reorder_prob: server_cfg.channel_reorder,
        seed: 0xc4a2,
    };
    let quiet = ChannelConfig { seed: cfg.seed, ..ChannelConfig::default() };
    if cfg == quiet {
        return Ok(None);
    }
    let ch = Channel::new(cfg).map_err(|e| anyhow::anyhow!("invalid channel model: {e}"))?;
    println!(
        "fault-injecting channel: BER {:.2e}, drop {:.2e}, truncate {:.2e}, \
         duplicate {:.2e}, reorder {:.2e}",
        cfg.ber, cfg.drop_prob, cfg.truncate_prob, cfg.duplicate_prob, cfg.reorder_prob
    );
    Ok(Some(ch))
}

/// Build one inference engine per configured worker (analog CiM, with
/// an optional collaborative digitization pool; the digital PJRT path
/// when built with `--features xla`; or `--engine mock` — a trivial
/// artifact-free engine for hermetic pipeline/telemetry exercises).
/// Artifacts are opened per-arm: the mock needs none, so CI can drive
/// the full serving pipeline on a machine with no trained model.
fn build_engines(
    args: &Args,
    chip: &ChipConfig,
    server_cfg: &ServerConfig,
) -> Result<Vec<Box<dyn InferenceEngine>>> {
    let pool = PoolSpec::parse(
        server_cfg.pool_arrays,
        &server_cfg.adc_mode,
        server_cfg.adc_bits,
        server_cfg.asymmetric_adc,
    )
    .map_err(|e| anyhow::anyhow!("invalid pool configuration: {e}"))?
    .map(|spec| PoolSpec {
        threads: server_cfg.pool_threads,
        fuse_batch: server_cfg.fuse_batch,
        ..spec
    });
    if pool.is_some() && server_cfg.engine != "analog" {
        anyhow::bail!(
            "--pool requires --engine analog (the digital PJRT path has no CiM array pool)"
        );
    }
    // Parse the fault plan once, outside the per-worker loop: an
    // unparseable plan is a configuration error, reported before any
    // engine spins up. Probe cadence knobs overlay the parsed plan.
    let fault_plan = if server_cfg.fault_plan.is_empty() {
        None
    } else {
        if pool.is_none() {
            anyhow::bail!(
                "--fault-plan injects into the collaborative digitization pool: \
                 add --pool N (and --engine analog)"
            );
        }
        let mut plan = FaultPlan::parse(&server_cfg.fault_plan)
            .map_err(|e| anyhow::anyhow!("invalid fault plan: {e}"))?;
        plan.probe_interval = server_cfg.fault_probe_interval;
        plan.probe_tolerance = server_cfg.fault_probe_tolerance;
        plan.probe_debounce = server_cfg.fault_probe_debounce;
        plan.validate().map_err(|e| anyhow::anyhow!("invalid fault plan: {e}"))?;
        println!(
            "fault plan: {} injected fault(s), probe every {} slot(s) \
             (tolerance {} LSB, debounce {})",
            plan.faults.len(),
            plan.probe_interval,
            plan.probe_tolerance,
            plan.probe_debounce
        );
        Some(plan)
    };
    let mut engines: Vec<Box<dyn InferenceEngine>> = Vec::new();
    match server_cfg.engine.as_str() {
        "mock" => {
            for _ in 0..server_cfg.workers {
                engines.push(Box::new(adcim::coordinator::engine::MockEngine {
                    classes: 10,
                    input: 64,
                    delay: std::time::Duration::from_micros(200),
                }));
            }
        }
        "analog" => {
            let artifacts = open_artifacts(args)?;
            let cfg = CrossbarConfig { op: chip.operating_point(), ..Default::default() };
            if let Some(spec) = &pool {
                println!(
                    "collaborative digitization pool: {} arrays, {:?} @ {} bits{}, \
                     plane fan-out threads {}{}",
                    spec.n_arrays,
                    spec.mode,
                    spec.adc_bits,
                    if spec.asymmetric { ", asymmetric tree" } else { "" },
                    if spec.threads == 0 { "auto".to_string() } else { spec.threads.to_string() },
                    if spec.fuse_batch { ", cross-sample fusion" } else { "" }
                );
            }
            for w in 0..server_cfg.workers {
                engines.push(Box::new(
                    AnalogEngine::load(&artifacts, cfg, None, 4, w as u64)?
                        .with_threads(server_cfg.engine_threads)
                        .with_pool(pool)?
                        .with_fault_plan(fault_plan.clone())?,
                ));
            }
        }
        _ => {
            #[cfg(feature = "xla")]
            {
                let artifacts = open_artifacts(args)?;
                for _ in 0..server_cfg.workers {
                    engines.push(Box::new(DigitalEngine::load(&artifacts, false)?));
                }
            }
            #[cfg(not(feature = "xla"))]
            anyhow::bail!(
                "the digital (PJRT) engine requires building with --features xla; \
                 this offline build serves with --engine analog (or --engine mock)"
            );
        }
    }
    Ok(engines)
}

fn open_artifacts(args: &Args) -> Result<Artifacts> {
    let dir = args.get("artifacts").map(String::from).unwrap_or_else(|| {
        Artifacts::default_dir().to_string_lossy().into_owned()
    });
    Artifacts::open(&dir)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (chip, mut server_cfg) = load_configs(args)?;
    apply_server_flags(args, &mut server_cfg)?;
    let n_requests: usize = args.get_parse_or("requests", 256);
    let policy = match args.get_or("policy", "rr") {
        "ll" => RoutingPolicy::LeastLoaded,
        "affinity" => RoutingPolicy::StreamAffinity,
        _ => RoutingPolicy::RoundRobin,
    };
    let engines = build_engines(args, &chip, &server_cfg)?;
    let input_dim = engines[0].input_dim();
    println!(
        "serving {n_requests} synthetic frames on {} x {} engine (batch {}, policy {:?})",
        server_cfg.workers,
        engines[0].name(),
        server_cfg.batch,
        policy
    );

    // Optional frequency-domain ingest frontend.
    let mut frontend = if server_cfg.frontend {
        let params =
            CodecParams::new(1, input_dim, server_cfg.sensor_bits, server_cfg.codec_bits)
                .map_err(|e| anyhow::anyhow!("invalid frontend codec: {e}"))?;
        // --select (all|topK|eF) overrides the plain --topk budget.
        let selection = if server_cfg.frontend_select.is_empty() {
            if server_cfg.frontend_topk == 0 {
                Selection::All
            } else {
                Selection::TopK(server_cfg.frontend_topk)
            }
        } else {
            Selection::parse(&server_cfg.frontend_select)
                .map_err(|e| anyhow::anyhow!("invalid --select: {e}"))?
        };
        let policy = RetentionPolicy::parse(&server_cfg.retain)
            .map_err(|e| anyhow::anyhow!("invalid retention policy: {e}"))?;
        println!(
            "sensor frontend: {selection:?}, {} codec bits (0 = lossless), policy {policy:?}",
            server_cfg.codec_bits
        );
        Some(SensorFrontend::new(FrontendConfig {
            policy,
            ..FrontendConfig::new(params, selection)
        }))
    } else {
        None
    };

    // Optional fault-injecting wire channel between the encoder and the
    // coordinator's validated ingest boundary.
    let mut channel = match build_channel(&server_cfg)? {
        Some(ch) => {
            if frontend.is_none() {
                anyhow::bail!(
                    "--channel-ber/--channel-drop (and friends) need --frontend: \
                     faults apply to compressed wire frames"
                );
            }
            Some(ch)
        }
        None => None,
    };

    let engine_name = engines[0].name();
    let mut sink = build_sink(&server_cfg, engine_name)?;
    let server = EdgeServer::start(&server_cfg, engines, policy)?;
    // Synthetic sensor load: digit frames from 4 streams.
    let data = Dataset::digits(n_requests, 12, 0x5e4e);
    let mut submitted = 0u64;
    let mut summaries: Vec<FrameSummary> = Vec::new();
    for (i, img) in data.images.iter().enumerate() {
        let flat = img.clone().reshape(&[input_dim]);
        let stream = (i % 4) as u32;
        match &mut frontend {
            Some(fe) => match fe.ingest(flat.data(), i as u64, stream) {
                IngestDecision::Keep(cf) => match &mut channel {
                    // Kept frames cross the faulty wire as bytes and
                    // re-enter through the validated ingest boundary;
                    // corrupted deliveries bounce off `from_bytes` and
                    // show up as wire rejections in the metrics.
                    Some(ch) => {
                        for (_, wire) in ch.transmit(i as u64, &cf.to_bytes()) {
                            if server.submit_wire(stream, &wire).is_ok() {
                                submitted += 1;
                            }
                        }
                    }
                    None => {
                        if server
                            .submit(InferenceRequest::compressed(i as u64, stream, cf))
                            .is_ok()
                        {
                            submitted += 1;
                        }
                    }
                },
                // Summarized frames shed their pixels but their
                // summaries survive (the bytes_out accounting);
                // dropped frames never reach the queue at all.
                IngestDecision::Summarize(s) => summaries.push(s),
                IngestDecision::Drop => {}
            },
            None => {
                if server
                    .submit(InferenceRequest::new(i as u64, stream, flat.data().to_vec()))
                    .is_ok()
                {
                    submitted += 1;
                }
            }
        }
    }
    if let Some(ch) = &mut channel {
        for (_, wire) in ch.flush() {
            if server.submit_wire(0, &wire).is_ok() {
                submitted += 1;
            }
        }
    }
    if let Some(fe) = &mut frontend {
        server.record_frontend(&fe.take_stats());
    }
    if !summaries.is_empty() {
        let mean_ac = summaries.iter().map(|s| s.ac_energy as f64).sum::<f64>()
            / summaries.len() as f64;
        println!(
            "retained {} frame summaries in place of shed frames (mean AC energy {:.4})",
            summaries.len(),
            mean_ac
        );
    }
    // Collect. A corrupted-but-decodable frame may carry a hostile id,
    // so the label lookup is checked; failure responses never score.
    // Short receive slices keep the telemetry sink on cadence; the run
    // still gives up after 10 idle seconds like before.
    let mut correct = 0usize;
    let mut got = 0u64;
    let mut last_progress = std::time::Instant::now();
    while got < submitted {
        if let Some(s) = sink.as_mut() {
            s.maybe_flush_with(|| server.metrics_snapshot());
        }
        match server.recv_response(std::time::Duration::from_millis(50)) {
            Some(r) => {
                if r.error.is_none()
                    && data.labels.get(r.id as usize).is_some_and(|&l| l == r.class)
                {
                    correct += 1;
                }
                got += 1;
                last_progress = std::time::Instant::now();
            }
            None => {
                if last_progress.elapsed() >= std::time::Duration::from_secs(10) {
                    break;
                }
            }
        }
    }
    if let Some(ch) = &channel {
        println!("{}", ch.stats());
    }
    let shed = server.shed_count();
    let snap = server.shutdown();
    if let Some(s) = sink.as_mut() {
        s.flush_final(&snap);
    }
    println!("{snap}");
    println!(
        "accuracy {:.3} ({correct}/{got}), shed {shed}",
        correct as f64 / got.max(1) as f64
    );
    Ok(())
}

/// Deterministic load generator against a freshly started server.
///
/// Content is seed-stable: the generator cycles through a bank of at
/// most 1024 distinct digit frames, so any `--requests` count offers
/// the same byte-identical traffic. Timing is wall-clock (that is the
/// point of a load test); the exact offered/admitted/shed/malformed
/// accounting identity still holds on every run.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let (chip, mut server_cfg) = load_configs(args)?;
    apply_server_flags(args, &mut server_cfg)?;
    let total: u64 = args.get_parse_or("requests", 1024);
    let mode = if args.flag("closed") {
        LoadMode::Closed { concurrency: args.get_parse_or("concurrency", 32) }
    } else {
        LoadMode::Open {
            qps: args.get_parse_or("qps", 2000),
            burst: args.get_parse_or("burst", 1),
        }
    };
    let policy = match args.get_or("policy", "rr") {
        "ll" => RoutingPolicy::LeastLoaded,
        "affinity" => RoutingPolicy::StreamAffinity,
        _ => RoutingPolicy::RoundRobin,
    };
    let engines = build_engines(args, &chip, &server_cfg)?;
    let input_dim = engines[0].input_dim();
    println!(
        "loadgen: {total} frames, {mode:?}, {} x {} engine (batch {}, adaptive {})",
        server_cfg.workers,
        engines[0].name(),
        server_cfg.batch,
        server_cfg.adaptive
    );
    let engine_name = engines[0].name();
    let mut sink = build_sink(&server_cfg, engine_name)?;
    let server = EdgeServer::start(&server_cfg, engines, policy)?;

    // Deterministic frame bank the generator cycles through.
    let distinct = (total as usize).clamp(1, 1024);
    let data = Dataset::digits(distinct, 12, 0x10ad);
    let frames: Vec<Vec<f32>> = data
        .images
        .iter()
        .map(|img| img.clone().reshape(&[input_dim]).data().to_vec())
        .collect();
    let spec = LoadSpec { mode, total, drain: std::time::Duration::from_secs(10) };

    let report = if args.flag("wire") {
        // Drive the validated ingest boundary with encoded wire bytes;
        // the server scores each frame's QoS priority from --retain.
        // With any --channel-* knob set, the bytes cross the lossy link
        // first: corrupted deliveries bounce off ingest as malformed,
        // wire-dropped frames count as admitted here (the generator
        // offered them; the channel stats line owns the loss).
        let params =
            CodecParams::new(1, input_dim, server_cfg.sensor_bits, server_cfg.codec_bits)
                .map_err(|e| anyhow::anyhow!("invalid frontend codec: {e}"))?;
        let mut enc = FrameEncoder::new(params, Selection::All);
        let wires: Vec<Vec<u8>> = frames
            .iter()
            .enumerate()
            .map(|(i, f)| enc.encode_wire(f, i as u64))
            .collect();
        let mut channel = build_channel(&server_cfg)?;
        let report = loadgen::run_with_tick(
            &server,
            &spec,
            |i| {
                let stream = (i % 4) as u32;
                let wire = &wires[i as usize % distinct];
                match channel.as_mut() {
                    Some(ch) => {
                        let mut res = Ok(());
                        for (_, bytes) in ch.transmit(i, wire) {
                            if let Err(e) = server.submit_wire(stream, &bytes) {
                                res = Err(e);
                            }
                        }
                        res
                    }
                    None => server.submit_wire(stream, wire).map(|_| ()),
                }
            },
            || {
                if let Some(s) = sink.as_mut() {
                    s.maybe_flush_with(|| server.metrics_snapshot());
                }
            },
        );
        if let Some(ch) = &mut channel {
            // Release a held-back reordered frame; its response (if any)
            // lands outside the report's drain window, which is honest
            // for a frame the link delivered after end of stream.
            for (_, bytes) in ch.flush() {
                let _ = server.submit_wire(0, &bytes);
            }
            println!("{}", ch.stats());
        }
        report
    } else {
        loadgen::run_with_tick(
            &server,
            &spec,
            |i| {
                let frame = frames[i as usize % distinct].clone();
                server.submit(InferenceRequest::new(i, (i % 4) as u32, frame))
            },
            || {
                if let Some(s) = sink.as_mut() {
                    s.maybe_flush_with(|| server.metrics_snapshot());
                }
            },
        )
    };

    // Score completed responses against the bank's labels; failure
    // responses never score.
    let mut correct = 0usize;
    let mut scored = 0usize;
    for r in &report.responses {
        if r.error.is_none() {
            if data.labels.get(r.id as usize % distinct).is_some_and(|&l| l == r.class) {
                correct += 1;
            }
            scored += 1;
        }
    }
    let snap = server.shutdown();
    if let Some(s) = sink.as_mut() {
        s.flush_final(&snap);
        print_timeline(s);
    }
    println!("{report}");
    println!("{snap}");
    println!("accuracy {:.3} ({correct}/{scored})", correct as f64 / scored.max(1) as f64);
    Ok(())
}

/// Per-interval timeline table from the exporter's retained rows: what
/// the run looked like over time, not just in aggregate — when the
/// admission ramp started shedding, where the p99 spiked, how much the
/// engines fused.
fn print_timeline(sink: &TelemetrySink) {
    let rows = sink.rows();
    if rows.is_empty() {
        return;
    }
    println!(
        "{:>9} {:>8} {:>9} {:>6} {:>6} {:>10} {:>8} {:>6}",
        "t_ms", "offered", "admitted", "shed", "bad", "completed", "p99_us", "fused"
    );
    for r in rows {
        println!(
            "{:>9.1} {:>8} {:>9} {:>6} {:>6} {:>10} {:>8} {:>6}",
            r.t_ms, r.offered, r.admitted, r.shed, r.malformed, r.completed, r.p99_us, r.fused
        );
    }
}

/// Standalone frontend demo: encode a synthetic multispectral deluge at
/// several selection budgets and print the compression-ratio /
/// retained-energy / reconstruction-error / accuracy table (accuracy
/// from a small classifier trained on the raw frames).
fn cmd_compress(args: &Args) -> Result<()> {
    let n_frames: usize = args.get_parse_or("frames", 400);
    let channels: usize = args.get_parse_or("channels", 4);
    let side: usize = args.get_parse_or("side", 8);
    let classes: usize = args.get_parse_or("classes", 4);
    let codec_bits: u8 = args.get_parse_or("codec-bits", 8);
    let sensor_bits: u8 = args.get_parse_or("sensor-bits", 8);
    let samples = side * side;
    let input = channels * samples;

    println!(
        "multispectral deluge: {n_frames} frames, {channels} ch x {side}x{side}, \
         {classes} classes"
    );
    let data = Dataset::multispectral(n_frames, classes, side, channels, 0xde1);
    let (tr, te) = data.split(0.8);
    let (tr, te) = (tr.flattened(), te.flattened());

    let mut classifier = model::bwht_mlp(input, classes, 32, &mut Rng::new(7));
    let log = train(
        &mut classifier,
        &tr,
        &te,
        TrainConfig { epochs: 5, lr: 0.06, ..Default::default() },
    );
    let raw_acc = *log.epoch_test_acc.last().unwrap();
    println!("classifier trained on raw frames: test accuracy {raw_acc:.3}\n");

    let selections: &[(&str, u8, Selection)] = &[
        ("all lossless", adcim::frontend::LOSSLESS, Selection::All),
        ("all", codec_bits, Selection::All),
        ("e0.98", codec_bits, Selection::EnergyFrac(0.98)),
        ("top64", codec_bits, Selection::TopK(64)),
        ("top32", codec_bits, Selection::TopK(32)),
        ("top16", codec_bits, Selection::TopK(16)),
        ("top8", codec_bits, Selection::TopK(8)),
    ];
    println!(
        "{:<14} {:>10} {:>12} {:>8} {:>10} {:>10} {:>8}",
        "selection", "kept/frame", "bytes/frame", "ratio", "retained", "rmse", "acc"
    );
    let raw_bytes = input * 4;
    for (label, bits, selection) in selections {
        let params = CodecParams::new(channels, samples, sensor_bits, *bits)
            .map_err(|e| anyhow::anyhow!("codec: {e}"))?;
        let mut enc = FrameEncoder::new(params, *selection);
        let mut bytes = 0usize;
        let mut kept = 0usize;
        let mut retained = 0.0f64;
        let mut err_sq = 0.0f64;
        let mut n_vals = 0usize;
        let mut correct = 0usize;
        for (i, (img, &label_i)) in te.images.iter().zip(&te.labels).enumerate() {
            let cf = enc.encode(img.data(), i as u64);
            bytes += cf.encoded_bytes();
            kept += cf.kept;
            retained += cf.retained_energy as f64;
            let dec = cf.decode();
            for (a, &b) in dec.iter().zip(img.data()) {
                let d = (a - params.snap(b)) as f64;
                err_sq += d * d;
            }
            n_vals += dec.len();
            let logits = classifier.forward_inference(&Tensor::vec1(&dec));
            if logits.argmax() == label_i {
                correct += 1;
            }
        }
        let n = te.len().max(1);
        println!(
            "{label:<14} {:>10.1} {:>12.1} {:>7.1}x {:>10.3} {:>10.5} {:>8.3}",
            kept as f64 / n as f64,
            bytes as f64 / n as f64,
            raw_bytes as f64 * n as f64 / bytes.max(1) as f64,
            retained / n as f64,
            (err_sq / n_vals.max(1) as f64).sqrt(),
            correct as f64 / n as f64
        );
    }

    // Retention triage over a mixed deluge: the multispectral frames
    // plus blank/noise filler the policy should shed.
    let params = CodecParams::new(channels, samples, sensor_bits, codec_bits)
        .map_err(|e| anyhow::anyhow!("codec: {e}"))?;
    let mut fe = SensorFrontend::new(FrontendConfig {
        policy: RetentionPolicy::triage_default(),
        ..FrontendConfig::new(params, Selection::TopK(16))
    });
    let mut rng = Rng::new(0xb1a);
    let mut id = 0u64;
    for img in &te.images {
        fe.ingest(img.data(), id, 0);
        id += 1;
        // One blank-ish filler frame per real frame.
        let blank: Vec<f32> =
            (0..input).map(|_| (0.5 + 0.01 * rng.normal()) as f32).collect();
        fe.ingest(&blank, id, 0);
        id += 1;
    }
    println!("\ntriage over a 50% blank deluge: {}", fe.stats());
    Ok(())
}
