//! `adcim` — leader binary: serve, report, characterize, sweep.
//!
//! Subcommands:
//!   serve     run the edge-inference server on a synthetic sensor load
//!   report    regenerate paper tables/figures (--all or --id fig7)
//!   adc       one-off ADC characterization (staircase/linearity)
//!   info      print chip/model/artifact status

use adcim::adc::{Adc, ImmersedAdc, ImmersedMode};
use adcim::analog::NoiseModel;
use adcim::cim::{CrossbarConfig, PoolSpec};
use adcim::config::{ChipConfig, ServerConfig, TomlLite};
#[cfg(feature = "xla")]
use adcim::coordinator::DigitalEngine;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::nn::dataset::Dataset;
use adcim::runtime::Artifacts;
use adcim::util::cli::Args;
use adcim::util::Rng;
use anyhow::Result;

const VALUE_KEYS: &[&str] = &[
    "id", "out-dir", "config", "engine", "workers", "requests", "batch", "vdd", "clock",
    "bits", "mode", "artifacts", "policy", "threads", "pool", "adc-mode", "adc-bits",
    "pool-threads",
];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUE_KEYS);
    match args.positional().first().map(String::as_str) {
        Some("serve") => cmd_serve(&args),
        Some("report") => cmd_report(&args),
        Some("adc") => cmd_adc(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: adcim <serve|report|adc|info> [--config file.toml]\n\
                 \n\
                 serve  --engine digital|analog --workers N --requests N [--policy rr|ll|affinity]\n\
                 \x20       [--pool N --adc-mode sar|flash|hybrid --adc-bits B --asym]\n\
                 \x20       [--pool-threads T]\n\
                 \x20       (--pool N serves the analog BWHT stages through an N-array\n\
                 \x20        collaborative digitization pool; 0/omitted = ADC-free 1-bit path;\n\
                 \x20        --pool-threads T fans the pool's coupling groups across T worker\n\
                 \x20        threads per phase, 0 = auto — results are thread-count invariant)\n\
                 report --all | --id <table1|fig1c|fig1d|fig3|fig5|fig6|fig7|fig8|fig10|fig12|fig13> [--out-dir reports]\n\
                 adc    --bits B --mode sar|flash|hybrid [--vdd V]\n\
                 info"
            );
            Ok(())
        }
    }
}

fn load_configs(args: &Args) -> Result<(ChipConfig, ServerConfig)> {
    let mut doc = TomlLite::default();
    if let Some(path) = args.get("config") {
        doc.merge_from(TomlLite::load(path)?);
    }
    Ok((ChipConfig::from_toml(&doc), ServerConfig::from_toml(&doc)))
}

fn cmd_report(args: &Args) -> Result<()> {
    let out_dir = args.get("out-dir");
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
    }
    let ids: Vec<&str> = if args.flag("all") {
        adcim::report::ALL.iter().map(|(n, _)| *n).collect()
    } else if let Some(id) = args.get("id") {
        vec![id]
    } else {
        anyhow::bail!("report: pass --all or --id <name>");
    };
    for id in ids {
        let text = adcim::report::generate(id)
            .ok_or_else(|| anyhow::anyhow!("unknown report id {id}"))?;
        match out_dir {
            Some(dir) => {
                let path = format!("{dir}/{id}.txt");
                std::fs::write(&path, &text)?;
                println!("wrote {path}");
            }
            None => println!("{text}"),
        }
    }
    Ok(())
}

fn cmd_adc(args: &Args) -> Result<()> {
    let bits: u8 = args.get_parse_or("bits", 5);
    let vdd: f64 = args.get_parse_or("vdd", 1.0);
    let mode = match args.get_or("mode", "hybrid") {
        "sar" => ImmersedMode::Sar,
        "flash" => ImmersedMode::Flash,
        _ => ImmersedMode::Hybrid { flash_bits: 2 },
    };
    let mut rng = Rng::new(0xadc);
    let noise = NoiseModel::default();
    let units = (1usize << bits).max(32);
    let mut adc = ImmersedAdc::sample(bits, vdd, mode, units, 20.0, &noise, &mut rng);
    let lin = adcim::adc::metrics::linearity(&mut adc, 32, &mut rng);
    println!(
        "immersed ADC {bits}-bit {:?} @ {vdd} V: max|DNL| {:.3} LSB, max|INL| {:.3} LSB",
        mode,
        lin.max_abs_dnl(),
        lin.max_abs_inl()
    );
    for v in [0.2, 0.5, 0.8] {
        let c = adc.convert(v * vdd, &mut rng);
        println!(
            "  V_in {:.2} -> code {} ({} comparisons, {} cycles, {:.1} fJ)",
            v * vdd,
            c.code,
            c.comparisons,
            c.cycles,
            c.energy_fj
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let (chip, server) = load_configs(args)?;
    println!("chip:   {chip:?}");
    println!("server: {server:?}");
    let dir = args.get("artifacts").map(String::from).unwrap_or_else(|| {
        Artifacts::default_dir().to_string_lossy().into_owned()
    });
    match Artifacts::open(&dir) {
        Ok(a) => {
            let m = a.manifest()?;
            println!(
                "artifacts: {dir} (batch {}, input {}, hidden {}, classes {}, {} params)",
                m.batch,
                m.input,
                m.hidden,
                m.classes,
                m.params.len()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (chip, mut server_cfg) = load_configs(args)?;
    if let Some(w) = args.get_parse::<usize>("workers") {
        server_cfg.workers = w;
    }
    if let Some(b) = args.get_parse::<usize>("batch") {
        server_cfg.batch = b;
    }
    if let Some(e) = args.get("engine") {
        server_cfg.engine = e.to_string();
    }
    if let Some(t) = args.get_parse::<usize>("threads") {
        server_cfg.engine_threads = t;
    }
    if let Some(p) = args.get_parse::<usize>("pool") {
        server_cfg.pool_arrays = p;
    }
    if let Some(m) = args.get("adc-mode") {
        server_cfg.adc_mode = m.to_string();
    }
    if let Some(b) = args.get_parse::<u8>("adc-bits") {
        server_cfg.adc_bits = b;
    }
    if args.flag("asym") {
        server_cfg.asymmetric_adc = true;
    }
    if let Some(t) = args.get_parse::<usize>("pool-threads") {
        server_cfg.pool_threads = t;
    }
    let n_requests: usize = args.get_parse_or("requests", 256);
    let policy = match args.get_or("policy", "rr") {
        "ll" => RoutingPolicy::LeastLoaded,
        "affinity" => RoutingPolicy::StreamAffinity,
        _ => RoutingPolicy::RoundRobin,
    };
    let dir = args.get("artifacts").map(String::from).unwrap_or_else(|| {
        Artifacts::default_dir().to_string_lossy().into_owned()
    });
    let artifacts = Artifacts::open(&dir)?;

    // Build one engine per worker.
    let pool = PoolSpec::parse(
        server_cfg.pool_arrays,
        &server_cfg.adc_mode,
        server_cfg.adc_bits,
        server_cfg.asymmetric_adc,
    )
    .map_err(|e| anyhow::anyhow!("invalid pool configuration: {e}"))?
    .map(|spec| PoolSpec { threads: server_cfg.pool_threads, ..spec });
    if pool.is_some() && server_cfg.engine != "analog" {
        anyhow::bail!(
            "--pool requires --engine analog (the digital PJRT path has no CiM array pool)"
        );
    }
    let mut engines: Vec<Box<dyn InferenceEngine>> = Vec::new();
    match server_cfg.engine.as_str() {
        "analog" => {
            let cfg = CrossbarConfig { op: chip.operating_point(), ..Default::default() };
            if let Some(spec) = &pool {
                println!(
                    "collaborative digitization pool: {} arrays, {:?} @ {} bits{}, \
                     plane fan-out threads {}",
                    spec.n_arrays,
                    spec.mode,
                    spec.adc_bits,
                    if spec.asymmetric { ", asymmetric tree" } else { "" },
                    if spec.threads == 0 { "auto".to_string() } else { spec.threads.to_string() }
                );
            }
            for w in 0..server_cfg.workers {
                engines.push(Box::new(
                    AnalogEngine::load(&artifacts, cfg, None, 4, w as u64)?
                        .with_threads(server_cfg.engine_threads)
                        .with_pool(pool)?,
                ));
            }
        }
        _ => {
            #[cfg(feature = "xla")]
            for _ in 0..server_cfg.workers {
                engines.push(Box::new(DigitalEngine::load(&artifacts, false)?));
            }
            #[cfg(not(feature = "xla"))]
            anyhow::bail!(
                "the digital (PJRT) engine requires building with --features xla; \
                 this offline build serves with --engine analog"
            );
        }
    }
    let input_dim = engines[0].input_dim();
    println!(
        "serving {n_requests} synthetic frames on {} x {} engine (batch {}, policy {:?})",
        server_cfg.workers,
        engines[0].name(),
        server_cfg.batch,
        policy
    );

    let server = EdgeServer::start(&server_cfg, engines, policy)?;
    // Synthetic sensor load: digit frames from 4 streams.
    let data = Dataset::digits(n_requests, 12, 0x5e4e);
    let mut submitted = 0u64;
    for (i, img) in data.images.iter().enumerate() {
        let flat = img.clone().reshape(&[input_dim]);
        if server.submit(InferenceRequest::new(i as u64, (i % 4) as u32, flat.data().to_vec())) {
            submitted += 1;
        }
    }
    // Collect.
    let mut correct = 0usize;
    let mut got = 0u64;
    while got < submitted {
        match server.recv_response(std::time::Duration::from_secs(10)) {
            Some(r) => {
                if r.class == data.labels[r.id as usize] {
                    correct += 1;
                }
                got += 1;
            }
            None => break,
        }
    }
    let shed = server.shed_count();
    let snap = server.shutdown();
    println!("{snap}");
    println!(
        "accuracy {:.3} ({correct}/{got}), shed {shed}",
        correct as f64 / got.max(1) as f64
    );
    Ok(())
}
