//! Technology-node scaling.
//!
//! The paper compares a 65 nm implementation against 40 nm baselines, so
//! cross-node comparisons need explicit scaling rules. We use standard
//! first-order rules: area ∝ node², switching energy ∝ node · VDD²
//! (capacitance per unit structure ∝ node).

/// A CMOS technology node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TechNode {
    /// Feature size (nm).
    pub nm: f64,
    /// Nominal supply (V).
    pub vdd_nom: f64,
}

impl TechNode {
    /// The paper's chip: 65 nm, 1.0 V nominal.
    pub fn n65() -> Self {
        TechNode { nm: 65.0, vdd_nom: 1.0 }
    }

    /// The baseline ADCs of [34]: 40 nm, 0.9 V nominal.
    pub fn n40() -> Self {
        TechNode { nm: 40.0, vdd_nom: 0.9 }
    }

    /// Predictive 16 nm node (the PTM library of the paper's Fig 3 sims).
    pub fn n16() -> Self {
        TechNode { nm: 16.0, vdd_nom: 0.85 }
    }

    /// Area scale factor relative to `other` (this / other).
    pub fn area_scale_vs(&self, other: TechNode) -> f64 {
        (self.nm / other.nm).powi(2)
    }

    /// Switching-energy scale factor relative to `other`.
    pub fn energy_scale_vs(&self, other: TechNode) -> f64 {
        (self.nm / other.nm) * (self.vdd_nom / other.vdd_nom).powi(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_node_scales_to_one() {
        let t = TechNode::n65();
        assert_eq!(t.area_scale_vs(t), 1.0);
        assert_eq!(t.energy_scale_vs(t), 1.0);
    }

    #[test]
    fn bigger_node_is_bigger_and_hungrier() {
        let a = TechNode::n65().area_scale_vs(TechNode::n40());
        assert!((a - (65.0f64 / 40.0).powi(2)).abs() < 1e-12);
        assert!(a > 2.6 && a < 2.7);
        let e = TechNode::n65().energy_scale_vs(TechNode::n40());
        assert!(e > 1.0, "65nm at 1.0V costs more energy per op than 40nm at 0.9V");
    }
}
