//! ADC and SRAM-array area models (paper Table I, Fig 13(a)).
//!
//! Component-based area accounting, calibrated so the 5-bit points land
//! exactly on the paper's Table I anchors:
//!
//! | style            | tech  | 5-bit area (µm²) |
//! |------------------|-------|------------------|
//! | SAR [34]         | 40 nm | 5235.20          |
//! | Flash [34]       | 40 nm | 10703.36         |
//! | In-memory (ours) | 65 nm | 207.8            |
//!
//! Structure drives the scaling: a SAR needs a binary-weighted capacitor
//! bank (∝ 2^bits) plus per-bit SAR logic; a Flash needs 2^bits − 1
//! comparators with a resistive ladder; the memory-immersed converter
//! needs only a comparator and a precharge-array tweak — its "capacitor
//! bank" is the neighbouring array's parasitic column lines, which the
//! floorplan already pays for.

/// Converter style for area/energy/latency queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdcStyle {
    /// Conventional SAR with dedicated cap DAC (40 nm baseline, [34]).
    Sar,
    /// Conventional Flash (40 nm baseline, [34]).
    Flash,
    /// The paper's SRAM-immersed converter (65 nm), SAR-mode networking.
    InMemorySar,
    /// SRAM-immersed, hybrid Flash+SAR networking (2 flash bits).
    InMemoryHybrid,
}

impl AdcStyle {
    /// Every modelled digitization style.
    pub const ALL: [AdcStyle; 4] =
        [AdcStyle::Sar, AdcStyle::Flash, AdcStyle::InMemorySar, AdcStyle::InMemoryHybrid];

    /// Display name of the style.
    pub fn name(&self) -> &'static str {
        match self {
            AdcStyle::Sar => "SAR (40nm, [34])",
            AdcStyle::Flash => "Flash (40nm, [34])",
            AdcStyle::InMemorySar => "In-Memory SAR (65nm, ours)",
            AdcStyle::InMemoryHybrid => "In-Memory Hybrid (65nm, ours)",
        }
    }
}

// Calibration constants (µm²). Derivations in the module docs: each
// style's 5-bit total hits the Table I anchor.
const SAR_CAP_UNIT_UM2: f64 = 120.0; // per unit cap of the 2^b bank
const SAR_LOGIC_PER_BIT_UM2: f64 = 200.0;
const SAR_CMP_UM2: f64 = 395.2;
const FLASH_CMP_UM2: f64 = 330.0; // per flash comparator
const FLASH_ENC_PER_BIT_UM2: f64 = 94.672;
const IMEM_FIXED_UM2: f64 = 150.0; // comparator + precharge modification
const IMEM_PER_BIT_UM2: f64 = 11.56; // SAR sequencing logic

/// Area in µm² for a converter of `style` at `bits` resolution (in the
/// style's native technology, as reported by the paper).
pub fn adc_area_um2(style: AdcStyle, bits: u8) -> f64 {
    let b = bits as f64;
    match style {
        AdcStyle::Sar => {
            SAR_CAP_UNIT_UM2 * (1u64 << bits) as f64 + SAR_LOGIC_PER_BIT_UM2 * b + SAR_CMP_UM2
        }
        AdcStyle::Flash => {
            FLASH_CMP_UM2 * ((1u64 << bits) - 1) as f64 + FLASH_ENC_PER_BIT_UM2 * b
        }
        // Both immersed modes share the same per-array silicon: the
        // flash-mode "extra" references live in *other* arrays.
        AdcStyle::InMemorySar | AdcStyle::InMemoryHybrid => {
            IMEM_FIXED_UM2 + IMEM_PER_BIT_UM2 * b
        }
    }
}

/// Area of an 8T compute-in-SRAM array (µm²): 8T cell ≈ 160 F² where
/// F is the feature size in µm.
pub fn sram_array_area_um2(rows: usize, cols: usize, tech_nm: f64) -> f64 {
    let f_um = tech_nm / 1000.0;
    160.0 * f_um * f_um * (rows * cols) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_area_anchors() {
        // Exact Table I reproduction at 5 bits.
        assert!((adc_area_um2(AdcStyle::Sar, 5) - 5235.2).abs() < 0.5);
        assert!((adc_area_um2(AdcStyle::Flash, 5) - 10703.36).abs() < 0.5);
        assert!((adc_area_um2(AdcStyle::InMemorySar, 5) - 207.8).abs() < 0.5);
    }

    #[test]
    fn paper_area_ratios() {
        // "~25× less area than SAR, ~51× less than Flash".
        let ours = adc_area_um2(AdcStyle::InMemorySar, 5);
        let sar = adc_area_um2(AdcStyle::Sar, 5) / ours;
        let flash = adc_area_um2(AdcStyle::Flash, 5) / ours;
        assert!((24.0..27.0).contains(&sar), "SAR ratio {sar}");
        assert!((49.0..53.0).contains(&flash), "Flash ratio {flash}");
    }

    #[test]
    fn flash_area_grows_exponentially() {
        // Fig 13(a): flash doubles per bit; immersed stays near flat.
        let f6 = adc_area_um2(AdcStyle::Flash, 6) / adc_area_um2(AdcStyle::Flash, 5);
        assert!(f6 > 1.9, "flash 5→6 bit growth {f6}");
        let m6 = adc_area_um2(AdcStyle::InMemorySar, 6) / adc_area_um2(AdcStyle::InMemorySar, 5);
        assert!(m6 < 1.1, "immersed growth {m6}");
    }

    #[test]
    fn sar_area_dominated_by_cap_bank_at_high_bits() {
        let a8 = adc_area_um2(AdcStyle::Sar, 8);
        let cap = SAR_CAP_UNIT_UM2 * 256.0;
        assert!(cap / a8 > 0.9);
    }

    #[test]
    fn sram_area_scales_with_cells_and_node() {
        let a = sram_array_area_um2(16, 32, 65.0);
        assert!((a - 160.0 * 0.065 * 0.065 * 512.0).abs() < 1e-9);
        assert!(sram_array_area_um2(16, 32, 40.0) < a);
    }
}
