//! ADC conversion energy and latency models (Table I, Fig 13(b)).
//!
//! Calibrated to the paper's Table I energy anchors at 5 bits / 10 MHz:
//! SAR 105 pJ, Flash 952 pJ, in-memory 74.23 pJ. Structure:
//!
//! - **SAR** — cap-bank switching energy ∝ 2^bits plus per-cycle
//!   comparator + SAR-logic energy ∝ bits.
//! - **Flash** — every one of the 2^bits − 1 comparators fires each
//!   conversion, plus static ladder burn over the conversion window.
//! - **In-memory** — per cycle: one column-line charge share (the
//!   "DAC") + one comparator decision; no dedicated DAC to charge, so
//!   the per-cycle cost is small and flat in bits.

use super::area::AdcStyle;

// Energy calibration constants (pJ). Each style's 5-bit total hits the
// Table I anchor; see tests.
const SAR_CAP_UNIT_PJ: f64 = 2.5; // per unit of the 2^b bank
const SAR_PER_BIT_PJ: f64 = 5.0; // comparator + logic per cycle
const FLASH_CMP_PJ: f64 = 28.0; // per comparator per conversion
const FLASH_LADDER_PJ: f64 = 84.0; // static ladder per conversion
const IMEM_PER_CYCLE_PJ: f64 = 14.0; // share + comparator + precharge drive
const IMEM_FIXED_PJ: f64 = 4.23; // sequencing / clocking

/// Energy per conversion in pJ at the Table I operating point
/// (10 MHz clock, nominal supply of the style's native node).
pub fn adc_energy_pj(style: AdcStyle, bits: u8) -> f64 {
    let b = bits as f64;
    match style {
        AdcStyle::Sar => SAR_CAP_UNIT_PJ * (1u64 << bits) as f64 + SAR_PER_BIT_PJ * b,
        AdcStyle::Flash => FLASH_CMP_PJ * ((1u64 << bits) - 1) as f64 + FLASH_LADDER_PJ,
        AdcStyle::InMemorySar => IMEM_PER_CYCLE_PJ * b + IMEM_FIXED_PJ,
        AdcStyle::InMemoryHybrid => {
            // One flash cycle (3 parallel shares + comparators at the
            // 2-bit coarse stage) then b−2 SAR cycles.
            let flash_cycle = 3.0 * IMEM_PER_CYCLE_PJ * 0.9; // shared precharge clocking
            flash_cycle + IMEM_PER_CYCLE_PJ * (b - 2.0) + IMEM_FIXED_PJ
        }
    }
}

/// Conversion latency in clock cycles.
pub fn adc_latency_cycles(style: AdcStyle, bits: u8) -> u32 {
    match style {
        AdcStyle::Sar | AdcStyle::InMemorySar => bits as u32,
        AdcStyle::Flash => 1,
        AdcStyle::InMemoryHybrid => 1 + (bits as u32).saturating_sub(2),
    }
}

/// Conversion latency in ns at `clock_mhz`.
pub fn adc_latency_ns(style: AdcStyle, bits: u8, clock_mhz: f64) -> f64 {
    adc_latency_cycles(style, bits) as f64 * 1000.0 / clock_mhz
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_energy_anchors() {
        assert!((adc_energy_pj(AdcStyle::Sar, 5) - 105.0).abs() < 0.5);
        assert!((adc_energy_pj(AdcStyle::Flash, 5) - 952.0).abs() < 0.5);
        assert!((adc_energy_pj(AdcStyle::InMemorySar, 5) - 74.23).abs() < 0.5);
    }

    #[test]
    fn paper_energy_ratios() {
        // "~1.4× less energy than SAR, ~13× less than Flash".
        let ours = adc_energy_pj(AdcStyle::InMemorySar, 5);
        let sar = adc_energy_pj(AdcStyle::Sar, 5) / ours;
        let flash = adc_energy_pj(AdcStyle::Flash, 5) / ours;
        assert!((1.3..1.6).contains(&sar), "SAR ratio {sar}");
        assert!((12.0..14.0).contains(&flash), "Flash ratio {flash}");
    }

    #[test]
    fn latency_shapes_match_fig13b() {
        // SAR latency grows linearly with precision; Flash is flat;
        // hybrid sits between (the paper's "middle ground").
        for bits in 3..=8u8 {
            let sar = adc_latency_cycles(AdcStyle::Sar, bits);
            let flash = adc_latency_cycles(AdcStyle::Flash, bits);
            let hybrid = adc_latency_cycles(AdcStyle::InMemoryHybrid, bits);
            assert_eq!(sar, bits as u32);
            assert_eq!(flash, 1);
            assert!(hybrid < sar && hybrid > flash, "bits={bits}");
        }
    }

    #[test]
    fn flash_energy_explodes_with_bits() {
        let r = adc_energy_pj(AdcStyle::Flash, 8) / adc_energy_pj(AdcStyle::Flash, 5);
        assert!(r > 7.0, "flash 5→8 bit energy growth {r}");
        let m = adc_energy_pj(AdcStyle::InMemorySar, 8) / adc_energy_pj(AdcStyle::InMemorySar, 5);
        assert!(m < 1.7, "immersed growth {m}");
    }

    #[test]
    fn latency_ns_at_10mhz() {
        assert!((adc_latency_ns(AdcStyle::Sar, 5, 10.0) - 500.0).abs() < 1e-9);
        assert!((adc_latency_ns(AdcStyle::Flash, 5, 10.0) - 100.0).abs() < 1e-9);
    }
}
