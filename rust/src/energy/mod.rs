//! Area / energy / latency models (paper Table I, Fig 13(a,b)).
//!
//! Anchored to the paper's published numbers: a 40 nm 5-bit SAR ADC
//! (5235.20 µm², 105 pJ) and 5-bit Flash ADC (10703.36 µm², 952 pJ) from
//! [34], versus the paper's 65 nm memory-immersed converter
//! (207.8 µm², 74.23 pJ) at a 10 MHz clock. The *structural* scaling in
//! bits (exponential capacitor bank / comparator count vs near-constant
//! immersed overhead) is what regenerates Fig 13(a,b).

pub mod area;
pub mod power;
pub mod tech;

pub use area::{adc_area_um2, sram_array_area_um2, AdcStyle};
pub use power::{adc_energy_pj, adc_latency_cycles, adc_latency_ns};
pub use tech::TechNode;
