//! Training: SGD + momentum, softmax cross-entropy, evaluation.
//!
//! Drives the learning-side reproductions: float baselines vs
//! quantization-aware training (Fig 5), threshold-regularised training
//! for early termination (Fig 6), and the compression sweep (Fig 1(c)).

use crate::util::Rng;

use super::dataset::Dataset;
use super::model::Sequential;
use super::tensor::Tensor;

/// Softmax + cross-entropy; returns (loss, grad wrt logits).
pub fn softmax_ce(logits: &Tensor, label: usize) -> (f32, Tensor) {
    let max = logits.data().iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = logits.data().iter().map(|&v| (v - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    let probs: Vec<f32> = exps.iter().map(|&e| e / sum).collect();
    let loss = -(probs[label].max(1e-9)).ln();
    let mut grad = probs;
    grad[label] -= 1.0;
    (loss, Tensor::vec1(&grad))
}

/// Training hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    /// Full passes over the training split.
    pub epochs: usize,
    /// Initial learning rate.
    pub lr: f32,
    /// Minibatch size.
    pub batch: usize,
    /// Shuffle/init seed.
    pub seed: u64,
    /// LR decay factor applied each epoch.
    pub lr_decay: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 8, lr: 0.05, batch: 16, seed: 0xace, lr_decay: 0.85 }
    }
}

/// Per-epoch training record.
#[derive(Debug, Clone)]
pub struct TrainLog {
    /// Mean training loss per epoch.
    pub epoch_loss: Vec<f32>,
    /// Training-split accuracy per epoch.
    pub epoch_train_acc: Vec<f64>,
    /// Test-split accuracy per epoch.
    pub epoch_test_acc: Vec<f64>,
}

/// Train `model` on `train_set`, evaluating on `test_set` each epoch.
pub fn train(
    model: &mut Sequential,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: TrainConfig,
) -> TrainLog {
    let mut rng = Rng::new(cfg.seed);
    let mut order: Vec<usize> = (0..train_set.len()).collect();
    let mut log = TrainLog {
        epoch_loss: Vec::new(),
        epoch_train_acc: Vec::new(),
        epoch_test_acc: Vec::new(),
    };
    let mut lr = cfg.lr;
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        let mut in_batch = 0usize;
        for &i in &order {
            let x = &train_set.images[i];
            let label = train_set.labels[i];
            let logits = model.forward(x);
            if logits.argmax() == label {
                correct += 1;
            }
            let (loss, grad) = softmax_ce(&logits, label);
            loss_sum += loss;
            model.backward(&grad);
            in_batch += 1;
            if in_batch == cfg.batch {
                model.step(lr, cfg.batch);
                in_batch = 0;
            }
        }
        if in_batch > 0 {
            model.step(lr, in_batch);
        }
        log.epoch_loss.push(loss_sum / train_set.len() as f32);
        log.epoch_train_acc.push(correct as f64 / train_set.len() as f64);
        log.epoch_test_acc.push(evaluate(model, test_set));
        lr *= cfg.lr_decay;
    }
    log
}

/// Classification accuracy on a dataset.
pub fn evaluate(model: &mut Sequential, set: &Dataset) -> f64 {
    if set.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for (img, &label) in set.images.iter().zip(&set.labels) {
        if model.forward(img).argmax() == label {
            correct += 1;
        }
    }
    correct as f64 / set.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{bwht_mlp, mini_resnet};

    #[test]
    fn softmax_ce_gradient_sums_to_zero() {
        let logits = Tensor::vec1(&[1.0, -0.5, 2.0]);
        let (loss, grad) = softmax_ce(&logits, 2);
        assert!(loss > 0.0);
        assert!(grad.data().iter().sum::<f32>().abs() < 1e-6);
        assert!(grad.data()[2] < 0.0, "true-class grad must be negative");
    }

    #[test]
    fn softmax_ce_confident_correct_has_low_loss() {
        let (l_good, _) = softmax_ce(&Tensor::vec1(&[10.0, 0.0]), 0);
        let (l_bad, _) = softmax_ce(&Tensor::vec1(&[10.0, 0.0]), 1);
        assert!(l_good < 0.01);
        assert!(l_bad > 5.0);
    }

    /// End-to-end learning smoke: a small MLP must beat chance clearly
    /// on the digit patterns within a few epochs.
    #[test]
    fn mlp_learns_digits_above_chance() {
        let data = Dataset::digits(300, 12, 42);
        let (tr, te) = data.split(0.8);
        let mut rng = Rng::new(7);
        let mut model = bwht_mlp(144, 10, 32, &mut rng);
        // Flatten images to vectors.
        let flatten = |d: &Dataset| Dataset {
            images: d.images.iter().map(|i| i.clone().reshape(&[144])).collect(),
            labels: d.labels.clone(),
            classes: d.classes,
            side: d.side,
        };
        let (tr, te) = (flatten(&tr), flatten(&te));
        let log = train(
            &mut model,
            &tr,
            &te,
            TrainConfig { epochs: 6, lr: 0.08, ..Default::default() },
        );
        let final_acc = *log.epoch_test_acc.last().unwrap();
        assert!(final_acc > 0.5, "test acc {final_acc} not above chance (0.1)");
        // Loss decreased.
        assert!(log.epoch_loss.last().unwrap() < log.epoch_loss.first().unwrap());
    }

    /// A conv model also trains (slower; tiny config).
    #[test]
    fn conv_model_trains() {
        let data = Dataset::oriented_patterns(160, 4, 8, 11);
        let (tr, te) = data.split(0.8);
        // Tiny conv stacks are init-sensitive; this seed trains reliably
        // under the current Rng::normal stream.
        let mut rng = Rng::new(99);
        let mut model = mini_resnet(8, 4, 6, 1, 1, &mut rng);
        let log = train(
            &mut model,
            &tr,
            &te,
            TrainConfig { epochs: 4, lr: 0.05, ..Default::default() },
        );
        let acc = *log.epoch_test_acc.last().unwrap();
        assert!(acc > 0.4, "acc {acc} vs chance 0.25");
    }
}
