//! Minimal tensor: shape + contiguous f32 data (CHW layout for images).
//!
//! Deliberately small — the miniature models train sample-at-a-time on
//! one core, so a full broadcasting tensor library would be dead weight.

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Wrap a flat buffer (length must match the shape's product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    /// 1-D tensor from a slice.
    pub fn vec1(data: &[f32]) -> Self {
        Tensor { shape: vec![data.len()], data: data.to_vec() }
    }

    #[inline]
    /// Dimension sizes.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    /// Flat row-major view.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    /// Mutable flat row-major view.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len(), "reshape size mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// CHW accessor for 3-D tensors.
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f32 {
        let (_, hh, ww) = self.dims3();
        self.data[(c * hh + h) * ww + w]
    }

    #[inline]
    /// Write one CHW element.
    pub fn set3(&mut self, c: usize, h: usize, w: usize, v: f32) {
        let (_, hh, ww) = self.dims3();
        self.data[(c * hh + h) * ww + w] = v;
    }

    /// (C, H, W) of a 3-D tensor.
    pub fn dims3(&self) -> (usize, usize, usize) {
        assert_eq!(self.shape.len(), 3, "expected 3-D tensor, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2])
    }

    /// Index of the largest element (0 when empty).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Elementwise map, consuming self.
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Sum of squares (for grad-norm diagnostics).
    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn chw_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 7.0);
        assert_eq!(t.at3(1, 2, 3), 7.0);
        // Row-major CHW: index (1,2,3) = (1*3+2)*4+3 = 23.
        assert_eq!(t.data()[23], 7.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "reshape size mismatch")]
    fn reshape_rejects_bad_size() {
        Tensor::vec1(&[1.0, 2.0]).reshape(&[3]);
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(Tensor::vec1(&[0.1, 0.9, 0.5]).argmax(), 1);
    }
}
