//! The BWHT compression layer (paper §II-B, eq. (3)).
//!
//! Replaces a 1×1 convolution: per spatial position, the channel vector
//! is (block-)Walsh–Hadamard transformed, soft-thresholded with
//! *trainable* per-coefficient thresholds `T`, and transformed back.
//! The transform itself is parameter-free — the layer's only parameters
//! are `T` and a scalar reconstruction gain — which is where the ~87%
//! MobileNetV2 parameter reduction comes from (Fig 1(c)).
//!
//! Execution modes ([`BwhtExec`]):
//! - `Float` — exact transform (training default).
//! - `QuantDigital` — bit-exact model of the crossbar's bitplane path:
//!   inputs quantized to `input_bits`, each plane's ±1 sums quantized to
//!   **one bit** (the ADC-free extreme), planes reassembled, STE
//!   backward. This is what "training against 1-bit quantization"
//!   (paper §III-B, Fig 5) means.
//! - `Analog` — inference through the [`crate::cim`] crossbar simulator
//!   at a given operating point (noise, settling, early termination) —
//!   feeds the accuracy axes of Figs 7 and 13(c,d).

use std::sync::Arc;

use crate::cim::{
    BitplaneEngine, CimArrayPool, ConversionStats, Crossbar, CrossbarConfig, EarlyTermination,
    FaultPlan, FaultStats, PoolSpec,
};
use crate::util::{Executor, Rng};
use crate::wht::{fwht_inplace, Bwht, BwhtLayout};

use super::layer::Layer;
use super::quant::UniformQuantizer;
use super::tensor::Tensor;

/// Execution mode of a BWHT layer.
#[derive(Debug, Clone, Copy)]
pub enum BwhtExec {
    /// Exact float transform.
    Float,
    /// Bitplane path with 1-bit product-sum quantization (bit-exact
    /// digital model of the crossbar).
    QuantDigital { input_bits: u8 },
    /// Analog crossbar simulation (inference only). With `pool` set,
    /// each block's planes run through a scheduled [`CimArrayPool`]: the
    /// multi-bit MAVs are digitized by neighbour arrays (paper §IV)
    /// instead of 1-bit row comparators, and per-conversion
    /// energy/cycles/comparisons accumulate on the layer.
    Analog {
        input_bits: u8,
        config: CrossbarConfig,
        early_term: Option<EarlyTermination>,
        seed: u64,
        pool: Option<PoolSpec>,
    },
}

/// BWHT + soft-threshold layer over the channel dimension.
#[derive(Clone)]
pub struct BwhtLayer {
    /// Logical channel count (input == output).
    pub channels: usize,
    layout: BwhtLayout,
    bwht: Bwht,
    /// Trainable per-coefficient thresholds (padded frequency domain).
    t: Vec<f32>,
    gt: Vec<f32>,
    /// Trainable reconstruction gain for the quantized path.
    gamma: f32,
    ggamma: f32,
    /// Input quantizer range for the quantized/analog paths.
    pub in_quant_hi: f32,
    /// Which execution path `forward_inference` takes.
    pub exec: BwhtExec,
    /// L1-style pull on T (the paper's Fig 6 "unique loss" driving T
    /// outward to widen the dead band): dL/dT −= t_reg each step.
    pub t_reg: f32,
    // caches
    cache_z: Vec<Vec<f32>>,    // thresholded-domain pre-activation per pixel
    cache_gout: Vec<Vec<f32>>, // padded grad per pixel (for T grads)
    cache_shape: Vec<usize>,
    // analog engine (lazily built), and accumulated termination stats
    analog: Option<BitplaneEngine>,
    analog_rng: Option<Rng>,
    /// Pending per-sample noise stream (batch determinism contract):
    /// applied to `analog_rng` at the start of the next forward.
    analog_stream: Option<u64>,
    /// Pending per-sample noise streams for the next **batched**
    /// forward: sample `i` of the batch draws exactly as if
    /// `set_analog_stream(streams[i])` preceded a per-sample forward.
    analog_batch_streams: Option<Vec<u64>>,
    /// Shared persistent worker runtime injected by the serving engine
    /// (`AnalogEngine`): handed to the pool at `prepare_analog` so
    /// batch shards and pool plane lanes draw from one set of workers.
    executor: Option<Arc<Executor>>,
    /// Analog fault-injection plan (robustness harness): handed to the
    /// pool at `prepare_analog` like the executor, so worker-shard
    /// clones inherit the identical plan. `None` (the default) leaves
    /// the pool's fault layer uninstalled — serving is byte-identical
    /// to a build without the fault module.
    fault_plan: Option<FaultPlan>,
    /// Early-termination accounting: coefficient columns processed.
    pub term_processed: u64,
    /// Early-termination accounting: coefficient columns skipped.
    pub term_skipped: u64,
    /// Collaborative-digitization accounting accumulated across analog
    /// forwards (all zeros unless the exec mode carries a pool).
    pub conv_stats: ConversionStats,
    // inference scratch (gather buffer, padded frequency buffer,
    // quantized levels, per-crossbar block) — reused across forwards
    scratch_x: Vec<f32>,
    scratch_z: Vec<f32>,
    scratch_levels: Vec<u32>,
    scratch_block: Vec<u32>,
}

impl BwhtLayer {
    /// New layer for `channels` with Hadamard blocks of at most
    /// `max_block` (the crossbar size the layer maps onto).
    pub fn new(channels: usize, max_block: usize, rng: &mut Rng) -> Self {
        let layout = BwhtLayout::new(channels, max_block);
        let padded = layout.padded_len();
        let m = layout.block_size as f32;
        BwhtLayer {
            channels,
            layout,
            bwht: Bwht::new(layout),
            // Small positive random thresholds to break symmetry.
            t: (0..padded).map(|_| (0.01 + 0.02 * rng.uniform()) as f32).collect(),
            gt: vec![0.0; padded],
            gamma: m.sqrt() / 2.0,
            ggamma: 0.0,
            in_quant_hi: 4.0,
            exec: BwhtExec::Float,
            t_reg: 0.0,
            cache_z: Vec::new(),
            cache_gout: Vec::new(),
            cache_shape: Vec::new(),
            analog: None,
            analog_rng: None,
            analog_stream: None,
            analog_batch_streams: None,
            executor: None,
            fault_plan: None,
            term_processed: 0,
            term_skipped: 0,
            conv_stats: ConversionStats::default(),
            scratch_x: Vec::new(),
            scratch_z: Vec::new(),
            scratch_levels: Vec::new(),
            scratch_block: Vec::new(),
        }
    }

    /// The block layout (block size, block count).
    pub fn layout(&self) -> BwhtLayout {
        self.layout
    }

    /// Per-coefficient soft thresholds T.
    pub fn thresholds(&self) -> &[f32] {
        &self.t
    }

    /// Overwrite thresholds (padded length) — AOT weight import, tests.
    pub fn set_thresholds(&mut self, t: Vec<f32>) {
        assert_eq!(t.len(), self.layout.padded_len());
        self.t = t;
    }

    /// The output scale gamma.
    pub fn gamma(&self) -> f32 {
        self.gamma
    }

    /// Override the output scale gamma.
    pub fn set_gamma(&mut self, g: f32) {
        self.gamma = g;
    }

    /// Switch the inference execution path.
    pub fn set_exec(&mut self, exec: BwhtExec) {
        self.exec = exec;
        self.analog = None;
        self.analog_rng = None;
        self.analog_stream = None;
        self.analog_batch_streams = None;
    }

    /// Pin the analog noise stream for the next forward pass to
    /// `Rng::for_stream(layer_seed, stream)`.
    ///
    /// Batch engines call this with the sample's **global batch index**
    /// before each forward, which makes analog inference results a pure
    /// function of `(seed, sample index)` — independent of worker-thread
    /// count and shard boundaries. No-op outside `BwhtExec::Analog`.
    pub fn set_analog_stream(&mut self, stream: u64) {
        self.analog_stream = Some(stream);
    }

    /// Pin per-sample analog noise streams for the next
    /// [`Layer::forward_batch_inference`] call: sample `i` draws from
    /// `Rng::for_stream(layer_seed ^ …, streams[i])` exactly as if
    /// [`BwhtLayer::set_analog_stream`] with `streams[i]` had preceded
    /// a per-sample forward. Consumed by the next batched forward;
    /// no-op outside `BwhtExec::Analog`. This is what lets the serving
    /// engine's lockstep batch stay a pure function of
    /// `(seed, global sample index)` regardless of batch boundaries.
    pub fn set_analog_streams(&mut self, streams: Vec<u64>) {
        self.analog_batch_streams = Some(streams);
    }

    /// Inject the serving engine's persistent worker runtime. Applied
    /// to the layer's pool at the next [`BwhtLayer::prepare_analog`]
    /// (and immediately if the pool is already built), so the pool's
    /// plane lanes run on the same workers as the engine's batch
    /// shards instead of spawning their own — no-op outside
    /// `BwhtExec::Analog` with a pool.
    pub fn set_executor(&mut self, executor: Option<Arc<Executor>>) {
        self.executor = executor;
        // Propagate clears too: a pool holding a stale runtime would
        // keep its worker threads alive past the owner's release.
        if let Some(pool) = self.analog.as_mut().and_then(|e| e.pool_mut()) {
            pool.set_executor(self.executor.clone());
        }
    }

    /// Install (or clear) an analog fault-injection plan. Stored on the
    /// layer so a pool rebuilt after [`BwhtLayer::set_exec`] re-installs
    /// it, and applied immediately when the pool is already built — the
    /// same lifecycle as [`BwhtLayer::set_executor`]. Validation needs
    /// the pool geometry: with a built pool the plan is validated here
    /// (clean error), otherwise it is checked at the next
    /// [`BwhtLayer::prepare_analog`]. No-op outside `BwhtExec::Analog`
    /// with a pool (the plan simply never reaches a pool).
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) -> Result<(), String> {
        if let Some(pool) = self.analog.as_mut().and_then(|e| e.pool_mut()) {
            pool.set_fault_plan(plan.clone())?;
        }
        self.fault_plan = plan;
        Ok(())
    }

    /// Telemetry read of this layer's pool fault counters (injection,
    /// probe, quarantine, degraded-plane accounting) — zeros when the
    /// layer has no built pool or no plan is installed. Serving engines
    /// aggregate this across layers and worker-shard clones exactly
    /// like [`BwhtLayer::pool_planes`].
    pub fn fault_stats(&self) -> FaultStats {
        self.analog
            .as_ref()
            .and_then(|e| e.pool())
            .map_or(FaultStats::default(), CimArrayPool::fault_stats)
    }

    /// This layer's pool health ledger (per-converter and per-array
    /// debounced probe state), if a fault layer is installed.
    pub fn health(&self) -> Option<&crate::cim::HealthLedger> {
        self.analog.as_ref().and_then(|e| e.pool()).and_then(CimArrayPool::health)
    }

    /// Telemetry read of this layer's pool plane counters:
    /// `(planes_dispatched, planes_fused)`, zeros when the layer has no
    /// built pool. Serving engines aggregate this across layers (and
    /// worker-shard clones, delta-merged like `conv_stats`) into the
    /// metrics snapshots.
    pub fn pool_planes(&self) -> (u64, u64) {
        self.analog
            .as_ref()
            .and_then(|e| e.pool())
            .map_or((0, 0), |p| (p.planes_dispatched(), p.planes_fused()))
    }

    /// Build the lazily-constructed analog engine and apply any pending
    /// stream pin. Idempotent; no-op outside `BwhtExec::Analog`. Runs at
    /// the start of every forward, and batch engines call it explicitly
    /// before cloning worker-shard models so the crossbar fabrication
    /// (Hadamard matrix + comparator sampling) happens once and the
    /// clones copy it instead of re-fabricating per shard.
    pub fn prepare_analog(&mut self) {
        let BwhtExec::Analog { input_bits, config, early_term, seed, pool } = self.exec else {
            return;
        };
        if self.analog.is_none() {
            let mut frng = Rng::new(seed);
            let matrix = crate::cim::SignMatrix::hadamard(self.layout.block_size);
            let xb = Crossbar::new(matrix.clone(), config, &mut frng);
            let mut eng = BitplaneEngine::new(xb, input_bits);
            eng.early_term = early_term;
            if let Some(spec) = pool {
                // The pool's arrays share the block's programmed matrix;
                // fabrication (comparators, converter DACs) continues the
                // same deterministic stream.
                let mut built = CimArrayPool::new(&matrix, config, spec, &mut frng);
                // Share the serving engine's persistent runtime when one
                // was injected (one worker set for shards + lanes).
                built.set_executor(self.executor.clone());
                // Re-install any stored fault plan on the fresh pool.
                // Plans reaching this point were either validated when
                // set (pool already built) or are validated now; an
                // infeasible plan against a *rebuilt* geometry is a
                // configuration bug worth stopping the line for.
                built
                    .set_fault_plan(self.fault_plan.clone())
                    .expect("stored fault plan must fit the pool geometry");
                eng.set_pool(Some(built));
            }
            self.analog = Some(eng);
            self.analog_rng = Some(Rng::new(seed ^ 0xa5a5_5a5a));
        }
        if let Some(stream) = self.analog_stream.take() {
            self.analog_rng = Some(Rng::for_stream(seed ^ 0xa5a5_5a5a, stream));
        }
    }

    /// Iterate pixels: a CHW tensor yields H·W channel vectors; a 1-D
    /// tensor yields itself.
    fn pixel_count(shape: &[usize]) -> usize {
        match shape.len() {
            1 => 1,
            3 => shape[1] * shape[2],
            s => panic!("BwhtLayer expects 1-D or 3-D tensors, got {s}-D"),
        }
    }

    fn gather_pixel(x: &Tensor, pix: usize, out: &mut [f32]) {
        match x.shape().len() {
            1 => out[..x.len()].copy_from_slice(x.data()),
            3 => {
                let (c, h, w) = x.dims3();
                let (py, px) = (pix / w, pix % w);
                for ci in 0..c {
                    out[ci] = x.data()[(ci * h + py) * w + px];
                }
            }
            _ => unreachable!(),
        }
    }

    fn scatter_pixel(y: &mut Tensor, pix: usize, vals: &[f32]) {
        match y.shape().len() {
            1 => y.data_mut().copy_from_slice(&vals[..]),
            3 => {
                let (c, h, w) = y.dims3();
                let (py, px) = (pix / w, pix % w);
                for ci in 0..c {
                    y.data_mut()[(ci * h + py) * w + px] = vals[ci];
                }
            }
            _ => unreachable!(),
        }
    }

    /// Float path: z = H·pad(x); the quantized paths replace z with the
    /// bitplane reconstruction. Writes z (padded frequency domain) into
    /// the caller-owned buffer — the hot-path form, allocation-free once
    /// the layer scratch is warm. [`BwhtLayer::prepare_analog`] must have
    /// run first when in `Analog` mode.
    fn transform_forward_into(
        &mut self,
        xs: &[f32],
        rng_scratch: &mut Option<Rng>,
        z: &mut Vec<f32>,
    ) {
        match self.exec {
            BwhtExec::Float => {
                self.bwht.pad_into(xs, z);
                self.bwht.forward_padded_inplace(z);
            }
            BwhtExec::QuantDigital { input_bits } => {
                let q = UniformQuantizer::unsigned(input_bits, self.in_quant_hi);
                let mut levels = std::mem::take(&mut self.scratch_levels);
                q.levels_into(xs, &mut levels);
                let padded = self.layout.padded_len();
                let bs = self.layout.block_size;
                z.clear();
                z.resize(padded, 0.0);
                let mut plane = vec![0.0f32; bs];
                // Per block, per plane: transform the {0,1} plane and
                // 1-bit quantize each coefficient's sum.
                for b in 0..self.layout.blocks {
                    for p in 0..input_bits {
                        for (i, slot) in plane.iter_mut().enumerate() {
                            let idx = b * bs + i;
                            let lv = if idx < levels.len() { levels[idx] } else { 0 };
                            *slot = ((lv >> p) & 1) as f32;
                        }
                        fwht_inplace(&mut plane);
                        let w = (1u32 << p) as f32;
                        for i in 0..bs {
                            let s = if plane[i] > 0.0 { 1.0 } else { -1.0 };
                            z[b * bs + i] += w * s;
                        }
                    }
                }
                // Rescale into the float transform's units: the exact
                // z for level-valued inputs is (H·levels)·step; gamma
                // absorbs the 1-bit quantization's magnitude loss.
                let step = self.in_quant_hi / (q.levels() - 1) as f32;
                for v in z.iter_mut() {
                    *v *= self.gamma * step;
                }
                self.scratch_levels = levels;
            }
            BwhtExec::Analog { input_bits, .. } => {
                let q = UniformQuantizer::unsigned(input_bits, self.in_quant_hi);
                let step = self.in_quant_hi / (q.levels() - 1) as f32;
                let mut levels = std::mem::take(&mut self.scratch_levels);
                q.levels_into(xs, &mut levels);
                let padded = self.layout.padded_len();
                let bs = self.layout.block_size;
                z.clear();
                z.resize(padded, 0.0);
                let mut block = std::mem::take(&mut self.scratch_block);
                let eng = self.analog.as_mut().expect("prepare_analog builds the engine");
                let rng = rng_scratch.as_mut().expect("analog rng set with engine");
                // 1-bit path: gamma absorbs the sign-reassembly magnitude
                // loss. Pooled path: values are near-exact signed sums
                // (≈ H·levels), so the exact reconstruction scale `step`
                // applies and gamma is bypassed.
                let scale = if eng.has_pool() { step } else { self.gamma * step };
                // Gather every block's zero-padded levels once; the two
                // execution shapes below differ only in how the blocks
                // reach the engine, never in values (each `transform_many`
                // input consumes one plane seed exactly like a
                // `transform` call, and the engine reuses its scratch
                // arenas across blocks and forwards either way).
                block.clear();
                block.reserve(self.layout.blocks * bs);
                for b in 0..self.layout.blocks {
                    block.extend((0..bs).map(|i| {
                        let idx = b * bs + i;
                        if idx < levels.len() {
                            levels[idx]
                        } else {
                            0
                        }
                    }));
                }
                let outs = if eng.pool().is_some_and(|p| p.spec().fuse_batch) {
                    // Cross-sample plane fusion at layer scope: every
                    // Hadamard block of this pixel is its own pooled
                    // transform, so all blocks go to the pool together
                    // — one submission for the pixel instead of one per
                    // block, bit-identical to the per-block path.
                    let refs: Vec<&[u32]> = block.chunks(bs).collect();
                    eng.transform_many(&refs, rng)
                } else {
                    block.chunks(bs).map(|chunk| eng.transform(chunk, rng)).collect()
                };
                for (b, out) in outs.iter().enumerate() {
                    self.term_processed += out.term.processed;
                    self.term_skipped += out.term.skipped;
                    self.conv_stats.merge(&out.conv);
                    for i in 0..bs {
                        z[b * bs + i] = out.values[i] * scale;
                    }
                }
                self.scratch_block = block;
                self.scratch_levels = levels;
            }
        }
    }

    /// Cross-sample fused batched forward: every (sample, pixel, block)
    /// of the batch becomes one entry of a single pooled submission, so
    /// pool lanes stay busy across sample boundaries instead of
    /// draining between samples. Bit-identical to running
    /// [`Layer::forward_inference`] per sample with
    /// `set_analog_stream(streams[i])`: sample `i`'s plane seeds are
    /// drawn from its own stream generator in exactly the order the
    /// sequential walk consumes them (one `next_u64` per pooled
    /// transform, pixel-major then block-major), and the engine replays
    /// deferred per-plane `ConversionStats` input-major — the flat
    /// sample-major order below, i.e. the sequential merge order.
    fn forward_batch_fused(&mut self, xs: &[Tensor], streams: &[u64]) -> Vec<Tensor> {
        let BwhtExec::Analog { input_bits, seed, .. } = self.exec else {
            unreachable!("fused batched forward outside analog mode");
        };
        self.prepare_analog();
        let q = UniformQuantizer::unsigned(input_bits, self.in_quant_hi);
        let step = self.in_quant_hi / (q.levels() - 1) as f32;
        let padded = self.layout.padded_len();
        let bs = self.layout.block_size;
        let blocks = self.layout.blocks;

        // Stage 1: quantize and gather every (sample, pixel, block) in
        // flat sample-major order, drawing each block's plane seed from
        // the owning sample's stream generator.
        let pixels: Vec<usize> = xs.iter().map(|x| Self::pixel_count(x.shape())).collect();
        let total_blocks: usize = pixels.iter().map(|p| p * blocks).sum();
        let mut flat = std::mem::take(&mut self.scratch_block);
        flat.clear();
        flat.reserve(total_blocks * bs);
        let mut plane_seeds = Vec::with_capacity(total_blocks);
        let mut xbuf = std::mem::take(&mut self.scratch_x);
        xbuf.clear();
        xbuf.resize(padded.max(self.channels), 0.0);
        let mut levels = std::mem::take(&mut self.scratch_levels);
        let mut last_rng = None;
        for (s, x) in xs.iter().enumerate() {
            let mut rng = Rng::for_stream(seed ^ 0xa5a5_5a5a, streams[s]);
            for pix in 0..pixels[s] {
                xbuf.iter_mut().for_each(|v| *v = 0.0);
                Self::gather_pixel(x, pix, &mut xbuf);
                q.levels_into(&xbuf[..self.channels], &mut levels);
                for b in 0..blocks {
                    plane_seeds.push(rng.next_u64());
                    flat.extend((0..bs).map(|i| {
                        let idx = b * bs + i;
                        if idx < levels.len() {
                            levels[idx]
                        } else {
                            0
                        }
                    }));
                }
            }
            last_rng = Some(rng);
        }

        // Stage 2: ONE fused submission spanning the whole batch.
        let eng = self.analog.as_mut().expect("prepare_analog builds the engine");
        debug_assert!(eng.has_pool(), "fused batched forward requires a pool");
        let scale = step; // pooled reconstruction is quantizer-exact
        let refs: Vec<&[u32]> = flat.chunks(bs).collect();
        let outs = eng.transform_fused_seeded(&refs, &plane_seeds);
        drop(refs);

        // Stage 3: per-sample epilogue — merge term/conv accounting in
        // flat (= sequential) order, then threshold + inverse per pixel.
        let mut ys = Vec::with_capacity(xs.len());
        let mut z = std::mem::take(&mut self.scratch_z);
        let mut cursor = 0usize;
        for (s, x) in xs.iter().enumerate() {
            let mut y = x.clone();
            for pix in 0..pixels[s] {
                z.clear();
                z.resize(padded, 0.0);
                for b in 0..blocks {
                    let out = &outs[cursor];
                    cursor += 1;
                    self.term_processed += out.term.processed;
                    self.term_skipped += out.term.skipped;
                    self.conv_stats.merge(&out.conv);
                    for i in 0..bs {
                        z[b * bs + i] = out.values[i] * scale;
                    }
                }
                for (v, &t) in z.iter_mut().zip(&self.t) {
                    *v = crate::wht::soft_threshold(*v, t.abs());
                }
                self.bwht.inverse_padded_inplace(&mut z);
                Self::scatter_pixel(&mut y, pix, &z[..self.channels]);
            }
            ys.push(y);
        }
        // Leave the layer's generator where the sequential walk would:
        // the last sample's stream rng after its draws.
        self.analog_rng = last_rng;
        self.scratch_x = xbuf;
        self.scratch_z = z;
        self.scratch_levels = levels;
        self.scratch_block = flat;
        ys
    }
}

impl Layer for BwhtLayer {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        self.prepare_analog();
        let pixels = Self::pixel_count(x.shape());
        self.cache_shape = x.shape().to_vec();
        self.cache_z = Vec::with_capacity(pixels);
        let mut y = x.clone();
        let padded = self.layout.padded_len();
        let mut xbuf = vec![0.0f32; padded.max(self.channels)];
        // Take the analog RNG out to avoid double-borrow of self.
        let mut arng = self.analog_rng.take();
        for pix in 0..pixels {
            xbuf[..].iter_mut().for_each(|v| *v = 0.0);
            Self::gather_pixel(x, pix, &mut xbuf);
            let mut z = Vec::new();
            self.transform_forward_into(&xbuf[..self.channels], &mut arng, &mut z);
            // Soft threshold per coefficient.
            let mut yt = z.clone();
            for (v, &t) in yt.iter_mut().zip(&self.t) {
                *v = crate::wht::soft_threshold(*v, t.abs());
            }
            self.cache_z.push(z);
            // Inverse transform; the logical output is the first
            // `channels` values of the padded buffer.
            self.bwht.inverse_padded_inplace(&mut yt);
            Self::scatter_pixel(&mut y, pix, &yt[..self.channels]);
        }
        self.analog_rng = arng;
        y
    }

    /// Serving path: identical values to `forward`, but no backward
    /// caches and every per-pixel buffer comes from the layer's scratch
    /// (EXPERIMENTS.md §Perf).
    fn forward_inference(&mut self, x: &Tensor) -> Tensor {
        self.prepare_analog();
        let pixels = Self::pixel_count(x.shape());
        let mut y = x.clone();
        let padded = self.layout.padded_len();
        let mut xbuf = std::mem::take(&mut self.scratch_x);
        xbuf.clear();
        xbuf.resize(padded.max(self.channels), 0.0);
        let mut z = std::mem::take(&mut self.scratch_z);
        let mut arng = self.analog_rng.take();
        for pix in 0..pixels {
            xbuf.iter_mut().for_each(|v| *v = 0.0);
            Self::gather_pixel(x, pix, &mut xbuf);
            self.transform_forward_into(&xbuf[..self.channels], &mut arng, &mut z);
            // Soft threshold in place (no cache to preserve), then
            // inverse in place.
            for (v, &t) in z.iter_mut().zip(&self.t) {
                *v = crate::wht::soft_threshold(*v, t.abs());
            }
            self.bwht.inverse_padded_inplace(&mut z);
            Self::scatter_pixel(&mut y, pix, &z[..self.channels]);
        }
        self.analog_rng = arng;
        self.scratch_x = xbuf;
        self.scratch_z = z;
        y
    }

    /// Batched serving path. With per-sample streams pinned
    /// ([`BwhtLayer::set_analog_streams`]) and an analog pool that
    /// requests `fuse_batch`, all samples' Hadamard blocks go to the
    /// pool as ONE fused submission ([`BwhtLayer::forward_batch_fused`]);
    /// otherwise this is the per-sample loop with each sample's stream
    /// pinned — both bit-identical to sequential per-sample serving.
    fn forward_batch_inference(&mut self, xs: &[Tensor]) -> Vec<Tensor> {
        let streams = self.analog_batch_streams.take();
        if let Some(streams) = &streams {
            assert_eq!(streams.len(), xs.len(), "stream count != batch size");
        }
        let fused = !xs.is_empty()
            && streams.is_some()
            && matches!(self.exec,
                BwhtExec::Analog { pool: Some(spec), .. } if spec.fuse_batch);
        if !fused {
            return match streams {
                Some(streams) => xs
                    .iter()
                    .zip(streams)
                    .map(|(x, s)| {
                        self.set_analog_stream(s);
                        self.forward_inference(x)
                    })
                    .collect(),
                None => xs.iter().map(|x| self.forward_inference(x)).collect(),
            };
        }
        let streams = streams.expect("fused requires pinned streams");
        self.forward_batch_fused(xs, &streams)
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        // Gradients flow through the float linearisation (STE for the
        // quantized paths): out = H S_T(z) / m, z = H x.
        let pixels = Self::pixel_count(g.shape());
        assert_eq!(self.cache_z.len(), pixels, "backward without forward");
        let mut gx = g.clone();
        let padded = self.layout.padded_len();
        let bs = self.layout.block_size as f32;
        let mut gbuf = vec![0.0f32; padded.max(self.channels)];
        self.cache_gout = Vec::new();
        for pix in 0..pixels {
            gbuf.iter_mut().for_each(|v| *v = 0.0);
            Self::gather_pixel(g, pix, &mut gbuf[..]);
            // dL/dyt = Hᵀ g / m (inverse transform is H/m; H symmetric).
            let mut gy = vec![0.0f32; padded];
            gy[..self.channels].copy_from_slice(&gbuf[..self.channels]);
            for chunk in gy.chunks_exact_mut(self.layout.block_size) {
                fwht_inplace(chunk);
                for v in chunk.iter_mut() {
                    *v /= bs;
                }
            }
            let z = &self.cache_z[pix];
            // Threshold grads + pass-through mask.
            let mut gz = vec![0.0f32; padded];
            for i in 0..padded {
                let t = self.t[i].abs();
                if z[i].abs() > t {
                    gz[i] = gy[i];
                    // dS/dT = −sign(z); d|T|/dT = sign(T).
                    let sgn_t = if self.t[i] >= 0.0 { 1.0 } else { -1.0 };
                    self.gt[i] += -z[i].signum() * gy[i] * sgn_t;
                }
            }
            // dL/dx = Hᵀ gz = H gz, truncated.
            for chunk in gz.chunks_exact_mut(self.layout.block_size) {
                fwht_inplace(chunk);
            }
            Self::scatter_pixel(&mut gx, pix, &gz);
        }
        gx
    }

    fn step(&mut self, lr: f32, batch: usize) {
        let scale = 1.0 / batch as f32;
        for i in 0..self.t.len() {
            // t_reg pulls |T| outward (widens the dead band — Fig 6's
            // workload-reduction loss term).
            let reg = -self.t_reg * if self.t[i] >= 0.0 { 1.0 } else { -1.0 };
            self.t[i] -= lr * (self.gt[i] * scale + reg);
            self.gt[i] = 0.0;
        }
        self.gamma -= lr * self.ggamma * scale;
        self.ggamma = 0.0;
    }

    fn param_count(&self) -> usize {
        // Thresholds + gamma. The transform itself is parameter-free.
        self.t.len() + 1
    }

    fn mac_count(&self) -> usize {
        // Two blockwise transforms per pixel, counted as add-ops
        // (a WHT has no multiplies; Fig 1(d) counts these ops).
        2 * self.bwht.add_ops()
    }

    fn name(&self) -> &'static str {
        "bwht"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(ch: usize, mb: usize, seed: u64) -> (BwhtLayer, Rng) {
        let mut rng = Rng::new(seed);
        let l = BwhtLayer::new(ch, mb, &mut rng);
        (l, rng)
    }

    #[test]
    fn zero_threshold_float_is_identity() {
        let (mut l, mut rng) = layer(16, 16, 1);
        l.t.iter_mut().for_each(|t| *t = 0.0);
        let x = Tensor::vec1(&rng.normal_vec(16));
        let y = l.forward(&x);
        for (a, b) in y.data().iter().zip(x.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn large_threshold_zeroes_everything() {
        let (mut l, mut rng) = layer(16, 16, 2);
        l.t.iter_mut().for_each(|t| *t = 1e6);
        let x = Tensor::vec1(&rng.normal_vec(16));
        let y = l.forward(&x);
        assert!(y.data().iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn works_on_chw_tensors_per_pixel() {
        let (mut l, mut rng) = layer(8, 8, 3);
        l.t.iter_mut().for_each(|t| *t = 0.0);
        let x = Tensor::from_vec(&[8, 2, 2], rng.normal_vec(32));
        let y = l.forward(&x);
        for (a, b) in y.data().iter().zip(x.data()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn gradient_check_float_mode() {
        let (mut l, mut rng) = layer(8, 8, 4);
        // Fixed moderate thresholds so some coefficients pass, some not.
        l.t.iter_mut().for_each(|t| *t = 0.3);
        let x = Tensor::vec1(&rng.normal_vec(8));
        let y = l.forward(&x);
        let ones = Tensor::from_vec(y.shape(), vec![1.0; y.len()]);
        let gx = l.backward(&ones);
        let eps = 1e-3f32;
        for i in 0..8 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fp: f32 = l.forward(&xp).data().iter().sum();
            let fm: f32 = l.forward(&xm).data().iter().sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - gx.data()[i]).abs() < 2e-2 * (1.0 + num.abs()),
                "i={i}: num {num} vs ana {}",
                gx.data()[i]
            );
        }
    }

    #[test]
    fn quant_digital_correlates_with_float() {
        let (mut lf, mut rng) = layer(32, 32, 5);
        lf.t.iter_mut().for_each(|t| *t = 0.0);
        let (mut lq, _) = layer(32, 32, 5);
        lq.t.iter_mut().for_each(|t| *t = 0.0);
        lq.set_exec(BwhtExec::QuantDigital { input_bits: 4 });
        let mut dot = 0.0f64;
        let mut nf = 0.0f64;
        let mut nq = 0.0f64;
        for _ in 0..10 {
            let x = Tensor::vec1(
                &(0..32).map(|_| (rng.uniform() * 3.0) as f32).collect::<Vec<_>>(),
            );
            let yf = lf.forward(&x);
            let yq = lq.forward(&x);
            for (a, b) in yf.data().iter().zip(yq.data()) {
                dot += *a as f64 * *b as f64;
                nf += (*a as f64).powi(2);
                nq += (*b as f64).powi(2);
            }
        }
        let corr = dot / (nf.sqrt() * nq.sqrt() + 1e-12);
        assert!(corr > 0.4, "quantized path decorrelated: {corr}");
    }

    #[test]
    fn analog_mode_runs_and_counts_termination() {
        let (mut l, _) = layer(16, 16, 6);
        l.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::ideal(),
            early_term: Some(EarlyTermination::exact(8.0)),
            seed: 42,
            pool: None,
        });
        let x = Tensor::vec1(&(0..16).map(|i| (i % 4) as f32).collect::<Vec<_>>());
        let _ = l.forward(&x);
        assert!(l.term_processed > 0);
        assert_eq!(l.term_processed + l.term_skipped, 16 * 4);
    }

    #[test]
    fn analog_inference_path_matches_training_path() {
        // With the per-sample stream pinned, the scratch-reusing
        // inference path must be bit-identical to the training forward —
        // including under a *noisy* crossbar config (same RNG schedule).
        let mk = || {
            let (mut l, _) = layer(16, 16, 9);
            l.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 7,
                pool: None,
            });
            l
        };
        let mut a = mk();
        let mut b = mk();
        let x = Tensor::vec1(&(0..16).map(|i| (i % 4) as f32).collect::<Vec<_>>());
        for stream in 0..3u64 {
            a.set_analog_stream(stream);
            b.set_analog_stream(stream);
            let ya = a.forward(&x);
            let yb = b.forward_inference(&x);
            assert_eq!(ya.data(), yb.data(), "stream {stream}");
        }
    }

    #[test]
    fn pinned_stream_makes_analog_forward_reproducible() {
        let (mut l, _) = layer(16, 16, 10);
        l.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::default(),
            early_term: None,
            seed: 11,
            pool: None,
        });
        let x = Tensor::vec1(&(0..16).map(|i| (i % 3) as f32).collect::<Vec<_>>());
        l.set_analog_stream(5);
        let y1 = l.forward_inference(&x).data().to_vec();
        l.set_analog_stream(5);
        let y2 = l.forward_inference(&x).data().to_vec();
        assert_eq!(y1, y2);
    }

    #[test]
    fn pooled_analog_mode_counts_conversions_and_tracks_float() {
        use crate::adc::ImmersedMode;
        let (mut l, _) = layer(16, 16, 12);
        l.t.iter_mut().for_each(|t| *t = 0.0);
        l.set_exec(BwhtExec::Analog {
            input_bits: 4,
            config: CrossbarConfig::ideal(),
            early_term: None,
            seed: 21,
            pool: Some(PoolSpec {
                n_arrays: 4,
                adc_bits: 4,
                mode: ImmersedMode::Sar,
                asymmetric: false,
                threads: 1,
                fuse_batch: false,
            }),
        });
        let x = Tensor::vec1(&(0..16).map(|i| (i % 4) as f32).collect::<Vec<_>>());
        let y = l.forward(&x);
        // 16 rows x 4 planes digitized exactly once each.
        assert_eq!(l.conv_stats.conversions, 16 * 4);
        assert!(l.conv_stats.energy_fj > 0.0);
        assert_eq!(l.conv_stats.cycles, 4 * l.conv_stats.conversions); // SAR: bits cycles/conv
        // Pooled multi-bit reconstruction tracks the float transform far
        // more closely than the 1-bit path's gamma-scaled signs: with
        // zero thresholds and an ideal fabric it is the quantizer-exact
        // round trip of the level-quantized input.
        let (mut lf, _) = layer(16, 16, 12);
        lf.t.iter_mut().for_each(|t| *t = 0.0);
        let yf = lf.forward(&x);
        for (a, b) in y.data().iter().zip(yf.data()) {
            assert!((a - b).abs() < 0.3, "pooled {a} vs float {b}");
        }
    }

    #[test]
    fn fused_pooled_layer_matches_sequential_blocks() {
        use crate::adc::ImmersedMode;
        // 32 channels over 16-wide blocks: two pooled transforms per
        // pixel, so fusion genuinely batches across blocks. Noisy
        // crossbars pin the full RNG schedule, not just ideal values.
        let mk = |fuse: bool| {
            let (mut l, _) = layer(32, 16, 14);
            l.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 33,
                pool: Some(PoolSpec {
                    n_arrays: 4,
                    adc_bits: 4,
                    mode: ImmersedMode::Sar,
                    asymmetric: false,
                    threads: 1,
                    fuse_batch: fuse,
                }),
            });
            l
        };
        let mut seq = mk(false);
        let mut fused = mk(true);
        let x = Tensor::vec1(&(0..32).map(|i| (i % 5) as f32 * 0.7).collect::<Vec<_>>());
        for stream in 0..3u64 {
            seq.set_analog_stream(stream);
            fused.set_analog_stream(stream);
            let ys = seq.forward_inference(&x);
            let yf = fused.forward_inference(&x);
            assert_eq!(ys.data(), yf.data(), "stream {stream}");
        }
        assert_eq!(seq.conv_stats, fused.conv_stats, "fusion must not change accounting");
        assert_eq!(
            (seq.term_processed, seq.term_skipped),
            (fused.term_processed, fused.term_skipped)
        );
        assert!(fused.conv_stats.conversions > 0);
    }

    #[test]
    fn batched_fused_forward_matches_streamed_per_sample() {
        use crate::adc::ImmersedMode;
        // 32 channels over 16-wide blocks, 3 samples: the fused batched
        // forward submits 6 blocks to the pool at once. Values AND
        // accounting (conv stats incl. energy, term counters) must be
        // bit-identical to per-sample serving with the same streams.
        let mk = |early: Option<EarlyTermination>| {
            let (mut l, _) = layer(32, 16, 15);
            l.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: early,
                seed: 51,
                pool: Some(PoolSpec {
                    n_arrays: 4,
                    adc_bits: 4,
                    mode: ImmersedMode::Sar,
                    asymmetric: false,
                    threads: 1,
                    fuse_batch: true,
                }),
            });
            l
        };
        for early in [None, Some(EarlyTermination::exact(8.0))] {
            let mut seq = mk(early);
            let mut bat = mk(early);
            let xs: Vec<Tensor> = (0..3)
                .map(|s| {
                    Tensor::vec1(
                        &(0..32).map(|i| ((i + s) % 5) as f32 * 0.7).collect::<Vec<_>>(),
                    )
                })
                .collect();
            let streams = vec![7u64, 8, 9];
            let expect: Vec<Tensor> = xs
                .iter()
                .zip(&streams)
                .map(|(x, &s)| {
                    seq.set_analog_stream(s);
                    seq.forward_inference(x)
                })
                .collect();
            bat.set_analog_streams(streams.clone());
            let got = bat.forward_batch_inference(&xs);
            for (i, (a, b)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(a.data(), b.data(), "sample {i} (early={})", early.is_some());
            }
            assert_eq!(seq.conv_stats, bat.conv_stats);
            assert_eq!(
                (seq.term_processed, seq.term_skipped),
                (bat.term_processed, bat.term_skipped)
            );
        }
    }

    #[test]
    fn batched_forward_without_pool_falls_back_per_sample() {
        // No pool → no fusion; the batched entry must still honour the
        // pinned per-sample streams via the fallback loop.
        let mk = || {
            let (mut l, _) = layer(16, 16, 16);
            l.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 13,
                pool: None,
            });
            l
        };
        let mut seq = mk();
        let mut bat = mk();
        let xs: Vec<Tensor> = (0..2)
            .map(|s| {
                Tensor::vec1(&(0..16).map(|i| ((i + s) % 4) as f32).collect::<Vec<_>>())
            })
            .collect();
        let expect: Vec<Tensor> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| {
                seq.set_analog_stream(i as u64);
                seq.forward_inference(x)
            })
            .collect();
        bat.set_analog_streams(vec![0, 1]);
        let got = bat.forward_batch_inference(&xs);
        for (a, b) in expect.iter().zip(&got) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn float_inference_matches_forward_on_chw() {
        let (mut l, mut rng) = layer(8, 8, 11);
        let x = Tensor::from_vec(&[8, 3, 3], rng.normal_vec(72));
        let a = l.forward(&x);
        let b = l.forward_inference(&x);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn param_count_is_tiny_vs_dense_equivalent() {
        let (l, _) = layer(64, 64, 7);
        // 1×1 conv with 64→64 channels: 4160 params. BWHT: 65.
        assert!(l.param_count() < 100);
        assert_eq!(l.param_count(), 64 + 1);
    }

    #[test]
    fn non_pow2_channels_round_trip() {
        let (mut l, mut rng) = layer(24, 16, 8);
        l.t.iter_mut().for_each(|t| *t = 0.0);
        let x = Tensor::vec1(&rng.normal_vec(24));
        let y = l.forward(&x);
        for (a, b) in y.data().iter().zip(x.data()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
