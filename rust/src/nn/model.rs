//! Model container and the miniature paper models.
//!
//! [`Sequential`] chains boxed layers; the builders mirror the paper's
//! two benchmark topologies at laptop scale (DESIGN.md §Substitutions
//! #4): the *structure* — where 1×1 channel-mixing convolutions sit, and
//! that each can be swapped for a BWHT layer — is preserved, so the
//! parameter/MAC accounting of Figs 1(c,d) is real.

use crate::util::Rng;

use super::bwht_layer::BwhtLayer;
use super::layer::{AvgPool2d, BatchScale, Conv2d, Dense, Flatten, Layer, LeakyRelu, Relu};
use super::tensor::Tensor;

/// A sequential stack of layers.
pub struct Sequential {
    layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    /// Empty layer stack.
    pub fn new() -> Self {
        Sequential { layers: Vec::new() }
    }

    /// Append a layer.
    pub fn push(&mut self, l: Box<dyn Layer>) -> &mut Self {
        self.layers.push(l);
        self
    }

    /// Training-mode forward through every layer.
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let mut cur = x.clone();
        for l in &mut self.layers {
            cur = l.forward(&cur);
        }
        cur
    }

    /// Inference-only forward: no backward caches, internal scratch
    /// reused by the hot layers. Same values as [`Sequential::forward`];
    /// this is what the serving engines call (EXPERIMENTS.md §Perf).
    pub fn forward_inference(&mut self, x: &Tensor) -> Tensor {
        let mut layers = self.layers.iter_mut();
        let Some(first) = layers.next() else {
            return x.clone();
        };
        let mut cur = first.forward_inference(x);
        for l in layers {
            cur = l.forward_inference(&cur);
        }
        cur
    }

    /// Lockstep batched inference forward: every sample of `xs`
    /// advances through each layer together via
    /// [`Layer::forward_batch_inference`], so layers with a real
    /// batched path (`Dense` multi-RHS matvec, `BwhtLayer` cross-sample
    /// plane fusion) see the whole served batch at once. Bit-identical
    /// to calling [`Sequential::forward_inference`] per sample in
    /// order — for analog BWHT layers that contract holds when
    /// per-sample noise streams are pinned with
    /// `BwhtLayer::set_analog_streams` (the serving engine does; see
    /// `coordinator::engine`).
    pub fn forward_batch_inference(&mut self, xs: &[Tensor]) -> Vec<Tensor> {
        let mut cur: Vec<Tensor> = xs.to_vec();
        for l in &mut self.layers {
            cur = l.forward_batch_inference(&cur);
        }
        cur
    }

    /// Backpropagate the loss gradient through every layer.
    pub fn backward(&mut self, g: &Tensor) -> Tensor {
        let mut cur = g.clone();
        for l in self.layers.iter_mut().rev() {
            cur = l.backward(&cur);
        }
        cur
    }

    /// SGD step on every layer's accumulated gradients.
    pub fn step(&mut self, lr: f32, batch: usize) {
        for l in &mut self.layers {
            l.step(lr, batch);
        }
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Total multiply-accumulates per forward pass.
    pub fn mac_count(&self) -> usize {
        self.layers.iter().map(|l| l.mac_count()).sum()
    }

    /// The layer stack.
    pub fn layers(&self) -> &[Box<dyn Layer>] {
        &self.layers
    }

    /// Mutable layer stack.
    pub fn layers_mut(&mut self) -> &mut [Box<dyn Layer>] {
        &mut self.layers
    }

    /// Visit every BWHT layer mutably (mode switches, T inspection).
    pub fn for_each_bwht(&mut self, mut f: impl FnMut(&mut BwhtLayer)) {
        for l in &mut self.layers {
            // Safety: name() uniquely identifies our concrete types.
            if l.name() == "bwht" {
                // Downcast via raw pointer since we control all types.
                let ptr = l.as_mut() as *mut dyn Layer as *mut BwhtLayer;
                unsafe { f(&mut *ptr) }
            }
        }
    }

    /// The first layer, if it is a [`Dense`] — what the compressed
    /// serving fast path folds into the sequency domain
    /// (`coordinator::engine`).
    pub fn first_layer_dense(&self) -> Option<&super::layer::Dense> {
        let l = self.layers.first()?;
        if l.name() == "dense" {
            // Safety: name() uniquely identifies our concrete types
            // (same contract as `for_each_bwht`).
            let ptr = l.as_ref() as *const dyn Layer as *const super::layer::Dense;
            Some(unsafe { &*ptr })
        } else {
            None
        }
    }
}

impl Default for Sequential {
    fn default() -> Self {
        Sequential::new()
    }
}

impl Clone for Sequential {
    /// Deep copy via [`Layer::clone_box`] — parameters, exec modes and
    /// scratch state all duplicate, which is what the multi-threaded
    /// analog batch engine hands to each worker shard.
    fn clone(&self) -> Self {
        Sequential { layers: self.layers.iter().map(|l| l.clone_box()).collect() }
    }
}

/// Channel-mixing stage: either a trainable 1×1 conv (expressed as a
/// Dense over channels via Conv2d with k=1… we use Conv2d k=1) or the
/// parameter-free BWHT layer — the swap the paper studies in Fig 1(c).
pub enum Mixer {
    /// Trainable 1x1 convolution mixer.
    Conv1x1,
    /// Parameter-free blockwise WHT mixer.
    Bwht,
}

/// Miniature ResNet20-flavoured model: stem conv → `stages` residual-ish
/// stages (3×3 conv + channel mixer) → pool → classifier. `bwht_stages`
/// of the `stages` mixers use BWHT instead of 1×1 conv (Fig 1(c) x-axis).
pub fn mini_resnet(
    side: usize,
    classes: usize,
    channels: usize,
    stages: usize,
    bwht_stages: usize,
    rng: &mut Rng,
) -> Sequential {
    assert!(bwht_stages <= stages);
    let mut m = Sequential::new();
    m.push(Box::new(Conv2d::new(1, channels, 3, (side, side), rng)));
    m.push(Box::new(BatchScale::new(channels)));
    m.push(Box::new(LeakyRelu::new(0.1)));
    for s in 0..stages {
        m.push(Box::new(Conv2d::new(channels, channels, 3, (side, side), rng)));
        m.push(Box::new(BatchScale::new(channels)));
        m.push(Box::new(LeakyRelu::new(0.1)));
        // Channel mixer — the replaceable 1×1.
        if s < bwht_stages {
            m.push(Box::new(BwhtLayer::new(channels, channels.next_power_of_two(), rng)));
        } else {
            m.push(Box::new(Conv2d::new(channels, channels, 1, (side, side), rng)));
        }
        m.push(Box::new(BatchScale::new(channels)));
        m.push(Box::new(LeakyRelu::new(0.1)));
    }
    // Two 2× poolings keep coarse spatial structure for the classifier
    // (a global pool of ReLU features is nearly class-invariant on
    // glyph data — stroke *placement* is the signal).
    m.push(Box::new(AvgPool2d::new()));
    m.push(Box::new(AvgPool2d::new()));
    m.push(Box::new(Flatten::new()));
    let feat = channels * (side / 4) * (side / 4);
    m.push(Box::new(Dense::new(feat, classes, rng)));
    m
}

/// Miniature MobileNetV2-flavoured model: inverted bottlenecks whose
/// expand/project 1×1s are the replaceable mixers.
pub fn mini_mobilenet(
    side: usize,
    classes: usize,
    channels: usize,
    blocks: usize,
    use_bwht: bool,
    rng: &mut Rng,
) -> Sequential {
    let mut m = Sequential::new();
    m.push(Box::new(Conv2d::new(1, channels, 3, (side, side), rng)));
    m.push(Box::new(BatchScale::new(channels)));
    m.push(Box::new(LeakyRelu::new(0.1)));
    for _ in 0..blocks {
        // Expand (1×1 or BWHT) → depthwise-ish 3×3 → project (1×1 or BWHT).
        if use_bwht {
            m.push(Box::new(BwhtLayer::new(channels, channels.next_power_of_two(), rng)));
        } else {
            m.push(Box::new(Conv2d::new(channels, channels, 1, (side, side), rng)));
        }
        m.push(Box::new(LeakyRelu::new(0.1)));
        m.push(Box::new(Conv2d::new(channels, channels, 3, (side, side), rng)));
        m.push(Box::new(BatchScale::new(channels)));
        m.push(Box::new(LeakyRelu::new(0.1)));
        if use_bwht {
            m.push(Box::new(BwhtLayer::new(channels, channels.next_power_of_two(), rng)));
        } else {
            m.push(Box::new(Conv2d::new(channels, channels, 1, (side, side), rng)));
        }
        m.push(Box::new(BatchScale::new(channels)));
        m.push(Box::new(LeakyRelu::new(0.1)));
    }
    m.push(Box::new(AvgPool2d::new()));
    m.push(Box::new(AvgPool2d::new()));
    m.push(Box::new(Flatten::new()));
    let feat = channels * (side / 4) * (side / 4);
    m.push(Box::new(Dense::new(feat, classes, rng)));
    m
}

/// Build the digit MLP from AOT-exported JAX weights (the L2→L3 weight
/// hand-off): python trains, `make artifacts` exports, rust serves —
/// either digitally (PJRT HLO) or through the analog simulator with the
/// *same* parameters.
pub fn bwht_mlp_from_weights(
    manifest: &crate::runtime::Manifest,
    blob: &[f32],
) -> anyhow::Result<Sequential> {
    use anyhow::Context;
    let (input, hidden, classes) = (manifest.input, manifest.hidden, manifest.classes);
    let slice = |name: &str| -> anyhow::Result<&[f32]> {
        let (_, _, off, len) =
            manifest.param(name).with_context(|| format!("param {name} missing"))?;
        Ok(&blob[*off..*off + *len])
    };
    // JAX stores w1 as [input, hidden] for x @ w1; rust Dense wants
    // [out][in] row-major — transpose.
    let transpose = |w: &[f32], rows: usize, cols: usize| -> Vec<f32> {
        let mut out = vec![0.0f32; w.len()];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = w[r * cols + c];
            }
        }
        out
    };
    let mut rng = Rng::new(0);
    let mut d1 = Dense::new(input, hidden, &mut rng);
    d1.set_weights(transpose(slice("w1")?, input, hidden), slice("b1")?.to_vec());
    let mut bw = BwhtLayer::new(hidden, hidden.next_power_of_two(), &mut rng);
    bw.set_thresholds(slice("t")?.to_vec());
    bw.set_gamma(slice("gamma")?[0]);
    bw.in_quant_hi = 4.0; // model.IN_QUANT_HI on the python side
    let mut d2 = Dense::new(hidden, classes, &mut rng);
    d2.set_weights(transpose(slice("w2")?, hidden, classes), slice("b2")?.to_vec());

    let mut m = Sequential::new();
    m.push(Box::new(d1));
    m.push(Box::new(Relu::new()));
    m.push(Box::new(bw));
    m.push(Box::new(Relu::new()));
    m.push(Box::new(d2));
    Ok(m)
}

/// Small MLP with one BWHT hidden stage — the Fig 13(c,d) digit model
/// that maps 1:1 onto a single crossbar.
pub fn bwht_mlp(input: usize, classes: usize, hidden: usize, rng: &mut Rng) -> Sequential {
    let mut m = Sequential::new();
    m.push(Box::new(Dense::new(input, hidden, rng)));
    m.push(Box::new(Relu::new()));
    m.push(Box::new(BwhtLayer::new(hidden, hidden.next_power_of_two(), rng)));
    m.push(Box::new(Relu::new()));
    m.push(Box::new(Dense::new(hidden, classes, rng)));
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mini_resnet_shapes() {
        let mut rng = Rng::new(1);
        let mut m = mini_resnet(12, 8, 8, 2, 1, &mut rng);
        let x = Tensor::zeros(&[1, 12, 12]);
        let y = m.forward(&x);
        assert_eq!(y.shape(), &[8]);
    }

    #[test]
    fn bwht_swap_reduces_params() {
        let mut rng = Rng::new(2);
        let dense = mini_resnet(12, 8, 16, 3, 0, &mut rng);
        let mut rng2 = Rng::new(2);
        let compressed = mini_resnet(12, 8, 16, 3, 3, &mut rng2);
        assert!(
            compressed.param_count() < dense.param_count(),
            "{} !< {}",
            compressed.param_count(),
            dense.param_count()
        );
    }

    #[test]
    fn mobilenet_bwht_param_reduction_substantial() {
        // The Fig 1(c) claim shape: most 1×1 mixer params disappear.
        let mut rng = Rng::new(3);
        let dense = mini_mobilenet(12, 8, 16, 2, false, &mut rng);
        let mut rng2 = Rng::new(3);
        let compressed = mini_mobilenet(12, 8, 16, 2, true, &mut rng2);
        // The miniature's 3×3 convs dominate (channels are tiny), so the
        // reduction is modest here; the full-dimension accounting in
        // `macs` shows the paper-scale ~87% effect.
        let reduction = 1.0 - compressed.param_count() as f64 / dense.param_count() as f64;
        assert!(reduction > 0.1, "reduction {reduction}");
    }

    #[test]
    fn bwht_swap_increases_transform_ops() {
        // Fig 1(d): frequency-domain processing costs more raw ops.
        let mut rng = Rng::new(4);
        let mut with_bwht = mini_resnet(12, 8, 16, 2, 2, &mut rng);
        // BWHT layers exist and report nonzero op counts.
        let mut ops = 0usize;
        with_bwht.for_each_bwht(|b| ops += b.mac_count());
        assert!(ops > 0);
    }

    #[test]
    fn for_each_bwht_visits_only_bwht() {
        let mut rng = Rng::new(5);
        let mut m = mini_resnet(8, 4, 8, 2, 1, &mut rng);
        let mut count = 0;
        m.for_each_bwht(|_| count += 1);
        assert_eq!(count, 1);
    }

    #[test]
    fn mlp_forward_shape() {
        let mut rng = Rng::new(6);
        let mut m = bwht_mlp(144, 10, 32, &mut rng);
        let y = m.forward(&Tensor::zeros(&[144]));
        assert_eq!(y.shape(), &[10]);
    }

    #[test]
    fn mlp_inference_matches_training_forward() {
        let mut rng = Rng::new(7);
        let mut m = bwht_mlp(144, 10, 32, &mut rng);
        for s in 0..4u64 {
            let mut xr = Rng::new(100 + s);
            let x = Tensor::vec1(&xr.normal_vec(144));
            let a = m.forward(&x);
            let b = m.forward_inference(&x);
            assert_eq!(a.data(), b.data(), "seed {s}");
        }
    }

    #[test]
    fn batched_inference_matches_per_sample() {
        // Float-mode model (no analog noise streams involved): the
        // lockstep walk must be bit-identical to per-sample inference.
        let mut rng = Rng::new(11);
        let mut m = bwht_mlp(144, 10, 32, &mut rng);
        let mut xr = Rng::new(200);
        let xs: Vec<Tensor> = (0..6).map(|_| Tensor::vec1(&xr.normal_vec(144))).collect();
        let mut per = m.clone();
        let expect: Vec<Tensor> = xs.iter().map(|x| per.forward_inference(x)).collect();
        let got = m.forward_batch_inference(&xs);
        assert_eq!(expect.len(), got.len());
        for (a, b) in expect.iter().zip(&got) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn cloned_model_forwards_identically() {
        let mut rng = Rng::new(8);
        let mut m = bwht_mlp(36, 4, 16, &mut rng);
        let mut c = m.clone();
        let x = Tensor::vec1(&Rng::new(9).normal_vec(36));
        assert_eq!(m.forward_inference(&x).data(), c.forward_inference(&x).data());
        assert_eq!(m.param_count(), c.param_count());
    }
}
