//! Quantized neural-network stack.
//!
//! Everything needed to reproduce the paper's learning-side results
//! without any external ML dependency: a small tensor type, hand-rolled
//! backprop layers, the BWHT compression layer with soft-thresholding,
//! miniature MobileNetV2/ResNet20-style models, straight-through
//! estimator (STE) training against the crossbar's 1-bit product-sum
//! quantization, analytic MAC/parameter accounting at the paper's full
//! model dimensions, and the synthetic edge-sensor dataset that stands
//! in for CIFAR/MNIST (DESIGN.md §Substitutions).
//!
//! - [`tensor`] — shape + data, minimal ops.
//! - [`layer`] — Dense / Conv2d / DepthwiseConv2d / ReLU / BatchScale /
//!   GlobalAvgPool with forward/backward/step.
//! - [`bwht_layer`] — the paper's parameter-free frequency-domain layer:
//!   WHT → soft-threshold(T, trainable) → inverse WHT, with float,
//!   quantized-digital (1-bit product-sum) and analog-crossbar execution
//!   modes.
//! - [`quant`] — uniform quantizers + STE fake-quant.
//! - [`model`] — `Sequential` plus the miniature model builders.
//! - [`train`] — SGD/momentum, softmax CE, the training loops for
//!   Figs 1(c), 5, 6, 13(c,d).
//! - [`dataset`] — procedural multispectral-ish pattern datasets.
//! - [`macs`] — analytic parameter/MAC tables for full-size MobileNetV2
//!   and ResNet20 with/without BWHT replacement (Figs 1(c,d)).

pub mod bwht_layer;
pub mod dataset;
pub mod layer;
pub mod macs;
pub mod model;
pub mod quant;
pub mod tensor;
pub mod train;

pub use bwht_layer::{BwhtExec, BwhtLayer};
pub use dataset::Dataset;
pub use layer::Layer;
pub use model::Sequential;
pub use tensor::Tensor;
pub use train::{evaluate, train, TrainConfig, TrainLog};
