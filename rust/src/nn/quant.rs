//! Uniform quantizers and straight-through estimators (paper §III-B).
//!
//! The crossbar consumes *unsigned* `bits`-wide integers (bitplanes).
//! Activations are clipped to a fixed range and affinely mapped onto the
//! integer grid; training sees the quantizer as identity on the backward
//! pass (STE), which is how the paper's models "learn around" extreme
//! quantization (Fig 5).

/// Affine quantization of `x ∈ [lo, hi]` onto `{0 … 2^bits − 1}`.
#[derive(Debug, Clone, Copy)]
pub struct UniformQuantizer {
    /// Code width, 1..=16.
    pub bits: u8,
    /// Bottom of the input range.
    pub lo: f32,
    /// Top of the input range.
    pub hi: f32,
}

impl UniformQuantizer {
    /// Quantizer over `[lo, hi]` at `bits` (panics on a bad range).
    pub fn new(bits: u8, lo: f32, hi: f32) -> Self {
        assert!((1..=16).contains(&bits) && hi > lo);
        UniformQuantizer { bits, lo, hi }
    }

    /// Unit-range unsigned quantizer (post-ReLU activations in [0, hi]).
    pub fn unsigned(bits: u8, hi: f32) -> Self {
        UniformQuantizer::new(bits, 0.0, hi)
    }

    #[inline]
    /// Number of code levels, `2^bits`.
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    /// Quantize to an integer level.
    #[inline]
    pub fn to_level(&self, x: f32) -> u32 {
        let t = ((x - self.lo) / (self.hi - self.lo)).clamp(0.0, 1.0);
        let q = (t * (self.levels() - 1) as f32).round() as u32;
        q.min(self.levels() - 1)
    }

    /// Reconstruct the float value of a level.
    #[inline]
    pub fn from_level(&self, q: u32) -> f32 {
        self.lo + (self.hi - self.lo) * q as f32 / (self.levels() - 1) as f32
    }

    /// Fake-quantize: quantize-dequantize in float (forward of the STE).
    #[inline]
    pub fn fake(&self, x: f32) -> f32 {
        self.from_level(self.to_level(x))
    }

    /// STE backward: gradient passes where x is inside the clip range.
    #[inline]
    pub fn ste_mask(&self, x: f32) -> f32 {
        if x >= self.lo && x <= self.hi {
            1.0
        } else {
            0.0
        }
    }

    /// Quantize a slice to levels.
    pub fn levels_of(&self, xs: &[f32]) -> Vec<u32> {
        let mut out = Vec::new();
        self.levels_into(xs, &mut out);
        out
    }

    /// Quantize a slice into a caller-owned buffer (hot-path form).
    pub fn levels_into(&self, xs: &[f32], out: &mut Vec<u32>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.to_level(x)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_trip_error_bounded_by_half_step() {
        prop::check("quantizer round trip", 256, |rng| {
            let bits = 1 + rng.index(8) as u8;
            let q = UniformQuantizer::unsigned(bits, 4.0);
            let x = (rng.uniform() * 4.0) as f32;
            let err = (q.fake(x) - x).abs();
            let step = 4.0 / (q.levels() - 1) as f32;
            crate::prop_assert!(err <= step / 2.0 + 1e-6, "bits={bits} x={x} err={err}");
            Ok(())
        });
    }

    #[test]
    fn levels_cover_range() {
        let q = UniformQuantizer::unsigned(2, 3.0);
        assert_eq!(q.to_level(0.0), 0);
        assert_eq!(q.to_level(3.0), 3);
        assert_eq!(q.from_level(3), 3.0);
        assert_eq!(q.levels(), 4);
    }

    #[test]
    fn clipping_clamps() {
        let q = UniformQuantizer::unsigned(4, 1.0);
        assert_eq!(q.to_level(-5.0), 0);
        assert_eq!(q.to_level(42.0), 15);
        assert_eq!(q.ste_mask(-5.0), 0.0);
        assert_eq!(q.ste_mask(0.5), 1.0);
    }

    #[test]
    fn one_bit_is_binary() {
        let q = UniformQuantizer::unsigned(1, 1.0);
        assert_eq!(q.to_level(0.2), 0);
        assert_eq!(q.to_level(0.8), 1);
    }

    #[test]
    fn monotone_levels() {
        let q = UniformQuantizer::unsigned(5, 2.0);
        let mut prev = 0;
        for i in 0..100 {
            let l = q.to_level(2.0 * i as f32 / 99.0);
            assert!(l >= prev);
            prev = l;
        }
    }
}
