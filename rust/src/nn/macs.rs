//! Analytic parameter/MAC accounting at the paper's full model
//! dimensions (Figs 1(c), 1(d)).
//!
//! Training full MobileNetV2/ResNet20 is out of scope for this testbed
//! (DESIGN.md §Substitutions), but *counting* needs no training: these
//! tables enumerate every layer of the published architectures, mark the
//! 1×1 channel-mixing convolutions BWHT can replace, and compute
//!
//! - the parameter reduction from the swap (Fig 1(c) right axis; the
//!   paper quotes ~87% for MobileNetV2), and
//! - the MAC increase (Fig 1(d)): on crossbar hardware a WHT executes as
//!   a *dense* ±1 matrix–vector product at the padded power-of-two
//!   dimension, so ops grow even as parameters vanish — the motivation
//!   for the paper's analog accelerator.

use crate::wht::BwhtLayout;

/// One counted layer of a published architecture.
#[derive(Debug, Clone)]
pub struct LayerCount {
    /// Layer label (architecture position).
    pub name: String,
    /// Trainable parameters (weights + biases; BN folded as 2/ch).
    pub params: usize,
    /// Multiply-accumulates for one inference.
    pub macs: usize,
    /// True for 1×1 channel-mixing convs that BWHT can replace.
    pub replaceable: bool,
    /// Spatial positions (H·W) the layer runs at.
    pub spatial: usize,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
}

fn conv(
    name: &str,
    cin: usize,
    cout: usize,
    k: usize,
    h: usize,
    w: usize,
    replaceable: bool,
) -> LayerCount {
    LayerCount {
        name: name.to_string(),
        params: cout * cin * k * k + cout,
        macs: cout * cin * k * k * h * w,
        replaceable: replaceable && k == 1,
        spatial: h * w,
        cin,
        cout,
    }
}

fn dwconv(name: &str, ch: usize, k: usize, h: usize, w: usize) -> LayerCount {
    LayerCount {
        name: name.to_string(),
        params: ch * k * k + ch,
        macs: ch * k * k * h * w,
        replaceable: false,
        spatial: h * w,
        cin: ch,
        cout: ch,
    }
}

fn bn(name: &str, ch: usize) -> LayerCount {
    LayerCount {
        name: name.to_string(),
        params: 2 * ch,
        macs: 0,
        replaceable: false,
        spatial: 0,
        cin: ch,
        cout: ch,
    }
}

fn fc(name: &str, cin: usize, cout: usize) -> LayerCount {
    LayerCount {
        name: name.to_string(),
        params: cin * cout + cout,
        macs: cin * cout,
        replaceable: false,
        spatial: 1,
        cin,
        cout,
    }
}

/// Full MobileNetV2 at 224×224 ImageNet dimensions (Sandler et al. 2018
/// Table 2): t = expansion, c = output channels, n = repeats, s = stride.
pub fn mobilenet_v2_table() -> Vec<LayerCount> {
    let mut layers = Vec::new();
    let mut h = 112usize; // after stride-2 stem
    layers.push(conv("stem 3x3/2", 3, 32, 3, h, h, false));
    layers.push(bn("stem bn", 32));

    let spec: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32usize;
    for (bi, &(t, c, n, s)) in spec.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let hin = h;
            if stride == 2 {
                h /= 2;
            }
            let hid = cin * t;
            if t != 1 {
                layers.push(conv(&format!("b{bi}.{r} expand 1x1"), cin, hid, 1, hin, hin, true));
                layers.push(bn(&format!("b{bi}.{r} bn1"), hid));
            }
            layers.push(dwconv(&format!("b{bi}.{r} dw 3x3/{stride}"), hid, 3, h, h));
            layers.push(bn(&format!("b{bi}.{r} bn2"), hid));
            layers.push(conv(&format!("b{bi}.{r} project 1x1"), hid, c, 1, h, h, true));
            layers.push(bn(&format!("b{bi}.{r} bn3"), c));
            cin = c;
        }
    }
    layers.push(conv("head 1x1", cin, 1280, 1, h, h, true));
    layers.push(bn("head bn", 1280));
    layers.push(fc("classifier", 1280, 1000));
    layers
}

/// Full ResNet20 at 32×32 CIFAR dimensions (He et al. 2016): stem +
/// 3 stages × 3 blocks × 2 convs, widths 16/32/64; 1×1 shortcut
/// projections at stage transitions are the replaceable mixers; the
/// paper's Fig 1(c) additionally studies replacing the 3×3 stacks
/// progressively (see [`resnet20_progressive`]).
pub fn resnet20_table() -> Vec<LayerCount> {
    let mut layers = Vec::new();
    layers.push(conv("stem 3x3", 3, 16, 3, 32, 32, false));
    layers.push(bn("stem bn", 16));
    let widths = [16usize, 32, 64];
    let sides = [32usize, 16, 8];
    let mut cin = 16usize;
    for (si, (&wd, &side)) in widths.iter().zip(&sides).enumerate() {
        for b in 0..3 {
            layers.push(conv(&format!("s{si}.b{b} conv1 3x3"), cin, wd, 3, side, side, false));
            layers.push(bn(&format!("s{si}.b{b} bn1"), wd));
            layers.push(conv(&format!("s{si}.b{b} conv2 3x3"), wd, wd, 3, side, side, false));
            layers.push(bn(&format!("s{si}.b{b} bn2"), wd));
            if b == 0 && cin != wd {
                layers.push(conv(&format!("s{si} shortcut 1x1"), cin, wd, 1, side, side, true));
            }
            cin = wd;
        }
    }
    layers.push(fc("classifier", 64, 10));
    layers
}

/// Aggregate accounting for a table, with and without BWHT replacement.
#[derive(Debug, Clone, Copy)]
pub struct CompressionSummary {
    /// Parameters of the unmodified architecture.
    pub params_base: usize,
    /// Parameters after replaceable mixers go BWHT.
    pub params_bwht: usize,
    /// Fraction of parameters removed (all layers).
    pub reduction_total: f64,
    /// Fraction removed counting feature extractor only (no classifier) —
    /// the basis closest to the paper's "87% for MobileNetV2".
    pub reduction_features: f64,
    /// MACs of the unmodified architecture.
    pub macs_base: usize,
    /// MACs with BWHT executed as dense ±1 crossbar matvec.
    pub macs_bwht_dense: usize,
    /// Ops with BWHT executed as the fast O(m log m) butterfly.
    pub ops_bwht_fast: usize,
    /// Dense-execution MAC increase factor (Fig 1(d)).
    pub mac_increase_dense: f64,
}

/// BWHT stand-in costs for a replaced 1×1 layer: the transform runs at
/// the padded power-of-two of max(cin, cout), blocks capped at 512.
fn bwht_costs(l: &LayerCount) -> (usize, usize, usize) {
    let dim = l.cin.max(l.cout);
    let layout = BwhtLayout::new(dim, 512);
    let padded = layout.padded_len();
    let params = padded + 1; // thresholds + gain
    let dense = layout.blocks * layout.block_size * layout.block_size * l.spatial;
    let fast =
        layout.blocks * layout.block_size * layout.block_size.trailing_zeros() as usize * l.spatial;
    (params, dense, fast)
}

/// Summarise a table under full replacement of all replaceable layers.
pub fn compression_summary(table: &[LayerCount]) -> CompressionSummary {
    let params_base: usize = table.iter().map(|l| l.params).sum();
    let macs_base: usize = table.iter().map(|l| l.macs).sum();
    let classifier_params: usize =
        table.iter().filter(|l| l.name.contains("classifier")).map(|l| l.params).sum();

    let mut params_bwht = 0usize;
    let mut macs_dense = 0usize;
    let mut ops_fast = 0usize;
    let mut replaced_params = 0usize;
    for l in table {
        if l.replaceable {
            let (p, d, f) = bwht_costs(l);
            params_bwht += p;
            macs_dense += d;
            ops_fast += f;
            replaced_params += l.params;
        } else {
            params_bwht += l.params;
            macs_dense += l.macs;
            ops_fast += l.macs;
        }
    }
    let features_base = params_base - classifier_params;
    let reduction_features = replaced_params as f64 / features_base as f64;
    CompressionSummary {
        params_base,
        params_bwht,
        reduction_total: 1.0 - params_bwht as f64 / params_base as f64,
        reduction_features,
        macs_base,
        macs_bwht_dense: macs_dense,
        ops_bwht_fast: ops_fast,
        mac_increase_dense: macs_dense as f64 / macs_base as f64,
    }
}

/// Fig 1(c) progression for ResNet20: replace the first `k` replaceable-
/// or-3×3 conv layers (the paper progressively WHT-processes layers) and
/// report (fraction of params remaining, layers replaced).
pub fn resnet20_progressive(k: usize) -> (usize, f64) {
    let table = resnet20_table();
    let conv_idx: Vec<usize> = table
        .iter()
        .enumerate()
        .filter(|(_, l)| l.name.contains("conv") || l.replaceable)
        .map(|(i, _)| i)
        .collect();
    let replace: Vec<usize> = conv_idx.into_iter().take(k).collect();
    let base: usize = table.iter().map(|l| l.params).sum();
    let mut now = 0usize;
    for (i, l) in table.iter().enumerate() {
        if replace.contains(&i) {
            let (p, _, _) = bwht_costs(l);
            now += p;
        } else {
            now += l.params;
        }
    }
    (replace.len(), now as f64 / base as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenet_v2_totals_match_published() {
        let t = mobilenet_v2_table();
        let params: usize = t.iter().map(|l| l.params).sum();
        let macs: usize = t.iter().map(|l| l.macs).sum();
        // Published: ~3.4–3.5 M params, ~300 M MACs at 224².
        assert!((3_200_000..3_700_000).contains(&params), "params={params}");
        assert!((250_000_000..360_000_000).contains(&macs), "macs={macs}");
    }

    #[test]
    fn resnet20_totals_match_published() {
        let t = resnet20_table();
        let params: usize = t.iter().map(|l| l.params).sum();
        let macs: usize = t.iter().map(|l| l.macs).sum();
        // Published: ~0.27 M params, ~41 M MACs.
        assert!((250_000..300_000).contains(&params), "params={params}");
        assert!((35_000_000..48_000_000).contains(&macs), "macs={macs}");
    }

    #[test]
    fn mobilenet_bwht_reduction_near_87_percent() {
        // The paper's headline: ~87% parameter reduction in MobileNetV2.
        let s = compression_summary(&mobilenet_v2_table());
        // We measure ~0.95 on the strict feature-extractor basis; the
        // paper's 0.87 corresponds to a basis between features-only and
        // total — both bases bracket it (see EXPERIMENTS.md F1c).
        assert!(
            (0.80..0.97).contains(&s.reduction_features),
            "feature-param reduction {} not near 0.87",
            s.reduction_features
        );
        // Total (incl. classifier) is necessarily lower but substantial.
        assert!(s.reduction_total > 0.5, "total reduction {}", s.reduction_total);
    }

    #[test]
    fn dense_execution_increases_macs() {
        // Fig 1(d): frequency-domain processing costs *more* MACs when
        // the WHT runs as a dense crossbar matvec.
        let s = compression_summary(&mobilenet_v2_table());
        assert!(
            s.mac_increase_dense > 1.2,
            "expected MAC increase, got {}",
            s.mac_increase_dense
        );
        // The fast butterfly form is cheaper than dense.
        assert!(s.ops_bwht_fast < s.macs_bwht_dense);
    }

    #[test]
    fn resnet20_progression_monotone() {
        let mut prev = 1.0;
        for k in 0..10 {
            let (_, frac) = resnet20_progressive(k);
            assert!(frac <= prev + 1e-12, "k={k}");
            prev = frac;
        }
        // Replacing everything leaves far fewer params.
        let (_, all) = resnet20_progressive(100);
        assert!(all < 0.2, "full replacement fraction {all}");
    }

    #[test]
    fn replaceable_layers_are_1x1_only() {
        for l in mobilenet_v2_table().iter().chain(resnet20_table().iter()) {
            if l.replaceable {
                assert!(l.name.contains("1x1"), "{}", l.name);
            }
        }
    }
}
