//! Procedural datasets (DESIGN.md §Substitutions #3).
//!
//! Stand-ins for the paper's CIFAR-10 / MNIST workloads that are small
//! enough to train in seconds on one core but structured enough that the
//! paper's compression / quantization trade-offs show their shape:
//!
//! - [`Dataset::oriented_patterns`] — "edge-sensor" images: an oriented
//!   grating + blob per class with additive noise; classes are angle
//!   bins. Stresses the frequency-domain layers exactly where WHT
//!   compression lives (orientation = sequency content).
//! - [`Dataset::digits`] — 10-class procedural seven-segment-ish glyphs
//!   with jitter and noise (the Fig 13(c,d) "MNIST character
//!   recognition" stand-in).

use crate::util::Rng;

use super::tensor::Tensor;

/// A labelled image-classification dataset (CHW tensors).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// CHW image tensors.
    pub images: Vec<Tensor>,
    /// Class label per image.
    pub labels: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
    /// Square image side length.
    pub side: usize,
}

impl Dataset {
    /// Oriented-grating patterns: `classes` angle bins, `n` samples,
    /// `side × side` single-channel images in [0, 1].
    pub fn oriented_patterns(n: usize, classes: usize, side: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.index(classes);
            let angle =
                std::f64::consts::PI * (class as f64 + 0.5 * rng.uniform()) / classes as f64;
            let freq = 2.0 + (class % 3) as f64;
            let (s, c) = angle.sin_cos();
            let phase = rng.uniform() * std::f64::consts::TAU;
            let mut img = Tensor::zeros(&[1, side, side]);
            for y in 0..side {
                for x in 0..side {
                    let u = (x as f64 / side as f64 - 0.5) * c + (y as f64 / side as f64 - 0.5) * s;
                    let v = (0.5 + 0.5 * (std::f64::consts::TAU * freq * u + phase).sin())
                        + 0.15 * rng.normal();
                    img.set3(0, y, x, v.clamp(0.0, 1.0) as f32);
                }
            }
            images.push(img);
            labels.push(class);
        }
        Dataset { images, labels, classes, side }
    }

    /// Multispectral edge-sensor frames: `channels`-deep `side × side`
    /// images where each class is an oriented grating viewed through a
    /// per-channel *spectral tilt* — channel `c` sees the grating at a
    /// scaled spatial frequency and a class-dependent amplitude (the
    /// multi-band signature a real multispectral sensor produces). This
    /// is the `adcim compress` deluge workload: class-discriminative
    /// energy concentrates in few sequency bins per channel, which is
    /// exactly where top-K frequency-domain retention earns its ratio.
    pub fn multispectral(
        n: usize,
        classes: usize,
        side: usize,
        channels: usize,
        seed: u64,
    ) -> Self {
        assert!(classes > 0 && channels > 0);
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.index(classes);
            let angle =
                std::f64::consts::PI * (class as f64 + 0.3 * rng.uniform()) / classes as f64;
            let (s, c) = angle.sin_cos();
            let base_freq = 2.0 + (class % 3) as f64;
            // Small shared jitter; the class signature must survive it.
            let phase = 0.4 * rng.uniform() * std::f64::consts::TAU;
            let mut img = Tensor::zeros(&[channels, side, side]);
            for ch in 0..channels {
                // Spectral tilt: higher channels see the pattern at a
                // higher spatial frequency…
                let tilt = 1.0 + 0.5 * ch as f64 / channels.max(2) as f64;
                // …and a class × channel amplitude signature (linearly
                // separable even before orientation is decoded).
                let sig = ((class * (ch + 2) + ch) % classes) as f64
                    / (classes - 1).max(1) as f64;
                let amp = 0.15 + 0.3 * sig;
                for y in 0..side {
                    for x in 0..side {
                        let u = (x as f64 / side as f64 - 0.5) * c
                            + (y as f64 / side as f64 - 0.5) * s;
                        let wave =
                            (std::f64::consts::TAU * base_freq * tilt * u + phase).sin();
                        let v = 0.5 + amp * wave + 0.08 * rng.normal();
                        img.set3(ch, y, x, v.clamp(0.0, 1.0) as f32);
                    }
                }
            }
            images.push(img);
            labels.push(class);
        }
        Dataset { images, labels, classes, side }
    }

    /// Procedural digit glyphs (10 classes): seven-segment masks with
    /// positional jitter, stroke-width variation and noise.
    pub fn digits(n: usize, side: usize, seed: u64) -> Self {
        // Segment layout: 0 top, 1 top-left, 2 top-right, 3 middle,
        // 4 bottom-left, 5 bottom-right, 6 bottom.
        const GLYPHS: [[bool; 7]; 10] = [
            [true, true, true, false, true, true, true],    // 0
            [false, false, true, false, false, true, false], // 1
            [true, false, true, true, true, false, true],   // 2
            [true, false, true, true, false, true, true],   // 3
            [false, true, true, true, false, true, false],  // 4
            [true, true, false, true, false, true, true],   // 5
            [true, true, false, true, true, true, true],    // 6
            [true, false, true, false, false, true, false], // 7
            [true, true, true, true, true, true, true],     // 8
            [true, true, true, true, false, true, true],    // 9
        ];
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let digit = rng.index(10);
            let segs = GLYPHS[digit];
            let jx = (rng.uniform() * 0.2 - 0.1) as f32;
            let jy = (rng.uniform() * 0.2 - 0.1) as f32;
            let thick = 0.08 + 0.05 * rng.uniform() as f32;
            let mut img = Tensor::zeros(&[1, side, side]);
            for y in 0..side {
                for x in 0..side {
                    // Normalised glyph coords: x in [0.25,0.75], y in [0.1,0.9].
                    let u = x as f32 / side as f32 - jx;
                    let v = y as f32 / side as f32 - jy;
                    let lit = segs
                        .iter()
                        .enumerate()
                        .filter(|(_, &on)| on)
                        .any(|(i, _)| segment_hit(i, u, v, thick as f32));
                    let noise = 0.1 * rng.normal() as f32;
                    img.set3(0, y, x, ((if lit { 0.9 } else { 0.1 }) + noise).clamp(0.0, 1.0));
                }
            }
            images.push(img);
            labels.push(digit);
        }
        Dataset { images, labels, classes: 10, side }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.images.len()
    }

    /// True when the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// The same dataset with every image reshaped to a flat 1-D vector
    /// (what the MLP serving stack and the frontend codec consume).
    pub fn flattened(&self) -> Dataset {
        Dataset {
            images: self.images.iter().map(|i| i.clone().reshape(&[i.len()])).collect(),
            labels: self.labels.clone(),
            classes: self.classes,
            side: self.side,
        }
    }

    /// Deterministic train/test split (fraction to train).
    pub fn split(self, train_frac: f64) -> (Dataset, Dataset) {
        let n_train = (self.len() as f64 * train_frac) as usize;
        let (ti, vi) = (
            self.images[..n_train].to_vec(),
            self.images[n_train..].to_vec(),
        );
        let (tl, vl) = (
            self.labels[..n_train].to_vec(),
            self.labels[n_train..].to_vec(),
        );
        (
            Dataset { images: ti, labels: tl, classes: self.classes, side: self.side },
            Dataset { images: vi, labels: vl, classes: self.classes, side: self.side },
        )
    }
}

/// Hit-test one seven-segment segment in normalised glyph coordinates.
fn segment_hit(seg: usize, u: f32, v: f32, t: f32) -> bool {
    let hline = |cy: f32, u: f32, v: f32| (v - cy).abs() < t && (0.3..=0.7).contains(&u);
    let vline = |cx: f32, lo: f32, hi: f32, u: f32, v: f32| {
        (u - cx).abs() < t && (lo..=hi).contains(&v)
    };
    match seg {
        0 => hline(0.15, u, v),
        1 => vline(0.3, 0.15, 0.5, u, v),
        2 => vline(0.7, 0.15, 0.5, u, v),
        3 => hline(0.5, u, v),
        4 => vline(0.3, 0.5, 0.85, u, v),
        5 => vline(0.7, 0.5, 0.85, u, v),
        6 => hline(0.85, u, v),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oriented_patterns_shapes_and_range() {
        let d = Dataset::oriented_patterns(50, 8, 16, 1);
        assert_eq!(d.len(), 50);
        assert_eq!(d.images[0].shape(), &[1, 16, 16]);
        for img in &d.images {
            assert!(img.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        assert!(d.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_generation() {
        let a = Dataset::oriented_patterns(10, 4, 8, 7);
        let b = Dataset::oriented_patterns(10, 4, 8, 7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images[3].data(), b.images[3].data());
        let c = Dataset::oriented_patterns(10, 4, 8, 8);
        assert_ne!(a.images[3].data(), c.images[3].data());
    }

    #[test]
    fn multispectral_shapes_range_and_determinism() {
        let d = Dataset::multispectral(40, 4, 8, 4, 9);
        assert_eq!(d.len(), 40);
        assert_eq!(d.images[0].shape(), &[4, 8, 8]);
        for img in &d.images {
            assert!(img.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        assert!(d.labels.iter().all(|&l| l < 4));
        let e = Dataset::multispectral(40, 4, 8, 4, 9);
        assert_eq!(d.labels, e.labels);
        assert_eq!(d.images[7].data(), e.images[7].data());
    }

    /// The per-channel amplitude signature makes class means separable —
    /// what lets `adcim compress` train a classifier on this workload.
    #[test]
    fn multispectral_classes_are_distinguishable() {
        let d = Dataset::multispectral(200, 4, 8, 4, 21);
        let mean_img = |cls: usize| -> Vec<f32> {
            let mut acc = vec![0.0f32; 4 * 64];
            let mut n = 0usize;
            for (img, &l) in d.images.iter().zip(&d.labels) {
                if l == cls {
                    for (a, &v) in acc.iter_mut().zip(img.data()) {
                        *a += v;
                    }
                    n += 1;
                }
            }
            acc.iter().map(|v| v / n.max(1) as f32).collect()
        };
        let (m0, m2) = (mean_img(0), mean_img(2));
        let dist: f32 = m0.iter().zip(&m2).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn digits_cover_all_classes() {
        let d = Dataset::digits(200, 12, 3);
        let mut seen = [false; 10];
        for &l in &d.labels {
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s), "labels={:?}", seen);
    }

    #[test]
    fn digits_are_distinguishable() {
        // Mean image of class 1 and class 8 must differ markedly.
        let d = Dataset::digits(400, 12, 5);
        let mean_of = |cls: usize| -> Vec<f32> {
            let mut acc = vec![0.0f32; 144];
            let mut n = 0;
            for (img, &l) in d.images.iter().zip(&d.labels) {
                if l == cls {
                    for (a, &v) in acc.iter_mut().zip(img.data()) {
                        *a += v;
                    }
                    n += 1;
                }
            }
            acc.iter().map(|v| v / n as f32).collect()
        };
        let m1 = mean_of(1);
        let m8 = mean_of(8);
        let dist: f32 = m1.iter().zip(&m8).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 5.0, "class means too close: {dist}");
    }

    #[test]
    fn flattened_preserves_data() {
        let d = Dataset::multispectral(6, 4, 8, 3, 2);
        let f = d.flattened();
        assert_eq!(f.images[0].shape(), &[3 * 64]);
        assert_eq!(f.images[2].data(), d.images[2].data());
        assert_eq!(f.labels, d.labels);
    }

    #[test]
    fn split_partitions() {
        let d = Dataset::digits(100, 12, 9);
        let (tr, te) = d.split(0.8);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }
}
