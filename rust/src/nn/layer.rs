//! Hand-rolled backprop layers.
//!
//! Sample-at-a-time training (batch gradients are accumulated across
//! `backward` calls and applied by `step`). Every layer reports its
//! parameter and MAC counts so the compression accounting of Figs 1(c,d)
//! is structural, not estimated.

use crate::util::Rng;

use super::tensor::Tensor;

/// Common layer interface (forward caches what backward needs).
pub trait Layer: Send {
    /// Forward pass; caches activations for backward.
    fn forward(&mut self, x: &Tensor) -> Tensor;
    /// Forward pass without training bookkeeping: layers may skip
    /// activation caching and reuse internal scratch buffers. Must
    /// produce the same values as [`Layer::forward`]; calling
    /// `backward` afterwards is unsupported. Default falls back to the
    /// training path — hot layers override (the serving path,
    /// EXPERIMENTS.md §Perf).
    fn forward_inference(&mut self, x: &Tensor) -> Tensor {
        self.forward(x)
    }
    /// Batched inference forward: every sample of the served batch
    /// advances through this layer together (the lockstep walk of
    /// `Sequential::forward_batch_inference`). The default is the
    /// per-sample [`Layer::forward_inference`] loop — bit-exact by
    /// construction for any layer. Hot layers override with genuinely
    /// batched execution (`Dense`'s multi-RHS matvec, `BwhtLayer`'s
    /// cross-sample plane fusion); overrides MUST return values
    /// bit-identical to the default loop, sample order preserved.
    fn forward_batch_inference(&mut self, xs: &[Tensor]) -> Vec<Tensor> {
        xs.iter().map(|x| self.forward_inference(x)).collect()
    }
    /// Backward pass: gradient w.r.t. input; accumulates param grads.
    fn backward(&mut self, grad_out: &Tensor) -> Tensor;
    /// Apply accumulated gradients (averaged over `batch`) and clear.
    fn step(&mut self, lr: f32, batch: usize);
    /// Trainable parameter count.
    fn param_count(&self) -> usize;
    /// Multiply-accumulate ops for one forward pass.
    fn mac_count(&self) -> usize;
    /// Human-readable kind (reports).
    fn name(&self) -> &'static str;
    /// Clone into a boxed trait object — what lets `Sequential` (and the
    /// analog batch engine's worker shards) duplicate a model.
    fn clone_box(&self) -> Box<dyn Layer>;
}

/// Kaiming-ish init scale.
fn init_std(fan_in: usize) -> f32 {
    (2.0 / fan_in as f32).sqrt()
}

/// Dot product with eight independent accumulators.
///
/// PERF: a scalar `acc += w*x` reduction serializes on the FP-add
/// latency (~4 cycles per element); eight lanes break the dependency
/// chain and let the backend vectorize, which is the dominant win on the
/// Dense matvec of the digit-MLP serving path (EXPERIMENTS.md §Perf).
/// Summation order differs from the scalar loop by reassociation only.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let n8 = a.len() - a.len() % 8;
    let (ah, at) = a.split_at(n8);
    let (bh, bt) = b.split_at(n8);
    for (ca, cb) in ah.chunks_exact(8).zip(bh.chunks_exact(8)) {
        for i in 0..8 {
            lanes[i] += ca[i] * cb[i];
        }
    }
    let tail: f32 = at.iter().zip(bt).map(|(x, y)| x * y).sum();
    tail + ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
        + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
}

// ---------------------------------------------------------------- Dense

/// Fully connected layer `y = Wx + b`.
#[derive(Clone)]
pub struct Dense {
    /// Input width.
    pub in_dim: usize,
    /// Output width.
    pub out_dim: usize,
    w: Vec<f32>,
    b: Vec<f32>,
    gw: Vec<f32>,
    gb: Vec<f32>,
    // momentum buffers
    mw: Vec<f32>,
    mb: Vec<f32>,
    cache_x: Vec<f32>,
}

impl Dense {
    /// Weight matrix, `[out_dim][in_dim]` row-major (read-only view —
    /// the compressed-serving fast path folds these into the sequency
    /// domain).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Bias vector.
    pub fn bias(&self) -> &[f32] {
        &self.b
    }

    /// Overwrite weights/bias (e.g. from AOT-exported JAX parameters).
    /// `w` is `[out_dim][in_dim]` row-major.
    pub fn set_weights(&mut self, w: Vec<f32>, b: Vec<f32>) {
        assert_eq!(w.len(), self.in_dim * self.out_dim);
        assert_eq!(b.len(), self.out_dim);
        self.w = w;
        self.b = b;
    }

    /// Kaiming-initialized dense layer.
    pub fn new(in_dim: usize, out_dim: usize, rng: &mut Rng) -> Self {
        let std = init_std(in_dim);
        Dense {
            in_dim,
            out_dim,
            w: (0..in_dim * out_dim).map(|_| rng.normal() as f32 * std).collect(),
            b: vec![0.0; out_dim],
            gw: vec![0.0; in_dim * out_dim],
            gb: vec![0.0; out_dim],
            mw: vec![0.0; in_dim * out_dim],
            mb: vec![0.0; out_dim],
            cache_x: Vec::new(),
        }
    }
}

impl Dense {
    /// `Wx + b` with the unrolled dot product (shared by both forwards).
    fn matvec(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.len(), self.in_dim, "Dense input size");
        let mut y = vec![0.0f32; self.out_dim];
        for (o, slot) in y.iter_mut().enumerate() {
            let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
            *slot = self.b[o] + dot_f32(row, x.data());
        }
        Tensor::from_vec(&[self.out_dim], y)
    }
}

impl Layer for Dense {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        self.cache_x = x.data().to_vec();
        self.matvec(x)
    }

    fn forward_inference(&mut self, x: &Tensor) -> Tensor {
        // No backward follows: skip the activation cache copy.
        self.matvec(x)
    }

    fn forward_batch_inference(&mut self, xs: &[Tensor]) -> Vec<Tensor> {
        // Multi-RHS matvec: stream each weight row once across the
        // whole batch (one pass over W instead of one per sample).
        // Each slot is the same `b[o] + dot_f32(row, x)` as `matvec`,
        // so values are bit-identical to the per-sample loop; only the
        // W traffic is amortized (EXPERIMENTS.md §Perf, PR 7).
        for x in xs {
            assert_eq!(x.len(), self.in_dim, "Dense input size");
        }
        let mut ys = vec![vec![0.0f32; self.out_dim]; xs.len()];
        for o in 0..self.out_dim {
            let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
            let b = self.b[o];
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                y[o] = b + dot_f32(row, x.data());
            }
        }
        ys.into_iter().map(|y| Tensor::from_vec(&[self.out_dim], y)).collect()
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        assert_eq!(g.len(), self.out_dim);
        let mut gx = vec![0.0f32; self.in_dim];
        for o in 0..self.out_dim {
            let go = g.data()[o];
            self.gb[o] += go;
            let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
            let grow = &mut self.gw[o * self.in_dim..(o + 1) * self.in_dim];
            for i in 0..self.in_dim {
                grow[i] += go * self.cache_x[i];
                gx[i] += go * row[i];
            }
        }
        Tensor::vec1(&gx)
    }

    fn step(&mut self, lr: f32, batch: usize) {
        let scale = 1.0 / batch as f32;
        for i in 0..self.w.len() {
            self.mw[i] = 0.9 * self.mw[i] + self.gw[i] * scale;
            self.w[i] -= lr * self.mw[i];
            self.gw[i] = 0.0;
        }
        for o in 0..self.out_dim {
            self.mb[o] = 0.9 * self.mb[o] + self.gb[o] * scale;
            self.b[o] -= lr * self.mb[o];
            self.gb[o] = 0.0;
        }
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn mac_count(&self) -> usize {
        self.in_dim * self.out_dim
    }

    fn name(&self) -> &'static str {
        "dense"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

// --------------------------------------------------------------- Conv2d

/// 2-D convolution, CHW, stride 1, same padding, odd kernel.
#[derive(Clone)]
pub struct Conv2d {
    /// Input channels.
    pub in_ch: usize,
    /// Output channels.
    pub out_ch: usize,
    /// Kernel side (odd).
    pub k: usize,
    /// Spatial height x width the layer operates on.
    pub hw: (usize, usize),
    w: Vec<f32>, // [out_ch, in_ch, k, k]
    b: Vec<f32>,
    gw: Vec<f32>,
    gb: Vec<f32>,
    mw: Vec<f32>,
    mb: Vec<f32>,
    cache_x: Tensor,
}

impl Conv2d {
    /// Kaiming-initialized convolution.
    pub fn new(in_ch: usize, out_ch: usize, k: usize, hw: (usize, usize), rng: &mut Rng) -> Self {
        assert!(k % 2 == 1, "odd kernels only");
        let n = out_ch * in_ch * k * k;
        let std = init_std(in_ch * k * k);
        Conv2d {
            in_ch,
            out_ch,
            k,
            hw,
            w: (0..n).map(|_| rng.normal() as f32 * std).collect(),
            b: vec![0.0; out_ch],
            gw: vec![0.0; n],
            gb: vec![0.0; out_ch],
            mw: vec![0.0; n],
            mb: vec![0.0; out_ch],
            cache_x: Tensor::zeros(&[1, 1, 1]),
        }
    }

    #[inline]
    fn widx(&self, o: usize, i: usize, dy: usize, dx: usize) -> usize {
        ((o * self.in_ch + i) * self.k + dy) * self.k + dx
    }
}

impl Layer for Conv2d {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        let (c, h, w) = x.dims3();
        assert_eq!(c, self.in_ch);
        assert_eq!((h, w), self.hw);
        self.cache_x = x.clone();
        let r = (self.k / 2) as isize;
        let mut y = Tensor::zeros(&[self.out_ch, h, w]);
        for o in 0..self.out_ch {
            for yy in 0..h {
                for xx in 0..w {
                    let mut acc = self.b[o];
                    for i in 0..self.in_ch {
                        for dy in -r..=r {
                            let sy = yy as isize + dy;
                            if sy < 0 || sy >= h as isize {
                                continue;
                            }
                            for dx in -r..=r {
                                let sx = xx as isize + dx;
                                if sx < 0 || sx >= w as isize {
                                    continue;
                                }
                                let wv = self.w[self.widx(
                                    o,
                                    i,
                                    (dy + r) as usize,
                                    (dx + r) as usize,
                                )];
                                acc += wv * x.at3(i, sy as usize, sx as usize);
                            }
                        }
                    }
                    y.set3(o, yy, xx, acc);
                }
            }
        }
        y
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        let (c, h, w) = self.cache_x.dims3();
        let r = (self.k / 2) as isize;
        let mut gx = Tensor::zeros(&[c, h, w]);
        for o in 0..self.out_ch {
            for yy in 0..h {
                for xx in 0..w {
                    let go = g.at3(o, yy, xx);
                    self.gb[o] += go;
                    for i in 0..self.in_ch {
                        for dy in -r..=r {
                            let sy = yy as isize + dy;
                            if sy < 0 || sy >= h as isize {
                                continue;
                            }
                            for dx in -r..=r {
                                let sx = xx as isize + dx;
                                if sx < 0 || sx >= w as isize {
                                    continue;
                                }
                                let wi = self.widx(o, i, (dy + r) as usize, (dx + r) as usize);
                                self.gw[wi] += go * self.cache_x.at3(i, sy as usize, sx as usize);
                                let cur = gx.at3(i, sy as usize, sx as usize);
                                gx.set3(i, sy as usize, sx as usize, cur + go * self.w[wi]);
                            }
                        }
                    }
                }
            }
        }
        gx
    }

    fn step(&mut self, lr: f32, batch: usize) {
        let scale = 1.0 / batch as f32;
        for i in 0..self.w.len() {
            self.mw[i] = 0.9 * self.mw[i] + self.gw[i] * scale;
            self.w[i] -= lr * self.mw[i];
            self.gw[i] = 0.0;
        }
        for o in 0..self.out_ch {
            self.mb[o] = 0.9 * self.mb[o] + self.gb[o] * scale;
            self.b[o] -= lr * self.mb[o];
            self.gb[o] = 0.0;
        }
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn mac_count(&self) -> usize {
        self.out_ch * self.in_ch * self.k * self.k * self.hw.0 * self.hw.1
    }

    fn name(&self) -> &'static str {
        "conv2d"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

// ----------------------------------------------------------- activations

/// ReLU.
#[derive(Clone, Default)]
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    /// Fresh ReLU (mask filled on forward).
    pub fn new() -> Self {
        Relu { mask: Vec::new() }
    }
}

impl Layer for Relu {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        self.mask = x.data().iter().map(|&v| v > 0.0).collect();
        x.clone().map(|v| v.max(0.0))
    }

    fn forward_inference(&mut self, x: &Tensor) -> Tensor {
        // No backward follows: skip the mask allocation.
        x.clone().map(|v| v.max(0.0))
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        let mut out = g.clone();
        for (v, &m) in out.data_mut().iter_mut().zip(&self.mask) {
            if !m {
                *v = 0.0;
            }
        }
        out
    }

    fn step(&mut self, _lr: f32, _batch: usize) {}

    fn param_count(&self) -> usize {
        0
    }

    fn mac_count(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "relu"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

/// Leaky ReLU (`slope·x` for x < 0). The conv miniatures use this
/// instead of plain ReLU: at their size a bad init can kill every unit
/// in a layer (dead-ReLU cascade), and the leak keeps gradients alive —
/// training becomes seed-robust instead of seed-lucky.
#[derive(Clone)]
pub struct LeakyRelu {
    slope: f32,
    mask: Vec<bool>,
}

impl LeakyRelu {
    /// Leaky ReLU with the given negative-side slope.
    pub fn new(slope: f32) -> Self {
        LeakyRelu { slope, mask: Vec::new() }
    }
}

impl Layer for LeakyRelu {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        self.mask = x.data().iter().map(|&v| v > 0.0).collect();
        let s = self.slope;
        x.clone().map(|v| if v > 0.0 { v } else { s * v })
    }

    fn forward_inference(&mut self, x: &Tensor) -> Tensor {
        let s = self.slope;
        x.clone().map(|v| if v > 0.0 { v } else { s * v })
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        let mut out = g.clone();
        for (v, &m) in out.data_mut().iter_mut().zip(&self.mask) {
            if !m {
                *v *= self.slope;
            }
        }
        out
    }

    fn step(&mut self, _lr: f32, _batch: usize) {}

    fn param_count(&self) -> usize {
        0
    }

    fn mac_count(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "leaky_relu"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

/// Per-channel affine `y = a·x + c` (batch-norm stand-in that trains
/// sample-at-a-time).
#[derive(Clone)]
pub struct BatchScale {
    ch: usize,
    a: Vec<f32>,
    c: Vec<f32>,
    ga: Vec<f32>,
    gc: Vec<f32>,
    cache_x: Tensor,
}

impl BatchScale {
    /// Identity-initialized per-channel scale/shift over `ch` channels.
    pub fn new(ch: usize) -> Self {
        BatchScale {
            ch,
            a: vec![1.0; ch],
            c: vec![0.0; ch],
            ga: vec![0.0; ch],
            gc: vec![0.0; ch],
            cache_x: Tensor::zeros(&[1, 1, 1]),
        }
    }
}

impl Layer for BatchScale {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        let (c, h, w) = x.dims3();
        assert_eq!(c, self.ch);
        self.cache_x = x.clone();
        let mut y = x.clone();
        for ci in 0..c {
            for hi in 0..h {
                for wi in 0..w {
                    y.set3(ci, hi, wi, self.a[ci] * x.at3(ci, hi, wi) + self.c[ci]);
                }
            }
        }
        y
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        let (c, h, w) = self.cache_x.dims3();
        let mut gx = g.clone();
        for ci in 0..c {
            for hi in 0..h {
                for wi in 0..w {
                    let gv = g.at3(ci, hi, wi);
                    self.ga[ci] += gv * self.cache_x.at3(ci, hi, wi);
                    self.gc[ci] += gv;
                    gx.set3(ci, hi, wi, gv * self.a[ci]);
                }
            }
        }
        gx
    }

    fn step(&mut self, lr: f32, batch: usize) {
        let scale = 1.0 / batch as f32;
        for i in 0..self.ch {
            self.a[i] -= lr * self.ga[i] * scale;
            self.c[i] -= lr * self.gc[i] * scale;
            self.ga[i] = 0.0;
            self.gc[i] = 0.0;
        }
    }

    fn param_count(&self) -> usize {
        2 * self.ch
    }

    fn mac_count(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "batch_scale"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

/// Global average pool CHW → C.
#[derive(Clone, Default)]
pub struct GlobalAvgPool {
    dims: (usize, usize, usize),
}

impl GlobalAvgPool {
    /// Fresh pool (dims captured on forward).
    pub fn new() -> Self {
        GlobalAvgPool { dims: (0, 0, 0) }
    }
}

impl Layer for GlobalAvgPool {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        let (c, h, w) = x.dims3();
        self.dims = (c, h, w);
        let mut y = vec![0.0f32; c];
        for (ci, val) in y.iter_mut().enumerate() {
            let mut acc = 0.0;
            for hi in 0..h {
                for wi in 0..w {
                    acc += x.at3(ci, hi, wi);
                }
            }
            *val = acc / (h * w) as f32;
        }
        Tensor::vec1(&y)
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        let (c, h, w) = self.dims;
        let mut gx = Tensor::zeros(&[c, h, w]);
        let inv = 1.0 / (h * w) as f32;
        for ci in 0..c {
            let gv = g.data()[ci] * inv;
            for hi in 0..h {
                for wi in 0..w {
                    gx.set3(ci, hi, wi, gv);
                }
            }
        }
        gx
    }

    fn step(&mut self, _lr: f32, _batch: usize) {}

    fn param_count(&self) -> usize {
        0
    }

    fn mac_count(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "global_avg_pool"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

/// 2×2 average pooling, stride 2 (CHW; odd trailing row/col dropped).
#[derive(Clone, Default)]
pub struct AvgPool2d {
    dims: (usize, usize, usize),
}

impl AvgPool2d {
    /// Fresh 2x2 average pool (dims captured on forward).
    pub fn new() -> Self {
        AvgPool2d { dims: (0, 0, 0) }
    }
}

impl Layer for AvgPool2d {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        let (c, h, w) = x.dims3();
        self.dims = (c, h, w);
        let (oh, ow) = (h / 2, w / 2);
        let mut y = Tensor::zeros(&[c, oh, ow]);
        for ci in 0..c {
            for yy in 0..oh {
                for xx in 0..ow {
                    let s = x.at3(ci, 2 * yy, 2 * xx)
                        + x.at3(ci, 2 * yy + 1, 2 * xx)
                        + x.at3(ci, 2 * yy, 2 * xx + 1)
                        + x.at3(ci, 2 * yy + 1, 2 * xx + 1);
                    y.set3(ci, yy, xx, s * 0.25);
                }
            }
        }
        y
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        let (c, h, w) = self.dims;
        let mut gx = Tensor::zeros(&[c, h, w]);
        let (oh, ow) = (h / 2, w / 2);
        for ci in 0..c {
            for yy in 0..oh {
                for xx in 0..ow {
                    let gv = g.at3(ci, yy, xx) * 0.25;
                    gx.set3(ci, 2 * yy, 2 * xx, gv);
                    gx.set3(ci, 2 * yy + 1, 2 * xx, gv);
                    gx.set3(ci, 2 * yy, 2 * xx + 1, gv);
                    gx.set3(ci, 2 * yy + 1, 2 * xx + 1, gv);
                }
            }
        }
        gx
    }

    fn step(&mut self, _lr: f32, _batch: usize) {}

    fn param_count(&self) -> usize {
        0
    }

    fn mac_count(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "avg_pool2d"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

/// Flatten CHW → vector.
#[derive(Clone, Default)]
pub struct Flatten {
    shape: Vec<usize>,
}

impl Flatten {
    /// Fresh flatten (input shape captured on forward).
    pub fn new() -> Self {
        Flatten { shape: Vec::new() }
    }
}

impl Layer for Flatten {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        self.shape = x.shape().to_vec();
        x.clone().reshape(&[x.len()])
    }

    fn backward(&mut self, g: &Tensor) -> Tensor {
        g.clone().reshape(&self.shape.clone())
    }

    fn step(&mut self, _lr: f32, _batch: usize) {}

    fn param_count(&self) -> usize {
        0
    }

    fn mac_count(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "flatten"
    }

    fn clone_box(&self) -> Box<dyn Layer> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference gradient check for a layer on a small input.
    fn grad_check<L: Layer>(layer: &mut L, shape: &[usize], seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor::from_vec(shape, rng.normal_vec(shape.iter().product()));
        // Loss = sum(forward(x)); grad_out = ones.
        let y = layer.forward(&x);
        let ones = Tensor::from_vec(y.shape(), vec![1.0; y.len()]);
        let gx = layer.backward(&ones);

        let eps = 1e-3f32;
        for i in (0..x.len()).step_by((x.len() / 6).max(1)) {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fp: f32 = layer.forward(&xp).data().iter().sum();
            let fm: f32 = layer.forward(&xm).data().iter().sum();
            let num = (fp - fm) / (2.0 * eps);
            let ana = gx.data()[i];
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                "grad mismatch at {i}: numeric {num}, analytic {ana}"
            );
        }
    }

    #[test]
    fn dot_f32_matches_scalar_reduction() {
        let mut rng = Rng::new(77);
        for n in [0usize, 1, 7, 8, 9, 63, 144, 1000] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot_f32(&a, &b);
            assert!(
                (scalar - fast).abs() <= 1e-4 * (1.0 + scalar.abs()),
                "n={n}: scalar {scalar} vs unrolled {fast}"
            );
        }
    }

    #[test]
    fn forward_inference_matches_forward() {
        let mut rng = Rng::new(78);
        let mut d = Dense::new(17, 9, &mut rng);
        let x = Tensor::vec1(&rng.normal_vec(17));
        assert_eq!(d.forward(&x).data(), d.forward_inference(&x).data());
        let mut r = Relu::new();
        assert_eq!(r.forward(&x).data(), r.forward_inference(&x).data());
        let mut l = LeakyRelu::new(0.1);
        assert_eq!(l.forward(&x).data(), l.forward_inference(&x).data());
    }

    #[test]
    fn dense_batched_inference_is_bit_exact() {
        let mut rng = Rng::new(91);
        let mut d = Dense::new(33, 11, &mut rng);
        let xs: Vec<Tensor> =
            (0..5).map(|_| Tensor::vec1(&rng.normal_vec(33))).collect();
        let per_sample: Vec<Tensor> =
            xs.iter().map(|x| d.forward_inference(x)).collect();
        let batched = d.forward_batch_inference(&xs);
        assert_eq!(per_sample.len(), batched.len());
        for (a, b) in per_sample.iter().zip(&batched) {
            assert_eq!(a.data(), b.data());
        }
        // Default trait loop (any layer) is the same thing by definition.
        let mut r = Relu::new();
        let lb = r.forward_batch_inference(&xs);
        for (x, y) in xs.iter().zip(&lb) {
            assert_eq!(r.forward_inference(x).data(), y.data());
        }
    }

    #[test]
    fn clone_box_duplicates_parameters() {
        let mut rng = Rng::new(79);
        let mut d = Dense::new(6, 3, &mut rng);
        let mut c = d.clone_box();
        let x = Tensor::vec1(&rng.normal_vec(6));
        assert_eq!(d.forward(&x).data(), c.forward(&x).data());
    }

    #[test]
    fn dense_forward_known_values() {
        let mut rng = Rng::new(1);
        let mut d = Dense::new(2, 1, &mut rng);
        d.w = vec![2.0, -1.0];
        d.b = vec![0.5];
        let y = d.forward(&Tensor::vec1(&[3.0, 4.0]));
        assert_eq!(y.data(), &[2.0 * 3.0 - 4.0 + 0.5]);
    }

    #[test]
    fn dense_grad_check() {
        let mut rng = Rng::new(2);
        let mut d = Dense::new(6, 4, &mut rng);
        grad_check(&mut d, &[6], 3);
    }

    #[test]
    fn conv_grad_check() {
        let mut rng = Rng::new(4);
        let mut c = Conv2d::new(2, 3, 3, (5, 5), &mut rng);
        grad_check(&mut c, &[2, 5, 5], 5);
    }

    #[test]
    fn relu_grad_check() {
        let mut r = Relu::new();
        grad_check(&mut r, &[10], 6);
    }

    #[test]
    fn batch_scale_grad_check() {
        let mut b = BatchScale::new(3);
        grad_check(&mut b, &[3, 4, 4], 7);
    }

    #[test]
    fn pool_grad_check() {
        let mut p = GlobalAvgPool::new();
        grad_check(&mut p, &[3, 4, 4], 8);
    }

    #[test]
    fn avg_pool2d_grad_check_and_shape() {
        let mut p = AvgPool2d::new();
        let y = p.forward(&Tensor::zeros(&[3, 6, 6]));
        assert_eq!(y.shape(), &[3, 3, 3]);
        grad_check(&mut p, &[3, 6, 6], 12);
    }

    #[test]
    fn avg_pool2d_known_values() {
        let mut p = AvgPool2d::new();
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let y = p.forward(&x);
        assert_eq!(y.data(), &[2.5]);
    }

    #[test]
    fn flatten_round_trips() {
        let mut f = Flatten::new();
        let x = Tensor::from_vec(&[2, 2, 2], (0..8).map(|i| i as f32).collect());
        let y = f.forward(&x);
        assert_eq!(y.shape(), &[8]);
        let g = f.backward(&y);
        assert_eq!(g.shape(), &[2, 2, 2]);
        assert_eq!(g.data(), x.data());
    }

    #[test]
    fn dense_learns_linear_map() {
        // Train y = 2x0 - x1 with SGD; loss must collapse.
        let mut rng = Rng::new(9);
        let mut d = Dense::new(2, 1, &mut rng);
        for _ in 0..600 {
            let x = Tensor::vec1(&[rng.normal() as f32, rng.normal() as f32]);
            let target = 2.0 * x.data()[0] - x.data()[1];
            let y = d.forward(&x);
            let err = y.data()[0] - target;
            d.backward(&Tensor::vec1(&[2.0 * err]));
            // Per-sample stepping with 0.9 momentum: keep lr small.
            d.step(0.005, 1);
        }
        let y = d.forward(&Tensor::vec1(&[1.0, 1.0]));
        assert!((y.data()[0] - 1.0).abs() < 0.05, "got {}", y.data()[0]);
    }

    #[test]
    fn conv_mac_count() {
        let mut rng = Rng::new(10);
        let c = Conv2d::new(4, 8, 3, (16, 16), &mut rng);
        assert_eq!(c.mac_count(), 8 * 4 * 9 * 256);
        assert_eq!(c.param_count(), 8 * 4 * 9 + 8);
    }
}
