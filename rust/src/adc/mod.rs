//! Digitization substrate (paper §IV).
//!
//! When the ADC-free 1-bit path of [`crate::cim`] is not enough — i.e.
//! multi-bit MAV outputs must be digitized — the paper replaces dedicated
//! per-array ADCs with **memory-immersed collaborative digitization**:
//! a neighbouring compute-in-SRAM array's parasitic column lines act as
//! the capacitive DAC of a SAR/Flash/hybrid converter.
//!
//! - [`sar`] / [`flash`] — conventional SAR and Flash baselines
//!   (the Table I comparison rows, behavioural + energy/area anchors
//!   from [34]).
//! - [`immersed`] — the paper's SRAM-immersed converter: SAR, Flash and
//!   hybrid Flash+SAR modes, with the common-mode non-ideality
//!   cancellation that comes from generating references in an identical
//!   array.
//! - [`asymmetric`] — MAV-statistics-aware successive approximation
//!   (paper §IV-C, Fig 10): an optimal comparison tree for the skewed
//!   bitplane MAV distribution (~3.7 comparisons avg vs 5 for 5 bits),
//!   plus [`AsymmetricAdc`], the tree bound to an immersed converter
//!   behind the common trait.
//! - [`metrics`] — staircase, DNL, INL, ENOB characterization (Fig 12).
//!
//! Every converter style implements the [`Adc`] trait, and [`AnyAdc`]
//! packages them into one clonable value so the serving-path digitizer
//! ([`crate::cim::pool::CimArrayPool`]) picks its converter at
//! construction time — Sar/Flash/Hybrid immersed, asymmetric-tree, or
//! the dedicated baselines — without monomorphising the pool.

pub mod asymmetric;
pub mod flash;
pub mod immersed;
pub mod metrics;
pub mod sar;

pub use asymmetric::{binomial_mav_pmf, AsymmetricAdc, AsymmetricSearch};
pub use flash::FlashAdc;
pub use immersed::{ImmersedAdc, ImmersedMode};
pub use metrics::{staircase, Linearity};
pub use sar::SarAdc;

use crate::util::Rng;

/// Result of one analog→digital conversion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Conversion {
    /// Output code in `[0, 2^bits)`.
    pub code: u32,
    /// Comparator decisions used.
    pub comparisons: u32,
    /// Clock cycles used (Flash resolves many comparisons per cycle).
    pub cycles: u32,
    /// Energy spent (fJ): comparator decisions + reference generation.
    pub energy_fj: f64,
}

/// Common interface over all converter styles.
pub trait Adc {
    /// Resolution in bits.
    fn bits(&self) -> u8;
    /// Full-scale voltage.
    fn vdd(&self) -> f64;
    /// Convert one input voltage.
    fn convert(&mut self, v_in: f64, rng: &mut Rng) -> Conversion;

    /// Ideal (noise-free) code for `v` — the oracle used by tests and
    /// linearity metrics: `floor(v / vdd · 2^bits)` clamped to range.
    fn ideal_code(&self, v: f64) -> u32 {
        ideal_code(v, self.vdd(), self.bits())
    }
}

/// `floor(v / vdd · 2^bits)` clamped into `[0, 2^bits)`.
pub fn ideal_code(v: f64, vdd: f64, bits: u8) -> u32 {
    let n = 1u32 << bits;
    let t = (v / vdd * n as f64).floor();
    (t.max(0.0) as u32).min(n - 1)
}

/// Apply a converter gain/offset drift fault to an input voltage:
/// `v' = gain·v + offset·vdd`, clamped back to the rails. The second
/// return is `true` when the pre-clamp value left `[0, vdd]` — the
/// pool's per-converter MAV sanity bound counts those excursions
/// (`FaultStats::mav_out_of_bounds`).
pub fn drifted(v: f64, gain: f64, offset: f64, vdd: f64) -> (f64, bool) {
    let raw = gain * v + offset * vdd;
    let oob = !(0.0..=vdd).contains(&raw);
    (raw.clamp(0.0, vdd), oob)
}

/// Mid-bin calibration voltage for code `2^(bits−1)`: the centre of the
/// mid-scale code bin, so a healthy converter's probe code is maximally
/// robust to sub-LSB noise (the probe oracle compares against
/// [`ideal_code`] within a tolerance).
pub fn probe_voltage(vdd: f64, bits: u8) -> f64 {
    let n = (1u32 << bits) as f64;
    vdd * (n / 2.0 + 0.5) / n
}

/// Any converter style behind one clonable value — the construction-time
/// choice point of [`crate::cim::pool::CimArrayPool`] and the subject of
/// the trait-conformance property tests (`tests/adc_conformance.rs`).
#[derive(Debug, Clone)]
pub enum AnyAdc {
    /// Dedicated-DAC SAR baseline (Table I row 1).
    Sar(SarAdc),
    /// Dedicated resistor-ladder Flash baseline (Table I row 2).
    Flash(FlashAdc),
    /// Memory-immersed collaborative converter (any [`ImmersedMode`]).
    Immersed(ImmersedAdc),
    /// Immersed SAR driven by the Fig 10 asymmetric comparison tree.
    Asymmetric(AsymmetricAdc),
}

impl AnyAdc {
    /// Short label for reports and test diagnostics.
    pub fn style(&self) -> &'static str {
        match self {
            AnyAdc::Sar(_) => "dedicated-sar",
            AnyAdc::Flash(_) => "dedicated-flash",
            AnyAdc::Immersed(a) => match a.mode() {
                ImmersedMode::Sar => "immersed-sar",
                ImmersedMode::Flash => "immersed-flash",
                ImmersedMode::Hybrid { .. } => "immersed-hybrid",
            },
            AnyAdc::Asymmetric(_) => "immersed-asymmetric",
        }
    }
}

impl Adc for AnyAdc {
    fn bits(&self) -> u8 {
        match self {
            AnyAdc::Sar(a) => a.bits(),
            AnyAdc::Flash(a) => a.bits(),
            AnyAdc::Immersed(a) => a.bits(),
            AnyAdc::Asymmetric(a) => a.bits(),
        }
    }

    fn vdd(&self) -> f64 {
        match self {
            AnyAdc::Sar(a) => a.vdd(),
            AnyAdc::Flash(a) => a.vdd(),
            AnyAdc::Immersed(a) => a.vdd(),
            AnyAdc::Asymmetric(a) => a.vdd(),
        }
    }

    fn convert(&mut self, v_in: f64, rng: &mut Rng) -> Conversion {
        match self {
            AnyAdc::Sar(a) => a.convert(v_in, rng),
            AnyAdc::Flash(a) => a.convert(v_in, rng),
            AnyAdc::Immersed(a) => a.convert(v_in, rng),
            AnyAdc::Asymmetric(a) => a.convert(v_in, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_code_boundaries() {
        assert_eq!(ideal_code(0.0, 1.0, 5), 0);
        assert_eq!(ideal_code(0.999, 1.0, 5), 31);
        assert_eq!(ideal_code(1.5, 1.0, 5), 31); // clamps high
        assert_eq!(ideal_code(-0.2, 1.0, 5), 0); // clamps low
        // Mid-scale: 0.5 → code 16 of 32.
        assert_eq!(ideal_code(0.5, 1.0, 5), 16);
    }

    #[test]
    fn ideal_code_scales_with_vdd() {
        assert_eq!(ideal_code(0.425, 0.85, 5), 16);
    }

    #[test]
    fn drift_clamps_and_flags_excursions() {
        // Identity drift: untouched, in bounds.
        assert_eq!(drifted(0.4, 1.0, 0.0, 1.0), (0.4, false));
        // Gain pushes past the rail: clamped + flagged.
        assert_eq!(drifted(0.8, 2.0, 0.0, 1.0), (1.0, true));
        // Negative offset under the rail: clamped + flagged.
        assert_eq!(drifted(0.1, 1.0, -0.5, 1.0), (0.0, true));
        // In-range drift is not an excursion.
        let (v, oob) = drifted(0.4, 1.1, 0.05, 1.0);
        assert!((v - 0.49).abs() < 1e-12 && !oob);
    }

    #[test]
    fn probe_voltage_sits_mid_bin() {
        // 5 bits: centre of code-16 bin of 32 → ideal code 16 with
        // half-LSB slack on both sides.
        let v = probe_voltage(1.0, 5);
        assert_eq!(ideal_code(v, 1.0, 5), 16);
        assert_eq!(ideal_code(v - 0.4 / 32.0, 1.0, 5), 16);
        assert_eq!(ideal_code(v + 0.4 / 32.0, 1.0, 5), 16);
    }
}
