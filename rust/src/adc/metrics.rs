//! Converter characterization: staircase, DNL, INL (paper Fig 12).
//!
//! Mirrors the paper's test-chip measurement flow: sweep a fine voltage
//! ramp, record the measured transfer staircase, locate code transition
//! levels, and derive differential/integral non-linearity in LSB.

use crate::util::Rng;

use super::Adc;

/// Measured transfer function: `(v_in, code)` samples over a ramp.
pub fn staircase<A: Adc>(adc: &mut A, points: usize, rng: &mut Rng) -> Vec<(f64, u32)> {
    assert!(points >= 2);
    let vdd = adc.vdd();
    (0..points)
        .map(|i| {
            let v = vdd * i as f64 / (points - 1) as f64;
            (v, adc.convert(v, rng).code)
        })
        .collect()
}

/// Linearity metrics derived from measured transition levels.
#[derive(Debug, Clone)]
pub struct Linearity {
    /// Differential non-linearity per code step, in LSB.
    pub dnl: Vec<f64>,
    /// Integral non-linearity per code, in LSB.
    pub inl: Vec<f64>,
}

impl Linearity {
    /// Worst-case |DNL| in LSB.
    pub fn max_abs_dnl(&self) -> f64 {
        self.dnl.iter().fold(0.0, |a, d| a.max(d.abs()))
    }

    /// Worst-case |INL| in LSB.
    pub fn max_abs_inl(&self) -> f64 {
        self.inl.iter().fold(0.0, |a, d| a.max(d.abs()))
    }
}

/// Measure DNL/INL of a converter by ramp search for each transition
/// level `T_i` (first input producing code ≥ i), then
/// `DNL_i = (T_{i+1} − T_i)/LSB − 1`, `INL_i = (T_i − T_1)/LSB − (i−1)`.
pub fn linearity<A: Adc>(adc: &mut A, steps_per_code: usize, rng: &mut Rng) -> Linearity {
    let n = 1u32 << adc.bits();
    let vdd = adc.vdd();
    let lsb = vdd / n as f64;
    let fine = vdd / (n as usize * steps_per_code) as f64;

    // Majority-vote the code at each ramp point to suppress per-decision
    // comparator noise (the chip measurement averages the same way).
    let code_at = |adc: &mut A, v: f64, rng: &mut Rng| -> u32 {
        let mut votes = [0u32; 3];
        for s in 0..3 {
            votes[s] = adc.convert(v, rng).code;
        }
        votes.sort();
        votes[1]
    };

    // Transition levels T_1..T_{n-1}.
    let mut transitions = vec![f64::NAN; n as usize];
    let mut v = 0.0;
    let mut current = code_at(adc, 0.0, rng);
    while v < vdd {
        v += fine;
        let c = code_at(adc, v, rng);
        if c > current {
            // Record every transition we crossed (nonmonotone glitches
            // fill the first crossing only).
            for t in (current + 1)..=c.min(n - 1) {
                if transitions[t as usize].is_nan() {
                    transitions[t as usize] = v;
                }
            }
            current = c;
        }
    }

    // Fill any never-seen transitions (missing codes) with neighbours.
    for i in 1..n as usize {
        if transitions[i].is_nan() {
            transitions[i] = if i > 1 { transitions[i - 1] } else { 0.0 };
        }
    }

    let mut dnl = Vec::with_capacity(n as usize - 2);
    for i in 1..(n as usize - 1) {
        dnl.push((transitions[i + 1] - transitions[i]) / lsb - 1.0);
    }
    let mut inl = Vec::with_capacity(n as usize - 1);
    for i in 1..n as usize {
        inl.push((transitions[i] - transitions[1]) / lsb - (i as f64 - 1.0));
    }
    Linearity { dnl, inl }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adc::immersed::{ImmersedAdc, ImmersedMode};
    use crate::adc::sar::SarAdc;
    use crate::analog::NoiseModel;

    #[test]
    fn staircase_is_monotone_for_ideal_adc() {
        let mut adc = SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(1);
        let stairs = staircase(&mut adc, 400, &mut rng);
        assert!(stairs.windows(2).all(|w| w[1].1 >= w[0].1));
        assert_eq!(stairs.first().unwrap().1, 0);
        assert_eq!(stairs.last().unwrap().1, 31);
    }

    #[test]
    fn ideal_adc_has_zero_dnl_inl() {
        let mut adc = SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(2);
        let lin = linearity(&mut adc, 64, &mut rng);
        assert!(lin.max_abs_dnl() < 0.05, "dnl={}", lin.max_abs_dnl());
        assert!(lin.max_abs_inl() < 0.05, "inl={}", lin.max_abs_inl());
    }

    #[test]
    fn immersed_adc_near_ideal_linearity_with_default_noise() {
        // Fig 12: the measured chip shows sub-LSB DNL/INL.
        let noise = NoiseModel::default();
        let mut rng = Rng::new(3);
        let mut adc =
            ImmersedAdc::sample(5, 1.0, ImmersedMode::Sar, 32, 20.0, &noise, &mut rng);
        let lin = linearity(&mut adc, 32, &mut rng);
        assert!(lin.max_abs_dnl() < 1.0, "dnl={}", lin.max_abs_dnl());
        assert!(lin.max_abs_inl() < 1.5, "inl={}", lin.max_abs_inl());
    }

    #[test]
    fn heavy_mismatch_degrades_linearity() {
        let clean = NoiseModel { cap_mismatch_sigma: 0.001, ..NoiseModel::ideal() };
        let dirty = NoiseModel { cap_mismatch_sigma: 0.2, ..NoiseModel::ideal() };
        let mut rng = Rng::new(4);
        let mut adc_c = ImmersedAdc::sample(5, 1.0, ImmersedMode::Sar, 32, 20.0, &clean, &mut rng);
        let mut adc_d = ImmersedAdc::sample(5, 1.0, ImmersedMode::Sar, 32, 20.0, &dirty, &mut rng);
        let lin_c = linearity(&mut adc_c, 32, &mut rng);
        let lin_d = linearity(&mut adc_d, 32, &mut rng);
        assert!(lin_d.max_abs_inl() > lin_c.max_abs_inl());
    }
}
