//! Conventional Flash ADC baseline (Table I row 2, anchored to [34]).
//!
//! `2^bits − 1` parallel comparators against a resistor-ladder reference:
//! single-cycle conversion, but comparator count — and with it area and
//! energy — grows exponentially with resolution (the paper's Fig 13(a)
//! scaling argument).

use crate::analog::{Comparator, NoiseModel};
use crate::util::Rng;

use super::{Adc, Conversion};

/// Conventional Flash ADC with a per-level comparator bank.
#[derive(Debug, Clone)]
pub struct FlashAdc {
    bits: u8,
    vdd: f64,
    /// One comparator per transition level `i/2^bits`, `i = 1..2^bits-1`.
    comparators: Vec<Comparator>,
    /// Ladder tap errors (V), one per level.
    tap_err: Vec<f64>,
    /// Comparator decision energy (fJ).
    e_cmp_fj: f64,
    /// Static ladder energy per conversion (fJ).
    e_ladder_fj: f64,
}

impl FlashAdc {
    /// Draw a flash ADC instance with sampled comparator offsets.
    pub fn sample(bits: u8, vdd: f64, noise: &NoiseModel, rng: &mut Rng) -> Self {
        assert!((1..=10).contains(&bits));
        let levels = (1usize << bits) - 1;
        FlashAdc {
            bits,
            vdd,
            comparators: (0..levels).map(|_| Comparator::sample(noise, rng)).collect(),
            tap_err: (0..levels)
                .map(|_| rng.normal() * noise.cap_mismatch_sigma * vdd / (1u64 << bits) as f64)
                .collect(),
            e_cmp_fj: 5.0,
            e_ladder_fj: 20.0,
        }
    }

    /// Offset-free reference instance.
    pub fn ideal(bits: u8, vdd: f64) -> Self {
        let levels = (1usize << bits) - 1;
        FlashAdc {
            bits,
            vdd,
            comparators: (0..levels).map(|_| Comparator::ideal()).collect(),
            tap_err: vec![0.0; levels],
            e_cmp_fj: 5.0,
            e_ladder_fj: 20.0,
        }
    }

    /// Number of comparators (the exponential cost driver).
    pub fn comparator_count(&self) -> usize {
        self.comparators.len()
    }
}

impl Adc for FlashAdc {
    fn bits(&self) -> u8 {
        self.bits
    }

    fn vdd(&self) -> f64 {
        self.vdd
    }

    /// One cycle: all comparators fire; the output code is the
    /// thermometer count (bubble-tolerant encoding).
    fn convert(&mut self, v_in: f64, rng: &mut Rng) -> Conversion {
        let n = 1u64 << self.bits;
        let mut count = 0u32;
        for (i, cmp) in self.comparators.iter_mut().enumerate() {
            let v_ref = self.vdd * (i as f64 + 1.0) / n as f64 + self.tap_err[i];
            if cmp.compare(v_in, v_ref, rng) {
                count += 1;
            }
        }
        Conversion {
            code: count,
            comparisons: self.comparators.len() as u32,
            cycles: 1,
            energy_fj: self.comparators.len() as f64 * self.e_cmp_fj + self.e_ladder_fj,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn ideal_flash_matches_ideal_code() {
        prop::check("ideal flash == ideal_code", 256, |rng| {
            let bits = 2 + rng.index(6) as u8;
            let mut adc = FlashAdc::ideal(bits, 1.0);
            let v = rng.uniform();
            let got = adc.convert(v, rng).code;
            let expect = adc.ideal_code(v);
            crate::prop_assert!(got == expect, "bits={bits} v={v}: {got} != {expect}");
            Ok(())
        });
    }

    #[test]
    fn single_cycle_many_comparisons() {
        let mut adc = FlashAdc::ideal(5, 1.0);
        let mut rng = Rng::new(1);
        let c = adc.convert(0.61, &mut rng);
        assert_eq!(c.cycles, 1);
        assert_eq!(c.comparisons, 31);
    }

    #[test]
    fn comparator_count_exponential() {
        assert_eq!(FlashAdc::ideal(3, 1.0).comparator_count(), 7);
        assert_eq!(FlashAdc::ideal(8, 1.0).comparator_count(), 255);
    }

    #[test]
    fn flash_energy_exceeds_sar_energy_at_5_bits() {
        // The Table I shape: Flash burns ~9x SAR energy at 5 bits.
        let mut flash = FlashAdc::ideal(5, 1.0);
        let mut sar = super::super::sar::SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(2);
        let ef = flash.convert(0.5, &mut rng).energy_fj;
        let es = sar.convert(0.5, &mut rng).energy_fj;
        assert!(ef > 2.0 * es, "flash {ef} vs sar {es}");
    }

    #[test]
    fn offsets_cause_rare_code_errors_only() {
        let noise = NoiseModel::default();
        let mut rng = Rng::new(3);
        let mut adc = FlashAdc::sample(5, 1.0, &noise, &mut rng);
        let trials = 500;
        let mut bad = 0;
        for i in 0..trials {
            let v = (i as f64 + 0.5) / trials as f64;
            let got = adc.convert(v, &mut rng).code as i64;
            if (got - adc.ideal_code(v) as i64).abs() > 1 {
                bad += 1;
            }
        }
        assert!(bad < trials / 20, "bad={bad}");
    }
}
