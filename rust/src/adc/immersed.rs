//! Memory-immersed collaborative ADC (paper §IV-A/B, Figs 8–9, 11).
//!
//! The converter that gives the paper its Table I area/energy win: the
//! reference voltages come from a *neighbouring compute-in-SRAM array*
//! whose column lines form a capacitive DAC ([`crate::analog::CapDac`]).
//! No dedicated capacitor bank, no resistor ladder — only a comparator
//! and a tweak to the precharge array.
//!
//! Modes (programmable networking, Fig 9):
//! - **SAR** — one neighbour array; binary search, `bits` cycles.
//! - **Flash** — `2^bits − 1` neighbour arrays each generate one
//!   reference simultaneously; 1 cycle.
//! - **Hybrid** — `2^f − 1` neighbours resolve the `f` MSBs flash-style
//!   in one cycle, then nearest-neighbour SAR resolves the rest:
//!   `1 + (bits − f)` cycles (the paper's measured configuration:
//!   f = 2, 5 bits → 4 cycles).
//!
//! **Common-mode cancellation** (paper §IV-A): the MAV being digitized
//! and the references are produced by *identical* arrays, so gain-type
//! non-idealities (incomplete settling, supply droop) appear on both
//! sides of the comparator and cancel. [`ImmersedAdc::with_common_gain`]
//! models this: the same `gain` multiplies input and references, and the
//! output code is unchanged — property-tested, and the mechanism behind
//! the near-ideal measured staircase (Fig 12).

use crate::analog::{CapDac, Comparator, NoiseModel};
use crate::util::Rng;

use super::{Adc, Conversion};

/// Networking mode of the collaborative converter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImmersedMode {
    /// Nearest-neighbour successive approximation (Fig 8).
    Sar,
    /// Fully parallel flash across `2^bits − 1` neighbour arrays.
    Flash,
    /// Flash for `flash_bits` MSBs, SAR for the rest (Fig 9).
    Hybrid { flash_bits: u8 },
}

impl ImmersedMode {
    /// Neighbour arrays required by this mode at `bits` resolution.
    pub fn neighbours(&self, bits: u8) -> usize {
        match self {
            ImmersedMode::Sar => 1,
            ImmersedMode::Flash => (1usize << bits) - 1,
            ImmersedMode::Hybrid { flash_bits } => {
                assert!(*flash_bits < bits);
                (1usize << flash_bits) - 1
            }
        }
    }

    /// Conversion latency in cycles at `bits` resolution.
    pub fn cycles(&self, bits: u8) -> u32 {
        match self {
            ImmersedMode::Sar => bits as u32,
            ImmersedMode::Flash => 1,
            ImmersedMode::Hybrid { flash_bits } => 1 + (bits - flash_bits) as u32,
        }
    }
}

/// SRAM-immersed collaborative ADC.
#[derive(Debug, Clone)]
pub struct ImmersedAdc {
    bits: u8,
    vdd: f64,
    mode: ImmersedMode,
    /// One capacitive DAC per coupled neighbour array (column lines).
    neighbours: Vec<CapDac>,
    /// One comparator per neighbour (flash) / the shared SAR comparator.
    comparators: Vec<Comparator>,
    noise: NoiseModel,
    /// Gain-type non-ideality common to the MAV array and the reference
    /// arrays (settling, droop). 1.0 = ideal.
    common_gain: f64,
    /// Comparator decision energy (fJ).
    e_cmp_fj: f64,
}

impl ImmersedAdc {
    /// Fabricate: `units_per_array` column lines per neighbour (must be
    /// ≥ 2^bits; the paper's 16×32 arrays give 32 units for 5 bits),
    /// `c_col_ff` parasitic capacitance per column line.
    pub fn sample(
        bits: u8,
        vdd: f64,
        mode: ImmersedMode,
        units_per_array: usize,
        c_col_ff: f64,
        noise: &NoiseModel,
        rng: &mut Rng,
    ) -> Self {
        assert!((1..=10).contains(&bits));
        assert!(
            units_per_array >= (1usize << bits),
            "need ≥ 2^bits column lines ({} < {})",
            units_per_array,
            1usize << bits
        );
        let n = mode.neighbours(bits);
        ImmersedAdc {
            bits,
            vdd,
            mode,
            neighbours: (0..n)
                .map(|_| CapDac::sample(units_per_array, c_col_ff, noise, rng))
                .collect(),
            comparators: (0..n.max(1)).map(|_| Comparator::sample(noise, rng)).collect(),
            noise: *noise,
            common_gain: 1.0,
            e_cmp_fj: 5.0,
        }
    }

    /// Ideal instance with the paper's 16×32 geometry (32 column lines).
    pub fn ideal(bits: u8, vdd: f64, mode: ImmersedMode) -> Self {
        let mut rng = Rng::new(0);
        let units = (1usize << bits).max(32);
        ImmersedAdc::sample(bits, vdd, mode, units, 20.0, &NoiseModel::ideal(), &mut rng)
    }

    /// Apply a common gain non-ideality to input *and* references
    /// (models identical-array cancellation; see module docs).
    pub fn with_common_gain(mut self, gain: f64) -> Self {
        assert!(gain > 0.0 && gain <= 1.0);
        self.common_gain = gain;
        self
    }

    /// The conversion mode (SAR / Flash / hybrid).
    pub fn mode(&self) -> ImmersedMode {
        self.mode
    }

    /// Reference voltage for precharging `k` of `n` units on neighbour
    /// `idx` — including the common gain and the DAC's own noise.
    pub fn ref_level(&mut self, idx: usize, k_units: usize, rng: &mut Rng) -> f64 {
        let noise = self.noise;
        let g = self.common_gain;
        g * self.neighbours[idx].share_first_k(k_units, self.vdd, &noise, rng)
    }

    /// Units-per-code scale factor (n_units / 2^bits). External search
    /// strategies ([`super::asymmetric::AsymmetricSearch`] drives the
    /// converter's references directly) map output codes to precharge
    /// counts with this.
    pub fn units_per_code(&self) -> usize {
        self.neighbours[0].len() >> self.bits
    }

    /// Gain non-ideality shared by the MAV array and reference arrays
    /// (1.0 = ideal; see [`ImmersedAdc::with_common_gain`]).
    pub fn common_gain(&self) -> f64 {
        self.common_gain
    }

    /// Energy (fJ) of one reference charge-share on a neighbour array.
    pub fn share_energy_fj(&self) -> f64 {
        self.neighbours[0].share_energy_fj(self.vdd)
    }

    /// One comparator decision against neighbour `idx`'s reference at
    /// `k_units`, bookkeeping energy (`share/2 + e_cmp`) and the
    /// comparison count. Every decision — the built-in SAR/Flash/Hybrid
    /// loops and external search strategies alike
    /// ([`super::asymmetric::AsymmetricSearch`] walks its comparison
    /// tree through this) — goes through the converter's fabricated
    /// comparator, so offsets and decision noise apply uniformly.
    pub fn compare_at(
        &mut self,
        idx: usize,
        k_units: usize,
        v_in: f64,
        energy: &mut f64,
        comparisons: &mut u32,
        rng: &mut Rng,
    ) -> bool {
        let v_ref = self.ref_level(idx, k_units, rng);
        *energy += self.neighbours[idx].share_energy_fj(self.vdd) * 0.5 + self.e_cmp_fj;
        *comparisons += 1;
        self.comparators[idx].compare(v_in, v_ref, rng)
    }

    /// SAR conversion within code range [0, 2^bits) using neighbour 0.
    fn convert_sar_range(
        &mut self,
        v_in: f64,
        mut code: u32,
        first_bit: u8,
        energy: &mut f64,
        comparisons: &mut u32,
        rng: &mut Rng,
    ) -> u32 {
        let upc = self.units_per_code();
        for bit in (0..first_bit).rev() {
            let trial = code | (1 << bit);
            if self.compare_at(0, trial as usize * upc, v_in, energy, comparisons, rng) {
                code = trial;
            }
        }
        code
    }
}

impl Adc for ImmersedAdc {
    fn bits(&self) -> u8 {
        self.bits
    }

    fn vdd(&self) -> f64 {
        self.vdd
    }

    fn convert(&mut self, v_in: f64, rng: &mut Rng) -> Conversion {
        let v_in = v_in * self.common_gain; // MAV sees the same non-ideality
        let mut energy = 0.0;
        let mut comparisons = 0;
        let upc = self.units_per_code();
        let code = match self.mode {
            ImmersedMode::Sar => {
                self.convert_sar_range(v_in, 0, self.bits, &mut energy, &mut comparisons, rng)
            }
            ImmersedMode::Flash => {
                // All neighbours fire simultaneously: thermometer count.
                let mut count = 0u32;
                for i in 0..self.neighbours.len() {
                    if self.compare_at(i, (i + 1) * upc, v_in, &mut energy, &mut comparisons, rng) {
                        count += 1;
                    }
                }
                count
            }
            ImmersedMode::Hybrid { flash_bits } => {
                // Cycle 1: coarse flash over 2^f − 1 neighbours.
                let seg_codes = 1u32 << (self.bits - flash_bits);
                let mut seg = 0u32;
                for i in 0..self.neighbours.len() {
                    let k = (i as u32 + 1) * seg_codes;
                    let k_units = k as usize * upc;
                    if self.compare_at(i, k_units, v_in, &mut energy, &mut comparisons, rng) {
                        seg += 1;
                    }
                }
                // Remaining bits: SAR inside the selected segment.
                let base = seg * seg_codes;
                self.convert_sar_range(
                    v_in,
                    base,
                    self.bits - flash_bits,
                    &mut energy,
                    &mut comparisons,
                    rng,
                )
            }
        };
        Conversion { code, comparisons, cycles: self.mode.cycles(self.bits), energy_fj: energy }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn mode_neighbour_and_cycle_counts() {
        assert_eq!(ImmersedMode::Sar.neighbours(5), 1);
        assert_eq!(ImmersedMode::Flash.neighbours(5), 31);
        assert_eq!(ImmersedMode::Hybrid { flash_bits: 2 }.neighbours(5), 3);
        assert_eq!(ImmersedMode::Sar.cycles(5), 5);
        assert_eq!(ImmersedMode::Flash.cycles(5), 1);
        // The paper's measured configuration: 2 bits flash + 3 bits SAR.
        assert_eq!(ImmersedMode::Hybrid { flash_bits: 2 }.cycles(5), 4);
    }

    #[test]
    fn ideal_sar_mode_matches_ideal_code() {
        prop::check("immersed SAR == ideal_code", 200, |rng| {
            let mut adc = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar);
            let v = rng.uniform();
            let got = adc.convert(v, rng).code;
            crate::prop_assert!(got == adc.ideal_code(v), "v={v}");
            Ok(())
        });
    }

    #[test]
    fn ideal_flash_mode_matches_ideal_code() {
        prop::check("immersed flash == ideal_code", 100, |rng| {
            let mut adc = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Flash);
            let v = rng.uniform();
            let got = adc.convert(v, rng).code;
            crate::prop_assert!(got == adc.ideal_code(v), "v={v}");
            Ok(())
        });
    }

    #[test]
    fn ideal_hybrid_mode_matches_ideal_code() {
        prop::check("immersed hybrid == ideal_code", 200, |rng| {
            let mut adc = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Hybrid { flash_bits: 2 });
            let v = rng.uniform();
            let got = adc.convert(v, rng).code;
            crate::prop_assert!(got == adc.ideal_code(v), "v={v}");
            Ok(())
        });
    }

    /// The paper's common-mode claim: gain non-idealities shared by the
    /// MAV array and reference arrays do not move output codes.
    #[test]
    fn common_gain_cancels_exactly() {
        prop::check("common-mode gain cancellation", 200, |rng| {
            let gain = 0.6 + 0.4 * rng.uniform();
            let v = rng.uniform();
            let mut plain = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar);
            let mut gained =
                ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar).with_common_gain(gain);
            let c0 = plain.convert(v, rng).code;
            let c1 = gained.convert(v, rng).code;
            crate::prop_assert!(c0 == c1, "gain={gain} v={v}: {c0} != {c1}");
            Ok(())
        });
    }

    /// A conventional converter with *ideal* references has no such
    /// cancellation: a gained MAV mis-codes.
    #[test]
    fn conventional_sar_does_not_cancel_gain() {
        let mut sar = super::super::sar::SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(5);
        let v = 0.7;
        let gained = sar.convert(v * 0.8, &mut rng).code;
        let plain = sar.convert(v, &mut rng).code;
        assert_ne!(gained, plain);
    }

    #[test]
    fn hybrid_uses_fewer_cycles_than_sar_more_than_flash() {
        let mut rng = Rng::new(6);
        let mut sar = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar);
        let mut fl = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Flash);
        let mut hy = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Hybrid { flash_bits: 2 });
        let cs = sar.convert(0.4, &mut rng).cycles;
        let cf = fl.convert(0.4, &mut rng).cycles;
        let ch = hy.convert(0.4, &mut rng).cycles;
        assert!(cf < ch && ch < cs, "flash {cf} < hybrid {ch} < sar {cs}");
    }

    #[test]
    fn noisy_conversion_stays_near_ideal() {
        let noise = NoiseModel::default();
        let mut rng = Rng::new(7);
        let hybrid = ImmersedMode::Hybrid { flash_bits: 2 };
        let mut adc = ImmersedAdc::sample(5, 1.0, hybrid, 32, 20.0, &noise, &mut rng);
        let trials = 400;
        let mut bad = 0;
        for i in 0..trials {
            let v = (i as f64 + 0.5) / trials as f64;
            let got = adc.convert(v, &mut rng).code as i64;
            if (got - adc.ideal_code(v) as i64).abs() > 1 {
                bad += 1;
            }
        }
        assert!(bad < trials / 10, "bad={bad}/{trials}");
    }

    #[test]
    #[should_panic(expected = "column lines")]
    fn rejects_too_few_units() {
        let mut rng = Rng::new(8);
        ImmersedAdc::sample(6, 1.0, ImmersedMode::Sar, 32, 20.0, &NoiseModel::ideal(), &mut rng);
    }
}
