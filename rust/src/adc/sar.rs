//! Conventional SAR ADC baseline (Table I row 1, anchored to [34]).
//!
//! Binary search over a dedicated binary-weighted capacitive DAC: `bits`
//! comparator decisions, one per cycle. This is the *baseline* the
//! paper's memory-immersed converter is compared against — functionally
//! similar, but it pays for a dedicated capacitor bank and comparator per
//! array (area/energy numbers in [`crate::energy`]).

use crate::analog::{Comparator, NoiseModel};
use crate::util::Rng;

use super::{Adc, Conversion};

/// Conventional SAR ADC with a dedicated binary-weighted cap DAC.
#[derive(Debug, Clone)]
pub struct SarAdc {
    bits: u8,
    vdd: f64,
    comparator: Comparator,
    /// Per-binary-weight fractional error of the dedicated DAC
    /// (weight `2^i` has relative error `mismatch[i]`).
    weight_err: Vec<f64>,
    /// Unit capacitance of the DAC (fF) — sets conversion energy.
    c_unit_ff: f64,
    /// Comparator decision energy (fJ).
    e_cmp_fj: f64,
}

impl SarAdc {
    /// Fabricate a SAR ADC; comparator offset and DAC mismatch sampled
    /// from `noise`.
    pub fn sample(bits: u8, vdd: f64, noise: &NoiseModel, rng: &mut Rng) -> Self {
        assert!((1..=12).contains(&bits));
        // Binary-weighted caps: relative sigma shrinks as 1/sqrt(weight).
        let weight_err = (0..bits)
            .map(|i| {
                let w = (1u64 << i) as f64;
                rng.normal() * noise.cap_mismatch_sigma / w.sqrt()
            })
            .collect();
        SarAdc {
            bits,
            vdd,
            comparator: Comparator::sample(noise, rng),
            weight_err,
            c_unit_ff: 2.0,
            e_cmp_fj: 5.0,
        }
    }

    /// Ideal instance (tests/oracles).
    pub fn ideal(bits: u8, vdd: f64) -> Self {
        SarAdc {
            bits,
            vdd,
            comparator: Comparator::ideal(),
            weight_err: vec![0.0; bits as usize],
            c_unit_ff: 2.0,
            e_cmp_fj: 5.0,
        }
    }

    /// DAC output voltage for a digital `code`, including weight errors.
    fn dac_v(&self, code: u32) -> f64 {
        let n = (1u64 << self.bits) as f64;
        let mut acc = 0.0;
        for i in 0..self.bits {
            if (code >> i) & 1 == 1 {
                let w = (1u64 << i) as f64;
                acc += w * (1.0 + self.weight_err[i as usize]);
            }
        }
        self.vdd * acc / n
    }

    /// Total DAC capacitance (fF): `2^bits` units.
    pub fn c_total_ff(&self) -> f64 {
        (1u64 << self.bits) as f64 * self.c_unit_ff
    }
}

impl Adc for SarAdc {
    fn bits(&self) -> u8 {
        self.bits
    }

    fn vdd(&self) -> f64 {
        self.vdd
    }

    /// Classic SAR loop: trial-set each bit MSB→LSB, keep it if the DAC
    /// midpoint (code + ½LSB) is still below the input.
    fn convert(&mut self, v_in: f64, rng: &mut Rng) -> Conversion {
        let mut code = 0u32;
        let mut energy = 0.0;
        for bit in (0..self.bits).rev() {
            let trial = code | (1 << bit);
            // Binary search on "v_in > trial level" — converges to the
            // floor quantizer: dac(code) ≤ v_in < dac(code+1).
            let v_ref = self.dac_v(trial);
            let keep = self.comparator.compare(v_in, v_ref, rng);
            // Each trial switches roughly the trial weight of capacitance.
            let c_sw = (1u64 << bit) as f64 * self.c_unit_ff;
            energy += 0.5 * c_sw * self.vdd * self.vdd + self.e_cmp_fj;
            if keep {
                code = trial;
            }
        }
        Conversion {
            code,
            comparisons: self.bits as u32,
            cycles: self.bits as u32,
            energy_fj: energy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn ideal_sar_matches_ideal_code() {
        prop::check("ideal SAR == ideal_code", 256, |rng| {
            let bits = 3 + rng.index(6) as u8;
            let mut adc = SarAdc::ideal(bits, 1.0);
            let v = rng.uniform();
            let got = adc.convert(v, rng).code;
            let expect = adc.ideal_code(v);
            crate::prop_assert!(got == expect, "bits={bits} v={v}: {got} != {expect}");
            Ok(())
        });
    }

    #[test]
    fn conversion_uses_bits_comparisons_and_cycles() {
        let mut adc = SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(1);
        let c = adc.convert(0.37, &mut rng);
        assert_eq!(c.comparisons, 5);
        assert_eq!(c.cycles, 5);
        assert!(c.energy_fj > 0.0);
    }

    #[test]
    fn noisy_sar_stays_within_one_code_mostly() {
        let noise = NoiseModel::default();
        let mut rng = Rng::new(2);
        let mut adc = SarAdc::sample(5, 1.0, &noise, &mut rng);
        let mut bad = 0;
        let trials = 500;
        for i in 0..trials {
            let v = (i as f64 + 0.5) / trials as f64;
            let got = adc.convert(v, &mut rng).code as i64;
            let expect = adc.ideal_code(v) as i64;
            if (got - expect).abs() > 1 {
                bad += 1;
            }
        }
        assert!(bad < trials / 20, "too many multi-code errors: {bad}/{trials}");
    }

    #[test]
    fn monotone_codes_on_ramp() {
        let mut adc = SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(3);
        let mut prev = 0;
        for i in 0..200 {
            let v = i as f64 / 200.0;
            let c = adc.convert(v, &mut rng).code;
            assert!(c >= prev, "non-monotone at v={v}");
            prev = c;
        }
        assert_eq!(prev, 31);
    }

    #[test]
    fn full_scale_and_zero() {
        let mut adc = SarAdc::ideal(5, 1.0);
        let mut rng = Rng::new(4);
        assert_eq!(adc.convert(0.0, &mut rng).code, 0);
        assert_eq!(adc.convert(0.9999, &mut rng).code, 31);
    }
}
