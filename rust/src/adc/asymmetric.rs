//! MAV-statistics-aware asymmetric search (paper §IV-C, Fig 10).
//!
//! Bitplane-wise CiM processing produces multiply-average voltages that
//! are **not** uniformly distributed: the positive-line charge count is
//! binomial, concentrated well below mid-scale (Fig 10(a)). A symmetric
//! binary search ignores this and always spends `bits` comparisons; an
//! *optimal comparison tree* built for the actual code distribution
//! reaches the likely codes in fewer steps — the paper reports ~3.7
//! average comparisons instead of 5 for 5-bit conversion (Fig 10(c)).
//!
//! The tree is the classic optimal alphabetic search tree (dynamic
//! programming over contiguous code ranges); each internal node is one
//! comparator decision against a memory-immersed reference level, so the
//! tree drops straight onto [`super::ImmersedAdc`] hardware: only the
//! precharge *sequence* changes.

use crate::util::Rng;

use super::immersed::{ImmersedAdc, ImmersedMode};
use super::{Adc, Conversion};

/// Probability mass over output codes for a binomially distributed MAV.
///
/// A crossbar row has `cols` cells; with input-bit density `density` and
/// ±1 cells balanced on average, a cell dumps charge on the positive sum
/// line with probability `density / 2`. The MAV is `plus / cols`, and
/// the code is `floor(MAV · 2^bits)`.
pub fn binomial_mav_pmf(cols: usize, density: f64, bits: u8) -> Vec<f64> {
    let p = (density * 0.5).clamp(0.0, 1.0);
    let n_codes = 1usize << bits;
    let mut pmf = vec![0.0f64; n_codes];
    // Binomial(cols, p) evaluated iteratively to avoid factorial overflow.
    let mut prob = (1.0 - p).powi(cols as i32); // P[plus = 0]
    for k in 0..=cols {
        let mav = k as f64 / cols as f64;
        let code = ((mav * n_codes as f64) as usize).min(n_codes - 1);
        pmf[code] += prob;
        // Advance to P[plus = k+1].
        if k < cols {
            prob *= (cols - k) as f64 / (k + 1) as f64 * p / (1.0 - p);
        }
    }
    pmf
}

/// One node of the comparison tree.
#[derive(Debug, Clone, Copy)]
enum Node {
    /// Compare `v_in > level(split+1)`; false → `lo`, true → `hi`.
    Cmp { split: u32, lo: u32, hi: u32 },
    /// Resolved output code.
    Leaf { code: u32 },
}

/// Optimal asymmetric successive-approximation search.
#[derive(Debug, Clone)]
pub struct AsymmetricSearch {
    bits: u8,
    nodes: Vec<Node>,
    root: u32,
    expected: f64,
}

impl AsymmetricSearch {
    /// Build the optimal comparison tree for `pmf` (len must be 2^bits).
    ///
    /// DP over code ranges: `e[i][j] = P(i..=j) + min_k e[i][k] + e[k+1][j]`,
    /// `e[i][i] = 0` — the expected number of comparisons to isolate a
    /// code drawn from `pmf`.
    pub fn build(bits: u8, pmf: &[f64]) -> Self {
        let n = 1usize << bits;
        assert_eq!(pmf.len(), n, "pmf length must be 2^bits");
        let total: f64 = pmf.iter().sum();
        assert!(total > 0.0, "pmf must have mass");
        let p: Vec<f64> = pmf.iter().map(|x| x / total).collect();

        // Prefix sums for O(1) range mass.
        let mut pre = vec![0.0f64; n + 1];
        for i in 0..n {
            pre[i + 1] = pre[i] + p[i];
        }
        let mass = |i: usize, j: usize| pre[j + 1] - pre[i];

        // e[i][j] stored flat; split[i][j] the optimal split point.
        let mut e = vec![0.0f64; n * n];
        let mut sp = vec![0usize; n * n];
        let idx = |i: usize, j: usize| i * n + j;
        for len in 2..=n {
            for i in 0..=(n - len) {
                let j = i + len - 1;
                let mut best = f64::INFINITY;
                let mut best_k = i;
                for k in i..j {
                    let cost = e[idx(i, k)] + e[idx(k + 1, j)];
                    if cost < best {
                        best = cost;
                        best_k = k;
                    }
                }
                e[idx(i, j)] = mass(i, j) + best;
                sp[idx(i, j)] = best_k;
            }
        }

        // Materialise the tree.
        let mut nodes = Vec::with_capacity(2 * n);
        fn build_range(
            i: usize,
            j: usize,
            n: usize,
            sp: &[usize],
            nodes: &mut Vec<Node>,
        ) -> u32 {
            if i == j {
                nodes.push(Node::Leaf { code: i as u32 });
                return (nodes.len() - 1) as u32;
            }
            let k = sp[i * n + j];
            let lo = build_range(i, k, n, sp, nodes);
            let hi = build_range(k + 1, j, n, sp, nodes);
            nodes.push(Node::Cmp { split: k as u32, lo, hi });
            (nodes.len() - 1) as u32
        }
        let root = build_range(0, n - 1, n, &sp, &mut nodes);
        AsymmetricSearch { bits, nodes, root, expected: e[idx(0, n - 1)] }
    }

    /// Build for the uniform distribution — recovers the symmetric
    /// binary search (expected comparisons == bits).
    pub fn symmetric(bits: u8) -> Self {
        AsymmetricSearch::build(bits, &vec![1.0; 1usize << bits])
    }

    /// Expected comparisons under the build distribution.
    pub fn expected_comparisons(&self) -> f64 {
        self.expected
    }

    /// Resolution of the search tree.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Depth (comparisons) to resolve a specific `code`.
    pub fn depth_of(&self, code: u32) -> u32 {
        fn walk(nodes: &[Node], at: u32, code: u32, d: u32) -> Option<u32> {
            match nodes[at as usize] {
                Node::Leaf { code: c } => (c == code).then_some(d),
                Node::Cmp { lo, hi, .. } => {
                    walk(nodes, lo, code, d + 1).or_else(|| walk(nodes, hi, code, d + 1))
                }
            }
        }
        walk(&self.nodes, self.root, code, 0).expect("code in range")
    }

    /// Run the asymmetric conversion on a memory-immersed converter:
    /// each internal node is one reference generation + comparison on
    /// neighbour 0 (SAR-style coupling, different precharge sequence).
    /// Decisions go through [`ImmersedAdc::compare_at`], so the tree
    /// sees the converter's fabricated comparator (offset/noise) and
    /// pays its per-decision energy, exactly like the built-in modes.
    pub fn convert(&self, adc: &mut ImmersedAdc, v_in: f64, rng: &mut Rng) -> Conversion {
        let upc = adc.units_per_code();
        let v_in_eff = v_in * adc.common_gain();
        let mut at = self.root;
        let mut comparisons = 0u32;
        let mut energy = 0.0f64;
        loop {
            match self.nodes[at as usize] {
                Node::Leaf { code } => {
                    return Conversion { code, comparisons, cycles: comparisons, energy_fj: energy }
                }
                Node::Cmp { split, lo, hi } => {
                    let k_units = (split as usize + 1) * upc;
                    let up =
                        adc.compare_at(0, k_units, v_in_eff, &mut energy, &mut comparisons, rng);
                    at = if up { hi } else { lo };
                }
            }
        }
    }
}

/// An [`ImmersedAdc`] driven by an [`AsymmetricSearch`] comparison tree,
/// packaged behind the common [`Adc`] trait so MAV-statistics-aware
/// conversion is interchangeable with the symmetric converters at pool
/// construction time ([`crate::cim::pool`]).
#[derive(Debug, Clone)]
pub struct AsymmetricAdc {
    adc: ImmersedAdc,
    tree: AsymmetricSearch,
}

impl AsymmetricAdc {
    /// Pair a SAR-coupled immersed converter with a comparison tree of
    /// matching resolution.
    pub fn new(adc: ImmersedAdc, tree: AsymmetricSearch) -> Self {
        assert_eq!(adc.bits(), tree.bits(), "tree/converter resolution mismatch");
        assert!(
            matches!(adc.mode(), ImmersedMode::Sar),
            "asymmetric search drives SAR-coupled (nearest-neighbour) references"
        );
        AsymmetricAdc { adc, tree }
    }

    /// Build for the binomial bitplane-MAV distribution of a `cols`-wide
    /// crossbar at input-bit density `density` (the paper's Fig 10 tree).
    pub fn for_mav(adc: ImmersedAdc, cols: usize, density: f64) -> Self {
        let pmf = binomial_mav_pmf(cols, density, adc.bits());
        let tree = AsymmetricSearch::build(adc.bits(), &pmf);
        AsymmetricAdc::new(adc, tree)
    }

    /// The MAV-statistics-shaped search tree.
    pub fn tree(&self) -> &AsymmetricSearch {
        &self.tree
    }

    /// The wrapped immersed ADC.
    pub fn inner(&self) -> &ImmersedAdc {
        &self.adc
    }
}

impl Adc for AsymmetricAdc {
    fn bits(&self) -> u8 {
        self.adc.bits()
    }

    fn vdd(&self) -> f64 {
        self.adc.vdd()
    }

    fn convert(&mut self, v_in: f64, rng: &mut Rng) -> Conversion {
        self.tree.convert(&mut self.adc, v_in, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pmf_sums_to_one_and_is_skewed() {
        let pmf = binomial_mav_pmf(32, 0.5, 5);
        let total: f64 = pmf.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Mean code ≈ 0.25 · 32 = 8, well below mid-scale 16.
        let mean: f64 = pmf.iter().enumerate().map(|(c, p)| c as f64 * p).sum();
        assert!((mean - 8.0).abs() < 1.0, "mean={mean}");
        // Mass near mid-scale is tiny.
        assert!(pmf[16] < 0.01);
    }

    #[test]
    fn symmetric_tree_costs_bits_comparisons() {
        for bits in 1..=6u8 {
            let t = AsymmetricSearch::symmetric(bits);
            assert!(
                (t.expected_comparisons() - bits as f64).abs() < 1e-9,
                "bits={bits}: {}",
                t.expected_comparisons()
            );
        }
    }

    /// The Fig 10(c) claim: ~3.7 avg comparisons for 5-bit skewed MAV
    /// vs 5 for symmetric binary search.
    #[test]
    fn asymmetric_beats_symmetric_on_skewed_mav() {
        let pmf = binomial_mav_pmf(32, 0.5, 5);
        let t = AsymmetricSearch::build(5, &pmf);
        let e = t.expected_comparisons();
        assert!(e < 4.2, "expected comparisons {e} not < 4.2");
        assert!(e > 2.5, "suspiciously low: {e}");
    }

    #[test]
    fn expected_matches_depth_weighted_pmf() {
        let pmf = binomial_mav_pmf(16, 0.5, 4);
        let t = AsymmetricSearch::build(4, &pmf);
        let total: f64 = pmf.iter().sum();
        let by_depth: f64 = pmf
            .iter()
            .enumerate()
            .map(|(c, p)| (p / total) * t.depth_of(c as u32) as f64)
            .sum();
        assert!((by_depth - t.expected_comparisons()).abs() < 1e-9);
    }

    #[test]
    fn entropy_lower_bound_holds() {
        let pmf = binomial_mav_pmf(32, 0.5, 5);
        let t = AsymmetricSearch::build(5, &pmf);
        let h = crate::util::stats::entropy_bits(&pmf);
        assert!(t.expected_comparisons() >= h - 1e-9, "E[cmp] below entropy bound");
    }

    /// Codes from the asymmetric conversion equal the symmetric/ideal
    /// codes — only the comparison *count* differs (paper's claim).
    #[test]
    fn asymmetric_codes_match_ideal() {
        prop::check("asymmetric codes == ideal", 200, |rng| {
            let pmf = binomial_mav_pmf(32, 0.5, 5);
            let tree = AsymmetricSearch::build(5, &pmf);
            let mut adc = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar);
            let v = rng.uniform();
            let c = tree.convert(&mut adc, v, rng);
            crate::prop_assert!(c.code == adc.ideal_code(v), "v={v}");
            Ok(())
        });
    }

    #[test]
    fn average_comparisons_measured_on_hardware_path() {
        // Draw MAVs from the binomial, digitize with the tree, and check
        // the *measured* average comparisons is near the predicted one.
        let cols = 32;
        let pmf = binomial_mav_pmf(cols, 0.5, 5);
        let tree = AsymmetricSearch::build(5, &pmf);
        let mut adc = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar);
        let mut rng = Rng::new(11);
        let mut total = 0u64;
        let trials = 3000;
        for _ in 0..trials {
            let plus = (0..cols).filter(|_| rng.bernoulli(0.25)).count();
            let v = plus as f64 / cols as f64 + 1e-6;
            total += tree.convert(&mut adc, v, &mut rng).comparisons as u64;
        }
        let avg = total as f64 / trials as f64;
        let predicted = tree.expected_comparisons();
        assert!((avg - predicted).abs() < 0.3, "avg={avg} predicted={predicted}");
        assert!(avg < 5.0, "must beat symmetric 5 comparisons, got {avg}");
    }

    #[test]
    #[should_panic(expected = "pmf length")]
    fn rejects_wrong_pmf_len() {
        AsymmetricSearch::build(4, &[0.5, 0.5]);
    }
}
