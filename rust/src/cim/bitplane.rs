//! Bitplane-wise multi-bit processing (paper §III-B, Fig 4).
//!
//! A multi-bit digital input vector is processed one significance plane
//! at a time: all bits of significance `p` form a {0,1} plane, the
//! crossbar computes the plane's ±1-weighted sums in analog, and the row
//! comparators quantize each sum to a **single bit** (ADC-free). The
//! per-plane sign bits are reassembled with their plane weights into the
//! approximate multi-bit output the network is trained against:
//!
//! `ŷ_r = Σ_p 2^p · s_{r,p}`, `s ∈ {−1,+1}`  (vs exact `y_r = Σ_p 2^p · d_{r,p}`).
//!
//! Signed inputs use a positive/negative split (`x = x⁺ − x⁻`), each half
//! processed unsigned — two crossbar passes, still DAC-free.

use crate::util::Rng;

use super::bitvec::BitVec;
use super::crossbar::Crossbar;
use super::early_term::{EarlyTermination, TermStats};
use super::pool::{CimArrayPool, ConversionStats, PlaneRequest};

/// Decompose non-negative integers into packed bitplanes, LSB first,
/// reusing the buffers in `planes` (the scratch-arena form — zero
/// allocations once the arena is warm).
/// Every value must fit in `bits` (values are asserted, not clipped —
/// quantization happens upstream in the NN layers).
pub fn decompose_bitplanes_into(x: &[u32], bits: u8, planes: &mut Vec<BitVec>) {
    for &v in x {
        assert!(v < (1u32 << bits), "value {v} does not fit in {bits} bits");
    }
    planes.resize_with(bits as usize, || BitVec::zeros(0));
    for (p, plane) in planes.iter_mut().enumerate() {
        plane.reset(x.len());
        for (i, &v) in x.iter().enumerate() {
            if (v >> p) & 1 == 1 {
                plane.set(i, true);
            }
        }
    }
}

/// Allocating wrapper over [`decompose_bitplanes_into`].
pub fn decompose_bitplanes(x: &[u32], bits: u8) -> Vec<BitVec> {
    let mut planes = Vec::new();
    decompose_bitplanes_into(x, bits, &mut planes);
    planes
}

/// Reusable working set for bitplane transforms: plane decompositions,
/// the packed per-plane sign buffer and the early-termination `active`
/// mask. One arena amortizes every per-transform allocation the engine
/// used to make (five `Vec`s per call — EXPERIMENTS.md §Perf); engines
/// own one internally and batch APIs reuse it across samples.
#[derive(Debug, Clone, Default)]
pub struct PlaneScratch {
    planes: Vec<BitVec>,
    active: Vec<bool>,
    signs: BitVec,
    /// Decoded per-row signed sums for the pooled multi-bit path,
    /// plane-major (`input_bits × rows` once warm).
    mav_values: Vec<f64>,
}

/// Row-value source for one plane walk — the only thing that differs
/// between the ADC-free 1-bit path and the pooled multi-bit path. The
/// shared scaffolding (active mask, [`TermStats`], ET bound tests and
/// zeroing) lives once in [`walk_planes`].
trait RowValueSource {
    /// Divisor normalising the running partial into per-plane units
    /// before the early-termination bound test: 1.0 for ±1 sign planes
    /// (division by 1.0 is exact, so the 1-bit path's arithmetic is
    /// bit-for-bit untouched), `cols` for decoded signed sums (a
    /// normalized plane value lies in `[−1, 1]`, exactly the 1-bit
    /// path's per-plane `±1`, so one `EarlyTermination` policy behaves
    /// comparably on both paths).
    fn et_divisor(&self) -> f32;
    /// Process plane `p` so [`RowValueSource::row_value`] can read its
    /// per-row values. `active` is the live early-termination mask —
    /// the pooled source forwards it as the conversion-gating mask.
    fn load_plane(&mut self, p: usize, plane: &BitVec, active: &[bool], rng: &mut Rng);
    /// Per-row value of the last loaded plane (±1 or a decoded sum).
    fn row_value(&self, r: usize) -> f32;
}

/// One plane's row pass — the arithmetic core of the walk, shared
/// verbatim by the sequential [`walk_planes`] loop and the fused
/// cross-sample lockstep driver ([`BitplaneEngine::transform_batch`]
/// with `PoolSpec::fuse_batch`), so the two paths cannot drift:
/// accumulate weighted row values, record signs, and apply the
/// early-termination bound test + dead-band zeroing against the live
/// mask.
fn step_plane_rows(
    row_value: impl Fn(usize) -> f32,
    p: usize,
    rows: usize,
    divisor: f32,
    early_term: Option<EarlyTermination>,
    active: &mut [bool],
    acc: &mut [f32],
    plane_signs_p: &mut [bool],
    term: &mut TermStats,
) {
    let weight = (1u32 << p) as f32;
    for r in 0..rows {
        if !active[r] {
            term.record_skipped_row(r);
            continue;
        }
        let v = row_value(r);
        acc[r] += weight * v;
        plane_signs_p[r] = v > 0.0;
        term.record_processed(r);
        if let Some(et) = &early_term {
            // Remaining planes 0..p contribute at most 2^p − 1 (in
            // the source's normalized per-plane units).
            let remaining = (1u32 << p) as f32 - 1.0;
            if et.should_terminate(acc[r] / divisor, remaining) {
                active[r] = false;
                acc[r] = 0.0; // provably inside the dead band ⇒ zero
                term.record_terminated(r, p);
            }
        }
    }
}

/// The single plane-walk loop shared by the 1-bit and pooled paths:
/// MSB → LSB so the early-termination bound (remaining planes can add
/// at most `2^p − 1`) tightens fastest, skipping fully-terminated
/// planes, accumulating weighted row values and applying the ET
/// dead-band zeroing.
fn walk_planes<S: RowValueSource>(
    src: &mut S,
    planes: &[BitVec],
    nbits: usize,
    rows: usize,
    early_term: Option<EarlyTermination>,
    rng: &mut Rng,
    active: &mut Vec<bool>,
) -> (Vec<f32>, Vec<Vec<bool>>, TermStats) {
    let mut acc = vec![0.0f32; rows];
    let mut plane_signs = vec![vec![false; rows]; nbits];
    active.clear();
    active.resize(rows, true);
    let mut term = TermStats::new(rows, nbits);
    let divisor = src.et_divisor();

    for p in (0..nbits).rev() {
        if active.iter().all(|a| !a) {
            term.record_skipped_plane(p, active);
            continue;
        }
        src.load_plane(p, &planes[p], active, rng);
        step_plane_rows(
            |r| src.row_value(r),
            p,
            rows,
            divisor,
            early_term,
            active,
            &mut acc,
            &mut plane_signs[p],
            &mut term,
        );
    }
    (acc, plane_signs, term)
}

/// 1-bit source: one crossbar op per plane, packed sign outputs.
struct SignSource<'a> {
    crossbar: &'a mut Crossbar,
    signs: &'a mut BitVec,
}

impl RowValueSource for SignSource<'_> {
    fn et_divisor(&self) -> f32 {
        1.0
    }

    fn load_plane(&mut self, _p: usize, plane: &BitVec, _active: &[bool], rng: &mut Rng) {
        self.crossbar.process_bitplane_into(plane, rng, self.signs);
    }

    fn row_value(&self, r: usize) -> f32 {
        if self.signs.get(r) {
            1.0
        } else {
            -1.0
        }
    }
}

/// Pooled source: planes run through the scheduled [`CimArrayPool`].
/// Without early termination every plane was already fanned through
/// [`CimArrayPool::process_planes`] in one batched call (`buf` is
/// prefilled); with it, each plane is dispatched on demand under the
/// live mask so pruned rows gate their conversions.
struct PooledSource<'a> {
    pool: &'a mut CimArrayPool,
    /// Plane-major decoded values, `nbits × rows`.
    buf: &'a mut [f64],
    rows: usize,
    nbits: usize,
    plane_seed: u64,
    /// True when `buf` is already filled (no-ET batched fan-out).
    prefilled: bool,
    divisor: f32,
    /// Offset of the current plane's values in `buf`.
    cur: usize,
}

impl RowValueSource for PooledSource<'_> {
    fn et_divisor(&self) -> f32 {
        self.divisor
    }

    fn load_plane(&mut self, p: usize, plane: &BitVec, active: &[bool], _rng: &mut Rng) {
        self.cur = (self.nbits - 1 - p) * self.rows;
        if !self.prefilled {
            let chunk = &mut self.buf[self.cur..self.cur + self.rows];
            self.pool.process_plane_masked(plane, p as u64, self.plane_seed, Some(active), chunk);
        }
    }

    fn row_value(&self, r: usize) -> f32 {
        self.buf[self.cur + r] as f32
    }
}

/// Result of one bitplane-wise transform.
#[derive(Debug, Clone)]
pub struct BitplaneOutput {
    /// Reconstructed outputs, one per crossbar row: 1-bit-quantized sign
    /// reassembly on the default path, decoded multi-bit signed sums on
    /// the pooled path.
    pub values: Vec<f32>,
    /// Per-plane sign bits (LSB first), one Vec<bool> per plane; rows
    /// skipped by early termination repeat their last decided bit.
    pub plane_signs: Vec<Vec<bool>>,
    /// Early-termination statistics for this transform.
    pub term: TermStats,
    /// Collaborative-digitization accounting for this transform (all
    /// zeros on the ADC-free default path).
    pub conv: ConversionStats,
}

/// Bitplane-wise engine wrapping one crossbar, optionally emitting
/// through a collaborative digitization pool.
#[derive(Debug, Clone)]
pub struct BitplaneEngine {
    crossbar: Crossbar,
    /// Input quantization width in bits.
    pub input_bits: u8,
    /// Optional early-termination policy (paper §III-C).
    pub early_term: Option<EarlyTermination>,
    /// Internal scratch arena reused by every transform call.
    scratch: PlaneScratch,
    /// Per-input scratch arenas for the fused cross-sample pooled path
    /// (every input's plane decomposition, mask and MAV buffer must be
    /// alive at once), reused across fused calls.
    fused_scratch: Vec<PlaneScratch>,
    /// When set, planes run through the pool's scheduled arrays and the
    /// per-row outputs are multi-bit digitized MAVs instead of the
    /// ADC-free 1-bit signs (paper §IV). `None` (the default) keeps the
    /// pre-pool path bit-exact.
    pool: Option<CimArrayPool>,
}

impl BitplaneEngine {
    /// Engine slicing inputs into `input_bits` bitplanes over `crossbar`.
    pub fn new(crossbar: Crossbar, input_bits: u8) -> Self {
        assert!((1..=16).contains(&input_bits));
        BitplaneEngine {
            crossbar,
            input_bits,
            early_term: None,
            scratch: PlaneScratch::default(),
            fused_scratch: Vec::new(),
            pool: None,
        }
    }

    /// Enable MSB-first early termination.
    pub fn with_early_term(mut self, et: EarlyTermination) -> Self {
        self.early_term = Some(et);
        self
    }

    /// Route transforms through a collaborative digitization pool. The
    /// pool's arrays must share the engine crossbar's geometry (they are
    /// normally fabricated from the same programmed matrix).
    pub fn with_pool(mut self, pool: CimArrayPool) -> Self {
        self.set_pool(Some(pool));
        self
    }

    /// Attach (or detach) a collaborative digitization pool.
    pub fn set_pool(&mut self, pool: Option<CimArrayPool>) {
        if let Some(p) = &pool {
            assert_eq!(p.rows(), self.crossbar.rows(), "pool/crossbar row mismatch");
            assert_eq!(p.cols(), self.crossbar.cols(), "pool/crossbar col mismatch");
        }
        self.pool = pool;
    }

    /// The attached pool, if any.
    pub fn pool(&self) -> Option<&CimArrayPool> {
        self.pool.as_ref()
    }

    /// Mutable access to the attached pool.
    pub fn pool_mut(&mut self) -> Option<&mut CimArrayPool> {
        self.pool.as_mut()
    }

    /// True when a pool is attached.
    pub fn has_pool(&self) -> bool {
        self.pool.is_some()
    }

    /// The underlying crossbar.
    pub fn crossbar(&self) -> &Crossbar {
        &self.crossbar
    }

    /// Mutable access to the underlying crossbar.
    pub fn crossbar_mut(&mut self) -> &mut Crossbar {
        &mut self.crossbar
    }

    /// Transform an unsigned quantized vector (values < 2^input_bits),
    /// reusing the engine's internal scratch arena.
    ///
    /// Planes are processed **MSB → LSB** so the early-termination bound
    /// (remaining planes can add at most `2^p − 1`) tightens fastest.
    pub fn transform(&mut self, x: &[u32], rng: &mut Rng) -> BitplaneOutput {
        let mut scratch = std::mem::take(&mut self.scratch);
        let out = self.transform_with_scratch(x, rng, &mut scratch);
        self.scratch = scratch;
        out
    }

    /// [`BitplaneEngine::transform`] against an explicit caller-owned
    /// scratch arena (for callers that pool arenas across engines).
    /// Identical RNG consumption and bit-identical output to `transform`.
    pub fn transform_with_scratch(
        &mut self,
        x: &[u32],
        rng: &mut Rng,
        s: &mut PlaneScratch,
    ) -> BitplaneOutput {
        assert_eq!(x.len(), self.crossbar.cols(), "input length != crossbar cols");
        if self.pool.is_some() {
            return self.transform_pooled(x, rng, s);
        }
        decompose_bitplanes_into(x, self.input_bits, &mut s.planes);
        let rows = self.crossbar.rows();
        let nbits = self.input_bits as usize;
        let early_term = self.early_term;
        let (values, plane_signs, term) = {
            let mut src = SignSource { crossbar: &mut self.crossbar, signs: &mut s.signs };
            walk_planes(&mut src, &s.planes, nbits, rows, early_term, rng, &mut s.active)
        };
        BitplaneOutput { values, plane_signs, term, conv: ConversionStats::default() }
    }

    /// The pooled (collaborative digitization) plane walk: steps 1–3 on
    /// a scheduled compute-role array, multi-bit conversion through the
    /// group's memory-immersed converter, and reassembly of the decoded
    /// signed sums `2·plus − |x|` with their plane weights — so `values`
    /// approximates the *exact* integer transform instead of the 1-bit
    /// sign reconstruction (and is exactly equal to it in the aligned
    /// ideal case; see `tests/pool_serving.rs`).
    ///
    /// Each plane draws its analog noise from the deterministic stream
    /// `Rng::for_stream(plane_seed, p)` (one `plane_seed` draw ties the
    /// transform to the caller's generator), so planes are independent
    /// dispatch units:
    ///
    /// - **No early termination**: all planes fan through one
    ///   [`CimArrayPool::process_planes`] call — independent coupling
    ///   groups of each interleave phase run on scoped worker threads
    ///   (`PoolSpec::threads`), results identical at any thread count.
    /// - **Early termination**: planes dispatch one at a time under the
    ///   live active mask, and rows the walk has pruned **gate** their
    ///   conversions — the converter never fires, `ConversionStats`
    ///   energy/cycles shrink with ET, and the gated count rides up to
    ///   `MetricsSnapshot` (per-row conversion gating).
    fn transform_pooled(
        &mut self,
        x: &[u32],
        rng: &mut Rng,
        s: &mut PlaneScratch,
    ) -> BitplaneOutput {
        let early_term = self.early_term;
        let pool = self.pool.as_mut().expect("pooled path without a pool");
        decompose_bitplanes_into(x, self.input_bits, &mut s.planes);
        let rows = pool.rows();
        let divisor = pool.cols() as f32;
        let nbits = self.input_bits as usize;
        let base = pool.stats();
        pool.begin_transform();
        let plane_seed = rng.next_u64();
        s.mav_values.clear();
        s.mav_values.resize(nbits * rows, 0.0);

        let prefilled = early_term.is_none();
        if prefilled {
            // No mask can change mid-walk: fan every plane (MSB → LSB)
            // through the pool in one batched call.
            let planes: Vec<&BitVec> = s.planes[..nbits].iter().rev().collect();
            let streams: Vec<u64> = (0..nbits as u64).rev().collect();
            pool.process_planes(&planes, &streams, plane_seed, None, &mut s.mav_values);
        }
        let (values, plane_signs, term) = {
            let mut src = PooledSource {
                pool,
                buf: &mut s.mav_values,
                rows,
                nbits,
                plane_seed,
                prefilled,
                divisor,
                cur: 0,
            };
            walk_planes(&mut src, &s.planes, nbits, rows, early_term, rng, &mut s.active)
        };
        let conv = self.pool.as_ref().expect("pool unchanged").stats().minus(&base);
        BitplaneOutput { values, plane_signs, term, conv }
    }

    /// Transform a batch of unsigned vectors, reusing the engine's
    /// scratch arena across samples.
    ///
    /// Sample `i` draws its analog noise from `Rng::for_stream(seed, i)`,
    /// so the result is **bit-exactly** equal to calling
    /// [`BitplaneEngine::transform`] once per sample with those
    /// generators — and therefore independent of how a caller shards the
    /// batch across worker threads (each shard derives the same
    /// per-sample streams from `seed` + the sample's global index).
    ///
    /// With a pool whose spec sets [`super::PoolSpec::fuse_batch`], the
    /// batch takes the **cross-sample plane fusion** path: every
    /// sample's bitplanes go to the pool together (one submission for
    /// the whole batch without early termination; one submission per
    /// plane depth under ET, gating masks included) instead of each
    /// sample draining the pool alone. Outputs, `ConversionStats` and
    /// pool accounting are bit-identical to the sequential walk —
    /// fusion changes only when the coupling-group lanes see the work.
    pub fn transform_batch(&mut self, xs: &[Vec<u32>], seed: u64) -> Vec<BitplaneOutput> {
        if self.fuses() {
            // Per-sample plane seeds exactly as the sequential path
            // draws them: the single `next_u64` each pooled transform
            // takes from `Rng::for_stream(seed, i)`.
            let plane_seeds: Vec<u64> =
                (0..xs.len() as u64).map(|i| Rng::for_stream(seed, i).next_u64()).collect();
            let refs: Vec<&[u32]> = xs.iter().map(Vec::as_slice).collect();
            return self.transform_fused(&refs, &plane_seeds);
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        let out = xs
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let mut rng = Rng::for_stream(seed, i as u64);
                self.transform_with_scratch(x, &mut rng, &mut scratch)
            })
            .collect();
        self.scratch = scratch;
        out
    }

    /// Transform several inputs that share one caller RNG — the
    /// [`crate::nn`] BWHT layer's shape, where every Hadamard block of a
    /// pixel is its own pooled transform. Bit-identical to calling
    /// [`BitplaneEngine::transform`] once per input with `rng` (each
    /// pooled input consumes exactly one `next_u64`, in order); with
    /// [`super::PoolSpec::fuse_batch`] set the inputs fuse into shared
    /// pool submissions like [`BitplaneEngine::transform_batch`].
    pub fn transform_many(&mut self, xs: &[&[u32]], rng: &mut Rng) -> Vec<BitplaneOutput> {
        if !self.fuses() {
            let mut scratch = std::mem::take(&mut self.scratch);
            let out =
                xs.iter().map(|x| self.transform_with_scratch(x, rng, &mut scratch)).collect();
            self.scratch = scratch;
            return out;
        }
        let plane_seeds: Vec<u64> = xs.iter().map(|_| rng.next_u64()).collect();
        self.transform_fused(xs, &plane_seeds)
    }

    /// True when transforms route through a pool that requests
    /// cross-sample plane fusion.
    pub fn fuses(&self) -> bool {
        self.pool.as_ref().is_some_and(|p| p.spec().fuse_batch)
    }

    /// Public seeded entry to the fused cross-sample transform core,
    /// for callers that draw each input's plane seed themselves (the
    /// batched BWHT serving forward draws input `i`'s seed from sample
    /// `i`'s stream generator, exactly where the sequential walk would
    /// consume it). Input `i` is bit-identical to
    /// [`BitplaneEngine::transform`] with a generator whose next
    /// `next_u64` is `plane_seeds[i]`; outputs and deferred-stats
    /// replay order match the sequential per-input walk.
    pub fn transform_fused_seeded(
        &mut self,
        xs: &[&[u32]],
        plane_seeds: &[u64],
    ) -> Vec<BitplaneOutput> {
        assert!(self.fuses(), "transform_fused_seeded requires a pool with fuse_batch");
        self.transform_fused(xs, plane_seeds)
    }

    /// The fused (cross-sample) pooled transform core. Input `i` is the
    /// exact computation `transform` would run with plane seed
    /// `plane_seeds[i]`, replayed so the pool sees all inputs at once:
    ///
    /// - **No early termination**: every input's planes (MSB → LSB,
    ///   input-major) go to the pool in *one*
    ///   [`CimArrayPool::process_plane_requests`] submission. Each
    ///   plane keeps the cursor slot, noise stream and therefore the
    ///   exact conversion values of its sequential counterpart; the
    ///   lanes just stay saturated across input boundaries instead of
    ///   draining per input.
    /// - **Early termination**: inputs walk their planes in lockstep —
    ///   one fused submission per plane depth, each input under its own
    ///   live mask (pruned rows still gate their conversions), with the
    ///   shared [`step_plane_rows`] updating masks between depths.
    ///
    /// Deferred accounting: per-plane stats come back unapplied and are
    /// replayed into the pool input-major, dispatch-ordered — the exact
    /// merge sequence of the sequential walk — so `ConversionStats`
    /// (energy float accumulation included) and the per-input `minus`
    /// snapshots are bit-identical, not just close.
    fn transform_fused(&mut self, xs: &[&[u32]], plane_seeds: &[u64]) -> Vec<BitplaneOutput> {
        assert_eq!(xs.len(), plane_seeds.len());
        let n = xs.len();
        if n == 0 {
            return Vec::new();
        }
        let nbits = self.input_bits as usize;
        let input_bits = self.input_bits;
        let early_term = self.early_term;
        let pool = self.pool.as_mut().expect("fused transform requires a pool");
        let rows = pool.rows();
        let cols = pool.cols();
        let divisor = cols as f32;

        let mut arenas = std::mem::take(&mut self.fused_scratch);
        arenas.resize_with(n, PlaneScratch::default);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), cols, "input length != crossbar cols");
            let a = &mut arenas[i];
            decompose_bitplanes_into(x, input_bits, &mut a.planes);
            a.active.clear();
            a.active.resize(rows, true);
            a.mav_values.clear();
            a.mav_values.resize(nbits * rows, 0.0);
        }
        let mut accs: Vec<Vec<f32>> = vec![vec![0.0f32; rows]; n];
        let mut signs: Vec<Vec<Vec<bool>>> = vec![vec![vec![false; rows]; nbits]; n];
        let mut terms: Vec<TermStats> = (0..n).map(|_| TermStats::new(rows, nbits)).collect();
        // Per-input deferred stats, in each input's dispatch order.
        let mut stats: Vec<Vec<ConversionStats>> = vec![Vec::new(); n];

        if early_term.is_none() {
            // One submission for the whole batch, input-major MSB→LSB —
            // the same (slot, stream) pairs per input as the sequential
            // `process_planes` fan-out after `begin_transform`.
            let per = {
                let mut requests = Vec::with_capacity(n * nbits);
                for (i, a) in arenas.iter_mut().enumerate() {
                    let PlaneScratch { planes, mav_values, .. } = a;
                    for (j, chunk) in mav_values.chunks_mut(rows).enumerate() {
                        let p = nbits - 1 - j;
                        requests.push(PlaneRequest {
                            slot: j,
                            seed: plane_seeds[i],
                            stream: p as u64,
                            plane: &planes[p],
                            active: None,
                            out: chunk,
                        });
                    }
                }
                pool.process_plane_requests(requests)
            };
            for (i, chunk) in per.chunks(nbits).enumerate() {
                stats[i].extend_from_slice(chunk);
            }
            for i in 0..n {
                let PlaneScratch { active, mav_values, .. } = &mut arenas[i];
                for p in (0..nbits).rev() {
                    let off = (nbits - 1 - p) * rows;
                    let buf = &mav_values[off..off + rows];
                    step_plane_rows(
                        |r| buf[r] as f32,
                        p,
                        rows,
                        divisor,
                        None,
                        active,
                        &mut accs[i],
                        &mut signs[i][p],
                        &mut terms[i],
                    );
                }
            }
        } else {
            // Lockstep walk: one fused submission per plane depth, each
            // input under its own live mask; slots advance only for
            // dispatched planes, exactly like the sequential ET walk.
            let mut next_slot = vec![0usize; n];
            for p in (0..nbits).rev() {
                let dispatch: Vec<bool> =
                    arenas.iter().map(|a| a.active.iter().any(|&x| x)).collect();
                for (i, a) in arenas.iter().enumerate() {
                    if !dispatch[i] {
                        terms[i].record_skipped_plane(p, &a.active);
                    }
                }
                let off = (nbits - 1 - p) * rows;
                let per = {
                    let mut requests = Vec::new();
                    for (i, a) in arenas.iter_mut().enumerate() {
                        if !dispatch[i] {
                            continue;
                        }
                        let slot = next_slot[i];
                        next_slot[i] += 1;
                        let PlaneScratch { planes, active, mav_values, .. } = a;
                        requests.push(PlaneRequest {
                            slot,
                            seed: plane_seeds[i],
                            stream: p as u64,
                            plane: &planes[p],
                            active: Some(&active[..]),
                            out: &mut mav_values[off..off + rows],
                        });
                    }
                    pool.process_plane_requests(requests)
                };
                let mut k = 0usize;
                for (i, a) in arenas.iter_mut().enumerate() {
                    if !dispatch[i] {
                        continue;
                    }
                    stats[i].push(per[k]);
                    k += 1;
                    let PlaneScratch { active, mav_values, .. } = a;
                    let buf = &mav_values[off..off + rows];
                    step_plane_rows(
                        |r| buf[r] as f32,
                        p,
                        rows,
                        divisor,
                        early_term,
                        active,
                        &mut accs[i],
                        &mut signs[i][p],
                        &mut terms[i],
                    );
                }
            }
        }

        // Accounting replay: input-major, dispatch order — the exact
        // sequence of merges the sequential walk performs against the
        // pool's running accumulators, so totals and per-input deltas
        // are bit-identical (energy float accumulation included).
        let mut outputs = Vec::with_capacity(n);
        for i in 0..n {
            let base = pool.stats();
            for s in &stats[i] {
                pool.apply_plane_stats(s);
            }
            let conv = pool.stats().minus(&base);
            outputs.push(BitplaneOutput {
                values: std::mem::take(&mut accs[i]),
                plane_signs: std::mem::take(&mut signs[i]),
                term: std::mem::take(&mut terms[i]),
                conv,
            });
        }
        self.fused_scratch = arenas;
        outputs
    }

    /// Signed transform via positive/negative split: `x = x⁺ − x⁻`.
    /// Values must satisfy `|v| < 2^input_bits`.
    ///
    /// Costs two unsigned crossbar passes **only when both halves carry
    /// charge**: an all-zero half corresponds to a pass the hardware
    /// never fires (no input bit ever raises a column line), so its
    /// contribution is identically zero and the pass — its ops, its
    /// energy, its noise draws — is skipped. All-non-negative inputs
    /// therefore cost exactly one pass. (This also changes *values* vs
    /// earlier releases, deliberately: quantizing a zero half used to
    /// inject a spurious noise-dependent offset of up to ±(2^bits − 1)
    /// per row into the subtraction.)
    pub fn transform_signed(&mut self, x: &[i32], rng: &mut Rng) -> BitplaneOutput {
        let pos: Vec<u32> = x.iter().map(|&v| v.max(0) as u32).collect();
        let neg: Vec<u32> = x.iter().map(|&v| (-v).max(0) as u32).collect();
        if neg.iter().all(|&v| v == 0) {
            return self.transform(&pos, rng);
        }
        if pos.iter().all(|&v| v == 0) {
            let mut out = self.transform(&neg, rng);
            for v in &mut out.values {
                *v = -*v;
            }
            return out;
        }
        let out_p = self.transform(&pos, rng);
        let out_n = self.transform(&neg, rng);
        let values =
            out_p.values.iter().zip(&out_n.values).map(|(a, b)| a - b).collect();
        let mut conv = out_p.conv;
        conv.merge(&out_n.conv);
        BitplaneOutput {
            values,
            plane_signs: out_p.plane_signs,
            term: out_p.term.merged(&out_n.term),
            conv,
        }
    }

    /// Exact (infinite-precision) oracle: `y_r = Σ_p 2^p · d_{r,p}`,
    /// which equals the integer ±1 matrix–vector product.
    pub fn transform_exact(&self, x: &[u32]) -> Vec<i64> {
        let planes = decompose_bitplanes(x, self.input_bits);
        let rows = self.crossbar.rows();
        let mut acc = vec![0i64; rows];
        for (p, plane) in planes.iter().enumerate() {
            let d = self.crossbar.ideal_bitplane(plane);
            for r in 0..rows {
                acc[r] += (1i64 << p) * d[r] as i64;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::crossbar::CrossbarConfig;
    use crate::util::prop;

    fn engine(m: usize, bits: u8, seed: u64) -> (BitplaneEngine, Rng) {
        let mut rng = Rng::new(seed);
        let xb = Crossbar::walsh(m, CrossbarConfig::ideal(), &mut rng);
        (BitplaneEngine::new(xb, bits), rng)
    }

    #[test]
    fn decompose_reassembles_exactly() {
        prop::check("bitplane decompose/reassemble", 128, |rng| {
            let n = 1 + rng.index(64);
            let bits = 1 + rng.index(8) as u8;
            let x: Vec<u32> = (0..n).map(|_| rng.below(1 << bits) as u32).collect();
            let planes = decompose_bitplanes(&x, bits);
            for (i, &v) in x.iter().enumerate() {
                let mut re = 0u32;
                for (p, plane) in planes.iter().enumerate() {
                    if plane.get(i) {
                        re |= 1 << p;
                    }
                }
                crate::prop_assert!(re == v, "i={i}: {re} != {v}");
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn decompose_rejects_overflow() {
        decompose_bitplanes(&[16], 4);
    }

    #[test]
    fn exact_oracle_is_integer_matvec() {
        let (eng, _) = engine(16, 4, 1);
        let mut rng = Rng::new(2);
        let x: Vec<u32> = (0..16).map(|_| rng.below(16) as u32).collect();
        let got = eng.transform_exact(&x);
        // Naive oracle.
        for r in 0..16 {
            let expect: i64 = (0..16)
                .map(|c| eng.crossbar().matrix().get(r, c) as i64 * x[c] as i64)
                .sum();
            assert_eq!(got[r], expect, "row {r}");
        }
    }

    #[test]
    fn quantized_output_tracks_exact_sign_and_scale() {
        // With 1-bit product-sum quantization the reconstruction is an
        // approximation; on average it must correlate strongly with the
        // exact transform (this is what training relies on).
        let (mut eng, mut rng) = engine(64, 4, 3);
        let mut dot = 0.0f64;
        let mut nq = 0.0f64;
        let mut ne = 0.0f64;
        for _ in 0..20 {
            let x: Vec<u32> = (0..64).map(|_| rng.below(16) as u32).collect();
            let exact = eng.transform_exact(&x);
            let out = eng.transform(&x, &mut rng);
            for (q, e) in out.values.iter().zip(&exact) {
                dot += *q as f64 * *e as f64;
                nq += (*q as f64).powi(2);
                ne += (*e as f64).powi(2);
            }
        }
        let corr = dot / (nq.sqrt() * ne.sqrt());
        assert!(corr > 0.5, "correlation {corr} too weak");
    }

    #[test]
    fn one_bit_input_reduces_to_single_plane() {
        let (mut eng, mut rng) = engine(16, 1, 4);
        let x: Vec<u32> = (0..16).map(|i| (i % 2) as u32).collect();
        let out = eng.transform(&x, &mut rng);
        assert_eq!(out.plane_signs.len(), 1);
        // Reconstruction is ±1 per row.
        for v in &out.values {
            assert!(*v == 1.0 || *v == -1.0);
        }
    }

    #[test]
    fn signed_transform_matches_pos_neg_split_oracle() {
        let (mut eng, mut rng) = engine(16, 4, 5);
        let x: Vec<i32> = (0..16)
            .map(|i| if i % 3 == 0 { -(i as i32 % 8) } else { i as i32 % 8 })
            .collect();
        let out = eng.transform_signed(&x, &mut rng);
        // With an ideal crossbar, signed output == pos-pass − neg-pass.
        let pos: Vec<u32> = x.iter().map(|&v| v.max(0) as u32).collect();
        let neg: Vec<u32> = x.iter().map(|&v| (-v).max(0) as u32).collect();
        let op = eng.transform(&pos, &mut rng).values;
        let on = eng.transform(&neg, &mut rng).values;
        for (got, (a, b)) in out.values.iter().zip(op.iter().zip(&on)) {
            assert_eq!(*got, a - b);
        }
    }

    #[test]
    fn plane_count_and_ops_accounting() {
        let (mut eng, mut rng) = engine(16, 6, 6);
        let x = vec![21u32; 16];
        eng.crossbar_mut().reset_counters();
        let _ = eng.transform(&x, &mut rng);
        assert_eq!(eng.crossbar().ops(), 6, "one crossbar op per plane");
    }

    #[test]
    fn decompose_into_reuses_wider_arena() {
        let mut planes = Vec::new();
        decompose_bitplanes_into(&[200, 17, 3], 8, &mut planes);
        assert_eq!(planes.len(), 8);
        // Narrower redecomposition over a shorter input must fully reset.
        decompose_bitplanes_into(&[1, 0], 2, &mut planes);
        assert_eq!(planes.len(), 2);
        assert_eq!(planes[0].len(), 2);
        assert!(planes[0].get(0) && !planes[0].get(1));
        assert_eq!(planes[1].count_ones(), 0);
        let fresh = decompose_bitplanes(&[1, 0], 2);
        assert_eq!(planes, fresh);
    }

    #[test]
    fn transform_matches_manual_plane_walk() {
        // Bit-exactness guard for the provider refactor: the 1-bit path
        // must equal a first-principles re-derivation of the plane walk
        // (decompose, MSB→LSB crossbar ops, ±1 sign accumulation) on a
        // *noisy* config — same RNG schedule, same f32 arithmetic.
        let mut fab = Rng::new(9);
        let xb = Crossbar::walsh(32, CrossbarConfig::default(), &mut fab);
        let mut eng = BitplaneEngine::new(xb.clone(), 4);
        let mut manual_xb = xb;
        let x: Vec<u32> = (0..32).map(|i| ((i * 5 + 3) % 16) as u32).collect();
        let seed = 0xd00d;
        let out = eng.transform(&x, &mut Rng::new(seed));

        let planes = decompose_bitplanes(&x, 4);
        let mut r = Rng::new(seed);
        let mut acc = vec![0.0f32; 32];
        let mut signs = BitVec::zeros(32);
        let mut plane_signs = vec![vec![false; 32]; 4];
        for p in (0..4).rev() {
            manual_xb.process_bitplane_into(&planes[p], &mut r, &mut signs);
            let w = (1u32 << p) as f32;
            for row in 0..32 {
                let sgn = signs.get(row);
                acc[row] += w * if sgn { 1.0 } else { -1.0 };
                plane_signs[p][row] = sgn;
            }
        }
        assert_eq!(out.values, acc);
        assert_eq!(out.plane_signs, plane_signs);
        assert_eq!(out.conv, ConversionStats::default());
    }

    #[test]
    fn batch_equals_sequential_per_stream_transforms() {
        // The transform_batch determinism contract, on a *noisy* config:
        // batch output == one transform per sample with Rng::for_stream.
        let mut rng = Rng::new(9);
        let xb = Crossbar::walsh(32, CrossbarConfig::default(), &mut rng);
        let mut batch_eng = BitplaneEngine::new(xb.clone(), 4);
        let mut seq_eng = BitplaneEngine::new(xb, 4);
        let xs: Vec<Vec<u32>> = (0..12)
            .map(|s| (0..32).map(|i| ((i * 7 + s * 13) % 16) as u32).collect())
            .collect();
        let seed = 0xbeef;
        let batched = batch_eng.transform_batch(&xs, seed);
        for (i, x) in xs.iter().enumerate() {
            let mut r = Rng::for_stream(seed, i as u64);
            let single = seq_eng.transform(x, &mut r);
            assert_eq!(batched[i].values, single.values, "sample {i}");
            assert_eq!(batched[i].plane_signs, single.plane_signs, "sample {i}");
        }
    }

    #[test]
    fn signed_skips_all_zero_half() {
        let (mut eng, mut rng) = engine(16, 4, 7);
        // All-non-negative input: exactly one pass worth of crossbar ops.
        let x: Vec<i32> = (0..16).map(|i| (i % 8) as i32).collect();
        eng.crossbar_mut().reset_counters();
        let out = eng.transform_signed(&x, &mut rng);
        assert_eq!(eng.crossbar().ops(), 4, "one op per plane, single pass");
        // And the output equals the plain unsigned transform (ideal
        // crossbar ⇒ deterministic, rng-independent).
        let pos: Vec<u32> = x.iter().map(|&v| v as u32).collect();
        let unsigned = eng.transform(&pos, &mut rng);
        assert_eq!(out.values, unsigned.values);

        // All-non-positive input: single pass, negated values.
        let xn: Vec<i32> = x.iter().map(|&v| -v).collect();
        eng.crossbar_mut().reset_counters();
        let out_n = eng.transform_signed(&xn, &mut rng);
        assert_eq!(eng.crossbar().ops(), 4);
        for (a, b) in out_n.values.iter().zip(&unsigned.values) {
            assert_eq!(*a, -*b);
        }

        // Mixed input still costs both passes.
        let mut xm = x.clone();
        xm[0] = -3;
        eng.crossbar_mut().reset_counters();
        let _ = eng.transform_signed(&xm, &mut rng);
        assert_eq!(eng.crossbar().ops(), 8, "two passes for mixed signs");
    }
}
