//! Packed bit-vectors and ±1 sign matrices.
//!
//! The analog crossbar's charge sums have an exact digital shadow:
//! `sum_r = Σ_c M[r,c] · x[c]` with `M[r,c] ∈ {±1}` and `x[c] ∈ {0,1}`.
//! Packing `x` and the +1 positions of `M` into `u64` words turns each
//! row sum into a handful of `popcount`s — this is the simulator's hot
//! loop (see EXPERIMENTS.md §Perf).

/// A packed bit-vector of `len` bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl Default for BitVec {
    /// Empty vector (scratch-buffer initial state; see [`BitVec::reset`]).
    fn default() -> Self {
        BitVec::zeros(0)
    }
}

impl BitVec {
    /// All-zeros vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { words: vec![0; len.div_ceil(64)], len }
    }

    /// Build from a bool slice.
    pub fn from_bits(bits: &[bool]) -> Self {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    #[inline]
    /// Bit count.
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    /// True when the vector holds no bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    /// Read bit `i`.
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    /// Write bit `i`.
    pub fn set(&mut self, i: usize, b: bool) {
        debug_assert!(i < self.len);
        let (w, s) = (i / 64, i % 64);
        if b {
            self.words[w] |= 1 << s;
        } else {
            self.words[w] &= !(1 << s);
        }
    }

    /// Number of set bits.
    #[inline]
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Raw words (trailing bits beyond `len` are zero by construction).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Clear all bits (length unchanged).
    #[inline]
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Resize to `len` bits and clear — reuses the word allocation when
    /// possible. Scratch buffers in the crossbar hot path use this
    /// instead of constructing a fresh `BitVec` per operation.
    #[inline]
    pub fn reset(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(len.div_ceil(64), 0);
        self.len = len;
    }
}

/// A dense `rows × cols` matrix over {−1, +1}, stored as the bitmask of
/// +1 positions, one packed row at a time.
#[derive(Debug, Clone)]
pub struct SignMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    /// Bit set ⇒ entry is +1; clear ⇒ −1.
    plus: Vec<u64>,
}

impl SignMatrix {
    /// Build from a generator: `f(r, c) == true` ⇒ +1.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> bool) -> Self {
        let words_per_row = cols.div_ceil(64);
        let mut plus = vec![0u64; rows * words_per_row];
        for r in 0..rows {
            for c in 0..cols {
                if f(r, c) {
                    plus[r * words_per_row + c / 64] |= 1 << (c % 64);
                }
            }
        }
        SignMatrix { rows, cols, words_per_row, plus }
    }

    /// ±1 Hadamard matrix of order `m` (natural order).
    pub fn hadamard(m: usize) -> Self {
        let h = crate::wht::matrix::hadamard(m);
        SignMatrix::from_fn(m, m, |r, c| h[r * m + c] > 0)
    }

    /// ±1 Walsh (sequency-ordered) matrix of order `m`.
    pub fn walsh(m: usize) -> Self {
        let w = crate::wht::matrix::walsh(m);
        SignMatrix::from_fn(m, m, |r, c| w[r * m + c] > 0)
    }

    #[inline]
    /// Matrix rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    /// Matrix columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Entry at (r, c) as ±1.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        debug_assert!(r < self.rows && c < self.cols);
        if (self.plus[r * self.words_per_row + c / 64] >> (c % 64)) & 1 == 1 {
            1
        } else {
            -1
        }
    }

    /// Write entry (r, c): `plus == true` ⇒ +1, else −1. Used by the
    /// fault layer to apply (and revert) stuck-cell injections around a
    /// plane dispatch; the crossbar's derived constants do not depend
    /// on matrix content, so no recomputation is needed.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, plus: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = r * self.words_per_row + c / 64;
        if plus {
            self.plus[w] |= 1 << (c % 64);
        } else {
            self.plus[w] &= !(1 << (c % 64));
        }
    }

    /// Exact row dot product with a {0,1} input vector:
    /// `Σ_c M[r,c]·x[c] = 2·|plus ∩ x| − |x|`.
    ///
    /// Recomputes `|x|` per call; any caller evaluating **multiple rows
    /// against the same `x`** should hoist `x.count_ones()` once and
    /// use [`SignMatrix::row_dot_with_ones`] instead — the per-row
    /// recomputation doubles the popcount work of a full matvec (the
    /// PR-5 audit left this wrapper with no multi-row callers in the
    /// library; `matvec` and the crossbar paths all hoist).
    #[inline]
    pub fn row_dot(&self, r: usize, x: &BitVec) -> i32 {
        self.row_dot_with_ones(r, x, x.count_ones() as i32)
    }

    /// [`SignMatrix::row_dot`] with the input popcount `ones ==
    /// x.count_ones()` hoisted out by the caller — the multi-row form:
    /// one popcount pass over the row intersection, zero over `x`.
    #[inline]
    pub fn row_dot_with_ones(&self, r: usize, x: &BitVec, ones: i32) -> i32 {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(ones, x.count_ones() as i32);
        2 * self.row_plus_count(r, x) as i32 - ones
    }

    /// Count of +1 cells that see a 1 input in row `r` — the charge count
    /// dumped on the positive sum line SL (the analog MAV numerator).
    #[inline]
    pub fn row_plus_count(&self, r: usize, x: &BitVec) -> u32 {
        let row = &self.plus[r * self.words_per_row..(r + 1) * self.words_per_row];
        row.iter().zip(x.words()).map(|(w, xw)| (w & xw).count_ones()).sum()
    }

    /// All row dot products (the exact digital transform of one plane).
    ///
    /// PERF: `x.count_ones()` is hoisted out of the row loop (see
    /// EXPERIMENTS.md §Perf) — this is just the hoisted
    /// [`SignMatrix::row_dot_with_ones`] mapped over the rows.
    pub fn matvec(&self, x: &BitVec) -> Vec<i32> {
        debug_assert_eq!(x.len(), self.cols);
        let ones = x.count_ones() as i32;
        (0..self.rows).map(|r| self.row_dot_with_ones(r, x, ones)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn bitvec_set_get_count() {
        let mut v = BitVec::zeros(130);
        v.set(0, true);
        v.set(64, true);
        v.set(129, true);
        assert!(v.get(0) && v.get(64) && v.get(129));
        assert!(!v.get(1));
        assert_eq!(v.count_ones(), 3);
        v.set(64, false);
        assert_eq!(v.count_ones(), 2);
    }

    #[test]
    fn from_bits_round_trips() {
        let bits: Vec<bool> = (0..100).map(|i| i % 3 == 0).collect();
        let v = BitVec::from_bits(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(v.get(i), b);
        }
    }

    #[test]
    fn sign_matrix_hadamard_entries() {
        let m = SignMatrix::hadamard(4);
        let dense = crate::wht::matrix::hadamard(4);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(m.get(r, c), dense[r * 4 + c]);
            }
        }
    }

    #[test]
    fn sign_matrix_set_flips_and_restores() {
        let mut m = SignMatrix::hadamard(8);
        let orig = m.get(3, 5);
        m.set(3, 5, orig < 0);
        assert_eq!(m.get(3, 5), -orig, "set flips the entry");
        // Neighbours in the same packed word are untouched.
        assert_eq!(m.get(3, 4), SignMatrix::hadamard(8).get(3, 4));
        assert_eq!(m.get(3, 6), SignMatrix::hadamard(8).get(3, 6));
        m.set(3, 5, orig > 0);
        assert_eq!(m.get(3, 5), orig, "set restores the entry");
    }

    #[test]
    fn row_dot_matches_naive() {
        prop::check("row_dot vs naive", 128, |rng: &mut Rng| {
            let cols = 1 + rng.index(200);
            let rows = 1 + rng.index(20);
            let mx = SignMatrix::from_fn(rows, cols, |_, _| rng.bool());
            let bits: Vec<bool> = (0..cols).map(|_| rng.bool()).collect();
            let x = BitVec::from_bits(&bits);
            for r in 0..rows {
                let naive: i32 =
                    (0..cols).filter(|&c| bits[c]).map(|c| mx.get(r, c) as i32).sum();
                crate::prop_assert!(
                    mx.row_dot(r, &x) == naive,
                    "row {r}: packed {} vs naive {naive}",
                    mx.row_dot(r, &x)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn plus_count_consistent_with_dot() {
        prop::check("plus_count vs dot", 128, |rng: &mut Rng| {
            let cols = 1 + rng.index(128);
            let mx = SignMatrix::from_fn(4, cols, |_, _| rng.bool());
            let bits: Vec<bool> = (0..cols).map(|_| rng.bool()).collect();
            let x = BitVec::from_bits(&bits);
            for r in 0..4 {
                let dot = mx.row_dot(r, &x);
                let plus = mx.row_plus_count(r, &x) as i32;
                let ones = x.count_ones() as i32;
                crate::prop_assert!(dot == 2 * plus - ones, "identity broken");
            }
            Ok(())
        });
    }

    #[test]
    fn row_dot_with_hoisted_ones_matches_naive() {
        // Independent oracle (not `row_dot`, which now delegates here).
        prop::check("row_dot_with_ones vs naive", 96, |rng: &mut Rng| {
            let cols = 1 + rng.index(150);
            let rows = 1 + rng.index(16);
            let mx = SignMatrix::from_fn(rows, cols, |_, _| rng.bool());
            let bits: Vec<bool> = (0..cols).map(|_| rng.bool()).collect();
            let x = BitVec::from_bits(&bits);
            let ones = x.count_ones() as i32;
            for r in 0..rows {
                let naive: i32 =
                    (0..cols).filter(|&c| bits[c]).map(|c| mx.get(r, c) as i32).sum();
                crate::prop_assert!(
                    mx.row_dot_with_ones(r, &x, ones) == naive,
                    "row {r}: hoisted {} vs naive {naive}",
                    mx.row_dot_with_ones(r, &x, ones)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn matvec_equals_per_row_dot() {
        prop::check("matvec == row_dot per row", 96, |rng: &mut Rng| {
            let cols = 1 + rng.index(180);
            let rows = 1 + rng.index(24);
            let mx = SignMatrix::from_fn(rows, cols, |_, _| rng.bool());
            let bits: Vec<bool> = (0..cols).map(|_| rng.bool()).collect();
            let x = BitVec::from_bits(&bits);
            let mv = mx.matvec(&x);
            for r in 0..rows {
                crate::prop_assert!(
                    mv[r] == mx.row_dot(r, &x),
                    "row {r}: matvec {} vs row_dot {}",
                    mv[r],
                    mx.row_dot(r, &x)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn reset_reuses_and_clears() {
        let mut v = BitVec::from_bits(&[true; 130]);
        v.reset(70);
        assert_eq!(v.len(), 70);
        assert_eq!(v.count_ones(), 0);
        v.set(69, true);
        v.reset(200);
        assert_eq!(v.len(), 200);
        assert_eq!(v.count_ones(), 0);
        v.set(199, true);
        assert!(v.get(199));
        v.clear();
        assert_eq!(v.count_ones(), 0);
        assert_eq!(v.len(), 200);
    }

    #[test]
    fn walsh_matvec_matches_fwht_on_binary_input() {
        let m = 64;
        let mx = SignMatrix::walsh(m);
        let bits: Vec<bool> = (0..m).map(|i| (i * 7) % 5 < 2).collect();
        let x = BitVec::from_bits(&bits);
        let got = mx.matvec(&x);
        let mut f: Vec<f32> = bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        crate::wht::fwht_sequency_inplace(&mut f);
        for (g, e) in got.iter().zip(&f) {
            assert_eq!(*g as f32, *e);
        }
    }
}
