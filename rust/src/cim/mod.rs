//! Compute-in-SRAM crossbar simulator (paper §III).
//!
//! The paper's first contribution: an ADC/DAC-free analog crossbar that
//! executes Walsh–Hadamard transforms in the charge domain. The transform
//! matrix is parameter-free (entries ±1), so cells are simple NMOS pairs,
//! and the four-step operation (precharge → parallel local compute →
//! row-merge charge sum → comparator/threshold) completes in two clock
//! cycles (Figs 2–3).
//!
//! Multi-bit digital inputs are processed **bitplane-wise** (Fig 4): each
//! input significance bit is applied as one crossbar operation; the analog
//! row sums are quantized to a *single bit* by the row comparators
//! (ADC-free, paper §III-B), and output bitplanes are reassembled into a
//! multi-bit output vector. Training absorbs the quantization error
//! ([`crate::nn::train`]).
//!
//! Module map:
//! - [`bitvec`] — packed bit-vectors and ±1 sign matrices with popcount
//!   row dot products (the digital shadow of the analog charge sums).
//! - [`crossbar`] — the analog 4-step operation with settling, noise and
//!   energy accounting; also exposes raw MAV voltages for the ADC path.
//! - [`bitplane`] — multi-bit input decomposition / output reassembly,
//!   through either the 1-bit comparators or a digitization pool.
//! - [`early_term`] — the paper's §III-C early-termination engine
//!   exploiting soft-threshold output sparsity.
//! - [`pool`] — the collaborative digitization fabric (paper §IV): N
//!   scheduled arrays taking turns computing MAVs and digitizing their
//!   neighbour's through memory-immersed converters, with runtime
//!   exactly-once enforcement and per-conversion energy accounting.

pub mod bitplane;
pub mod bitvec;
pub mod crossbar;
pub mod early_term;
pub mod fault;
pub mod pool;

pub use bitplane::{
    decompose_bitplanes, decompose_bitplanes_into, BitplaneEngine, BitplaneOutput, PlaneScratch,
};
pub use bitvec::{BitVec, SignMatrix};
pub use crossbar::{Crossbar, CrossbarConfig};
pub use early_term::{EarlyTermination, TermStats};
pub use fault::{Fault, FaultKind, FaultPlan, FaultStats, HealthLedger, HealthStatus};
pub use pool::{CimArrayPool, ConversionStats, PlaneRequest, PoolSpec};
