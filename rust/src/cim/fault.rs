//! Deterministic analog fault injection and pool self-healing.
//!
//! Real analog fabrics degrade continuously: crossbar cells stick,
//! memory-immersed converters drift, whole arrays die. Because the
//! paper's area argument *shares* converters across coupling groups, a
//! single faulty converter or array silently corrupts every group
//! member's digitization — so this module gives the serving stack three
//! layers of defence:
//!
//! 1. **Injection** — a [`FaultPlan`] of typed faults
//!    ([`FaultKind::StuckCell`], [`FaultKind::ConverterDrift`],
//!    [`FaultKind::ConverterDead`], [`FaultKind::ArrayDown`]) with each
//!    onset expressed on the pool's **plane-slot clock** (the dispatch
//!    cursor that [`super::pool::CimArrayPool::begin_transform`]
//!    resets). Every effect is a pure function of a dispatch's slot
//!    value and the static plan, so fused, batched and multi-threaded
//!    paths replay bit-identically.
//! 2. **Detection** — periodic calibration probes at every
//!    `probe_interval`-th slot: each group's converter digitizes a
//!    known mid-bin voltage whose exact code is precomputed
//!    ([`crate::adc::probe_voltage`] + [`crate::adc::ideal_code`],
//!    the PR-2 aligned-ideal property), and each array answers a
//!    liveness ping. Failures feed a [`HealthLedger`] with debounced
//!    per-unit state transitions ([`HealthStatus`]).
//! 3. **Healing** — a quarantined converter's group reroutes its
//!    conversions (healthy-peer / intra-array SAR fallback, one extra
//!    cycle per conversion); a quarantined array is idled out of a
//!    recomputed degraded [`InterleaveSchedule`]; a fully-dead group's
//!    planes remap onto the next healthy group. [`FaultStats`] counts
//!    the blast radius for metrics and JSONL telemetry.
//!
//! Probes are evaluated lazily but **monotonically** in slot order, and
//! quarantine latches record the probe slot they fired at
//! (`quarantined_at`), so a dispatch at slot `s` observes exactly the
//! health state as of `s` regardless of the order submissions arrive —
//! the arrival-order-independence half of the determinism contract.

use crate::adc::{drifted, probe_voltage, Adc, AnyAdc};
use crate::network::{InterleaveSchedule, Role, Topology};
use crate::util::Rng;

use super::crossbar::Crossbar;

/// Stream salt separating probe noise draws from every serving stream.
const PROBE_SEED_SALT: u64 = 0x50_52_4f_42_45; // "PROBE"

/// One typed hardware fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A crossbar cell in `array`'s sign matrix stuck at `plus`
    /// (`true` = +1, `false` = −1) from the onset slot onward.
    StuckCell {
        /// Pool array holding the faulty cell.
        array: usize,
        /// Matrix row of the cell.
        row: usize,
        /// Matrix column of the cell.
        col: usize,
        /// Stuck polarity: `true` sticks the cell at +1.
        plus: bool,
    },
    /// `group`'s memory-immersed converter develops gain/offset error:
    /// inputs become `gain·v + offset·vdd` (clamped to the rails).
    ConverterDrift {
        /// Coupling group whose converter drifts.
        group: usize,
        /// Multiplicative gain error (1.0 = none).
        gain: f64,
        /// Additive offset in units of `vdd`.
        offset: f64,
    },
    /// `group`'s converter dies outright: every input reads 0 V.
    ConverterDead {
        /// Coupling group whose converter dies.
        group: usize,
    },
    /// `array` stops computing: its MAVs read 0 V until the health
    /// probes quarantine it out of the schedule.
    ArrayDown {
        /// Pool array that goes down.
        array: usize,
    },
}

/// A fault plus its onset on the plane-slot clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    /// First plane slot the fault affects. The slot clock restarts at
    /// `begin_transform`, so onset `s` spares the first `s` plane
    /// dispatches of every transform; onset 0 makes the fault
    /// unconditional.
    pub onset: u64,
    /// What breaks.
    pub kind: FaultKind,
}

/// A validated, seeded set of faults plus probe cadence knobs — the
/// whole configuration of the fault layer. Construct via
/// [`FaultPlan::parse`] or field-by-field, then hand to
/// [`super::pool::CimArrayPool::set_fault_plan`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for probe conversion noise (`Rng::for_stream` keyed per
    /// probe slot × unit, salted away from every serving stream).
    pub seed: u64,
    /// The injected faults.
    pub faults: Vec<Fault>,
    /// Calibration probes fire at every slot divisible by this
    /// interval; 0 disables probing (faults inject but never heal).
    pub probe_interval: u64,
    /// Probe failure threshold in output codes: a probe fails when
    /// `|code − expected| > probe_tolerance`.
    pub probe_tolerance: u32,
    /// Consecutive probe failures before a unit is quarantined.
    pub probe_debounce: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0xfa17,
            faults: Vec::new(),
            probe_interval: 2,
            probe_tolerance: 1,
            probe_debounce: 2,
        }
    }
}

impl FaultPlan {
    /// Parse a semicolon-separated fault list:
    ///
    /// - `stuck@SLOT=ARRAY,ROW,COL,+` (or `-`) — stuck cell,
    /// - `drift@SLOT=GROUP,GAIN,OFFSET` — converter drift,
    /// - `dead@SLOT=GROUP` — converter dead,
    /// - `down@SLOT=ARRAY` — array down.
    ///
    /// e.g. `"dead@0=1;stuck@2=0,3,17,+"`. Whitespace around entries is
    /// ignored; an empty string yields an empty plan (probes only).
    /// Probe knobs keep their [`FaultPlan::default`] values.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            plan.faults.push(parse_entry(entry)?);
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Validate parameter ranges (index bounds are checked against the
    /// pool's geometry at install time): drift gain finite in `[0, 4]`,
    /// drift offset finite in `[−1, 1]`, probe debounce ≥ 1.
    pub fn validate(&self) -> Result<(), String> {
        if self.probe_debounce == 0 {
            return Err("fault plan: probe_debounce must be >= 1".into());
        }
        for f in &self.faults {
            if let FaultKind::ConverterDrift { group, gain, offset } = f.kind {
                if !gain.is_finite() || !(0.0..=4.0).contains(&gain) {
                    return Err(format!(
                        "fault plan: drift gain {gain} on group {group} outside [0, 4]"
                    ));
                }
                if !offset.is_finite() || !(-1.0..=1.0).contains(&offset) {
                    return Err(format!(
                        "fault plan: drift offset {offset} on group {group} outside [-1, 1]"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Validate fault indices against a pool geometry.
    pub fn validate_for(
        &self,
        n_arrays: usize,
        n_groups: usize,
        rows: usize,
        cols: usize,
    ) -> Result<(), String> {
        for f in &self.faults {
            match f.kind {
                FaultKind::StuckCell { array, row, col, .. } => {
                    if array >= n_arrays {
                        return Err(format!("stuck cell array {array} >= {n_arrays} arrays"));
                    }
                    if row >= rows || col >= cols {
                        return Err(format!(
                            "stuck cell ({row}, {col}) outside {rows}x{cols} matrix"
                        ));
                    }
                }
                FaultKind::ConverterDrift { group, .. } | FaultKind::ConverterDead { group } => {
                    if group >= n_groups {
                        return Err(format!("converter fault group {group} >= {n_groups} groups"));
                    }
                }
                FaultKind::ArrayDown { array } => {
                    if array >= n_arrays {
                        return Err(format!("array-down index {array} >= {n_arrays} arrays"));
                    }
                }
            }
        }
        Ok(())
    }
}

fn parse_entry(entry: &str) -> Result<Fault, String> {
    let bad = |why: &str| format!("fault plan entry '{entry}': {why}");
    let (head, args) = entry.split_once('=').ok_or_else(|| bad("missing '='"))?;
    let (kind, onset) = head.split_once('@').ok_or_else(|| bad("missing '@SLOT'"))?;
    let onset: u64 = onset.trim().parse().map_err(|_| bad("onset is not an integer"))?;
    let parts: Vec<&str> = args.split(',').map(str::trim).collect();
    let int = |s: &str| s.parse::<usize>().map_err(|_| bad("index is not an integer"));
    let num = |s: &str| s.parse::<f64>().map_err(|_| bad("value is not a number"));
    let kind = match kind.trim() {
        "stuck" => {
            if parts.len() != 4 {
                return Err(bad("stuck needs ARRAY,ROW,COL,SIGN"));
            }
            let plus = match parts[3] {
                "+" => true,
                "-" => false,
                _ => return Err(bad("stuck sign must be '+' or '-'")),
            };
            FaultKind::StuckCell {
                array: int(parts[0])?,
                row: int(parts[1])?,
                col: int(parts[2])?,
                plus,
            }
        }
        "drift" => {
            if parts.len() != 3 {
                return Err(bad("drift needs GROUP,GAIN,OFFSET"));
            }
            FaultKind::ConverterDrift {
                group: int(parts[0])?,
                gain: num(parts[1])?,
                offset: num(parts[2])?,
            }
        }
        "dead" => {
            if parts.len() != 1 {
                return Err(bad("dead needs GROUP"));
            }
            FaultKind::ConverterDead { group: int(parts[0])? }
        }
        "down" => {
            if parts.len() != 1 {
                return Err(bad("down needs ARRAY"));
            }
            FaultKind::ArrayDown { array: int(parts[0])? }
        }
        other => return Err(bad(&format!("unknown fault kind '{other}'"))),
    };
    Ok(Fault { onset, kind })
}

/// Blast-radius accounting for the fault layer. Every field is a
/// monotone count; `faults_injected` always equals the sum of the four
/// per-type counters (they increment together).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Faults whose onset slot has been reached (each counted once).
    pub faults_injected: u64,
    /// Injected faults of kind [`FaultKind::StuckCell`].
    pub stuck_cells: u64,
    /// Injected faults of kind [`FaultKind::ConverterDrift`].
    pub converters_drifting: u64,
    /// Injected faults of kind [`FaultKind::ConverterDead`].
    pub converters_dead: u64,
    /// Injected faults of kind [`FaultKind::ArrayDown`].
    pub arrays_down: u64,
    /// Calibration probes evaluated (converter probes + array pings).
    pub probes_run: u64,
    /// Probes whose code missed the precomputed expectation.
    pub probes_failed: u64,
    /// Units (converters or arrays) quarantined by debounced failures.
    pub quarantined: u64,
    /// Plane dispatches that ran in any degraded mode (zeroed MAVs,
    /// drifting/dead converter, reroute, or group remap).
    pub degraded_planes: u64,
    /// Conversions rerouted away from a quarantined converter.
    pub conversions_rerouted: u64,
    /// Digitized MAVs whose pre-clamp voltage left `[0, vdd]` — the
    /// per-converter sanity bound (advisory; never triggers
    /// quarantine, so lane timing cannot affect health transitions).
    pub mav_out_of_bounds: u64,
}

impl FaultStats {
    /// Accumulate `other` into `self`.
    pub fn merge(&mut self, other: &FaultStats) {
        self.faults_injected += other.faults_injected;
        self.stuck_cells += other.stuck_cells;
        self.converters_drifting += other.converters_drifting;
        self.converters_dead += other.converters_dead;
        self.arrays_down += other.arrays_down;
        self.probes_run += other.probes_run;
        self.probes_failed += other.probes_failed;
        self.quarantined += other.quarantined;
        self.degraded_planes += other.degraded_planes;
        self.conversions_rerouted += other.conversions_rerouted;
        self.mav_out_of_bounds += other.mav_out_of_bounds;
    }

    /// Counter-wise difference vs an earlier snapshot of the same
    /// accumulator (all fields are monotone).
    pub fn minus(&self, base: &FaultStats) -> FaultStats {
        FaultStats {
            faults_injected: self.faults_injected - base.faults_injected,
            stuck_cells: self.stuck_cells - base.stuck_cells,
            converters_drifting: self.converters_drifting - base.converters_drifting,
            converters_dead: self.converters_dead - base.converters_dead,
            arrays_down: self.arrays_down - base.arrays_down,
            probes_run: self.probes_run - base.probes_run,
            probes_failed: self.probes_failed - base.probes_failed,
            quarantined: self.quarantined - base.quarantined,
            degraded_planes: self.degraded_planes - base.degraded_planes,
            conversions_rerouted: self.conversions_rerouted - base.conversions_rerouted,
            mav_out_of_bounds: self.mav_out_of_bounds - base.mav_out_of_bounds,
        }
    }

    /// True when every counter is zero (the inert-layer signature).
    pub fn is_zero(&self) -> bool {
        *self == FaultStats::default()
    }

    /// Sum of the four per-type injection counters — always equal to
    /// `faults_injected` (asserted by tests and the CI fault smoke).
    pub fn injected_by_type(&self) -> u64 {
        self.stuck_cells + self.converters_drifting + self.converters_dead + self.arrays_down
    }
}

/// Debounced health of one unit (converter or array).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthStatus {
    /// No outstanding probe failures.
    Healthy,
    /// `n` consecutive probe failures, below the debounce threshold.
    Suspect(u32),
    /// Debounce threshold reached; the unit is out of service.
    Quarantined,
}

/// Per-unit debounce state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct UnitHealth {
    streak: u32,
    quarantined_at: Option<u64>,
}

impl UnitHealth {
    /// Record one probe outcome at probe slot `p`; returns `true` on
    /// the transition into quarantine.
    fn note(&mut self, ok: bool, debounce: u32, p: u64) -> bool {
        if self.quarantined_at.is_some() {
            return false;
        }
        if ok {
            self.streak = 0;
            return false;
        }
        self.streak += 1;
        if self.streak >= debounce {
            self.quarantined_at = Some(p);
            return true;
        }
        false
    }

    fn status(&self) -> HealthStatus {
        match (self.quarantined_at, self.streak) {
            (Some(_), _) => HealthStatus::Quarantined,
            (None, 0) => HealthStatus::Healthy,
            (None, n) => HealthStatus::Suspect(n),
        }
    }

    /// Quarantine active for dispatches at `slot`?
    fn quarantined_for(&self, slot: u64) -> bool {
        self.quarantined_at.is_some_and(|q| q <= slot)
    }
}

/// Per-converter and per-array health, fed by the calibration probes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HealthLedger {
    converters: Vec<UnitHealth>,
    arrays: Vec<UnitHealth>,
}

impl HealthLedger {
    fn new(n_groups: usize, n_arrays: usize) -> Self {
        HealthLedger {
            converters: vec![UnitHealth::default(); n_groups],
            arrays: vec![UnitHealth::default(); n_arrays],
        }
    }

    /// Health of group `g`'s converter as of the latest evaluated probe.
    pub fn converter_status(&self, g: usize) -> HealthStatus {
        self.converters[g].status()
    }

    /// Health of array `a` as of the latest evaluated probe.
    pub fn array_status(&self, a: usize) -> HealthStatus {
        self.arrays[a].status()
    }

    /// Total units currently quarantined.
    pub fn quarantined(&self) -> usize {
        self.converters
            .iter()
            .chain(&self.arrays)
            .filter(|u| u.quarantined_at.is_some())
            .count()
    }
}

/// Fault context of one plane dispatch — a pure function of the slot.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub(crate) struct SlotFault {
    /// The computing array is down: its MAVs read 0 V.
    pub computer_down: bool,
    /// The serving converter is dead (pre-quarantine): inputs read 0 V.
    pub dead: bool,
    /// Composed active drift `(gain, offset)` on the serving converter.
    pub drift: Option<(f64, f64)>,
    /// The serving converter is quarantined: conversions reroute to the
    /// healthy-peer / intra-array fallback at +1 cycle each.
    pub reroute: bool,
}

impl SlotFault {
    /// Any effect set (used for degraded-plane accounting).
    fn any(&self) -> bool {
        self.computer_down || self.dead || self.drift.is_some() || self.reroute
    }
}

/// One stuck-cell application scoped to a single dispatch: applied to
/// the computing array before the plane runs and reverted after, so
/// effects stay pure per slot under any submission interleaving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct StuckApply {
    /// Matrix row of the cell.
    pub row: usize,
    /// Matrix column of the cell.
    pub col: usize,
    /// Faulty polarity while the dispatch runs.
    pub plus: bool,
    /// Programmed polarity to restore afterwards.
    pub orig: bool,
}

/// Everything the fault layer decided about one dispatch.
#[derive(Debug, Clone, Default, PartialEq)]
pub(crate) struct Resolution {
    /// Group whose lane (arrays + converter) serves this dispatch —
    /// differs from `slot % n_groups` only after a full-group loss.
    pub group: usize,
    /// Absolute index of the computing array.
    pub computer: usize,
    /// Converter/array effects for this slot.
    pub fault: SlotFault,
    /// Stuck cells to apply around the computer's plane op.
    pub stuck: Vec<StuckApply>,
}

/// A health epoch: the degraded schedule and group remap in force from
/// `from_slot` onward (epoch 0 is the pristine schedule from slot 0).
#[derive(Debug, Clone)]
struct Epoch {
    from_slot: u64,
    schedule: InterleaveSchedule,
    /// `serving[g]` = group whose lane serves group `g`'s slots.
    serving: Vec<usize>,
}

#[derive(Debug, Clone)]
struct StuckInfo {
    onset: u64,
    array: usize,
    apply: StuckApply,
}

/// The installed fault layer: static plan + lazily evaluated health
/// timeline. Lives inside [`super::pool::CimArrayPool`].
#[derive(Debug, Clone)]
pub(crate) struct FaultLayer {
    plan: FaultPlan,
    topology: Topology,
    phases: usize,
    group_size: usize,
    n_groups: usize,
    stuck: Vec<StuckInfo>,
    /// Per plan fault: onset reached and counted as injected.
    applied: Vec<bool>,
    ledger: HealthLedger,
    epochs: Vec<Epoch>,
    next_probe: u64,
    stats: FaultStats,
}

impl FaultLayer {
    /// Validate the plan against the pool geometry, capture the
    /// programmed polarity of every stuck cell, and start the health
    /// timeline at the pristine schedule.
    pub(crate) fn install(
        plan: FaultPlan,
        arrays: &[Crossbar],
        topology: &Topology,
        phases: usize,
    ) -> Result<Self, String> {
        plan.validate()?;
        let n_groups = topology.groups().len();
        let rows = arrays.first().map_or(0, |a| a.rows());
        let cols = arrays.first().map_or(0, |a| a.cols());
        plan.validate_for(arrays.len(), n_groups, rows, cols)?;
        let stuck = plan
            .faults
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::StuckCell { array, row, col, plus } => Some(StuckInfo {
                    onset: f.onset,
                    array,
                    apply: StuckApply {
                        row,
                        col,
                        plus,
                        orig: arrays[array].matrix().get(row, col) > 0,
                    },
                }),
                _ => None,
            })
            .collect();
        let applied = vec![false; plan.faults.len()];
        let epochs = vec![Epoch {
            from_slot: 0,
            schedule: InterleaveSchedule::build(topology, phases),
            serving: (0..n_groups).collect(),
        }];
        Ok(FaultLayer {
            ledger: HealthLedger::new(n_groups, arrays.len()),
            topology: topology.clone(),
            phases,
            group_size: topology.mode().group_size(),
            n_groups,
            stuck,
            applied,
            epochs,
            next_probe: 0,
            stats: FaultStats::default(),
            plan,
        })
    }

    /// Running blast-radius counters.
    pub(crate) fn stats(&self) -> FaultStats {
        self.stats
    }

    /// The health ledger (latest evaluated probe state).
    pub(crate) fn ledger(&self) -> &HealthLedger {
        &self.ledger
    }

    /// Advance the health timeline to `slot` and resolve the dispatch
    /// context for the slot's coupling group. Called on the coordinator
    /// for every dispatch, in submission order; all returned effects
    /// are pure functions of `slot`, so submission order cannot change
    /// any outcome.
    pub(crate) fn on_dispatch(&mut self, slot: u64, converters: &mut [AnyAdc]) -> Resolution {
        self.advance_probes(slot, converters);
        self.count_activations(slot);
        let g = (slot as usize) % self.n_groups;
        let phase = ((slot as usize) / self.n_groups) % self.phases;
        let e = self.epoch_for(slot);
        let serving = self.epochs[e].serving[g];
        let pristine =
            self.computer_for(0, phase, serving).expect("pristine schedule covers every group");
        let (computer, orphaned) = match self.computer_for(e, phase, serving) {
            Some(c) => (c, false),
            // Every array of every group is quarantined: fall back to
            // the pristine computer and zero its MAVs.
            None => (pristine, true),
        };
        let computer_down = orphaned || self.down_active(computer, slot);
        let mut fault = SlotFault { computer_down, ..SlotFault::default() };
        if self.ledger.converters[serving].quarantined_for(slot) {
            fault.reroute = true;
        } else {
            let (dead, drift) = self.converter_faults(serving, slot);
            fault.dead = dead;
            fault.drift = if dead { None } else { drift };
        }
        // Degraded when any converter/array effect is live, the group
        // was remapped, or a health epoch moved the compute role off
        // the pristine schedule's array.
        if fault.any() || serving != g || computer != pristine {
            self.stats.degraded_planes += 1;
        }
        let stuck: Vec<StuckApply> = self
            .stuck
            .iter()
            .filter(|s| s.array == computer && slot >= s.onset)
            .map(|s| s.apply)
            .collect();
        Resolution { group: serving, computer, fault, stuck }
    }

    /// Fold one dispatch's lane-side outcome back into the counters:
    /// conversions that ran rerouted, and MAV sanity-bound excursions.
    pub(crate) fn record_outcome(&mut self, fault: &SlotFault, conversions: u64, oob: u64) {
        if fault.reroute {
            self.stats.conversions_rerouted += conversions;
        }
        self.stats.mav_out_of_bounds += oob;
    }

    fn advance_probes(&mut self, slot: u64, converters: &mut [AnyAdc]) {
        if self.plan.probe_interval == 0 {
            return;
        }
        while self.next_probe <= slot {
            let p = self.next_probe;
            self.probe_round(p, converters);
            self.next_probe += self.plan.probe_interval;
        }
    }

    /// One probe round at probe slot `p`: every non-quarantined
    /// converter digitizes the known mid-bin voltage (under whatever
    /// faults are active at `p`), every non-quarantined array answers a
    /// liveness ping, and debounced failures latch quarantines dated at
    /// `p`. An array transition rebuilds the degraded schedule epoch.
    fn probe_round(&mut self, p: u64, converters: &mut [AnyAdc]) {
        let units = (self.n_groups + self.topology.n_arrays()) as u64;
        for (g, adc) in converters.iter_mut().enumerate().take(self.n_groups) {
            if self.ledger.converters[g].quarantined_at.is_some() {
                continue;
            }
            let vdd = adc.vdd();
            let mut v = probe_voltage(vdd, adc.bits());
            let expected = adc.ideal_code(v);
            let (dead, drift) = self.converter_faults(g, p);
            if dead {
                v = 0.0;
            } else if let Some((gain, offset)) = drift {
                v = drifted(v, gain, offset, vdd).0;
            }
            let mut rng = Rng::for_stream(self.plan.seed ^ PROBE_SEED_SALT, p * units + g as u64);
            let code = adc.convert(v, &mut rng).code;
            let ok = code.abs_diff(expected) <= self.plan.probe_tolerance;
            self.stats.probes_run += 1;
            if !ok {
                self.stats.probes_failed += 1;
            }
            if self.ledger.converters[g].note(ok, self.plan.probe_debounce, p) {
                self.stats.quarantined += 1;
            }
        }
        let mut rebuilt = false;
        for a in 0..self.topology.n_arrays() {
            if self.ledger.arrays[a].quarantined_at.is_some() {
                continue;
            }
            let ok = !self.down_active(a, p);
            self.stats.probes_run += 1;
            if !ok {
                self.stats.probes_failed += 1;
            }
            if self.ledger.arrays[a].note(ok, self.plan.probe_debounce, p) {
                self.stats.quarantined += 1;
                rebuilt = true;
            }
        }
        if rebuilt {
            self.push_epoch(p);
        }
    }

    /// Record a new health epoch at probe slot `p` from the current
    /// set of quarantined arrays.
    fn push_epoch(&mut self, p: u64) {
        let down: Vec<bool> =
            self.ledger.arrays.iter().map(|u| u.quarantined_at.is_some()).collect();
        let schedule = InterleaveSchedule::build_degraded(&self.topology, self.phases, &down);
        let groups = self.topology.groups();
        let healthy: Vec<bool> =
            groups.iter().map(|g| g.iter().any(|&a| !down[a])).collect();
        let serving = (0..self.n_groups)
            .map(|g| {
                if healthy[g] {
                    g
                } else {
                    (1..self.n_groups)
                        .map(|k| (g + k) % self.n_groups)
                        .find(|&h| healthy[h])
                        .unwrap_or(g)
                }
            })
            .collect();
        self.epochs.push(Epoch { from_slot: p, schedule, serving });
    }

    fn count_activations(&mut self, slot: u64) {
        for (i, f) in self.plan.faults.iter().enumerate() {
            if self.applied[i] || slot < f.onset {
                continue;
            }
            self.applied[i] = true;
            self.stats.faults_injected += 1;
            match f.kind {
                FaultKind::StuckCell { .. } => self.stats.stuck_cells += 1,
                FaultKind::ConverterDrift { .. } => self.stats.converters_drifting += 1,
                FaultKind::ConverterDead { .. } => self.stats.converters_dead += 1,
                FaultKind::ArrayDown { .. } => self.stats.arrays_down += 1,
            }
        }
    }

    /// Latest epoch in force at `slot`.
    fn epoch_for(&self, slot: u64) -> usize {
        self.epochs.iter().rposition(|e| e.from_slot <= slot).expect("epoch 0 covers slot 0")
    }

    /// The compute-role member of `group` in epoch `e` at `phase`.
    fn computer_for(&self, e: usize, phase: usize, group: usize) -> Option<usize> {
        let base = group * self.group_size;
        (base..base + self.group_size)
            .find(|&a| self.epochs[e].schedule.role(phase, a) == Role::Compute)
    }

    /// Is an [`FaultKind::ArrayDown`] fault on `array` active at `slot`?
    fn down_active(&self, array: usize, slot: u64) -> bool {
        self.plan.faults.iter().any(|f| {
            slot >= f.onset && matches!(f.kind, FaultKind::ArrayDown { array: a } if a == array)
        })
    }

    /// Active converter faults on `group` at `slot`: dead flag plus the
    /// composition of every active drift, folded in plan order
    /// (`v → gain·v + offset·vdd` each).
    fn converter_faults(&self, group: usize, slot: u64) -> (bool, Option<(f64, f64)>) {
        let mut dead = false;
        let mut drift: Option<(f64, f64)> = None;
        for f in &self.plan.faults {
            if slot < f.onset {
                continue;
            }
            match f.kind {
                FaultKind::ConverterDead { group: g } if g == group => dead = true,
                FaultKind::ConverterDrift { group: g, gain, offset } if g == group => {
                    let (pg, po) = drift.unwrap_or((1.0, 0.0));
                    drift = Some((gain * pg, gain * po + offset));
                }
                _ => {}
            }
        }
        (dead, drift)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_kind() {
        let p = FaultPlan::parse("stuck@2=0,3,17,+; drift@0=1,1.5,-0.25; dead@4=0; down@1=2")
            .unwrap();
        assert_eq!(p.faults.len(), 4);
        assert_eq!(
            p.faults[0],
            Fault { onset: 2, kind: FaultKind::StuckCell { array: 0, row: 3, col: 17, plus: true } }
        );
        assert_eq!(
            p.faults[1],
            Fault {
                onset: 0,
                kind: FaultKind::ConverterDrift { group: 1, gain: 1.5, offset: -0.25 }
            }
        );
        assert_eq!(p.faults[2], Fault { onset: 4, kind: FaultKind::ConverterDead { group: 0 } });
        assert_eq!(p.faults[3], Fault { onset: 1, kind: FaultKind::ArrayDown { array: 2 } });
        assert!(FaultPlan::parse("").unwrap().faults.is_empty());
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "stuck@2=0,3,17",         // missing sign
            "stuck@2=0,3,17,x",       // bad sign
            "drift@=0,1.0,0.0",       // empty onset
            "drift@0=0,nan,0.0",      // non-finite gain fails validate
            "wobble@0=1",             // unknown kind
            "dead@0",                 // missing '='
            "down=3",                 // missing '@SLOT'
            "drift@0=0,9.0,0.0",      // gain out of range
            "drift@0=0,1.0,2.0",      // offset out of range
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn stats_invariant_and_merge_minus() {
        let mut a = FaultStats {
            faults_injected: 3,
            stuck_cells: 1,
            converters_drifting: 1,
            converters_dead: 0,
            arrays_down: 1,
            probes_run: 10,
            probes_failed: 4,
            quarantined: 1,
            degraded_planes: 7,
            conversions_rerouted: 64,
            mav_out_of_bounds: 2,
        };
        assert_eq!(a.injected_by_type(), a.faults_injected);
        let b = a;
        a.merge(&b);
        assert_eq!(a.minus(&b), b);
        assert_eq!(a.injected_by_type(), a.faults_injected);
        assert!(FaultStats::default().is_zero());
        assert!(!a.is_zero());
    }

    #[test]
    fn debounce_latches_after_consecutive_failures_only() {
        let mut u = UnitHealth::default();
        assert!(!u.note(false, 3, 0));
        assert_eq!(u.status(), HealthStatus::Suspect(1));
        assert!(!u.note(true, 3, 2)); // success resets the streak
        assert_eq!(u.status(), HealthStatus::Healthy);
        assert!(!u.note(false, 3, 4));
        assert!(!u.note(false, 3, 6));
        assert!(u.note(false, 3, 8));
        assert_eq!(u.status(), HealthStatus::Quarantined);
        assert!(u.quarantined_for(8) && !u.quarantined_for(7));
        // Already-quarantined units never transition again.
        assert!(!u.note(false, 3, 10));
    }
}
