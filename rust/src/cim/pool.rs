//! Collaborative digitization pool: the serving-path fabric that turns
//! crossbar MAVs into codes (paper §IV, Figs 9/11).
//!
//! A [`CimArrayPool`] owns N identically-programmed crossbar arrays, a
//! [`Topology`] describing how they couple, and an [`InterleaveSchedule`]
//! assigning each array a per-phase role: **compute** an in-memory scalar
//! product, or **digitize** a neighbour's multiply-average voltage by
//! lending its column lines as the capacitive DAC of a memory-immersed
//! converter ([`crate::adc::ImmersedAdc`]). This is the paper's second
//! contribution made a first-class inference stage: the multi-bit MAVs
//! from [`Crossbar::compute_mav_into`] flow through the neighbour array
//! instead of a dedicated ADC, and [`super::BitplaneEngine`] reassembles
//! the digitized planes into near-exact transform outputs (vs the 1-bit
//! ADC-free default path).
//!
//! Coupling groups are mutually independent — disjoint arrays, disjoint
//! converters — which is what [`CimArrayPool::process_planes`] exploits:
//! submitted planes queue onto per-group lanes that fan across the
//! pool's persistent worker runtime ([`crate::util::Executor`]; shared
//! with the engine's batch shards when serving, lazily built otherwise
//! — thread spawn is paid once per pool lifetime, never per call), with
//! per-plane deterministic noise streams (`Rng::for_stream`) and
//! submission-order stat merging so results are identical at any thread
//! count (the same contract as `AnalogEngine::infer_batch` sharding).
//! [`CimArrayPool::process_plane_requests`] is the fused-batch form of
//! the same dispatch: every plane carries its own cursor slot, stream
//! seed and gating mask, and the per-plane accounting is returned to
//! the caller instead of applied, so cross-sample fusion can replay the
//! sequential walk's accounting order exactly.
//!
//! **Runtime invariants** — enforced on the live data path, not just in
//! `network::schedule::validate`:
//!
//! 1. *No array computes and digitizes in the same phase.* Every phase
//!    dispatch re-derives the group's roles from the schedule and
//!    asserts exactly one computer whose partners all hold the digitize
//!    role ([`CimArrayPool::process_plane`] / `process_planes`).
//! 2. *Every computed MAV is digitized exactly once — or explicitly
//!    gated.* The batched plane tasks make this structural (one pass
//!    that either converts or gates each row), and the public per-plane
//!    ledger ([`CimArrayPool::begin_plane`] / [`CimArrayPool::digitize_row`] /
//!    [`CimArrayPool::gate_row`] / [`CimArrayPool::end_plane`]) panics on
//!    a double conversion and on any row left unaccounted when the phase
//!    closes. Gated rows are the per-row conversion-gating path: early
//!    termination already pruned the row, so the converter never fires
//!    for it and the saved work is counted in [`ConversionStats::gated`].
//!
//! Per-conversion energy/cycles/comparisons accumulate in
//! [`ConversionStats`] and thread up through the engines into
//! [`crate::coordinator::Metrics`].
//!
//! A deterministic analog fault-injection and self-healing layer
//! ([`super::fault`]) installs via [`CimArrayPool::set_fault_plan`]:
//! each dispatch then resolves stuck cells, converter drift/death,
//! array loss, calibration probes and quarantine reroutes as pure
//! functions of its plane slot, so faulty runs remain bit-identical at
//! any thread count, fused or sequential. Without a plan the layer is
//! fully inert — the dispatch paths run the exact pre-fault code.

use std::sync::Arc;

use crate::adc::{drifted, Adc, AnyAdc, AsymmetricAdc, Conversion, ImmersedAdc, ImmersedMode};
use crate::network::{CouplingMode, InterleaveSchedule, Role, Topology};
use crate::util::{Executor, Rng};

use super::bitvec::{BitVec, SignMatrix};
use super::crossbar::{Crossbar, CrossbarConfig};
use super::fault::{
    FaultLayer, FaultPlan, FaultStats, HealthLedger, Resolution, SlotFault, StuckApply,
};

/// Pool shape: how many arrays, what converter networking, how many
/// output bits, whether the Fig 10 asymmetric comparison tree drives
/// the SAR references, and how many worker threads `process_planes`
/// fans coupling groups across. `Copy` so it rides inside `BwhtExec`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSpec {
    /// CiM arrays in the pool (the fabricated chip has 4).
    pub n_arrays: usize,
    /// Converter resolution; needs `cols ≥ 2^adc_bits` column lines.
    pub adc_bits: u8,
    /// Collaborative networking mode (Sar / Flash / Hybrid).
    pub mode: ImmersedMode,
    /// Drive SAR references with the MAV-statistics comparison tree.
    pub asymmetric: bool,
    /// Worker threads for [`CimArrayPool::process_planes`]: 1 runs the
    /// fan-out inline (the default), 0 auto-detects, N caps the
    /// persistent workers. Results are thread-count invariant.
    pub threads: usize,
    /// Plane fusion (`adcim serve --fuse-batch`): consumers collect
    /// same-shape bitplanes from several transforms into shared pooled
    /// submissions instead of draining the pool per transform —
    /// [`crate::cim::BitplaneEngine::transform_batch`] fuses across
    /// *samples*; the serving path (`nn::BwhtLayer`, which forwards one
    /// sample at a time) fuses across the sample's Hadamard *blocks*.
    /// Bit-identical outputs and accounting to the sequential walk
    /// either way (`tests/executor_fusion.rs`).
    pub fuse_batch: bool,
}

impl PoolSpec {
    /// The fabricated test chip of Fig 11: four arrays. Resolution per
    /// mode is bounded by the hardware — flash needs `2^bits − 1`
    /// neighbour arrays, so 4 arrays cap flash at 2 bits; SAR and hybrid
    /// run the paper's 5 bits.
    pub fn fig11(mode: ImmersedMode) -> Self {
        let adc_bits = if matches!(mode, ImmersedMode::Flash) { 2 } else { 5 };
        PoolSpec { n_arrays: 4, adc_bits, mode, asymmetric: false, threads: 1, fuse_batch: false }
    }

    /// Parse CLI/config inputs; `Ok(None)` when `n_arrays == 0` (no
    /// pool: the ADC-free 1-bit default path). `adc_bits == 0`
    /// auto-selects per mode (flash 2, otherwise 5). Unknown mode
    /// strings and infeasible (mode, bits, arrays) combinations are
    /// errors, not silent fallbacks. The parsed spec runs sequentially
    /// (`threads == 1`); callers plumb their thread knob with a struct
    /// update.
    pub fn parse(
        n_arrays: usize,
        mode: &str,
        adc_bits: u8,
        asymmetric: bool,
    ) -> Result<Option<Self>, String> {
        if n_arrays == 0 {
            return Ok(None);
        }
        let mode = match mode {
            "sar" => ImmersedMode::Sar,
            "flash" => ImmersedMode::Flash,
            "hybrid" => ImmersedMode::Hybrid { flash_bits: 2 },
            other => {
                return Err(format!("unknown adc mode '{other}' (expected sar|flash|hybrid)"))
            }
        };
        let adc_bits = if adc_bits > 0 {
            adc_bits
        } else if matches!(mode, ImmersedMode::Flash) {
            2
        } else {
            5
        };
        let spec =
            PoolSpec { n_arrays, adc_bits, mode, asymmetric, threads: 1, fuse_batch: false };
        spec.validate()?;
        Ok(Some(spec))
    }

    /// Feasibility of this converter on this pool shape — the checks
    /// that would otherwise surface as assertion panics deep inside
    /// pool construction. (Column-line count vs `adc_bits` depends on
    /// the programmed matrix and is still checked at construction.)
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=10).contains(&self.adc_bits) {
            return Err(format!("adc_bits {} outside the supported 1..=10", self.adc_bits));
        }
        // Upper bound catches nonsense sizes — including negative TOML
        // values that wrapped through an integer cast — before pool
        // construction tries to fabricate that many arrays.
        if self.n_arrays > 4096 {
            return Err(format!(
                "n_arrays {} exceeds the supported maximum of 4096 (negative config value?)",
                self.n_arrays
            ));
        }
        if let ImmersedMode::Hybrid { flash_bits } = self.mode {
            if flash_bits >= self.adc_bits {
                return Err(format!(
                    "hybrid flash stage ({flash_bits} bits) must be narrower than adc_bits {}",
                    self.adc_bits
                ));
            }
        }
        if self.asymmetric && !matches!(self.mode, ImmersedMode::Sar) {
            return Err("the asymmetric comparison tree requires sar mode".to_string());
        }
        let group = CouplingMode::for_adc_mode(self.mode, self.adc_bits).group_size();
        if self.n_arrays < group {
            return Err(format!(
                "{:?} at {} bits needs a coupling group of {group} arrays; pool has {}",
                self.mode, self.adc_bits, self.n_arrays
            ));
        }
        Ok(())
    }
}

/// Accumulated per-conversion accounting: how much digitization work
/// (and energy) the collaborative fabric spent — and how much per-row
/// conversion gating avoided. Threaded from the pool through
/// `BitplaneOutput` and `BwhtLayer` into `AnalogEngine` and the
/// coordinator's `MetricsSnapshot`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ConversionStats {
    /// MAV→code conversions performed.
    pub conversions: u64,
    /// Comparator decisions across all conversions.
    pub comparisons: u64,
    /// Conversion clock cycles (mode-dependent; flash = 1/conversion).
    pub cycles: u64,
    /// Conversion energy (fJ): reference generation + comparators.
    pub energy_fj: f64,
    /// Row conversions skipped by per-row gating: early termination had
    /// already deactivated the row, so the converter never fired for it
    /// (no comparisons, no cycles, no energy — the ET savings the ADC
    /// energy column sees).
    pub gated: u64,
}

impl ConversionStats {
    /// Fold one conversion into the running totals.
    pub fn record(&mut self, c: &Conversion) {
        self.conversions += 1;
        self.comparisons += c.comparisons as u64;
        self.cycles += c.cycles as u64;
        self.energy_fj += c.energy_fj;
    }

    /// Fold another accumulator into this one (shard merges, signed
    /// two-pass transforms).
    pub fn merge(&mut self, other: &ConversionStats) {
        self.conversions += other.conversions;
        self.comparisons += other.comparisons;
        self.cycles += other.cycles;
        self.energy_fj += other.energy_fj;
        self.gated += other.gated;
    }

    /// Delta of two snapshots of a monotone counter (`self` later).
    pub fn minus(&self, base: &ConversionStats) -> ConversionStats {
        ConversionStats {
            conversions: self.conversions - base.conversions,
            comparisons: self.comparisons - base.comparisons,
            cycles: self.cycles - base.cycles,
            energy_fj: (self.energy_fj - base.energy_fj).max(0.0),
            gated: self.gated - base.gated,
        }
    }

    /// Average comparator decisions per conversion (the Fig 10 axis).
    pub fn comparisons_per_conversion(&self) -> f64 {
        if self.conversions == 0 {
            0.0
        } else {
            self.comparisons as f64 / self.conversions as f64
        }
    }
}

/// Ledger states for the public begin/digitize/end API.
const ROW_PENDING: u8 = 0;
const ROW_CONVERTED: u8 = 1;
const ROW_GATED: u8 = 2;

/// Digitize one MAV through `adc` and decode the code back to a
/// signed-sum estimate. Shared by the sequential ledger API
/// ([`CimArrayPool::digitize_row`]) and the batched plane tasks.
///
/// The comparator input is offset by half a charge count: the
/// crossbar's discrete MAV levels otherwise sit exactly on the
/// converter's ideal transition levels (both are `k/cols` grids when
/// `2^bits == cols`), where real hardware breaks ties with noise.
/// Centring each level in its code bin keeps the behavioural model
/// exact and noise-robust. Decoding inverts the floor quantizer at
/// the bin's expected charge count, so the aligned ideal case
/// recovers the exact `plus` count.
fn decode_mav(
    per_count: f64,
    adc: &mut AnyAdc,
    v_mav: f64,
    ones: f64,
    rng: &mut Rng,
) -> (f64, Conversion) {
    let n_codes = (1u64 << adc.bits()) as f64;
    let vdd = adc.vdd();
    let c = adc.convert(v_mav + 0.5 * per_count, rng);
    // Charge counts per code step; 1.0 in the aligned ideal case.
    let bin_counts = vdd / (n_codes * per_count);
    let plus_hat = (c.code as f64 * bin_counts + 0.5 * (bin_counts - 1.0).max(0.0)).min(ones);
    (2.0 * plus_hat - ones, c)
}

/// One scheduled plane on one coupling group, against disjoint borrows
/// of the group's state — the per-group unit [`CimArrayPool::process_planes`]
/// fans across scoped threads. The compute-role array runs crossbar
/// steps 1–3 (raw MAVs) and the group's converter digitizes every
/// *active* row; rows the `active` mask has pruned are gated (their
/// slot reads 0.0, never consumed — the walk skips them). Exactly-once
/// is structural here: the single pass converts or gates each row.
///
/// `fault` is the slot's resolved fault context (all-default when the
/// fault layer is uninstalled, in which case the arithmetic below is
/// exactly the pre-fault-layer path): a down computer skips the crossbar
/// op and reads 0 V MAVs; a dead converter reads 0 V inputs; drift maps
/// each MAV through `gain·v + offset·vdd` (rail-clamped, excursions
/// counted in the returned out-of-bounds tally); reroute digitizes via
/// the healthy fallback path at one extra cycle per conversion.
#[allow(clippy::too_many_arguments)]
fn run_plane_task(
    computer: &mut Crossbar,
    adc: &mut AnyAdc,
    mavs: &mut Vec<f64>,
    plane: &BitVec,
    active: Option<&[bool]>,
    fault: SlotFault,
    rng: &mut Rng,
    out: &mut [f64],
) -> (ConversionStats, u64) {
    let rows = computer.rows();
    debug_assert_eq!(out.len(), rows);
    mavs.resize(rows, 0.0);
    if fault.computer_down {
        mavs.fill(0.0);
    } else {
        computer.compute_mav_into(plane, rng, mavs);
    }
    let ones = plane.count_ones() as f64;
    let per_count = computer.mav_volts_per_count();
    let vdd = adc.vdd();
    let mut stats = ConversionStats::default();
    let mut oob = 0u64;
    for (r, slot) in out.iter_mut().enumerate() {
        if active.is_some_and(|m| !m[r]) {
            // Per-row conversion gating (ISSUE 3): the schedule skips
            // the conversion the hardware would never fire.
            *slot = 0.0;
            stats.gated += 1;
            continue;
        }
        let v_row = if fault.dead {
            0.0
        } else if let Some((gain, offset)) = fault.drift {
            let (v, excursion) = drifted(mavs[r], gain, offset, vdd);
            oob += u64::from(excursion);
            v
        } else {
            mavs[r]
        };
        let (v, mut c) = decode_mav(per_count, adc, v_row, ones, rng);
        if fault.reroute {
            c.cycles += 1;
        }
        *slot = v;
        stats.record(&c);
    }
    (stats, oob)
}

/// One fully-described plane dispatch — the unit of the fused batch
/// entry point [`CimArrayPool::process_plane_requests`]. Unlike
/// [`CimArrayPool::process_planes`] (which assigns consecutive cursor
/// slots and shares one seed/mask across the call), every request pins
/// its own slot, noise stream and gating mask, so a caller can replay
/// *exactly* the dispatches an arbitrary interleaving of sequential
/// transforms would have made — the contract cross-sample plane fusion
/// is built on.
pub struct PlaneRequest<'a> {
    /// Cursor slot this plane occupies: the same (group, phase,
    /// computer) derivation as the `slot`-th `process_plane` call after
    /// a [`CimArrayPool::begin_transform`].
    pub slot: usize,
    /// Noise-stream seed; the plane's analog noise is drawn from
    /// `Rng::for_stream(seed, stream)`.
    pub seed: u64,
    /// Noise sub-stream selector (sample x plane unique).
    pub stream: u64,
    /// The input bitplane this lane computes MAVs for.
    pub plane: &'a BitVec,
    /// Per-row conversion-gating mask (rows early termination pruned).
    pub active: Option<&'a [bool]>,
    /// Decoded signed sums, one per row.
    pub out: &'a mut [f64],
}

/// One plane bound for one coupling group.
struct PlaneJob<'a> {
    /// Submission index — accounting merges in this order.
    idx: usize,
    /// Compute-role array's offset inside the group's array block.
    computer: usize,
    seed: u64,
    stream: u64,
    plane: &'a BitVec,
    active: Option<&'a [bool]>,
    /// Resolved fault context for this slot (default when no plan).
    fault: SlotFault,
    /// Stuck cells applied to the computer around this job and
    /// reverted after — scoped per dispatch so effects stay a pure
    /// function of the slot under any lane interleaving.
    stuck: Vec<StuckApply>,
    out: &'a mut [f64],
}

/// A coupling group's worth of a batched dispatch: the group's disjoint
/// pool state (contiguous array block, converter, MAV scratch) plus its
/// ordered queue of plane jobs. Lanes share no state, so they are the
/// unit submitted to the persistent worker runtime — the executor's
/// threads were spawned at pool/engine construction, so the per-call
/// cost is a channel send, not a `thread::spawn`.
struct GroupLane<'a> {
    group: &'a mut [Crossbar],
    adc: &'a mut AnyAdc,
    mavs: &'a mut Vec<f64>,
    jobs: Vec<PlaneJob<'a>>,
}

impl GroupLane<'_> {
    /// Run this lane's jobs in submission order — the only ordering
    /// that matters, since jobs in different lanes share no state.
    /// Returns `(idx, stats, out_of_bounds)` per job.
    fn run(self) -> Vec<(usize, ConversionStats, u64)> {
        let GroupLane { group, adc, mavs, jobs } = self;
        jobs.into_iter()
            .map(|job| {
                let mut rng = Rng::for_stream(job.seed, job.stream);
                let computer = &mut group[job.computer];
                for s in &job.stuck {
                    computer.set_weight(s.row, s.col, s.plus);
                }
                let (stats, oob) = run_plane_task(
                    computer,
                    adc,
                    mavs,
                    job.plane,
                    job.active,
                    job.fault,
                    &mut rng,
                    job.out,
                );
                for s in &job.stuck {
                    computer.set_weight(s.row, s.col, s.orig);
                }
                (job.idx, stats, oob)
            })
            .collect()
    }
}

/// A scheduled pool of collaborating CiM arrays (see module docs).
#[derive(Debug, Clone)]
pub struct CimArrayPool {
    arrays: Vec<Crossbar>,
    topology: Topology,
    schedule: InterleaveSchedule,
    /// Complete coupling groups, precomputed (hot path: no re-derivation).
    /// Group `g` owns the contiguous arrays `g·size .. (g+1)·size` —
    /// asserted at construction; the batched fan-out splits on it.
    groups: Vec<Vec<usize>>,
    /// One converter per coupling group (the digitize-role partners'
    /// column lines form its capacitive DAC).
    converters: Vec<AnyAdc>,
    spec: PoolSpec,
    /// Digitize-role partners expected per group per phase.
    expected_refs: usize,
    /// Dispatch cursor: group = cursor % groups, phase advances once per
    /// full rotation. Reset by [`CimArrayPool::begin_transform`].
    cursor: usize,
    stats: ConversionStats,
    mavs_produced: u64,
    mavs_digitized: u64,
    mavs_gated: u64,
    /// Planes dispatched through any path (telemetry counter, folded
    /// at the same submission-order merge points as `stats`).
    planes_dispatched: u64,
    /// Planes submitted through the fused deferred-accounting path
    /// ([`CimArrayPool::process_plane_requests`]) — the cross-sample
    /// fusion share of `planes_dispatched`.
    planes_fused: u64,
    /// Per-plane ledger for the public begin/digitize/end API.
    converted: Vec<u8>,
    plane_open: bool,
    /// Per-group MAV scratch, reused across planes and transforms.
    group_scratch: Vec<Vec<f64>>,
    /// Persistent worker runtime for the batched plane fan-out. Shared
    /// with the serving engine when injected ([`CimArrayPool::set_executor`]
    /// — one runtime for batch shards *and* pool lanes, so
    /// `engine_threads × pool_threads` never oversubscribes), lazily
    /// built at first parallel use otherwise. Cloned pools (worker-shard
    /// model clones) share the same runtime through the `Arc`.
    executor: Option<Arc<Executor>>,
    /// Installed fault-injection/self-healing layer
    /// ([`CimArrayPool::set_fault_plan`]); `None` leaves every dispatch
    /// path exactly as fault-free (the inert guarantee).
    fault: Option<FaultLayer>,
}

impl CimArrayPool {
    /// Fabricate a pool: `spec.n_arrays` crossbars all programmed with
    /// `matrix` at `cfg` (per-array comparator/noise sampling from
    /// `rng`), coupled per `spec.mode`, with one immersed converter per
    /// coupling group whose DAC units are the arrays' `cols` column
    /// lines.
    pub fn new(matrix: &SignMatrix, cfg: CrossbarConfig, spec: PoolSpec, rng: &mut Rng) -> Self {
        if let Err(e) = spec.validate() {
            panic!("invalid pool spec: {e}");
        }
        let cols = matrix.cols();
        assert!(
            cols >= (1usize << spec.adc_bits),
            "pool needs >= 2^adc_bits column lines per array ({} < {})",
            cols,
            1usize << spec.adc_bits
        );
        let coupling = CouplingMode::for_adc_mode(spec.mode, spec.adc_bits);
        let topology = Topology::new(spec.n_arrays, coupling);
        let schedule = InterleaveSchedule::build(&topology, 2 * coupling.group_size());
        schedule.validate(&topology).expect("interleave schedule invalid");
        let groups = topology.groups();
        assert!(!groups.is_empty(), "pool has no complete coupling group");
        let size = coupling.group_size();
        for (g, grp) in groups.iter().enumerate() {
            assert!(
                grp.iter().enumerate().all(|(j, &a)| a == g * size + j),
                "coupling group {g} is not the contiguous block {:?}",
                (g * size..(g + 1) * size)
            );
        }
        let arrays: Vec<Crossbar> =
            (0..spec.n_arrays).map(|_| Crossbar::new(matrix.clone(), cfg, rng)).collect();
        let vdd = cfg.op.vdd;
        // Each DAC unit is one partner-array *column* line spanning
        // `rows` cells at `c_cell_ff` each — a different line from the
        // row-merge sum line (`cols` cells) the crossbar's kT/C model
        // uses, but the same per-cell capacitance, so conversion and
        // compute energy share one parameter (the fabricated 16-row
        // array at 1.2 fF/cell gives the ~20 fF PR 2 hardcoded).
        let c_line_ff = matrix.rows() as f64 * cfg.c_cell_ff;
        let converters: Vec<AnyAdc> = groups
            .iter()
            .map(|_| {
                let adc = ImmersedAdc::sample(
                    spec.adc_bits,
                    vdd,
                    spec.mode,
                    cols,
                    c_line_ff,
                    &cfg.noise,
                    rng,
                );
                if spec.asymmetric {
                    AnyAdc::Asymmetric(AsymmetricAdc::for_mav(adc, cols, 0.5))
                } else {
                    AnyAdc::Immersed(adc)
                }
            })
            .collect();
        let group_scratch = vec![Vec::new(); groups.len()];
        CimArrayPool {
            arrays,
            expected_refs: coupling.group_size() - 1,
            topology,
            schedule,
            groups,
            converters,
            spec,
            cursor: 0,
            stats: ConversionStats::default(),
            mavs_produced: 0,
            mavs_digitized: 0,
            mavs_gated: 0,
            planes_dispatched: 0,
            planes_fused: 0,
            converted: Vec::new(),
            plane_open: false,
            group_scratch,
            executor: None,
            fault: None,
        }
    }

    /// The spec the pool was built from.
    pub fn spec(&self) -> PoolSpec {
        self.spec
    }

    /// Override the `process_planes` worker-thread count after
    /// construction (0 = auto, 1 = inline sequential). Does not resize
    /// an already-built runtime; pair with [`CimArrayPool::set_executor`]
    /// to swap one in.
    pub fn set_threads(&mut self, threads: usize) {
        self.spec.threads = threads;
    }

    /// Inject (or clear) the persistent worker runtime the plane
    /// fan-out submits to. The serving engine injects its own executor
    /// here so batch shards and pool lanes share one set of workers;
    /// standalone pools may leave it unset and a private runtime is
    /// built lazily at first parallel use. Results never depend on the
    /// runtime's width (submission-order merge).
    pub fn set_executor(&mut self, executor: Option<Arc<Executor>>) {
        self.executor = executor;
    }

    /// The runtime currently backing the parallel fan-out, if any.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        self.executor.as_ref()
    }

    /// Install (or clear, with `None`) a fault-injection plan. The plan
    /// is validated against the pool geometry before anything changes;
    /// on error the previous layer stays in place. With a plan
    /// installed every plane dispatch resolves its fault context from
    /// the pure per-slot clock (see [`super::fault`]); without one the
    /// dispatch paths are bit-identical to a build without this module.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) -> Result<(), String> {
        self.fault = match plan {
            None => None,
            Some(p) => Some(FaultLayer::install(
                p,
                &self.arrays,
                &self.topology,
                self.schedule.phases(),
            )?),
        };
        Ok(())
    }

    /// Blast-radius counters of the installed fault layer — all zero
    /// when no plan is installed (the inert signature telemetry keys
    /// off).
    pub fn fault_stats(&self) -> FaultStats {
        self.fault.as_ref().map(FaultLayer::stats).unwrap_or_default()
    }

    /// Health ledger of the installed fault layer (latest evaluated
    /// probe state), if any.
    pub fn health(&self) -> Option<&HealthLedger> {
        self.fault.as_ref().map(FaultLayer::ledger)
    }

    /// Resolve the fault context for one dispatch slot, if a plan is
    /// installed. Borrows the fault layer and the converters disjointly
    /// (probe rounds digitize through the live converters).
    fn resolve_slot(&mut self, slot: usize) -> Option<Resolution> {
        let CimArrayPool { fault, converters, .. } = self;
        fault.as_mut().map(|fl| fl.on_dispatch(slot as u64, converters))
    }

    /// Crossbar rows per array.
    pub fn rows(&self) -> usize {
        self.arrays[0].rows()
    }

    /// Crossbar columns per array.
    pub fn cols(&self) -> usize {
        self.arrays[0].cols()
    }

    /// Arrays in the pool.
    pub fn n_arrays(&self) -> usize {
        self.arrays.len()
    }

    /// Coupling groups (compute/digitize pairs or triples).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// The neighbour-coupling topology.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// The compute/digitize interleave schedule.
    pub fn schedule(&self) -> &InterleaveSchedule {
        &self.schedule
    }

    /// Read-only view of the arrays (ops/energy counters per array).
    pub fn arrays(&self) -> &[Crossbar] {
        &self.arrays
    }

    /// Accumulated conversion accounting since construction/reset.
    pub fn stats(&self) -> ConversionStats {
        self.stats
    }

    /// Zero the accumulated conversion statistics.
    pub fn reset_stats(&mut self) {
        self.stats = ConversionStats::default();
        self.mavs_produced = 0;
        self.mavs_digitized = 0;
        self.mavs_gated = 0;
        self.planes_dispatched = 0;
        self.planes_fused = 0;
    }

    /// MAVs produced by compute-role arrays so far.
    pub fn mavs_produced(&self) -> u64 {
        self.mavs_produced
    }

    /// MAVs digitized by the collaborative converters so far. Together
    /// with [`CimArrayPool::mavs_gated`] this accounts for every MAV
    /// produced whenever no plane is open — the exactly-once-or-gated
    /// invariant.
    pub fn mavs_digitized(&self) -> u64 {
        self.mavs_digitized
    }

    /// MAVs whose conversion was skipped by per-row gating (the rows
    /// early termination had already pruned).
    pub fn mavs_gated(&self) -> u64 {
        self.mavs_gated
    }

    /// Planes dispatched so far, through any path (telemetry counter).
    pub fn planes_dispatched(&self) -> u64 {
        self.planes_dispatched
    }

    /// Planes submitted through the fused deferred-accounting path so
    /// far — how much of [`CimArrayPool::planes_dispatched`] the
    /// cross-sample fusion (`--fuse-batch`) actually carried.
    pub fn planes_fused(&self) -> u64 {
        self.planes_fused
    }

    /// Total crossbar (compute-side) energy across the pool (fJ).
    pub fn crossbar_energy_fj(&self) -> f64 {
        self.arrays.iter().map(|a| a.energy_fj()).sum()
    }

    /// Rewind the dispatch cursor to phase 0 / group 0. Engines call
    /// this at the start of every transform so pooled results are a pure
    /// function of `(pool state at build, input, rng)` — the contract
    /// that keeps batched inference thread-count invariant.
    pub fn begin_transform(&mut self) {
        self.cursor = 0;
    }

    /// Re-derive the compute-role array of group `g` in `phase`,
    /// asserting the runtime role invariants (exactly one computer, all
    /// partners digitizing — an array never holds both roles at once).
    fn derive_computer(&self, phase: usize, g: usize) -> usize {
        let mut computer: Option<usize> = None;
        let mut refs = 0usize;
        for &a in &self.groups[g] {
            match self.schedule.role(phase, a) {
                Role::Compute => {
                    assert!(
                        computer.is_none(),
                        "phase {phase}: two compute roles in group {g}"
                    );
                    computer = Some(a);
                }
                Role::Digitize => refs += 1,
                Role::Idle => {}
            }
        }
        let computer =
            computer.unwrap_or_else(|| panic!("phase {phase}: no compute role in group {g}"));
        assert_eq!(
            refs, self.expected_refs,
            "phase {phase} group {g}: {refs} digitize partners, expected {}",
            self.expected_refs
        );
        computer
    }

    /// Fold one plane task's accounting into the pool totals. Always in
    /// plane-submission order, whatever ran the task — which is what
    /// makes the batched and sequential paths bit-identical (including
    /// `energy_fj` float accumulation order).
    fn apply_plane_result(&mut self, rows: u64, res: &ConversionStats) {
        self.mavs_produced += rows;
        self.mavs_digitized += res.conversions;
        self.mavs_gated += res.gated;
        self.planes_dispatched += 1;
        self.stats.merge(res);
    }

    /// Advance one cursor slot and run its plane on its coupling group,
    /// with an optional conversion-gating mask — the allocation-free
    /// core shared by [`CimArrayPool::process_plane`] and the gated
    /// per-plane serving path.
    fn dispatch_slot(
        &mut self,
        x: &BitVec,
        active: Option<&[bool]>,
        rng: &mut Rng,
        out: &mut [f64],
    ) {
        let rows = self.rows();
        assert_eq!(out.len(), rows, "output length != array rows");
        let n_groups = self.groups.len();
        let slot = self.cursor;
        self.cursor += 1;
        let size = self.topology.mode().group_size();
        // With a fault plan installed the layer resolves the slot's
        // serving group/computer (possibly remapped by a health epoch)
        // and effects; otherwise take the original schedule-only path.
        let (g, computer, fault, stuck) = match self.resolve_slot(slot) {
            Some(r) => (r.group, r.computer, r.fault, r.stuck),
            None => {
                let phase = (slot / n_groups) % self.schedule.phases();
                let g = slot % n_groups;
                (g, self.derive_computer(phase, g), SlotFault::default(), Vec::new())
            }
        };
        let local = computer - g * size;
        let group = &mut self.arrays[g * size..(g + 1) * size];
        let mut mavs = std::mem::take(&mut self.group_scratch[g]);
        let adc = &mut self.converters[g];
        for s in &stuck {
            group[local].set_weight(s.row, s.col, s.plus);
        }
        let (res, oob) =
            run_plane_task(&mut group[local], adc, &mut mavs, x, active, fault, rng, out);
        for s in &stuck {
            group[local].set_weight(s.row, s.col, s.orig);
        }
        self.group_scratch[g] = mavs;
        if let Some(fl) = self.fault.as_mut() {
            fl.record_outcome(&fault, res.conversions, oob);
        }
        self.apply_plane_result(rows as u64, &res);
    }

    /// One scheduled phase of one coupling group: the compute-role array
    /// runs crossbar steps 1–3 on plane `x` (raw MAVs), and the group's
    /// collaborative converter digitizes every row MAV exactly once.
    /// Writes the decoded signed sums (`2·plus − |x|` estimates, same
    /// units as [`Crossbar::ideal_bitplane`]) into `out`.
    pub fn process_plane(&mut self, x: &BitVec, rng: &mut Rng, out: &mut [f64]) {
        self.dispatch_slot(x, None, rng, out);
    }

    /// Single-plane form of [`CimArrayPool::process_planes`]: the same
    /// cursor slot, `Rng::for_stream(seed, stream)` noise and gating
    /// semantics, but none of the batch machinery — this is the
    /// early-termination walk's per-plane hot path, where the gating
    /// mask changes between planes and a 1-element batch would pay
    /// queue/lane allocations for nothing.
    pub fn process_plane_masked(
        &mut self,
        x: &BitVec,
        stream: u64,
        seed: u64,
        active: Option<&[bool]>,
        out: &mut [f64],
    ) {
        let mut rng = Rng::for_stream(seed, stream);
        self.dispatch_slot(x, active, &mut rng, out);
    }

    /// Batched plane dispatch: task `i` occupies the cursor slot the
    /// equivalent sequence of [`CimArrayPool::process_plane`] calls
    /// would have used and draws its analog noise from
    /// `Rng::for_stream(seed, streams[i])`. Planes are queued onto
    /// per-group *lanes* — disjoint arrays, disjoint converters, plane
    /// order preserved within each lane — and the lanes run on the
    /// pool's **persistent** worker runtime (`PoolSpec::threads` lanes;
    /// see [`CimArrayPool::set_executor`]), so the per-call cost is a
    /// channel send — thread spawn was paid once at runtime
    /// construction, not per call and not per interleave rotation.
    /// Outputs, counters and even the `energy_fj` accumulation order
    /// are identical at any thread count, because per-task accounting
    /// re-merges in submission order after the lanes drain.
    ///
    /// `active` is the per-row conversion-gating mask shared by every
    /// submitted plane: rows early termination has pruned are gated
    /// (no conversion fired, counted in [`ConversionStats::gated`]).
    /// `out` is plane-major, `planes.len() × rows`.
    pub fn process_planes(
        &mut self,
        planes: &[&BitVec],
        streams: &[u64],
        seed: u64,
        active: Option<&[bool]>,
        out: &mut [f64],
    ) {
        let rows = self.rows();
        assert_eq!(planes.len(), streams.len(), "planes/streams length mismatch");
        assert_eq!(out.len(), planes.len() * rows, "output length != planes x rows");
        if planes.is_empty() {
            return;
        }
        let cursor0 = self.cursor;
        self.cursor += planes.len();
        let requests: Vec<PlaneRequest<'_>> = out
            .chunks_mut(rows)
            .enumerate()
            .map(|(i, chunk)| PlaneRequest {
                slot: cursor0 + i,
                seed,
                stream: streams[i],
                plane: planes[i],
                active,
                out: chunk,
            })
            .collect();
        let ordered = self.run_requests(requests);
        for res in &ordered {
            self.apply_plane_result(rows as u64, res);
        }
    }

    /// Fused batch dispatch with **deferred accounting**: run every
    /// request (own slot, own noise stream, own gating mask — see
    /// [`PlaneRequest`]) and return the per-request [`ConversionStats`]
    /// in submission order *without* folding them into the pool's
    /// accumulators. The caller must feed every returned entry through
    /// [`CimArrayPool::apply_plane_stats`] exactly once, in whatever
    /// order the equivalent sequential walk would have produced them —
    /// that replay is what keeps fused serving bit-identical to the
    /// sequential path down to the `energy_fj` float accumulation and
    /// the per-transform `minus` snapshots. Conversion values, the
    /// exactly-once-or-gated row pass and the per-request stats
    /// themselves are computed here as usual.
    pub fn process_plane_requests(
        &mut self,
        requests: Vec<PlaneRequest<'_>>,
    ) -> Vec<ConversionStats> {
        self.planes_fused += requests.len() as u64;
        self.run_requests(requests)
    }

    /// Fold one plane's deferred accounting (from
    /// [`CimArrayPool::process_plane_requests`]) into the pool totals —
    /// the caller-side half of the deferred-accounting contract.
    pub fn apply_plane_stats(&mut self, stats: &ConversionStats) {
        let rows = self.rows() as u64;
        self.apply_plane_result(rows, stats);
    }

    /// The dispatch core shared by [`CimArrayPool::process_planes`] and
    /// [`CimArrayPool::process_plane_requests`]: derive each request's
    /// (group, phase, computer) from its slot, queue onto per-group
    /// lanes, run the lanes (inline, or on the persistent runtime when
    /// `PoolSpec::threads` asks for fan-out and more than one lane has
    /// work), and return per-request stats in submission order.
    fn run_requests(&mut self, requests: Vec<PlaneRequest<'_>>) -> Vec<ConversionStats> {
        let n = requests.len();
        if n == 0 {
            return Vec::new();
        }
        let rows = self.rows();
        let n_groups = self.groups.len();
        let size = self.topology.mode().group_size();
        let phases = self.schedule.phases();
        let threads = crate::util::executor::resolve_lanes(self.spec.threads);

        let mut queues: Vec<Vec<PlaneJob<'_>>> = (0..n_groups).map(|_| Vec::new()).collect();
        // Per-submission fault contexts, kept for the post-run outcome
        // fold (empty when no plan — the inert path allocates nothing).
        let mut slot_faults: Vec<SlotFault> =
            if self.fault.is_some() { Vec::with_capacity(n) } else { Vec::new() };
        for (i, req) in requests.into_iter().enumerate() {
            assert_eq!(req.out.len(), rows, "request output length != array rows");
            if let Some(mask) = req.active {
                assert_eq!(mask.len(), rows, "active mask length != rows");
            }
            let (g, computer, fault, stuck) = match self.resolve_slot(req.slot) {
                Some(r) => (r.group, r.computer - r.group * size, r.fault, r.stuck),
                None => {
                    let g = req.slot % n_groups;
                    let phase = (req.slot / n_groups) % phases;
                    (g, self.derive_computer(phase, g) - g * size, SlotFault::default(), Vec::new())
                }
            };
            if self.fault.is_some() {
                slot_faults.push(fault);
            }
            queues[g].push(PlaneJob {
                idx: i,
                computer,
                seed: req.seed,
                stream: req.stream,
                plane: req.plane,
                active: req.active,
                fault,
                stuck,
                out: req.out,
            });
        }

        // Resolve the runtime handle before taking the disjoint lane
        // borrows below (the handle is just an Arc clone). A self-built
        // runtime never needs more lanes than the pool has coupling
        // groups — at most `n_groups` lanes can ever hold work.
        let busy = queues.iter().filter(|q| !q.is_empty()).count();
        let workers = threads.clamp(1, busy.max(1));
        let executor = (workers > 1).then(|| self.ensure_executor(threads.min(n_groups)));

        // Disjoint mutable views per group with queued work: its
        // contiguous array block, its converter, its MAV scratch.
        let lanes: Vec<GroupLane<'_>> = self
            .arrays
            .chunks_mut(size)
            .take(n_groups)
            .zip(self.converters.iter_mut())
            .zip(self.group_scratch.iter_mut())
            .zip(queues)
            .filter(|(_, jobs)| !jobs.is_empty())
            .map(|(((group, adc), mavs), jobs)| GroupLane { group, adc, mavs, jobs })
            .collect();

        let results: Vec<(usize, ConversionStats, u64)> = match executor {
            None => lanes.into_iter().flat_map(GroupLane::run).collect(),
            Some(exec) => {
                // PR-1 shard pattern on the persistent runtime: lanes
                // group into at most `workers` tasks, so
                // `PoolSpec::threads` still caps this call's
                // concurrency even when the injected runtime is wider
                // (it is shared with the engine's batch shards). The
                // idx merge below removes any ordering dependence.
                let shard_len = lanes.len().div_ceil(workers);
                let mut shards: Vec<Vec<GroupLane<'_>>> = Vec::with_capacity(workers);
                let mut it = lanes.into_iter();
                loop {
                    let shard: Vec<GroupLane<'_>> = it.by_ref().take(shard_len).collect();
                    if shard.is_empty() {
                        break;
                    }
                    shards.push(shard);
                }
                let tasks: Vec<_> = shards
                    .into_iter()
                    .map(|shard| {
                        move || shard.into_iter().flat_map(GroupLane::run).collect::<Vec<_>>()
                    })
                    .collect();
                exec.run(tasks).into_iter().flatten().collect()
            }
        };

        // Submission-order merge, whatever worker ran what.
        let mut ordered = vec![ConversionStats::default(); n];
        let mut oob = vec![0u64; n];
        for (idx, stats, o) in results {
            ordered[idx] = stats;
            oob[idx] = o;
        }
        // Fold lane-side fault outcomes in submission order (pure u64
        // sums — order-free totals, ordered anyway for uniformity).
        if let Some(fl) = self.fault.as_mut() {
            for (i, fault) in slot_faults.iter().enumerate() {
                fl.record_outcome(fault, ordered[i].conversions, oob[i]);
            }
        }
        ordered
    }

    /// The persistent runtime backing parallel dispatch — injected by
    /// the serving engine, or lazily built (sized `lanes`) at first
    /// parallel use so standalone pools pay the spawn exactly once.
    fn ensure_executor(&mut self, lanes: usize) -> Arc<Executor> {
        if self.executor.is_none() {
            self.executor = Some(Arc::new(Executor::new(lanes)));
        }
        self.executor.as_ref().expect("executor just ensured").clone()
    }

    /// Open the per-plane exactly-once ledger for `rows` MAVs. Driven by
    /// custom phase drivers and the invariant tests; the batched serving
    /// path enforces the same property structurally (see module docs).
    pub fn begin_plane(&mut self, rows: usize) {
        assert!(!self.plane_open, "begin_plane while a plane is still open");
        self.plane_open = true;
        self.converted.clear();
        self.converted.resize(rows, ROW_PENDING);
    }

    /// Digitize one row's MAV through group `group`'s converter and
    /// decode it back to a signed-sum estimate (see [`decode_mav`] for
    /// the bin-centring rationale). Panics if the row was already
    /// digitized — or gated — this plane (exactly-once invariant).
    pub fn digitize_row(
        &mut self,
        group: usize,
        computer: usize,
        row: usize,
        v_mav: f64,
        ones: f64,
        rng: &mut Rng,
    ) -> f64 {
        assert!(self.plane_open, "digitize_row outside begin_plane/end_plane");
        assert!(
            self.converted[row] != ROW_CONVERTED,
            "MAV of row {row} digitized twice in one phase (exactly-once invariant)"
        );
        assert!(
            self.converted[row] != ROW_GATED,
            "MAV of row {row} digitized after being gated this phase"
        );
        let per_count = self.arrays[computer].mav_volts_per_count();
        let (v, c) = decode_mav(per_count, &mut self.converters[group], v_mav, ones, rng);
        self.converted[row] = ROW_CONVERTED;
        self.mavs_digitized += 1;
        self.stats.record(&c);
        v
    }

    /// Account row `row` as conversion-gated this plane: early
    /// termination pruned it, so the converter never fires. Panics if
    /// the row was already digitized (a conversion cannot be un-spent).
    pub fn gate_row(&mut self, row: usize) {
        assert!(self.plane_open, "gate_row outside begin_plane/end_plane");
        assert!(
            self.converted[row] != ROW_CONVERTED,
            "row {row} gated after its MAV was already digitized this phase"
        );
        if self.converted[row] != ROW_GATED {
            self.converted[row] = ROW_GATED;
            self.mavs_gated += 1;
            self.stats.gated += 1;
        }
    }

    /// Close the plane; panics if any MAV was neither digitized nor
    /// gated.
    pub fn end_plane(&mut self) {
        assert!(self.plane_open, "end_plane without begin_plane");
        self.plane_open = false;
        let missed = self.converted.iter().filter(|&&c| c == ROW_PENDING).count();
        assert!(
            missed == 0,
            "{missed} MAVs left undigitized at end of phase (exactly-once invariant)"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::fault::HealthStatus;

    fn plane(cols: usize, seed: u64, density: f64) -> BitVec {
        let mut rng = Rng::new(seed);
        BitVec::from_bits(&(0..cols).map(|_| rng.bernoulli(density)).collect::<Vec<_>>())
    }

    fn spec(n_arrays: usize, mode: ImmersedMode, adc_bits: u8) -> PoolSpec {
        PoolSpec { n_arrays, adc_bits, mode, asymmetric: false, threads: 1, fuse_batch: false }
    }

    fn ideal_pool(mode: ImmersedMode, adc_bits: u8) -> CimArrayPool {
        let mut rng = Rng::new(7);
        CimArrayPool::new(
            &SignMatrix::walsh(32),
            CrossbarConfig::ideal(),
            spec(4, mode, adc_bits),
            &mut rng,
        )
    }

    fn noisy_pool(n_arrays: usize, threads: usize) -> CimArrayPool {
        let mut rng = Rng::new(17);
        CimArrayPool::new(
            &SignMatrix::walsh(32),
            CrossbarConfig::default(),
            PoolSpec { threads, ..spec(n_arrays, ImmersedMode::Sar, 5) },
            &mut rng,
        )
    }

    #[test]
    fn fig11_specs_fit_four_arrays() {
        for mode in [ImmersedMode::Sar, ImmersedMode::Flash, ImmersedMode::Hybrid { flash_bits: 2 }]
        {
            let spec = PoolSpec::fig11(mode);
            let mut rng = Rng::new(1);
            let pool =
                CimArrayPool::new(&SignMatrix::walsh(32), CrossbarConfig::ideal(), spec, &mut rng);
            assert_eq!(pool.n_arrays(), 4);
            assert!(pool.n_groups() >= 1);
        }
    }

    #[test]
    fn ideal_sar_pool_decodes_exact_signed_sums() {
        // Aligned case (cols == 2^bits, settle == 1): the decoded plane
        // equals the exact ±1 weighted sums whenever |x| < cols.
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(3);
        let mut out = vec![0.0; 32];
        for seed in 0..8 {
            let x = plane(32, seed, 0.45);
            if x.count_ones() as usize == 32 {
                continue;
            }
            let exact = pool.arrays()[0].matrix().matvec(&x);
            pool.process_plane(&x, &mut rng, &mut out);
            for (r, e) in exact.iter().enumerate() {
                assert_eq!(out[r], *e as f64, "row {r} seed {seed}");
            }
        }
    }

    #[test]
    fn nearest_neighbour_roles_alternate_across_phases() {
        // 4 arrays, SAR coupling: groups [0,1] and [2,3]. A full rotation
        // later the compute role has swapped inside each pair.
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(4);
        let mut out = vec![0.0; 32];
        let x = plane(32, 1, 0.5);
        for _ in 0..4 {
            pool.process_plane(&x, &mut rng, &mut out);
        }
        let ops: Vec<u64> = pool.arrays().iter().map(|a| a.ops()).collect();
        assert_eq!(ops, vec![1, 1, 1, 1], "each array computed exactly once");
    }

    #[test]
    fn exactly_once_accounting_holds() {
        let mut pool = ideal_pool(ImmersedMode::Hybrid { flash_bits: 2 }, 5);
        let mut rng = Rng::new(5);
        let mut out = vec![0.0; 32];
        for seed in 0..3 {
            pool.process_plane(&plane(32, seed, 0.5), &mut rng, &mut out);
        }
        assert_eq!(pool.mavs_produced(), 3 * 32);
        assert_eq!(pool.mavs_digitized(), pool.mavs_produced());
        assert_eq!(pool.mavs_gated(), 0);
        assert_eq!(pool.stats().conversions, 3 * 32);
        assert!(pool.stats().energy_fj > 0.0);
    }

    #[test]
    fn per_mode_cycle_and_comparison_arithmetic() {
        let cases = [
            (ImmersedMode::Sar, 5u8, 5u64, 5u64),
            (ImmersedMode::Flash, 2, 1, 3),
            (ImmersedMode::Hybrid { flash_bits: 2 }, 5, 4, 6),
        ];
        for (mode, bits, cycles, comparisons) in cases {
            let mut pool = ideal_pool(mode, bits);
            let mut rng = Rng::new(6);
            let mut out = vec![0.0; 32];
            pool.process_plane(&plane(32, 2, 0.5), &mut rng, &mut out);
            let s = pool.stats();
            assert_eq!(s.conversions, 32, "{mode:?}");
            assert_eq!(s.cycles, cycles * 32, "{mode:?}");
            assert_eq!(s.comparisons, comparisons * 32, "{mode:?}");
        }
    }

    #[test]
    fn asymmetric_tree_cuts_comparisons_on_skewed_mavs() {
        let spec = PoolSpec {
            n_arrays: 4,
            adc_bits: 5,
            mode: ImmersedMode::Sar,
            asymmetric: true,
            threads: 1,
            fuse_batch: false,
        };
        let mut rng = Rng::new(8);
        let mut asym =
            CimArrayPool::new(&SignMatrix::walsh(32), CrossbarConfig::ideal(), spec, &mut rng);
        let mut plain = ideal_pool(ImmersedMode::Sar, 5);
        let mut out = vec![0.0; 32];
        let mut ra = Rng::new(9);
        let mut rp = Rng::new(9);
        for seed in 0..16 {
            let x = plane(32, seed, 0.5);
            asym.process_plane(&x, &mut ra, &mut out);
            plain.process_plane(&x, &mut rp, &mut out);
        }
        assert_eq!(asym.stats().conversions, plain.stats().conversions);
        assert!(
            asym.stats().comparisons < plain.stats().comparisons,
            "asymmetric {} !< symmetric {}",
            asym.stats().comparisons,
            plain.stats().comparisons
        );
    }

    #[test]
    #[should_panic(expected = "digitized twice")]
    fn double_digitization_panics() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(10);
        pool.begin_plane(32);
        pool.digitize_row(0, 0, 3, 0.4, 16.0, &mut rng);
        pool.digitize_row(0, 0, 3, 0.4, 16.0, &mut rng);
    }

    #[test]
    #[should_panic(expected = "undigitized")]
    fn missed_digitization_panics() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(11);
        pool.begin_plane(32);
        pool.digitize_row(0, 0, 0, 0.4, 16.0, &mut rng);
        pool.end_plane();
    }

    #[test]
    #[should_panic(expected = "after being gated")]
    fn digitizing_a_gated_row_panics() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(12);
        pool.begin_plane(32);
        pool.gate_row(3);
        pool.digitize_row(0, 0, 3, 0.4, 16.0, &mut rng);
    }

    #[test]
    fn gated_rows_close_the_ledger() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(13);
        pool.begin_plane(4);
        pool.digitize_row(0, 0, 0, 0.4, 16.0, &mut rng);
        pool.gate_row(1);
        pool.gate_row(2);
        pool.digitize_row(0, 0, 3, 0.2, 16.0, &mut rng);
        pool.end_plane();
        assert_eq!(pool.mavs_digitized(), 2);
        assert_eq!(pool.mavs_gated(), 2);
        assert_eq!(pool.stats().gated, 2);
    }

    #[test]
    fn begin_transform_makes_runs_reproducible() {
        let mut a = ideal_pool(ImmersedMode::Sar, 5);
        let mut b = ideal_pool(ImmersedMode::Sar, 5);
        let x = plane(32, 3, 0.5);
        let mut oa = vec![0.0; 32];
        let mut ob = vec![0.0; 32];
        // Advance `a` an odd number of phases, then rewind: results must
        // match a fresh pool's first phase.
        a.process_plane(&x, &mut Rng::new(12), &mut oa);
        a.begin_transform();
        a.process_plane(&x, &mut Rng::new(13), &mut oa);
        b.begin_transform();
        b.process_plane(&x, &mut Rng::new(13), &mut ob);
        assert_eq!(oa, ob);
    }

    #[test]
    fn process_planes_equals_sequence_of_single_plane_calls() {
        // Batched dispatch == the same planes submitted one at a time,
        // bit for bit — outputs, counters, and float energy accumulation
        // (both paths merge per-plane subtotals in submission order).
        let planes: Vec<BitVec> = (0..5).map(|s| plane(32, s, 0.4)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..5).collect();
        let seed = 0xfeed;
        let mut batched = noisy_pool(4, 1);
        let mut singles = noisy_pool(4, 1);
        let mut out_b = vec![0.0; 5 * 32];
        let mut out_s = vec![0.0; 5 * 32];
        batched.process_planes(&refs, &streams, seed, None, &mut out_b);
        for (i, p) in refs.iter().copied().enumerate() {
            singles.process_planes(
                &[p],
                &[streams[i]],
                seed,
                None,
                &mut out_s[i * 32..(i + 1) * 32],
            );
        }
        assert_eq!(out_b, out_s);
        assert_eq!(batched.stats(), singles.stats());
        assert_eq!(batched.mavs_produced(), singles.mavs_produced());
        assert_eq!(batched.mavs_digitized(), singles.mavs_digitized());
    }

    #[test]
    fn process_planes_matches_process_plane_values() {
        // The batched path decodes the same values as the classic
        // per-plane entry point fed the matching per-plane streams.
        let planes: Vec<BitVec> = (0..4).map(|s| plane(32, 10 + s, 0.5)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..4).collect();
        let seed = 0xabba;
        let mut batched = noisy_pool(4, 1);
        let mut classic = noisy_pool(4, 1);
        let mut out_b = vec![0.0; 4 * 32];
        batched.process_planes(&refs, &streams, seed, None, &mut out_b);
        let mut out_c = vec![0.0; 32];
        for (i, p) in refs.iter().copied().enumerate() {
            let mut rng = Rng::for_stream(seed, streams[i]);
            classic.process_plane(p, &mut rng, &mut out_c);
            assert_eq!(&out_b[i * 32..(i + 1) * 32], &out_c[..], "plane {i}");
        }
        assert_eq!(batched.stats().conversions, classic.stats().conversions);
        assert_eq!(batched.stats().comparisons, classic.stats().comparisons);
    }

    #[test]
    fn process_planes_is_thread_count_invariant() {
        // 8 arrays, SAR coupling: 4 independent groups per phase. The
        // fan-out must be bit-identical at any worker count — including
        // the merged stats' float energy.
        let planes: Vec<BitVec> = (0..11).map(|s| plane(32, 20 + s, 0.5)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..11).collect();
        let mut base = noisy_pool(8, 1);
        let mut out_base = vec![0.0; 11 * 32];
        base.process_planes(&refs, &streams, 0x7007, None, &mut out_base);
        for threads in [2usize, 4, 8] {
            let mut pool = noisy_pool(8, threads);
            let mut out = vec![0.0; 11 * 32];
            pool.process_planes(&refs, &streams, 0x7007, None, &mut out);
            assert_eq!(out, out_base, "threads={threads}");
            assert_eq!(pool.stats(), base.stats(), "threads={threads}");
        }
    }

    #[test]
    fn gating_mask_skips_conversions_and_counts_them() {
        let x = plane(32, 2, 0.5);
        let mut gated = ideal_pool(ImmersedMode::Sar, 5);
        let mut full = ideal_pool(ImmersedMode::Sar, 5);
        let mut active = vec![true; 32];
        for r in (0..32).step_by(2) {
            active[r] = false;
        }
        let mut out_g = vec![1.0; 32];
        let mut out_f = vec![0.0; 32];
        gated.process_planes(&[&x], &[0], 1, Some(&active), &mut out_g);
        full.process_planes(&[&x], &[0], 1, None, &mut out_f);
        assert_eq!(gated.stats().conversions, 16);
        assert_eq!(gated.stats().gated, 16);
        assert_eq!(gated.mavs_gated(), 16);
        assert_eq!(full.stats().conversions, 32);
        assert_eq!(full.stats().gated, 0);
        assert!(gated.stats().energy_fj < full.stats().energy_fj);
        assert!(gated.stats().cycles < full.stats().cycles);
        for r in 0..32 {
            if active[r] {
                assert_eq!(out_g[r], out_f[r], "active row {r} decodes identically");
            } else {
                assert_eq!(out_g[r], 0.0, "gated row {r} reads zero");
            }
        }
        // The allocation-free single-plane form is the same dispatch:
        // identical outputs and accounting to a 1-element batch.
        let mut masked = ideal_pool(ImmersedMode::Sar, 5);
        let mut out_m = vec![0.0; 32];
        masked.process_plane_masked(&x, 0, 1, Some(&active), &mut out_m);
        assert_eq!(out_m, out_g);
        assert_eq!(masked.stats(), gated.stats());
    }

    #[test]
    fn plane_requests_match_process_planes_with_deferred_apply() {
        // The fused entry point fed the slots/seed/streams that
        // process_planes would derive itself, with the returned stats
        // replayed in submission order, is the same computation bit for
        // bit — outputs, counters, energy accumulation.
        let planes: Vec<BitVec> = (0..6).map(|s| plane(32, 40 + s, 0.45)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..6).collect();
        let seed = 0xf00d;
        let mut classic = noisy_pool(8, 1);
        let mut fused = noisy_pool(8, 1);
        let mut out_c = vec![0.0; 6 * 32];
        let mut out_f = vec![0.0; 6 * 32];
        classic.process_planes(&refs, &streams, seed, None, &mut out_c);
        let requests: Vec<PlaneRequest<'_>> = out_f
            .chunks_mut(32)
            .enumerate()
            .map(|(i, chunk)| PlaneRequest {
                slot: i,
                seed,
                stream: streams[i],
                plane: refs[i],
                active: None,
                out: chunk,
            })
            .collect();
        let per = fused.process_plane_requests(requests);
        assert_eq!(per.len(), 6);
        // Nothing applied yet: the deferred half is the caller's job.
        assert_eq!(fused.stats(), ConversionStats::default());
        assert_eq!(fused.mavs_produced(), 0);
        for s in &per {
            fused.apply_plane_stats(s);
        }
        assert_eq!(out_f, out_c);
        assert_eq!(fused.stats(), classic.stats());
        assert_eq!(fused.mavs_produced(), classic.mavs_produced());
        assert_eq!(fused.mavs_digitized(), classic.mavs_digitized());
    }

    #[test]
    fn parallel_dispatch_reuses_one_persistent_runtime() {
        // The first parallel call builds the executor; later calls (and
        // clones) reuse the same one — no per-call spawning.
        let planes: Vec<BitVec> = (0..8).map(|s| plane(32, 50 + s, 0.5)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..8).collect();
        let mut pool = noisy_pool(8, 4);
        assert!(pool.executor().is_none(), "no runtime before first parallel call");
        let mut out = vec![0.0; 8 * 32];
        pool.process_planes(&refs, &streams, 1, None, &mut out);
        let first = pool.executor().expect("parallel call builds the runtime").clone();
        pool.process_planes(&refs, &streams, 2, None, &mut out);
        let second = pool.executor().unwrap();
        assert!(Arc::ptr_eq(&first, second), "runtime must persist across calls");
        assert!(first.lanes() >= 2);
        let clone = pool.clone();
        assert!(
            Arc::ptr_eq(&first, clone.executor().unwrap()),
            "shard clones share the runtime"
        );
    }

    #[test]
    #[should_panic(expected = "column lines")]
    fn rejects_too_few_columns_for_resolution() {
        let mut rng = Rng::new(14);
        CimArrayPool::new(
            &SignMatrix::walsh(16),
            CrossbarConfig::ideal(),
            spec(4, ImmersedMode::Sar, 5),
            &mut rng,
        );
    }

    #[test]
    fn parse_maps_cli_inputs() {
        assert_eq!(PoolSpec::parse(0, "sar", 0, false), Ok(None));
        let s = PoolSpec::parse(4, "sar", 0, true).unwrap().unwrap();
        assert_eq!((s.n_arrays, s.adc_bits, s.asymmetric, s.threads), (4, 5, true, 1));
        assert_eq!(s.mode, ImmersedMode::Sar);
        let f = PoolSpec::parse(8, "flash", 0, false).unwrap().unwrap();
        assert_eq!((f.adc_bits, f.mode), (2, ImmersedMode::Flash));
        let h = PoolSpec::parse(4, "hybrid", 4, false).unwrap().unwrap();
        assert_eq!((h.adc_bits, h.mode), (4, ImmersedMode::Hybrid { flash_bits: 2 }));
    }

    #[test]
    fn parse_rejects_bad_configurations_with_diagnostics() {
        // Typo'd mode must not silently fall back to hybrid.
        let e = PoolSpec::parse(4, "slar", 0, false).unwrap_err();
        assert!(e.contains("unknown adc mode"), "{e}");
        // Hybrid's 2-bit flash stage needs adc_bits > 2.
        let e = PoolSpec::parse(4, "hybrid", 2, false).unwrap_err();
        assert!(e.contains("narrower"), "{e}");
        // 5-bit flash needs 2^5 − 1 reference arrays + the computer.
        let e = PoolSpec::parse(4, "flash", 5, false).unwrap_err();
        assert!(e.contains("coupling group"), "{e}");
        // Asymmetric tree is a SAR-coupling technique.
        let e = PoolSpec::parse(4, "flash", 0, true).unwrap_err();
        assert!(e.contains("asymmetric"), "{e}");
        // Out-of-range resolution.
        let e = PoolSpec::parse(4, "sar", 11, false).unwrap_err();
        assert!(e.contains("1..=10"), "{e}");
        // A negative TOML pool_arrays wraps to a huge usize: loud error,
        // not an attempt to fabricate usize::MAX crossbars.
        let e = PoolSpec::parse(usize::MAX, "sar", 0, false).unwrap_err();
        assert!(e.contains("4096"), "{e}");
    }

    #[test]
    fn dead_converter_zeroes_decodes_and_counts() {
        // Group 0's converter dies at slot 0 with probing disabled
        // (inject only, never heal): its planes decode from code 0
        // (−|x| after the signed-sum decode), group 1 is untouched, and
        // the blast radius is accounted.
        let mut faulty = ideal_pool(ImmersedMode::Sar, 5);
        let mut healthy = ideal_pool(ImmersedMode::Sar, 5);
        let plan = FaultPlan { probe_interval: 0, ..FaultPlan::parse("dead@0=0").unwrap() };
        faulty.set_fault_plan(Some(plan)).unwrap();
        let x = plane(32, 3, 0.5);
        let ones = x.count_ones() as f64;
        let mut out_f = vec![0.0; 32];
        let mut out_h = vec![0.0; 32];
        let mut rf = Rng::new(2);
        let mut rh = Rng::new(2);
        // Slot 0 → group 0 (dead converter), slot 1 → group 1 (healthy).
        faulty.process_plane(&x, &mut rf, &mut out_f);
        healthy.process_plane(&x, &mut rh, &mut out_h);
        assert!(out_f.iter().all(|&v| v == -ones), "dead converter decodes code 0");
        faulty.process_plane(&x, &mut rf, &mut out_f);
        healthy.process_plane(&x, &mut rh, &mut out_h);
        assert_eq!(out_f, out_h, "the other group is unaffected");
        let fs = faulty.fault_stats();
        assert_eq!(fs.faults_injected, 1);
        assert_eq!(fs.converters_dead, 1);
        assert_eq!(fs.injected_by_type(), fs.faults_injected);
        assert_eq!(fs.degraded_planes, 1);
        assert_eq!(fs.probes_run, 0);
        assert_eq!(healthy.fault_stats(), FaultStats::default());
    }

    #[test]
    fn stuck_cell_perturbs_one_row_and_restores_the_matrix() {
        let mut faulty = ideal_pool(ImmersedMode::Sar, 5);
        let mut healthy = ideal_pool(ImmersedMode::Sar, 5);
        // Slot 0 is (phase 0, group 0): find its compute-role array and
        // stick one of its cells at the inverted polarity.
        let computer = (0..2)
            .find(|&a| faulty.schedule().role(0, a) == crate::network::Role::Compute)
            .unwrap();
        let orig = faulty.arrays()[computer].matrix().get(2, 3);
        let sign = if orig > 0 { '-' } else { '+' };
        let plan = FaultPlan {
            probe_interval: 0,
            ..FaultPlan::parse(&format!("stuck@0={computer},2,3,{sign}")).unwrap()
        };
        faulty.set_fault_plan(Some(plan)).unwrap();
        // 31 of 32 bits set (|x| < cols keeps the ideal decode exact),
        // including column 3, so the stuck cell must show in row 2.
        let x = BitVec::from_bits(&(0..32).map(|i| i != 5).collect::<Vec<_>>());
        let mut out_f = vec![0.0; 32];
        let mut out_h = vec![0.0; 32];
        faulty.process_plane(&x, &mut Rng::new(4), &mut out_f);
        healthy.process_plane(&x, &mut Rng::new(4), &mut out_h);
        for r in 0..32 {
            if r == 2 {
                assert_eq!(
                    (out_f[r] - out_h[r]).abs(),
                    2.0,
                    "stuck cell flips exactly one ±1 weight"
                );
            } else {
                assert_eq!(out_f[r], out_h[r], "row {r} untouched");
            }
        }
        assert_eq!(
            faulty.arrays()[computer].matrix().get(2, 3),
            orig,
            "programmed polarity restored after the dispatch"
        );
        assert_eq!(faulty.fault_stats().stuck_cells, 1);
    }

    #[test]
    fn probes_quarantine_a_dead_converter_and_reroute_restores_decodes() {
        // Probe timeline for a dead converter on group 0 (interval 1,
        // debounce 2): fail at p=0 (suspect), fail at p=1 (quarantined
        // at 1). Slot 0 still reads zeros; slot 2 reroutes and decodes
        // healthy values at +1 cycle per conversion.
        let mut faulty = ideal_pool(ImmersedMode::Sar, 5);
        let mut healthy = ideal_pool(ImmersedMode::Sar, 5);
        let plan = FaultPlan {
            probe_interval: 1,
            probe_debounce: 2,
            ..FaultPlan::parse("dead@0=0").unwrap()
        };
        faulty.set_fault_plan(Some(plan)).unwrap();
        let x = plane(32, 7, 0.4);
        let ones = x.count_ones() as f64;
        let mut rf = Rng::new(5);
        let mut rh = Rng::new(5);
        let mut out_f = vec![0.0; 32];
        let mut out_h = vec![0.0; 32];
        for slot in 0..4 {
            faulty.process_plane(&x, &mut rf, &mut out_f);
            healthy.process_plane(&x, &mut rh, &mut out_h);
            if slot == 0 {
                assert!(out_f.iter().all(|&v| v == -ones), "pre-quarantine slot reads code 0");
            } else {
                assert_eq!(out_f, out_h, "slot {slot} decodes healthy values");
            }
        }
        let fs = faulty.fault_stats();
        assert_eq!(fs.quarantined, 1);
        assert!(fs.probes_failed >= 2);
        assert_eq!(fs.conversions_rerouted, 32, "slot 2 rerouted all 32 rows");
        assert_eq!(
            faulty.stats().cycles,
            healthy.stats().cycles + 32,
            "reroute costs one extra cycle per conversion"
        );
        let ledger = faulty.health().unwrap();
        assert_eq!(ledger.converter_status(0), HealthStatus::Quarantined);
        assert_eq!(ledger.converter_status(1), HealthStatus::Healthy);
        assert_eq!(ledger.quarantined(), 1);
    }

    #[test]
    fn array_down_is_scheduled_out_by_the_degraded_epoch() {
        // Array 0 is down from slot 0; probe p=0 (interval 1, debounce
        // 1) quarantines it before the first dispatch resolves, so the
        // degraded epoch hands group 0's compute role to array 1 and
        // the decode stays exact — the line never stops.
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let plan = FaultPlan {
            probe_interval: 1,
            probe_debounce: 1,
            ..FaultPlan::parse("down@0=0").unwrap()
        };
        pool.set_fault_plan(Some(plan)).unwrap();
        let x = plane(32, 9, 0.5);
        assert!((x.count_ones() as usize) < 32, "exact-decode precondition");
        let exact = pool.arrays()[0].matrix().matvec(&x);
        let mut rng = Rng::new(6);
        let mut out = vec![0.0; 32];
        for slot in 0..4 {
            pool.process_plane(&x, &mut rng, &mut out);
            if slot % 2 == 0 {
                // Group 0 slots: computed by the surviving array 1.
                for (r, e) in exact.iter().enumerate() {
                    assert_eq!(out[r], *e as f64, "slot {slot} row {r}");
                }
            }
        }
        let ops: Vec<u64> = pool.arrays().iter().map(|a| a.ops()).collect();
        assert_eq!(ops, vec![0, 2, 1, 1], "down array never computes; partner covers");
        assert_eq!(pool.health().unwrap().array_status(0), HealthStatus::Quarantined);
        let fs = pool.fault_stats();
        assert_eq!(fs.arrays_down, 1);
        assert_eq!(fs.quarantined, 1);
        assert!(fs.degraded_planes >= 1, "epoch-remapped compute role counts as degraded");
    }

    #[test]
    fn empty_plan_probes_only_leaves_serving_untouched() {
        // A plan with no faults runs calibration probes off their own
        // salted noise streams: serving outputs, stats and noise draws
        // are bit-identical to a pool with no plan at all.
        let mut probed = noisy_pool(4, 1);
        let mut plain = noisy_pool(4, 1);
        probed.set_fault_plan(Some(FaultPlan::default())).unwrap();
        let planes: Vec<BitVec> = (0..6).map(|s| plane(32, 60 + s, 0.5)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..6).collect();
        let mut out_p = vec![0.0; 6 * 32];
        let mut out_n = vec![0.0; 6 * 32];
        probed.process_planes(&refs, &streams, 0xbeef, None, &mut out_p);
        plain.process_planes(&refs, &streams, 0xbeef, None, &mut out_n);
        assert_eq!(out_p, out_n);
        assert_eq!(probed.stats(), plain.stats());
        let fs = probed.fault_stats();
        assert!(fs.probes_run > 0);
        assert_eq!(fs.quarantined, 0);
        assert_eq!(fs.faults_injected, 0);
        assert_eq!(fs.degraded_planes, 0);
        assert_eq!(plain.fault_stats(), FaultStats::default());
        // Clearing the plan returns to the inert signature.
        probed.set_fault_plan(None).unwrap();
        assert_eq!(probed.fault_stats(), FaultStats::default());
        assert!(probed.health().is_none());
    }

    #[test]
    fn install_rejects_out_of_range_indices() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let e = pool.set_fault_plan(Some(FaultPlan::parse("down@0=9").unwrap())).unwrap_err();
        assert!(e.contains("arrays"), "{e}");
        let e = pool.set_fault_plan(Some(FaultPlan::parse("dead@0=5").unwrap())).unwrap_err();
        assert!(e.contains("groups"), "{e}");
        let e = pool
            .set_fault_plan(Some(FaultPlan::parse("stuck@0=0,99,0,+").unwrap()))
            .unwrap_err();
        assert!(e.contains("matrix"), "{e}");
        // A failed install leaves no layer behind.
        assert!(pool.health().is_none());
        assert_eq!(pool.fault_stats(), FaultStats::default());
    }

    #[test]
    fn faulty_requests_match_sequential_dispatch_bit_for_bit() {
        // The determinism contract under an active plan covering every
        // fault kind: the fused deferred-accounting path on a threaded
        // pool replays the sequential walk bit for bit — outputs,
        // conversion stats, and the fault layer's own counters.
        let make = |threads: usize| {
            let mut p = noisy_pool(4, threads);
            let plan = FaultPlan {
                probe_interval: 2,
                ..FaultPlan::parse("dead@0=0; drift@1=1,1.3,0.1; stuck@0=2,1,1,+; down@2=3")
                    .unwrap()
            };
            p.set_fault_plan(Some(plan)).unwrap();
            p
        };
        let planes: Vec<BitVec> = (0..10).map(|s| plane(32, 70 + s, 0.5)).collect();
        let refs: Vec<&BitVec> = planes.iter().collect();
        let streams: Vec<u64> = (0..10).collect();
        let seed = 0x5eed;
        let mut seq = make(1);
        let mut out_s = vec![0.0; 10 * 32];
        seq.process_planes(&refs, &streams, seed, None, &mut out_s);
        let mut fused = make(4);
        let mut out_f = vec![0.0; 10 * 32];
        let requests: Vec<PlaneRequest<'_>> = out_f
            .chunks_mut(32)
            .enumerate()
            .map(|(i, chunk)| PlaneRequest {
                slot: i,
                seed,
                stream: streams[i],
                plane: refs[i],
                active: None,
                out: chunk,
            })
            .collect();
        let per = fused.process_plane_requests(requests);
        for s in &per {
            fused.apply_plane_stats(s);
        }
        assert_eq!(out_f, out_s);
        assert_eq!(fused.stats(), seq.stats());
        assert_eq!(fused.fault_stats(), seq.fault_stats());
        let fs = seq.fault_stats();
        assert_eq!(fs.faults_injected, 4, "every planned fault reached its onset");
        assert_eq!(fs.injected_by_type(), fs.faults_injected);
        assert!(fs.degraded_planes > 0);
    }
}
