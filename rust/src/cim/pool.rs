//! Collaborative digitization pool: the serving-path fabric that turns
//! crossbar MAVs into codes (paper §IV, Figs 9/11).
//!
//! A [`CimArrayPool`] owns N identically-programmed crossbar arrays, a
//! [`Topology`] describing how they couple, and an [`InterleaveSchedule`]
//! assigning each array a per-phase role: **compute** an in-memory scalar
//! product, or **digitize** a neighbour's multiply-average voltage by
//! lending its column lines as the capacitive DAC of a memory-immersed
//! converter ([`crate::adc::ImmersedAdc`]). This is the paper's second
//! contribution made a first-class inference stage: the multi-bit MAVs
//! from [`Crossbar::compute_mav_into`] flow through the neighbour array
//! instead of a dedicated ADC, and [`super::BitplaneEngine`] reassembles
//! the digitized planes into near-exact transform outputs (vs the 1-bit
//! ADC-free default path).
//!
//! **Runtime invariants** — enforced here with assertions on the live
//! data path, not just in `network::schedule::validate`:
//!
//! 1. *No array computes and digitizes in the same phase.* Every
//!    [`CimArrayPool::process_plane`] re-derives the group's roles from
//!    the schedule and asserts exactly one computer whose partners all
//!    hold the digitize role.
//! 2. *Every computed MAV is digitized exactly once.* A per-plane ledger
//!    ([`CimArrayPool::begin_plane`] / [`CimArrayPool::digitize_row`] /
//!    [`CimArrayPool::end_plane`]) panics on a double conversion and on
//!    any row left unconverted when the phase closes.
//!
//! Per-conversion energy/cycles/comparisons accumulate in
//! [`ConversionStats`] and thread up through the engines into
//! [`crate::coordinator::Metrics`].

use crate::adc::{Adc, AnyAdc, AsymmetricAdc, Conversion, ImmersedAdc, ImmersedMode};
use crate::network::{CouplingMode, InterleaveSchedule, Role, Topology};
use crate::util::Rng;

use super::bitvec::{BitVec, SignMatrix};
use super::crossbar::{Crossbar, CrossbarConfig};

/// Pool shape: how many arrays, what converter networking, how many
/// output bits, and whether the Fig 10 asymmetric comparison tree drives
/// the SAR references. `Copy` so it rides inside `BwhtExec`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSpec {
    /// CiM arrays in the pool (the fabricated chip has 4).
    pub n_arrays: usize,
    /// Converter resolution; needs `cols ≥ 2^adc_bits` column lines.
    pub adc_bits: u8,
    /// Collaborative networking mode (Sar / Flash / Hybrid).
    pub mode: ImmersedMode,
    /// Drive SAR references with the MAV-statistics comparison tree.
    pub asymmetric: bool,
}

impl PoolSpec {
    /// The fabricated test chip of Fig 11: four arrays. Resolution per
    /// mode is bounded by the hardware — flash needs `2^bits − 1`
    /// neighbour arrays, so 4 arrays cap flash at 2 bits; SAR and hybrid
    /// run the paper's 5 bits.
    pub fn fig11(mode: ImmersedMode) -> Self {
        let adc_bits = if matches!(mode, ImmersedMode::Flash) { 2 } else { 5 };
        PoolSpec { n_arrays: 4, adc_bits, mode, asymmetric: false }
    }

    /// Parse CLI/config inputs; `Ok(None)` when `n_arrays == 0` (no
    /// pool: the ADC-free 1-bit default path). `adc_bits == 0`
    /// auto-selects per mode (flash 2, otherwise 5). Unknown mode
    /// strings and infeasible (mode, bits, arrays) combinations are
    /// errors, not silent fallbacks.
    pub fn parse(
        n_arrays: usize,
        mode: &str,
        adc_bits: u8,
        asymmetric: bool,
    ) -> Result<Option<Self>, String> {
        if n_arrays == 0 {
            return Ok(None);
        }
        let mode = match mode {
            "sar" => ImmersedMode::Sar,
            "flash" => ImmersedMode::Flash,
            "hybrid" => ImmersedMode::Hybrid { flash_bits: 2 },
            other => {
                return Err(format!("unknown adc mode '{other}' (expected sar|flash|hybrid)"))
            }
        };
        let adc_bits = if adc_bits > 0 {
            adc_bits
        } else if matches!(mode, ImmersedMode::Flash) {
            2
        } else {
            5
        };
        let spec = PoolSpec { n_arrays, adc_bits, mode, asymmetric };
        spec.validate()?;
        Ok(Some(spec))
    }

    /// Feasibility of this converter on this pool shape — the checks
    /// that would otherwise surface as assertion panics deep inside
    /// pool construction. (Column-line count vs `adc_bits` depends on
    /// the programmed matrix and is still checked at construction.)
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=10).contains(&self.adc_bits) {
            return Err(format!("adc_bits {} outside the supported 1..=10", self.adc_bits));
        }
        if let ImmersedMode::Hybrid { flash_bits } = self.mode {
            if flash_bits >= self.adc_bits {
                return Err(format!(
                    "hybrid flash stage ({flash_bits} bits) must be narrower than adc_bits {}",
                    self.adc_bits
                ));
            }
        }
        if self.asymmetric && !matches!(self.mode, ImmersedMode::Sar) {
            return Err("the asymmetric comparison tree requires sar mode".to_string());
        }
        let group = CouplingMode::for_adc_mode(self.mode, self.adc_bits).group_size();
        if self.n_arrays < group {
            return Err(format!(
                "{:?} at {} bits needs a coupling group of {group} arrays; pool has {}",
                self.mode, self.adc_bits, self.n_arrays
            ));
        }
        Ok(())
    }
}

/// Accumulated per-conversion accounting: how much digitization work
/// (and energy) the collaborative fabric spent. Threaded from the pool
/// through `BitplaneOutput` and `BwhtLayer` into `AnalogEngine` and the
/// coordinator's `MetricsSnapshot`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ConversionStats {
    /// MAV→code conversions performed.
    pub conversions: u64,
    /// Comparator decisions across all conversions.
    pub comparisons: u64,
    /// Conversion clock cycles (mode-dependent; flash = 1/conversion).
    pub cycles: u64,
    /// Conversion energy (fJ): reference generation + comparators.
    pub energy_fj: f64,
}

impl ConversionStats {
    /// Fold one conversion into the running totals.
    pub fn record(&mut self, c: &Conversion) {
        self.conversions += 1;
        self.comparisons += c.comparisons as u64;
        self.cycles += c.cycles as u64;
        self.energy_fj += c.energy_fj;
    }

    /// Fold another accumulator into this one (shard merges, signed
    /// two-pass transforms).
    pub fn merge(&mut self, other: &ConversionStats) {
        self.conversions += other.conversions;
        self.comparisons += other.comparisons;
        self.cycles += other.cycles;
        self.energy_fj += other.energy_fj;
    }

    /// Delta of two snapshots of a monotone counter (`self` later).
    pub fn minus(&self, base: &ConversionStats) -> ConversionStats {
        ConversionStats {
            conversions: self.conversions - base.conversions,
            comparisons: self.comparisons - base.comparisons,
            cycles: self.cycles - base.cycles,
            energy_fj: (self.energy_fj - base.energy_fj).max(0.0),
        }
    }

    /// Average comparator decisions per conversion (the Fig 10 axis).
    pub fn comparisons_per_conversion(&self) -> f64 {
        if self.conversions == 0 {
            0.0
        } else {
            self.comparisons as f64 / self.conversions as f64
        }
    }
}

/// A scheduled pool of collaborating CiM arrays (see module docs).
#[derive(Debug, Clone)]
pub struct CimArrayPool {
    arrays: Vec<Crossbar>,
    topology: Topology,
    schedule: InterleaveSchedule,
    /// Complete coupling groups, precomputed (hot path: no re-derivation).
    groups: Vec<Vec<usize>>,
    /// One converter per coupling group (the digitize-role partners'
    /// column lines form its capacitive DAC).
    converters: Vec<AnyAdc>,
    spec: PoolSpec,
    /// Digitize-role partners expected per group per phase.
    expected_refs: usize,
    /// Dispatch cursor: group = cursor % groups, phase advances once per
    /// full rotation. Reset by [`CimArrayPool::begin_transform`].
    cursor: usize,
    stats: ConversionStats,
    mavs_produced: u64,
    mavs_digitized: u64,
    /// Per-plane exactly-once ledger.
    converted: Vec<bool>,
    plane_open: bool,
    mav_scratch: Vec<f64>,
}

impl CimArrayPool {
    /// Fabricate a pool: `spec.n_arrays` crossbars all programmed with
    /// `matrix` at `cfg` (per-array comparator/noise sampling from
    /// `rng`), coupled per `spec.mode`, with one immersed converter per
    /// coupling group whose DAC units are the arrays' `cols` column
    /// lines.
    pub fn new(matrix: &SignMatrix, cfg: CrossbarConfig, spec: PoolSpec, rng: &mut Rng) -> Self {
        if let Err(e) = spec.validate() {
            panic!("invalid pool spec: {e}");
        }
        let cols = matrix.cols();
        assert!(
            cols >= (1usize << spec.adc_bits),
            "pool needs >= 2^adc_bits column lines per array ({} < {})",
            cols,
            1usize << spec.adc_bits
        );
        let coupling = CouplingMode::for_adc_mode(spec.mode, spec.adc_bits);
        let topology = Topology::new(spec.n_arrays, coupling);
        let schedule = InterleaveSchedule::build(&topology, 2 * coupling.group_size());
        schedule.validate(&topology).expect("interleave schedule invalid");
        let groups = topology.groups();
        assert!(!groups.is_empty(), "pool has no complete coupling group");
        let arrays: Vec<Crossbar> =
            (0..spec.n_arrays).map(|_| Crossbar::new(matrix.clone(), cfg, rng)).collect();
        let vdd = cfg.op.vdd;
        let converters: Vec<AnyAdc> = groups
            .iter()
            .map(|_| {
                let adc =
                    ImmersedAdc::sample(spec.adc_bits, vdd, spec.mode, cols, 20.0, &cfg.noise, rng);
                if spec.asymmetric {
                    AnyAdc::Asymmetric(AsymmetricAdc::for_mav(adc, cols, 0.5))
                } else {
                    AnyAdc::Immersed(adc)
                }
            })
            .collect();
        CimArrayPool {
            arrays,
            expected_refs: coupling.group_size() - 1,
            topology,
            schedule,
            groups,
            converters,
            spec,
            cursor: 0,
            stats: ConversionStats::default(),
            mavs_produced: 0,
            mavs_digitized: 0,
            converted: Vec::new(),
            plane_open: false,
            mav_scratch: Vec::new(),
        }
    }

    pub fn spec(&self) -> PoolSpec {
        self.spec
    }

    pub fn rows(&self) -> usize {
        self.arrays[0].rows()
    }

    pub fn cols(&self) -> usize {
        self.arrays[0].cols()
    }

    pub fn n_arrays(&self) -> usize {
        self.arrays.len()
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn schedule(&self) -> &InterleaveSchedule {
        &self.schedule
    }

    /// Read-only view of the arrays (ops/energy counters per array).
    pub fn arrays(&self) -> &[Crossbar] {
        &self.arrays
    }

    /// Accumulated conversion accounting since construction/reset.
    pub fn stats(&self) -> ConversionStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = ConversionStats::default();
        self.mavs_produced = 0;
        self.mavs_digitized = 0;
    }

    /// MAVs produced by compute-role arrays so far.
    pub fn mavs_produced(&self) -> u64 {
        self.mavs_produced
    }

    /// MAVs digitized by the collaborative converters so far. Equal to
    /// [`CimArrayPool::mavs_produced`] whenever no plane is open — the
    /// exactly-once invariant, enforced per plane by the ledger.
    pub fn mavs_digitized(&self) -> u64 {
        self.mavs_digitized
    }

    /// Total crossbar (compute-side) energy across the pool (fJ).
    pub fn crossbar_energy_fj(&self) -> f64 {
        self.arrays.iter().map(|a| a.energy_fj()).sum()
    }

    /// Rewind the dispatch cursor to phase 0 / group 0. Engines call
    /// this at the start of every transform so pooled results are a pure
    /// function of `(pool state at build, input, rng)` — the contract
    /// that keeps batched inference thread-count invariant.
    pub fn begin_transform(&mut self) {
        self.cursor = 0;
    }

    /// One scheduled phase of one coupling group: the compute-role array
    /// runs crossbar steps 1–3 on plane `x` (raw MAVs), and the group's
    /// collaborative converter digitizes every row MAV exactly once.
    /// Writes the decoded signed sums (`2·plus − |x|` estimates, same
    /// units as [`Crossbar::ideal_bitplane`]) into `out`.
    pub fn process_plane(&mut self, x: &BitVec, rng: &mut Rng, out: &mut [f64]) {
        let rows = self.rows();
        assert_eq!(out.len(), rows, "output length != array rows");
        let n_groups = self.groups.len();
        let phase = (self.cursor / n_groups) % self.schedule.phases();
        let g = self.cursor % n_groups;
        self.cursor += 1;

        // Runtime role invariant: exactly one computer this phase, all
        // partners digitizing — an array never holds both roles at once.
        let mut computer: Option<usize> = None;
        let mut refs = 0usize;
        for &a in &self.groups[g] {
            match self.schedule.role(phase, a) {
                Role::Compute => {
                    assert!(
                        computer.is_none(),
                        "phase {phase}: two compute roles in group {g}"
                    );
                    computer = Some(a);
                }
                Role::Digitize => refs += 1,
                Role::Idle => {}
            }
        }
        let computer = computer
            .unwrap_or_else(|| panic!("phase {phase}: no compute role in group {g}"));
        assert_eq!(
            refs, self.expected_refs,
            "phase {phase} group {g}: {refs} digitize partners, expected {}",
            self.expected_refs
        );

        self.begin_plane(rows);
        let mut mavs = std::mem::take(&mut self.mav_scratch);
        mavs.resize(rows, 0.0);
        self.arrays[computer].compute_mav_into(x, rng, &mut mavs);
        self.mavs_produced += rows as u64;
        let ones = x.count_ones() as f64;
        for (r, slot) in out.iter_mut().enumerate() {
            *slot = self.digitize_row(g, computer, r, mavs[r], ones, rng);
        }
        self.mav_scratch = mavs;
        self.end_plane();
    }

    /// Open the per-plane exactly-once ledger for `rows` MAVs. Driven by
    /// [`CimArrayPool::process_plane`]; public so custom phase drivers
    /// (and the invariant tests) exercise the same assertions.
    pub fn begin_plane(&mut self, rows: usize) {
        assert!(!self.plane_open, "begin_plane while a plane is still open");
        self.plane_open = true;
        self.converted.clear();
        self.converted.resize(rows, false);
    }

    /// Digitize one row's MAV through group `group`'s converter and
    /// decode it back to a signed-sum estimate. Panics if the row was
    /// already digitized this plane (exactly-once invariant).
    ///
    /// The comparator input is offset by half a charge count: the
    /// crossbar's discrete MAV levels otherwise sit exactly on the
    /// converter's ideal transition levels (both are `k/cols` grids when
    /// `2^bits == cols`), where real hardware breaks ties with noise.
    /// Centring each level in its code bin keeps the behavioural model
    /// exact and noise-robust. Decoding inverts the floor quantizer at
    /// the bin's expected charge count, so the aligned ideal case
    /// recovers the exact `plus` count.
    pub fn digitize_row(
        &mut self,
        group: usize,
        computer: usize,
        row: usize,
        v_mav: f64,
        ones: f64,
        rng: &mut Rng,
    ) -> f64 {
        assert!(self.plane_open, "digitize_row outside begin_plane/end_plane");
        assert!(
            !self.converted[row],
            "MAV of row {row} digitized twice in one phase (exactly-once invariant)"
        );
        let per_count = self.arrays[computer].mav_volts_per_count();
        let adc = &mut self.converters[group];
        let n_codes = (1u64 << adc.bits()) as f64;
        let vdd = adc.vdd();
        let c = adc.convert(v_mav + 0.5 * per_count, rng);
        self.converted[row] = true;
        self.mavs_digitized += 1;
        self.stats.record(&c);
        // Charge counts per code step; 1.0 in the aligned ideal case.
        let bin_counts = vdd / (n_codes * per_count);
        let plus_hat =
            (c.code as f64 * bin_counts + 0.5 * (bin_counts - 1.0).max(0.0)).min(ones);
        2.0 * plus_hat - ones
    }

    /// Close the plane; panics if any MAV was left undigitized.
    pub fn end_plane(&mut self) {
        assert!(self.plane_open, "end_plane without begin_plane");
        self.plane_open = false;
        let missed = self.converted.iter().filter(|&&c| !c).count();
        assert!(
            missed == 0,
            "{missed} MAVs left undigitized at end of phase (exactly-once invariant)"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(cols: usize, seed: u64, density: f64) -> BitVec {
        let mut rng = Rng::new(seed);
        BitVec::from_bits(&(0..cols).map(|_| rng.bernoulli(density)).collect::<Vec<_>>())
    }

    fn ideal_pool(mode: ImmersedMode, adc_bits: u8) -> CimArrayPool {
        let mut rng = Rng::new(7);
        CimArrayPool::new(
            &SignMatrix::walsh(32),
            CrossbarConfig::ideal(),
            PoolSpec { n_arrays: 4, adc_bits, mode, asymmetric: false },
            &mut rng,
        )
    }

    #[test]
    fn fig11_specs_fit_four_arrays() {
        for mode in [ImmersedMode::Sar, ImmersedMode::Flash, ImmersedMode::Hybrid { flash_bits: 2 }]
        {
            let spec = PoolSpec::fig11(mode);
            let mut rng = Rng::new(1);
            let pool =
                CimArrayPool::new(&SignMatrix::walsh(32), CrossbarConfig::ideal(), spec, &mut rng);
            assert_eq!(pool.n_arrays(), 4);
            assert!(pool.n_groups() >= 1);
        }
    }

    #[test]
    fn ideal_sar_pool_decodes_exact_signed_sums() {
        // Aligned case (cols == 2^bits, settle == 1): the decoded plane
        // equals the exact ±1 weighted sums whenever |x| < cols.
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(3);
        let mut out = vec![0.0; 32];
        for seed in 0..8 {
            let x = plane(32, seed, 0.45);
            if x.count_ones() as usize == 32 {
                continue;
            }
            let exact = pool.arrays()[0].matrix().matvec(&x);
            pool.process_plane(&x, &mut rng, &mut out);
            for (r, e) in exact.iter().enumerate() {
                assert_eq!(out[r], *e as f64, "row {r} seed {seed}");
            }
        }
    }

    #[test]
    fn nearest_neighbour_roles_alternate_across_phases() {
        // 4 arrays, SAR coupling: groups [0,1] and [2,3]. A full rotation
        // later the compute role has swapped inside each pair.
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(4);
        let mut out = vec![0.0; 32];
        let x = plane(32, 1, 0.5);
        for _ in 0..4 {
            pool.process_plane(&x, &mut rng, &mut out);
        }
        let ops: Vec<u64> = pool.arrays().iter().map(|a| a.ops()).collect();
        assert_eq!(ops, vec![1, 1, 1, 1], "each array computed exactly once");
    }

    #[test]
    fn exactly_once_accounting_holds() {
        let mut pool = ideal_pool(ImmersedMode::Hybrid { flash_bits: 2 }, 5);
        let mut rng = Rng::new(5);
        let mut out = vec![0.0; 32];
        for seed in 0..3 {
            pool.process_plane(&plane(32, seed, 0.5), &mut rng, &mut out);
        }
        assert_eq!(pool.mavs_produced(), 3 * 32);
        assert_eq!(pool.mavs_digitized(), pool.mavs_produced());
        assert_eq!(pool.stats().conversions, 3 * 32);
        assert!(pool.stats().energy_fj > 0.0);
    }

    #[test]
    fn per_mode_cycle_and_comparison_arithmetic() {
        let cases = [
            (ImmersedMode::Sar, 5u8, 5u64, 5u64),
            (ImmersedMode::Flash, 2, 1, 3),
            (ImmersedMode::Hybrid { flash_bits: 2 }, 5, 4, 6),
        ];
        for (mode, bits, cycles, comparisons) in cases {
            let mut pool = ideal_pool(mode, bits);
            let mut rng = Rng::new(6);
            let mut out = vec![0.0; 32];
            pool.process_plane(&plane(32, 2, 0.5), &mut rng, &mut out);
            let s = pool.stats();
            assert_eq!(s.conversions, 32, "{mode:?}");
            assert_eq!(s.cycles, cycles * 32, "{mode:?}");
            assert_eq!(s.comparisons, comparisons * 32, "{mode:?}");
        }
    }

    #[test]
    fn asymmetric_tree_cuts_comparisons_on_skewed_mavs() {
        let spec = PoolSpec { n_arrays: 4, adc_bits: 5, mode: ImmersedMode::Sar, asymmetric: true };
        let mut rng = Rng::new(8);
        let mut asym =
            CimArrayPool::new(&SignMatrix::walsh(32), CrossbarConfig::ideal(), spec, &mut rng);
        let mut plain = ideal_pool(ImmersedMode::Sar, 5);
        let mut out = vec![0.0; 32];
        let mut ra = Rng::new(9);
        let mut rp = Rng::new(9);
        for seed in 0..16 {
            let x = plane(32, seed, 0.5);
            asym.process_plane(&x, &mut ra, &mut out);
            plain.process_plane(&x, &mut rp, &mut out);
        }
        assert_eq!(asym.stats().conversions, plain.stats().conversions);
        assert!(
            asym.stats().comparisons < plain.stats().comparisons,
            "asymmetric {} !< symmetric {}",
            asym.stats().comparisons,
            plain.stats().comparisons
        );
    }

    #[test]
    #[should_panic(expected = "digitized twice")]
    fn double_digitization_panics() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(10);
        pool.begin_plane(32);
        pool.digitize_row(0, 0, 3, 0.4, 16.0, &mut rng);
        pool.digitize_row(0, 0, 3, 0.4, 16.0, &mut rng);
    }

    #[test]
    #[should_panic(expected = "undigitized")]
    fn missed_digitization_panics() {
        let mut pool = ideal_pool(ImmersedMode::Sar, 5);
        let mut rng = Rng::new(11);
        pool.begin_plane(32);
        pool.digitize_row(0, 0, 0, 0.4, 16.0, &mut rng);
        pool.end_plane();
    }

    #[test]
    fn begin_transform_makes_runs_reproducible() {
        let mut a = ideal_pool(ImmersedMode::Sar, 5);
        let mut b = ideal_pool(ImmersedMode::Sar, 5);
        let x = plane(32, 3, 0.5);
        let mut oa = vec![0.0; 32];
        let mut ob = vec![0.0; 32];
        // Advance `a` an odd number of phases, then rewind: results must
        // match a fresh pool's first phase.
        a.process_plane(&x, &mut Rng::new(12), &mut oa);
        a.begin_transform();
        a.process_plane(&x, &mut Rng::new(13), &mut oa);
        b.begin_transform();
        b.process_plane(&x, &mut Rng::new(13), &mut ob);
        assert_eq!(oa, ob);
    }

    #[test]
    #[should_panic(expected = "column lines")]
    fn rejects_too_few_columns_for_resolution() {
        let mut rng = Rng::new(14);
        CimArrayPool::new(
            &SignMatrix::walsh(16),
            CrossbarConfig::ideal(),
            PoolSpec { n_arrays: 4, adc_bits: 5, mode: ImmersedMode::Sar, asymmetric: false },
            &mut rng,
        );
    }

    #[test]
    fn parse_maps_cli_inputs() {
        assert_eq!(PoolSpec::parse(0, "sar", 0, false), Ok(None));
        let s = PoolSpec::parse(4, "sar", 0, true).unwrap().unwrap();
        assert_eq!((s.n_arrays, s.adc_bits, s.asymmetric), (4, 5, true));
        assert_eq!(s.mode, ImmersedMode::Sar);
        let f = PoolSpec::parse(8, "flash", 0, false).unwrap().unwrap();
        assert_eq!((f.adc_bits, f.mode), (2, ImmersedMode::Flash));
        let h = PoolSpec::parse(4, "hybrid", 4, false).unwrap().unwrap();
        assert_eq!((h.adc_bits, h.mode), (4, ImmersedMode::Hybrid { flash_bits: 2 }));
    }

    #[test]
    fn parse_rejects_bad_configurations_with_diagnostics() {
        // Typo'd mode must not silently fall back to hybrid.
        let e = PoolSpec::parse(4, "slar", 0, false).unwrap_err();
        assert!(e.contains("unknown adc mode"), "{e}");
        // Hybrid's 2-bit flash stage needs adc_bits > 2.
        let e = PoolSpec::parse(4, "hybrid", 2, false).unwrap_err();
        assert!(e.contains("narrower"), "{e}");
        // 5-bit flash needs 2^5 − 1 reference arrays + the computer.
        let e = PoolSpec::parse(4, "flash", 5, false).unwrap_err();
        assert!(e.contains("coupling group"), "{e}");
        // Asymmetric tree is a SAR-coupling technique.
        let e = PoolSpec::parse(4, "flash", 0, true).unwrap_err();
        assert!(e.contains("asymmetric"), "{e}");
        // Out-of-range resolution.
        let e = PoolSpec::parse(4, "sar", 11, false).unwrap_err();
        assert!(e.contains("1..=10"), "{e}");
    }
}
