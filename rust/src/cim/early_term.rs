//! Early termination exploiting soft-threshold output sparsity
//! (paper §III-C, Fig 6).
//!
//! The BWHT layer's soft threshold zeroes every output in the dead band
//! `|y| ≤ T`. Processing input bitplanes MSB → LSB, once a row's partial
//! reconstruction *provably* cannot leave the dead band — the remaining
//! planes contribute at most `2^p − 1` — the row's final output is zero
//! and the remaining planes need not be computed for it.
//!
//! Two policies:
//! - **Exact**: terminate only on the provable bound. Never changes the
//!   output (property-tested); saves less work.
//! - **Aggressive(margin)**: terminate when `|partial| + remaining ≤
//!   T·margin` with `margin > 1` — saves more work, may zero outputs that
//!   would have barely escaped the dead band. Training with the paper's
//!   T-polarising loss makes this safe in practice (Fig 6).

/// Early-termination policy for [`super::BitplaneEngine`].
#[derive(Debug, Clone, Copy)]
pub struct EarlyTermination {
    /// Soft threshold T of the consuming layer (dead band half-width,
    /// in the same units as the reconstructed output).
    pub threshold: f32,
    /// Bound inflation: 1.0 = provably exact skips only; > 1.0 trades
    /// accuracy for workload (terminates when `|partial| + remaining ≤
    /// T·margin`).
    pub margin: f32,
}

impl EarlyTermination {
    /// Exact (output-preserving) policy for threshold `t`.
    pub fn exact(t: f32) -> Self {
        EarlyTermination { threshold: t, margin: 1.0 }
    }

    /// Aggressive policy: also skip when the bound holds against an
    /// inflated threshold.
    pub fn aggressive(t: f32, margin: f32) -> Self {
        assert!(margin >= 1.0);
        EarlyTermination { threshold: t, margin }
    }

    /// Should a row stop, given its partial reconstruction and the max
    /// magnitude the remaining planes can still contribute?
    #[inline]
    pub fn should_terminate(&self, partial: f32, remaining_max: f32) -> bool {
        partial.abs() + remaining_max <= self.threshold * self.margin
    }
}

/// Workload statistics for one (or more, merged) bitplane transforms.
#[derive(Debug, Clone, Default)]
pub struct TermStats {
    /// Row-plane pairs actually computed.
    pub processed: u64,
    /// Row-plane pairs skipped by termination.
    pub skipped: u64,
    /// Rows that terminated early at least once.
    pub rows_terminated: u64,
    /// Whole planes skipped because every row had terminated.
    pub planes_fully_skipped: u64,
    /// Total rows and planes (for normalisation).
    pub rows: usize,
    /// Total bitplanes (for normalisation).
    pub planes: usize,
}

impl TermStats {
    /// Zeroed counters over a `rows` x `planes` problem.
    pub fn new(rows: usize, planes: usize) -> Self {
        TermStats { rows, planes, ..Default::default() }
    }

    pub(crate) fn record_processed(&mut self, _row: usize) {
        self.processed += 1;
    }

    pub(crate) fn record_skipped_row(&mut self, _row: usize) {
        self.skipped += 1;
    }

    pub(crate) fn record_terminated(&mut self, _row: usize, _at_plane: usize) {
        self.rows_terminated += 1;
    }

    pub(crate) fn record_skipped_plane(&mut self, _plane: usize, active: &[bool]) {
        self.planes_fully_skipped += 1;
        self.skipped += active.len() as u64;
    }

    /// Fraction of row-plane work avoided (0.0 = none, → 1.0 = all).
    pub fn workload_saved(&self) -> f64 {
        let total = self.processed + self.skipped;
        if total == 0 {
            0.0
        } else {
            self.skipped as f64 / total as f64
        }
    }

    /// Merge statistics from two passes (signed transforms).
    pub fn merged(&self, other: &TermStats) -> TermStats {
        TermStats {
            processed: self.processed + other.processed,
            skipped: self.skipped + other.skipped,
            rows_terminated: self.rows_terminated + other.rows_terminated,
            planes_fully_skipped: self.planes_fully_skipped + other.planes_fully_skipped,
            rows: self.rows,
            planes: self.planes + other.planes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::bitplane::BitplaneEngine;
    use crate::cim::crossbar::{Crossbar, CrossbarConfig};
    use crate::util::{prop, Rng};
    use crate::wht::soft_threshold;

    fn engine(m: usize, bits: u8, seed: u64) -> (BitplaneEngine, Rng) {
        let mut rng = Rng::new(seed);
        let xb = Crossbar::walsh(m, CrossbarConfig::ideal(), &mut rng);
        (BitplaneEngine::new(xb, bits), rng)
    }

    #[test]
    fn policy_bound_logic() {
        let et = EarlyTermination::exact(5.0);
        assert!(et.should_terminate(2.0, 3.0)); // 2+3 <= 5
        assert!(!et.should_terminate(2.1, 3.0)); // 5.1 > 5
        let ag = EarlyTermination::aggressive(5.0, 1.5);
        assert!(ag.should_terminate(4.0, 3.0)); // 7 <= 7.5
    }

    /// THE invariant: exact early termination never changes the
    /// soft-thresholded output.
    #[test]
    fn exact_termination_preserves_thresholded_output() {
        prop::check("exact ET preserves S_T(output)", 48, |rng| {
            let m = 16;
            let bits = 5u8;
            let t = (1 + rng.index(12)) as f32;
            let x: Vec<u32> = (0..m).map(|_| rng.below(1 << bits) as u32).collect();
            let seed = rng.next_u64();

            let (mut base, _) = engine(m, bits, 7);
            let mut r1 = Rng::new(seed);
            let plain = base.transform(&x, &mut r1);

            let (eng, _) = engine(m, bits, 7);
            let mut et_eng = eng.with_early_term(EarlyTermination::exact(t));
            let mut r2 = Rng::new(seed);
            let early = et_eng.transform(&x, &mut r2);

            for (r, (a, b)) in plain.values.iter().zip(&early.values).enumerate() {
                let ya = soft_threshold(*a, t);
                let yb = soft_threshold(*b, t);
                crate::prop_assert!(
                    ya == yb,
                    "row {r}: plain {a}→{ya}, early {b}→{yb} (T={t})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn larger_threshold_saves_more_work() {
        let m = 32;
        let bits = 6u8;
        let mut rng = Rng::new(11);
        let x: Vec<u32> = (0..m).map(|_| rng.below(1 << bits) as u32).collect();

        let mut saved = Vec::new();
        for t in [0.0f32, 8.0, 32.0, 64.0] {
            let (eng, _) = engine(m, bits, 13);
            let mut e = eng.with_early_term(EarlyTermination::exact(t));
            let mut r = Rng::new(17);
            let out = e.transform(&x, &mut r);
            saved.push(out.term.workload_saved());
        }
        assert!(saved.windows(2).all(|w| w[0] <= w[1]), "saved={saved:?}");
        assert_eq!(saved[0], 0.0, "T=0 must save nothing");
    }

    #[test]
    fn aggressive_saves_at_least_as_much_as_exact() {
        let m = 32;
        let bits = 6u8;
        let mut rng = Rng::new(19);
        let x: Vec<u32> = (0..m).map(|_| rng.below(1 << bits) as u32).collect();
        let t = 24.0f32;

        let (eng, _) = engine(m, bits, 23);
        let mut exact = eng.with_early_term(EarlyTermination::exact(t));
        let s_exact = exact.transform(&x, &mut Rng::new(29)).term.workload_saved();

        let (eng, _) = engine(m, bits, 23);
        let mut aggr = eng.with_early_term(EarlyTermination::aggressive(t, 2.0));
        let s_aggr = aggr.transform(&x, &mut Rng::new(29)).term.workload_saved();

        assert!(s_aggr >= s_exact, "exact {s_exact} aggressive {s_aggr}");
    }

    #[test]
    fn stats_accounting_adds_up() {
        let m = 16;
        let bits = 4u8;
        let (eng, mut rng) = engine(m, bits, 31);
        let mut e = eng.with_early_term(EarlyTermination::exact(6.0));
        let x: Vec<u32> = (0..m).map(|i| (i as u32) % 16).collect();
        let out = e.transform(&x, &mut rng);
        assert_eq!(
            out.term.processed + out.term.skipped,
            (m * bits as usize) as u64,
            "every row-plane pair is either processed or skipped"
        );
    }

    #[test]
    fn merged_stats_sum() {
        let a = TermStats {
            processed: 10,
            skipped: 2,
            rows_terminated: 1,
            planes_fully_skipped: 0,
            rows: 4,
            planes: 3,
        };
        let b = TermStats {
            processed: 8,
            skipped: 4,
            rows_terminated: 2,
            planes_fully_skipped: 1,
            rows: 4,
            planes: 3,
        };
        let m = a.merged(&b);
        assert_eq!(m.processed, 18);
        assert_eq!(m.skipped, 6);
        assert_eq!(m.rows_terminated, 3);
        assert_eq!(m.planes, 6);
    }
}
