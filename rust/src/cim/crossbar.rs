//! The analog crossbar: four-step charge-domain WHT (paper Figs 2–3).
//!
//! One [`Crossbar::process_bitplane`] call models the full four-step
//! operation on one input bitplane:
//!
//! 1. **Precharge** — BL/BLB precharged, input bits applied on CL/CLB.
//! 2. **Local compute** — every cell's O/OB node charges to the product
//!    of its ±1 weight and the input bit (on low-capacitance local nodes,
//!    not bit lines — the paper's parallelism argument).
//! 3. **Row-merge** — RM shorts all cells of a row: charge averages onto
//!    the SL/SLB sum lines. `V_SL ∝ (#{+1 cells seeing 1}) / cols`,
//!    `V_SLB ∝ (#{−1 cells seeing 1}) / cols`, attenuated by the phase's
//!    RC settling at the current operating point.
//! 4. **Compare** — the row comparator resolves `V_SL > V_SLB` into the
//!    single-bit output (extreme 1-bit product-sum quantization; no ADC).
//!
//! The same step-3 voltages, *without* step 4, are the MAV outputs of
//! [`Crossbar::compute_mav_into`]. In the pooled serving path
//! ([`super::pool::CimArrayPool`]) a neighbouring array digitizes them
//! through a memory-immersed converter ([`crate::adc::immersed`])
//! instead of step 4's 1-bit comparator.
//!
//! Hot-path shape (EXPERIMENTS.md §Perf): the allocation-free
//! [`Crossbar::process_bitplane_into`] / [`Crossbar::compute_mav_into`]
//! write into caller-owned packed buffers; per-operating-point noise
//! statistics are folded into a single Gaussian draw per row
//! ([`OpConstants`] §noise-folding); and fully noise-free configs
//! degenerate to pure popcount sign decisions with zero RNG draws.

use crate::analog::timing::Phase;
use crate::analog::{Comparator, NoiseModel, OperatingPoint, PhaseTimer, SupplyModel};
use crate::util::Rng;

use super::bitvec::{BitVec, SignMatrix};

/// Electrical configuration of a crossbar instance.
#[derive(Debug, Clone, Copy)]
pub struct CrossbarConfig {
    /// Process/voltage scaling model.
    pub supply: SupplyModel,
    /// Analog noise sources (thermal, offset).
    pub noise: NoiseModel,
    /// Supply/frequency operating point.
    pub op: OperatingPoint,
    /// Per-cell local-node capacitance (fF).
    pub c_cell_ff: f64,
}

impl Default for CrossbarConfig {
    fn default() -> Self {
        CrossbarConfig {
            supply: SupplyModel::default(),
            noise: NoiseModel::default(),
            op: OperatingPoint::crossbar_nominal(),
            c_cell_ff: 1.2,
        }
    }
}

impl CrossbarConfig {
    /// Ideal electrical config: no noise, instant settling (for oracles).
    pub fn ideal() -> Self {
        CrossbarConfig {
            supply: SupplyModel { tau0_ps: 1e-6, ..SupplyModel::default() },
            noise: NoiseModel::ideal(),
            op: OperatingPoint::sweep_nominal(),
            c_cell_ff: 1.2,
        }
    }
}

/// A programmed analog crossbar array.
#[derive(Debug, Clone)]
pub struct Crossbar {
    matrix: SignMatrix,
    cfg: CrossbarConfig,
    timer: PhaseTimer,
    comparators: Vec<Comparator>,
    energy_fj: f64,
    ops: u64,
    /// Electrical constants cached per operating point (PERF: the hot
    /// loop is per-row; `exp`/`Φ` evaluations belong out here).
    consts: OpConstants,
    /// All comparator offsets are exactly zero (sampled from an ideal
    /// noise model) — combined with `OpConstants::draw_free` this enables
    /// the pure-popcount decision path.
    zero_offset: bool,
}

/// Per-operating-point constants used in the row loop.
///
/// §noise-folding — the decision path's statistics are precomputed here.
/// The four-step decision for row `r` used to draw five Gaussians (two
/// dead-cell thinnings, two kT/C samples, one comparator noise sample).
/// All of them are independent and enter the comparator *differentially*,
/// so they fold into one zero-mean Gaussian whose variance is the sum of
/// the individual variances:
///
/// - kT/C on SL and SLB:           `2 · ktc_sigma²`
/// - comparator decision noise:    `σ_cmp²`
/// - binomial dead-cell thinning:  `base² · p(1−p) · |x|`  (both rails)
/// - Vth settling spread:          `(vdd·spread/cols)² · (1−p)·|x|`
///   (both rails; the spread acts on the *surviving* charge counts, so
///   the expected thinned count `(1−p)·|x|` replaces the per-rail
///   post-thinning counts the unfolded model used)
///
/// with `base = vdd·settle/cols` and `|x| = x.count_ones()`. The
/// count-dependent terms depend only on `|x|`, not on the row, so the
/// folded sigma is computed **once per operation** — the row loop is a
/// popcount, one Gaussian draw and a compare (EXPERIMENTS.md §Perf).
/// The only behavioural difference vs the per-rail draws is that rail
/// clamping to [0, VDD] is no longer applied between noise and compare;
/// with mV-scale noise against mid-rail signals the clamp bound with
/// negligible probability (tail effect only, statistically invisible).
#[derive(Debug, Clone, Copy)]
struct OpConstants {
    /// Combined LocalCompute × RowMergeSum settled fraction.
    settle: f64,
    /// Dead-cell probability at this VDD (0.0 below the epsilon cutoff).
    p_dead: f64,
    /// Vth-mismatch settling spread (σ of settle across cells).
    spread: f64,
    /// kT/C rms on one sum line (V); 0.0 when noise disabled.
    ktc_sigma: f64,
    /// √(2·ktc² + σ_cmp²) — the count-independent part of the folded
    /// decision sigma (V).
    dec_sigma_const: f64,
    /// True when **no** decision-path noise source needs an RNG draw:
    /// the zero-noise fast path (`CrossbarConfig::ideal`) then reduces to
    /// word-popcount `row_plus_count` sign decisions and the whole
    /// operation draws nothing from the generator.
    draw_free: bool,
}

impl OpConstants {
    fn compute(cfg: &CrossbarConfig, timer: &PhaseTimer, cols: usize) -> Self {
        let settle =
            timer.settle(Phase::LocalCompute) * timer.settle(Phase::RowMergeSum);
        let mut p_dead =
            cfg.supply.dead_cell_prob(cfg.op.vdd, cfg.noise.vth_mismatch_sigma_v);
        if p_dead < 1e-9 {
            p_dead = 0.0; // skip thinning noise draws entirely
        }
        let mut spread = cfg.supply.settle_vth_sensitivity(cfg.op.vdd, timer.step_time_ps())
            * cfg.noise.vth_mismatch_sigma_v;
        // Below ~1e-4 the induced voltage noise is < µV against mV-scale
        // LSBs — far under the kT/C floor; skip the draws.
        if spread < 1e-4 {
            spread = 0.0;
        }
        let c_line_ff = cols as f64 * cfg.c_cell_ff;
        let ktc_sigma = if cfg.noise.temp_k > 0.0 {
            crate::analog::noise::ktc_noise_v(c_line_ff, cfg.noise.temp_k)
        } else {
            0.0
        };
        let cmp_sigma = cfg.noise.comparator_noise_sigma_v;
        let dec_sigma_const =
            (2.0 * ktc_sigma * ktc_sigma + cmp_sigma * cmp_sigma).sqrt();
        let draw_free = p_dead == 0.0 && spread == 0.0 && dec_sigma_const == 0.0;
        OpConstants { settle, p_dead, spread, ktc_sigma, dec_sigma_const, draw_free }
    }

    /// Folded decision sigma (V) for an input plane with `ones` set bits.
    /// Row-independent: hoisted out of the row loop.
    #[inline]
    fn decision_sigma(&self, base: f64, spread_scale: f64, ones: f64) -> f64 {
        if self.p_dead == 0.0 && self.spread == 0.0 {
            return self.dec_sigma_const;
        }
        let mut var = self.dec_sigma_const * self.dec_sigma_const;
        if self.p_dead > 0.0 {
            var += base * base * self.p_dead * (1.0 - self.p_dead) * ones;
        }
        if self.spread > 0.0 {
            // Spread scales with the thinned (surviving) charge counts:
            // E[plus_t + minus_t] = (1−p)·|x|.
            var += spread_scale * spread_scale * (1.0 - self.p_dead) * ones;
        }
        var.sqrt()
    }
}

impl Crossbar {
    /// Fabricate a crossbar programmed with `matrix`, sampling per-row
    /// comparator offsets from the config's noise model.
    pub fn new(matrix: SignMatrix, cfg: CrossbarConfig, rng: &mut Rng) -> Self {
        let comparators: Vec<Comparator> =
            (0..matrix.rows()).map(|_| Comparator::sample(&cfg.noise, rng)).collect();
        let zero_offset = comparators.iter().all(|c| c.offset_v() == 0.0);
        let timer = PhaseTimer::new(cfg.supply, cfg.op);
        let consts = OpConstants::compute(&cfg, &timer, matrix.cols());
        Crossbar { matrix, cfg, timer, comparators, energy_fj: 0.0, ops: 0, consts, zero_offset }
    }

    /// Crossbar programmed with the sequency-ordered Walsh matrix of
    /// order `m` (the paper's frequency-transform configuration).
    pub fn walsh(m: usize, cfg: CrossbarConfig, rng: &mut Rng) -> Self {
        Crossbar::new(SignMatrix::walsh(m), cfg, rng)
    }

    /// Weight-matrix rows (inputs).
    pub fn rows(&self) -> usize {
        self.matrix.rows()
    }

    /// Weight-matrix columns (MAV outputs).
    pub fn cols(&self) -> usize {
        self.matrix.cols()
    }

    /// The programmed ±1 weight matrix.
    pub fn matrix(&self) -> &SignMatrix {
        &self.matrix
    }

    /// The electrical configuration.
    pub fn config(&self) -> &CrossbarConfig {
        &self.cfg
    }

    /// Overwrite one programmed weight (`plus == true` ⇒ +1). The fault
    /// layer uses this to apply and revert stuck-cell injections around
    /// a plane dispatch; [`OpConstants`] depend only on geometry and
    /// operating point, so nothing needs recomputation.
    pub fn set_weight(&mut self, r: usize, c: usize, plus: bool) {
        self.matrix.set(r, c, plus);
    }

    /// Re-bias the array to a new operating point (Fig 7 sweeps).
    pub fn set_operating_point(&mut self, op: OperatingPoint) {
        self.cfg.op = op;
        self.timer = PhaseTimer::new(self.cfg.supply, op);
        self.consts = OpConstants::compute(&self.cfg, &self.timer, self.matrix.cols());
    }

    /// Volts of MAV per unit positive charge count at the current
    /// operating point (`vdd · settle / cols`) — the scale the
    /// collaborative digitizer ([`super::pool::CimArrayPool`]) inverts
    /// when decoding output codes back into signed sums.
    pub fn mav_volts_per_count(&self) -> f64 {
        self.cfg.op.vdd * self.consts.settle / self.cols() as f64
    }

    /// Total switched capacitance of one operation (all cells + sum lines).
    pub fn c_op_ff(&self) -> f64 {
        let cells = (self.rows() * self.cols()) as f64 * self.cfg.c_cell_ff;
        // Sum lines add ~1 unit per column per rail.
        cells + 2.0 * self.cols() as f64 * self.cfg.c_cell_ff
    }

    /// Full four-step operation, allocation-free: one input bitplane →
    /// one packed output bit per row, written into the caller-owned
    /// `out` (resized/cleared to `rows()` bits).
    ///
    /// This is the analog inner loop. Per row it does one packed-word
    /// popcount (`row_plus_count`), at most **one** Gaussian draw (the
    /// folded decision noise, see [`OpConstants`] §noise-folding) and a
    /// comparator decision. With a draw-free config and ideal
    /// comparators (`CrossbarConfig::ideal`) the decision degenerates to
    /// the exact popcount sign `2·|plus ∩ x| > |x|` and the RNG is never
    /// touched.
    pub fn process_bitplane_into(&mut self, x: &BitVec, rng: &mut Rng, out: &mut BitVec) {
        assert_eq!(x.len(), self.cols(), "input plane length != crossbar cols");
        self.account_op();
        let rows = self.rows();
        out.reset(rows);
        let ones = x.count_ones();
        let k = self.consts;

        if k.draw_free && self.zero_offset {
            // Popcount fast path: sign of the ±1 weighted sum, exact ties
            // resolve to false exactly like the strict analog comparison.
            for r in 0..rows {
                self.comparators[r].note_decision();
                if 2 * self.matrix.row_plus_count(r, x) > ones {
                    out.set(r, true);
                }
            }
            return;
        }

        let cols = self.cols() as f64;
        let vdd = self.cfg.op.vdd;
        let base = vdd * k.settle / cols; // volts per unit charge count
        let spread_scale = vdd * k.spread / cols;
        let ones_f = ones as f64;
        // Dead-cell thinning attenuates the differential mean by (1−p);
        // its binomial variance is folded into sigma below.
        let thin = 1.0 - k.p_dead;
        let sigma = k.decision_sigma(base, spread_scale, ones_f);
        for r in 0..rows {
            let plus = self.matrix.row_plus_count(r, x) as f64;
            let minus = ones_f - plus;
            let mut diff = base * (plus - minus) * thin;
            if sigma > 0.0 {
                diff += rng.normal() * sigma;
            }
            if self.comparators[r].compare_prenoised(diff) {
                out.set(r, true);
            }
        }
    }

    /// Full four-step operation: one input bitplane → one output bit per
    /// row (`V_SL > V_SLB`, i.e. the sign of the ±1 weighted sum).
    ///
    /// Compatibility wrapper over [`Crossbar::process_bitplane_into`];
    /// allocates the `Vec<bool>` per call — hot paths should hold a
    /// packed [`BitVec`] and call the `_into` variant.
    pub fn process_bitplane(&mut self, x: &BitVec, rng: &mut Rng) -> Vec<bool> {
        let mut out = BitVec::zeros(self.rows());
        self.process_bitplane_into(x, rng, &mut out);
        (0..self.rows()).map(|r| out.get(r)).collect()
    }

    /// Steps 1–3 only, allocation-free: per-row single-ended MAV voltages
    /// `V_MAV = VDD · plus/cols · settle` written into caller-owned
    /// `out`, with the SL-side noise (thinning, kT/C, settling spread)
    /// applied per rail exactly as the analog node sees it — the outputs
    /// handed to the memory-immersed ADC (paper §IV).
    pub fn compute_mav_into(&mut self, x: &BitVec, rng: &mut Rng, out: &mut [f64]) {
        assert_eq!(x.len(), self.cols(), "input plane length != crossbar cols");
        assert_eq!(out.len(), self.rows(), "output length != crossbar rows");
        self.account_op();
        let cols = self.cols() as f64;
        let vdd = self.cfg.op.vdd;
        let k = self.consts;
        let base = vdd * k.settle / cols;
        let spread_scale = vdd * k.spread / cols;
        for (r, slot) in out.iter_mut().enumerate() {
            let mut plus = self.matrix.row_plus_count(r, x) as f64;
            // Dead-cell thinning: cells with no overdrive at this VDD
            // drop their charge (binomial, normal-approximated).
            if k.p_dead > 0.0 {
                let mean = plus * (1.0 - k.p_dead);
                let sig = (plus * k.p_dead * (1.0 - k.p_dead)).sqrt();
                plus = (mean + rng.normal() * sig).max(0.0);
            }
            let mut v = base * plus;
            if k.ktc_sigma > 0.0 {
                v += rng.normal() * k.ktc_sigma;
            }
            if k.spread > 0.0 {
                v += rng.normal() * spread_scale * plus.sqrt();
            }
            *slot = v.clamp(0.0, vdd);
        }
    }

    /// Compatibility wrapper over [`Crossbar::compute_mav_into`].
    pub fn compute_mav(&mut self, x: &BitVec, rng: &mut Rng) -> Vec<f64> {
        let mut out = vec![0.0; self.rows()];
        self.compute_mav_into(x, rng, &mut out);
        out
    }

    /// Exact digital oracle of one plane (±1 weighted sums).
    pub fn ideal_bitplane(&self, x: &BitVec) -> Vec<i32> {
        self.matrix.matvec(x)
    }

    /// Energy of one four-step op (fJ): dynamic switching of all cells.
    pub fn energy_per_op_fj(&self) -> f64 {
        let v = self.cfg.op.vdd;
        self.cfg.supply.activity * self.c_op_ff() * v * v * 1.0 // fF·V² = fJ
    }

    /// Average power (µW) at the configured clock: one four-step op takes
    /// two cycles.
    pub fn power_uw(&self) -> f64 {
        self.cfg.supply.total_power_uw(self.c_op_ff(), self.cfg.op) / 2.0
    }

    /// Accumulated energy (fJ) and op count since construction/reset.
    pub fn energy_fj(&self) -> f64 {
        self.energy_fj
    }

    /// Crossbar operations executed since the last reset.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Zero the energy/op counters.
    pub fn reset_counters(&mut self) {
        self.energy_fj = 0.0;
        self.ops = 0;
    }

    fn account_op(&mut self) {
        self.ops += 1;
        self.energy_fj += self.energy_per_op_fj();
    }

    /// Measured probability that a row output bit differs from the exact
    /// sign over random input planes — the crossbar's raw bit error rate
    /// at its operating point (drives the Fig 7 accuracy curves).
    pub fn bit_error_rate(&mut self, trials: usize, density: f64, rng: &mut Rng) -> f64 {
        let mut errs = 0usize;
        let mut total = 0usize;
        let mut x = BitVec::zeros(self.cols());
        let mut got = BitVec::zeros(self.rows());
        for _ in 0..trials {
            x.clear();
            for i in 0..self.cols() {
                if rng.bernoulli(density) {
                    x.set(i, true);
                }
            }
            let ideal = self.ideal_bitplane(&x);
            self.process_bitplane_into(&x, rng, &mut got);
            for (r, i) in ideal.iter().enumerate() {
                // Exact ties count as correct either way.
                if *i != 0 && (got.get(r) != (*i > 0)) {
                    errs += 1;
                }
                total += 1;
            }
        }
        errs as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn input(cols: usize, seed: u64, density: f64) -> BitVec {
        let mut rng = Rng::new(seed);
        BitVec::from_bits(&(0..cols).map(|_| rng.bernoulli(density)).collect::<Vec<_>>())
    }

    #[test]
    fn ideal_crossbar_matches_sign_oracle() {
        let mut rng = Rng::new(1);
        let mut xb = Crossbar::walsh(32, CrossbarConfig::ideal(), &mut rng);
        for seed in 0..20 {
            let x = input(32, seed, 0.5);
            let ideal = xb.ideal_bitplane(&x);
            let got = xb.process_bitplane(&x, &mut rng);
            for (r, (g, i)) in got.iter().zip(&ideal).enumerate() {
                if *i != 0 {
                    assert_eq!(*g, *i > 0, "row {r}: ideal {i}");
                }
            }
        }
    }

    #[test]
    fn mav_proportional_to_plus_count_when_ideal() {
        let mut rng = Rng::new(2);
        let mut xb = Crossbar::walsh(16, CrossbarConfig::ideal(), &mut rng);
        let x = input(16, 3, 0.5);
        let mav = xb.compute_mav(&x, &mut rng);
        for r in 0..16 {
            let plus = xb.matrix().row_plus_count(r, &x) as f64;
            let expect = 1.0 * plus / 16.0; // vdd=1.0 at sweep_nominal
            assert!((mav[r] - expect).abs() < 1e-6, "row {r}");
        }
    }

    #[test]
    fn energy_accumulates_per_op() {
        let mut rng = Rng::new(3);
        let mut xb = Crossbar::walsh(16, CrossbarConfig::default(), &mut rng);
        let x = input(16, 4, 0.5);
        xb.process_bitplane(&x, &mut rng);
        xb.process_bitplane(&x, &mut rng);
        assert_eq!(xb.ops(), 2);
        assert!((xb.energy_fj() - 2.0 * xb.energy_per_op_fj()).abs() < 1e-9);
        xb.reset_counters();
        assert_eq!(xb.ops(), 0);
    }

    #[test]
    fn low_vdd_raises_bit_error_rate() {
        let mut rng = Rng::new(5);
        let mut nominal = Crossbar::walsh(32, CrossbarConfig::default(), &mut rng);
        let ber_nom = nominal.bit_error_rate(60, 0.5, &mut rng);
        let mut starved = Crossbar::walsh(
            32,
            CrossbarConfig {
                op: OperatingPoint::new(0.5, 4.0),
                ..CrossbarConfig::default()
            },
            &mut rng,
        );
        let ber_low = starved.bit_error_rate(60, 0.5, &mut rng);
        assert!(
            ber_low > ber_nom,
            "expected degradation: nominal {ber_nom} vs 0.5V {ber_low}"
        );
    }

    #[test]
    fn bigger_clock_does_not_improve_accuracy() {
        let mut rng = Rng::new(6);
        let cfg_slow = CrossbarConfig { op: OperatingPoint::new(0.85, 1.0), ..Default::default() };
        let cfg_fast = CrossbarConfig { op: OperatingPoint::new(0.85, 12.0), ..Default::default() };
        let mut slow = Crossbar::walsh(32, cfg_slow, &mut rng);
        let mut fast = Crossbar::walsh(32, cfg_fast, &mut rng);
        let ber_slow = slow.bit_error_rate(60, 0.5, &mut rng);
        let ber_fast = fast.bit_error_rate(60, 0.5, &mut rng);
        assert!(ber_fast >= ber_slow, "slow {ber_slow} fast {ber_fast}");
    }

    #[test]
    fn power_grows_with_array_size() {
        let mut rng = Rng::new(7);
        let small = Crossbar::walsh(16, CrossbarConfig::default(), &mut rng);
        let large = Crossbar::walsh(128, CrossbarConfig::default(), &mut rng);
        assert!(large.power_uw() > small.power_uw());
    }

    #[test]
    fn packed_into_matches_vec_wrapper() {
        // Same fabricated crossbar + same decision rng stream ⇒ the
        // packed and Vec<bool> paths must agree bit for bit, noisy or not.
        for cfg in [CrossbarConfig::default(), CrossbarConfig::ideal()] {
            let mut xa = Crossbar::walsh(64, cfg, &mut Rng::new(11));
            let mut xb = Crossbar::walsh(64, cfg, &mut Rng::new(11));
            let mut ra = Rng::new(99);
            let mut rb = Rng::new(99);
            let mut packed = BitVec::zeros(64);
            for seed in 0..10 {
                let x = input(64, seed, 0.4);
                let unpacked = xa.process_bitplane(&x, &mut ra);
                xb.process_bitplane_into(&x, &mut rb, &mut packed);
                for (r, u) in unpacked.iter().enumerate() {
                    assert_eq!(*u, packed.get(r), "row {r} seed {seed}");
                }
            }
            assert_eq!(xa.ops(), xb.ops());
        }
    }

    #[test]
    fn ideal_fast_path_draws_nothing_from_rng() {
        let mut rng = Rng::new(21);
        let mut xb = Crossbar::walsh(32, CrossbarConfig::ideal(), &mut rng);
        let x = input(32, 1, 0.5);
        let mut r = Rng::new(5);
        let mut witness = r.clone();
        let mut out = BitVec::zeros(32);
        xb.process_bitplane_into(&x, &mut r, &mut out);
        // The draw-free path must leave the generator untouched.
        assert_eq!(r.next_u64(), witness.next_u64());
    }

    #[test]
    fn mav_into_matches_vec_wrapper() {
        let mut xa = Crossbar::walsh(32, CrossbarConfig::default(), &mut Rng::new(13));
        let mut xb = Crossbar::walsh(32, CrossbarConfig::default(), &mut Rng::new(13));
        let x = input(32, 2, 0.5);
        let a = xa.compute_mav(&x, &mut Rng::new(31));
        let mut b = vec![0.0; 32];
        xb.compute_mav_into(&x, &mut Rng::new(31), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn noisy_decisions_flip_near_ties_only() {
        // Default config at nominal: rows with a large |±1 sum| must be
        // decided correctly despite the folded noise draw (the folded
        // sigma is sub-mV vs ~tens-of-mV signal LSBs).
        let mut rng = Rng::new(17);
        let mut xb = Crossbar::walsh(128, CrossbarConfig::default(), &mut rng);
        let x = input(128, 3, 0.5);
        let ideal = xb.ideal_bitplane(&x);
        let mut out = BitVec::zeros(128);
        let mut r = Rng::new(23);
        for _ in 0..20 {
            xb.process_bitplane_into(&x, &mut r, &mut out);
            for (row, i) in ideal.iter().enumerate() {
                if i.unsigned_abs() >= 8 {
                    assert_eq!(out.get(row), *i > 0, "row {row} ideal {i}");
                }
            }
        }
    }

    #[test]
    fn prop_ideal_outputs_match_oracle_signs() {
        prop::check("crossbar ideal == oracle", 64, |rng| {
            let m = 1usize << (2 + rng.index(4)); // 4..32
            let mut xb = Crossbar::walsh(m, CrossbarConfig::ideal(), rng);
            let bits: Vec<bool> = (0..m).map(|_| rng.bool()).collect();
            let x = BitVec::from_bits(&bits);
            let ideal = xb.ideal_bitplane(&x);
            let got = xb.process_bitplane(&x, rng);
            for (r, (g, i)) in got.iter().zip(&ideal).enumerate() {
                if *i != 0 {
                    crate::prop_assert!(*g == (*i > 0), "m={m} row={r} ideal={i} got={g}");
                }
            }
            Ok(())
        });
    }
}
