//! The analog crossbar: four-step charge-domain WHT (paper Figs 2–3).
//!
//! One [`Crossbar::process_bitplane`] call models the full four-step
//! operation on one input bitplane:
//!
//! 1. **Precharge** — BL/BLB precharged, input bits applied on CL/CLB.
//! 2. **Local compute** — every cell's O/OB node charges to the product
//!    of its ±1 weight and the input bit (on low-capacitance local nodes,
//!    not bit lines — the paper's parallelism argument).
//! 3. **Row-merge** — RM shorts all cells of a row: charge averages onto
//!    the SL/SLB sum lines. `V_SL ∝ (#{+1 cells seeing 1}) / cols`,
//!    `V_SLB ∝ (#{−1 cells seeing 1}) / cols`, attenuated by the phase's
//!    RC settling at the current operating point.
//! 4. **Compare** — the row comparator resolves `V_SL > V_SLB` into the
//!    single-bit output (extreme 1-bit product-sum quantization; no ADC).
//!
//! The same step-3 voltages, *without* step 4, are the MAV outputs the
//! memory-immersed ADC digitizes in [`crate::adc::immersed`].

use crate::analog::timing::Phase;
use crate::analog::{Comparator, NoiseModel, OperatingPoint, PhaseTimer, SupplyModel};
use crate::util::Rng;

use super::bitvec::{BitVec, SignMatrix};

/// Electrical configuration of a crossbar instance.
#[derive(Debug, Clone, Copy)]
pub struct CrossbarConfig {
    pub supply: SupplyModel,
    pub noise: NoiseModel,
    pub op: OperatingPoint,
    /// Per-cell local-node capacitance (fF).
    pub c_cell_ff: f64,
}

impl Default for CrossbarConfig {
    fn default() -> Self {
        CrossbarConfig {
            supply: SupplyModel::default(),
            noise: NoiseModel::default(),
            op: OperatingPoint::crossbar_nominal(),
            c_cell_ff: 1.2,
        }
    }
}

impl CrossbarConfig {
    /// Ideal electrical config: no noise, instant settling (for oracles).
    pub fn ideal() -> Self {
        CrossbarConfig {
            supply: SupplyModel { tau0_ps: 1e-6, ..SupplyModel::default() },
            noise: NoiseModel::ideal(),
            op: OperatingPoint::sweep_nominal(),
            c_cell_ff: 1.2,
        }
    }
}

/// A programmed analog crossbar array.
#[derive(Debug, Clone)]
pub struct Crossbar {
    matrix: SignMatrix,
    cfg: CrossbarConfig,
    timer: PhaseTimer,
    comparators: Vec<Comparator>,
    energy_fj: f64,
    ops: u64,
    /// Electrical constants cached per operating point (PERF: the hot
    /// loop is per-row; `exp`/`Φ` evaluations belong out here).
    consts: OpConstants,
}

/// Per-operating-point constants used in the row loop.
#[derive(Debug, Clone, Copy)]
struct OpConstants {
    /// Combined LocalCompute × RowMergeSum settled fraction.
    settle: f64,
    /// Dead-cell probability at this VDD (0.0 below the epsilon cutoff).
    p_dead: f64,
    /// Vth-mismatch settling spread (σ of settle across cells).
    spread: f64,
    /// kT/C rms on one sum line (V); 0.0 when noise disabled.
    ktc_sigma: f64,
}

impl OpConstants {
    fn compute(cfg: &CrossbarConfig, timer: &PhaseTimer, cols: usize) -> Self {
        let settle =
            timer.settle(Phase::LocalCompute) * timer.settle(Phase::RowMergeSum);
        let mut p_dead =
            cfg.supply.dead_cell_prob(cfg.op.vdd, cfg.noise.vth_mismatch_sigma_v);
        if p_dead < 1e-9 {
            p_dead = 0.0; // skip thinning noise draws entirely
        }
        let mut spread = cfg.supply.settle_vth_sensitivity(cfg.op.vdd, timer.step_time_ps())
            * cfg.noise.vth_mismatch_sigma_v;
        // Below ~1e-4 the induced voltage noise is < µV against mV-scale
        // LSBs — far under the kT/C floor; skip the draws.
        if spread < 1e-4 {
            spread = 0.0;
        }
        let c_line_ff = cols as f64 * cfg.c_cell_ff;
        let ktc_sigma = if cfg.noise.temp_k > 0.0 {
            crate::analog::noise::ktc_noise_v(c_line_ff, cfg.noise.temp_k)
        } else {
            0.0
        };
        OpConstants { settle, p_dead, spread, ktc_sigma }
    }
}

impl Crossbar {
    /// Fabricate a crossbar programmed with `matrix`, sampling per-row
    /// comparator offsets from the config's noise model.
    pub fn new(matrix: SignMatrix, cfg: CrossbarConfig, rng: &mut Rng) -> Self {
        let comparators =
            (0..matrix.rows()).map(|_| Comparator::sample(&cfg.noise, rng)).collect();
        let timer = PhaseTimer::new(cfg.supply, cfg.op);
        let consts = OpConstants::compute(&cfg, &timer, matrix.cols());
        Crossbar { matrix, cfg, timer, comparators, energy_fj: 0.0, ops: 0, consts }
    }

    /// Crossbar programmed with the sequency-ordered Walsh matrix of
    /// order `m` (the paper's frequency-transform configuration).
    pub fn walsh(m: usize, cfg: CrossbarConfig, rng: &mut Rng) -> Self {
        Crossbar::new(SignMatrix::walsh(m), cfg, rng)
    }

    pub fn rows(&self) -> usize {
        self.matrix.rows()
    }

    pub fn cols(&self) -> usize {
        self.matrix.cols()
    }

    pub fn matrix(&self) -> &SignMatrix {
        &self.matrix
    }

    pub fn config(&self) -> &CrossbarConfig {
        &self.cfg
    }

    /// Re-bias the array to a new operating point (Fig 7 sweeps).
    pub fn set_operating_point(&mut self, op: OperatingPoint) {
        self.cfg.op = op;
        self.timer = PhaseTimer::new(self.cfg.supply, op);
        self.consts = OpConstants::compute(&self.cfg, &self.timer, self.matrix.cols());
    }

    /// Total switched capacitance of one operation (all cells + sum lines).
    pub fn c_op_ff(&self) -> f64 {
        let cells = (self.rows() * self.cols()) as f64 * self.cfg.c_cell_ff;
        // Sum lines add ~1 unit per column per rail.
        cells + 2.0 * self.cols() as f64 * self.cfg.c_cell_ff
    }

    /// Analog differential sum-line voltages `(V_SL, V_SLB)` for row `r`
    /// under input plane `x` — steps 1–3 of the operation.
    fn row_sum_voltages(&self, r: usize, x: &BitVec, rng: &mut Rng) -> (f64, f64) {
        let cols = self.cols() as f64;
        let k = self.consts;
        let mut plus = self.matrix.row_plus_count(r, x) as f64;
        let ones = x.count_ones() as f64;
        let mut minus = ones - plus;
        // Dead-cell thinning: cells with no overdrive at this VDD drop
        // their charge. The mean attenuation is common-mode (same factor
        // on both rails) but the binomial thinning *variance* is not —
        // it is the dominant error source at low VDD (Fig 7(a) cliff).
        if k.p_dead > 0.0 {
            let thin = |count: f64, rng: &mut Rng| -> f64 {
                let mean = count * (1.0 - k.p_dead);
                let sigma = (count * k.p_dead * (1.0 - k.p_dead)).sqrt();
                (mean + rng.normal() * sigma).max(0.0)
            };
            plus = thin(plus, rng);
            minus = thin(minus, rng);
        }
        let vdd = self.cfg.op.vdd;
        // Per-cell Vth mismatch spreads the settled fractions; the spread
        // averages as 1/√count onto each sum line and does NOT cancel in
        // the differential pair — this is the low-VDD error mechanism.
        // All σ constants are precomputed per operating point (PERF).
        let mut v_sl = vdd * (plus / cols) * k.settle;
        let mut v_slb = vdd * (minus / cols) * k.settle;
        if k.ktc_sigma > 0.0 {
            v_sl += rng.normal() * k.ktc_sigma;
            v_slb += rng.normal() * k.ktc_sigma;
        }
        if k.spread > 0.0 {
            let scale = vdd * k.spread / cols;
            v_sl += rng.normal() * scale * plus.sqrt();
            v_slb += rng.normal() * scale * minus.sqrt();
        }
        (v_sl.clamp(0.0, vdd), v_slb.clamp(0.0, vdd))
    }

    /// Full four-step operation: one input bitplane → one output bit per
    /// row (`V_SL > V_SLB`, i.e. the sign of the ±1 weighted sum).
    pub fn process_bitplane(&mut self, x: &BitVec, rng: &mut Rng) -> Vec<bool> {
        self.account_op();
        (0..self.rows())
            .map(|r| {
                let (sl, slb) = self.row_sum_voltages(r, x, rng);
                self.comparators[r].compare(sl, slb, rng)
            })
            .collect()
    }

    /// Steps 1–3 only: per-row single-ended MAV voltages
    /// `V_MAV = VDD · plus/cols · settle` — the analog outputs handed to
    /// the memory-immersed ADC (paper §IV).
    pub fn compute_mav(&mut self, x: &BitVec, rng: &mut Rng) -> Vec<f64> {
        self.account_op();
        (0..self.rows()).map(|r| self.row_sum_voltages(r, x, rng).0).collect()
    }

    /// Exact digital oracle of one plane (±1 weighted sums).
    pub fn ideal_bitplane(&self, x: &BitVec) -> Vec<i32> {
        self.matrix.matvec(x)
    }

    /// Energy of one four-step op (fJ): dynamic switching of all cells.
    pub fn energy_per_op_fj(&self) -> f64 {
        let v = self.cfg.op.vdd;
        self.cfg.supply.activity * self.c_op_ff() * v * v * 1.0 // fF·V² = fJ
    }

    /// Average power (µW) at the configured clock: one four-step op takes
    /// two cycles.
    pub fn power_uw(&self) -> f64 {
        self.cfg.supply.total_power_uw(self.c_op_ff(), self.cfg.op) / 2.0
    }

    /// Accumulated energy (fJ) and op count since construction/reset.
    pub fn energy_fj(&self) -> f64 {
        self.energy_fj
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }

    pub fn reset_counters(&mut self) {
        self.energy_fj = 0.0;
        self.ops = 0;
    }

    fn account_op(&mut self) {
        self.ops += 1;
        self.energy_fj += self.energy_per_op_fj();
    }

    /// Measured probability that a row output bit differs from the exact
    /// sign over random input planes — the crossbar's raw bit error rate
    /// at its operating point (drives the Fig 7 accuracy curves).
    pub fn bit_error_rate(&mut self, trials: usize, density: f64, rng: &mut Rng) -> f64 {
        let mut errs = 0usize;
        let mut total = 0usize;
        for _ in 0..trials {
            let bits: Vec<bool> = (0..self.cols()).map(|_| rng.bernoulli(density)).collect();
            let x = BitVec::from_bits(&bits);
            let ideal = self.ideal_bitplane(&x);
            let got = self.process_bitplane(&x, rng);
            for (g, i) in got.iter().zip(&ideal) {
                // Exact ties count as correct either way.
                if *i != 0 && (*g != (*i > 0)) {
                    errs += 1;
                }
                total += 1;
            }
        }
        errs as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn input(cols: usize, seed: u64, density: f64) -> BitVec {
        let mut rng = Rng::new(seed);
        BitVec::from_bits(&(0..cols).map(|_| rng.bernoulli(density)).collect::<Vec<_>>())
    }

    #[test]
    fn ideal_crossbar_matches_sign_oracle() {
        let mut rng = Rng::new(1);
        let mut xb = Crossbar::walsh(32, CrossbarConfig::ideal(), &mut rng);
        for seed in 0..20 {
            let x = input(32, seed, 0.5);
            let ideal = xb.ideal_bitplane(&x);
            let got = xb.process_bitplane(&x, &mut rng);
            for (r, (g, i)) in got.iter().zip(&ideal).enumerate() {
                if *i != 0 {
                    assert_eq!(*g, *i > 0, "row {r}: ideal {i}");
                }
            }
        }
    }

    #[test]
    fn mav_proportional_to_plus_count_when_ideal() {
        let mut rng = Rng::new(2);
        let mut xb = Crossbar::walsh(16, CrossbarConfig::ideal(), &mut rng);
        let x = input(16, 3, 0.5);
        let mav = xb.compute_mav(&x, &mut rng);
        for r in 0..16 {
            let plus = xb.matrix().row_plus_count(r, &x) as f64;
            let expect = 1.0 * plus / 16.0; // vdd=1.0 at sweep_nominal
            assert!((mav[r] - expect).abs() < 1e-6, "row {r}");
        }
    }

    #[test]
    fn energy_accumulates_per_op() {
        let mut rng = Rng::new(3);
        let mut xb = Crossbar::walsh(16, CrossbarConfig::default(), &mut rng);
        let x = input(16, 4, 0.5);
        xb.process_bitplane(&x, &mut rng);
        xb.process_bitplane(&x, &mut rng);
        assert_eq!(xb.ops(), 2);
        assert!((xb.energy_fj() - 2.0 * xb.energy_per_op_fj()).abs() < 1e-9);
        xb.reset_counters();
        assert_eq!(xb.ops(), 0);
    }

    #[test]
    fn low_vdd_raises_bit_error_rate() {
        let mut rng = Rng::new(5);
        let mut nominal = Crossbar::walsh(32, CrossbarConfig::default(), &mut rng);
        let ber_nom = nominal.bit_error_rate(60, 0.5, &mut rng);
        let mut starved = Crossbar::walsh(
            32,
            CrossbarConfig {
                op: OperatingPoint::new(0.5, 4.0),
                ..CrossbarConfig::default()
            },
            &mut rng,
        );
        let ber_low = starved.bit_error_rate(60, 0.5, &mut rng);
        assert!(
            ber_low > ber_nom,
            "expected degradation: nominal {ber_nom} vs 0.5V {ber_low}"
        );
    }

    #[test]
    fn bigger_clock_does_not_improve_accuracy() {
        let mut rng = Rng::new(6);
        let cfg_slow = CrossbarConfig { op: OperatingPoint::new(0.85, 1.0), ..Default::default() };
        let cfg_fast = CrossbarConfig { op: OperatingPoint::new(0.85, 12.0), ..Default::default() };
        let mut slow = Crossbar::walsh(32, cfg_slow, &mut rng);
        let mut fast = Crossbar::walsh(32, cfg_fast, &mut rng);
        let ber_slow = slow.bit_error_rate(60, 0.5, &mut rng);
        let ber_fast = fast.bit_error_rate(60, 0.5, &mut rng);
        assert!(ber_fast >= ber_slow, "slow {ber_slow} fast {ber_fast}");
    }

    #[test]
    fn power_grows_with_array_size() {
        let mut rng = Rng::new(7);
        let small = Crossbar::walsh(16, CrossbarConfig::default(), &mut rng);
        let large = Crossbar::walsh(128, CrossbarConfig::default(), &mut rng);
        assert!(large.power_uw() > small.power_uw());
    }

    #[test]
    fn prop_ideal_outputs_match_oracle_signs() {
        prop::check("crossbar ideal == oracle", 64, |rng| {
            let m = 1usize << (2 + rng.index(4)); // 4..32
            let mut xb = Crossbar::walsh(m, CrossbarConfig::ideal(), rng);
            let bits: Vec<bool> = (0..m).map(|_| rng.bool()).collect();
            let x = BitVec::from_bits(&bits);
            let ideal = xb.ideal_bitplane(&x);
            let got = xb.process_bitplane(&x, rng);
            for (r, (g, i)) in got.iter().zip(&ideal).enumerate() {
                if *i != 0 {
                    crate::prop_assert!(*g == (*i > 0), "m={m} row={r} ideal={i} got={g}");
                }
            }
            Ok(())
        });
    }
}
