//! Fig 6: early-termination — threshold distribution under the
//! T-widening loss, workload reduction, and accuracy retention.

use crate::cim::{CrossbarConfig, EarlyTermination};
use crate::nn::train::evaluate;
use crate::util::stats::Histogram;

use super::support::{analog_accuracy, trained_digit_mlp};

/// Render Fig 6: soft-threshold (unique-loss) training sweep.
pub fn generate() -> String {
    let mut out = String::new();
    out.push_str("Fig 6 — early termination via soft-threshold sparsity\n\n");

    // (a) Threshold distributions: plain vs T-regularised training.
    for (label, t_reg) in [("plain loss", 0.0f32), ("T-widening loss", 0.02)] {
        let (mut model, _te, _acc) = trained_digit_mlp(5, 5, t_reg);
        let mut hist = Histogram::new(-0.1, 1.5, 8);
        model.for_each_bwht(|b| {
            for &t in b.thresholds() {
                hist.push(t.abs() as f64);
            }
        });
        out.push_str(&format!("|T| distribution after training ({label}):\n"));
        out.push_str(&hist.ascii(30));
        out.push('\n');
    }

    // (b) Workload reduction + accuracy vs termination aggressiveness.
    out.push_str("early termination on the analog path (4-bit inputs):\n");
    out.push_str(&format!(
        "{:<26} {:>10} {:>12}\n",
        "policy", "test acc", "work saved"
    ));
    let (mut model, te, acc_f) = trained_digit_mlp(5, 5, 0.02);
    let cfg = CrossbarConfig::default();
    let policies: [(&str, Option<EarlyTermination>); 4] = [
        ("no termination", None),
        ("exact (T)", Some(EarlyTermination::exact(6.0))),
        ("aggressive 1.5x", Some(EarlyTermination::aggressive(6.0, 1.5))),
        ("aggressive 3x", Some(EarlyTermination::aggressive(6.0, 3.0))),
    ];
    for (name, et) in policies {
        model.for_each_bwht(|b| {
            b.term_processed = 0;
            b.term_skipped = 0;
        });
        let acc = analog_accuracy(&mut model, &te, cfg, 4, et, 17);
        let (mut processed, mut skipped) = (0u64, 0u64);
        model.for_each_bwht(|b| {
            processed += b.term_processed;
            skipped += b.term_skipped;
        });
        let saved = skipped as f64 / (processed + skipped).max(1) as f64;
        out.push_str(&format!("{name:<26} {acc:>10.3} {:>11.1}%\n", saved * 100.0));
    }
    let _ = evaluate(&mut model, &te);
    out.push_str(&format!(
        "\nfloat reference acc {acc_f:.3}; paper shape: the T-polarising loss widens\n"
    ));
    out.push_str("dead bands, so bitplane processing terminates early with little accuracy cost\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_reports_policies_and_histograms() {
        let r = super::generate();
        assert!(r.contains("no termination"));
        assert!(r.contains("aggressive 3x"));
        assert!(r.contains("|T| distribution"));
    }
}
