//! Fig 13: design-space exploration of the memory-immersed ADC —
//! (a) area vs precision, (b) latency vs precision, (c) accuracy/power
//! vs clock, (d) accuracy/power vs supply voltage.

use crate::analog::{OperatingPoint, SupplyModel};
use crate::cim::CrossbarConfig;
use crate::energy::{adc_area_um2, adc_latency_cycles, AdcStyle};

use super::support::{analog_accuracy, trained_digit_mlp};

/// Render Fig 13: end-to-end analog accuracy vs ADC configuration.
pub fn generate() -> String {
    let mut out = String::new();

    // (a) area vs bit precision.
    out.push_str("Fig 13(a) — area (µm²) vs bit precision\n\n");
    out.push_str(&format!("{:>5}", "bits"));
    for s in AdcStyle::ALL {
        out.push_str(&format!(" {:>28}", s.name()));
    }
    out.push('\n');
    for bits in 3..=8u8 {
        out.push_str(&format!("{bits:>5}"));
        for s in AdcStyle::ALL {
            out.push_str(&format!(" {:>28.1}", adc_area_um2(s, bits)));
        }
        out.push('\n');
    }

    // (b) latency vs bit precision.
    out.push_str("\nFig 13(b) — latency (cycles) vs bit precision\n\n");
    out.push_str(&format!("{:>5}", "bits"));
    for s in AdcStyle::ALL {
        out.push_str(&format!(" {:>28}", s.name()));
    }
    out.push('\n');
    for bits in 3..=8u8 {
        out.push_str(&format!("{bits:>5}"));
        for s in AdcStyle::ALL {
            out.push_str(&format!(" {:>28}", adc_latency_cycles(s, bits)));
        }
        out.push('\n');
    }

    // (c, d): digit-recognition accuracy + power on the in-memory path.
    let (mut model, te, acc_f) = trained_digit_mlp(13, 5, 0.0);
    let supply = SupplyModel::default();
    let c_adc_ff = 32.0 * 20.0; // column-line DAC capacitance

    out.push_str(&format!(
        "\nFig 13(c) — in-memory ADC: digit accuracy & power vs frequency (1 V)\n  float reference acc {acc_f:.3}\n"
    ));
    out.push_str(&format!("{:>8} {:>10} {:>12}\n", "GHz", "acc", "power µW"));
    for ghz in [0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 5.0] {
        let op = OperatingPoint::new(1.0, ghz);
        let cfg = CrossbarConfig { op, ..Default::default() };
        let acc = analog_accuracy(&mut model, &te, cfg, 4, None, 51);
        let p = supply.total_power_uw(c_adc_ff, op);
        out.push_str(&format!("{ghz:>8.2} {acc:>10.3} {p:>12.2}\n"));
    }

    out.push_str("\nFig 13(d) — in-memory ADC: digit accuracy & power vs VDD (1 GHz)\n");
    out.push_str(&format!("{:>8} {:>10} {:>12}\n", "VDD", "acc", "power µW"));
    for vdd in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1, 1.2] {
        let op = OperatingPoint::new(vdd, 1.0);
        let cfg = CrossbarConfig { op, ..Default::default() };
        let acc = analog_accuracy(&mut model, &te, cfg, 4, None, 53);
        let p = supply.total_power_uw(c_adc_ff, op);
        out.push_str(&format!("{vdd:>8.2} {acc:>10.3} {p:>12.2}\n"));
    }
    out.push_str("\npaper shape: flash area/energy explode with precision while the immersed\n");
    out.push_str("converter stays flat; hybrid sits between SAR and flash on latency;\n");
    out.push_str("accuracy holds until VDD/frequency margins collapse\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig13_has_four_panels() {
        let r = super::generate();
        assert!(r.contains("Fig 13(a)"));
        assert!(r.contains("Fig 13(b)"));
        assert!(r.contains("Fig 13(c)"));
        assert!(r.contains("Fig 13(d)"));
    }
}
