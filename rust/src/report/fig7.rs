//! Fig 7: CIM accuracy & power vs (a) supply voltage, (b) array size,
//! (c) clock frequency.

use crate::analog::{OperatingPoint, SupplyModel};
use crate::cim::{Crossbar, CrossbarConfig};
use crate::util::Rng;

use super::support::{analog_accuracy, trained_digit_mlp};

fn power_uw(rows: usize, cols: usize, op: OperatingPoint) -> f64 {
    let mut rng = Rng::new(1);
    let mut xb = Crossbar::walsh(cols.max(rows), CrossbarConfig::default(), &mut rng);
    xb.set_operating_point(op);
    xb.power_uw()
}

/// Render Fig 7: crossbar power across supply/frequency points.
pub fn generate() -> String {
    let mut out = String::new();
    out.push_str("Fig 7 — CIM architecture sweeps (digit workload through the analog path)\n\n");
    let (mut model, te, acc_f) = trained_digit_mlp(7, 5, 0.0);
    out.push_str(&format!("float reference accuracy: {acc_f:.3}\n"));

    // (a) VDD sweep at 1 GHz, 32x32.
    out.push_str("\n(a) supply voltage sweep (1 GHz, 32x32):\n");
    out.push_str(&format!("{:>6} {:>10} {:>12}\n", "VDD", "acc", "power µW"));
    for vdd in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1, 1.2, 1.3] {
        let op = OperatingPoint::new(vdd, 1.0);
        let cfg = CrossbarConfig { op, ..Default::default() };
        let acc = analog_accuracy(&mut model, &te, cfg, 4, None, 21);
        out.push_str(&format!("{vdd:>6.2} {acc:>10.3} {:>12.1}\n", power_uw(32, 32, op)));
    }

    // (b) array size sweep at 1 V, 1 GHz.
    out.push_str("\n(b) array size sweep (1 V, 1 GHz):\n");
    out.push_str(&format!("{:>10} {:>10} {:>12}\n", "size", "acc", "power µW"));
    let op = OperatingPoint::sweep_nominal();
    for size in [16usize, 32, 64, 128] {
        // Accuracy: the MLP's 32-wide hidden layer runs on one block of
        // a `size`-wide crossbar — accuracy persistence across sizes is
        // the paper's point; we test the noise scaling at each size by
        // measuring raw bit error of the matching crossbar.
        let mut rng = Rng::new(31);
        let mut xb = Crossbar::walsh(size, CrossbarConfig { op, ..Default::default() }, &mut rng);
        let ber = xb.bit_error_rate(40, 0.5, &mut rng);
        let cfg = CrossbarConfig { op, ..Default::default() };
        let acc = analog_accuracy(&mut model, &te, cfg, 4, None, 33);
        out.push_str(&format!(
            "{:>7}x{:<3} {acc:>9.3} {:>12.1}   (raw bit-error {ber:.4})\n",
            size, size,
            power_uw(size, size, op)
        ));
    }

    // (c) clock sweep at 1 V, 32x32.
    out.push_str("\n(c) clock frequency sweep (1 V, 32x32):\n");
    out.push_str(&format!("{:>8} {:>10} {:>12}\n", "GHz", "acc", "power µW"));
    let supply = SupplyModel::default();
    let _ = supply;
    for ghz in [0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0] {
        let op = OperatingPoint::new(1.0, ghz);
        let cfg = CrossbarConfig { op, ..Default::default() };
        let acc = analog_accuracy(&mut model, &te, cfg, 4, None, 43);
        out.push_str(&format!("{ghz:>8.1} {acc:>10.3} {:>12.1}\n", power_uw(32, 32, op)));
    }
    out.push_str("\npaper shape: accuracy collapses below ~0.7 V; power escalates sharply at\n");
    out.push_str("1.3 V and beyond ~2.5 GHz; accuracy persists across array sizes\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig7_has_three_sweeps() {
        let r = super::generate();
        assert!(r.contains("(a) supply voltage"));
        assert!(r.contains("(b) array size"));
        assert!(r.contains("(c) clock frequency"));
    }
}
