//! Table I: 5-bit ADC comparison (area, energy) — model anchors plus the
//! behavioural converter's measured comparison counts.

use crate::adc::{binomial_mav_pmf, AsymmetricSearch, ImmersedAdc, ImmersedMode};
use crate::analog::NoiseModel;
use crate::energy::{adc_area_um2, adc_energy_pj, AdcStyle};
use crate::util::Rng;

/// Render the paper's Table I: per-style ADC area/energy at matched bits.
pub fn generate() -> String {
    let bits = 5u8;
    let mut out = String::new();
    out.push_str("Table I — 5-bit ADC comparison at 10 MHz (paper anchors reproduced by the\n");
    out.push_str("component area/energy model; ratios are the paper's headline claims)\n\n");
    out.push_str(&format!(
        "{:<30} {:>8} {:>12} {:>10}\n",
        "Architecture", "Tech", "Area (µm²)", "Energy (pJ)"
    ));
    let rows = [
        (AdcStyle::Sar, "40 nm"),
        (AdcStyle::Flash, "40 nm"),
        (AdcStyle::InMemorySar, "65 nm"),
    ];
    for (style, tech) in rows {
        out.push_str(&format!(
            "{:<30} {:>8} {:>12.2} {:>10.2}\n",
            style.name(),
            tech,
            adc_area_um2(style, bits),
            adc_energy_pj(style, bits)
        ));
    }
    let ours_a = adc_area_um2(AdcStyle::InMemorySar, bits);
    let ours_e = adc_energy_pj(AdcStyle::InMemorySar, bits);
    out.push_str(&format!(
        "\nratios vs ours: SAR {:.1}x area / {:.2}x energy; Flash {:.1}x area / {:.1}x energy\n",
        adc_area_um2(AdcStyle::Sar, bits) / ours_a,
        adc_energy_pj(AdcStyle::Sar, bits) / ours_e,
        adc_area_um2(AdcStyle::Flash, bits) / ours_a,
        adc_energy_pj(AdcStyle::Flash, bits) / ours_e,
    ));
    out.push_str("paper:          SAR ~25x area / ~1.4x energy; Flash ~51x area / ~13x energy\n");

    // Behavioural cross-check: measured per-conversion comparator work.
    let mut rng = Rng::new(0x7ab1);
    let noise = NoiseModel::default();
    let mut sar = ImmersedAdc::sample(bits, 1.0, ImmersedMode::Sar, 32, 20.0, &noise, &mut rng);
    let mut hybrid = ImmersedAdc::sample(
        bits,
        1.0,
        ImmersedMode::Hybrid { flash_bits: 2 },
        32,
        20.0,
        &noise,
        &mut rng,
    );
    let tree = AsymmetricSearch::build(bits, &binomial_mav_pmf(32, 0.5, bits));
    let trials = 500;
    let mut cmp_sar = 0u64;
    let mut cmp_hy = 0u64;
    let mut cmp_asym = 0u64;
    for i in 0..trials {
        use crate::adc::Adc;
        let v = (i as f64 + 0.5) / trials as f64;
        cmp_sar += sar.convert(v, &mut rng).comparisons as u64;
        cmp_hy += hybrid.convert(v, &mut rng).comparisons as u64;
        let plus = (0..32).filter(|_| rng.bernoulli(0.25)).count();
        cmp_asym += tree.convert(&mut sar, plus as f64 / 32.0, &mut rng).comparisons as u64;
    }
    out.push_str(&format!(
        "\nbehavioural sim, avg comparisons/conversion: SAR {:.2}, hybrid {:.2}, asymmetric (MAV-weighted) {:.2}\n",
        cmp_sar as f64 / trials as f64,
        cmp_hy as f64 / trials as f64,
        cmp_asym as f64 / trials as f64,
    ));
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_contains_anchor_numbers() {
        let r = super::generate();
        assert!(r.contains("5235.20"), "{r}");
        assert!(r.contains("10703.36"));
        assert!(r.contains("207.80"));
        assert!(r.contains("74.23"));
    }
}
