//! Fig 12: measured non-idealities of the SRAM-immersed ADC —
//! staircase transfer, DNL, INL.

use crate::adc::metrics::{linearity, staircase};
use crate::adc::{Adc, ImmersedAdc, ImmersedMode};
use crate::analog::NoiseModel;
use crate::util::Rng;

/// Render Fig 12: collaborative-ADC linearity (DNL/INL).
pub fn generate() -> String {
    let mut out = String::new();
    let bits = 5u8;
    let mut rng = Rng::new(0xf12);
    let noise = NoiseModel::default();
    let hybrid = ImmersedMode::Hybrid { flash_bits: 2 };
    let mut adc = ImmersedAdc::sample(bits, 1.0, hybrid, 32, 20.0, &noise, &mut rng);

    // (a) staircase, subsampled for the report.
    out.push_str("Fig 12(a) — output code vs input voltage (hybrid SAR+Flash, 5-bit)\n\n");
    let stairs = staircase(&mut adc, 128, &mut rng);
    out.push_str(&format!("{:>8} {:>6} {:>6}\n", "V_in", "code", "ideal"));
    for (v, c) in stairs.iter().step_by(8) {
        out.push_str(&format!("{v:>8.3} {c:>6} {:>6}\n", adc.ideal_code(*v)));
    }
    let max_dev = stairs
        .iter()
        .map(|(v, c)| (*c as i64 - adc.ideal_code(*v) as i64).unsigned_abs())
        .max()
        .unwrap_or(0);
    out.push_str(&format!("\nmax |code - ideal| over ramp: {max_dev} LSB\n"));

    // (b, c) DNL / INL.
    let lin = linearity(&mut adc, 48, &mut rng);
    out.push_str(&format!(
        "\nFig 12(b) — DNL: max |DNL| = {:.3} LSB\nFig 12(c) — INL: max |INL| = {:.3} LSB\n",
        lin.max_abs_dnl(),
        lin.max_abs_inl()
    ));
    out.push_str("\nDNL per code step: ");
    for d in lin.dnl.iter().step_by(4) {
        out.push_str(&format!("{d:+.2} "));
    }
    out.push_str("\nINL per code:      ");
    for d in lin.inl.iter().step_by(4) {
        out.push_str(&format!("{d:+.2} "));
    }
    out.push('\n');
    out.push_str("\npaper: near-ideal staircase; sub-LSB DNL/INL on the 65 nm chip —\n");
    out.push_str("common-mode cancellation between compute and reference arrays\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig12_reports_linearity() {
        let r = super::generate();
        assert!(r.contains("DNL"));
        assert!(r.contains("INL"));
        assert!(r.contains("staircase") || r.contains("output code"));
    }
}
