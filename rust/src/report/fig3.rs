//! Fig 3: timing diagram of the crossbar's four-step operation
//! (2 cycles @ 4 GHz, VDD 0.85 V, boosted CM/RM).

use crate::analog::timing::Phase;
use crate::analog::{OperatingPoint, PhaseTimer, SignalTrace, SupplyModel};

/// Render Fig 3: per-phase settle timing across operating points.
pub fn generate() -> String {
    let op = OperatingPoint::crossbar_nominal();
    let timer = PhaseTimer::new(SupplyModel::default(), op);
    let step = timer.step_time_ps();
    let vdd = op.vdd;

    // Reconstruct the signal flows of Fig 3 phase by phase.
    let mut tr = SignalTrace::new();
    let mut t = 0.0;
    // CLK: toggles every half cycle == every step.
    for i in 0..=4 {
        tr.record(i as f64 * step, "CLK", if i % 2 == 0 { 0.0 } else { vdd });
    }
    // Step 1: precharge — BL/BLB rise to VDD, PCH active low.
    tr.record(t, "PCH", 0.0);
    tr.record(t, "BL", vdd * timer.settle(Phase::Precharge));
    tr.record(t, "BLB", vdd * timer.settle(Phase::Precharge));
    t += step;
    // Step 2: local compute — O/OB develop on local nodes; CL carries input.
    tr.record(t, "PCH", vdd);
    tr.record(t, "CL", vdd);
    tr.record(t, "O", vdd * timer.settle(Phase::LocalCompute));
    tr.record(t, "OB", 0.0);
    t += step;
    // Step 3: row merge — RM boosted; SL/SLB settle to charge averages.
    tr.record(t, "RM", timer.merge_boost_v);
    tr.record(t, "SL", 0.55 * vdd * timer.settle(Phase::RowMergeSum));
    tr.record(t, "SLB", 0.30 * vdd * timer.settle(Phase::RowMergeSum));
    t += step;
    // Step 4: compare — comparator fires on SL-SLB.
    tr.record(t, "CMP", vdd * timer.settle(Phase::Compare));
    t += step;
    tr.record(t, "CMP", 0.0);

    let mut out = String::new();
    out.push_str(&format!(
        "Fig 3 — four-step CIM operation at {} GHz, VDD {} V (step = {:.0} ps; 4 steps = 2 cycles)\n\n",
        op.clock_ghz, vdd, step
    ));
    out.push_str(&tr.ascii_table(16));
    out.push_str("\nper-phase settled fraction (1.0 = fully settled):\n");
    for p in Phase::ALL {
        out.push_str(&format!("  {:<8} {:.4}\n", p.name(), timer.settle(p)));
    }
    out.push_str(&format!(
        "worst-case settle: {:.4} (operation valid > 0.95)\n",
        timer.worst_settle()
    ));
    out.push_str("boosted RM/CM at 1.25 V eliminate source degeneration (paper Fig 3 note)\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_report_shows_all_signals() {
        let r = super::generate();
        for sig in ["CLK", "PCH", "BL", "SL", "CMP", "RM"] {
            assert!(r.contains(sig), "missing {sig}: {r}");
        }
        assert!(r.contains("worst-case settle"));
    }
}
