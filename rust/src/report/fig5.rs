//! Fig 5: accuracy under training with 1-bit product-sum quantization,
//! across input quantization levels, vs the floating-point baseline.

use crate::nn::bwht_layer::BwhtExec;
use crate::nn::model::bwht_mlp;
use crate::nn::train::{train, TrainConfig};
use crate::util::Rng;

use super::support::digit_data;

/// Render Fig 5: digit-classification accuracy vs input quantization.
pub fn generate() -> String {
    let mut out = String::new();
    out.push_str("Fig 5 — training against 1-bit product-sum quantization\n");
    out.push_str("(digit workload stand-in; paper: CIFAR-10 on ResNet20/MobileNetV2)\n\n");

    let (tr, te) = digit_data(400, 0xf165);
    let epochs = 6usize;

    // Float baseline.
    let mut rng = Rng::new(3);
    let mut float_model = bwht_mlp(144, 10, 32, &mut rng);
    let cfg = TrainConfig { epochs, lr: 0.08, seed: 11, ..Default::default() };
    let log_f = train(&mut float_model, &tr, &te, cfg);
    let acc_f = *log_f.epoch_test_acc.last().unwrap();
    out.push_str(&format!(
        "float baseline: test acc/epoch {:?}\n\n",
        round3(&log_f.epoch_test_acc)
    ));

    // Quantized training at 1..4 input bits (product-sum always 1-bit).
    out.push_str("input quant | test acc per epoch (1-bit product-sum quantization)\n");
    let mut finals = Vec::new();
    for bits in 1..=4u8 {
        let mut rng = Rng::new(3);
        let mut model = bwht_mlp(144, 10, 32, &mut rng);
        model.for_each_bwht(|b| b.set_exec(BwhtExec::QuantDigital { input_bits: bits }));
        let log = train(&mut model, &tr, &te, cfg);
        let acc = *log.epoch_test_acc.last().unwrap();
        finals.push(acc);
        out.push_str(&format!("  {bits} bit     | {:?}\n", round3(&log.epoch_test_acc)));
    }
    let spread =
        finals.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - finals.iter().cloned().fold(f64::INFINITY, f64::min);
    out.push_str(&format!(
        "\nfloat {acc_f:.3}; quantized finals {:?} (spread {spread:.3})\n",
        round3(&finals)
    ));
    out.push_str("paper shape: accuracy converges to a similar level across input quant\n");
    out.push_str("levels, a few points below the floating-point baseline\n");
    out
}

fn round3(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| (x * 1000.0).round() / 1000.0).collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig5_reports_all_quant_levels() {
        let r = super::generate();
        for b in 1..=4 {
            assert!(r.contains(&format!("{b} bit")), "{r}");
        }
        assert!(r.contains("float baseline"));
    }
}
