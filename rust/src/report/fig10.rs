//! Fig 10: MAV statistics and the asymmetric binary search.

use crate::adc::{binomial_mav_pmf, AsymmetricSearch, ImmersedAdc, ImmersedMode};
use crate::cim::{BitVec, Crossbar, CrossbarConfig};
use crate::util::stats::{entropy_bits, Histogram};
use crate::util::Rng;

/// Render Fig 10: MAV distribution statistics and entropy.
pub fn generate() -> String {
    let mut out = String::new();
    let bits = 5u8;
    let cols = 32usize;

    // (a) Measured MAV distribution from the crossbar simulator.
    out.push_str("Fig 10(a) — MAV distribution under uniform input/weight bits (measured)\n\n");
    let mut rng = Rng::new(0xf10);
    let mut xb = Crossbar::walsh(cols, CrossbarConfig::ideal(), &mut rng);
    let mut hist = Histogram::new(0.0, 1.0, 16);
    for _ in 0..400 {
        let x = BitVec::from_bits(&(0..cols).map(|_| rng.bool()).collect::<Vec<_>>());
        for v in xb.compute_mav(&x, &mut rng) {
            hist.push(v);
        }
    }
    out.push_str(&hist.ascii(36));

    // Analytic pmf + optimal tree.
    let pmf = binomial_mav_pmf(cols, 0.5, bits);
    let mean_code: f64 = pmf.iter().enumerate().map(|(c, p)| c as f64 * p).sum();
    out.push_str(&format!(
        "\nanalytic: mean code {mean_code:.2} of {} (skewed well below mid-scale {})\n",
        1 << bits,
        (1 << bits) / 2
    ));

    // (b, c) Asymmetric search vs symmetric.
    let tree = AsymmetricSearch::build(bits, &pmf);
    let sym = AsymmetricSearch::symmetric(bits);
    out.push_str(&format!(
        "\nFig 10(b,c) — comparison trees:\n  symmetric:  E[comparisons] = {:.2}\n  asymmetric: E[comparisons] = {:.2}   (entropy bound {:.2} bits)\n",
        sym.expected_comparisons(),
        tree.expected_comparisons(),
        entropy_bits(&pmf),
    ));

    // Measured on the hardware path: draw MAVs, digitize, count.
    let mut adc = ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar);
    let trials = 2000;
    let mut total = 0u64;
    for _ in 0..trials {
        let plus = (0..cols).filter(|_| rng.bernoulli(0.25)).count();
        total += tree.convert(&mut adc, plus as f64 / cols as f64 + 1e-9, &mut rng).comparisons
            as u64;
    }
    out.push_str(&format!(
        "  measured on immersed converter: {:.2} comparisons avg over {trials} MAVs\n",
        total as f64 / trials as f64
    ));
    out.push_str("\npaper: ~3.7 comparisons vs 5 for symmetric at 5 bits\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig10_beats_symmetric() {
        let r = super::generate();
        assert!(r.contains("asymmetric"));
        assert!(r.contains("symmetric:  E[comparisons] = 5.00"));
    }
}
