//! Shared experiment plumbing: the trained digit model and helpers.

use crate::cim::{CrossbarConfig, EarlyTermination};
use crate::nn::bwht_layer::BwhtExec;
use crate::nn::dataset::Dataset;
use crate::nn::model::{bwht_mlp, Sequential};
use crate::nn::train::{evaluate, train, TrainConfig};
use crate::util::Rng;

/// Standard digit workload: 12×12 seven-segment digits, flattened.
pub fn digit_data(n: usize, seed: u64) -> (Dataset, Dataset) {
    let d = Dataset::digits(n, 12, seed);
    let flat = |d: Dataset| Dataset {
        images: d.images.into_iter().map(|i| i.reshape(&[144])).collect(),
        labels: d.labels,
        classes: d.classes,
        side: d.side,
    };
    let (tr, te) = d.split(0.8);
    (flat(tr), flat(te))
}

/// Train the Fig 13 digit MLP once: float epochs followed by a short
/// quantization-aware fine-tune against the 1-bit product-sum path
/// (paper §III-B — thresholds and the reconstruction gain must adapt to
/// the quantized scale, or the analog path underperforms for no
/// hardware reason). Returns (model, test set, float accuracy).
/// Deterministic per seed. `t_reg` widens thresholds (Fig 6).
pub fn trained_digit_mlp(seed: u64, epochs: usize, t_reg: f32) -> (Sequential, Dataset, f64) {
    let (tr, te) = digit_data(400, seed ^ 0x5eed);
    let mut rng = Rng::new(seed);
    let mut model = bwht_mlp(144, 10, 32, &mut rng);
    if t_reg > 0.0 {
        model.for_each_bwht(|b| b.t_reg = t_reg);
    }
    let cfg = TrainConfig { epochs, lr: 0.08, seed, ..Default::default() };
    let _ = train(&mut model, &tr, &te, cfg);
    // QAT fine-tune: bit-exact digital model of the crossbar path.
    model.for_each_bwht(|b| {
        b.set_exec(crate::nn::bwht_layer::BwhtExec::QuantDigital { input_bits: 4 })
    });
    let qcfg = TrainConfig { epochs: 2, lr: 0.02, seed: seed ^ 1, ..Default::default() };
    let _ = train(&mut model, &tr, &te, qcfg);
    model.for_each_bwht(|b| b.set_exec(BwhtExec::Float));
    let acc = evaluate(&mut model, &te);
    (model, te, acc)
}

/// Evaluate a trained model with its BWHT stage on the analog crossbar
/// at `config`; returns accuracy on `te`.
pub fn analog_accuracy(
    model: &mut Sequential,
    te: &Dataset,
    config: CrossbarConfig,
    input_bits: u8,
    early_term: Option<EarlyTermination>,
    seed: u64,
) -> f64 {
    model.for_each_bwht(|b| {
        b.set_exec(BwhtExec::Analog { input_bits, config, early_term, seed, pool: None });
    });
    let acc = evaluate(model, te);
    model.for_each_bwht(|b| b.set_exec(BwhtExec::Float));
    acc
}

/// Fixed-width table row helper.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    let mut s = String::new();
    for (c, w) in cells.iter().zip(widths) {
        s.push_str(&format!("{c:>w$}  ", w = w));
    }
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digit_mlp_trains_above_chance_quickly() {
        let (_m, _te, acc) = trained_digit_mlp(1, 3, 0.0);
        assert!(acc > 0.4, "acc {acc}");
    }

    #[test]
    fn analog_accuracy_close_to_float_at_nominal() {
        let (mut m, te, acc_f) = trained_digit_mlp(2, 3, 0.0);
        let acc_a = analog_accuracy(&mut m, &te, CrossbarConfig::default(), 4, None, 7);
        assert!(acc_a > acc_f - 0.35, "float {acc_f} analog {acc_a}");
        // Exec mode restored.
        let acc_back = evaluate(&mut m, &te);
        assert!((acc_back - acc_f).abs() < 1e-9);
    }
}
