//! Fig 8: SRAM-immersed SAR ADC — per-cycle conversion trace.

use crate::adc::{Adc, ImmersedAdc, ImmersedMode};
use crate::analog::NoiseModel;
use crate::util::Rng;

/// Render Fig 8: comparator offset/noise characterization.
pub fn generate() -> String {
    let mut out = String::new();
    out.push_str("Fig 8 — SRAM-immersed SAR conversion (left array computes MAV,\n");
    out.push_str("right array's column lines form the capacitive DAC)\n\n");

    let bits = 5u8;
    let vdd = 1.0;
    let mut rng = Rng::new(0xf18);
    let noise = NoiseModel::default();

    for &v_mav in &[0.18, 0.47, 0.83] {
        out.push_str(&format!("MAV = {v_mav:.2} V:\n"));
        out.push_str(&format!(
            "{:>6} {:>8} {:>10} {:>8} {:>8}\n",
            "cycle", "trial", "V_ref", "cmp", "code"
        ));
        // Re-run the SAR loop manually so every cycle is visible.
        let mut adc = ImmersedAdc::sample(bits, vdd, ImmersedMode::Sar, 32, 20.0, &noise, &mut rng);
        let mut code = 0u32;
        for (cycle, bit) in (0..bits).rev().enumerate() {
            let trial = code | (1 << bit);
            let k_units = trial as usize * adc.units_per_code();
            let v_ref = adc.ref_level(0, k_units, &mut rng);
            let take = v_mav > v_ref;
            if take {
                code = trial;
            }
            out.push_str(&format!(
                "{:>6} {trial:>8} {v_ref:>10.4} {:>8} {code:>8}\n",
                cycle + 1,
                if take { "1" } else { "0" },
            ));
        }
        let ideal = adc.ideal_code(v_mav);
        out.push_str(&format!("  final code {code} (ideal {ideal})\n\n"));
    }
    out.push_str("both arrays then swap roles (compute <-> digitize) — see the\n");
    out.push_str("network::schedule interleave and Fig 9/fig13 reports\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig8_traces_five_cycles_per_conversion() {
        let r = super::generate();
        assert!(r.contains("cycle"));
        assert!(r.contains("final code"));
        // 3 MAVs traced.
        assert_eq!(r.matches("MAV = ").count(), 3);
    }
}
