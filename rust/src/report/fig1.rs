//! Fig 1(c): compression & accuracy vs number of WHT-processed layers.
//! Fig 1(d): MAC increase under frequency-domain processing.

use crate::nn::macs::{
    compression_summary, mobilenet_v2_table, resnet20_progressive, resnet20_table,
};
use crate::nn::model::mini_resnet;
use crate::nn::train::{train, TrainConfig};
use crate::util::Rng;

/// Fig 1(c): the trained miniature sweep (accuracy axis) plus the
/// analytic full-dimension ResNet20 compression curve.
pub fn fig1c() -> String {
    let mut out = String::new();
    out.push_str("Fig 1(c) — WHT layers vs accuracy & compression\n\n");

    // Analytic full-size ResNet20 compression progression.
    out.push_str("ResNet20 (CIFAR dims, analytic): layers replaced -> params remaining\n");
    for k in [0usize, 2, 4, 8, 12, 16, 19] {
        let (replaced, frac) = resnet20_progressive(k);
        out.push_str(&format!(
            "  {replaced:>2} layers  -> {:>5.1}% of baseline params\n",
            frac * 100.0
        ));
    }

    // Trained miniature: accuracy as BWHT replaces more mixers.
    // (CHW images — the conv model takes unflattened frames.)
    out.push_str("\nminiature ResNet (digit workload, 3 mixer stages): BWHT stages vs test acc\n");
    let (tr, te) = crate::nn::Dataset::digits(300, 12, 0xf16c).split(0.8);
    let stages = 3usize;
    for bwht_stages in 0..=stages {
        // Tiny nets are init-sensitive even with leaky activations;
        // report the mean over three seeds.
        let mut accs = Vec::new();
        let mut params = 0;
        for seed in [42u64, 7, 19] {
            let mut rng = Rng::new(seed);
            let mut model = mini_resnet(12, 10, 8, stages, bwht_stages, &mut rng);
            params = model.param_count();
            let cfg = TrainConfig { epochs: 10, lr: 0.06, seed, ..Default::default() };
            let log = train(&mut model, &tr, &te, cfg);
            accs.push(*log.epoch_test_acc.last().unwrap());
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        out.push_str(&format!(
            "  {bwht_stages}/{stages} BWHT  params {params:>7}  test acc {mean:.3} (3-seed mean, {accs:.2?})\n",
        ));
    }
    out.push_str("\npaper shape: accuracy degrades only slightly while params drop steeply\n");
    out
}

/// Fig 1(d): MAC increase for MobileNetV2 and ResNet20 when the WHT runs
/// as a dense crossbar matvec.
pub fn fig1d() -> String {
    let mut out = String::new();
    out.push_str("Fig 1(d) — MAC operations under frequency-domain processing\n\n");
    for (name, table) in
        [("MobileNetV2 (224²)", mobilenet_v2_table()), ("ResNet20 (32²)", resnet20_table())]
    {
        let s = compression_summary(&table);
        out.push_str(&format!(
            "{name}:\n  baseline MACs {:>12}\n  BWHT dense-crossbar ops {:>12}  ({:.2}x increase)\n  BWHT fast-butterfly ops {:>12}  ({:.2}x)\n  params: {} -> {} ({:.1}% reduction total, {:.1}% of features)\n",
            s.macs_base,
            s.macs_bwht_dense,
            s.mac_increase_dense,
            s.ops_bwht_fast,
            s.ops_bwht_fast as f64 / s.macs_base as f64,
            s.params_base,
            s.params_bwht,
            s.reduction_total * 100.0,
            s.reduction_features * 100.0,
        ));
    }
    out.push_str("\npaper shape: parameters drop ~87% (MobileNetV2) while MACs increase —\n");
    out.push_str("the gap the analog crossbar (Fig 2) is built to close\n");
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig1d_reports_increase() {
        let r = super::fig1d();
        assert!(r.contains("x increase"));
        assert!(r.contains("MobileNetV2"));
    }
}
