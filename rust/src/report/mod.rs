//! Report generators: every table and figure of the paper's evaluation,
//! as text. Shared by the bench binaries (rust/benches/) and the CLI
//! (`adcim report`). Each generator returns the report as a `String`
//! and is deterministic given its seed.
//!
//! Experiment index (DESIGN.md has the full mapping):
//! - [`table1::generate`]  — Table I ADC area/energy comparison.
//! - [`fig1::fig1c`]/[`fig1::fig1d`] — compression & MAC accounting.
//! - [`fig3::generate`]    — crossbar 4-step timing diagram.
//! - [`fig5::generate`]    — accuracy under 1-bit quantized training.
//! - [`fig6::generate`]    — T distribution + early termination.
//! - [`fig7::generate`]    — crossbar VDD / size / clock sweeps.
//! - [`fig8::generate`]    — SRAM-immersed ADC conversion trace.
//! - [`fig10::generate`]   — MAV statistics + asymmetric search.
//! - [`fig12::generate`]   — staircase, DNL, INL.
//! - [`fig13::generate`]   — ADC design space + accuracy/power sweeps.

pub mod fig1;
pub mod fig10;
pub mod fig12;
pub mod fig13;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod support;
pub mod table1;

/// All report ids in paper order.
pub const ALL: &[(&str, fn() -> String)] = &[
    ("table1", table1::generate),
    ("fig1c", fig1::fig1c),
    ("fig1d", fig1::fig1d),
    ("fig3", fig3::generate),
    ("fig5", fig5::generate),
    ("fig6", fig6::generate),
    ("fig7", fig7::generate),
    ("fig8", fig8::generate),
    ("fig10", fig10::generate),
    ("fig12", fig12::generate),
    ("fig13", fig13::generate),
];

/// Generate one report by id.
pub fn generate(id: &str) -> Option<String> {
    ALL.iter().find(|(n, _)| *n == id).map(|(_, f)| f())
}
