//! Clocked rail-to-rail comparator (paper Fig 8(b)).
//!
//! The paper's design pairs n-type and p-type clocked comparators so the
//! valid input common-mode spans rail to rail. Behaviourally a comparator
//! is a sign decision corrupted by a static per-instance offset (device
//! mismatch) and per-decision noise; both come from [`super::NoiseModel`].

use super::noise::NoiseModel;
use crate::util::Rng;

/// One comparator instance with its sampled static offset.
#[derive(Debug, Clone)]
pub struct Comparator {
    /// Static input-referred offset (V), sampled at "fabrication".
    offset_v: f64,
    /// Per-decision noise sigma (V).
    noise_sigma_v: f64,
    /// Decisions made (for energy accounting).
    decisions: u64,
}

impl Comparator {
    /// Fabricate a comparator: samples its mismatch offset from `noise`.
    pub fn sample(noise: &NoiseModel, rng: &mut Rng) -> Self {
        Comparator {
            offset_v: noise.sample_comparator_offset_v(rng),
            noise_sigma_v: noise.comparator_noise_sigma_v,
            decisions: 0,
        }
    }

    /// An ideal comparator (zero offset, zero noise).
    pub fn ideal() -> Self {
        Comparator { offset_v: 0.0, noise_sigma_v: 0.0, decisions: 0 }
    }

    /// Construct with an explicit offset (tests, trimming experiments).
    pub fn with_offset(offset_v: f64) -> Self {
        Comparator { offset_v, noise_sigma_v: 0.0, decisions: 0 }
    }

    /// Clocked decision: `v_plus > v_minus` as seen through offset+noise.
    pub fn compare(&mut self, v_plus: f64, v_minus: f64, rng: &mut Rng) -> bool {
        self.decisions += 1;
        let noise = if self.noise_sigma_v > 0.0 { rng.normal() * self.noise_sigma_v } else { 0.0 };
        v_plus - v_minus + self.offset_v + noise > 0.0
    }

    /// Clocked decision on a differential that **already includes every
    /// per-decision noise term**: the crossbar hot path folds thermal and
    /// comparator noise into a single Gaussian draw per row (independent
    /// Gaussians add in variance), so only the static offset is applied
    /// here. See `crate::cim::crossbar` §noise-folding.
    #[inline]
    pub fn compare_prenoised(&mut self, diff_v: f64) -> bool {
        self.decisions += 1;
        diff_v + self.offset_v > 0.0
    }

    /// Record a decision resolved by the caller (the noiseless popcount
    /// fast path) so energy/decision accounting stays consistent.
    #[inline]
    pub fn note_decision(&mut self) {
        self.decisions += 1;
    }

    /// Per-decision noise sigma of this instance (V).
    #[inline]
    pub fn noise_sigma_v(&self) -> f64 {
        self.noise_sigma_v
    }

    /// Static offset of this instance (V).
    pub fn offset_v(&self) -> f64 {
        self.offset_v
    }

    /// Total decisions made by this instance.
    pub fn decisions(&self) -> u64 {
        self.decisions
    }

    /// Reset the decision counter (per-conversion energy accounting).
    pub fn reset_decisions(&mut self) {
        self.decisions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_comparator_is_exact_sign() {
        let mut c = Comparator::ideal();
        let mut rng = Rng::new(0);
        assert!(c.compare(0.5, 0.4, &mut rng));
        assert!(!c.compare(0.4, 0.5, &mut rng));
        assert!(!c.compare(0.5, 0.5, &mut rng)); // strict
    }

    #[test]
    fn offset_shifts_the_trip_point() {
        let mut c = Comparator::with_offset(0.1);
        let mut rng = Rng::new(0);
        // v_plus - v_minus = -0.05, but offset +0.1 flips the decision.
        assert!(c.compare(0.45, 0.5, &mut rng));
        let mut c2 = Comparator::with_offset(-0.1);
        assert!(!c2.compare(0.55, 0.5, &mut rng));
    }

    #[test]
    fn decision_counter_counts() {
        let mut c = Comparator::ideal();
        let mut rng = Rng::new(0);
        for _ in 0..5 {
            c.compare(1.0, 0.0, &mut rng);
        }
        assert_eq!(c.decisions(), 5);
        c.reset_decisions();
        assert_eq!(c.decisions(), 0);
    }

    #[test]
    fn noisy_comparator_flips_near_trip_point() {
        let noise = NoiseModel { comparator_noise_sigma_v: 10e-3, ..NoiseModel::ideal() };
        let mut rng = Rng::new(7);
        let mut c = Comparator::sample(&noise, &mut rng);
        // Exactly at the trip point the decision should be ~50/50.
        let n = 4000;
        let ones = (0..n).filter(|_| c.compare(0.5, 0.5, &mut rng)).count();
        let frac = ones as f64 / n as f64;
        assert!((0.35..0.65).contains(&frac), "frac={frac}");
        // Far from the trip point it is deterministic.
        assert!(c.compare(0.8, 0.2, &mut rng));
    }

    #[test]
    fn sampled_offsets_vary_per_instance() {
        let noise = NoiseModel::default();
        let mut rng = Rng::new(9);
        let c1 = Comparator::sample(&noise, &mut rng);
        let c2 = Comparator::sample(&noise, &mut rng);
        assert_ne!(c1.offset_v(), c2.offset_v());
    }
}
