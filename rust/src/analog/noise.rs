//! Noise sources: kT/C thermal noise, comparator offset, charge injection.
//!
//! These are the non-idealities behind the paper's measured DNL/INL
//! (Fig 12) and the accuracy roll-off at low VDD (Figs 7a, 13d): the
//! signal (one LSB) shrinks with VDD while the noise floor stays put.

use crate::util::Rng;

/// Boltzmann constant (J/K).
pub const K_BOLTZMANN: f64 = 1.380_649e-23;

/// kT/C thermal (sampling) noise rms in volts for capacitance `c_ff`
/// (femtofarads) at temperature `temp_k`.
pub fn ktc_noise_v(c_ff: f64, temp_k: f64) -> f64 {
    assert!(c_ff > 0.0);
    (K_BOLTZMANN * temp_k / (c_ff * 1e-15)).sqrt()
}

/// Aggregate noise model used by the CiM and ADC simulators.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Temperature (K).
    pub temp_k: f64,
    /// Comparator input-referred offset sigma (V) — device mismatch,
    /// sampled once per comparator instance.
    pub comparator_offset_sigma_v: f64,
    /// Comparator input-referred noise sigma (V) — per decision.
    pub comparator_noise_sigma_v: f64,
    /// Charge-injection error as a fraction of the switched voltage step,
    /// applied per switching event.
    pub charge_injection_frac: f64,
    /// Unit-capacitor mismatch sigma (fractional) for the in-memory
    /// capacitive DAC.
    pub cap_mismatch_sigma: f64,
    /// Threshold-voltage mismatch sigma (V) of the minimum-size NMOS
    /// compute cells — drives the low-VDD settling-spread error
    /// mechanism (see [`super::SupplyModel::settle_vth_sensitivity`]).
    pub vth_mismatch_sigma_v: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        // 65 nm class numbers: a few mV of comparator offset, sub-mV
        // decision noise, ~1% unit-cap mismatch on parasitic bit-lines.
        NoiseModel {
            temp_k: 300.0,
            comparator_offset_sigma_v: 3.0e-3,
            comparator_noise_sigma_v: 0.5e-3,
            charge_injection_frac: 0.002,
            cap_mismatch_sigma: 0.01,
            vth_mismatch_sigma_v: 0.08,
        }
    }
}

impl NoiseModel {
    /// Noise-free model (for exactness tests and digital oracles).
    pub fn ideal() -> Self {
        NoiseModel {
            temp_k: 0.0,
            comparator_offset_sigma_v: 0.0,
            comparator_noise_sigma_v: 0.0,
            charge_injection_frac: 0.0,
            cap_mismatch_sigma: 0.0,
            vth_mismatch_sigma_v: 0.0,
        }
    }

    /// Sample the thermal noise on a capacitor of `c_ff` fF.
    pub fn sample_ktc_v(&self, c_ff: f64, rng: &mut Rng) -> f64 {
        if self.temp_k <= 0.0 {
            return 0.0;
        }
        rng.normal() * ktc_noise_v(c_ff, self.temp_k)
    }

    /// Sample a comparator's static offset (once per instance).
    pub fn sample_comparator_offset_v(&self, rng: &mut Rng) -> f64 {
        rng.normal() * self.comparator_offset_sigma_v
    }

    /// Sample per-decision comparator noise.
    pub fn sample_comparator_noise_v(&self, rng: &mut Rng) -> f64 {
        rng.normal() * self.comparator_noise_sigma_v
    }

    /// Sample a unit capacitor value (nominal 1.0, fractional mismatch).
    pub fn sample_unit_cap(&self, rng: &mut Rng) -> f64 {
        (1.0 + rng.normal() * self.cap_mismatch_sigma).max(0.5)
    }

    /// Charge-injection error for a switching event of `v_step` volts.
    pub fn charge_injection_v(&self, v_step: f64, rng: &mut Rng) -> f64 {
        if self.charge_injection_frac == 0.0 {
            return 0.0;
        }
        rng.normal() * self.charge_injection_frac * v_step.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ktc_matches_textbook_value() {
        // kT/C at 300 K, 1 pF → ~64 µV rms.
        let v = ktc_noise_v(1000.0, 300.0);
        assert!((v - 64.4e-6).abs() < 2e-6, "v={v}");
    }

    #[test]
    fn ktc_grows_as_cap_shrinks() {
        assert!(ktc_noise_v(1.0, 300.0) > ktc_noise_v(100.0, 300.0));
    }

    #[test]
    fn ideal_model_is_silent() {
        let m = NoiseModel::ideal();
        let mut rng = Rng::new(1);
        assert_eq!(m.sample_ktc_v(10.0, &mut rng), 0.0);
        assert_eq!(m.sample_comparator_offset_v(&mut rng), 0.0);
        assert_eq!(m.sample_comparator_noise_v(&mut rng), 0.0);
        assert_eq!(m.sample_unit_cap(&mut rng), 1.0);
        assert_eq!(m.charge_injection_v(1.0, &mut rng), 0.0);
    }

    #[test]
    fn offset_sampling_has_right_scale() {
        let m = NoiseModel::default();
        let mut rng = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| m.sample_comparator_offset_v(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let std = (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64).sqrt();
        assert!(mean.abs() < 3e-4, "mean={mean}");
        assert!((std - m.comparator_offset_sigma_v).abs() < 3e-4, "std={std}");
    }

    #[test]
    fn unit_cap_clamped_positive() {
        let m = NoiseModel { cap_mismatch_sigma: 5.0, ..NoiseModel::default() };
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(m.sample_unit_cap(&mut rng) >= 0.5);
        }
    }
}
