//! Signal-phase timing and waveform traces (paper Figs 2–3).
//!
//! The crossbar's four-step operation (precharge → local compute →
//! row-merge sum → compare/threshold) completes in **two clock cycles**:
//! each step gets half a cycle. [`PhaseTimer`] computes per-step settle
//! quality from the supply model; [`SignalTrace`] records named waveform
//! points so the Fig 3 bench can print the timing diagram.

use super::supply::{OperatingPoint, SupplyModel};

/// The four steps of the crossbar operation (paper Fig 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Step 1: precharge BL/BLB, apply the input bit-plane.
    Precharge,
    /// Step 2: parallel local computation on O/OB nodes.
    LocalCompute,
    /// Step 3: row-merge — short all cells row-wise, sum on SL/SLB.
    RowMergeSum,
    /// Step 4: comparator + soft-threshold decision.
    Compare,
}

impl Phase {
    /// The four crossbar phases in execution order.
    pub const ALL: [Phase; 4] =
        [Phase::Precharge, Phase::LocalCompute, Phase::RowMergeSum, Phase::Compare];

    /// Short display name of the phase.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Precharge => "PCH",
            Phase::LocalCompute => "LOCAL",
            Phase::RowMergeSum => "RMERGE",
            Phase::Compare => "CMP",
        }
    }

    /// Relative capacitive load each phase drives (local nodes are much
    /// less capacitive than merged sum lines — the design point the paper
    /// emphasises vs bit-line-compute designs like [12]).
    pub fn load_factor(self) -> f64 {
        match self {
            Phase::Precharge => 1.0,
            Phase::LocalCompute => 0.25, // local O/OB nodes only
            Phase::RowMergeSum => 2.0,   // all cells shorted row-wise
            Phase::Compare => 0.5,
        }
    }
}

/// Per-phase settle evaluation at an operating point.
#[derive(Debug, Clone)]
pub struct PhaseTimer {
    /// Process/voltage scaling model.
    pub supply: SupplyModel,
    /// Supply/frequency operating point being evaluated.
    pub op: OperatingPoint,
    /// Merge-signal boost voltage (paper: CM/RM boosted to 1.25 V to kill
    /// source degeneration — effectively raises the drive on merge phases).
    pub merge_boost_v: f64,
}

impl PhaseTimer {
    /// Timer at the paper's 1.25 V merge-boost default.
    pub fn new(supply: SupplyModel, op: OperatingPoint) -> Self {
        PhaseTimer { supply, op, merge_boost_v: 1.25 }
    }

    /// Time allotted to one step: half a clock cycle (4 steps / 2 cycles).
    pub fn step_time_ps(&self) -> f64 {
        self.op.period_ps() / 2.0
    }

    /// Effective drive voltage for a phase (merge phases are boosted).
    fn drive_vdd(&self, phase: Phase) -> f64 {
        match phase {
            Phase::RowMergeSum => self.op.vdd.max(self.merge_boost_v),
            _ => self.op.vdd,
        }
    }

    /// Settled fraction (0..1) a node reaches in this phase, given the
    /// phase's load factor and (possibly boosted) drive.
    pub fn settle(&self, phase: Phase) -> f64 {
        let tau = self.supply.tau_ps(self.drive_vdd(phase)) * phase.load_factor();
        1.0 - (-self.step_time_ps() / tau).exp()
    }

    /// Worst settled fraction across all four phases — the operation's
    /// timing margin. < ~0.95 starts producing compute errors.
    pub fn worst_settle(&self) -> f64 {
        Phase::ALL.iter().map(|&p| self.settle(p)).fold(1.0, f64::min)
    }

    /// Multiplicative error applied to analog quantities due to
    /// incomplete settling (1.0 = exact).
    pub fn settle_gain(&self, phase: Phase) -> f64 {
        self.settle(phase)
    }
}

/// A named waveform sample for timing-diagram output.
#[derive(Debug, Clone)]
pub struct TracePoint {
    /// Sample time, picoseconds.
    pub t_ps: f64,
    /// Signal name (e.g. `CM`, `RM`).
    pub signal: &'static str,
    /// Sampled voltage.
    pub volts: f64,
}

/// Recorder for the Fig 3 timing diagram.
#[derive(Debug, Clone, Default)]
pub struct SignalTrace {
    points: Vec<TracePoint>,
}

impl SignalTrace {
    /// Empty trace.
    pub fn new() -> Self {
        SignalTrace { points: Vec::new() }
    }

    /// Append one waveform sample.
    pub fn record(&mut self, t_ps: f64, signal: &'static str, volts: f64) {
        self.points.push(TracePoint { t_ps, signal, volts });
    }

    /// All samples in record order.
    pub fn points(&self) -> &[TracePoint] {
        &self.points
    }

    /// All samples of one signal, time-ordered.
    pub fn signal(&self, name: &str) -> Vec<(f64, f64)> {
        let mut v: Vec<(f64, f64)> =
            self.points.iter().filter(|p| p.signal == name).map(|p| (p.t_ps, p.volts)).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        v
    }

    /// Distinct signal names in first-appearance order.
    pub fn signals(&self) -> Vec<&'static str> {
        let mut names = Vec::new();
        for p in &self.points {
            if !names.contains(&p.signal) {
                names.push(p.signal);
            }
        }
        names
    }

    /// Render an ASCII waveform table (time bins × signals) for reports.
    pub fn ascii_table(&self, bins: usize) -> String {
        let names = self.signals();
        if self.points.is_empty() || names.is_empty() {
            return String::new();
        }
        let t_max = self.points.iter().map(|p| p.t_ps).fold(0.0, f64::max);
        let mut out = format!("{:>8}", "t(ps)");
        for n in &names {
            out.push_str(&format!(" {:>8}", n));
        }
        out.push('\n');
        for b in 0..bins {
            let t = t_max * (b as f64 + 0.5) / bins as f64;
            out.push_str(&format!("{t:>8.1}"));
            for n in &names {
                let samples = self.signal(n);
                // Last sample at or before t (zero-order hold).
                let v = samples
                    .iter()
                    .rev()
                    .find(|(ts, _)| *ts <= t)
                    .map(|(_, v)| *v)
                    .unwrap_or(samples.first().map(|(_, v)| *v).unwrap_or(0.0));
                out.push_str(&format!(" {v:>8.3}"));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nominal() -> PhaseTimer {
        PhaseTimer::new(SupplyModel::default(), OperatingPoint::crossbar_nominal())
    }

    #[test]
    fn four_steps_two_cycles() {
        let t = nominal();
        // 4 GHz → 250 ps period → 125 ps per step; 4 steps = 500 ps = 2 cycles.
        assert!((t.step_time_ps() - 125.0).abs() < 1e-9);
    }

    #[test]
    fn nominal_point_settles() {
        // Paper Fig 3: the op completes at 4 GHz / 0.85 V with boosting.
        let t = nominal();
        assert!(t.worst_settle() > 0.95, "worst={}", t.worst_settle());
    }

    #[test]
    fn low_vdd_fails_to_settle() {
        let t = PhaseTimer::new(SupplyModel::default(), OperatingPoint::new(0.5, 4.0));
        assert!(t.worst_settle() < 0.9, "worst={}", t.worst_settle());
    }

    #[test]
    fn boost_helps_merge_phase() {
        let mut t = PhaseTimer::new(SupplyModel::default(), OperatingPoint::new(0.85, 4.0));
        let boosted = t.settle(Phase::RowMergeSum);
        t.merge_boost_v = 0.0; // disable boosting
        let unboosted = t.settle(Phase::RowMergeSum);
        assert!(boosted > unboosted);
    }

    #[test]
    fn local_compute_settles_better_than_merge() {
        // Less capacitive local nodes — the paper's design argument.
        let mut t = nominal();
        t.merge_boost_v = 0.0;
        assert!(t.settle(Phase::LocalCompute) > t.settle(Phase::RowMergeSum));
    }

    #[test]
    fn trace_records_and_orders() {
        let mut tr = SignalTrace::new();
        tr.record(10.0, "BL", 1.0);
        tr.record(0.0, "BL", 0.0);
        tr.record(5.0, "SL", 0.3);
        assert_eq!(tr.signal("BL"), vec![(0.0, 0.0), (10.0, 1.0)]);
        assert_eq!(tr.signals(), vec!["BL", "SL"]);
        let tab = tr.ascii_table(4);
        assert!(tab.contains("BL") && tab.contains("SL"));
    }
}
