//! Memory-immersed capacitive DAC (paper §IV-A).
//!
//! The key structural trick of the paper's collaborative digitization:
//! the parasitic *column lines* of a neighbouring compute-in-SRAM array
//! are repurposed as the unit capacitors of a charge-sharing DAC. A
//! precharge transistor array charges a selected subset of column lines
//! to VDD (the rest to ground); shorting all lines together then yields
//!
//! `V = (Σ_{i∈selected} C_i / Σ_j C_j) · VDD`
//!
//! — a reference voltage with ~`log2(columns)+1` distinct levels per
//! precharge pattern, with *zero* dedicated capacitor area.

use super::noise::NoiseModel;
use crate::util::Rng;

/// A capacitive DAC built from `n` unit (column-line) capacitors.
#[derive(Debug, Clone)]
pub struct CapDac {
    /// Per-unit capacitance, normalised to a nominal of 1.0 (mismatch
    /// sampled at fabrication).
    units: Vec<f64>,
    /// Physical unit capacitance (fF) — one column line's parasitic.
    pub c_unit_ff: f64,
    /// Charge-sharing switching events so far (energy accounting).
    switch_events: u64,
}

impl CapDac {
    /// Fabricate a DAC with `n` unit caps of `c_unit_ff` fF each,
    /// sampling mismatch from `noise`.
    pub fn sample(n: usize, c_unit_ff: f64, noise: &NoiseModel, rng: &mut Rng) -> Self {
        assert!(n > 0);
        CapDac {
            units: (0..n).map(|_| noise.sample_unit_cap(rng)).collect(),
            c_unit_ff,
            switch_events: 0,
        }
    }

    /// Ideal DAC (all units exactly nominal).
    pub fn ideal(n: usize, c_unit_ff: f64) -> Self {
        CapDac { units: vec![1.0; n], c_unit_ff, switch_events: 0 }
    }

    /// Number of unit capacitors (column lines).
    pub fn len(&self) -> usize {
        self.units.len()
    }

    /// True when the DAC has no unit capacitors.
    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    /// Total capacitance (fF).
    pub fn total_c_ff(&self) -> f64 {
        self.units.iter().sum::<f64>() * self.c_unit_ff
    }

    /// Generate the reference voltage for precharging the first `k` of
    /// `n` unit caps to `vdd` and charge-sharing. Adds kT/C noise on the
    /// shared node and counts a switching event.
    pub fn share_first_k(&mut self, k: usize, vdd: f64, noise: &NoiseModel, rng: &mut Rng) -> f64 {
        assert!(k <= self.units.len());
        self.switch_events += 1;
        let selected: f64 = self.units[..k].iter().sum();
        let total: f64 = self.units.iter().sum();
        let v = vdd * selected / total;
        v + noise.sample_ktc_v(self.total_c_ff(), rng) + noise.charge_injection_v(v, rng)
    }

    /// Reference voltage for an arbitrary selection mask.
    pub fn share_mask(
        &mut self,
        mask: &[bool],
        vdd: f64,
        noise: &NoiseModel,
        rng: &mut Rng,
    ) -> f64 {
        assert_eq!(mask.len(), self.units.len());
        self.switch_events += 1;
        let selected: f64 = self.units.iter().zip(mask).filter(|(_, &m)| m).map(|(c, _)| c).sum();
        let total: f64 = self.units.iter().sum();
        let v = vdd * selected / total;
        v + noise.sample_ktc_v(self.total_c_ff(), rng) + noise.charge_injection_v(v, rng)
    }

    /// Ideal code→voltage map: `code/n · vdd` (for staircase oracles).
    pub fn ideal_level(&self, k: usize, vdd: f64) -> f64 {
        vdd * k as f64 / self.units.len() as f64
    }

    /// Energy of one charge-share event at `vdd`, in femtojoules:
    /// `E = ½ · C_total · VDD²` (worst-case full swing).
    pub fn share_energy_fj(&self, vdd: f64) -> f64 {
        0.5 * self.total_c_ff() * vdd * vdd
    }

    /// Switching events so far.
    pub fn switch_events(&self) -> u64 {
        self.switch_events
    }

    /// Zero the switching-event counter.
    pub fn reset_events(&mut self) {
        self.switch_events = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_levels_are_uniform() {
        let mut dac = CapDac::ideal(32, 2.0);
        let noise = NoiseModel::ideal();
        let mut rng = Rng::new(0);
        for k in 0..=32 {
            let v = dac.share_first_k(k, 1.0, &noise, &mut rng);
            assert!((v - k as f64 / 32.0).abs() < 1e-12, "k={k} v={v}");
        }
    }

    #[test]
    fn mask_matches_first_k_for_prefix_masks() {
        let mut dac = CapDac::ideal(16, 2.0);
        let noise = NoiseModel::ideal();
        let mut rng = Rng::new(0);
        let mut mask = vec![false; 16];
        for k in 0..8 {
            mask[k] = true;
        }
        let vm = dac.share_mask(&mask, 1.0, &noise, &mut rng);
        let vk = dac.share_first_k(8, 1.0, &noise, &mut rng);
        assert_eq!(vm, vk);
    }

    #[test]
    fn mismatch_perturbs_but_preserves_endpoints() {
        let noise = NoiseModel { cap_mismatch_sigma: 0.05, ..NoiseModel::ideal() };
        let mut rng = Rng::new(42);
        let mut dac = CapDac::sample(32, 2.0, &noise, &mut rng);
        let v0 = dac.share_first_k(0, 1.0, &noise, &mut rng);
        let v32 = dac.share_first_k(32, 1.0, &noise, &mut rng);
        assert_eq!(v0, 0.0);
        assert!((v32 - 1.0).abs() < 1e-12);
        // Mid-levels deviate from ideal but stay monotone-ish in k.
        let mid = dac.share_first_k(16, 1.0, &noise, &mut rng);
        assert!((mid - 0.5).abs() < 0.05, "mid={mid}");
        assert!((mid - 0.5).abs() > 0.0);
    }

    #[test]
    fn share_levels_monotone_in_k() {
        let noise = NoiseModel { cap_mismatch_sigma: 0.02, ..NoiseModel::ideal() };
        let mut rng = Rng::new(7);
        let mut dac = CapDac::sample(64, 2.0, &noise, &mut rng);
        let mut prev = -1.0;
        for k in 0..=64 {
            let v = dac.share_first_k(k, 1.0, &noise, &mut rng);
            assert!(v > prev, "k={k}");
            prev = v;
        }
    }

    #[test]
    fn energy_scales_with_cap_and_vdd() {
        let dac = CapDac::ideal(32, 2.0);
        assert!((dac.share_energy_fj(1.0) - 32.0).abs() < 1e-12);
        assert!((dac.share_energy_fj(2.0) - 128.0).abs() < 1e-12);
    }

    #[test]
    fn switch_events_accumulate() {
        let mut dac = CapDac::ideal(4, 1.0);
        let noise = NoiseModel::ideal();
        let mut rng = Rng::new(0);
        dac.share_first_k(1, 1.0, &noise, &mut rng);
        dac.share_first_k(2, 1.0, &noise, &mut rng);
        assert_eq!(dac.switch_events(), 2);
        dac.reset_events();
        assert_eq!(dac.switch_events(), 0);
    }
}
