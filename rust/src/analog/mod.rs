//! Behavioural analog substrate.
//!
//! Everything the paper characterizes on its 65 nm test chip — charge
//! sharing on parasitic bit/column lines, clocked comparators, supply and
//! clock scaling, thermal and offset noise — is modelled here as explicit,
//! seedable arithmetic. The models are deliberately *mechanistic* (kT/C
//! noise, alpha-power-law drive delay, RC settling) rather than curve
//! fits, so the downstream figures (Fig 3 timing, Fig 7 VDD/size/clock
//! sweeps, Fig 8 conversion traces, Fig 12 DNL/INL, Fig 13(c,d)) emerge
//! from the same physics knobs the silicon obeys.
//!
//! Substitution note (DESIGN.md §Substitutions): the paper's transistor-
//! level results come from 16 nm PTM LSTP spice and a fabricated 65 nm
//! chip; here the same quantities come from closed-form charge/RC models
//! with technology-scaled constants.

pub mod capdac;
pub mod comparator;
pub mod noise;
pub mod supply;
pub mod timing;

pub use capdac::CapDac;
pub use comparator::Comparator;
pub use noise::NoiseModel;
pub use supply::{OperatingPoint, SupplyModel};
pub use timing::{PhaseTimer, SignalTrace};
