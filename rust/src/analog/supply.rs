//! Supply-voltage / clock-frequency scaling model.
//!
//! Mechanistic knobs behind the paper's Fig 7 and Fig 13(c,d) sweeps:
//!
//! - **Drive delay** follows the alpha-power law
//!   `τ(V) = τ0 · V / (V - Vth)^α` — delay explodes as VDD approaches the
//!   threshold voltage, which is why accuracy collapses at low VDD.
//! - **Dynamic power** `P = a·C·V²·f` plus a short-circuit component that
//!   grows when the clock leaves signals only partially settled (this is
//!   the super-linear escalation the paper reports beyond ~2.5 GHz and at
//!   1.3 V).
//! - **Leakage** is exponential in VDD (LSTP-style subthreshold model).

/// An operating point of the simulated chip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    /// Supply voltage in volts.
    pub vdd: f64,
    /// Clock frequency in GHz.
    pub clock_ghz: f64,
}

impl OperatingPoint {
    /// Operating point at `vdd` volts and `clock_ghz` GHz.
    pub fn new(vdd: f64, clock_ghz: f64) -> Self {
        assert!(vdd > 0.0 && clock_ghz > 0.0);
        OperatingPoint { vdd, clock_ghz }
    }

    /// Nominal point used by the paper's crossbar experiments
    /// (Fig 3: VDD = 0.85 V, 4 GHz).
    pub fn crossbar_nominal() -> Self {
        OperatingPoint { vdd: 0.85, clock_ghz: 4.0 }
    }

    /// Nominal point used by the paper's Fig 7 sweeps (1 V, 1 GHz).
    pub fn sweep_nominal() -> Self {
        OperatingPoint { vdd: 1.0, clock_ghz: 1.0 }
    }

    /// Clock period in picoseconds.
    pub fn period_ps(&self) -> f64 {
        1000.0 / self.clock_ghz
    }
}

/// Technology-level electrical model (defaults ≈ 65 nm LSTP).
#[derive(Debug, Clone, Copy)]
pub struct SupplyModel {
    /// NMOS threshold voltage (V).
    pub vth: f64,
    /// Alpha-power-law velocity-saturation exponent.
    pub alpha: f64,
    /// Unit drive delay at nominal VDD (ps) — the RC of one cell driving
    /// its local node.
    pub tau0_ps: f64,
    /// Nominal supply (V).
    pub vdd_nom: f64,
    /// Activity factor for dynamic power.
    pub activity: f64,
    /// Leakage power at nominal VDD per femtofarad of loaded cap (µW/fF).
    pub leak_uw_per_ff: f64,
}

impl Default for SupplyModel {
    fn default() -> Self {
        // 65 nm LSTP-flavoured constants; tau0 calibrated so the paper's
        // 4-step / 2-cycle crossbar op settles at 4 GHz and 0.85 V with
        // boosted merge signals (Fig 3).
        SupplyModel {
            vth: 0.45,
            alpha: 1.3,
            tau0_ps: 9.0,
            vdd_nom: 1.0,
            activity: 0.5,
            leak_uw_per_ff: 0.002,
        }
    }
}

impl SupplyModel {
    /// Drive time constant τ(V) in ps (alpha-power law). Saturates to a
    /// huge-but-finite value below threshold so sweeps stay total.
    pub fn tau_ps(&self, vdd: f64) -> f64 {
        let ov = vdd - self.vth;
        if ov <= 0.01 {
            return 1.0e6; // effectively never settles
        }
        let nom = self.vdd_nom / (self.vdd_nom - self.vth).powf(self.alpha);
        self.tau0_ps * (vdd / ov.powf(self.alpha)) / nom
    }

    /// Fraction of the final value a node reaches when given `t_ps` to
    /// settle: `1 - exp(-t/τ)`.
    pub fn settling_fraction(&self, vdd: f64, t_ps: f64) -> f64 {
        1.0 - (-t_ps / self.tau_ps(vdd)).exp()
    }

    /// Dynamic switching power in µW for `c_total_ff` of switched
    /// capacitance at operating point `op`:
    /// `P = a · C · V² · f` (fF · V² · GHz ⇒ µW).
    pub fn dynamic_power_uw(&self, c_total_ff: f64, op: OperatingPoint) -> f64 {
        self.activity * c_total_ff * op.vdd * op.vdd * op.clock_ghz
    }

    /// Short-circuit / contention power (µW): grows with the fraction of
    /// each half-cycle during which rails are still slewing — at high
    /// clock or low VDD the crowbar current dominates. The crowbar time
    /// constant is much slower than a single node's RC (full-swing rails
    /// and boosted merge drivers overlap), hence the 20× factor; this
    /// places the escalation knee near 2.5 GHz at 1 V, matching the
    /// paper's Fig 7(c).
    pub fn short_circuit_power_uw(&self, c_total_ff: f64, op: OperatingPoint) -> f64 {
        let half_cycle = op.period_ps() / 2.0;
        let crowbar_tau = 20.0 * self.tau_ps(op.vdd);
        let slewing = (-half_cycle / crowbar_tau).exp();
        3.0 * slewing * self.dynamic_power_uw(c_total_ff, op)
    }

    /// Sensitivity of the settled fraction to threshold-voltage mismatch,
    /// `|∂ settle / ∂ Vth|` at `(vdd, t_ps)`.
    ///
    /// This is the mechanistic source of low-VDD compute errors: each
    /// cell's Vth differs slightly, so near threshold the *spread* of
    /// per-cell settling explodes (`∂τ/∂Vth = τ·α/(V−Vth)`), turning into
    /// differential noise the comparator cannot cancel. Far above
    /// threshold `exp(-t/τ) → 0` and the sensitivity vanishes — which is
    /// why nominal operation is clean.
    pub fn settle_vth_sensitivity(&self, vdd: f64, t_ps: f64) -> f64 {
        let ov = vdd - self.vth;
        if ov <= 0.01 {
            return 0.0; // nothing settles; handled by settling_fraction
        }
        let tau = self.tau_ps(vdd);
        let x = t_ps / tau;
        (-x).exp() * x * self.alpha / ov
    }

    /// Probability that a compute cell is *dead* at `vdd`: its sampled
    /// threshold voltage leaves no overdrive (`Vth > vdd − margin`).
    ///
    /// This is the dominant low-VDD failure on real arrays: minimum-size
    /// NMOS cells with Vth ~ N(vth, σ_vth) simply stop conducting as VDD
    /// approaches threshold. With σ_vth = 80 mV the population is intact
    /// above ~0.7 V and collapses below ~0.6 V — the Fig 7(a) cliff.
    pub fn dead_cell_prob(&self, vdd: f64, sigma_vth: f64) -> f64 {
        if sigma_vth <= 0.0 {
            return if vdd - Self::MIN_OVERDRIVE_V > self.vth { 0.0 } else { 1.0 };
        }
        let z = (self.vth - (vdd - Self::MIN_OVERDRIVE_V)) / sigma_vth;
        crate::util::stats::normal_cdf(z)
    }

    /// Minimum overdrive for a cell to contribute charge (V).
    pub const MIN_OVERDRIVE_V: f64 = 0.05;

    /// Subthreshold leakage power in µW (exponential in VDD).
    pub fn leakage_power_uw(&self, c_total_ff: f64, vdd: f64) -> f64 {
        self.leak_uw_per_ff * c_total_ff * (2.5 * (vdd - self.vdd_nom)).exp()
    }

    /// Total power (µW) at an operating point for a block with
    /// `c_total_ff` switched capacitance.
    pub fn total_power_uw(&self, c_total_ff: f64, op: OperatingPoint) -> f64 {
        self.dynamic_power_uw(c_total_ff, op)
            + self.short_circuit_power_uw(c_total_ff, op)
            + self.leakage_power_uw(c_total_ff, op.vdd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_increases_as_vdd_drops() {
        let m = SupplyModel::default();
        assert!(m.tau_ps(0.6) > m.tau_ps(0.8));
        assert!(m.tau_ps(0.8) > m.tau_ps(1.2));
    }

    #[test]
    fn tau_nominal_is_tau0() {
        let m = SupplyModel::default();
        assert!((m.tau_ps(m.vdd_nom) - m.tau0_ps).abs() < 1e-9);
    }

    #[test]
    fn below_threshold_never_settles() {
        let m = SupplyModel::default();
        assert!(m.settling_fraction(0.4, 1000.0) < 0.01);
    }

    #[test]
    fn settling_monotone_in_time() {
        let m = SupplyModel::default();
        let s1 = m.settling_fraction(1.0, 5.0);
        let s2 = m.settling_fraction(1.0, 50.0);
        assert!(s2 > s1);
        assert!(s2 <= 1.0);
    }

    #[test]
    fn dynamic_power_quadratic_in_vdd() {
        let m = SupplyModel::default();
        let p1 = m.dynamic_power_uw(100.0, OperatingPoint::new(0.6, 1.0));
        let p2 = m.dynamic_power_uw(100.0, OperatingPoint::new(1.2, 1.0));
        assert!((p2 / p1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn power_escalates_superlinearly_at_high_clock() {
        // Paper Fig 7(c): beyond ~2.5 GHz average power escalates faster
        // than the linear C·V²·f trend (short-circuit current).
        let m = SupplyModel::default();
        let c = 500.0;
        let p1 = m.total_power_uw(c, OperatingPoint::new(1.0, 1.0));
        let p3 = m.total_power_uw(c, OperatingPoint::new(1.0, 3.0));
        let p6 = m.total_power_uw(c, OperatingPoint::new(1.0, 6.0));
        // Linear prediction from 1 GHz:
        assert!(p3 > 3.0 * p1 * 1.02, "p3={p3} linear={}", 3.0 * p1);
        assert!(p6 / p3 > 2.0, "super-linear escalation expected");
    }

    #[test]
    fn leakage_exponential_in_vdd() {
        let m = SupplyModel::default();
        let l_lo = m.leakage_power_uw(100.0, 0.8);
        let l_hi = m.leakage_power_uw(100.0, 1.3);
        assert!(l_hi > 3.0 * l_lo);
    }

    #[test]
    fn period_ps() {
        assert!((OperatingPoint::new(1.0, 4.0).period_ps() - 250.0).abs() < 1e-12);
    }
}
