//! Dense Hadamard/Walsh matrix construction.
//!
//! The Sylvester recursion (paper eq. (2)) builds the *natural order*
//! Hadamard matrix; sorting rows by sign-change count ("sequency") gives
//! the *Walsh* matrix used by the paper (and by signal-processing
//! convention, where sequency plays the role frequency plays for the DFT).

/// Dense `m x m` Hadamard matrix in natural (Sylvester) order, entries ±1.
///
/// `m` must be a power of two. Row-major storage as `i8` (±1) — matrices
/// are only materialised for tests, crossbar programming and the dense
/// oracle; the compute path uses [`super::fwht`].
pub fn hadamard(m: usize) -> Vec<i8> {
    assert!(m.is_power_of_two(), "Hadamard order must be a power of two, got {m}");
    let mut h = vec![0i8; m * m];
    h[0] = 1;
    let mut n = 1;
    // Sylvester doubling: H_{k} = [[H, H], [H, -H]].
    while n < m {
        for r in 0..n {
            for c in 0..n {
                let v = h[r * m + c];
                h[r * m + (c + n)] = v;
                h[(r + n) * m + c] = v;
                h[(r + n) * m + (c + n)] = -v;
            }
        }
        n *= 2;
    }
    h
}

/// Number of sign changes along a ±1 row — the row's *sequency*.
pub fn sequency_of_row(row: &[i8]) -> usize {
    row.windows(2).filter(|w| w[0] != w[1]).count()
}

/// Dense `m x m` *Walsh* matrix: Hadamard rows re-ordered by ascending
/// sequency. The re-ordering is the bit-reversed Gray-code permutation;
/// we compute it directly from the measured sequency which is simpler and
/// self-checking.
pub fn walsh(m: usize) -> Vec<i8> {
    let h = hadamard(m);
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by_key(|&r| sequency_of_row(&h[r * m..(r + 1) * m]));
    let mut w = vec![0i8; m * m];
    for (dst, &src) in order.iter().enumerate() {
        w[dst * m..(dst + 1) * m].copy_from_slice(&h[src * m..(src + 1) * m]);
    }
    w
}

/// Dense matrix–vector product `M x` for a ±1 matrix (oracle path).
pub fn pm1_matvec(mat: &[i8], m: usize, x: &[f32]) -> Vec<f32> {
    assert_eq!(mat.len(), m * m);
    assert_eq!(x.len(), m);
    let mut y = vec![0.0f32; m];
    for r in 0..m {
        let row = &mat[r * m..(r + 1) * m];
        let mut acc = 0.0f32;
        for (v, &xi) in row.iter().zip(x) {
            // ±1 entries: add or subtract, never multiply — mirrors hardware.
            if *v > 0 {
                acc += xi;
            } else {
                acc -= xi;
            }
        }
        y[r] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadamard_order_1_2_4() {
        assert_eq!(hadamard(1), vec![1]);
        assert_eq!(hadamard(2), vec![1, 1, 1, -1]);
        let h4 = hadamard(4);
        #[rustfmt::skip]
        let expect: Vec<i8> = vec![
            1,  1,  1,  1,
            1, -1,  1, -1,
            1,  1, -1, -1,
            1, -1, -1,  1,
        ];
        assert_eq!(h4, expect);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn hadamard_rejects_non_pow2() {
        hadamard(6);
    }

    /// Orthogonality: H Hᵀ = m I for every row pair.
    #[test]
    fn hadamard_rows_orthogonal() {
        for k in 0..6 {
            let m = 1usize << k;
            let h = hadamard(m);
            for r1 in 0..m {
                for r2 in 0..m {
                    let dot: i32 = (0..m)
                        .map(|c| i32::from(h[r1 * m + c]) * i32::from(h[r2 * m + c]))
                        .sum();
                    let expect = if r1 == r2 { m as i32 } else { 0 };
                    assert_eq!(dot, expect, "m={m} rows {r1},{r2}");
                }
            }
        }
    }

    /// Walsh ordering: sequency strictly increases row by row and spans 0..m-1.
    #[test]
    fn walsh_sequency_is_identity_ramp() {
        for k in 1..8 {
            let m = 1usize << k;
            let w = walsh(m);
            for r in 0..m {
                assert_eq!(sequency_of_row(&w[r * m..(r + 1) * m]), r, "m={m} row {r}");
            }
        }
    }

    #[test]
    fn walsh_is_row_permutation_of_hadamard() {
        let m = 16;
        let h = hadamard(m);
        let w = walsh(m);
        let mut h_rows: Vec<&[i8]> = (0..m).map(|r| &h[r * m..(r + 1) * m]).collect();
        let mut w_rows: Vec<&[i8]> = (0..m).map(|r| &w[r * m..(r + 1) * m]).collect();
        h_rows.sort();
        w_rows.sort();
        assert_eq!(h_rows, w_rows);
    }

    #[test]
    fn pm1_matvec_identity_on_first_row() {
        // First Hadamard row is all-ones: y[0] = sum(x).
        let m = 8;
        let h = hadamard(m);
        let x: Vec<f32> = (0..m).map(|i| i as f32).collect();
        let y = pm1_matvec(&h, m, &x);
        assert_eq!(y[0], x.iter().sum::<f32>());
    }
}
